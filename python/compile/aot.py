"""AOT pipeline: lower every L2/L1 entry point to HLO TEXT under artifacts/.

HLO *text* (never ``.serialize()``): jax >= 0.5 emits HloModuleProto with
64-bit instruction ids which the rust side's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md and gen_hlo.py there).

Run once via ``make artifacts`` — Python never executes on the request
path. Also writes ``artifacts/manifest.json`` recording the shapes baked
into each artifact so the rust loader can sanity-check.

Usage: cd python && python -m compile.aot [--out ../artifacts]
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model as M
from compile.kernels import mv_poly


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def model_artifacts():
    """(name, fn, abstract args) for every model entry point."""
    out = []
    for mname, spec in M.MODELS.items():
        eps = M.make_entry_points(spec)
        d, i, k, b = spec.dim, spec.in_dim, 10, M.BATCH
        out.append((f"{mname}_grad", eps["grad"], (f32(d), f32(b, i), f32(b, k))))
        out.append(
            (f"{mname}_signgrad", eps["signgrad"], (f32(d), f32(b, i), f32(b, k)))
        )
        out.append((f"{mname}_logits", eps["logits"], (f32(d), f32(b, i))))
    return out


def kernel_artifacts():
    """The standalone mv_poly kernel at the vote dimensions rust uses."""
    out = []
    for d in (1024, 8192, 25600):
        # 8192 = pad(7850 linear), 25600 = pad(25450 mlp) to BLOCK=512.
        def entry(x, coeffs):
            return (mv_poly.mv_poly_eval(x, coeffs),)

        out.append(
            (f"mv_poly_d{d}", entry, (i32(d), i32(mv_poly.MAX_COEFFS + 1)))
        )
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument(
        "--only", default=None, help="comma-separated artifact-name filter"
    )
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    only = set(args.only.split(",")) if args.only else None

    manifest = {}
    for name, fn, abstract_args in model_artifacts() + kernel_artifacts():
        if only and name not in only:
            continue
        lowered = jax.jit(fn).lower(*abstract_args)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest[name] = {
            "inputs": [list(a.shape) for a in abstract_args],
            "dtypes": [str(a.dtype) for a in abstract_args],
            "bytes": len(text),
        }
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {os.path.join(args.out, 'manifest.json')}")


if __name__ == "__main__":
    main()
