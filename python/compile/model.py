"""L2: JAX models for the FL workload — forward, loss, gradient, and the
sign-gradient path that calls the L1 Pallas kernels.

Parameters are FLAT f32 vectors (the vote dimension `d` of the protocol);
(un)flattening happens inside the jitted functions so the rust side only
ever handles one tensor per model. Layouts match the rust reference models
in `rust/src/fl/model.rs` exactly:

* linear:  [W (k×in) row-major, b (k)]
* mlp:     [W1 (h×in), b1 (h), W2 (k×h), b2 (k)]

so the two backends are cross-checkable coordinate by coordinate.
"""

import dataclasses

import jax
import jax.numpy as jnp

from compile.kernels import sign_quant


@dataclasses.dataclass(frozen=True)
class LinearSpec:
    in_dim: int
    n_classes: int

    @property
    def dim(self):
        return self.in_dim * self.n_classes + self.n_classes

    def unflatten(self, params):
        w = params[: self.in_dim * self.n_classes].reshape(
            self.n_classes, self.in_dim
        )
        b = params[self.in_dim * self.n_classes :]
        return w, b

    def logits(self, params, x):
        w, b = self.unflatten(params)
        return x @ w.T + b


@dataclasses.dataclass(frozen=True)
class MlpSpec:
    in_dim: int
    hidden: int
    n_classes: int

    @property
    def dim(self):
        return (
            self.hidden * self.in_dim
            + self.hidden
            + self.n_classes * self.hidden
            + self.n_classes
        )

    def unflatten(self, params):
        h, i, k = self.hidden, self.in_dim, self.n_classes
        at = 0
        w1 = params[at : at + h * i].reshape(h, i)
        at += h * i
        b1 = params[at : at + h]
        at += h
        w2 = params[at : at + k * h].reshape(k, h)
        at += k * h
        b2 = params[at : at + k]
        return w1, b1, w2, b2

    def logits(self, params, x):
        w1, b1, w2, b2 = self.unflatten(params)
        hid = jax.nn.relu(x @ w1.T + b1)
        return hid @ w2.T + b2


def cross_entropy(logits, y_onehot):
    """Mean softmax cross-entropy (y is one-hot f32)."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.sum(y_onehot * logp, axis=-1))


def make_entry_points(spec):
    """Build the jittable functions the AOT pipeline lowers.

    Returns dict of name -> (fn, abstract-arg builder). All fns return
    tuples (lowered with return_tuple=True for the rust loader).
    """

    def loss_fn(params, x, y):
        return cross_entropy(spec.logits(params, x), y)

    grad_fn = jax.value_and_grad(loss_fn)

    def grad_entry(params, x, y):
        loss, g = grad_fn(params, x, y)
        return (loss, g)

    def signgrad_entry(params, x, y):
        """Gradient + L1 Pallas sign quantization (Eq. 4) fused into one
        artifact: the sign kernel lowers into the same HLO module."""
        loss, g = grad_fn(params, x, y)
        d = g.shape[0]
        pad = (-d) % sign_quant.BLOCK
        gp = jnp.pad(g, (0, pad))
        s = sign_quant.sign_quantize(gp)[:d]
        return (loss, s)

    def logits_entry(params, x):
        return (spec.logits(params, x),)

    return {
        "grad": grad_entry,
        "signgrad": signgrad_entry,
        "logits": logits_entry,
    }


# The model zoo the artifacts are built from. Dimensions mirror the
# experiment presets (mnist/fmnist: 784-in; cifar-like: 3072-in).
MODELS = {
    "mnist_linear": LinearSpec(in_dim=784, n_classes=10),
    "mnist_mlp": MlpSpec(in_dim=784, hidden=32, n_classes=10),
    "cifar_mlp": MlpSpec(in_dim=3072, hidden=32, n_classes=10),
}

BATCH = 100
