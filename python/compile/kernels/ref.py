"""Pure-jnp oracles for the Pallas kernels (the CORE correctness signal).

Every kernel in this package has a reference here written with plain
jax.numpy ops and no Pallas; pytest sweeps shapes/dtypes with hypothesis
and asserts exact equality (integer kernels) / allclose (float kernels).
"""

import jax.numpy as jnp


def mv_poly_ref(x, coeffs, p):
    """Horner evaluation of sum_k coeffs[k] x^k mod p, canonical output.

    Args:
      x: int array of canonical field elements.
      coeffs: 1-D int array/list of polynomial coefficients (index = power).
      p: modulus.
    """
    x = jnp.asarray(x, dtype=jnp.int32)  # products < p² ≤ 101² fit easily
    acc = jnp.zeros_like(x)
    for c in reversed(list(coeffs)):
        acc = (acc * x + int(c)) % int(p)
    return acc.astype(jnp.int32)


def sign_ref(g):
    """SIGNSGD sign with sign(0) = +1."""
    g = jnp.asarray(g)
    return jnp.where(g < 0.0, -1.0, 1.0).astype(jnp.float32)


def majority_vote_ref(signs, tie_to=-1):
    """Plain SIGNSGD-MV: sign of the column sum of an (n, d) ±1 matrix.

    tie_to: value for zero sums (-1 = the paper's 1-bit policy; 0 = 2-bit).
    """
    s = jnp.sum(jnp.asarray(signs, dtype=jnp.int32), axis=0)
    vote = jnp.sign(s)
    return jnp.where(s == 0, int(tie_to), vote).astype(jnp.int32)
