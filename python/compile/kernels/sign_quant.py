"""L1 Pallas kernel: 1-bit sign quantization (Eq. 4).

``x_i(t) = sign(g_i(t))`` with the SIGNSGD convention sign(0) = +1 —
matching the rust trainer's ``fl::model::sign_vec``. The kernel tiles the
gradient into VMEM blocks and emits ±1.0f32 (the sign vector is consumed
by the field encoder / vote pipeline, which wants a dense ±1 array rather
than packed bits at this layer).

interpret=True: see mv_poly.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 512


def _sign_kernel(g_ref, o_ref):
    g = g_ref[...]
    o_ref[...] = jnp.where(g < 0.0, -1.0, 1.0).astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def sign_quantize(g, *, interpret=True):
    """±1 quantization of a flat f32 gradient (length multiple of BLOCK)."""
    d = g.shape[0]
    if d % BLOCK != 0:
        raise ValueError(f"d = {d} must be a multiple of BLOCK = {BLOCK}")
    return pl.pallas_call(
        _sign_kernel,
        out_shape=jax.ShapeDtypeStruct((d,), jnp.float32),
        grid=(d // BLOCK,),
        in_specs=[pl.BlockSpec((BLOCK,), lambda i: (i,))],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        interpret=interpret,
    )(g)
