"""L1 Pallas kernel: majority-vote polynomial evaluation over F_p.

The server-side vote readout of Hi-SAFE evaluates

    F(x)_j = sum_k c_k * x_j^k  (mod p)        for j = 1..d

on a model-sized vector ``x`` of canonical field elements (d ~ 10^4..10^5)
with a tiny coefficient vector (deg(F) <= 32 for every group size the
paper sweeps). The kernel is a **vectorized Horner scan over VMEM-resident
int32 tiles**:

* ``BlockSpec`` splits the d-vector into ``BLOCK``-lane tiles streamed
  HBM->VMEM; the coefficient vector is broadcast to every tile (index_map
  pins it to block 0).
* Each tile performs the full Horner recurrence ``acc = (acc*x + c_k) % p``
  entirely in VMEM — no HBM round-trips between Horner steps. This is the
  TPU re-think of the paper's per-coordinate loop (DESIGN.md
  §Hardware-Adaptation): registers -> VMEM tile, threadblock -> grid row.
* The loop over coefficients is statically unrolled (``MAX_COEFFS`` is a
  compile-time bound); unused high coefficients are zero and cost one
  fused multiply-add-mod each — deg <= 32 keeps that negligible.

Layout convention shared with the rust loader (`runtime::MvPolyKernel`):
``coeffs`` has ``MAX_COEFFS + 1`` slots; slots ``[0, MAX_COEFFS)`` are the
polynomial coefficients (zero-padded), and the **last slot carries p** so
the artifact keeps a two-input signature.

Overflow note: all values are canonical (< p <= 131), so
``acc * x + c < 131^2 + 131 << 2^31`` — exact in int32.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; real-TPU performance is assessed analytically in DESIGN.md.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Compile-time bounds shared with rust (runtime.rs::MvPolyKernel).
MAX_COEFFS = 32
BLOCK = 512


def _horner_kernel(x_ref, c_ref, o_ref):
    """One VMEM tile: full Horner recurrence, statically unrolled."""
    x = x_ref[...]
    p = c_ref[MAX_COEFFS]
    acc = jnp.zeros_like(x)
    # Horner from the highest stored coefficient down to c_0.
    for k in reversed(range(MAX_COEFFS)):
        acc = (acc * x + c_ref[k]) % p
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("interpret",))
def mv_poly_eval(x, coeffs, *, interpret=True):
    """Evaluate F on canonical int32 inputs.

    Args:
      x: int32[d] canonical field elements, d divisible by BLOCK (callers
         pad; the rust side bakes d per artifact).
      coeffs: int32[MAX_COEFFS + 1]; see module docstring for layout.

    Returns:
      int32[d] with ``F(x) mod p`` (canonical).
    """
    d = x.shape[0]
    if d % BLOCK != 0:
        raise ValueError(f"d = {d} must be a multiple of BLOCK = {BLOCK}")
    if coeffs.shape != (MAX_COEFFS + 1,):
        raise ValueError(f"coeffs must have shape ({MAX_COEFFS + 1},)")
    grid = (d // BLOCK,)
    return pl.pallas_call(
        _horner_kernel,
        out_shape=jax.ShapeDtypeStruct((d,), jnp.int32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            # broadcast the whole coefficient vector to every tile
            pl.BlockSpec((MAX_COEFFS + 1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        interpret=interpret,
    )(x, coeffs)


def pack_coeffs(coeffs, p):
    """Pack a python coefficient list + modulus into the kernel layout."""
    if len(coeffs) > MAX_COEFFS:
        raise ValueError(f"deg(F) too large: {len(coeffs) - 1} > {MAX_COEFFS - 1}")
    out = [0] * (MAX_COEFFS + 1)
    out[: len(coeffs)] = [int(c) % int(p) for c in coeffs]
    out[MAX_COEFFS] = int(p)
    return jnp.array(out, dtype=jnp.int32)
