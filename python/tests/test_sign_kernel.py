"""L1 correctness: sign_quant Pallas kernel vs oracle (sign(0) = +1)."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, sign_quant


@given(
    blocks=st.integers(min_value=1, max_value=8),
    seed=st.integers(min_value=0, max_value=2**32 - 1),
    scale=st.sampled_from([1e-6, 1.0, 1e6]),
)
@settings(max_examples=30, deadline=None)
def test_sign_matches_ref(blocks, seed, scale):
    rng = np.random.default_rng(seed)
    d = blocks * sign_quant.BLOCK
    g = (rng.standard_normal(d) * scale).astype(np.float32)
    # plant exact zeros and negative zeros
    g[:: 17] = 0.0
    g[1:: 23] = -0.0
    out = np.asarray(sign_quant.sign_quantize(jnp.asarray(g)))
    want = np.asarray(ref.sign_ref(g))
    np.testing.assert_array_equal(out, want)
    assert set(np.unique(out)).issubset({-1.0, 1.0})
    # zero maps to +1 (SIGNSGD convention, matches rust sign_vec)
    assert out[0] == 1.0


def test_majority_vote_ref_tie_policies():
    signs = np.array([[1, 1, -1], [1, -1, -1], [-1, 1, 1], [-1, -1, 1]])
    one_bit = np.asarray(ref.majority_vote_ref(signs, tie_to=-1))
    two_bit = np.asarray(ref.majority_vote_ref(signs, tie_to=0))
    np.testing.assert_array_equal(one_bit, [-1, -1, -1])
    np.testing.assert_array_equal(two_bit, [0, 0, 0])
