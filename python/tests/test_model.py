"""L2 correctness: model entry points — shapes, gradients, sign path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


@pytest.fixture(scope="module")
def batch():
    rng = np.random.default_rng(0)
    x = rng.standard_normal((M.BATCH, 784)).astype(np.float32)
    y = np.zeros((M.BATCH, 10), np.float32)
    y[np.arange(M.BATCH), rng.integers(0, 10, M.BATCH)] = 1.0
    return jnp.asarray(x), jnp.asarray(y)


@pytest.mark.parametrize("name", ["mnist_linear", "mnist_mlp"])
def test_entry_point_shapes(name, batch):
    spec = M.MODELS[name]
    eps = M.make_entry_points(spec)
    params = jnp.zeros(spec.dim, jnp.float32)
    x, y = batch
    loss, g = eps["grad"](params, x, y)
    assert loss.shape == ()
    assert g.shape == (spec.dim,)
    (logits,) = eps["logits"](params, x)
    assert logits.shape == (M.BATCH, 10)
    loss2, s = eps["signgrad"](params, x, y)
    assert s.shape == (spec.dim,)
    assert jnp.allclose(loss, loss2)
    assert set(np.unique(np.asarray(s))).issubset({-1.0, 1.0})


def test_signgrad_is_sign_of_grad(batch):
    spec = M.MODELS["mnist_linear"]
    eps = M.make_entry_points(spec)
    rng = np.random.default_rng(3)
    params = jnp.asarray(rng.standard_normal(spec.dim).astype(np.float32) * 0.05)
    x, y = batch
    _, g = eps["grad"](params, x, y)
    _, s = eps["signgrad"](params, x, y)
    want = np.where(np.asarray(g) < 0, -1.0, 1.0)
    np.testing.assert_array_equal(np.asarray(s), want)


def test_grad_matches_finite_difference(batch):
    spec = M.MODELS["mnist_linear"]
    eps = M.make_entry_points(spec)
    x, y = batch
    rng = np.random.default_rng(1)
    params = rng.standard_normal(spec.dim).astype(np.float32) * 0.05

    def loss_np(p):
        l, _ = eps["grad"](jnp.asarray(p), x, y)
        return float(l)

    _, g = eps["grad"](jnp.asarray(params), x, y)
    g = np.asarray(g)
    eps_fd = 1e-3
    for j in rng.integers(0, spec.dim, size=10):
        pp = params.copy()
        pp[j] += eps_fd
        lp = loss_np(pp)
        pp[j] -= 2 * eps_fd
        lm = loss_np(pp)
        fd = (lp - lm) / (2 * eps_fd)
        assert abs(fd - g[j]) < 2e-2 * (1 + abs(fd)), f"coord {j}: {fd} vs {g[j]}"


def test_param_layout_matches_rust_convention():
    """W row-major [class][pixel] then bias — the layout rust unpacks."""
    spec = M.MODELS["mnist_linear"]
    params = np.zeros(spec.dim, np.float32)
    # set W[3][5] = 2.0 and b[7] = 1.5 using the documented layout
    params[3 * 784 + 5] = 2.0
    params[784 * 10 + 7] = 1.5
    x = np.zeros((M.BATCH, 784), np.float32)
    x[:, 5] = 1.0
    (logits,) = M.make_entry_points(spec)["logits"](
        jnp.asarray(params), jnp.asarray(x)
    )
    assert float(logits[0, 3]) == 2.0
    assert float(logits[0, 7]) == 1.5
    assert float(logits[0, 0]) == 0.0


def test_mlp_dim_matches_rust():
    assert M.MODELS["mnist_mlp"].dim == 784 * 32 + 32 + 320 + 10
    assert M.MODELS["cifar_mlp"].dim == 3072 * 32 + 32 + 320 + 10
    assert M.MODELS["mnist_linear"].dim == 7850
