"""L1 correctness: mv_poly Pallas kernel vs the pure-jnp oracle.

hypothesis sweeps dimensions, moduli, coefficient vectors and inputs;
equality is exact (integer arithmetic).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import mv_poly, ref

PRIMES = [3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37, 41, 61, 101]


def eval_poly_int(x, coeffs, p):
    """Plain-python oracle (independent of jax)."""
    acc = 0
    for c in reversed(coeffs):
        acc = (acc * x + c) % p
    return acc


@given(
    p=st.sampled_from(PRIMES),
    deg=st.integers(min_value=0, max_value=mv_poly.MAX_COEFFS - 1),
    blocks=st.integers(min_value=1, max_value=4),
    seed=st.integers(min_value=0, max_value=2**32 - 1),
)
@settings(max_examples=40, deadline=None)
def test_kernel_matches_ref_and_python(p, deg, blocks, seed):
    rng = np.random.default_rng(seed)
    d = blocks * mv_poly.BLOCK
    coeffs = [int(c) for c in rng.integers(0, p, size=deg + 1)]
    x = rng.integers(0, p, size=d).astype(np.int32)
    packed = mv_poly.pack_coeffs(coeffs, p)

    out = np.asarray(mv_poly.mv_poly_eval(jnp.asarray(x), packed))
    want_ref = np.asarray(ref.mv_poly_ref(x, coeffs, p))
    np.testing.assert_array_equal(out, want_ref)
    # spot-check against the plain-python oracle
    for j in rng.integers(0, d, size=8):
        assert out[j] == eval_poly_int(int(x[j]), coeffs, p)


@pytest.mark.parametrize(
    "n,p,coeffs",
    [
        # Table III (1-bit tie-breaking): exact published polynomials.
        (2, 3, [2, 2, 1]),            # x^2 + 2x + 2 (mod 3)
        (3, 5, [0, 4, 0, 2]),         # 2x^3 + 4x (mod 5)
        (4, 5, [4, 1, 0, 3, 1]),      # x^4 + 3x^3 + x + 4 (mod 5)
        (5, 7, [0, 3, 0, 2, 0, 3]),   # 3x^5 + 2x^3 + 3x (mod 7)
        (6, 7, [6, 4, 0, 5, 0, 4, 1]),  # x^6+4x^5+5x^3+4x+6 (mod 7)
    ],
)
def test_kernel_computes_correct_majority_votes(n, p, coeffs):
    """Lemma 1 through the kernel: F(sum) == sign(sum) on the support."""
    packed = mv_poly.pack_coeffs(coeffs, p)
    sums = list(range(-n, n + 1, 2))
    x = np.array([s % p for s in sums] * mv_poly.BLOCK, dtype=np.int32)[
        : mv_poly.BLOCK
    ]
    out = np.asarray(mv_poly.mv_poly_eval(jnp.asarray(x), packed))
    for j, s in enumerate(sums):
        got = int(out[j])
        centered = got - p if got > p // 2 else got
        want = 1 if s > 0 else (-1 if s < 0 else -1)  # tie -> -1 (1-bit)
        assert centered == want, f"n={n} sum={s}: F={centered} != {want}"


def test_rejects_bad_shapes():
    with pytest.raises(ValueError):
        mv_poly.mv_poly_eval(
            jnp.zeros(100, jnp.int32), mv_poly.pack_coeffs([1], 5)
        )
    with pytest.raises(ValueError):
        mv_poly.pack_coeffs([0] * (mv_poly.MAX_COEFFS + 1), 5)


def test_zero_polynomial():
    packed = mv_poly.pack_coeffs([0], 7)
    x = jnp.arange(mv_poly.BLOCK, dtype=jnp.int32) % 7
    out = np.asarray(mv_poly.mv_poly_eval(x, packed))
    np.testing.assert_array_equal(out, np.zeros(mv_poly.BLOCK, np.int32))
