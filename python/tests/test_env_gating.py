"""Dependency-free suite: keeps `pytest python/tests` collecting at least
one test on runners without JAX/hypothesis (pytest exits 5 on an empty
collection, which would fail CI), and sanity-checks the conftest gating
logic itself plus a pure-python majority-vote oracle.
"""

import importlib.util


def _have(mod: str) -> bool:
    try:
        return importlib.util.find_spec(mod) is not None
    except (ImportError, ValueError):
        return False


def test_gating_matches_environment():
    import glob
    import os

    import conftest

    root = os.path.dirname(conftest.__file__)
    all_tests = sorted(
        os.path.relpath(p, root)
        for p in glob.glob(os.path.join(root, "python", "tests", "test_*.py"))
    )
    ignored = sorted(conftest.collect_ignore)
    # every ignored entry is a real test module, and this dependency-free
    # module is never ignored (it guarantees a non-empty collection)
    assert set(ignored) <= set(all_tests)
    this = os.path.join("python", "tests", "test_env_gating.py")
    assert this not in ignored
    known_jax = {
        os.path.join("python", "tests", n)
        for n in (
            "test_aot.py",
            "test_model.py",
            "test_mv_poly_kernel.py",
            "test_sign_kernel.py",
        )
    }
    if not _have("jax"):
        # the known jax-importing modules must all be ignored
        assert known_jax <= set(ignored)
    elif _have("hypothesis"):
        assert ignored == []
    else:
        # only hypothesis-based modules are ignored; currently both exist
        assert os.path.join("python", "tests", "test_mv_poly_kernel.py") in ignored
        assert os.path.join("python", "tests", "test_sign_kernel.py") in ignored
        assert os.path.join("python", "tests", "test_model.py") not in ignored


def test_majority_vote_oracle_pure_python():
    # sign(sum) over the support, with the paper's tie -> -1 policy —
    # the invariant every layer (pallas kernel, rust field, MPC) encodes.
    def vote(signs):
        s = sum(signs)
        return 1 if s > 0 else -1

    assert vote([1, 1, -1]) == 1
    assert vote([1, -1]) == -1  # tie -> -1 (Table III, 1-bit policy)
    assert vote([-1, -1, 1]) == -1
    # exhaustive n=3: majority always wins
    for a in (-1, 1):
        for b in (-1, 1):
            for c in (-1, 1):
                want = 1 if a + b + c > 0 else -1
                assert vote([a, b, c]) == want
