"""AOT pipeline smoke tests: lowering produces loadable HLO text."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp

from compile import aot, model as M
from compile.kernels import mv_poly


def test_to_hlo_text_smoke():
    spec = M.MODELS["mnist_linear"]
    eps = M.make_entry_points(spec)
    lowered = jax.jit(eps["logits"]).lower(
        aot.f32(spec.dim), aot.f32(M.BATCH, spec.in_dim)
    )
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    assert "f32[" in text
    # return_tuple=True → tuple root
    assert "tuple" in text.lower()


def test_kernel_artifact_lowers_with_pallas_inlined():
    (name, fn, args) = aot.kernel_artifacts()[0]
    assert name == "mv_poly_d1024"
    lowered = jax.jit(fn).lower(*args)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    # interpret=True must lower to plain HLO — no Mosaic custom-calls that
    # the CPU PJRT client can't execute
    assert "tpu_custom_call" not in text
    assert "mosaic" not in text.lower()
    assert "s32[1024]" in text


def test_artifact_list_covers_models_and_kernels():
    names = [n for (n, _, _) in aot.model_artifacts()]
    for m in M.MODELS:
        for suffix in ("grad", "signgrad", "logits"):
            assert f"{m}_{suffix}" in names
    knames = [n for (n, _, _) in aot.kernel_artifacts()]
    assert "mv_poly_d1024" in knames


def test_executable_end_to_end_via_jax():
    """The lowered computation computes the same numbers as eager jax."""
    (name, fn, args) = aot.kernel_artifacts()[0]
    del name
    x = jnp.arange(1024, dtype=jnp.int32) % 5
    coeffs = mv_poly.pack_coeffs([0, 4, 0, 2], 5)  # 2x^3+4x mod 5
    (eager,) = fn(x, coeffs)
    compiled = jax.jit(fn).lower(x, coeffs).compile()
    (aotted,) = compiled(x, coeffs)
    assert (eager == aotted).all()
