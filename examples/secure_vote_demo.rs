//! Appendix-A walkthrough: secure evaluation of F(x) = 2x³ + 4x (mod 5)
//! with n = 3 users holding x₁ = +1, x₂ = −1, x₃ = +1.
//!
//! Prints every subround — masked uploads, server openings, power shares,
//! final shares — and asserts the *protocol-level invariants* of the
//! published example (the paper's concrete numbers depend on its specific
//! Beaver shares; the invariants are what must hold for any shares):
//!   * reconstructed x − a¹, x − b¹ equal the openings the server got,
//!   * Σᵢ ⟦x²⟧ᵢ = x², Σᵢ ⟦x³⟧ᵢ = x³ (mod 5),
//!   * Σᵢ ⟦F(x)⟧ᵢ = F(1) = 1 = sign(+1).
//!
//! ```bash
//! cargo run --release --example secure_vote_demo
//! ```

use std::sync::Arc;

use hisafe::beaver::Dealer;
use hisafe::field::Fp;
use hisafe::mpc::{EvalPlan, Party, Server};
use hisafe::poly::{MvPolynomial, TiePolicy};
use hisafe::sharing::reconstruct_vec;

fn main() {
    let signs: Vec<i8> = vec![1, -1, 1];
    let n = signs.len();
    let mv = MvPolynomial::build_fermat(n, TiePolicy::OneBit);
    let fp: Fp = mv.fp;
    println!("=== Appendix A: secure evaluation of F(x) = {} ===", mv.poly.display());
    println!("users: x₁ = +1, x₂ = −1, x₃ = +1  ⇒  x = Σxᵢ = 1, sign(x) = +1\n");

    let plan = Arc::new(EvalPlan::new(&mv, 1, false));
    println!(
        "power schedule: {:?}\n",
        plan.schedule.steps.iter().map(|s| format!("x^{} = x^{}·x^{} @subround {}", s.target, s.left, s.right, s.depth)).collect::<Vec<_>>()
    );

    // Offline phase: Beaver triples (dealer-simulated MPC).
    let mut dealer = Dealer::new(fp, 2024);
    let mut triples = dealer.gen_round(1, n, plan.triples_needed());
    for r in 0..plan.triples_needed() {
        let a = reconstruct_vec(fp, &triples.iter().map(|t| t[r].a.clone()).collect::<Vec<_>>())[0];
        let b = reconstruct_vec(fp, &triples.iter().map(|t| t[r].b.clone()).collect::<Vec<_>>())[0];
        let c = reconstruct_vec(fp, &triples.iter().map(|t| t[r].c.clone()).collect::<Vec<_>>())[0];
        println!("triple r={}: a={a}, b={b}, c={c}  (c = a·b mod 5: {})", r + 1, fp.mul(a, b));
        assert_eq!(c, fp.mul(a, b));
    }

    let mut parties: Vec<Party> = signs
        .iter()
        .enumerate()
        .map(|(i, &s)| {
            Party::new(
                Arc::clone(&plan),
                i,
                fp.encode_signs(&[s]),
                std::mem::take(&mut triples[i]),
            )
        })
        .collect();
    let mut server = Server::new(Arc::clone(&plan));

    // true aggregate (the protocol never materializes this in one place)
    let x_true = fp.from_i64(signs.iter().map(|&s| s as i64).sum());

    for depth in 0..plan.schedule.depth() {
        println!("\n--- subround {depth} ---");
        let ups: Vec<_> = parties.iter().map(|p| p.uplink(depth)).collect();
        for u in &ups {
            for pair in &u.pairs {
                println!(
                    "  user {} uploads masked pair (mult #{}): d_i = {}, e_i = {}",
                    u.party + 1, pair.mult_idx + 1, pair.d_share[0], pair.e_share[0]
                );
            }
        }
        let bcast = server.aggregate(&ups);
        for o in &bcast.openings {
            let step = plan.schedule.steps[o.mult_idx];
            println!(
                "  server opens mult #{} (x^{} = x^{}·x^{}): δ = {}, ε = {}",
                o.mult_idx + 1, step.target, step.left, step.right, o.delta[0], o.eps[0]
            );
        }
        for p in parties.iter_mut() {
            p.absorb(&bcast);
        }
        // invariant: reconstructed power shares equal the true powers
        for st in plan.schedule.by_depth()[depth].iter() {
            let shares: Vec<Vec<u64>> = parties
                .iter()
                .map(|p| p.power_share(st.target).expect("power computed").clone())
                .collect();
            let rec = reconstruct_vec(fp, &shares)[0];
            let truth = fp.pow(x_true, st.target as u64);
            assert_eq!(rec, truth, "Σᵢ ⟦x^{}⟧ᵢ must equal x^{}", st.target, st.target);
            println!(
                "  ⇒ Σᵢ ⟦x^{}⟧ᵢ = {} = x^{} (mod 5) ✓ (shares: {:?})",
                st.target, rec, st.target,
                shares.iter().map(|s| s[0]).collect::<Vec<_>>()
            );
        }
    }

    println!("\n--- final shares ---");
    let finals: Vec<Vec<u64>> = parties.iter().map(|p| p.final_share()).collect();
    for (i, f) in finals.iter().enumerate() {
        println!("  user {} sends ⟦F(x)⟧ = {}", i + 1, f[0]);
    }
    let out = server.finalize(finals);
    println!("\nserver reconstructs F(x) = {} ⇒ vote = {:+}", out[0], fp.lift(out[0]));
    assert_eq!(out[0], 1, "F(1) must be 1 (the Appendix-A result)");
    assert_eq!(fp.lift(out[0]), 1);
    // cost lines of the example match Table VIII's n₁ = 3 row
    assert_eq!(server.stats.subrounds, 2);
    assert_eq!(server.stats.uplink_elems_per_user, 4); // R = 4
    assert_eq!(server.stats.c_u_bits(), 12); // C_u = 12 bits
    println!("\nall Appendix-A invariants hold ✓ (R = 4, 2 subrounds, C_u = 12 bits)");
}
