//! Subgroup-configuration sweep: regenerates the *measured* counterparts
//! of Tables VII/VIII/IX and Fig. 6 by actually running the secure
//! protocol at every (n, ℓ) the paper lists and reading the byte counters
//! — then cross-checks them against the analytic cost model.
//!
//! ```bash
//! cargo run --release --example subgroup_sweep
//! ```

use hisafe::cost;
use hisafe::poly::TiePolicy;
use hisafe::protocol::{run_sync, HiSafeConfig};
use hisafe::util::rng::{Rng, Xoshiro256pp};

fn main() {
    let mut rng = Xoshiro256pp::seed_from_u64(5);
    println!(
        "{:>4} {:>4} {:>4} {:>4} {:>6} {:>6} {:>8} {:>8} {:>9} {:>8}",
        "n", "l", "n1", "p1", "depth", "R", "C_u", "C_T", "Cu_red%", "CT_red%"
    );
    let mut flat_cu = std::collections::BTreeMap::new();
    for row in cost::paper_tables() {
        if row.n % row.ell != 0 {
            continue;
        }
        let cfg = HiSafeConfig {
            n: row.n,
            ell: row.ell,
            intra: TiePolicy::OneBit,
            inter: TiePolicy::OneBit,
            sparse: false,
        };
        // run the real protocol on one coordinate
        let signs: Vec<Vec<i8>> = (0..row.n).map(|_| vec![rng.gen_sign()]).collect();
        let out = run_sync(&signs, cfg, row.n as u64 * 31 + row.ell as u64);
        let model = cost::config_cost(row.n, row.ell, TiePolicy::OneBit, false);
        // measured must equal analytic
        assert_eq!(out.stats.c_u_bits(), model.group.c_u_bits, "C_u mismatch at {row:?}");
        assert_eq!(out.stats.c_t_paper_bits(), model.c_t_bits, "C_T mismatch at {row:?}");
        assert_eq!(out.stats.subrounds as usize, model.group.depth);
        if row.ell == 1 {
            flat_cu.insert(row.n, (model.group.c_u_bits, model.c_t_bits));
        }
        let (fcu, fct) = *flat_cu.get(&row.n).unwrap_or(&(model.group.c_u_bits, model.c_t_bits));
        println!(
            "{:>4} {:>4} {:>4} {:>4} {:>6} {:>6} {:>8} {:>8} {:>8.1}% {:>7.1}%",
            row.n,
            row.ell,
            model.group.n1,
            model.group.p1,
            model.group.depth,
            model.group.openings,
            model.group.c_u_bits,
            model.c_t_bits,
            cost::reduction_pct(fcu, model.group.c_u_bits),
            cost::reduction_pct(fct, model.c_t_bits),
        );
    }

    println!("\n=== headline claims ===");
    for n in [24usize, 36, 60, 90, 100] {
        let flat = cost::config_cost(n, 1, TiePolicy::OneBit, false);
        let best = cost::optimal_ell(n, TiePolicy::OneBit, false);
        println!(
            "n={n:>3}: ℓ*={:<2} C_u {} → {} bits ({:.1}% reduction), C_T {} → {} ({:.1}%)",
            best.ell,
            flat.group.c_u_bits,
            best.group.c_u_bits,
            cost::reduction_pct(flat.group.c_u_bits, best.group.c_u_bits),
            flat.c_t_bits,
            best.c_t_bits,
            cost::reduction_pct(flat.c_t_bits, best.c_t_bits),
        );
    }

    println!("\n=== sparse-schedule ablation (ours; not in paper) ===");
    println!("{:>4} {:>10} {:>10} {:>8}", "n1", "full R", "sparse R", "saving%");
    for n1 in [3usize, 4, 5, 6, 8, 10, 12] {
        let full = cost::group_cost(n1, TiePolicy::OneBit, false);
        let sparse = cost::group_cost(n1, TiePolicy::OneBit, true);
        println!(
            "{:>4} {:>10} {:>10} {:>7.1}%",
            n1,
            full.openings,
            sparse.openings,
            cost::reduction_pct(full.openings as u64, sparse.openings as u64)
        );
    }
    println!("\nall measured counters matched the analytic model ✓");
}
