//! End-to-end driver: the full three-layer system on a real workload.
//!
//! Trains the AOT-compiled JAX MLP (L2, with the L1 Pallas sign kernel in
//! its signgrad sibling) under federated SIGNSGD-MV with Hi-SAFE secure
//! aggregation (L3 rust MPC) on the synthetic FMNIST analogue, non-IID
//! (2 classes/user), N = 100 users with n = 24 participating per round —
//! the paper's Fig. 2/4 configuration — and logs the loss/accuracy curve
//! plus the communication bill vs the flat baseline.
//!
//! Requires `make artifacts`. Results recorded in EXPERIMENTS.md §E2E.
//!
//! ```bash
//! make artifacts && cargo run --release --example fl_e2e [-- --rounds 150]
//! ```

use hisafe::fl::data::{partition_users, synthetic, DataKind, Partition};
use hisafe::fl::model::Model;
use hisafe::fl::trainer::{train, Aggregator, TrainConfig};
use hisafe::poly::TiePolicy;
use hisafe::protocol::HiSafeConfig;
use hisafe::runtime::{JaxModel, MvPolyKernel};
use hisafe::util::cli::Args;

fn main() {
    let args = Args::from_env(&[]).expect("args");
    let rounds = args.get_usize("rounds", 120).expect("--rounds");
    let participants = 24usize;
    let ell = 8usize;

    println!("=== Hi-SAFE end-to-end: JAX/Pallas MLP + rust secure aggregation ===");
    let t0 = std::time::Instant::now();

    // L2 model: the AOT-compiled 784-32-10 MLP (25,450 params).
    let model = JaxModel::new("artifacts", "mnist_mlp", 25_450, 784, 10, 100)
        .expect("run `make artifacts` first");
    println!(
        "model: {} (d = {}), PJRT platform loaded in {:.2}s",
        model.name(),
        model.dim(),
        t0.elapsed().as_secs_f64()
    );

    // Workload: FMNIST analogue, 100 users, 2-class non-IID.
    let (tr, te) = synthetic(DataKind::FmnistLike, 6000, 1000, 1234);
    let shards = partition_users(&tr, 100, Partition::TwoClass, 42);
    println!("data: {} train / {} test, non-IID 2-class over 100 users", tr.len(), te.len());

    let cfg = TrainConfig {
        n_users: 100,
        participants,
        rounds,
        lr: 0.005,
        batch_size: 100,
        eval_every: 10,
        seed: 0,
    };

    // Secure hierarchical aggregation: ℓ* = 8 ⇒ n₁ = 3 (Table VII).
    let agg = Aggregator::HiSafe(HiSafeConfig::hierarchical(participants, ell, TiePolicy::OneBit));
    println!("aggregator: {} — training {rounds} rounds...", match &agg {
        Aggregator::HiSafe(c) => format!("Hi-SAFE ℓ={} ({})", c.ell, c.label()),
        _ => unreachable!(),
    });

    let t1 = std::time::Instant::now();
    let res = train(&model, &tr, &te, &shards, agg, &cfg);
    let wall = t1.elapsed().as_secs_f64();

    println!("\nround   loss     acc");
    for l in res.logs.iter().filter(|l| l.round % cfg.eval_every == 0) {
        println!("{:>5}  {:>7.4}  {:>6.4}", l.round, l.train_loss, l.test_acc);
    }
    println!(
        "\nfinal accuracy: {:.4}   wall: {:.1}s ({:.2}s/round)",
        res.final_acc,
        wall,
        wall / rounds as f64
    );

    // Communication bill vs flat (per round, whole model).
    let flat = hisafe::cost::config_cost(participants, 1, TiePolicy::OneBit, false);
    let hier = hisafe::cost::config_cost(participants, ell, TiePolicy::OneBit, false);
    let d = model.dim() as u64;
    println!("\nper-round per-user uplink:");
    println!("  flat Hi-SAFE (ℓ=1): {:>12} bits", flat.group.c_u_bits * d);
    println!(
        "  hier Hi-SAFE (ℓ={ell}): {:>12} bits  ({:.1}% reduction)",
        hier.group.c_u_bits * d,
        hisafe::cost::reduction_pct(flat.group.c_u_bits * d, hier.group.c_u_bits * d)
    );
    println!(
        "  measured this run : {:>12} bits/round",
        res.logs[0].uplink_bits_per_user
    );
    assert_eq!(res.logs[0].uplink_bits_per_user, hier.group.c_u_bits * d);

    // L1 sanity on the live path: the Pallas vote kernel agrees with the
    // rust polynomial on a fresh batch of sums.
    let kernel = MvPolyKernel::new("artifacts", 25_600, 32).expect("kernel artifact");
    let mv = hisafe::poly::MvPolynomial::build_fermat(3, TiePolicy::OneBit);
    let xs: Vec<u64> = (0..25_600).map(|i| (i % mv.fp.modulus() as usize) as u64).collect();
    let a = mv.poly.eval_vec(&xs);
    let b = kernel.eval(mv.fp, &mv.poly.coeffs, &xs).expect("kernel eval");
    assert_eq!(a, b);
    println!("\nL1 Pallas vote kernel ≡ rust poly eval on 25,600 lanes ✓");

    assert!(
        res.final_acc > 0.5,
        "e2e accuracy too low: {}",
        res.final_acc
    );
    println!("fl_e2e OK");
}
