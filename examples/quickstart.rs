//! Quickstart: the Hi-SAFE public API in ~40 effective lines.
//!
//! Six users vote securely on a 8-coordinate sign vector, flat vs
//! hierarchical; we print the votes, what the server actually saw, and the
//! communication bill.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use hisafe::mpc::{plain_group_vote, secure_group_vote};
use hisafe::poly::{MvPolynomial, TiePolicy};
use hisafe::protocol::{run_sync, HiSafeConfig};

fn main() {
    // Each user holds a private ±1 vector (a sign gradient in FL).
    let signs: Vec<Vec<i8>> = vec![
        vec![1, 1, 1, -1, -1, 1, -1, 1],
        vec![1, -1, 1, -1, 1, 1, -1, -1],
        vec![1, 1, -1, -1, -1, 1, 1, 1],
        vec![-1, 1, 1, -1, 1, -1, -1, 1],
        vec![1, -1, 1, 1, -1, 1, -1, 1],
        vec![-1, 1, 1, -1, -1, 1, -1, -1],
    ];
    let n = signs.len();

    // The majority-vote polynomial Hi-SAFE evaluates under MPC (Table III).
    let mv = MvPolynomial::build_fermat(n, TiePolicy::OneBit);
    println!("n = {n}: F(x) = {}", mv.poly.display());

    // 1. Flat Hi-SAFE (Algorithm 2): one secure vote over all users.
    let flat = secure_group_vote(&signs, TiePolicy::OneBit, false, 7);
    println!("\nflat secure vote : {:?}", flat.votes);
    println!("plaintext MV     : {:?}", plain_group_vote(&signs, TiePolicy::OneBit));
    assert_eq!(flat.votes, plain_group_vote(&signs, TiePolicy::OneBit));
    println!(
        "flat cost: C_u = {} bits/coord, {} subrounds, {} Beaver mults",
        flat.stats.c_u_bits() / 8, // per coordinate (d = 8)
        flat.stats.subrounds,
        flat.stats.mults
    );

    // 2. Hierarchical Hi-SAFE (Algorithm 3): 2 subgroups of 3.
    let cfg = HiSafeConfig::hierarchical(n, 2, TiePolicy::OneBit);
    let hier = run_sync(&signs, cfg, 7);
    println!("\nhierarchical vote: {:?}", hier.global_vote);
    println!("subgroup votes   : {:?}", hier.subgroup_votes);
    println!(
        "hier cost: C_u = {} bits/coord, {} subrounds, {} Beaver mults total",
        hier.stats.c_u_bits() / 8,
        hier.stats.subrounds,
        hier.stats.mults
    );

    // 3. What did the server see? Only uniform openings + the votes.
    let t = &flat.transcript;
    println!(
        "\nserver view (flat): {} masked openings (uniform on F_{}), output F(x) only",
        t.openings.len() * 2,
        mv.fp.modulus()
    );
    println!("quickstart OK");
}
