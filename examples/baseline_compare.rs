//! Table-I comparison, quantified: train the same FL workload under every
//! aggregation method and report accuracy, per-user uplink, and what the
//! server observes.
//!
//! ```bash
//! cargo run --release --example baseline_compare [-- --rounds 80]
//! ```

use hisafe::baselines::he_cost::HeParams;
use hisafe::fl::data::{partition_users, synthetic, DataKind, Partition};
use hisafe::fl::model::{LinearSoftmax, Model};
use hisafe::fl::trainer::{train, Aggregator, TrainConfig};
use hisafe::poly::TiePolicy;
use hisafe::protocol::HiSafeConfig;
use hisafe::util::cli::Args;

fn main() {
    let args = Args::from_env(&[]).expect("args");
    let rounds = args.get_usize("rounds", 80).expect("--rounds");

    let (tr, te) = synthetic(DataKind::FmnistLike, 4000, 800, 99);
    let n_users = 50;
    let participants = 12;
    let shards = partition_users(&tr, n_users, Partition::TwoClass, 7);
    let model = LinearSoftmax::new(784, 10);
    let d = model.dim() as u64;
    let cfg = TrainConfig {
        n_users,
        participants,
        rounds,
        lr: 0.002,
        batch_size: 64,
        eval_every: 10,
        seed: 1,
    };

    let methods: Vec<(&str, Aggregator, &str)> = vec![
        (
            "Hi-SAFE (l=4, A-1)",
            Aggregator::HiSafe(HiSafeConfig::hierarchical(participants, 4, TiePolicy::OneBit)),
            "subgroup votes + final vote only",
        ),
        (
            "Hi-SAFE flat",
            Aggregator::HiSafe(HiSafeConfig::flat(participants, TiePolicy::OneBit)),
            "final majority vote only",
        ),
        (
            "SIGNSGD-MV [25]",
            Aggregator::PlainMv(TiePolicy::OneBit),
            "ALL raw sign gradients",
        ),
        (
            "DP-SIGNSGD [21] s=2",
            Aggregator::DpSign { clip: 1.0, sigma: 2.0 },
            "all noisy sign gradients",
        ),
        (
            "Masking [18]",
            Aggregator::MaskedSum,
            "exact summation values",
        ),
        (
            "FedAvg (float)",
            Aggregator::FedAvg,
            "all raw float gradients",
        ),
    ];

    println!(
        "{:<22} {:>9} {:>16} {:>14}  {}",
        "method", "final acc", "uplink bits/user", "bits/coord", "server observes"
    );
    let mut rows = Vec::new();
    for (name, agg, observes) in methods {
        let res = train(&model, &tr, &te, &shards, agg, &cfg);
        let per_round = res.total_uplink_bits_per_user / rounds as u64;
        println!(
            "{:<22} {:>9.4} {:>16} {:>14.1}  {}",
            name,
            res.final_acc,
            per_round,
            per_round as f64 / d as f64,
            observes
        );
        rows.push((name, res.final_acc, per_round));
    }

    // HE row is analytic (Table I compares magnitude; CKKS can't evaluate
    // the nonlinear vote at all — the paper's incompatibility argument).
    let he = HeParams::default();
    println!(
        "{:<22} {:>9} {:>16} {:>14.1}  fully encrypted (but no sign/vote support)",
        "HE (CKKS) [22]",
        "n/a",
        he.uplink_bits_per_user(d as usize),
        he.expansion_vs_sign(d as usize)
    );

    // Shape assertions from Table I.
    let acc = |name: &str| rows.iter().find(|r| r.0.starts_with(name)).unwrap().1;
    let bits = |name: &str| rows.iter().find(|r| r.0.starts_with(name)).unwrap().2;
    assert!(
        (acc("Hi-SAFE flat") - acc("SIGNSGD-MV")).abs() < 1e-6,
        "flat Hi-SAFE must match plain MV exactly"
    );
    assert!(acc("DP-SIGNSGD") <= acc("SIGNSGD-MV") + 0.02, "DP should not beat clean MV");
    assert!(bits("Masking") > bits("Hi-SAFE (l=4, A-1)"), "masking ships 32-bit words");
    assert!(
        he.uplink_bits_per_user(d as usize) > bits("Hi-SAFE (l=4, A-1)") * 10,
        "HE must be >10x costlier"
    );
    println!("\nTable-I shape assertions hold ✓");
}
