//! Bench: end-to-end FL round throughput (Figs. 2–5 workloads).
//!
//! One full round = 24 users' minibatch gradients + sign quantization +
//! secure hierarchical aggregation + model update, on the pure-rust
//! linear model (7,850 params) and — when artifacts exist — the AOT JAX
//! MLP (25,450 params) including PJRT execution.

use hisafe::fl::data::{partition_users, synthetic, DataKind, Partition};
use hisafe::fl::model::{sign_vec, LinearSoftmax, Model};
use hisafe::poly::TiePolicy;
use hisafe::protocol::{run_sync, run_threaded, HiSafeConfig};
use hisafe::runtime::JaxModel;
use hisafe::util::bench::{section, Bencher};
use hisafe::util::rng::{Rng, Xoshiro256pp};

fn round<M: Model>(
    model: &M,
    params: &mut [f32],
    tr: &hisafe::fl::data::Dataset,
    shards: &[Vec<usize>],
    cfg: HiSafeConfig,
    rng: &mut Xoshiro256pp,
    seed: u64,
    batch_size: usize,
) -> f32 {
    let selected = rng.sample_indices(shards.len(), cfg.n);
    let signs: Vec<Vec<i8>> = selected
        .iter()
        .map(|&u| {
            let shard = &shards[u];
            let batch: Vec<usize> = (0..batch_size)
                .map(|_| shard[rng.gen_below(shard.len() as u64) as usize])
                .collect();
            let (_, g) = model.loss_grad(params, tr, &batch);
            sign_vec(&g)
        })
        .collect();
    let out = run_sync(&signs, cfg, seed);
    for (p, &v) in params.iter_mut().zip(&out.global_vote) {
        *p -= 0.005 * v as f32;
    }
    params[0]
}

fn main() {
    let mut b = Bencher::new();
    let (tr, _te) = synthetic(DataKind::FmnistLike, 3000, 100, 5);
    let shards = partition_users(&tr, 100, Partition::TwoClass, 5);
    let cfg = HiSafeConfig::hierarchical(24, 8, TiePolicy::OneBit);

    section("end-to-end round, rust linear model (d = 7,850)");
    let model = LinearSoftmax::new(784, 10);
    let mut params = model.init_params(1);
    let mut rng = Xoshiro256pp::seed_from_u64(2);
    let mut seed = 0u64;
    let s = b.bench("round n=24 l=8 batch=100 (grad+sign+secure agg+update)", || {
        seed += 1;
        round(&model, &mut params, &tr, &shards, cfg, &mut rng, seed, 100)
    });
    println!("  → {:.2} rounds/s", 1.0 / s.median.as_secs_f64());

    section("threaded coordinator vs in-process (n=24, d=7,850, signs only)");
    let signs: Vec<Vec<i8>> = (0..24)
        .map(|_| (0..7850).map(|_| rng.gen_sign()).collect())
        .collect();
    b.bench("run_sync", || {
        seed += 1;
        run_sync(&signs, cfg, seed).global_vote[0]
    });
    b.bench("run_threaded (25 OS threads + channels)", || {
        seed += 1;
        run_threaded(&signs, cfg, seed).global_vote[0]
    });

    if std::path::Path::new("artifacts/manifest.json").exists() {
        section("end-to-end round, AOT JAX MLP via PJRT (d = 25,450)");
        let jax = JaxModel::new("artifacts", "mnist_mlp", 25_450, 784, 10, 100)
            .expect("artifacts present");
        let mut params = jax.init_params(1);
        let s = b.bench("round n=24 l=8 batch=100 (PJRT grads + secure agg)", || {
            seed += 1;
            round(&jax, &mut params, &tr, &shards, cfg, &mut rng, seed, 100)
        });
        println!("  → {:.2} rounds/s", 1.0 / s.median.as_secs_f64());
    } else {
        println!("(artifacts missing — skipping PJRT end-to-end; run `make artifacts`)");
    }
    b.write_json("e2e_round");
}
