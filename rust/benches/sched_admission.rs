//! Bench: what admission control + per-tenant QoS buy on a shared
//! scheduler under adversarial load.
//!
//! Three scenarios:
//!
//! 1. **Flood isolation** — a greedy tenant floods the provisioning
//!    plane with a huge prefetch; a victim tenant then cold-starts a
//!    modest `provision`. Weighted round-robin must keep the victim's
//!    wait in the same class as an uncontended cold start (pre-QoS, the
//!    victim waited behind the whole flood).
//! 2. **Weighted share** — a priority (weight 3) tenant and a greedy
//!    (weight 1) tenant flood together; the dealt-round counters must
//!    split ~3:1 while the priority tenant's provision completes.
//! 3. **Throttling overhead** — a rate-limited tenant next to an
//!    unlimited one: the limited tenant pays its own throttle waits, the
//!    unlimited tenant's round latency stays in its solo class.
//!
//! Wall-clock assertions are opt-in via `HISAFE_BENCH_STRICT=1`
//! (advisory runs only print; CI compile-gates with `--no-run`).

use hisafe::engine::{AggScheduler, Engine, QosPolicy};
use hisafe::poly::TiePolicy;
use hisafe::protocol::HiSafeConfig;
use hisafe::util::bench::{black_box, section, Bencher};
use hisafe::util::rng::{Rng, Xoshiro256pp};
use std::time::{Duration, Instant};

fn main() {
    let strict = std::env::var("HISAFE_BENCH_STRICT").map(|v| v == "1").unwrap_or(false);
    let fast = std::env::var("HISAFE_BENCH_FAST").ok().is_some();
    let d: usize = if fast { 1024 } else { 4096 };
    let flood: usize = if fast { 16 } else { 48 };
    let want: usize = if fast { 4 } else { 8 };
    let cfg = HiSafeConfig::hierarchical(12, 4, TiePolicy::OneBit);

    // ---- 1. flood isolation -------------------------------------------
    section(&format!(
        "flood isolation: victim provision({want}) vs a {flood}-round flood at d = {d}"
    ));
    // Baseline: uncontended cold-start provision.
    let solo_t = {
        let sched = AggScheduler::with_threads(2);
        let mut victim = sched.session(cfg, d, 1);
        let t0 = Instant::now();
        victim.provision(want);
        t0.elapsed()
    };
    // Contended: the same provision behind a greedy tenant's flood.
    let (flooded_t, greedy_dealt_at_done) = {
        let sched = AggScheduler::with_threads(2);
        let mut victim = sched.session(cfg, d, 1);
        let mut greedy = sched.session(cfg, d, 2);
        greedy.try_prefetch(flood).expect("unbounded queue");
        let t0 = Instant::now();
        victim.provision(want);
        (t0.elapsed(), greedy.dealt_rounds())
    };
    println!("  solo cold start:    {:.2} ms", solo_t.as_secs_f64() * 1e3);
    println!(
        "  behind the flood:   {:.2} ms  (greedy had dealt {greedy_dealt_at_done}/{flood} \
         rounds when the victim finished)",
        flooded_t.as_secs_f64() * 1e3
    );
    if strict {
        // Equal weights → the victim owns half the dealing bandwidth:
        // same class as solo (2x + generous scheduling noise), not
        // "after the whole flood" (~(flood + want)/want times solo).
        assert!(
            flooded_t.as_secs_f64() < solo_t.as_secs_f64() * 3.0 + 0.05,
            "flooded cold start fell out of the solo class: {flooded_t:?} vs {solo_t:?}"
        );
        assert!(
            (greedy_dealt_at_done as usize) < flood,
            "victim waited for the whole flood"
        );
    }

    // ---- 2. weighted share --------------------------------------------
    section("weighted share: priority weight 3 vs greedy weight 1, both flooding");
    let sched = AggScheduler::with_threads(2);
    let mut priority = sched
        .try_session(cfg, d, 3, QosPolicy::unlimited().with_weight(3))
        .expect("admitted");
    let mut greedy = sched
        .try_session(cfg, d, 4, QosPolicy::unlimited().with_weight(1))
        .expect("admitted");
    greedy.try_prefetch(flood).expect("unbounded queue");
    priority.provision(want * 3);
    let (p_dealt, g_dealt) = (priority.dealt_rounds(), greedy.dealt_rounds());
    println!(
        "  priority dealt {p_dealt} rounds while greedy dealt {g_dealt} \
         (weights 3:1 → expected share ~3:1)"
    );
    if strict {
        // While the priority tenant's rounds dealt, WRR hands the
        // weight-1 greedy at most ceil(p/3) quanta plus race slack.
        let bound = (p_dealt as usize).div_ceil(3) + 5;
        assert!(
            (g_dealt as usize) <= bound,
            "greedy exceeded its weighted share: {g_dealt} > {bound}"
        );
    }
    drop(priority);
    drop(greedy);

    // ---- 3. throttling overhead ---------------------------------------
    section("throttling: a rate-limited tenant must not slow an unlimited one");
    let rounds = if fast { 3 } else { 5 };
    let mut rng = Xoshiro256pp::seed_from_u64(7);
    let signs: Vec<Vec<i8>> = (0..cfg.n)
        .map(|_| (0..d).map(|_| rng.gen_sign()).collect())
        .collect();
    // Solo baseline for the unlimited tenant.
    let solo_mean = {
        let sched = AggScheduler::with_threads(2);
        let mut s = sched.session(cfg, d, 5);
        let t0 = Instant::now();
        for _ in 0..rounds {
            black_box(s.run_round(&signs).global_vote[0]);
        }
        t0.elapsed().as_secs_f64() / rounds as f64
    };
    let sched = AggScheduler::with_threads(2);
    let mut unlimited = sched.session(cfg, d, 5);
    let mut limited = sched
        .try_session(cfg, d, 6, QosPolicy::unlimited().with_rounds_per_sec(40.0))
        .expect("admitted");
    let mut throttles = 0u64;
    let t0 = Instant::now();
    for _ in 0..rounds {
        black_box(unlimited.run_round(&signs).global_vote[0]);
        let (out, denials, _waited) = limited.run_round_admitted(&signs);
        black_box(out.global_vote[0]);
        throttles += denials;
    }
    let pair_t = t0.elapsed();
    let unlimited_mean = pair_t.as_secs_f64() / rounds as f64;
    println!(
        "  solo mean round: {:.2} ms; paired loop mean: {:.2} ms; \
         limited tenant throttled {throttles}x (its own waits, not the pool's)",
        solo_mean * 1e3,
        unlimited_mean * 1e3
    );
    println!(
        "  limited tenant admission: {:?}",
        limited.admission_stats()
    );
    if strict {
        assert!(throttles >= 1, "a 40 rounds/s budget must throttle back-to-back rounds");
    }

    let mut b = Bencher::new();
    b.record("solo cold-start provision", solo_t);
    b.record("cold-start provision behind flood", flooded_t);
    b.record("solo mean round", Duration::from_secs_f64(solo_mean));
    b.record(
        "paired-loop mean round (next to throttled tenant)",
        Duration::from_secs_f64(unlimited_mean),
    );
    b.write_json("sched_admission");
}
