//! Bench: Fig. 6 — per-user secure-multiplication cost (6a) and serial
//! latency (6b), flat vs optimal subgrouping, as n grows.
//!
//! Analytic series from the real polynomial/schedule, plus a measured
//! end-to-end latency of the subround loop at d = 1024 for both configs.

use hisafe::cost;
use hisafe::mpc::secure_group_vote;
use hisafe::poly::TiePolicy;
use hisafe::protocol::{run_sync, HiSafeConfig};
use hisafe::util::bench::{section, Bencher};
use hisafe::util::rng::{Rng, Xoshiro256pp};

fn main() {
    section("Fig. 6a: per-user masked uploads R (flat vs subgrouped)");
    println!("{:>4} {:>8} {:>10}", "n", "flat", "subgrouped");
    for n in [12usize, 16, 20, 24, 28, 30, 36, 40, 50, 60, 70, 80, 90, 100] {
        let flat = cost::config_cost(n, 1, TiePolicy::OneBit, false);
        let best = cost::optimal_ell(n, TiePolicy::OneBit, false);
        println!("{:>4} {:>8} {:>10}", n, flat.group.openings, best.group.openings);
    }

    section("Fig. 6b: latency — serial Beaver subrounds");
    println!("{:>4} {:>8} {:>10}", "n", "flat", "subgrouped");
    for n in [12usize, 16, 20, 24, 28, 30, 36, 40, 50, 60, 70, 80, 90, 100] {
        let flat = cost::config_cost(n, 1, TiePolicy::OneBit, false);
        let best = cost::optimal_ell(n, TiePolicy::OneBit, false);
        println!("{:>4} {:>8} {:>10}", n, flat.group.depth, best.group.depth);
    }

    section("measured wall-clock per aggregation round (d = 1024)");
    let mut b = Bencher::new();
    let d = 1024usize;
    let mut rng = Xoshiro256pp::seed_from_u64(3);
    for n in [12usize, 24, 60, 100] {
        let signs: Vec<Vec<i8>> =
            (0..n).map(|_| (0..d).map(|_| rng.gen_sign()).collect()).collect();
        let mut seed = 0u64;
        b.bench(&format!("flat secure round n={n}"), || {
            seed += 1;
            secure_group_vote(&signs, TiePolicy::OneBit, false, seed).votes[0]
        });
        let best = cost::optimal_ell(n, TiePolicy::OneBit, false);
        let cfg = HiSafeConfig::hierarchical(n, best.ell, TiePolicy::OneBit);
        b.bench(&format!("subgrouped secure round n={n} (l={})", best.ell), || {
            seed += 1;
            run_sync(&signs, cfg, seed).global_vote[0]
        });
    }
    b.write_json("fig6_mults_latency");
}
