//! Bench: MPC engine micro-benchmarks — the L3 hot path.
//!
//! Throughput targets (§Perf): ≥ 10⁷ coordinate-multiplications/s in the
//! Beaver recombination; the full n=24/ℓ=8 round on the MNIST MLP
//! dimension under 50 ms.

use hisafe::beaver::Dealer;
use hisafe::engine::{Engine, PipelinedEngine, RoundEngine};
use hisafe::field::Fp;
use hisafe::mpc::secure_group_vote;
use hisafe::poly::TiePolicy;
use hisafe::protocol::{run_sync, HiSafeConfig};
use hisafe::util::bench::{black_box, section, Bencher};
use hisafe::util::rng::{Rng, Xoshiro256pp};

fn main() {
    // Wall-clock assertions (speedup floors, latency ceilings) are
    // meaningful on a quiet dev box but flaky on loaded shared CI
    // runners; HISAFE_BENCH_STRICT=1 turns them on, advisory runs only
    // print the numbers.
    let strict = std::env::var("HISAFE_BENCH_STRICT").map(|v| v == "1").unwrap_or(false);
    let mut b = Bencher::new();
    let mut rng = Xoshiro256pp::seed_from_u64(11);

    section("field vector kernels (d = 65,536)");
    let fp = Fp::new(29);
    let d = 65_536usize;
    let xs: Vec<u64> = (0..d).map(|_| rng.gen_field(29)).collect();
    let ys: Vec<u64> = (0..d).map(|_| rng.gen_field(29)).collect();
    let mut acc = vec![0u64; d];
    let s = b.bench("vec_mul_add_assign (Beaver recombination kernel)", || {
        fp.vec_mul_add_assign(&mut acc, &xs, &ys);
        acc[0]
    });
    b.annotate_throughput(d as f64, "elements");
    println!(
        "  → {:.1} M coordinate-mults/s",
        s.throughput(d as f64) / 1e6
    );
    b.bench("vec_add_assign (share aggregation)", || {
        fp.vec_add_assign(&mut acc, &xs);
        acc[0]
    });
    b.annotate_throughput(d as f64, "elements");

    section("chunked kernels vs the old scalar lane loops (d = 65,536)");
    {
        // The pre-chunking lane loops, verbatim: per-element branchy
        // canonical add, Barrett reduce with a correction *loop*, a
        // fresh Vec per product call — kept here (not in the library) as
        // the old-vs-new baseline the strict gate compares against.
        struct OldKernels {
            p: u64,
            barrett: u64,
        }
        impl OldKernels {
            #[inline(always)]
            fn reduce(&self, x: u64) -> u64 {
                let q = ((x as u128 * self.barrett as u128) >> 64) as u64;
                let mut r = x.wrapping_sub(q.wrapping_mul(self.p));
                while r >= self.p {
                    r -= self.p;
                }
                r
            }
            #[inline(always)]
            fn add(&self, a: u64, b: u64) -> u64 {
                let s = a + b;
                if s >= self.p {
                    s - self.p
                } else {
                    s
                }
            }
            fn vec_mul_add_assign(&self, dst: &mut [u64], a: &[u64], b: &[u64]) {
                for i in 0..dst.len() {
                    dst[i] = self.add(dst[i], self.reduce(a[i] * b[i]));
                }
            }
            fn vec_mul(&self, a: &[u64], b: &[u64]) -> Vec<u64> {
                a.iter().zip(b).map(|(&x, &y)| self.reduce(x * y)).collect()
            }
        }
        let old = OldKernels { p: 29, barrett: u64::MAX / 29 };

        // Determinism first: a kernel that computes different lanes
        // measures nothing. (Exact Barrett reduction makes chunking and
        // branch elimination observationally invisible.)
        let mut want = vec![0u64; d];
        let mut got = vec![0u64; d];
        old.vec_mul_add_assign(&mut want, &xs, &ys);
        fp.vec_mul_add_assign(&mut got, &xs, &ys);
        assert_eq!(want, got, "chunked vec_mul_add_assign diverged from the old loop");
        assert_eq!(old.vec_mul(&xs, &ys), fp.vec_mul(&xs, &ys), "vec_mul diverged");

        let mut acc_old = vec![0u64; d];
        let s_old = b.bench("old scalar vec_mul_add_assign (branchy, per-term reduce)", || {
            old.vec_mul_add_assign(&mut acc_old, &xs, &ys);
            acc_old[0]
        });
        b.annotate_throughput(d as f64, "elements");
        let mut acc_new = vec![0u64; d];
        let s_new = b.bench("chunked vec_mul_add_assign (lane blocks, one reduce)", || {
            fp.vec_mul_add_assign(&mut acc_new, &xs, &ys);
            acc_new[0]
        });
        b.annotate_throughput(d as f64, "elements");

        let s_old_mul = b.bench("old vec_mul (fresh Vec per call)", || old.vec_mul(&xs, &ys)[0]);
        b.annotate_throughput(d as f64, "elements");
        let mut prod = vec![0u64; d];
        let s_new_mul = b.bench("vec_mul_into (reused scratch)", || {
            fp.vec_mul_into(&mut prod, &xs, &ys);
            prod[0]
        });
        b.annotate_throughput(d as f64, "elements");

        let mul_add_x = s_new.throughput(d as f64) / s_old.throughput(d as f64);
        let mul_x = s_new_mul.throughput(d as f64) / s_old_mul.throughput(d as f64);
        println!(
            "\n  mul_add: old {:.1} M/s → chunked {:.1} M/s ({mul_add_x:.2}x)   \
             mul: old {:.1} M/s → scratch {:.1} M/s ({mul_x:.2}x)",
            s_old.throughput(d as f64) / 1e6,
            s_new.throughput(d as f64) / 1e6,
            s_old_mul.throughput(d as f64) / 1e6,
            s_new_mul.throughput(d as f64) / 1e6,
        );
        if strict {
            // The tentpole claim: the chunked, branch-free kernels beat
            // the old lane loops. No margin — the gate exists to catch a
            // layout change that regresses below the scalar baseline.
            assert!(
                mul_add_x > 1.0,
                "chunked vec_mul_add_assign no faster than the old loop ({mul_add_x:.2}x)"
            );
            assert!(
                mul_x > 1.0,
                "scratch vec_mul_into no faster than the allocating loop ({mul_x:.2}x)"
            );
        }
    }

    section("beaver dealer (offline)");
    b.bench("gen_round n1=3, 2 mults, d=25,450", || {
        let mut dealer = Dealer::new(fp, 7);
        black_box(dealer.gen_round(25_450, 3, 2))
    });

    section("one secure group vote (online), d = 25,450");
    let d_model = 25_450usize;
    for n1 in [3usize, 4, 6] {
        let signs: Vec<Vec<i8>> = (0..n1)
            .map(|_| (0..d_model).map(|_| rng.gen_sign()).collect())
            .collect();
        let mut seed = 0u64;
        b.bench(&format!("secure_group_vote n1={n1}"), || {
            seed += 1;
            secure_group_vote(&signs, TiePolicy::OneBit, false, seed).votes[0]
        });
    }

    section("online-only (pre-dealt triples): Table V's split, d = 25,450");
    {
        use hisafe::mpc::{secure_group_vote_prepared, EvalPlan};
        use hisafe::poly::MvPolynomial;
        use std::sync::Arc;
        let n1 = 3usize;
        let mv = MvPolynomial::build_fermat(n1, TiePolicy::OneBit);
        let plan = Arc::new(EvalPlan::new(&mv, d_model, false));
        let signs: Vec<Vec<i8>> = (0..n1)
            .map(|_| (0..d_model).map(|_| rng.gen_sign()).collect())
            .collect();
        // pre-deal a pool of triple sets so each iteration consumes fresh ones
        let mut dealer = Dealer::new(plan.fp, 3);
        let pool: Vec<_> = (0..64)
            .map(|_| dealer.gen_round(d_model, n1, plan.triples_needed()))
            .collect();
        let mut i = 0usize;
        let s = b.bench("online secure eval n1=3 (triples pre-dealt)", || {
            i += 1;
            secure_group_vote_prepared(&signs, Arc::clone(&plan), pool[i % 64].clone())
                .votes[0]
        });
        println!(
            "  (includes one clone of the triple set per iter: {:.2} ms)",
            s.median.as_secs_f64() * 1e3
        );
    }

    section("full rounds at model dimension (n=24, d=25,450)");
    let signs: Vec<Vec<i8>> = (0..24)
        .map(|_| (0..d_model).map(|_| rng.gen_sign()).collect())
        .collect();
    let mut seed = 0u64;
    let hier = b.bench("hierarchical round l=8 (paper's optimum)", || {
        seed += 1;
        run_sync(&signs, HiSafeConfig::hierarchical(24, 8, TiePolicy::OneBit), seed)
            .global_vote[0]
    });
    let flat = b.bench("flat round l=1", || {
        seed += 1;
        run_sync(&signs, HiSafeConfig::flat(24, TiePolicy::OneBit), seed).global_vote[0]
    });
    println!(
        "\nhierarchical speedup over flat: {:.1}x  (hier {:.1} ms vs flat {:.1} ms)",
        flat.median.as_secs_f64() / hier.median.as_secs_f64(),
        hier.median.as_secs_f64() * 1e3,
        flat.median.as_secs_f64() * 1e3
    );
    if strict {
        assert!(
            hier.median.as_secs_f64() < 0.25,
            "hierarchical round too slow for the perf target"
        );
    }

    section("batched RoundEngine vs per-call run_sync (n=24, l=8, d=25,450)");
    // Apples to apples: both paths deal triples inline per round (the
    // engine with batch_rounds = 1); the engine's win is amortized
    // plan/polynomial setup, SoA chunking with lazy reduction, no
    // per-message allocation, and span-parallel party share computation.
    let cfg = HiSafeConfig::hierarchical(24, 8, TiePolicy::OneBit);
    let unbatched = b.bench("per-call run_sync (fresh plan + dealer each round)", || {
        seed += 1;
        run_sync(&signs, cfg, seed).global_vote[0]
    });
    let mut engine = RoundEngine::new(cfg, d_model, 42);
    let batched = b.bench("RoundEngine::run_round (amortized, inline dealing)", || {
        engine.run_round(&signs).global_vote[0]
    });
    // Pool-amortized dealing: triples provisioned 4 rounds at a time
    // (≈ 120 MB pooled at this d — the memory/amortization trade-off).
    let mut engine_pooled = RoundEngine::new(cfg, d_model, 43).with_batch_rounds(4);
    let online = b.bench("RoundEngine::run_round (pool batch = 4 rounds)", || {
        engine_pooled.run_round(&signs).global_vote[0]
    });
    let speedup = unbatched.median.as_secs_f64() / batched.median.as_secs_f64();
    println!(
        "\nbatched-vs-unbatched: {speedup:.2}x  (engine {:.2} ms vs run_sync {:.2} ms; pool-amortized {:.2} ms)",
        batched.median.as_secs_f64() * 1e3,
        unbatched.median.as_secs_f64() * 1e3,
        online.median.as_secs_f64() * 1e3
    );
    if strict {
        assert!(
            speedup > 1.0,
            "batched engine must beat the per-call path (got {speedup:.2}x)"
        );
    }

    section("pipelined scheduler vs sequential engine, cold pool (n=24, l=8, d=25,450)");
    // The tentpole overlap: the pipelined scheduler deals round r+1's
    // triples on a background stage while round r's online phase runs on
    // the persistent worker pool, so from round 2 on the offline cost
    // leaves the critical path. Both engines start cold (empty pool) and
    // run the same multi-round workload once — one-shot wall clock, not
    // Bencher medians, because warmup would silently pre-fill the pools
    // and erase exactly the cold-start cost being measured.
    {
        use std::time::Instant;
        const ROUNDS: usize = 6;
        let mut acc = 0i64;

        let t0 = Instant::now();
        let mut sequential = RoundEngine::new(cfg, d_model, 42);
        for _ in 0..ROUNDS {
            acc += sequential.run_round(&signs).global_vote[0] as i64;
        }
        let seq_t = t0.elapsed();

        let t0 = Instant::now();
        let mut pipelined = PipelinedEngine::new(cfg, d_model, 42);
        for _ in 0..ROUNDS {
            acc += pipelined.run_round(&signs).global_vote[0] as i64;
        }
        let pipe_t = t0.elapsed();
        black_box(acc);

        println!(
            "  sequential {ROUNDS} rounds: {:.1} ms   pipelined: {:.1} ms   overlap win: {:.2}x",
            seq_t.as_secs_f64() * 1e3,
            pipe_t.as_secs_f64() * 1e3,
            seq_t.as_secs_f64() / pipe_t.as_secs_f64()
        );
        b.record("cold-pool sequential engine, 6 rounds", seq_t);
        b.record("cold-pool pipelined scheduler, 6 rounds", pipe_t);
        if strict {
            assert!(
                pipe_t < seq_t,
                "pipelined scheduler must beat the sequential engine from a cold pool \
                 ({pipe_t:?} vs {seq_t:?})"
            );
        }
    }
    b.write_json("mpc_mult_throughput");
}
