//! Bench: Tables VII/VIII/IX — the communication-cost sweep, regenerated
//! from the implementation and compared row-by-row against the published
//! numbers. Also prints where the paper's rows disagree with its own
//! formula (documented in EXPERIMENTS.md).

use hisafe::cost;
use hisafe::poly::TiePolicy;
use hisafe::util::bench::{black_box, section, Bencher};

fn main() {
    section("Table VII: optimal configurations (ours, exact construction)");
    println!(
        "{:>4} {:>4} {:>4} {:>6} {:>4} {:>8} {:>9} {:>6} {:>9}",
        "n", "l*", "n1", "depth", "R", "C_T", "CT_red%", "C_u", "Cu_red%"
    );
    for (n, _ell_p, _n1_p, _d_p, _r_p, _ct_p, _ctr_p, _cu_p, _cur_p) in
        cost::paper_table7()
    {
        let flat = cost::config_cost(n, 1, TiePolicy::OneBit, false);
        let best = cost::optimal_ell(n, TiePolicy::OneBit, false);
        println!(
            "{:>4} {:>4} {:>4} {:>6} {:>4} {:>8} {:>8.1}% {:>6} {:>8.1}%",
            n,
            best.ell,
            best.group.n1,
            best.group.depth,
            best.group.openings,
            best.c_t_bits,
            cost::reduction_pct(flat.c_t_bits, best.c_t_bits),
            best.group.c_u_bits,
            cost::reduction_pct(flat.group.c_u_bits, best.group.c_u_bits),
        );
    }
    println!("(paper row reductions: C_T 52.0/47.8/44.4/50.5/43.6%, C_u 94.0–98.4% — ours are ≥ theirs because our flat baseline uses the true deg(F) = p−1)");

    section("Tables VIII/IX: sweep, ours vs published");
    println!(
        "{:>4} {:>4} | {:>4} {:>5} {:>4} {:>6} {:>6} | {:>6} {:>6} {:>6} | {}",
        "n", "l", "p1", "depth", "R", "C_u", "C_T", "R_pap", "Cu_pap", "CT_pap", "match"
    );
    let mut matches = 0usize;
    let mut total = 0usize;
    for row in cost::paper_tables() {
        if row.n % row.ell != 0 {
            continue;
        }
        let c = cost::config_cost(row.n, row.ell, TiePolicy::OneBit, false);
        let m = c.group.openings == row.r
            && c.group.c_u_bits == row.c_u
            && c.c_t_bits == row.c_t;
        total += 1;
        matches += usize::from(m);
        println!(
            "{:>4} {:>4} | {:>4} {:>5} {:>4} {:>6} {:>6} | {:>6} {:>6} {:>6} | {}",
            row.n,
            row.ell,
            c.group.p1,
            c.group.depth,
            c.group.openings,
            c.group.c_u_bits,
            c.c_t_bits,
            row.r,
            row.c_u,
            row.c_t,
            if m { "=" } else { "≠" }
        );
    }
    println!("\nexact row matches: {matches}/{total} (deltas analysed in EXPERIMENTS.md — the paper's R column does not follow a single consistent formula; every published n₁ ≤ 6 row matches ours exactly)");

    section("paper-row self-consistency audit (C_u = R·logp ∧ C_T = l·C_u)");
    let rows = cost::paper_tables();
    let incons: Vec<_> = rows
        .iter()
        .filter(|r| {
            r.c_u != (r.r as u64) * r.log_p1 as u64 || r.c_t != r.ell as u64 * r.c_u
        })
        .collect();
    println!(
        "{} of {} published rows are internally inconsistent:",
        incons.len(),
        rows.len()
    );
    for r in incons {
        println!(
            "  n={:<3} l={:<2}: published R·logp = {}·{} = {} vs C_u = {}; l·C_u = {} vs C_T = {}",
            r.n,
            r.ell,
            r.r,
            r.log_p1,
            r.r as u64 * r.log_p1 as u64,
            r.c_u,
            r.ell as u64 * r.c_u,
            r.c_t
        );
    }

    section("cost-model construction time (the sweep above, timed)");
    let mut b = Bencher::new();
    b.bench("optimal_ell n=100 (search over every divisor)", || {
        black_box(cost::optimal_ell(black_box(100), TiePolicy::OneBit, false))
    });
    b.bench("config_cost full paper sweep (Tables VIII/IX rows)", || {
        let mut acc = 0u64;
        for row in cost::paper_tables() {
            if row.n % row.ell != 0 {
                continue;
            }
            acc += cost::config_cost(row.n, row.ell, TiePolicy::OneBit, false).c_t_bits;
        }
        acc
    });
    b.write_json("tables789_comm_costs");
}
