//! Bench: what the wire costs — in-process scheduler rounds vs the same
//! rounds over the loopback-TCP service (JSON framing + syscalls + the
//! per-shard routing path), at the paper's n=24/ℓ=8 operating point.
//!
//! Four comparisons:
//!
//! 1. **Round latency** — mean admitted-round time, in-process session
//!    vs `ServiceClient::submit_round` against a `ServiceServer` in the
//!    same process (loopback TCP, so the numbers isolate transport cost
//!    from network cost).
//! 2. **Framing overhead** — the per-round wire bytes (request +
//!    reply), reported so the `+`/`-` sign-string encoding's ~20x win
//!    over number arrays stays visible.
//! 3. **Binary codec** — the same rounds at d=2048 over the negotiated
//!    v2 binary framing vs JSON, with bytes/round for both codecs.
//!    Strict mode pins the binary wire round into 2x of in-process —
//!    the acceptance bar for the framing being "nearly free" at the
//!    paper's operating point.
//! 4. **Per-shard parallel wire path** — two sessions on two different
//!    shards driven serially (one connection, alternating rounds) vs
//!    concurrently (two connections, two threads). Under the old
//!    whole-frontend mutex these were the same speed; with per-shard
//!    locks the concurrent sweep must beat the serialized one.
//!
//! Wall-clock assertions are opt-in via `HISAFE_BENCH_STRICT=1`
//! (advisory runs only print; CI compile-gates with `--no-run`).
//! Correctness (remote votes ≡ local votes) is asserted always — a
//! bench that computes wrong votes measures nothing.

use hisafe::engine::QosPolicy;
use hisafe::poly::TiePolicy;
use hisafe::protocol::HiSafeConfig;
use hisafe::service::{AggFrontend, Codec, Request, ServiceClient, ServiceServer};
use hisafe::util::bench::{black_box, section, Bencher};
use hisafe::util::rng::{Rng, Xoshiro256pp};
use std::time::{Duration, Instant};

fn main() {
    let strict = std::env::var("HISAFE_BENCH_STRICT").map(|v| v == "1").unwrap_or(false);
    let fast = std::env::var("HISAFE_BENCH_FAST").ok().is_some();
    let d: usize = if fast { 1024 } else { 4096 };
    let rounds: usize = if fast { 3 } else { 8 };
    let cfg = HiSafeConfig::hierarchical(24, 8, TiePolicy::OneBit);
    let seed = 11u64;

    let mut rng = Xoshiro256pp::seed_from_u64(7);
    let sign_sets: Vec<Vec<Vec<i8>>> = (0..rounds)
        .map(|_| {
            (0..cfg.n)
                .map(|_| (0..d).map(|_| rng.gen_sign()).collect())
                .collect()
        })
        .collect();

    // ---- in-process baseline --------------------------------------------
    section(&format!(
        "in-process: {rounds} rounds at n={}, ell={}, d={d} (one scheduler session)",
        cfg.n, cfg.ell
    ));
    let mut local_votes: Vec<Vec<i8>> = Vec::with_capacity(rounds);
    let local_mean = {
        let fe = AggFrontend::new(1, 2);
        // Same frontend code path as the server, minus the transport:
        // what the wire adds is exactly the difference to measure.
        let sid = match fe.handle(&Request::SessionOpen {
            cfg,
            d,
            seed,
            qos: QosPolicy::unlimited(),
            codec: None,
        }) {
            hisafe::service::Response::Admission(r) => r.session.expect("admitted"),
            other => panic!("unexpected reply: {other:?}"),
        };
        // Warm up the dealing plane so both sides measure steady state.
        fe.handle(&Request::Prefetch { session: sid, rounds: 1 });
        let t0 = Instant::now();
        for signs in &sign_sets {
            match fe.handle(&Request::RoundSubmit {
                session: sid,
                signs: signs.clone(),
                present: None,
            }) {
                hisafe::service::Response::Vote(v) => {
                    black_box(v.global_vote[0]);
                    local_votes.push(v.global_vote);
                }
                other => panic!("unexpected reply: {other:?}"),
            }
        }
        t0.elapsed().as_secs_f64() / rounds as f64
    };
    println!("  mean round: {:.3} ms", local_mean * 1e3);

    // ---- loopback TCP ---------------------------------------------------
    section("loopback TCP: the same rounds through ServiceServer/ServiceClient");
    let server =
        ServiceServer::bind("127.0.0.1:0", AggFrontend::new(1, 2)).expect("bind loopback");
    let addr = server.local_addr().expect("bound addr").to_string();
    let serve = std::thread::spawn(move || server.serve());
    let mut client = ServiceClient::connect(&addr).expect("connect");
    let sid = client.open_session(cfg, d, seed, QosPolicy::unlimited()).expect("admitted");
    client.prefetch(sid, 1).expect("warm-up prefetch");
    // One frame's size, for the framing-overhead report.
    let req_bytes = Request::RoundSubmit {
        session: sid,
        signs: sign_sets[0].clone(),
        present: None,
    }
    .to_json()
    .to_string_compact()
    .len();
    let remote_mean = {
        let t0 = Instant::now();
        for (r, signs) in sign_sets.iter().enumerate() {
            let reply = client.submit_round(sid, signs).expect("round admitted");
            black_box(reply.global_vote[0]);
            assert_eq!(
                reply.global_vote, local_votes[r],
                "remote round {r} diverged from in-process"
            );
        }
        t0.elapsed().as_secs_f64() / rounds as f64
    };
    println!("  mean round: {:.3} ms", remote_mean * 1e3);
    println!(
        "  wire overhead: {:.3} ms/round ({:.1}x); request frame {:.1} KiB \
         ({} users x {d} coords as sign-chars)",
        (remote_mean - local_mean) * 1e3,
        remote_mean / local_mean,
        req_bytes as f64 / 1024.0,
        cfg.n
    );

    client.close_session(sid).expect("close");
    client.shutdown().expect("shutdown");
    serve.join().expect("serve thread").expect("clean shutdown");

    // ---- binary codec at d=2048 -----------------------------------------
    let d2: usize = if fast { 1024 } else { 2048 };
    section(&format!(
        "binary codec: {rounds} rounds at d={d2}, negotiated v2 framing vs JSON"
    ));
    let mut rng2 = Xoshiro256pp::seed_from_u64(13);
    let sign_sets2: Vec<Vec<Vec<i8>>> = (0..rounds)
        .map(|_| {
            (0..cfg.n)
                .map(|_| (0..d2).map(|_| rng2.gen_sign()).collect())
                .collect()
        })
        .collect();
    // Fresh in-process baseline at this dimension.
    let mut local2_votes: Vec<Vec<i8>> = Vec::with_capacity(rounds);
    let local2_mean = {
        let fe = AggFrontend::new(1, 2);
        let sid = match fe.handle(&Request::SessionOpen {
            cfg,
            d: d2,
            seed,
            qos: QosPolicy::unlimited(),
            codec: None,
        }) {
            hisafe::service::Response::Admission(r) => r.session.expect("admitted"),
            other => panic!("unexpected reply: {other:?}"),
        };
        fe.handle(&Request::Prefetch { session: sid, rounds: 1 });
        let t0 = Instant::now();
        for signs in &sign_sets2 {
            match fe.handle(&Request::RoundSubmit {
                session: sid,
                signs: signs.clone(),
                present: None,
            }) {
                hisafe::service::Response::Vote(v) => {
                    black_box(v.global_vote[0]);
                    local2_votes.push(v.global_vote);
                }
                other => panic!("unexpected reply: {other:?}"),
            }
        }
        t0.elapsed().as_secs_f64() / rounds as f64
    };
    println!("  in-process mean round: {:.3} ms", local2_mean * 1e3);
    let server =
        ServiceServer::bind("127.0.0.1:0", AggFrontend::new(1, 2)).expect("bind loopback");
    let addr = server.local_addr().expect("bound addr").to_string();
    let serve = std::thread::spawn(move || server.serve());
    // Binary-negotiated client. Sessions opened with the same (cfg, d,
    // seed) regenerate the same triple streams, so every client below
    // must reproduce the in-process votes bit-for-bit.
    let mut bclient = ServiceClient::connect_with_codec(&addr, Codec::Binary).expect("connect");
    let bsid = bclient.open_session(cfg, d2, seed, QosPolicy::unlimited()).expect("admitted");
    assert_eq!(bclient.codec(), Codec::Binary, "server must ack the binary ask");
    bclient.prefetch(bsid, 1).expect("warm-up prefetch");
    let bin_bytes0 = bclient.bytes_sent() + bclient.bytes_received();
    let binary_mean = {
        let t0 = Instant::now();
        for (r, signs) in sign_sets2.iter().enumerate() {
            let reply = bclient.submit_round(bsid, signs).expect("round admitted");
            black_box(reply.global_vote[0]);
            assert_eq!(
                reply.global_vote, local2_votes[r],
                "binary-codec round {r} diverged from in-process"
            );
        }
        t0.elapsed().as_secs_f64() / rounds as f64
    };
    let bin_bytes_round =
        (bclient.bytes_sent() + bclient.bytes_received() - bin_bytes0) / rounds as u64;
    // The same rounds over a plain JSON connection, for the bandwidth
    // comparison (and to keep the compatibility codec measured).
    let mut jclient = ServiceClient::connect(&addr).expect("connect json");
    let jsid = jclient.open_session(cfg, d2, seed, QosPolicy::unlimited()).expect("admitted");
    jclient.prefetch(jsid, 1).expect("warm-up prefetch");
    let json_bytes0 = jclient.bytes_sent() + jclient.bytes_received();
    let json2_mean = {
        let t0 = Instant::now();
        for (r, signs) in sign_sets2.iter().enumerate() {
            let reply = jclient.submit_round(jsid, signs).expect("round admitted");
            black_box(reply.global_vote[0]);
            assert_eq!(
                reply.global_vote, local2_votes[r],
                "json-codec round {r} diverged from in-process"
            );
        }
        t0.elapsed().as_secs_f64() / rounds as f64
    };
    let json_bytes_round =
        (jclient.bytes_sent() + jclient.bytes_received() - json_bytes0) / rounds as u64;
    println!(
        "  binary: {:.3} ms/round, {} bytes/round  |  json: {:.3} ms/round, {} bytes/round \
         ({:.1}x smaller frames)",
        binary_mean * 1e3,
        bin_bytes_round,
        json2_mean * 1e3,
        json_bytes_round,
        json_bytes_round as f64 / bin_bytes_round as f64
    );
    bclient.close_session(bsid).expect("close");
    jclient.close_session(jsid).expect("close");
    jclient.shutdown().expect("shutdown");
    serve.join().expect("serve thread").expect("clean shutdown");

    // ---- per-shard parallel wire path -----------------------------------
    section("parallel wire path: 2 sessions on 2 shards, serialized vs concurrent");
    let server = ServiceServer::bind_with_workers("127.0.0.1:0", AggFrontend::new(2, 2), 4)
        .expect("bind loopback");
    let addr = server.local_addr().expect("bound addr").to_string();
    let serve = std::thread::spawn(move || server.serve());
    let mut setup = ServiceClient::connect(&addr).expect("connect");
    // Rendezvous placement is seed-driven: open sessions until two land
    // on different shards (and release the rest).
    let mut pinned: Vec<(hisafe::engine::SessionId, usize)> = Vec::new();
    let mut probe = 0u64;
    while pinned.len() < 2 {
        let sid = setup
            .open_session(cfg, d, 1000 + probe, QosPolicy::unlimited())
            .expect("admitted");
        let shard = setup.stats(Some(sid)).expect("stats").shard.expect("shard");
        if pinned.iter().all(|&(_, sh)| sh != shard) {
            setup.prefetch(sid, 1).expect("warm-up prefetch");
            pinned.push((sid, shard));
        } else {
            setup.close_session(sid).expect("close probe");
        }
        probe += 1;
        assert!(probe < 100, "rendezvous never covered both shards");
    }

    // Serialized sweep: one connection alternates rounds between the
    // two sessions — every round waits for the previous one.
    let serial_total = {
        let t0 = Instant::now();
        for signs in &sign_sets {
            for &(sid, _) in &pinned {
                let reply = setup.submit_round(sid, signs).expect("round admitted");
                black_box(reply.global_vote[0]);
            }
        }
        t0.elapsed().as_secs_f64()
    };
    println!("  serialized (1 conn): {:.3} ms total", serial_total * 1e3);

    // Concurrent sweep: each session gets its own connection + thread;
    // per-shard locks let both shards run rounds at the same time.
    let concurrent_total = {
        let t0 = Instant::now();
        let drivers: Vec<_> = pinned
            .iter()
            .map(|&(sid, _)| {
                let addr = addr.clone();
                let sign_sets = sign_sets.clone();
                std::thread::spawn(move || {
                    let mut client = ServiceClient::connect(&addr).expect("connect");
                    for signs in &sign_sets {
                        let reply = client.submit_round(sid, signs).expect("round admitted");
                        black_box(reply.global_vote[0]);
                    }
                })
            })
            .collect();
        for dr in drivers {
            dr.join().expect("driver thread");
        }
        t0.elapsed().as_secs_f64()
    };
    println!(
        "  concurrent (2 conns): {:.3} ms total ({:.2}x of serialized)",
        concurrent_total * 1e3,
        concurrent_total / serial_total
    );

    for &(sid, _) in &pinned {
        setup.close_session(sid).expect("close");
    }
    setup.shutdown().expect("shutdown");
    serve.join().expect("serve thread").expect("clean shutdown");

    if strict {
        // The tentpole claim: with per-shard locks, two shards serve two
        // wire-round streams concurrently — the old whole-frontend mutex
        // made this ratio ~1.0. The bound is loose (engine pools share
        // cores, runners are noisy); it exists to catch the wire path
        // re-serializing, which pushes the ratio back to ~1.
        assert!(
            concurrent_total < serial_total * 0.8,
            "concurrent shard sweeps did not beat the serialized baseline: \
             {concurrent_total:.6}s vs {serial_total:.6}s"
        );
    }

    let mut b = Bencher::new();
    b.record("in-process mean round", Duration::from_secs_f64(local_mean));
    b.record("loopback-TCP mean round", Duration::from_secs_f64(remote_mean));
    b.record(
        "binary-codec loopback mean round",
        Duration::from_secs_f64(binary_mean),
    );
    b.annotate_throughput(bin_bytes_round as f64, "bytes/round");
    b.record(
        "json-codec loopback mean round",
        Duration::from_secs_f64(json2_mean),
    );
    b.annotate_throughput(json_bytes_round as f64, "bytes/round");
    b.record("2-shard serialized sweep", Duration::from_secs_f64(serial_total));
    b.record(
        "2-shard concurrent sweep",
        Duration::from_secs_f64(concurrent_total),
    );
    b.write_json("sched_remote");

    if strict {
        // Loopback TCP + JSON framing must stay in the same latency
        // class as in-process rounds at model-sized d — the engine work
        // dominates, the wire does not. Generous bounds: shared runners
        // are noisy, and the point is catching order-of-magnitude
        // regressions (e.g. accidental per-round reconnects or O(d)
        // re-parsing blowups), not micro-variance.
        assert!(
            remote_mean < local_mean * 30.0 + 0.01,
            "wire rounds fell out of the in-process latency class: \
             remote {remote_mean:.6}s vs local {local_mean:.6}s"
        );
        // The sign-char encoding keeps a round's request frame near
        // n*d bytes (plus fixed framing), not the ~5x of number arrays.
        assert!(
            req_bytes < cfg.n * d * 2 + 4096,
            "request framing blew up: {req_bytes} bytes for n={} d={d}",
            cfg.n
        );
        // The v2 binary codec's acceptance bar: at d=2048 a negotiated
        // wire round stays within 2x of the in-process round — the
        // framing is nearly free next to the MPC work (small additive
        // epsilon so sub-millisecond jitter can't flake the ratio).
        assert!(
            binary_mean < local2_mean * 2.0 + 0.005,
            "binary wire rounds exceeded 2x in-process at d={d2}: \
             remote {binary_mean:.6}s vs local {local2_mean:.6}s"
        );
        // And binary frames are materially smaller than JSON: 2 bits
        // per sign coordinate vs one char, ≥3x end to end per round.
        assert!(
            bin_bytes_round * 3 <= json_bytes_round,
            "binary framing lost its size win: {bin_bytes_round} vs \
             {json_bytes_round} bytes/round"
        );
    }
}
