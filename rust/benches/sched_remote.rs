//! Bench: what the wire costs — in-process scheduler rounds vs the same
//! rounds over the loopback-TCP service (JSON framing + syscalls + the
//! frontend mutex), at the paper's n=24/ℓ=8 operating point.
//!
//! Two comparisons:
//!
//! 1. **Round latency** — mean admitted-round time, in-process session
//!    vs `ServiceClient::submit_round` against a `ServiceServer` in the
//!    same process (loopback TCP, so the numbers isolate transport cost
//!    from network cost).
//! 2. **Framing overhead** — the per-round wire bytes (request +
//!    reply), reported so the `+`/`-` sign-string encoding's ~20x win
//!    over number arrays stays visible.
//!
//! Wall-clock assertions are opt-in via `HISAFE_BENCH_STRICT=1`
//! (advisory runs only print; CI compile-gates with `--no-run`).
//! Correctness (remote votes ≡ local votes) is asserted always — a
//! bench that computes wrong votes measures nothing.

use hisafe::engine::QosPolicy;
use hisafe::poly::TiePolicy;
use hisafe::protocol::HiSafeConfig;
use hisafe::service::{AggFrontend, Request, ServiceClient, ServiceServer};
use hisafe::util::bench::{black_box, section};
use hisafe::util::rng::{Rng, Xoshiro256pp};
use std::time::Instant;

fn main() {
    let strict = std::env::var("HISAFE_BENCH_STRICT").map(|v| v == "1").unwrap_or(false);
    let fast = std::env::var("HISAFE_BENCH_FAST").ok().is_some();
    let d: usize = if fast { 1024 } else { 4096 };
    let rounds: usize = if fast { 3 } else { 8 };
    let cfg = HiSafeConfig::hierarchical(24, 8, TiePolicy::OneBit);
    let seed = 11u64;

    let mut rng = Xoshiro256pp::seed_from_u64(7);
    let sign_sets: Vec<Vec<Vec<i8>>> = (0..rounds)
        .map(|_| {
            (0..cfg.n)
                .map(|_| (0..d).map(|_| rng.gen_sign()).collect())
                .collect()
        })
        .collect();

    // ---- in-process baseline --------------------------------------------
    section(&format!(
        "in-process: {rounds} rounds at n={}, ell={}, d={d} (one scheduler session)",
        cfg.n, cfg.ell
    ));
    let mut local_votes: Vec<Vec<i8>> = Vec::with_capacity(rounds);
    let local_mean = {
        let mut fe = AggFrontend::new(1, 2);
        // Same frontend code path as the server, minus the transport:
        // what the wire adds is exactly the difference to measure.
        let sid = match fe.handle(&Request::SessionOpen {
            cfg,
            d,
            seed,
            qos: QosPolicy::unlimited(),
        }) {
            hisafe::service::Response::Admission(r) => r.session.expect("admitted"),
            other => panic!("unexpected reply: {other:?}"),
        };
        // Warm up the dealing plane so both sides measure steady state.
        fe.handle(&Request::Prefetch { session: sid, rounds: 1 });
        let t0 = Instant::now();
        for signs in &sign_sets {
            match fe.handle(&Request::RoundSubmit { session: sid, signs: signs.clone() }) {
                hisafe::service::Response::Vote(v) => {
                    black_box(v.global_vote[0]);
                    local_votes.push(v.global_vote);
                }
                other => panic!("unexpected reply: {other:?}"),
            }
        }
        t0.elapsed().as_secs_f64() / rounds as f64
    };
    println!("  mean round: {:.3} ms", local_mean * 1e3);

    // ---- loopback TCP ---------------------------------------------------
    section("loopback TCP: the same rounds through ServiceServer/ServiceClient");
    let server =
        ServiceServer::bind("127.0.0.1:0", AggFrontend::new(1, 2)).expect("bind loopback");
    let addr = server.local_addr().expect("bound addr").to_string();
    let serve = std::thread::spawn(move || server.serve());
    let mut client = ServiceClient::connect(&addr).expect("connect");
    let sid = client.open_session(cfg, d, seed, QosPolicy::unlimited()).expect("admitted");
    client.prefetch(sid, 1).expect("warm-up prefetch");
    // One frame's size, for the framing-overhead report.
    let req_bytes = Request::RoundSubmit { session: sid, signs: sign_sets[0].clone() }
        .to_json()
        .to_string_compact()
        .len();
    let remote_mean = {
        let t0 = Instant::now();
        for (r, signs) in sign_sets.iter().enumerate() {
            let reply = client.submit_round(sid, signs).expect("round admitted");
            black_box(reply.global_vote[0]);
            assert_eq!(
                reply.global_vote, local_votes[r],
                "remote round {r} diverged from in-process"
            );
        }
        t0.elapsed().as_secs_f64() / rounds as f64
    };
    println!("  mean round: {:.3} ms", remote_mean * 1e3);
    println!(
        "  wire overhead: {:.3} ms/round ({:.1}x); request frame {:.1} KiB \
         ({} users x {d} coords as sign-chars)",
        (remote_mean - local_mean) * 1e3,
        remote_mean / local_mean,
        req_bytes as f64 / 1024.0,
        cfg.n
    );

    client.close_session(sid).expect("close");
    client.shutdown().expect("shutdown");
    serve.join().expect("serve thread").expect("clean shutdown");

    if strict {
        // Loopback TCP + JSON framing must stay in the same latency
        // class as in-process rounds at model-sized d — the engine work
        // dominates, the wire does not. Generous bounds: shared runners
        // are noisy, and the point is catching order-of-magnitude
        // regressions (e.g. accidental per-round reconnects or O(d)
        // re-parsing blowups), not micro-variance.
        assert!(
            remote_mean < local_mean * 30.0 + 0.01,
            "wire rounds fell out of the in-process latency class: \
             remote {remote_mean:.6}s vs local {local_mean:.6}s"
        );
        // The sign-char encoding keeps a round's request frame near
        // n*d bytes (plus fixed framing), not the ~5x of number arrays.
        assert!(
            req_bytes < cfg.n * d * 2 + 4096,
            "request framing blew up: {req_bytes} bytes for n={} d={d}",
            cfg.n
        );
    }
}
