//! Bench: what precision costs — the same tenant shape swept across
//! q ∈ {2, 4, 8, 16}, measuring wire bytes/round and round latency on
//! the negotiated v2 binary codec (packed b-bit level coordinates:
//! 2/3/4/5 bits at q = 2/4/8/16) and on the v1 JSON codec, at the
//! paper's n=24/ℓ=8 operating point.
//!
//! The headline claim is the uplink scaling law: a level coordinate
//! costs ⌈log₂ q⌉ + 1 bits, so quadrupling the quantization alphabet
//! (q=2 → q=8) costs 2 extra bits per coordinate, not a reformat to
//! bytes. Strict mode pins the packed binary frames to that law —
//! monotone in q, with q=16 frames under 3x of q=2 (the ideal ratio is
//! 5/2, framing overhead only shrinks it) — and pins binary under JSON
//! at every q. Wall-clock is reported but never asserted (shared
//! runners are noisy); vote correctness against the q-level plaintext
//! reference is asserted always — a bench that computes wrong votes
//! measures nothing.
//!
//! Opt-in assertions via `HISAFE_BENCH_STRICT=1`; `HISAFE_BENCH_FAST=1`
//! shrinks d and the round count for smoke runs.

use hisafe::engine::QosPolicy;
use hisafe::poly::TiePolicy;
use hisafe::protocol::{plain_quant_aggregate, HiSafeConfig};
use hisafe::service::{AggFrontend, Codec, ServiceClient, ServiceServer};
use hisafe::util::bench::{black_box, section, Bencher};
use hisafe::util::rng::{Rng, Xoshiro256pp};
use std::time::{Duration, Instant};

fn main() {
    let strict = std::env::var("HISAFE_BENCH_STRICT").map(|v| v == "1").unwrap_or(false);
    let fast = std::env::var("HISAFE_BENCH_FAST").ok().is_some();
    let d: usize = if fast { 256 } else { 1024 };
    let rounds: usize = if fast { 2 } else { 4 };
    let base = HiSafeConfig::hierarchical(24, 8, TiePolicy::OneBit);
    let seed = 29u64;

    let mut b = Bencher::new();
    // (q, binary mean s/round, binary bytes/round, json bytes/round)
    let mut rows: Vec<(u8, f64, u64, u64)> = Vec::new();

    for &q in &hisafe::quant::PRECISIONS {
        let cfg = base.with_precision(q);
        section(&format!(
            "q={q}: {rounds} rounds at n={}, ell={}, d={d} (p1={})",
            cfg.n,
            cfg.ell,
            hisafe::cost::group_cost_q(cfg.n / cfg.ell, q, cfg.intra, cfg.sparse).p1
        ));

        // Deterministic level matrices from L_q (odd levels only — even
        // values never reach the wire).
        let mut rng = Xoshiro256pp::seed_from_u64(17 ^ q as u64);
        let sign_sets: Vec<Vec<Vec<i8>>> = (0..rounds)
            .map(|_| {
                (0..cfg.n)
                    .map(|_| {
                        (0..d)
                            .map(|_| {
                                (2 * rng.gen_below(q as u64) as i64 - (q as i64 - 1)) as i8
                            })
                            .collect()
                    })
                    .collect()
            })
            .collect();
        let expected: Vec<Vec<i8>> =
            sign_sets.iter().map(|signs| plain_quant_aggregate(signs, cfg)).collect();

        let server = ServiceServer::bind("127.0.0.1:0", AggFrontend::new(1, 2))
            .expect("bind loopback");
        let addr = server.local_addr().expect("bound addr").to_string();
        let serve = std::thread::spawn(move || server.serve());

        // Binary-negotiated client: latency + packed-frame bytes.
        let mut bclient =
            ServiceClient::connect_with_codec(&addr, Codec::Binary).expect("connect");
        let bsid =
            bclient.open_session(cfg, d, seed, QosPolicy::unlimited()).expect("admitted");
        assert_eq!(bclient.codec(), Codec::Binary, "server must ack the binary ask");
        bclient.prefetch(bsid, 1).expect("warm-up prefetch");
        let bin0 = bclient.bytes_sent() + bclient.bytes_received();
        let bin_mean = {
            let t0 = Instant::now();
            for (r, signs) in sign_sets.iter().enumerate() {
                let reply = bclient.submit_round(bsid, signs).expect("round admitted");
                black_box(reply.global_vote[0]);
                assert_eq!(
                    reply.global_vote, expected[r],
                    "q={q} binary round {r} diverged from the plaintext reference"
                );
            }
            t0.elapsed().as_secs_f64() / rounds as f64
        };
        let bin_bytes_round =
            (bclient.bytes_sent() + bclient.bytes_received() - bin0) / rounds as u64;

        // The same rounds over plain v1 JSON, for the bandwidth column.
        let mut jclient = ServiceClient::connect(&addr).expect("connect json");
        let jsid =
            jclient.open_session(cfg, d, seed, QosPolicy::unlimited()).expect("admitted");
        jclient.prefetch(jsid, 1).expect("warm-up prefetch");
        let json0 = jclient.bytes_sent() + jclient.bytes_received();
        let json_mean = {
            let t0 = Instant::now();
            for (r, signs) in sign_sets.iter().enumerate() {
                let reply = jclient.submit_round(jsid, signs).expect("round admitted");
                black_box(reply.global_vote[0]);
                assert_eq!(
                    reply.global_vote, expected[r],
                    "q={q} json round {r} diverged from the plaintext reference"
                );
            }
            t0.elapsed().as_secs_f64() / rounds as f64
        };
        let json_bytes_round =
            (jclient.bytes_sent() + jclient.bytes_received() - json0) / rounds as u64;

        bclient.close_session(bsid).expect("close binary session");
        jclient.close_session(jsid).expect("close json session");
        drop(bclient);
        jclient.shutdown().expect("shutdown");
        serve.join().expect("serve thread").expect("clean shutdown");

        println!(
            "  binary: {:.3} ms/round, {} bytes/round ({} bits/coord)  |  \
             json: {:.3} ms/round, {} bytes/round",
            bin_mean * 1e3,
            bin_bytes_round,
            hisafe::quant::uplink_bits(q),
            json_mean * 1e3,
            json_bytes_round
        );

        b.record(
            &format!("q={q} binary wire mean round"),
            Duration::from_secs_f64(bin_mean),
        );
        b.annotate_throughput(bin_bytes_round as f64, "bytes/round");
        b.record(
            &format!("q={q} json wire mean round"),
            Duration::from_secs_f64(json_mean),
        );
        b.annotate_throughput(json_bytes_round as f64, "bytes/round");
        rows.push((q, bin_mean, bin_bytes_round, json_bytes_round));
    }

    b.write_json("quant_precision");

    if strict {
        // The scaling law on the packed binary frames. Bytes are a pure
        // function of (n, d, q) plus fixed framing, so these bounds are
        // deterministic — unlike wall-clock, they cannot flake.
        for w in rows.windows(2) {
            let ((qa, _, ba, ja), (qb, _, bb, jb)) = (w[0], w[1]);
            assert!(
                bb >= ba,
                "binary frames shrank as precision grew: q={qa} {ba} B vs q={qb} {bb} B"
            );
            assert!(
                jb >= ja,
                "json frames shrank as precision grew: q={qa} {ja} B vs q={qb} {jb} B"
            );
        }
        let (_, _, bin_q2, _) = rows[0];
        let (_, _, bin_q16, _) = rows[rows.len() - 1];
        assert!(
            bin_q16 < bin_q2 * 3,
            "packed coordinates lost the log2(q) law: q=16 frames are {bin_q16} B \
             vs q=2 {bin_q2} B (ideal ratio 5/2)"
        );
        for &(q, _, bin_bytes, json_bytes) in &rows {
            assert!(
                bin_bytes <= json_bytes,
                "q={q}: binary frames ({bin_bytes} B) must never exceed JSON \
                 ({json_bytes} B)"
            );
        }
    }
}
