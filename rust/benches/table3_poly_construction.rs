//! Bench: majority-vote polynomial construction (Table III content,
//! Table IV complexity claim `O(n log p)` vs `O(n₁ log p₁)`).
//!
//! Prints the polynomials (regenerating Table III) and times both
//! constructions across group sizes, demonstrating the subgrouping
//! reduction: constructing F for n₁ = 3 is orders cheaper than for n = 100.

use hisafe::poly::{MvPolynomial, TiePolicy};
use hisafe::util::bench::{black_box, section, Bencher};

fn main() {
    section("Table III: precomputed majority-vote polynomials");
    for n in 2..=6 {
        let a = MvPolynomial::build_fermat(n, TiePolicy::OneBit);
        let b = MvPolynomial::build_fermat(n, TiePolicy::TwoBit);
        println!(
            "n={n}: 1-bit: {:<40} 2-bit: {}",
            a.poly.display(),
            b.poly.display()
        );
    }

    let mut b = Bencher::new();
    section("Table IV: construction cost — flat group sizes");
    for n in [12usize, 24, 36, 60, 100] {
        b.bench(&format!("fermat_construct n={n} (flat)"), || {
            black_box(MvPolynomial::build_fermat(black_box(n), TiePolicy::OneBit))
        });
    }
    section("Table IV: construction cost — optimal subgroup sizes");
    for n1 in [3usize, 4, 5, 6] {
        b.bench(&format!("fermat_construct n1={n1} (subgrouped)"), || {
            black_box(MvPolynomial::build_fermat(black_box(n1), TiePolicy::OneBit))
        });
    }
    section("cross-check: Lagrange construction (must equal Fermat)");
    for n in [6usize, 24] {
        b.bench(&format!("lagrange_construct n={n}"), || {
            black_box(MvPolynomial::build_lagrange(black_box(n), TiePolicy::OneBit))
        });
    }

    // report the Table-IV ratio
    let flat = b.results().iter().find(|s| s.name.contains("n=100")).unwrap();
    let sub = b.results().iter().find(|s| s.name.contains("n1=3")).unwrap();
    println!(
        "\nconstruction speedup n=100 flat vs n1=3 subgrouped: {:.0}x",
        flat.median.as_secs_f64() / sub.median.as_secs_f64()
    );
    b.write_json("table3_poly_construction");
}
