//! Bench: k dedicated `PipelinedEngine`s vs ONE `AggScheduler`
//! multiplexing k tenant sessions, at equal total work.
//!
//! The dedicated configuration spawns k worker pools and k provisioning
//! threads (the pre-scheduler world: thread count grows k-fold with
//! tenancy); the scheduler runs the same rounds on exactly one pool's
//! worth of span workers plus one dealer thread. On a machine with fewer
//! spare cores than the dedicated configuration wants, the shared
//! scheduler avoids the oversubscription thrash; on a wide machine the
//! dedicated engines can use more silicon — the point of the bench is to
//! see the trade, not to declare a universal winner, so wall-clock
//! assertions are opt-in via `HISAFE_BENCH_STRICT=1` (advisory runs only
//! print, and `cargo bench --no-run` compile-gates this file in CI).

use hisafe::engine::{AggScheduler, AggSession, Engine, PipelinedEngine};
use hisafe::poly::TiePolicy;
use hisafe::protocol::HiSafeConfig;
use hisafe::util::bench::{black_box, section, Bencher};
use hisafe::util::rng::{Rng, Xoshiro256pp};
use std::time::Instant;

fn main() {
    let strict = std::env::var("HISAFE_BENCH_STRICT").map(|v| v == "1").unwrap_or(false);
    let fast = std::env::var("HISAFE_BENCH_FAST").ok().is_some();
    let rounds: usize = if fast { 2 } else { 4 };
    let d: usize = if fast { 2048 } else { 8192 };

    // A mixed-tenant workload: the paper's n=24/ℓ=8 operating point next
    // to two smaller federations (different polynomials, depths, and
    // triple appetites — the multiplexing case the scheduler exists for).
    let shapes: Vec<HiSafeConfig> = vec![
        HiSafeConfig::hierarchical(24, 8, TiePolicy::OneBit),
        HiSafeConfig::hierarchical(12, 4, TiePolicy::OneBit),
        HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit),
    ];
    let k = shapes.len();
    let mut rng = Xoshiro256pp::seed_from_u64(3);
    let signs: Vec<Vec<Vec<i8>>> = shapes
        .iter()
        .map(|cfg| {
            (0..cfg.n)
                .map(|_| (0..d).map(|_| rng.gen_sign()).collect())
                .collect()
        })
        .collect();

    section(&format!(
        "{k} tenants × {rounds} rounds at d = {d}: dedicated engines vs one scheduler"
    ));
    let mut acc = 0i64;

    // Dedicated: every engine owns a worker pool + provisioning plane.
    let t0 = Instant::now();
    {
        let mut engines: Vec<PipelinedEngine> = shapes
            .iter()
            .enumerate()
            .map(|(i, cfg)| PipelinedEngine::new(*cfg, d, 42 + i as u64))
            .collect();
        for _ in 0..rounds {
            for (i, engine) in engines.iter_mut().enumerate() {
                acc += engine.run_round(&signs[i]).global_vote[0] as i64;
            }
        }
    }
    let dedicated_t = t0.elapsed();

    // Shared: one scheduler, k sessions, identical rounds and seeds.
    // Construction AND teardown sit inside the timed region, exactly
    // like the dedicated block above, so neither side hides setup,
    // prefetch-drain, or thread-join cost from the comparison.
    let t0 = Instant::now();
    let (shared_workers, shared_dealers) = {
        let sched = AggScheduler::new();
        let counts = (sched.worker_threads(), sched.dealer_threads());
        let mut sessions: Vec<AggSession> = shapes
            .iter()
            .enumerate()
            .map(|(i, cfg)| sched.session(*cfg, d, 42 + i as u64))
            .collect();
        for _ in 0..rounds {
            for (i, session) in sessions.iter_mut().enumerate() {
                acc += session.run_round(&signs[i]).global_vote[0] as i64;
            }
        }
        counts
    };
    let shared_t = t0.elapsed();
    black_box(acc);

    println!(
        "  dedicated ({k} pools + {k} dealer threads): {:.1} ms",
        dedicated_t.as_secs_f64() * 1e3
    );
    println!(
        "  scheduler ({shared_workers} span workers + {shared_dealers} dealer thread, shared): {:.1} ms",
        shared_t.as_secs_f64() * 1e3
    );
    println!(
        "  shared/dedicated: {:.2}x  (threads: one pool's worth vs {k}x)",
        shared_t.as_secs_f64() / dedicated_t.as_secs_f64()
    );
    let mut b = Bencher::new();
    b.record("dedicated engines, full workload", dedicated_t);
    b.record("shared scheduler, full workload", shared_t);
    b.write_json("sched_multi_tenant");

    if strict {
        // The scheduler trades peak parallelism for a bounded thread
        // budget; at equal total work it must stay in the same
        // performance class as k oversubscribing engines.
        assert!(
            shared_t.as_secs_f64() < dedicated_t.as_secs_f64() * 1.5,
            "one scheduler fell out of the dedicated engines' class: \
             {shared_t:?} vs {dedicated_t:?}"
        );
    }
}
