//! Bench: Table V — runtime of Algorithm 1 phases under the adopted
//! subgroup configuration (n = 24, ℓ = 8, n₁ = 3, d_sub = deg F_sub).
//!
//! Paper targets: offline triple generation < 0.01 s, polynomial
//! precompute < 0.01 s, online secure evaluation 0.01–0.02 s, total
//! < 0.03 s — at FL model dimension (we use the MNIST MLP d = 25,450).

use hisafe::beaver::Dealer;
use hisafe::mpc::secure_group_vote;
use hisafe::poly::{MvPolynomial, PowerSchedule, TiePolicy};
use hisafe::util::bench::{black_box, section, Bencher};
use hisafe::util::rng::{Rng, Xoshiro256pp};

fn main() {
    let d = 25_450usize; // MNIST MLP dimension
    let ell = 8usize;
    let n1 = 3usize;
    let mv = MvPolynomial::build_fermat(n1, TiePolicy::OneBit);
    let sched = PowerSchedule::full(mv.degree());
    let mut b = Bencher::new();

    section(&format!(
        "Table V (n=24, ℓ={ell}, n₁={n1}, d={d}, {} mults/group)",
        sched.mults()
    ));

    // Offline: Beaver triple generation for ALL subgroups, full model dim.
    let s_offline = b.bench("offline: beaver triple generation (all groups)", || {
        let mut total = 0u64;
        for g in 0..ell {
            let mut dealer = Dealer::new(mv.fp, g as u64);
            let r = dealer.gen_round(d, n1, sched.mults());
            total += r.len() as u64;
        }
        total
    });

    // Offline: polynomial precompute.
    let s_poly = b.bench("offline: precompute F_sub", || {
        black_box(MvPolynomial::build_fermat(n1, TiePolicy::OneBit))
    });

    // Online: full secure evaluation (all subgroups, model-dim vectors).
    let mut rng = Xoshiro256pp::seed_from_u64(1);
    let group_signs: Vec<Vec<Vec<i8>>> = (0..ell)
        .map(|_| (0..n1).map(|_| (0..d).map(|_| rng.gen_sign()).collect()).collect())
        .collect();
    let mut seed = 0u64;
    let s_online = b.bench("online: secure evaluation of F_sub (all groups)", || {
        seed += 1;
        let mut votes = 0i64;
        for gs in &group_signs {
            let out = secure_group_vote(gs, TiePolicy::OneBit, false, seed);
            votes += out.votes[0] as i64;
        }
        votes
    });

    println!("\nTable V summary (paper targets in parentheses):");
    println!(
        "  offline triple gen : {:>10.4} s   (< 0.01 s at paper's d)",
        s_offline.median.as_secs_f64()
    );
    println!(
        "  offline F precompute: {:>9.6} s   (< 0.01 s)",
        s_poly.median.as_secs_f64()
    );
    println!(
        "  online secure eval : {:>10.4} s   (0.01–0.02 s)",
        s_online.median.as_secs_f64()
    );
    println!(
        "  total              : {:>10.4} s   (< 0.03 s)",
        s_offline.median.as_secs_f64()
            + s_poly.median.as_secs_f64()
            + s_online.median.as_secs_f64()
    );
    b.write_json("table5_alg1_runtime");
}
