//! Heavier property + adversarial tests over the protocol stack
//! (separate from the fast unit suites; still seconds, not minutes).

use hisafe::beaver::Dealer;
use hisafe::cost;
use hisafe::field::{field_for_group, next_prime};
use hisafe::mpc::{plain_group_vote, secure_group_vote, EvalPlan, Party};
use hisafe::poly::{MvPolynomial, PowerSchedule, TiePolicy};
use hisafe::prop_assert_eq;
use hisafe::protocol::{
    partition, plain_hierarchical_vote, run_sync, run_threaded, HiSafeConfig,
};
use hisafe::util::prop::forall;
use hisafe::util::rng::{Rng, Xoshiro256pp};

/// Exhaustive protocol correctness for n = 5..8, single coordinate, all
/// 2^n sign patterns, both policies — the strongest correctness statement
/// we can check exactly.
#[test]
fn exhaustive_patterns_n5_to_8() {
    for n in 5..=8usize {
        for policy in [TiePolicy::OneBit, TiePolicy::TwoBit] {
            for pattern in 0..(1u32 << n) {
                let signs: Vec<Vec<i8>> = (0..n)
                    .map(|i| vec![if pattern >> i & 1 == 1 { 1i8 } else { -1 }])
                    .collect();
                let out = secure_group_vote(&signs, policy, false, pattern as u64);
                assert_eq!(
                    out.votes,
                    plain_group_vote(&signs, policy),
                    "n={n} {policy:?} pattern={pattern:b}"
                );
            }
        }
    }
}

/// Larger cohorts: random patterns up to n = 31 (p = 37, deg ≤ 36).
#[test]
fn large_group_random_patterns() {
    forall("secure ≡ plain up to n=31", 15, |g| {
        let n = g.usize_range(13, 31);
        let d = g.usize_range(1, 6);
        let signs: Vec<Vec<i8>> = (0..n).map(|_| g.sign_vec(d)).collect();
        let policy = if g.bool() { TiePolicy::OneBit } else { TiePolicy::TwoBit };
        let out = secure_group_vote(&signs, policy, false, g.u64());
        prop_assert_eq!(out.votes, plain_group_vote(&signs, policy), "n={n}");
        Ok(())
    });
}

/// Every (n, ℓ) from the paper's tables runs the full protocol and
/// matches Eq. 8 — the sweep Table VIII/IX implicitly assumes.
#[test]
fn paper_sweep_configs_all_correct() {
    let mut rng = Xoshiro256pp::seed_from_u64(42);
    for row in cost::paper_tables() {
        if row.n % row.ell != 0 || row.n > 40 {
            continue; // big flat configs are covered by cost tests; keep runtime sane
        }
        let cfg = HiSafeConfig {
            n: row.n,
            ell: row.ell,
            intra: TiePolicy::OneBit,
            inter: TiePolicy::OneBit,
            sparse: false,
            precision: 2,
        };
        let signs: Vec<Vec<i8>> = (0..row.n).map(|_| vec![rng.gen_sign(), rng.gen_sign()]).collect();
        let out = run_sync(&signs, cfg, row.n as u64 * 7 + row.ell as u64);
        assert_eq!(
            out.global_vote,
            plain_hierarchical_vote(&signs, cfg),
            "n={} ell={}",
            row.n,
            row.ell
        );
    }
}

/// Threaded coordinator under stress: biggest preset config, multiple d.
#[test]
fn threaded_stress_n24_ell8() {
    let mut rng = Xoshiro256pp::seed_from_u64(9);
    for d in [1usize, 64, 512] {
        let signs: Vec<Vec<i8>> =
            (0..24).map(|_| (0..d).map(|_| rng.gen_sign()).collect()).collect();
        let cfg = HiSafeConfig::hierarchical(24, 8, TiePolicy::TwoBit);
        let a = run_sync(&signs, cfg, 4);
        let b = run_threaded(&signs, cfg, 4);
        assert_eq!(a.global_vote, b.global_vote, "d={d}");
        assert_eq!(a.subgroup_votes, b.subgroup_votes, "d={d}");
    }
}

/// Failure injection: wrong triple count must panic (protocol-integrity
/// guard), not silently mis-compute.
#[test]
#[should_panic(expected = "wrong triple count")]
fn party_rejects_wrong_triple_budget() {
    let mv = MvPolynomial::build_fermat(4, TiePolicy::OneBit);
    let plan = std::sync::Arc::new(EvalPlan::new(&mv, 2, false));
    let mut dealer = Dealer::new(mv.fp, 3);
    // one triple short
    let short = dealer.gen_round(2, 4, plan.triples_needed() - 1);
    let _ = Party::new(plan, 0, vec![1, 1], short[0].clone());
}

/// Failure injection: dimension mismatch must panic.
#[test]
#[should_panic(expected = "input dimension mismatch")]
fn party_rejects_dim_mismatch() {
    let mv = MvPolynomial::build_fermat(3, TiePolicy::OneBit);
    let plan = std::sync::Arc::new(EvalPlan::new(&mv, 4, false));
    let mut dealer = Dealer::new(mv.fp, 3);
    let triples = dealer.gen_round(4, 3, plan.triples_needed());
    let _ = Party::new(plan, 0, vec![1, 1], triples[0].clone()); // d=2 ≠ 4
}

/// A corrupted share (bit-flip by one user) must corrupt the output —
/// i.e. the protocol has no silent self-healing that could mask bugs —
/// while leaving other coordinates untouched (coordinate independence).
#[test]
fn share_corruption_is_coordinate_local() {
    let n = 5;
    let d = 8;
    let mut rng = Xoshiro256pp::seed_from_u64(12);
    let signs: Vec<Vec<i8>> =
        (0..n).map(|_| (0..d).map(|_| rng.gen_sign()).collect()).collect();
    let clean = secure_group_vote(&signs, TiePolicy::OneBit, false, 5);
    // corrupt user 2's input on coordinate 3 (flip the sign)
    let mut bad = signs.clone();
    bad[2][3] = -bad[2][3];
    let dirty = secure_group_vote(&bad, TiePolicy::OneBit, false, 5);
    for j in 0..d {
        if j == 3 {
            continue; // may or may not flip the vote depending on margin
        }
        assert_eq!(clean.votes[j], dirty.votes[j], "coordinate {j} leaked across");
    }
}

/// Partition + inter-group vote associativity: permuting users within a
/// subgroup never changes the outcome; permuting across subgroups can.
#[test]
fn within_group_permutation_invariance() {
    forall("within-group permutation invariance", 25, |g| {
        let ell = g.usize_range(2, 4);
        let n1 = g.usize_range(2, 4);
        let n = ell * n1;
        let d = g.usize_range(1, 6);
        let cfg = HiSafeConfig::hierarchical(n, ell, TiePolicy::OneBit);
        let signs: Vec<Vec<i8>> = (0..n).map(|_| g.sign_vec(d)).collect();
        let base = run_sync(&signs, cfg, 1).global_vote;
        // swap two users inside group 0
        let mut perm = signs.clone();
        perm.swap(0, n1 - 1);
        prop_assert_eq!(run_sync(&perm, cfg, 2).global_vote, base);
        Ok(())
    });
}

/// Tie-policy matrix (Section III-E): A-2/B-2 produce 0 votes at global
/// ties; A-1/B-1 never produce 0.
#[test]
fn tie_policy_matrix_outputs() {
    let signs: Vec<Vec<i8>> = vec![vec![1], vec![-1], vec![1], vec![-1]];
    for intra in [TiePolicy::OneBit, TiePolicy::TwoBit] {
        for inter in [TiePolicy::OneBit, TiePolicy::TwoBit] {
            let cfg = HiSafeConfig { n: 4, ell: 2, intra, inter, sparse: false, precision: 2 };
            let out = run_sync(&signs, cfg, 3);
            let has_zero = out.global_vote.iter().any(|&v| v == 0);
            if inter == TiePolicy::OneBit {
                assert!(!has_zero, "{}", cfg.label());
                assert!(cfg.signsgd_compatible());
            } else {
                assert!(!cfg.signsgd_compatible());
            }
        }
    }
}

/// The schedule's triple budget equals the dealer's Table-V accounting.
#[test]
fn triple_budget_matches_schedule() {
    forall("triples = schedule.mults", 40, |g| {
        let n1 = g.usize_range(2, 12);
        let policy = if g.bool() { TiePolicy::OneBit } else { TiePolicy::TwoBit };
        let mv = MvPolynomial::build_fermat(n1, policy);
        let plan = EvalPlan::new(&mv, 1, false);
        let sched = PowerSchedule::full(mv.degree());
        prop_assert_eq!(plan.triples_needed(), sched.mults());
        Ok(())
    });
}

/// Field/modulus invariants across the entire sweep range.
#[test]
fn moduli_odd_primes_above_group_size() {
    for n in 2..=128usize {
        let fp = field_for_group(n);
        assert!(fp.modulus() > n as u64);
        assert!(fp.modulus() % 2 == 1);
        assert_eq!(fp.modulus(), next_prime(n as u64));
    }
}

/// partition() composes with plain votes exactly like run_sync's grouping.
#[test]
fn partition_grouping_consistency() {
    forall("partition ↔ run_sync grouping", 20, |g| {
        let ell = g.usize_range(1, 5);
        let n1 = g.usize_range(2, 5);
        let n = ell * n1;
        let signs: Vec<Vec<i8>> = (0..n).map(|_| g.sign_vec(3)).collect();
        let cfg = HiSafeConfig::hierarchical(n, ell, TiePolicy::OneBit);
        let out = run_sync(&signs, cfg, g.u64());
        // recompute subgroup votes from the partition directly
        for (gi, members) in partition(n, ell).iter().enumerate() {
            let group: Vec<Vec<i8>> = members.iter().map(|&i| signs[i].clone()).collect();
            prop_assert_eq!(
                &out.subgroup_votes[gi],
                &plain_group_vote(&group, TiePolicy::OneBit),
                "group {gi}"
            );
        }
        Ok(())
    });
}

/// Cost model exactly matches the paper for every n₁ ≤ 6 row of Tables
/// VIII/IX (the rows all optimal configurations use).
#[test]
fn paper_rows_small_n1_match_exactly() {
    for row in cost::paper_tables() {
        if row.n % row.ell != 0 {
            continue;
        }
        let n1 = row.n / row.ell;
        if n1 > 6 {
            continue;
        }
        // skip the two rows that violate the paper's OWN formulas
        // (n=15 ℓ=3: C_T ≠ ℓ·C_u; n=30 ℓ=2: C_u ≠ R·⌈log p⌉) — audited in
        // the tables789_comm_costs bench and EXPERIMENTS.md.
        if row.c_u != (row.r as u64) * row.log_p1 as u64
            || row.c_t != row.ell as u64 * row.c_u
        {
            continue;
        }
        let c = cost::config_cost(row.n, row.ell, TiePolicy::OneBit, false);
        assert_eq!(c.group.openings, row.r, "R at n={} ℓ={}", row.n, row.ell);
        assert_eq!(c.group.c_u_bits, row.c_u, "C_u at n={} ℓ={}", row.n, row.ell);
        assert_eq!(c.c_t_bits, row.c_t, "C_T at n={} ℓ={}", row.n, row.ell);
    }
}

/// Sum-type sanity of the whole stack on a model-sized vector.
#[test]
fn model_dim_round_smoke() {
    let d = 7850;
    let mut rng = Xoshiro256pp::seed_from_u64(31);
    let signs: Vec<Vec<i8>> =
        (0..12).map(|_| (0..d).map(|_| rng.gen_sign()).collect()).collect();
    let cfg = HiSafeConfig::hierarchical(12, 4, TiePolicy::OneBit);
    let out = run_sync(&signs, cfg, 77);
    assert_eq!(out.global_vote.len(), d);
    assert_eq!(out.stats.c_u_bits(), 12 * d as u64); // n₁=3 → 12 bits/coord
    assert!(out.global_vote.iter().all(|&v| v == 1 || v == -1));
}
