//! Property tests pinning the batched engines to the reference paths:
//! across random `n`, `d`, `ℓ`, tie policies, schedules and chunk sizes,
//! every [`Engine`] implementation — the sequential [`RoundEngine`], the
//! pipelined [`PipelinedEngine`], and a multi-tenant scheduler
//! [`AggSession`](hisafe::engine::AggSession) — must produce votes
//! bit-identical to the plaintext majority vote and the message-passing
//! `secure_group_vote` / `run_sync` implementations, and the engines'
//! analytic `CommStats` must equal the measured per-message counters
//! field for field. The suite is generic over the trait: one property
//! body, three implementations, zero copy-pasted checks.

use hisafe::engine::{AggScheduler, Engine, PipelinedEngine, RoundEngine};
use hisafe::mpc::{plain_group_vote, secure_group_vote};
use hisafe::poly::TiePolicy;
use hisafe::prop_assert_eq;
use hisafe::protocol::{
    check_thresholds, plain_hierarchical_vote, plain_hierarchical_vote_present,
    plain_quant_aggregate, plain_quant_aggregate_present, run_sync, run_sync_with_dropouts,
    HiSafeConfig, ParticipantSet,
};
use hisafe::util::prop::{forall, Gen};

/// A vector of uniformly random quantization levels from `L_q` — the odd
/// integers `{-(q-1), …, q-1}` the secure path aggregates (sign bits at
/// `q = 2`). Even values never reach the wire, so generators must not
/// emit them: the plaintext reference is only pinned on `L_q`.
fn level_vec(g: &mut Gen, q: u8, d: usize) -> Vec<i8> {
    (0..d)
        .map(|_| (2 * g.usize_range(0, q as usize - 1) as i64 - (q as i64 - 1)) as i8)
        .collect()
}

/// Build one engine implementation for a random workload — the factory
/// the generic properties run over.
fn factories() -> Vec<(&'static str, Box<dyn Fn(HiSafeConfig, usize, u64) -> Box<dyn Engine>>)> {
    vec![
        (
            "sequential",
            Box::new(|cfg, d, seed| Box::new(RoundEngine::new(cfg, d, seed)) as Box<dyn Engine>),
        ),
        (
            "pipelined",
            Box::new(|cfg, d, seed| {
                Box::new(PipelinedEngine::new(cfg, d, seed)) as Box<dyn Engine>
            }),
        ),
        (
            "scheduled",
            Box::new(|cfg, d, seed| {
                // A fresh single-tenant scheduler per engine: the session
                // keeps the shared core alive after the handle drops.
                Box::new(AggScheduler::with_threads(2).session(cfg, d, seed))
                    as Box<dyn Engine>
            }),
        ),
    ]
}

#[test]
fn engine_vote_equals_plain_and_secure_flat() {
    for (impl_name, mk) in factories() {
        forall(&format!("{impl_name} ≡ plain ≡ mpc (flat)"), 30, |g| {
            let n = g.usize_range(1, 12);
            let d = g.usize_range(1, 48);
            let policy = if g.bool() { TiePolicy::OneBit } else { TiePolicy::TwoBit };
            let sparse = g.bool();
            let signs: Vec<Vec<i8>> = (0..n).map(|_| g.sign_vec(d)).collect();
            let cfg = HiSafeConfig { sparse, ..HiSafeConfig::flat(n, policy) };
            let seed = g.u64();
            let got = mk(cfg, d, seed).run_round(&signs);
            let plain = plain_group_vote(&signs, policy);
            prop_assert_eq!(
                &got.global_vote,
                &plain,
                "{impl_name} n={n} d={d} {policy:?} sparse={sparse}"
            );
            let mpc = secure_group_vote(&signs, policy, sparse, seed);
            prop_assert_eq!(&got.global_vote, &mpc.votes, "{impl_name} vs mpc n={n} d={d}");
            Ok(())
        });
    }
}

#[test]
fn engine_vote_equals_hierarchical_reference() {
    for (impl_name, mk) in factories() {
        forall(&format!("{impl_name} ≡ Eq. 8 (hierarchical)"), 20, |g| {
            let ell = g.usize_range(1, 4);
            let n1 = g.usize_range(2, 6);
            let n = ell * n1;
            let d = g.usize_range(1, 24);
            let intra = if g.bool() { TiePolicy::OneBit } else { TiePolicy::TwoBit };
            let inter = if g.bool() { TiePolicy::OneBit } else { TiePolicy::TwoBit };
            let cfg = HiSafeConfig { n, ell, intra, inter, sparse: g.bool(), precision: 2 };
            let signs: Vec<Vec<i8>> = (0..n).map(|_| g.sign_vec(d)).collect();
            let seed = g.u64();
            let got = mk(cfg, d, seed).run_round(&signs);
            prop_assert_eq!(
                &got.global_vote,
                &plain_hierarchical_vote(&signs, cfg),
                "{impl_name} cfg={cfg:?}"
            );
            // per-subgroup votes match the reference protocol too
            let reference = run_sync(&signs, cfg, seed);
            prop_assert_eq!(
                &got.subgroup_votes,
                &reference.subgroup_votes,
                "{impl_name} cfg={cfg:?}"
            );
            prop_assert_eq!(got.stats.c_u_bits(), reference.stats.c_u_bits());
            prop_assert_eq!(got.stats.subrounds, reference.stats.subrounds);
            Ok(())
        });
    }
}

#[test]
fn pipelined_engine_pins_bit_identical_to_sequential_and_run_sync() {
    // The tentpole determinism claim: no matter how the background
    // dealing stage interleaves with online evaluation, the pipelined
    // scheduler's votes equal the sequential engine's and run_sync's,
    // round after round on one long-lived engine pair. (Votes are
    // triple-independent — Beaver masks cancel — so this pins the online
    // arithmetic; the offline streams themselves are pinned to the
    // group_dealer_seed derivation by the in-crate tests in
    // engine/scheduler.rs and engine/pipeline.rs, which can see the
    // pools. The multi-tenant interleaving variant lives in
    // rust/tests/sched_props.rs.)
    forall("pipelined ≡ sequential ≡ run_sync", 20, |g| {
        let ell = g.usize_range(1, 4);
        let n1 = g.usize_range(1, 6);
        let n = ell * n1;
        let d = g.usize_range(1, 32);
        let intra = if g.bool() { TiePolicy::OneBit } else { TiePolicy::TwoBit };
        let inter = if g.bool() { TiePolicy::OneBit } else { TiePolicy::TwoBit };
        let cfg = HiSafeConfig { n, ell, intra, inter, sparse: g.bool(), precision: 2 };
        let seed = g.u64();
        let mut seq = RoundEngine::new(cfg, d, seed);
        let mut piped = PipelinedEngine::new(cfg, d, seed)
            .with_batch_rounds(g.usize_range(1, 3));
        for round in 0..4u64 {
            let signs: Vec<Vec<i8>> = (0..n).map(|_| g.sign_vec(d)).collect();
            let a = seq.run_round(&signs);
            let b = piped.run_round(&signs);
            prop_assert_eq!(&a.global_vote, &b.global_vote, "round {round} cfg={cfg:?}");
            prop_assert_eq!(&a.subgroup_votes, &b.subgroup_votes, "round {round} cfg={cfg:?}");
            prop_assert_eq!(&a.stats, &b.stats, "round {round} cfg={cfg:?}");
            let reference = run_sync(&signs, cfg, seed ^ round);
            prop_assert_eq!(&b.global_vote, &reference.global_vote, "round {round} vs run_sync");
            prop_assert_eq!(&b.subgroup_votes, &reference.subgroup_votes, "round {round}");
            prop_assert_eq!(
                &b.global_vote,
                &plain_hierarchical_vote(&signs, cfg),
                "round {round} vs Eq. 8"
            );
        }
        prop_assert_eq!(piped.rounds_run, 4u64);
        Ok(())
    });
}

#[test]
fn engine_analytic_stats_equal_measured_field_for_field() {
    // The engines never pass messages; their CommStats are analytic. The
    // doc contract is that every counter equals the measured one from the
    // message-passing path — full struct equality, not just the derived
    // C_u/C_T bit costs. Checked for every Engine implementation.
    for (impl_name, mk) in factories() {
        forall(&format!("{impl_name} analytic CommStats ≡ measured"), 15, |g| {
            let ell = g.usize_range(1, 4);
            let n1 = g.usize_range(1, 6);
            let n = ell * n1;
            let d = g.usize_range(1, 24);
            let intra = if g.bool() { TiePolicy::OneBit } else { TiePolicy::TwoBit };
            let inter = if g.bool() { TiePolicy::OneBit } else { TiePolicy::TwoBit };
            let cfg = HiSafeConfig { n, ell, intra, inter, sparse: g.bool(), precision: 2 };
            let signs: Vec<Vec<i8>> = (0..n).map(|_| g.sign_vec(d)).collect();
            let seed = g.u64();
            let reference = run_sync(&signs, cfg, seed);
            let got = mk(cfg, d, seed).run_round(&signs);
            prop_assert_eq!(&got.stats, &reference.stats, "{impl_name} cfg={cfg:?} d={d}");
            Ok(())
        });
    }
}

#[test]
fn engine_invariant_under_chunk_size_and_pool_batching() {
    forall("engine chunk/pool invariance", 20, |g| {
        let ell = g.usize_range(1, 3);
        let n1 = g.usize_range(2, 5);
        let n = ell * n1;
        let d = g.usize_range(1, 40);
        let cfg = HiSafeConfig::hierarchical(n, ell, TiePolicy::OneBit);
        let signs: Vec<Vec<i8>> = (0..n).map(|_| g.sign_vec(d)).collect();
        let plain = plain_hierarchical_vote(&signs, cfg);
        for (chunk, batch) in [(1usize, 1usize), (3, 2), (7, 3), (4096, 1)] {
            let mut engine = RoundEngine::new(cfg, d, g.u64())
                .with_chunk(chunk)
                .with_batch_rounds(batch);
            let got = engine.run_round(&signs);
            prop_assert_eq!(
                &got.global_vote,
                &plain,
                "chunk={chunk} batch={batch} n={n} ell={ell} d={d}"
            );
        }
        Ok(())
    });
}

#[test]
fn engine_stays_correct_across_many_rounds_one_pool() {
    // One engine, many rounds: the triple pool refills and every round's
    // triples are fresh (a reuse bug would desync votes from plain MV).
    forall("engine multi-round freshness", 12, |g| {
        let n = g.usize_range(2, 8);
        let d = g.usize_range(1, 16);
        let cfg = HiSafeConfig::flat(n, TiePolicy::OneBit);
        let mut engine =
            RoundEngine::new(cfg, d, g.u64()).with_batch_rounds(g.usize_range(1, 4));
        for round in 0..8 {
            let signs: Vec<Vec<i8>> = (0..n).map(|_| g.sign_vec(d)).collect();
            let got = engine.run_round(&signs);
            prop_assert_eq!(
                &got.global_vote,
                &plain_group_vote(&signs, TiePolicy::OneBit),
                "round {round} n={n} d={d}"
            );
        }
        prop_assert_eq!(engine.rounds_run, 8);
        Ok(())
    });
}

#[test]
fn engine_churn_survivor_votes_equal_reference_for_random_masks() {
    // The tentpole churn property, generic over every Engine: for random
    // dropout patterns, a round over the survivor set is bit-identical —
    // votes, subgroup votes, and analytic stats — to the reference
    // `run_sync_with_dropouts` over the same set, and a below-threshold
    // mask is the SAME typed ChurnError on both paths, never a panic.
    // Absent users' sign rows are random garbage on purpose: the
    // contract says absent rows are ignored, so they must not leak into
    // any vote.
    for (impl_name, mk) in factories() {
        forall(&format!("{impl_name} churn ≡ run_sync_with_dropouts"), 20, |g| {
            let ell = g.usize_range(1, 3);
            let n1 = g.usize_range(1, 5);
            let n = ell * n1;
            let d = g.usize_range(1, 24);
            let intra = if g.bool() { TiePolicy::OneBit } else { TiePolicy::TwoBit };
            let inter = if g.bool() { TiePolicy::OneBit } else { TiePolicy::TwoBit };
            let cfg = HiSafeConfig { n, ell, intra, inter, sparse: g.bool(), precision: 2 };
            let signs: Vec<Vec<i8>> = (0..n).map(|_| g.sign_vec(d)).collect();
            // ~3/4 of users answer; below-threshold masks arise naturally.
            let mask: Vec<bool> = (0..n).map(|_| g.usize_range(0, 3) > 0).collect();
            let present = ParticipantSet::from_mask(mask);
            let seed = g.u64();
            let got = mk(cfg, d, seed).run_round_present(&signs, &present);
            let reference = run_sync_with_dropouts(&signs, &present, cfg, seed);
            match (got, reference) {
                (Ok(got), Ok(reference)) => {
                    prop_assert_eq!(
                        &got.global_vote,
                        &reference.global_vote,
                        "{impl_name} cfg={cfg:?} mask={:?}",
                        present.mask()
                    );
                    prop_assert_eq!(
                        &got.subgroup_votes,
                        &reference.subgroup_votes,
                        "{impl_name} cfg={cfg:?} subgroups"
                    );
                    prop_assert_eq!(&got.stats, &reference.stats, "{impl_name} cfg={cfg:?}");
                    prop_assert_eq!(
                        &got.global_vote,
                        &plain_hierarchical_vote_present(&signs, &present, cfg),
                        "{impl_name} cfg={cfg:?} vs survivor plaintext"
                    );
                }
                (Err(e), Err(r)) => {
                    prop_assert_eq!(e.clone(), r, "{impl_name} typed aborts must agree");
                    prop_assert_eq!(
                        check_thresholds(cfg, &present).expect_err("both paths aborted"),
                        e,
                        "{impl_name} abort must name the check_thresholds group"
                    );
                }
                (got, reference) => {
                    return Err(format!(
                        "{impl_name} cfg={cfg:?} mask={:?}: engine and reference disagree \
                         on abort: {got:?} vs {reference:?}",
                        present.mask()
                    ))
                }
            }
            Ok(())
        });
    }
}

#[test]
fn engine_churned_and_full_rounds_interleave_bit_identically() {
    // One long-lived engine per implementation, alternating full-present
    // and one-dropout rounds: churned rounds must not perturb later
    // full-present rounds (the base triple stream advances in lockstep),
    // and every completed round matches the reference over its own set.
    for (impl_name, mk) in factories() {
        forall(&format!("{impl_name} full/churned interleave"), 10, |g| {
            let ell = g.usize_range(1, 3);
            let n1 = g.usize_range(2, 5); // n₁ ≥ 2 ⇒ one dropout always survives
            let n = ell * n1;
            let d = g.usize_range(1, 24);
            let intra = if g.bool() { TiePolicy::OneBit } else { TiePolicy::TwoBit };
            let inter = if g.bool() { TiePolicy::OneBit } else { TiePolicy::TwoBit };
            let cfg = HiSafeConfig { n, ell, intra, inter, sparse: g.bool(), precision: 2 };
            let seed = g.u64();
            let mut engine = mk(cfg, d, seed);
            for round in 0..5u64 {
                let signs: Vec<Vec<i8>> = (0..n).map(|_| g.sign_vec(d)).collect();
                let present = if round % 2 == 1 {
                    let mut mask = vec![true; n];
                    mask[g.usize_range(0, n - 1)] = false;
                    ParticipantSet::from_mask(mask)
                } else {
                    ParticipantSet::all(n)
                };
                let got = engine
                    .run_round_present(&signs, &present)
                    .expect("one dropout stays above threshold for n1 >= 2");
                let reference = run_sync_with_dropouts(&signs, &present, cfg, seed ^ round)
                    .expect("one dropout stays above threshold");
                prop_assert_eq!(
                    &got.global_vote,
                    &reference.global_vote,
                    "{impl_name} round {round} cfg={cfg:?} mask={:?}",
                    present.mask()
                );
                prop_assert_eq!(
                    &got.subgroup_votes,
                    &reference.subgroup_votes,
                    "{impl_name} round {round} subgroups"
                );
                prop_assert_eq!(&got.stats, &reference.stats, "{impl_name} round {round}");
                prop_assert_eq!(
                    &got.global_vote,
                    &plain_hierarchical_vote_present(&signs, &present, cfg),
                    "{impl_name} round {round} vs survivor plaintext"
                );
            }
            prop_assert_eq!(engine.rounds_run(), 5u64, "{impl_name} aborts never counted");
            Ok(())
        });
    }
}

#[test]
fn engine_quantized_votes_equal_plain_reference_for_all_precisions() {
    // The quantization subsystem, generic over every Engine: at each
    // q ∈ {2, 4, 8, 16} the engines' votes are bit-identical to the
    // plaintext q-level reference `plain_quant_aggregate` and to the
    // message-passing `run_sync`, on full-present and churned rounds
    // alike. Inputs are drawn from L_q only (odd levels).
    for (impl_name, mk) in factories() {
        forall(&format!("{impl_name} q-level ≡ plain_quant_aggregate"), 16, |g| {
            let q = hisafe::quant::PRECISIONS[g.usize_range(0, 3)];
            let ell = g.usize_range(1, 3);
            let n1 = g.usize_range(2, 5); // n₁ ≥ 2 ⇒ one dropout always survives
            let n = ell * n1;
            let d = g.usize_range(1, 16);
            let intra = if g.bool() { TiePolicy::OneBit } else { TiePolicy::TwoBit };
            let inter = if g.bool() { TiePolicy::OneBit } else { TiePolicy::TwoBit };
            let cfg = HiSafeConfig { n, ell, intra, inter, sparse: g.bool(), precision: q };
            let signs: Vec<Vec<i8>> = (0..n).map(|_| level_vec(g, q, d)).collect();
            let seed = g.u64();
            let got = mk(cfg, d, seed).run_round(&signs);
            prop_assert_eq!(
                &got.global_vote,
                &plain_quant_aggregate(&signs, cfg),
                "{impl_name} q={q} cfg={cfg:?}"
            );
            let reference = run_sync(&signs, cfg, seed);
            prop_assert_eq!(
                &got.global_vote,
                &reference.global_vote,
                "{impl_name} q={q} vs run_sync"
            );
            prop_assert_eq!(
                &got.subgroup_votes,
                &reference.subgroup_votes,
                "{impl_name} q={q} subgroups"
            );
            // A churned round on a fresh engine: one dropout, survivors
            // must still match the q-level survivor-set reference.
            let mut mask = vec![true; n];
            mask[g.usize_range(0, n - 1)] = false;
            let present = ParticipantSet::from_mask(mask);
            let churned = mk(cfg, d, seed)
                .run_round_present(&signs, &present)
                .expect("one dropout stays above threshold for n1 >= 2");
            prop_assert_eq!(
                &churned.global_vote,
                &plain_quant_aggregate_present(&signs, &present, cfg),
                "{impl_name} q={q} churned cfg={cfg:?} mask={:?}",
                present.mask()
            );
            Ok(())
        });
    }
}

#[test]
fn engine_exhaustive_small_patterns() {
    // Exhaustive over every sign assignment for n ≤ 4, mirroring the mpc
    // suite's strongest exact check.
    for n in 1..=4usize {
        for policy in [TiePolicy::OneBit, TiePolicy::TwoBit] {
            for pattern in 0..(1u32 << n) {
                let signs: Vec<Vec<i8>> = (0..n)
                    .map(|i| vec![if pattern >> i & 1 == 1 { 1i8 } else { -1 }])
                    .collect();
                let cfg = HiSafeConfig::flat(n, policy);
                let got = RoundEngine::new(cfg, 1, pattern as u64).run_round(&signs);
                assert_eq!(
                    got.global_vote,
                    plain_group_vote(&signs, policy),
                    "n={n} {policy:?} pattern={pattern:b}"
                );
            }
        }
    }
}
