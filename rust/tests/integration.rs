//! Cross-layer integration tests: PJRT runtime ↔ AOT artifacts ↔ the pure
//! rust substrates.
//!
//! These tests require `make artifacts` to have run; they SKIP (with a
//! stderr note) when artifacts are missing so `cargo test` stays green in
//! a fresh checkout.

use hisafe::field::{field_for_group, Fp};
use hisafe::fl::data::{partition_users, synthetic, DataKind, Partition};
use hisafe::fl::model::{LinearSoftmax, Model};
use hisafe::fl::trainer::{train, Aggregator, TrainConfig};
use hisafe::poly::{MvPolynomial, TiePolicy};
use hisafe::protocol::HiSafeConfig;
use hisafe::runtime::{JaxModel, MvPolyKernel, Runtime};
use hisafe::util::rng::{Rng, Xoshiro256pp};

const ART: &str = "artifacts";

fn have_artifacts() -> bool {
    let ok = std::path::Path::new(ART).join("manifest.json").exists();
    if !ok {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
    }
    ok
}

#[test]
fn runtime_loads_and_runs_logits_artifact() {
    if !have_artifacts() {
        return;
    }
    let mut rt = Runtime::cpu(ART).expect("pjrt client");
    assert!(rt.platform().to_lowercase().contains("cpu")
        || rt.platform().to_lowercase().contains("host"));
    let params = vec![0.0f32; 7850];
    let xs = vec![0.5f32; 100 * 784];
    let out = rt
        .exec_f32("mnist_linear_logits", &[(&params, &[7850]), (&xs, &[100, 784])])
        .expect("exec");
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].len(), 100 * 10);
    assert!(out[0].iter().all(|&v| v == 0.0)); // zero params → zero logits
}

/// The L2 JAX gradient must match the pure-rust model's gradient on the
/// same parameter layout — the two backends are interchangeable.
#[test]
fn jax_grad_matches_rust_grad() {
    if !have_artifacts() {
        return;
    }
    let (tr, _) = synthetic(DataKind::MnistLike, 200, 50, 13);
    let rust_model = LinearSoftmax::new(784, 10);
    let jax_model = JaxModel::new(ART, "mnist_linear", 7850, 784, 10, 100).expect("jax model");
    let params = rust_model.init_params(3);
    let batch: Vec<usize> = (0..100).collect();
    let (loss_r, grad_r) = rust_model.loss_grad(&params, &tr, &batch);
    let (loss_j, grad_j) = jax_model.loss_grad(&params, &tr, &batch);
    assert!(
        (loss_r - loss_j).abs() < 1e-4 * (1.0 + loss_r.abs()),
        "loss {loss_r} vs {loss_j}"
    );
    let mut max_rel = 0.0f32;
    for (a, b) in grad_r.iter().zip(&grad_j) {
        let rel = (a - b).abs() / (1e-6 + a.abs().max(b.abs()));
        if rel > max_rel {
            max_rel = rel;
        }
    }
    assert!(max_rel < 1e-2, "max relative grad deviation {max_rel}");
    // signs agree on effectively all coordinates (ties near 0 may flip)
    let disagree = grad_r
        .iter()
        .zip(&grad_j)
        .filter(|(a, b)| (a.signum() != b.signum()) && (a.abs().max(b.abs()) > 1e-6))
        .count();
    assert!(disagree < 8, "{disagree} sign disagreements");
}

#[test]
fn jax_accuracy_matches_rust_accuracy() {
    if !have_artifacts() {
        return;
    }
    let (tr, _) = synthetic(DataKind::MnistLike, 300, 50, 17);
    let rust_model = LinearSoftmax::new(784, 10);
    let jax_model = JaxModel::new(ART, "mnist_linear", 7850, 784, 10, 100).expect("jax model");
    let params = rust_model.init_params(8);
    let a = rust_model.accuracy(&params, &tr);
    let b = jax_model.accuracy(&params, &tr);
    assert!((a - b).abs() < 1e-6, "accuracy {a} vs {b}");
}

/// Cross-layer consistency: the L1 Pallas Horner kernel (compiled through
/// HLO, loaded via PJRT) computes exactly the same votes as the rust
/// field/poly substrate.
#[test]
fn mv_poly_kernel_matches_rust_poly_eval() {
    if !have_artifacts() {
        return;
    }
    let kernel = MvPolyKernel::new(ART, 1024, 32).expect("kernel artifact");
    let mut rng = Xoshiro256pp::seed_from_u64(99);
    for n in [2usize, 3, 4, 5, 6, 8, 12, 24] {
        for policy in [TiePolicy::OneBit, TiePolicy::TwoBit] {
            let mv = MvPolynomial::build_fermat(n, policy);
            if mv.poly.coeffs.len() > 32 {
                continue;
            }
            let fp = mv.fp;
            let xs: Vec<u64> = (0..1024).map(|_| rng.gen_field(fp.modulus())).collect();
            let rust_out = mv.poly.eval_vec(&xs);
            let hlo_out = kernel.eval(fp, &mv.poly.coeffs, &xs).expect("kernel eval");
            assert_eq!(rust_out, hlo_out, "n={n} {policy:?}");
        }
    }
}

/// Secure protocol votes, decoded through the HLO kernel on the plaintext
/// sums, agree with the protocol output — ties L3 MPC to L1 compute.
#[test]
fn protocol_votes_consistent_with_kernel_readout() {
    if !have_artifacts() {
        return;
    }
    let n = 6;
    let d = 1024;
    let kernel = MvPolyKernel::new(ART, d, 32).expect("kernel artifact");
    let mut rng = Xoshiro256pp::seed_from_u64(5);
    let signs: Vec<Vec<i8>> = (0..n).map(|_| (0..d).map(|_| rng.gen_sign()).collect()).collect();
    let out = hisafe::mpc::secure_group_vote(&signs, TiePolicy::OneBit, false, 2);
    // plaintext sums, canonical
    let fp: Fp = field_for_group(n);
    let sums: Vec<u64> = (0..d)
        .map(|j| {
            let s: i64 = signs.iter().map(|v| v[j] as i64).sum();
            fp.from_i64(s)
        })
        .collect();
    let mv = MvPolynomial::build_fermat(n, TiePolicy::OneBit);
    let kernel_votes: Vec<i8> = kernel
        .eval(fp, &mv.poly.coeffs, &sums)
        .expect("eval")
        .iter()
        .map(|&v| fp.sign_of(v))
        .collect();
    assert_eq!(out.votes, kernel_votes);
}

/// End-to-end smoke: a short FL run on the JAX backend with the full
/// secure hierarchical aggregation learns on synthetic data.
#[test]
fn e2e_jax_hisafe_short_training() {
    if !have_artifacts() {
        return;
    }
    let (tr, te) = synthetic(DataKind::MnistLike, 2000, 300, 77);
    let shards = partition_users(&tr, 12, Partition::TwoClass, 77);
    let model = JaxModel::new(ART, "mnist_linear", 7850, 784, 10, 100).expect("jax model");
    let cfg = TrainConfig {
        n_users: 12,
        participants: 6,
        rounds: 60,
        lr: 0.002,
        batch_size: 100,
        eval_every: 5,
        seed: 3,
        churn: 0.0,
    };
    let agg = Aggregator::HiSafe(HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit));
    let res = train(&model, &tr, &te, &shards, agg, &cfg);
    let first_loss = res.logs[0].train_loss;
    let last_loss = res.logs.last().unwrap().train_loss;
    assert!(
        last_loss < first_loss,
        "loss did not decrease: {first_loss} → {last_loss}"
    );
    assert!(res.final_acc > 0.4, "acc only {}", res.final_acc);
}

/// The signgrad artifact (grad + L1 Pallas sign kernel fused in one HLO)
/// produces the sign of the grad artifact's output.
#[test]
fn signgrad_artifact_consistent_with_grad_artifact() {
    if !have_artifacts() {
        return;
    }
    let mut rt = Runtime::cpu(ART).expect("client");
    let mut rng = Xoshiro256pp::seed_from_u64(21);
    let params: Vec<f32> = (0..7850).map(|_| 0.05 * rng.gen_gaussian() as f32).collect();
    let xs: Vec<f32> = (0..100 * 784).map(|_| rng.gen_gaussian() as f32 * 0.5).collect();
    let mut ys = vec![0.0f32; 100 * 10];
    for b in 0..100 {
        ys[b * 10 + (b % 10)] = 1.0;
    }
    let grad_out = rt
        .exec_f32(
            "mnist_linear_grad",
            &[(&params, &[7850]), (&xs, &[100, 784]), (&ys, &[100, 10])],
        )
        .expect("grad");
    let sign_out = rt
        .exec_f32(
            "mnist_linear_signgrad",
            &[(&params, &[7850]), (&xs, &[100, 784]), (&ys, &[100, 10])],
        )
        .expect("signgrad");
    assert!((grad_out[0][0] - sign_out[0][0]).abs() < 1e-5, "losses differ");
    let mut mismatches = 0;
    for (g, s) in grad_out[1].iter().zip(&sign_out[1]) {
        let want = if *g < 0.0 { -1.0 } else { 1.0 };
        if *s != want {
            mismatches += 1;
        }
    }
    assert_eq!(mismatches, 0, "{mismatches} sign mismatches");
}
