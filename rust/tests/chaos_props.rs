//! Seeded chaos schedules over the whole service stack (see
//! [`hisafe::service::faults`]). Every schedule is a pure function of
//! its seed: the tenant shapes, the sign matrices, the churn masks, and
//! the fault rounds all derive from one RNG stream, so a failure here
//! prints a seed that replays the *identical* schedule:
//!
//! ```text
//! HISAFE_CHAOS_SEED=<seed> cargo test --test chaos_props
//! hisafe sweep --chaos-seed <seed>
//! ```
//!
//! `HISAFE_CHAOS_SCHEDULES=<n>` widens or narrows the sweep (default
//! 32). Each schedule asserts the anchor invariant under fire:
//! client-observed votes bit-identical to the plaintext reference over
//! the scheduled survivor sets, below-threshold churn aborting with the
//! same typed error, no wedged pump, and zero leaked sessions.

use std::collections::BTreeSet;
use std::panic::{catch_unwind, resume_unwind};

use hisafe::service::faults::{run_schedule, FaultPlan};

fn env_u64(name: &str) -> Option<u64> {
    std::env::var(name)
        .ok()
        .map(|v| v.parse().unwrap_or_else(|_| panic!("{name} must be a u64, got {v:?}")))
}

#[test]
fn seeded_fault_schedules_preserve_votes_and_leak_nothing() {
    // Single-seed replay mode, for debugging a sweep failure.
    if let Some(seed) = env_u64("HISAFE_CHAOS_SEED") {
        let report = run_schedule(seed);
        println!(
            "replayed seed {seed}: {} votes checked, {} typed aborts, faults {:?}",
            report.votes_checked, report.typed_aborts, report.faults
        );
        return;
    }

    let schedules = env_u64("HISAFE_CHAOS_SCHEDULES").unwrap_or(32);
    let mut executed: BTreeSet<&'static str> = BTreeSet::new();
    let mut votes = 0u64;
    let mut quant_tenants = 0u64;
    for seed in 0..schedules {
        match catch_unwind(|| run_schedule(seed)) {
            Ok(report) => {
                votes += report.votes_checked;
                executed.extend(report.faults.iter().copied());
                quant_tenants +=
                    report.precisions.iter().filter(|&&q| q > 2).count() as u64;
            }
            Err(payload) => {
                eprintln!(
                    "chaos schedule failed at seed {seed}; replay it with \
                     `HISAFE_CHAOS_SEED={seed} cargo test --test chaos_props` \
                     or `hisafe sweep --chaos-seed {seed}`"
                );
                resume_unwind(payload);
            }
        }
    }
    assert!(votes > 0, "the sweep must check real votes");
    // Quantization coverage: plans guarantee ≥ 1 q > 2 tenant each, so
    // every sweep drives the q-level secure path under faults.
    assert!(
        quant_tenants >= schedules,
        "a {schedules}-schedule sweep ran only {quant_tenants} q > 2 tenant(s)"
    );

    // Execution coverage. Every plan guarantees a kill/revive pair and
    // one frame-level fault drawn from three kinds; the draws are
    // deterministic per seed, so these assertions can never flake —
    // they pin that the *default sweep* exercises the whole taxonomy.
    for kind in
        ["kill_host", "revive_host", "corrupt_header", "corrupt_payload", "truncate_frame"]
    {
        assert!(executed.contains(kind), "sweep never executed {kind}: {executed:?}");
    }

    // Coin-gated kinds (balancer restart, shard poison, churn rounds)
    // appear in roughly half the plans: check them in the pure plan
    // domain over the same seeds, and that everything planned actually
    // ran.
    let mut planned: BTreeSet<&'static str> = BTreeSet::new();
    for seed in 0..schedules {
        for (_, fault) in FaultPlan::from_seed(seed).schedule {
            planned.insert(fault.kind());
        }
    }
    assert_eq!(
        planned.difference(&executed).count(),
        0,
        "every planned fault kind must execute: planned {planned:?}, executed {executed:?}"
    );
    for kind in ["restart_balancer", "poison_shard", "churn_round"] {
        assert!(
            planned.contains(kind),
            "a {schedules}-seed sweep should schedule {kind} at least once"
        );
    }
}
