//! Multi-tenant scheduler properties: an [`AggScheduler`] running
//! several concurrent tenants with *randomly interleaved* `run_round`
//! calls must produce, per tenant, votes bit-identical to a dedicated
//! [`PipelinedEngine`] and to `run_sync` — across random `n`, `d`, `ℓ`,
//! tie policies, batch sizes, and interleaving orders — while the live
//! worker-thread budget stays at exactly one pool's worth no matter how
//! many tenants are registered. Plus the lifecycle regressions: dropping
//! one session mid-stream must neither stall nor corrupt the others.

use hisafe::engine::{AdmissionError, AggScheduler, AggSession, Engine, PipelinedEngine};
use hisafe::poly::TiePolicy;
use hisafe::prop_assert_eq;
use hisafe::protocol::{
    check_thresholds, plain_hierarchical_vote, plain_hierarchical_vote_present,
    plain_quant_aggregate, plain_quant_aggregate_present, run_sync, run_sync_with_dropouts,
    ChurnError, HiSafeConfig, ParticipantSet,
};
use hisafe::util::prop::{forall, Gen};
use hisafe::util::rng::Rng;

/// A vector of uniformly random quantization levels from `L_q` (the odd
/// integers `{-(q-1), …, q-1}`; sign bits at `q = 2`).
fn level_vec(g: &mut Gen, q: u8, d: usize) -> Vec<i8> {
    (0..d)
        .map(|_| (2 * g.usize_range(0, q as usize - 1) as i64 - (q as i64 - 1)) as i8)
        .collect()
}

fn rand_cfg(g: &mut Gen) -> HiSafeConfig {
    let ell = g.usize_range(1, 3);
    let n1 = g.usize_range(1, 5);
    let intra = if g.bool() { TiePolicy::OneBit } else { TiePolicy::TwoBit };
    let inter = if g.bool() { TiePolicy::OneBit } else { TiePolicy::TwoBit };
    HiSafeConfig { n: ell * n1, ell, intra, inter, sparse: g.bool(), precision: 2 }
}

/// Visit order for one round: a random permutation of the tenants, so
/// the scheduler sees every interleaving pattern, not just round-robin.
fn rand_order(g: &mut Gen, k: usize) -> Vec<usize> {
    let mut order: Vec<usize> = (0..k).collect();
    g.rng().shuffle(&mut order);
    order
}

#[test]
fn interleaved_tenants_bit_identical_to_dedicated_engines_and_run_sync() {
    forall("scheduler ≡ dedicated ≡ run_sync (interleaved tenants)", 10, |g| {
        let n_tenants = g.usize_range(2, 4);
        let threads = g.usize_range(1, 3);
        let sched = AggScheduler::with_threads(threads);
        prop_assert_eq!(sched.worker_threads(), threads);
        prop_assert_eq!(sched.dealer_threads(), 1usize);

        struct Tenant {
            cfg: HiSafeConfig,
            d: usize,
            seed: u64,
            session: AggSession,
            dedicated: PipelinedEngine,
        }
        let mut tenants: Vec<Tenant> = (0..n_tenants)
            .map(|_| {
                let cfg = rand_cfg(g);
                let d = g.usize_range(1, 24);
                let seed = g.u64();
                let batch = g.usize_range(1, 3);
                Tenant {
                    cfg,
                    d,
                    seed,
                    session: sched.session(cfg, d, seed).with_batch_rounds(batch),
                    dedicated: PipelinedEngine::new(cfg, d, seed).with_batch_rounds(batch),
                }
            })
            .collect();
        // k tenants registered: the reported budget stays at one pool's
        // worth of span workers and one dealer thread. (These accessors
        // are construction-time facts; the measured live-thread gauge
        // assertion lives in rust/tests/thread_budget.rs.)
        prop_assert_eq!(
            sched.worker_threads(),
            threads,
            "{n_tenants} tenants must share one worker pool"
        );
        prop_assert_eq!(sched.dealer_threads(), 1usize);

        for round in 0..3u64 {
            for &ti in &rand_order(g, n_tenants) {
                let t = &mut tenants[ti];
                let signs: Vec<Vec<i8>> = (0..t.cfg.n).map(|_| g.sign_vec(t.d)).collect();
                let a = t.session.run_round(&signs);
                let b = t.dedicated.run_round(&signs);
                let cfg = t.cfg;
                prop_assert_eq!(
                    &a.global_vote,
                    &b.global_vote,
                    "tenant {ti} round {round} cfg={cfg:?}"
                );
                prop_assert_eq!(
                    &a.subgroup_votes,
                    &b.subgroup_votes,
                    "tenant {ti} round {round} cfg={cfg:?}"
                );
                prop_assert_eq!(&a.stats, &b.stats, "tenant {ti} round {round}");
                let reference = run_sync(&signs, cfg, t.seed ^ round);
                prop_assert_eq!(
                    &a.global_vote,
                    &reference.global_vote,
                    "tenant {ti} round {round} vs run_sync"
                );
                prop_assert_eq!(
                    &a.subgroup_votes,
                    &reference.subgroup_votes,
                    "tenant {ti} round {round} vs run_sync"
                );
                prop_assert_eq!(
                    &a.global_vote,
                    &plain_hierarchical_vote(&signs, cfg),
                    "tenant {ti} round {round} vs Eq. 8"
                );
            }
        }
        for (ti, t) in tenants.iter().enumerate() {
            prop_assert_eq!(t.session.rounds_run(), 3u64, "tenant {ti}");
        }
        prop_assert_eq!(sched.worker_threads(), threads);
        Ok(())
    });
}

#[test]
fn churned_scheduler_rounds_match_reference_and_aborts_are_typed() {
    // Scheduler-layer churn property: interleaved tenants with random
    // per-round dropout masks. Every completed round's votes must equal
    // the reference over the same survivor set; a below-threshold mask
    // must surface as AdmissionError::ChurnBelowThreshold naming the
    // exact group check_thresholds names — never a panic — while the
    // session stays healthy, bills the abort under `rejected`, and keeps
    // serving later rounds.
    forall("scheduler churn ≡ reference (interleaved tenants)", 8, |g| {
        let sched = AggScheduler::with_threads(g.usize_range(1, 2));
        struct Tenant {
            cfg: HiSafeConfig,
            d: usize,
            seed: u64,
            session: AggSession,
            completed: u64,
            aborted: u64,
        }
        let n_tenants = g.usize_range(2, 3);
        let mut tenants: Vec<Tenant> = (0..n_tenants)
            .map(|_| {
                let cfg = rand_cfg(g);
                let d = g.usize_range(1, 16);
                let seed = g.u64();
                Tenant {
                    cfg,
                    d,
                    seed,
                    session: sched.session(cfg, d, seed),
                    completed: 0,
                    aborted: 0,
                }
            })
            .collect();

        for round in 0..3u64 {
            for &ti in &rand_order(g, n_tenants) {
                let t = &mut tenants[ti];
                let signs: Vec<Vec<i8>> = (0..t.cfg.n).map(|_| g.sign_vec(t.d)).collect();
                let mask: Vec<bool> =
                    (0..t.cfg.n).map(|_| g.usize_range(0, 3) > 0).collect();
                let present = ParticipantSet::from_mask(mask);
                let cfg = t.cfg;
                match t.session.try_run_round_present(&signs, &present) {
                    Ok(got) => {
                        t.completed += 1;
                        let reference =
                            run_sync_with_dropouts(&signs, &present, cfg, t.seed ^ round)
                                .expect("the session completed, so thresholds held");
                        prop_assert_eq!(
                            &got.global_vote,
                            &reference.global_vote,
                            "tenant {ti} round {round} cfg={cfg:?} mask={:?}",
                            present.mask()
                        );
                        prop_assert_eq!(
                            &got.subgroup_votes,
                            &reference.subgroup_votes,
                            "tenant {ti} round {round} subgroups"
                        );
                        prop_assert_eq!(&got.stats, &reference.stats, "tenant {ti} round {round}");
                        prop_assert_eq!(
                            &got.global_vote,
                            &plain_hierarchical_vote_present(&signs, &present, cfg),
                            "tenant {ti} round {round} vs survivor plaintext"
                        );
                    }
                    Err(AdmissionError::ChurnBelowThreshold { group, survivors, required }) => {
                        t.aborted += 1;
                        prop_assert_eq!(
                            ChurnError::BelowThreshold { group, survivors, required },
                            check_thresholds(cfg, &present)
                                .expect_err("the scheduler aborted, so the mask violates"),
                            "tenant {ti} round {round} abort identity"
                        );
                    }
                    Err(e) => {
                        return Err(format!(
                            "tenant {ti} round {round}: unlimited QoS must only abort on \
                             churn, got {e:?}"
                        ))
                    }
                }
            }
        }
        // Aborts are billed as rejections, never as admitted rounds, and
        // the round counter only moves on completions.
        for (ti, t) in tenants.iter().enumerate() {
            prop_assert_eq!(t.session.rounds_run(), t.completed, "tenant {ti} round counter");
            let adm = t.session.admission_stats();
            prop_assert_eq!(adm.admitted_rounds, t.completed, "tenant {ti} admitted");
            prop_assert_eq!(adm.rejected, t.aborted, "tenant {ti} rejected");
            prop_assert_eq!(adm.throttled, 0u64, "tenant {ti} unlimited QoS never throttles");
        }
        Ok(())
    });
}

#[test]
fn mixed_precision_tenants_interleave_without_cross_talk() {
    // Quantization × scheduling: tenants at different q ∈ {2, 4, 8, 16}
    // share one scheduler with randomly interleaved rounds, then each
    // takes a churned round. Every vote must match the tenant's *own*
    // q-level plaintext reference — one tenant's wider field, larger
    // Fermat polynomial, and fatter triple stream must never bleed into
    // a neighbour's dealing or evaluation.
    forall("scheduler mixed-precision tenants", 8, |g| {
        let sched = AggScheduler::with_threads(g.usize_range(1, 2));
        struct Tenant {
            cfg: HiSafeConfig,
            d: usize,
            session: AggSession,
        }
        let n_tenants = g.usize_range(2, 4);
        let mut tenants: Vec<Tenant> = (0..n_tenants)
            .map(|i| {
                // Force precision diversity: tenant 0 stays legacy q=2,
                // tenant 1 is always quantized, the rest draw randomly.
                let q = match i {
                    0 => 2u8,
                    1 => [4u8, 8, 16][g.usize_range(0, 2)],
                    _ => hisafe::quant::PRECISIONS[g.usize_range(0, 3)],
                };
                let cfg = rand_cfg(g).with_precision(q);
                let d = g.usize_range(1, 12);
                Tenant { cfg, d, session: sched.session(cfg, d, g.u64()) }
            })
            .collect();

        for round in 0..3u64 {
            for &ti in &rand_order(g, n_tenants) {
                let t = &mut tenants[ti];
                let q = t.cfg.precision;
                let signs: Vec<Vec<i8>> =
                    (0..t.cfg.n).map(|_| level_vec(g, q, t.d)).collect();
                let cfg = t.cfg;
                let got = t.session.run_round(&signs);
                prop_assert_eq!(
                    &got.global_vote,
                    &plain_quant_aggregate(&signs, cfg),
                    "tenant {ti} q={q} round {round} cfg={cfg:?}"
                );
            }
        }

        // One churned round per tenant, where a single dropout survives
        // the threshold (n₁ ≥ 2): survivor votes still match the
        // tenant's q-level survivor-set reference.
        for (ti, t) in tenants.iter_mut().enumerate() {
            if t.cfg.n / t.cfg.ell < 2 {
                continue;
            }
            let q = t.cfg.precision;
            let signs: Vec<Vec<i8>> = (0..t.cfg.n).map(|_| level_vec(g, q, t.d)).collect();
            let mut mask = vec![true; t.cfg.n];
            mask[g.usize_range(0, t.cfg.n - 1)] = false;
            let present = ParticipantSet::from_mask(mask);
            let cfg = t.cfg;
            let got = t
                .session
                .try_run_round_present(&signs, &present)
                .expect("one dropout stays above threshold for n1 >= 2");
            prop_assert_eq!(
                &got.global_vote,
                &plain_quant_aggregate_present(&signs, &present, cfg),
                "tenant {ti} q={q} churned cfg={cfg:?} mask={:?}",
                present.mask()
            );
        }
        Ok(())
    });
}

#[test]
fn dropping_sessions_mid_stream_never_stalls_survivors() {
    forall("session drop isolation", 8, |g| {
        let sched = AggScheduler::with_threads(g.usize_range(1, 2));
        let n_tenants = g.usize_range(3, 5);
        let mut tenants: Vec<(HiSafeConfig, usize, AggSession)> = (0..n_tenants)
            .map(|_| {
                let cfg = rand_cfg(g);
                let d = g.usize_range(1, 16);
                let session = sched
                    .session(cfg, d, g.u64())
                    .with_batch_rounds(g.usize_range(1, 3));
                (cfg, d, session)
            })
            .collect();
        // Warm every tenant (leaves prefetch batches in flight).
        for (cfg, d, session) in tenants.iter_mut() {
            let signs: Vec<Vec<i8>> = (0..cfg.n).map(|_| g.sign_vec(*d)).collect();
            let got = session.run_round(&signs);
            prop_assert_eq!(
                &got.global_vote,
                &plain_hierarchical_vote(&signs, *cfg),
                "warmup cfg={cfg:?}"
            );
        }
        // Drop a random tenant mid-stream.
        let victim = g.usize_range(0, n_tenants - 1);
        tenants.remove(victim);
        // Survivors keep provisioning and evaluating correctly: blocking
        // pre-provision first (the path that would hang if the plane
        // stalled on the dead tenant), then normal rounds.
        for (_, _, session) in tenants.iter_mut() {
            session.provision(2);
            if session.plan().triples_needed() > 0 {
                let provisioned = session.provisioned_rounds();
                if provisioned < 2 {
                    return Err(format!("provision(2) left only {provisioned} rounds"));
                }
            }
        }
        for round in 0..2u64 {
            for (cfg, d, session) in tenants.iter_mut() {
                let signs: Vec<Vec<i8>> = (0..cfg.n).map(|_| g.sign_vec(*d)).collect();
                let got = session.run_round(&signs);
                prop_assert_eq!(
                    &got.global_vote,
                    &plain_hierarchical_vote(&signs, *cfg),
                    "round {round} after drop cfg={cfg:?}"
                );
            }
        }
        Ok(())
    });
}
