//! Admission-control / QoS properties for the multi-tenant scheduler.
//!
//! Two pillars, matching the admission layer's two promises:
//!
//! 1. **QoS never changes votes.** Tenants running under tight policies
//!    (bounded queues, rate budgets, weights) with throttle-and-retry
//!    admission and randomly interleaved rounds stay bit-identical to
//!    dedicated, unthrottled [`PipelinedEngine`]s and to `run_sync` —
//!    admission decides *when* a round runs, never what it computes.
//! 2. **A greedy tenant cannot starve a well-behaved one.** Under the
//!    provisioning plane's weighted round-robin, a tenant flooding the
//!    plane with prefetch requests cannot push another tenant's dealing
//!    share below its weight. The loose (scheduling-order) bound is
//!    asserted always; the tight proportional-share bound involves a
//!    wall-clock race window on the plane's command drain, so it is
//!    opt-in via `HISAFE_BENCH_STRICT=1` like every timing assert in
//!    this repo.
//!
//! Plus the deterministic admission mechanics: queue-depth bounds,
//! throttle retry_after, tenant capacity — no sleeps, no clock
//! dependence beyond "a 2000-second budget does not refill mid-test".

use std::time::Duration;

use hisafe::engine::{AdmissionError, AggScheduler, AggSession, Engine, PipelinedEngine, QosPolicy};
use hisafe::poly::TiePolicy;
use hisafe::prop_assert_eq;
use hisafe::protocol::{plain_hierarchical_vote, run_sync, HiSafeConfig};
use hisafe::util::prop::{forall, Gen};
use hisafe::util::rng::Rng;

fn rand_cfg(g: &mut Gen) -> HiSafeConfig {
    let ell = g.usize_range(1, 3);
    let n1 = g.usize_range(2, 4); // n₁ ≥ 2 so every tenant needs triples
    let intra = if g.bool() { TiePolicy::OneBit } else { TiePolicy::TwoBit };
    let inter = if g.bool() { TiePolicy::OneBit } else { TiePolicy::TwoBit };
    HiSafeConfig { n: ell * n1, ell, intra, inter, sparse: g.bool(), precision: 2 }
}

/// A QoS policy tight enough to exercise every admission path but
/// generous enough (rates in the hundreds per second) that retries cost
/// milliseconds, not seconds.
fn rand_tight_qos(g: &mut Gen) -> QosPolicy {
    let mut qos = QosPolicy::unlimited().with_weight(g.usize_range(1, 3) as u32);
    if g.bool() {
        qos = qos.with_queue_depth(g.usize_range(1, 3));
    }
    if g.bool() {
        qos = qos.with_rounds_per_sec(g.usize_range(200, 1000) as f64);
    }
    if g.bool() {
        qos = qos.with_triples_per_sec(g.usize_range(2000, 20000) as f64);
    }
    if g.bool() {
        qos = qos.with_burst_rounds(g.usize_range(1, 3) as f64);
    }
    qos
}

#[test]
fn throttled_interleaved_tenants_bit_identical_to_dedicated_and_run_sync() {
    forall("QoS ≢ votes: throttled scheduler ≡ dedicated ≡ run_sync", 6, |g| {
        let n_tenants = g.usize_range(2, 3);
        let threads = g.usize_range(1, 2);
        let sched = AggScheduler::with_threads(threads);

        struct Tenant {
            cfg: HiSafeConfig,
            d: usize,
            seed: u64,
            session: AggSession,
            dedicated: PipelinedEngine,
        }
        let mut tenants: Vec<Tenant> = (0..n_tenants)
            .map(|_| {
                let cfg = rand_cfg(g);
                let d = g.usize_range(1, 16);
                let seed = g.u64();
                let qos = rand_tight_qos(g);
                Tenant {
                    cfg,
                    d,
                    seed,
                    session: sched.try_session(cfg, d, seed, qos).expect("policy is valid"),
                    dedicated: PipelinedEngine::new(cfg, d, seed),
                }
            })
            .collect();

        for round in 0..3u64 {
            // Random visit order: the scheduler must tolerate every
            // interleaving pattern, with throttling injected anywhere.
            let mut order: Vec<usize> = (0..n_tenants).collect();
            g.rng().shuffle(&mut order);
            for &ti in &order {
                let t = &mut tenants[ti];
                let signs: Vec<Vec<i8>> = (0..t.cfg.n).map(|_| g.sign_vec(t.d)).collect();
                // The shared blocking retry helper — the same loop the
                // trainer and sweep use — waits out throttle denials.
                let (a, _denials, _waited) = t.session.run_round_admitted(&signs);
                let b = t.dedicated.run_round(&signs);
                let cfg = t.cfg;
                prop_assert_eq!(
                    &a.global_vote,
                    &b.global_vote,
                    "tenant {ti} round {round} cfg={cfg:?}"
                );
                prop_assert_eq!(
                    &a.subgroup_votes,
                    &b.subgroup_votes,
                    "tenant {ti} round {round} cfg={cfg:?}"
                );
                prop_assert_eq!(&a.stats, &b.stats, "tenant {ti} round {round}");
                let reference = run_sync(&signs, cfg, t.seed ^ round);
                prop_assert_eq!(
                    &a.global_vote,
                    &reference.global_vote,
                    "tenant {ti} round {round} vs run_sync"
                );
                prop_assert_eq!(
                    &a.global_vote,
                    &plain_hierarchical_vote(&signs, cfg),
                    "tenant {ti} round {round} vs Eq. 8"
                );
            }
        }
        for (ti, t) in tenants.iter().enumerate() {
            prop_assert_eq!(t.session.rounds_run(), 3u64, "tenant {ti}");
            let adm = t.session.admission_stats();
            prop_assert_eq!(adm.admitted_rounds, 3u64, "tenant {ti} admitted");
            // The retry loop only ever eats Throttled denials.
            prop_assert_eq!(adm.queue_full, 0u64, "tenant {ti} queue_full");
            prop_assert_eq!(adm.rejected, 0u64, "tenant {ti} rejected");
        }
        Ok(())
    });
}

#[test]
fn greedy_flood_cannot_starve_a_weighted_tenant() {
    let strict = std::env::var("HISAFE_BENCH_STRICT").map(|v| v == "1").unwrap_or(false);
    // (victim weight, greedy weight, victim rounds, flood size)
    for (vw, gw, want, flood) in [(1u32, 1u32, 8usize, 40usize), (3, 1, 9, 40)] {
        let sched = AggScheduler::with_threads(1);
        let cfg = HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit);
        let d = 2048; // big enough that one dealt round is real work
        let mut victim = sched
            .try_session(cfg, d, 7, QosPolicy::unlimited().with_weight(vw))
            .unwrap();
        let mut greedy = sched
            .try_session(cfg, d, 8, QosPolicy::unlimited().with_weight(gw))
            .unwrap();
        assert!(victim.plan().triples_needed() > 0);

        // The greedy tenant floods the plane, then the victim asks for a
        // modest provision and blocks until it is served.
        greedy.try_prefetch(flood).expect("unbounded queue");
        victim.provision(want);
        assert!(victim.provisioned_rounds() >= want);

        let greedy_dealt = greedy.dealt_rounds();
        let victim_dealt = victim.dealt_rounds();
        assert!(victim_dealt as usize >= want, "victim got {victim_dealt} < {want}");
        // Loose, scheduling-order bound (always on): under weighted
        // round-robin the victim finishes long before the flood drains;
        // under starvation (flood-first FIFO) greedy_dealt would be the
        // whole flood before the victim saw a single round.
        assert!(
            (greedy_dealt as usize) < flood,
            "victim waited for the whole flood: greedy dealt {greedy_dealt}/{flood} \
             before victim's {want} rounds (vw={vw} gw={gw})"
        );
        // Tight proportional bound (strict only: the plane may deal a
        // few greedy rounds in the race window between the flood request
        // and the victim's request landing): while the victim's `want`
        // rounds deal, WRR hands the greedy tenant at most
        // ceil(want / vw) · gw quanta, plus the race slack.
        if strict {
            let proportional = (want as u32).div_ceil(vw) * gw;
            let slack = 4;
            assert!(
                greedy_dealt <= (proportional + slack) as u64,
                "greedy exceeded its weighted share: {greedy_dealt} > {proportional} + {slack} \
                 (vw={vw} gw={gw} want={want})"
            );
        }

        // Fairness must not corrupt anything: both tenants still vote
        // bit-identically to the plaintext reference afterwards.
        let signs: Vec<Vec<i8>> = {
            let mut rng = hisafe::util::rng::Xoshiro256pp::seed_from_u64(5);
            (0..cfg.n).map(|_| (0..d).map(|_| rng.gen_sign()).collect()).collect()
        };
        assert_eq!(victim.run_round(&signs).global_vote, plain_hierarchical_vote(&signs, cfg));
        assert_eq!(greedy.run_round(&signs).global_vote, plain_hierarchical_vote(&signs, cfg));
    }
}

#[test]
fn queue_depth_is_enforced_and_typed() {
    let sched = AggScheduler::with_threads(1);
    let cfg = HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit);
    let mut s = sched
        .try_session(cfg, 6, 3, QosPolicy::unlimited().with_queue_depth(2))
        .unwrap();
    // Construction bootstraps one round onto the queue.
    assert_eq!(s.queued_rounds(), 1);
    match s.try_prefetch(3) {
        Err(AdmissionError::Rejected { reason }) => {
            assert!(reason.contains("queue depth"), "reason: {reason}");
        }
        other => panic!("oversized prefetch must be Rejected, got {other:?}"),
    }
    s.try_prefetch(1).expect("one slot free");
    match s.try_prefetch(1) {
        Err(AdmissionError::QueueFull { depth }) => assert_eq!(depth, 2),
        other => panic!("expected QueueFull, got {other:?}"),
    }
    let adm = s.admission_stats();
    assert_eq!(adm.rejected, 1);
    assert_eq!(adm.queue_full, 1);
    assert_eq!(adm.throttled, 0);
}

#[test]
fn exhausted_budget_throttles_with_usable_retry_after() {
    let sched = AggScheduler::with_threads(1);
    let cfg = HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit);
    // One round per 2000 s: the burst admits round 1, round 2 throttles
    // (the bucket cannot meaningfully refill within the test's runtime).
    let mut s = sched
        .try_session(cfg, 5, 3, QosPolicy::unlimited().with_rounds_per_sec(0.0005))
        .unwrap();
    let signs: Vec<Vec<i8>> = {
        let mut rng = hisafe::util::rng::Xoshiro256pp::seed_from_u64(9);
        (0..cfg.n).map(|_| (0..5).map(|_| rng.gen_sign()).collect()).collect()
    };
    let out = s.try_run_round(&signs).expect("burst admits the first round");
    assert_eq!(out.global_vote, plain_hierarchical_vote(&signs, cfg));
    match s.try_run_round(&signs) {
        Err(AdmissionError::Throttled { retry_after }) => {
            assert!(retry_after > Duration::ZERO);
            assert!(retry_after <= Duration::from_secs(3600), "retry_after is usable");
        }
        Ok(_) => panic!("second round must throttle"),
        Err(e) => panic!("expected Throttled, got {e:?}"),
    }
    // The blocking Engine surface stays exempt and bit-identical — a
    // legacy caller is never broken by someone else's QoS experiment.
    assert_eq!(s.run_round(&signs).global_vote, plain_hierarchical_vote(&signs, cfg));
    assert_eq!(s.admission_stats().admitted_rounds, 2);
    assert_eq!(s.admission_stats().throttled, 1);
}

#[test]
fn tenant_capacity_is_enforced_and_recovers() {
    let sched = AggScheduler::with_capacity(1, 2);
    let cfg = HiSafeConfig::flat(3, TiePolicy::OneBit);
    let a = sched.try_session(cfg, 4, 1, QosPolicy::unlimited()).unwrap();
    let _b = sched.try_session(cfg, 4, 2, QosPolicy::unlimited()).unwrap();
    assert_eq!(sched.live_tenants(), 2);
    assert!(matches!(
        sched.try_session(cfg, 4, 3, QosPolicy::unlimited()),
        Err(AdmissionError::Rejected { .. })
    ));
    drop(a);
    assert_eq!(sched.live_tenants(), 1);
    // Freed capacity readmits, and the new session works end-to-end.
    let mut c = sched.try_session(cfg, 4, 4, QosPolicy::unlimited()).unwrap();
    let signs: Vec<Vec<i8>> = {
        let mut rng = hisafe::util::rng::Xoshiro256pp::seed_from_u64(11);
        (0..3).map(|_| (0..4).map(|_| rng.gen_sign()).collect()).collect()
    };
    assert_eq!(
        c.run_round(&signs).global_vote,
        plain_hierarchical_vote(&signs, cfg)
    );
}
