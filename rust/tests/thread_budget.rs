//! The oversubscription fix, asserted on *measured* thread counts — not
//! on accessors that return construction-time constants.
//!
//! `live_engine_threads()` is a process-wide spawn/join-balanced gauge
//! maintained at every engine-subsystem spawn site (span workers,
//! provisioning planes). This file deliberately contains a SINGLE test:
//! integration-test binaries run as separate processes, and with only
//! one test in this process nothing else spawns or joins engine threads
//! concurrently, so every assertion below is deterministic.

use hisafe::engine::{live_engine_threads, AggScheduler, AggSession, Engine, PipelinedEngine};
use hisafe::poly::TiePolicy;
use hisafe::protocol::{plain_hierarchical_vote, HiSafeConfig};
use hisafe::util::rng::{Rng, Xoshiro256pp};

fn rand_signs(n: usize, d: usize, seed: u64) -> Vec<Vec<i8>> {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    (0..n).map(|_| (0..d).map(|_| rng.gen_sign()).collect()).collect()
}

#[test]
fn k_tenants_cost_one_pools_worth_of_live_threads() {
    let base = live_engine_threads();
    let cfg = HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit);

    // One scheduler with 2 pinned span workers: 2 workers + 1 dealer.
    let sched = AggScheduler::with_threads(2);
    assert_eq!(
        live_engine_threads() - base,
        3,
        "scheduler = 2 span workers + 1 dealer thread"
    );

    // k = 4 tenants: the live thread count MUST NOT move — sessions run
    // entirely on the shared pool and plane.
    let mut sessions: Vec<AggSession> =
        (0..4).map(|i| sched.session(cfg, 8, i as u64)).collect();
    assert_eq!(
        live_engine_threads() - base,
        3,
        "registering k tenants must not spawn threads"
    );
    for (i, s) in sessions.iter_mut().enumerate() {
        let signs = rand_signs(6, 8, 40 + i as u64);
        let got = s.run_round(&signs);
        assert_eq!(got.global_vote, plain_hierarchical_vote(&signs, cfg));
    }
    assert_eq!(
        live_engine_threads() - base,
        3,
        "running k tenants' rounds must not spawn threads"
    );

    // Contrast: ONE dedicated engine adds its own pool + plane on top —
    // the k-fold growth the scheduler exists to eliminate.
    let mut dedicated = PipelinedEngine::on_scheduler(&AggScheduler::with_threads(2), cfg, 8, 9);
    assert_eq!(
        live_engine_threads() - base,
        6,
        "a dedicated engine spawns a second pool's worth"
    );
    let signs = rand_signs(6, 8, 99);
    let got = dedicated.run_round(&signs);
    assert_eq!(got.global_vote, plain_hierarchical_vote(&signs, cfg));
    drop(dedicated);
    assert_eq!(live_engine_threads() - base, 3, "dedicated engine joined its threads");

    // Full teardown returns the gauge to baseline: every spawned engine
    // thread was joined.
    drop(sessions);
    drop(sched);
    assert_eq!(live_engine_threads(), base, "all engine threads joined");
}
