//! Service-layer properties over a **real loopback TCP client/server
//! pair**: remote rounds and remote training must be bit-identical to
//! the in-process engines and `run_sync` — across random tenant shapes,
//! shard counts, interleavings, and QoS throttling (with the
//! `Throttled` denial crossing the wire and being retried by the
//! client) — and invalid QoS policies must be the same typed rejection
//! on the wire path as on the in-process path.

use hisafe::engine::{
    AdmissionError, AggScheduler, Engine, PipelinedEngine, QosPolicy, SessionId, SessionSnapshot,
};
use hisafe::fl::data::{partition_users, synthetic, DataKind, Partition};
use hisafe::fl::model::LinearSoftmax;
use hisafe::fl::trainer::{train, train_remote, Aggregator, FedSpec, TrainConfig};
use hisafe::poly::TiePolicy;
use hisafe::protocol::{
    check_thresholds, plain_hierarchical_vote, plain_hierarchical_vote_present,
    plain_quant_aggregate, plain_quant_aggregate_present, run_sync, run_sync_with_dropouts,
    ChurnError, HiSafeConfig, ParticipantSet,
};
use hisafe::service::{
    binary, AdmissionReply, AggFrontend, Codec, Error, Request, Response, ServiceClient,
    ServiceServer,
};
use hisafe::prop_assert_eq;
use hisafe::util::json::parse;
use hisafe::util::prop::{forall, Gen};
use hisafe::util::rng::Rng;

fn rand_cfg(g: &mut Gen) -> HiSafeConfig {
    let ell = g.usize_range(1, 3);
    let n1 = g.usize_range(1, 5);
    let intra = if g.bool() { TiePolicy::OneBit } else { TiePolicy::TwoBit };
    let inter = if g.bool() { TiePolicy::OneBit } else { TiePolicy::TwoBit };
    HiSafeConfig { n: ell * n1, ell, intra, inter, sparse: g.bool(), precision: 2 }
}

fn rand_order(g: &mut Gen, k: usize) -> Vec<usize> {
    let mut order: Vec<usize> = (0..k).collect();
    g.rng().shuffle(&mut order);
    order
}

/// A vector of uniformly random quantization levels from `L_q` (the odd
/// integers `{-(q-1), …, q-1}`; sign bits at `q = 2`).
fn level_vec(g: &mut Gen, q: u8, d: usize) -> Vec<i8> {
    (0..d)
        .map(|_| (2 * g.usize_range(0, q as usize - 1) as i64 - (q as i64 - 1)) as i8)
        .collect()
}

/// Spawn a server on an ephemeral loopback port. The handle is joined
/// at the end of each test to assert a clean serve-loop exit.
fn spawn_server(
    frontend: AggFrontend,
) -> (String, std::thread::JoinHandle<std::io::Result<()>>) {
    let server = ServiceServer::bind("127.0.0.1:0", frontend).expect("bind loopback");
    let addr = server.local_addr().expect("bound addr").to_string();
    let handle = std::thread::spawn(move || server.serve());
    (addr, handle)
}

#[test]
fn remote_rounds_bit_identical_to_dedicated_engines_and_run_sync() {
    forall("remote ≡ dedicated ≡ run_sync (interleaved tenants over TCP)", 6, |g| {
        let shards = g.usize_range(1, 3);
        let (addr, server) = spawn_server(AggFrontend::new(shards, g.usize_range(1, 2)));
        let mut client = ServiceClient::connect(&addr).map_err(|e| e.to_string())?;

        struct Tenant {
            cfg: HiSafeConfig,
            d: usize,
            seed: u64,
            sid: SessionId,
            dedicated: PipelinedEngine,
        }
        let n_tenants = g.usize_range(2, 4);
        let mut tenants: Vec<Tenant> = Vec::with_capacity(n_tenants);
        for _ in 0..n_tenants {
            let cfg = rand_cfg(g);
            let d = g.usize_range(1, 24);
            let seed = g.u64();
            // Some tenants carry a modest rate budget, so a slice of the
            // interleaving runs through wire-level Throttled + client
            // retry (timing decides how often; votes must never care).
            let qos = if g.bool() {
                QosPolicy::unlimited().with_rounds_per_sec(200.0)
            } else {
                QosPolicy::unlimited()
            };
            let sid = client
                .open_session(cfg, d, seed, qos)
                .map_err(|e| format!("open_session: {e}"))?;
            tenants.push(Tenant { cfg, d, seed, sid, dedicated: PipelinedEngine::new(cfg, d, seed) });
        }

        for round in 0..3u64 {
            for &ti in &rand_order(g, n_tenants) {
                let t = &mut tenants[ti];
                let signs: Vec<Vec<i8>> = (0..t.cfg.n).map(|_| g.sign_vec(t.d)).collect();
                let (reply, _denials, _waited) = client
                    .run_round_admitted(t.sid, &signs)
                    .map_err(|e| format!("round: {e}"))?;
                let local = t.dedicated.run_round(&signs);
                let cfg = t.cfg;
                prop_assert_eq!(
                    &reply.global_vote,
                    &local.global_vote,
                    "tenant {ti} round {round} cfg={cfg:?} vs dedicated"
                );
                prop_assert_eq!(
                    &reply.subgroup_votes,
                    &local.subgroup_votes,
                    "tenant {ti} round {round} cfg={cfg:?} vs dedicated"
                );
                prop_assert_eq!(&reply.stats, &local.stats, "tenant {ti} round {round}");
                let reference = run_sync(&signs, cfg, t.seed ^ round);
                prop_assert_eq!(
                    &reply.global_vote,
                    &reference.global_vote,
                    "tenant {ti} round {round} vs run_sync"
                );
                prop_assert_eq!(
                    &reply.global_vote,
                    &plain_hierarchical_vote(&signs, cfg),
                    "tenant {ti} round {round} vs Eq. 8"
                );
            }
        }
        for t in &tenants {
            let stats = client.stats(Some(t.sid)).map_err(|e| format!("stats: {e}"))?;
            prop_assert_eq!(stats.rounds_run, 3u64, "tenant rounds over the wire");
            prop_assert_eq!(stats.admission.admitted_rounds, 3u64);
            client.close_session(t.sid).map_err(|e| format!("close: {e}"))?;
        }
        client.shutdown().map_err(|e| format!("shutdown: {e}"))?;
        server
            .join()
            .map_err(|_| "serve thread panicked".to_string())?
            .map_err(|e| format!("serve loop: {e}"))?;
        Ok(())
    });
}

#[test]
fn throttled_wire_rounds_are_retried_and_bit_identical() {
    // Deterministic throttle exercise: a 2 rounds/s budget guarantees
    // back-to-back rounds are denied, the denial crosses the wire, the
    // client retries until admitted — and the votes are bit-identical
    // to a dedicated engine's, because admission decides *when* a round
    // runs, never what it computes.
    let (addr, server) = spawn_server(AggFrontend::new(1, 1));
    let mut client = ServiceClient::connect(&addr).expect("connect");
    let cfg = HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit);
    let (d, seed) = (16usize, 9u64);
    let sid = client
        .open_session(cfg, d, seed, QosPolicy::unlimited().with_rounds_per_sec(2.0))
        .expect("admitted");
    let mut dedicated = PipelinedEngine::new(cfg, d, seed);
    let mut rng = hisafe::util::rng::Xoshiro256pp::seed_from_u64(31);
    let mut total_denials = 0u64;
    for round in 0..3u64 {
        let signs: Vec<Vec<i8>> =
            (0..cfg.n).map(|_| (0..d).map(|_| rng.gen_sign()).collect()).collect();
        let (reply, denials, _waited) =
            client.run_round_admitted(sid, &signs).expect("retried to admission");
        total_denials += denials;
        let local = dedicated.run_round(&signs);
        assert_eq!(reply.global_vote, local.global_vote, "round {round}");
        assert_eq!(reply.subgroup_votes, local.subgroup_votes, "round {round}");
        assert_eq!(
            reply.global_vote,
            run_sync(&signs, cfg, seed ^ round).global_vote,
            "round {round} vs run_sync"
        );
    }
    assert!(
        total_denials >= 1,
        "a 2 rounds/s budget must throttle back-to-back wire rounds"
    );
    let stats = client.stats(Some(sid)).expect("stats");
    assert_eq!(stats.admission.admitted_rounds, 3);
    assert_eq!(
        stats.admission.throttled, total_denials,
        "client-side retry count must equal server-side throttle count"
    );
    client.close_session(sid).expect("close");
    client.shutdown().expect("shutdown");
    server.join().expect("serve thread").expect("clean shutdown");
}

#[test]
fn churned_wire_rounds_match_reference_and_aborts_are_typed_end_to_end() {
    // Wire-layer churn property: random tenants over real loopback TCP,
    // each round carrying a random `present` mask. Completed rounds must
    // be bit-identical to `run_sync_with_dropouts` over the same
    // survivor set; a below-threshold mask must come back as
    // `Error::Admission(ChurnBelowThreshold)` naming the exact group the
    // in-process `check_thresholds` names — the typed abort survives
    // JSON encode/decode and the per-shard routing path — while the
    // session stays open, bills the abort under `rejected`, and serves
    // the next round normally.
    forall("wire churn ≡ reference (random tenants over TCP)", 5, |g| {
        let (addr, server) = spawn_server(AggFrontend::new(g.usize_range(1, 3), 1));
        let mut client = ServiceClient::connect(&addr).map_err(|e| e.to_string())?;

        struct Tenant {
            cfg: HiSafeConfig,
            d: usize,
            seed: u64,
            sid: SessionId,
            completed: u64,
            aborted: u64,
        }
        let n_tenants = g.usize_range(2, 3);
        let mut tenants: Vec<Tenant> = Vec::with_capacity(n_tenants);
        for _ in 0..n_tenants {
            let cfg = rand_cfg(g);
            let d = g.usize_range(1, 16);
            let seed = g.u64();
            let sid = client
                .open_session(cfg, d, seed, QosPolicy::unlimited())
                .map_err(|e| format!("open_session: {e}"))?;
            tenants.push(Tenant { cfg, d, seed, sid, completed: 0, aborted: 0 });
        }

        for round in 0..3u64 {
            for &ti in &rand_order(g, n_tenants) {
                let t = &mut tenants[ti];
                let cfg = t.cfg;
                let signs: Vec<Vec<i8>> = (0..cfg.n).map(|_| g.sign_vec(t.d)).collect();
                let mask: Vec<bool> = (0..cfg.n).map(|_| g.usize_range(0, 3) > 0).collect();
                let present = ParticipantSet::from_mask(mask.clone());
                match client.submit_round_present(t.sid, &signs, &mask) {
                    Ok(reply) => {
                        t.completed += 1;
                        let reference =
                            run_sync_with_dropouts(&signs, &present, cfg, t.seed ^ round)
                                .expect("the wire round completed, so thresholds held");
                        prop_assert_eq!(
                            &reply.global_vote,
                            &reference.global_vote,
                            "tenant {ti} round {round} cfg={cfg:?} mask={mask:?}"
                        );
                        prop_assert_eq!(
                            &reply.subgroup_votes,
                            &reference.subgroup_votes,
                            "tenant {ti} round {round} subgroups"
                        );
                        prop_assert_eq!(&reply.stats, &reference.stats, "tenant {ti} round {round}");
                        prop_assert_eq!(
                            &reply.global_vote,
                            &plain_hierarchical_vote_present(&signs, &present, cfg),
                            "tenant {ti} round {round} vs survivor plaintext"
                        );
                    }
                    Err(Error::Admission(AdmissionError::ChurnBelowThreshold {
                        group,
                        survivors,
                        required,
                    })) => {
                        t.aborted += 1;
                        prop_assert_eq!(
                            ChurnError::BelowThreshold { group, survivors, required },
                            check_thresholds(cfg, &present)
                                .expect_err("the server aborted, so the mask violates"),
                            "tenant {ti} round {round} wire abort identity"
                        );
                    }
                    Err(e) => {
                        return Err(format!(
                            "tenant {ti} round {round}: unlimited QoS must only abort on \
                             churn, got {e:?}"
                        ))
                    }
                }
            }
        }
        for (ti, t) in tenants.iter().enumerate() {
            let stats = client.stats(Some(t.sid)).map_err(|e| format!("stats: {e}"))?;
            prop_assert_eq!(stats.rounds_run, t.completed, "tenant {ti} round counter");
            prop_assert_eq!(stats.admission.admitted_rounds, t.completed, "tenant {ti} admitted");
            prop_assert_eq!(stats.admission.rejected, t.aborted, "tenant {ti} rejected");
            client.close_session(t.sid).map_err(|e| format!("close: {e}"))?;
        }
        client.shutdown().map_err(|e| format!("shutdown: {e}"))?;
        server
            .join()
            .map_err(|_| "serve thread panicked".to_string())?
            .map_err(|e| format!("serve loop: {e}"))?;
        Ok(())
    });
}

#[test]
fn train_remote_bit_identical_to_solo_train_for_random_federations() {
    // The acceptance property: 2–4 random federations driven through
    // train_remote over loopback TCP (round-robin interleaved on the
    // shared connection, shards chosen at random, some tenants under a
    // rate budget that forces wire throttle-retries) must produce
    // final parameters and accuracies bit-identical to training each
    // federation alone, in-process.
    let (tr, te) = synthetic(DataKind::MnistLike, 600, 150, 7);
    let shards_data = partition_users(&tr, 12, Partition::TwoClass, 7);
    let m = LinearSoftmax::new(784, 10);

    forall("train_remote ≡ solo train (random federations over TCP)", 2, |g| {
        let n_feds = g.usize_range(2, 4);
        let mut cfgs: Vec<(TrainConfig, Aggregator, QosPolicy)> = Vec::with_capacity(n_feds);
        for _ in 0..n_feds {
            let ell = [1usize, 2, 3][g.usize_range(0, 2)];
            let intra = if g.bool() { TiePolicy::OneBit } else { TiePolicy::TwoBit };
            let agg = Aggregator::HiSafe(HiSafeConfig::hierarchical(6, ell, intra));
            let tc = TrainConfig {
                n_users: 12,
                participants: 6,
                rounds: g.usize_range(2, 3),
                lr: 0.002,
                batch_size: 16,
                eval_every: 10,
                seed: g.u64(),
                churn: 0.0,
            };
            // Half the federations run under a tight-but-generous QoS so
            // the wire retry loop is exercised without stalling the test.
            let qos = if g.bool() {
                QosPolicy::unlimited().with_rounds_per_sec(5000.0).with_queue_depth(2)
            } else {
                QosPolicy::unlimited()
            };
            cfgs.push((tc, agg, qos));
        }

        // Solo, in-process reference runs (one private scheduler each).
        let solo: Vec<_> = cfgs
            .iter()
            .map(|(tc, agg, _)| train(&m, &tr, &te, &shards_data, *agg, tc))
            .collect();

        // The same federations, through a sharded frontend over TCP.
        let (addr, server) =
            spawn_server(AggFrontend::new(g.usize_range(1, 3), g.usize_range(1, 2)));
        let mut client = ServiceClient::connect(&addr).map_err(|e| e.to_string())?;
        let specs: Vec<_> = cfgs
            .iter()
            .map(|(tc, agg, qos)| FedSpec {
                model: &m,
                train_ds: &tr,
                test_ds: &te,
                shards: &shards_data,
                agg: *agg,
                cfg: tc.clone(),
                qos: *qos,
            })
            .collect();
        let remote = train_remote(&mut client, &specs);

        prop_assert_eq!(remote.len(), solo.len());
        for (i, (r, s)) in remote.iter().zip(&solo).enumerate() {
            prop_assert_eq!(&r.final_params, &s.final_params, "federation {i} diverged");
            prop_assert_eq!(r.final_acc, s.final_acc, "federation {i} accuracy");
            prop_assert_eq!(r.logs.len(), s.logs.len(), "federation {i} rounds");
            let adm = r.admission.as_ref().expect("secure run reports admission");
            prop_assert_eq!(
                adm.admitted_rounds,
                cfgs[i].0.rounds as u64,
                "federation {i} admitted rounds"
            );
            // Per-round vote directions agree too (loss/acc curves are
            // derived from the same params, so spot-check the logs).
            for (rl, sl) in r.logs.iter().zip(&s.logs) {
                prop_assert_eq!(rl.train_loss, sl.train_loss, "federation {i} loss curve");
                prop_assert_eq!(
                    rl.uplink_bits_per_user, sl.uplink_bits_per_user,
                    "federation {i} uplink"
                );
            }
        }
        // train_remote closed every session.
        let fe_stats = client.stats(None).map_err(|e| e.to_string())?;
        prop_assert_eq!(
            fe_stats.shard_tenants.expect("frontend scope").iter().sum::<usize>(),
            0usize,
            "sessions must be closed"
        );
        client.shutdown().map_err(|e| format!("shutdown: {e}"))?;
        server
            .join()
            .map_err(|_| "serve thread panicked".to_string())?
            .map_err(|e| format!("serve loop: {e}"))?;
        Ok(())
    });
}

#[test]
fn invalid_qos_policies_rejected_identically_on_both_paths() {
    // Satellite property: weight == 0, zero-capacity rate buckets, and
    // queue_depth == 0 must be AdmissionError::Rejected — never a panic,
    // never Throttled — at SessionOpen on BOTH the in-process path and
    // the wire path, and must leak no tenant slot on either.
    let sched = AggScheduler::with_threads(1);
    let (addr, server) = spawn_server(AggFrontend::new(2, 1));
    let mut client = ServiceClient::connect(&addr).expect("connect");

    forall("invalid QosPolicy ⇒ Rejected on local and wire paths", 40, |g| {
        let cfg = rand_cfg(g);
        let d = g.usize_range(1, 8);
        let qos = match g.range(0, 3) {
            0 => QosPolicy::unlimited().with_weight(0),
            1 => QosPolicy::unlimited().with_queue_depth(0),
            2 => {
                // Zero-capacity (or negative) token buckets.
                let rate = if g.bool() { 0.0 } else { -(g.f64() * 10.0) };
                if g.bool() {
                    QosPolicy::unlimited().with_rounds_per_sec(rate)
                } else {
                    QosPolicy::unlimited().with_triples_per_sec(rate)
                }
            }
            _ => QosPolicy::unlimited().with_burst_rounds(g.f64() * 0.99),
        };
        match sched.try_session(cfg, d, g.u64(), qos) {
            Err(AdmissionError::Rejected { .. }) => {}
            Err(e) => return Err(format!("local: {qos:?} must be Rejected, got {e:?}")),
            Ok(_) => return Err(format!("local: {qos:?} must be rejected, was admitted")),
        }
        match client.open_session(cfg, d, g.u64(), qos) {
            Err(Error::Admission(AdmissionError::Rejected { .. })) => {}
            Err(e) => return Err(format!("wire: {qos:?} must be Rejected, got {e:?}")),
            Ok(sid) => return Err(format!("wire: {qos:?} must be rejected, got session {sid}")),
        }
        prop_assert_eq!(sched.live_tenants(), 0usize, "local slot leaked");
        Ok(())
    });

    // No wire-side tenant slot leaked either.
    let stats = client.stats(None).expect("frontend stats");
    let live: usize = stats.shard_tenants.expect("frontend scope").iter().sum();
    assert_eq!(live, 0, "rejected admissions must not leak wire sessions");
    client.shutdown().expect("shutdown");
    server.join().expect("serve thread").expect("clean shutdown");
}

#[test]
fn snapshot_restore_replay_bit_identical_across_servers() {
    // The cluster primitive: for random tenants, consume k rounds on
    // server A, fetch the session's SessionSnapshot over the wire,
    // restore it on an INDEPENDENT server B (different shard count,
    // fresh schedulers), and drive both forward. Every subsequent round
    // must be bit-identical on A, on B, and on a dedicated in-process
    // engine — the statement that a session is a serializable value a
    // balancer can move between hosts without touching votes.
    forall("snapshot → restore ≡ uninterrupted (random tenants over TCP)", 6, |g| {
        let (addr_a, server_a) = spawn_server(AggFrontend::new(g.usize_range(1, 3), 1));
        let (addr_b, server_b) = spawn_server(AggFrontend::new(g.usize_range(1, 3), 1));
        let mut ca = ServiceClient::connect(&addr_a).map_err(|e| e.to_string())?;
        let mut cb = ServiceClient::connect(&addr_b).map_err(|e| e.to_string())?;

        let cfg = rand_cfg(g);
        let d = g.usize_range(1, 24);
        let seed = g.u64();
        let sid_a = ca
            .open_session(cfg, d, seed, QosPolicy::unlimited())
            .map_err(|e| format!("open: {e}"))?;
        let mut dedicated = PipelinedEngine::new(cfg, d, seed);

        let consumed = g.usize_range(0, 3) as u64;
        for _ in 0..consumed {
            let signs: Vec<Vec<i8>> = (0..cfg.n).map(|_| g.sign_vec(d)).collect();
            let reply =
                ca.submit_round(sid_a, &signs).map_err(|e| format!("pre-round: {e}"))?;
            let local = dedicated.run_round(&signs);
            prop_assert_eq!(&reply.global_vote, &local.global_vote, "pre-snapshot round");
        }

        let snap = ca.snapshot_session(sid_a).map_err(|e| format!("snapshot: {e}"))?;
        prop_assert_eq!(snap.rounds, consumed, "snapshot counts consumed rounds");
        prop_assert_eq!(snap.seed, seed);
        let sid_b = cb.restore_session(&snap).map_err(|e| format!("restore: {e}"))?;

        for round in 0..2u64 {
            let signs: Vec<Vec<i8>> = (0..cfg.n).map(|_| g.sign_vec(d)).collect();
            let ra = ca.submit_round(sid_a, &signs).map_err(|e| format!("A round: {e}"))?;
            let rb = cb.submit_round(sid_b, &signs).map_err(|e| format!("B round: {e}"))?;
            let local = dedicated.run_round(&signs);
            prop_assert_eq!(&ra.global_vote, &rb.global_vote, "post-restore round {round}");
            prop_assert_eq!(&ra.subgroup_votes, &rb.subgroup_votes, "round {round} subgroups");
            prop_assert_eq!(&ra.global_vote, &local.global_vote, "round {round} vs dedicated");
            prop_assert_eq!(
                &ra.global_vote,
                &plain_hierarchical_vote(&signs, cfg),
                "round {round} vs Eq. 8"
            );
        }
        // Counter continuity: the restored session reports the full
        // history, not just the rounds it ran locally.
        let stats_b = cb.stats(Some(sid_b)).map_err(|e| format!("stats: {e}"))?;
        prop_assert_eq!(stats_b.rounds_run, consumed + 2, "restored counters continue");

        for (c, s) in [(&mut ca, server_a), (&mut cb, server_b)] {
            c.shutdown().map_err(|e| format!("shutdown: {e}"))?;
            s.join()
                .map_err(|_| "serve thread panicked".to_string())?
                .map_err(|e| format!("serve loop: {e}"))?;
        }
        Ok(())
    });
}

/// A random snapshot exercising every field the codecs must preserve:
/// fractional QoS rates, optional fields on both sides of `None`, and a
/// full-range `rounds` fast-forward distance.
fn rand_snapshot(g: &mut Gen) -> SessionSnapshot {
    SessionSnapshot {
        cfg: rand_cfg(g),
        d: g.usize_range(1, 40),
        seed: g.u64(),
        qos: QosPolicy {
            weight: 1 + g.usize_range(0, 8) as u32,
            queue_depth: if g.bool() { Some(g.usize_range(1, 64)) } else { None },
            rounds_per_sec: if g.bool() { Some(g.f64() * 100.0 + 0.5) } else { None },
            triples_per_sec: if g.bool() { Some(g.f64() * 1e6 + 1.0) } else { None },
            burst_rounds: 1.0 + g.f64() * 7.0,
        },
        rounds: g.u64(),
    }
}

#[test]
fn session_snapshots_round_trip_bit_identically_through_both_codecs() {
    // The snapshot is the cluster's fail-over/rebuild currency (balancer
    // restores, host re-join reconciliation, table rebuild — see
    // `service::faults`), so BOTH codecs must preserve it bit-identically,
    // including `qos` and `rounds`, in the request that ships it and the
    // reply that returns it.
    forall("SessionSnapshot ≡ decode∘encode in both codecs", 48, |g| {
        let snap = rand_snapshot(g);
        let req = Request::SessionRestore { snapshot: snap.clone(), codec: None };
        let resp = Response::Snapshot(hisafe::service::SnapshotReply {
            session: SessionId::new(g.u64()),
            snapshot: snap.clone(),
        });

        // v1 JSON: value → compact text → parse → value.
        let text = req.to_json().to_string_compact();
        let back = Request::from_json(&parse(&text).map_err(|e| format!("parse: {e:?}"))?)
            .map_err(|e| format!("decode: {e:?}"))?;
        match back {
            Request::SessionRestore { snapshot, .. } => {
                prop_assert_eq!(&snapshot, &snap, "JSON request trip, wire text {text}");
            }
            other => return Err(format!("wrong request decoded: {other:?}")),
        }
        let text = resp.to_json().to_string_compact();
        let back = Response::from_json(&parse(&text).map_err(|e| format!("parse: {e:?}"))?)
            .map_err(|e| format!("decode: {e:?}"))?;
        match back {
            Response::Snapshot(r) => {
                prop_assert_eq!(&r.snapshot, &snap, "JSON reply trip, wire text {text}");
            }
            other => return Err(format!("wrong response decoded: {other:?}")),
        }

        // v2 binary: value → payload bytes → value.
        let back = binary::decode_request(&binary::encode_request(&req))
            .map_err(|e| format!("binary decode: {e:?}"))?;
        match back {
            Request::SessionRestore { snapshot, .. } => {
                prop_assert_eq!(&snapshot, &snap, "binary request trip");
            }
            other => return Err(format!("wrong request decoded: {other:?}")),
        }
        let back = binary::decode_response(&binary::encode_response(&resp))
            .map_err(|e| format!("binary decode: {e:?}"))?;
        match back {
            Response::Snapshot(r) => {
                prop_assert_eq!(&r.snapshot, &snap, "binary reply trip");
            }
            other => return Err(format!("wrong response decoded: {other:?}")),
        }
        Ok(())
    });
}

#[test]
fn restores_from_round_tripped_snapshots_replay_identically() {
    // Deeper than value equality: a snapshot that crossed either codec
    // must *restore* into the same dealer-stream position — the rounds
    // after the restore are bit-identical to the uninterrupted session,
    // to a dedicated engine, and to the plaintext reference.
    forall("restore(roundtrip(snap)) ≡ uninterrupted", 6, |g| {
        let (addr_a, server_a) = spawn_server(AggFrontend::new(g.usize_range(1, 3), 1));
        let (addr_b, server_b) = spawn_server(AggFrontend::new(g.usize_range(1, 3), 1));
        let mut ca = ServiceClient::connect(&addr_a).map_err(|e| e.to_string())?;
        let mut cb = ServiceClient::connect(&addr_b).map_err(|e| e.to_string())?;

        let cfg = rand_cfg(g);
        let d = g.usize_range(1, 16);
        let seed = g.u64();
        let sid_a = ca
            .open_session(cfg, d, seed, QosPolicy::unlimited())
            .map_err(|e| format!("open: {e}"))?;
        let mut dedicated = PipelinedEngine::new(cfg, d, seed);
        let consumed = g.usize_range(1, 3) as u64;
        for _ in 0..consumed {
            let signs: Vec<Vec<i8>> = (0..cfg.n).map(|_| g.sign_vec(d)).collect();
            let reply =
                ca.submit_round(sid_a, &signs).map_err(|e| format!("pre-round: {e}"))?;
            let local = dedicated.run_round(&signs);
            prop_assert_eq!(&reply.global_vote, &local.global_vote, "pre-snapshot round");
        }
        let snap = ca.snapshot_session(sid_a).map_err(|e| format!("snapshot: {e}"))?;

        // Ship the snapshot through each codec before restoring it.
        let restore = Request::SessionRestore { snapshot: snap.clone(), codec: None };
        let via_json = match Request::from_json(
            &parse(&restore.to_json().to_string_compact())
                .map_err(|e| format!("parse: {e:?}"))?,
        )
        .map_err(|e| format!("decode: {e:?}"))?
        {
            Request::SessionRestore { snapshot, .. } => snapshot,
            other => return Err(format!("wrong request decoded: {other:?}")),
        };
        let via_bin = match binary::decode_request(&binary::encode_request(&restore))
            .map_err(|e| format!("binary decode: {e:?}"))?
        {
            Request::SessionRestore { snapshot, .. } => snapshot,
            other => return Err(format!("wrong request decoded: {other:?}")),
        };
        prop_assert_eq!(&via_json, &snap, "JSON trip preserved the snapshot");
        prop_assert_eq!(&via_bin, &snap, "binary trip preserved the snapshot");

        let sid_json = cb.restore_session(&via_json).map_err(|e| format!("restore: {e}"))?;
        let sid_bin = cb.restore_session(&via_bin).map_err(|e| format!("restore: {e}"))?;
        for round in 0..2u64 {
            let signs: Vec<Vec<i8>> = (0..cfg.n).map(|_| g.sign_vec(d)).collect();
            let ra = ca.submit_round(sid_a, &signs).map_err(|e| format!("A round: {e}"))?;
            let rj =
                cb.submit_round(sid_json, &signs).map_err(|e| format!("json round: {e}"))?;
            let rb = cb.submit_round(sid_bin, &signs).map_err(|e| format!("bin round: {e}"))?;
            let local = dedicated.run_round(&signs);
            prop_assert_eq!(&ra.global_vote, &rj.global_vote, "round {round} via JSON");
            prop_assert_eq!(&ra.global_vote, &rb.global_vote, "round {round} via binary");
            prop_assert_eq!(&ra.subgroup_votes, &rj.subgroup_votes, "round {round} subgroups");
            prop_assert_eq!(&ra.subgroup_votes, &rb.subgroup_votes, "round {round} subgroups");
            prop_assert_eq!(&ra.global_vote, &local.global_vote, "round {round} vs dedicated");
        }
        // Continuity survives the codec trip too.
        let stats = cb.stats(Some(sid_json)).map_err(|e| format!("stats: {e}"))?;
        prop_assert_eq!(stats.rounds_run, consumed + 2, "restored counters continue");

        for (c, s) in [(&mut ca, server_a), (&mut cb, server_b)] {
            c.shutdown().map_err(|e| format!("shutdown: {e}"))?;
            s.join()
                .map_err(|_| "serve thread panicked".to_string())?
                .map_err(|e| format!("serve loop: {e}"))?;
        }
        Ok(())
    });
}

#[test]
fn killing_a_shard_mid_sweep_recovers_with_bit_identical_votes() {
    // Shard-death recovery as a property: random tenants spread over a
    // multi-shard frontend, a random shard killed mid-sweep (the same
    // state a poisoned shard lock degrades to), and every session —
    // displaced or not — must finish the sweep with votes bit-identical
    // to dedicated engines, with no panic and no lost session.
    forall("kill a shard mid-sweep ⇒ transparent restore", 6, |g| {
        let shards = g.usize_range(2, 4);
        let fe = AggFrontend::new(shards, 1);

        struct Tenant {
            cfg: HiSafeConfig,
            d: usize,
            sid: SessionId,
            dedicated: PipelinedEngine,
        }
        let n_tenants = g.usize_range(2, 5);
        let mut tenants: Vec<Tenant> = Vec::with_capacity(n_tenants);
        for _ in 0..n_tenants {
            let cfg = rand_cfg(g);
            let d = g.usize_range(1, 16);
            let seed = g.u64();
            let sid = match fe.handle(&Request::SessionOpen {
                cfg,
                d,
                seed,
                qos: QosPolicy::unlimited(),
                codec: None,
            }) {
                Response::Admission(AdmissionReply { session: Some(sid), error: None, .. }) => sid,
                other => return Err(format!("open rejected: {other:?}")),
            };
            tenants.push(Tenant { cfg, d, sid, dedicated: PipelinedEngine::new(cfg, d, seed) });
        }

        let kill_at = g.usize_range(0, 2) as u64; // round before which the shard dies
        let victim = g.usize_range(0, shards - 1);
        for round in 0..3u64 {
            if round == kill_at {
                fe.kill_shard(victim);
            }
            for &ti in &rand_order(g, n_tenants) {
                let t = &mut tenants[ti];
                let signs: Vec<Vec<i8>> = (0..t.cfg.n).map(|_| g.sign_vec(t.d)).collect();
                let reply = match fe.handle(&Request::RoundSubmit {
                    session: t.sid,
                    signs: signs.clone(),
                    present: None,
                }) {
                    Response::Vote(v) => v,
                    other => {
                        return Err(format!(
                            "tenant {ti} round {round} after shard kill: {other:?}"
                        ))
                    }
                };
                let local = t.dedicated.run_round(&signs);
                prop_assert_eq!(
                    &reply.global_vote,
                    &local.global_vote,
                    "tenant {ti} round {round} (shard {victim} killed at {kill_at})"
                );
                prop_assert_eq!(
                    &reply.subgroup_votes,
                    &local.subgroup_votes,
                    "tenant {ti} round {round} subgroups"
                );
            }
        }
        // No session lost, the dead shard reports no tenants, and every
        // session still answers stats with full counter continuity.
        prop_assert_eq!(fe.live_sessions(), n_tenants, "no session lost to the kill");
        prop_assert_eq!(fe.shard_tenants()[victim], 0usize, "dead shard holds nothing");
        for (ti, t) in tenants.iter().enumerate() {
            match fe.handle(&Request::StatsQuery { session: Some(t.sid) }) {
                Response::Stats(s) => {
                    prop_assert_eq!(s.rounds_run, 3u64, "tenant {ti} counters continue")
                }
                other => return Err(format!("tenant {ti} stats: {other:?}")),
            }
        }
        Ok(())
    });
}

#[test]
fn quantized_wire_rounds_bit_identical_across_codecs_and_reference() {
    // Quantization over the wire: a guaranteed q > 2 tenant and a q = 2
    // sibling drive the same loopback server from a binary-negotiated
    // client and a plain v1 JSON client. The packed b-bit binary
    // payloads and the JSON char-per-level strings must decode to the
    // same votes —
    // equal to a dedicated engine and the q-level plaintext reference —
    // on full-present and churned rounds alike.
    forall("wire q-level ≡ plain_quant_aggregate (both codecs)", 5, |g| {
        let (addr, server) = spawn_server(AggFrontend::new(g.usize_range(1, 3), 1));
        let mut bin = ServiceClient::connect_with_codec(&addr, Codec::Binary)
            .map_err(|e| e.to_string())?;
        let mut v1 = ServiceClient::connect(&addr).map_err(|e| e.to_string())?;
        prop_assert_eq!(bin.codec(), Codec::Binary, "binary server must ack the ask");
        prop_assert_eq!(v1.codec(), Codec::Json, "a client that never asks stays on v1");

        for q in [hisafe::quant::PRECISIONS[g.usize_range(1, 3)], 2u8] {
            let ell = g.usize_range(1, 3);
            let n1 = g.usize_range(2, 4); // n₁ ≥ 2 ⇒ one dropout always survives
            let intra = if g.bool() { TiePolicy::OneBit } else { TiePolicy::TwoBit };
            let inter = if g.bool() { TiePolicy::OneBit } else { TiePolicy::TwoBit };
            let cfg = HiSafeConfig {
                n: ell * n1,
                ell,
                intra,
                inter,
                sparse: g.bool(),
                precision: q,
            };
            let d = g.usize_range(1, 12);
            let seed = g.u64();
            let sid_b = bin
                .open_session(cfg, d, seed, QosPolicy::unlimited())
                .map_err(|e| format!("q={q} open bin: {e}"))?;
            let sid_j = v1
                .open_session(cfg, d, seed, QosPolicy::unlimited())
                .map_err(|e| format!("q={q} open v1: {e}"))?;
            let mut dedicated = PipelinedEngine::new(cfg, d, seed);

            for round in 0..3u64 {
                let signs: Vec<Vec<i8>> = (0..cfg.n).map(|_| level_vec(g, q, d)).collect();
                if round == 1 {
                    // The churned round: one dropout, both codecs, and
                    // the dedicated engine advances over the same set so
                    // the triple streams stay in lockstep.
                    let mut mask = vec![true; cfg.n];
                    mask[g.usize_range(0, cfg.n - 1)] = false;
                    let present = ParticipantSet::from_mask(mask.clone());
                    let rb = bin
                        .submit_round_present(sid_b, &signs, &mask)
                        .map_err(|e| format!("q={q} churned bin: {e:?}"))?;
                    let rj = v1
                        .submit_round_present(sid_j, &signs, &mask)
                        .map_err(|e| format!("q={q} churned v1: {e:?}"))?;
                    let local = dedicated
                        .run_round_present(&signs, &present)
                        .expect("one dropout stays above threshold for n1 >= 2");
                    prop_assert_eq!(&rb, &rj, "q={q} churned binary vs JSON");
                    prop_assert_eq!(
                        &rb.global_vote,
                        &local.global_vote,
                        "q={q} churned vs dedicated cfg={cfg:?}"
                    );
                    prop_assert_eq!(
                        &rb.global_vote,
                        &plain_quant_aggregate_present(&signs, &present, cfg),
                        "q={q} churned vs survivor plaintext mask={mask:?}"
                    );
                } else {
                    let rb = bin
                        .submit_round(sid_b, &signs)
                        .map_err(|e| format!("q={q} round {round} bin: {e:?}"))?;
                    let rj = v1
                        .submit_round(sid_j, &signs)
                        .map_err(|e| format!("q={q} round {round} v1: {e:?}"))?;
                    let local = dedicated.run_round(&signs);
                    prop_assert_eq!(&rb, &rj, "q={q} round {round} binary vs JSON");
                    prop_assert_eq!(
                        &rb.global_vote,
                        &local.global_vote,
                        "q={q} round {round} vs dedicated cfg={cfg:?}"
                    );
                    prop_assert_eq!(
                        &rb.subgroup_votes,
                        &local.subgroup_votes,
                        "q={q} round {round} subgroups"
                    );
                    prop_assert_eq!(
                        &rb.global_vote,
                        &plain_quant_aggregate(&signs, cfg),
                        "q={q} round {round} vs plaintext reference"
                    );
                }
            }
            bin.close_session(sid_b).map_err(|e| format!("q={q} close bin: {e}"))?;
            v1.close_session(sid_j).map_err(|e| format!("q={q} close v1: {e}"))?;
        }
        drop(bin); // the serve loop only exits once every connection is gone
        v1.shutdown().map_err(|e| format!("shutdown: {e}"))?;
        server
            .join()
            .map_err(|_| "serve thread panicked".to_string())?
            .map_err(|e| format!("serve loop: {e}"))?;
        Ok(())
    });
}

#[test]
fn cross_codec_sessions_negotiate_correctly_and_votes_are_bit_identical() {
    // Codec interop as a property: the wire format a connection lands on
    // is pure transport. Three clients drive the SAME (cfg, d, seed)
    // session shape with the same signs and churn masks every round:
    //
    //   1. a binary-wanting client on a binary-capable server — the
    //      SessionOpen ask is acked and the connection negotiates up;
    //   2. a plain v1 client on that same server — never asks, stays on
    //      newline-delimited JSON for the connection's whole life;
    //   3. a binary-wanting client on a `with_codec(Json)` server — the
    //      ask is ignored and the connection stays on v1.
    //
    // Completed rounds must be bit-identical across all three paths and
    // to the survivor-plaintext reference; a below-threshold mask must
    // surface the SAME typed `ChurnBelowThreshold` on every path.
    forall("codec negotiation ⇒ bit-identical votes (incl. churn)", 4, |g| {
        let (addr_bin, server_bin) = spawn_server(AggFrontend::new(g.usize_range(1, 3), 1));
        let server_json = ServiceServer::bind("127.0.0.1:0", AggFrontend::new(1, 1))
            .expect("bind loopback")
            .with_codec(Codec::Json);
        let addr_json = server_json.local_addr().expect("bound addr").to_string();
        let handle_json = std::thread::spawn(move || server_json.serve());

        let mut up = ServiceClient::connect_with_codec(&addr_bin, Codec::Binary)
            .map_err(|e| e.to_string())?;
        let mut v1 = ServiceClient::connect(&addr_bin).map_err(|e| e.to_string())?;
        let mut down = ServiceClient::connect_with_codec(&addr_json, Codec::Binary)
            .map_err(|e| e.to_string())?;

        let cfg = rand_cfg(g);
        let d = g.usize_range(1, 24);
        let seed = g.u64();
        let sid_up = up
            .open_session(cfg, d, seed, QosPolicy::unlimited())
            .map_err(|e| format!("open up: {e}"))?;
        let sid_v1 = v1
            .open_session(cfg, d, seed, QosPolicy::unlimited())
            .map_err(|e| format!("open v1: {e}"))?;
        let sid_down = down
            .open_session(cfg, d, seed, QosPolicy::unlimited())
            .map_err(|e| format!("open down: {e}"))?;

        prop_assert_eq!(up.codec(), Codec::Binary, "binary server must ack the ask");
        prop_assert_eq!(v1.codec(), Codec::Json, "a client that never asks stays on v1");
        prop_assert_eq!(down.codec(), Codec::Json, "a JSON-policy server never acks");

        let names = ["negotiated-up", "plain-json", "negotiated-down"];
        let mut completed = 0u64;
        for round in 0..3u64 {
            let signs: Vec<Vec<i8>> = (0..cfg.n).map(|_| g.sign_vec(d)).collect();
            let mask: Vec<bool> = (0..cfg.n).map(|_| g.usize_range(0, 3) > 0).collect();
            let present = ParticipantSet::from_mask(mask.clone());
            let results = [
                up.submit_round_present(sid_up, &signs, &mask),
                v1.submit_round_present(sid_v1, &signs, &mask),
                down.submit_round_present(sid_down, &signs, &mask),
            ];
            match check_thresholds(cfg, &present) {
                Ok(()) => {
                    completed += 1;
                    let reference = run_sync_with_dropouts(&signs, &present, cfg, seed ^ round)
                        .expect("thresholds hold, so the reference completes");
                    let mut replies = Vec::with_capacity(names.len());
                    for (name, r) in names.iter().zip(results) {
                        let reply = r.map_err(|e| format!("{name} round {round}: {e:?}"))?;
                        prop_assert_eq!(
                            &reply.global_vote,
                            &reference.global_vote,
                            "{name} round {round} cfg={cfg:?}"
                        );
                        prop_assert_eq!(
                            &reply.subgroup_votes,
                            &reference.subgroup_votes,
                            "{name} round {round} subgroups"
                        );
                        replies.push(reply);
                    }
                    // The three wire replies are one value: stats and
                    // votes identical coordinate-for-coordinate.
                    prop_assert_eq!(&replies[0], &replies[1], "round {round} up vs v1");
                    prop_assert_eq!(&replies[0], &replies[2], "round {round} up vs down");
                    prop_assert_eq!(
                        &replies[0].global_vote,
                        &plain_hierarchical_vote_present(&signs, &present, cfg),
                        "round {round} vs survivor plaintext"
                    );
                }
                Err(ref expected) => {
                    for (name, r) in names.iter().zip(results) {
                        match r {
                            Err(Error::Admission(AdmissionError::ChurnBelowThreshold {
                                group,
                                survivors,
                                required,
                            })) => prop_assert_eq!(
                                &ChurnError::BelowThreshold { group, survivors, required },
                                expected,
                                "{name} round {round} abort identity"
                            ),
                            Ok(_) => {
                                return Err(format!(
                                    "{name} round {round}: mask {mask:?} violates thresholds \
                                     but the round completed"
                                ))
                            }
                            Err(e) => {
                                return Err(format!(
                                    "{name} round {round}: expected typed churn abort, \
                                     got {e:?}"
                                ))
                            }
                        }
                    }
                }
            }
        }

        // Counter continuity is codec-independent too.
        for (name, (c, sid)) in names.iter().zip([
            (&mut up, sid_up),
            (&mut v1, sid_v1),
            (&mut down, sid_down),
        ]) {
            let stats = c.stats(Some(sid)).map_err(|e| format!("{name} stats: {e}"))?;
            prop_assert_eq!(stats.rounds_run, completed, "{name} round counter");
            c.close_session(sid).map_err(|e| format!("{name} close: {e}"))?;
        }
        v1.shutdown().map_err(|e| format!("shutdown bin server: {e}"))?;
        down.shutdown().map_err(|e| format!("shutdown json server: {e}"))?;
        for (s, which) in [(server_bin, "binary"), (handle_json, "json")] {
            s.join()
                .map_err(|_| format!("{which} serve thread panicked"))?
                .map_err(|e| format!("{which} serve loop: {e}"))?;
        }
        Ok(())
    });
}
