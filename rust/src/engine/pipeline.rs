//! The single-tenant pipelined engine — a thin wrapper over a private
//! one-session [`AggScheduler`].
//!
//! Historically this file owned the whole pipelined round scheduler: a
//! dedicated background `Provisioner` thread plus a per-engine
//! [`WorkerPool`](super::workers::WorkerPool). That machinery now lives
//! in [`super::scheduler`], generalized to many tenants; what remains
//! here is the convenient "one engine, own infrastructure" construction
//! the FL trainer's single-federation path and the benches use:
//!
//! ```text
//!            round r                round r+1              round r+2
//! online   │ evaluate(r)          │ evaluate(r+1)        │ evaluate(r+2)
//! offline  │ deal triples(r+1)    │ deal triples(r+2)    │ deal …
//! ```
//!
//! Semantics are unchanged from the pre-scheduler engine: dealing for
//! round `r+1` overlaps round `r`'s online phase, evaluation runs on a
//! persistent worker pool, and votes are bit-identical to `run_sync` and
//! the sequential [`super::RoundEngine`] (each group's dealer is seeded
//! with [`crate::protocol::group_dealer_seed`], the provisioning plane
//! advances each per-group stream strictly in round order, and pools
//! refill a whole round at a time). `rust/tests/engine_props.rs` pins
//! all of it; `rust/tests/sched_props.rs` additionally pins this wrapper
//! bit-identical to scheduler sessions under tenant interleaving.
//!
//! To share infrastructure between several engines instead, construct
//! them on one scheduler via [`PipelinedEngine::on_scheduler`] — or use
//! [`AggScheduler::session`] directly.
//!
//! Pipelined engines always run under the unlimited
//! [`QosPolicy`](super::QosPolicy) (the session default): the
//! single-tenant wrapper predates admission control and keeps its
//! infallible, rate-limiter-exempt semantics. Tenants that want bounded
//! queues, rate budgets, or dealing weights use
//! [`AggScheduler::try_session`](super::AggScheduler::try_session).

use crate::mpc::EvalPlan;
use crate::protocol::{ChurnError, HiSafeConfig, ParticipantSet};

use super::scheduler::{AggScheduler, AggSession};
use super::{Engine, EngineOutcome};

/// Pipelined Hi-SAFE aggregation engine: the [`super::RoundEngine`]
/// arithmetic (bit-identical votes) scheduled so the offline phase of
/// round `r+1` overlaps the online phase of round `r`, with evaluation
/// on a persistent worker pool instead of per-round thread spawns. Since
/// the multi-tenant refactor this is exactly one [`AggSession`] on a
/// private [`AggScheduler`]; the FL trainer's single-federation path
/// runs through it, and the sequential `RoundEngine` remains the
/// reference.
pub struct PipelinedEngine {
    session: AggSession,
    /// Rounds executed so far (kept as a public field for callers that
    /// predate the [`Engine`] trait).
    pub rounds_run: u64,
}

impl PipelinedEngine {
    /// Build a pipelined engine with its own private scheduler (one
    /// worker pool + one provisioning plane serving this engine alone).
    /// `seed` drives all offline randomness, one independent stream per
    /// subgroup (same derivation as [`crate::protocol::run_sync`]).
    ///
    /// Dealing for the first round starts immediately on the background
    /// plane, so caller-side work before the first `run_round` (gradient
    /// computation, say) already overlaps the offline phase.
    pub fn new(cfg: HiSafeConfig, d: usize, seed: u64) -> PipelinedEngine {
        Self::on_scheduler(&AggScheduler::new(), cfg, d, seed)
    }

    /// Build the engine as one tenant of `sched` — several engines built
    /// this way share one worker pool and one provisioning plane instead
    /// of spawning their own. Tests also use this with
    /// [`AggScheduler::with_threads`] to pin `threads = 1`
    /// deterministically.
    pub fn on_scheduler(
        sched: &AggScheduler,
        cfg: HiSafeConfig,
        d: usize,
        seed: u64,
    ) -> PipelinedEngine {
        PipelinedEngine { session: sched.session(cfg, d, seed), rounds_run: 0 }
    }

    /// Test-only view of the session (e.g. for pool audits).
    #[cfg(test)]
    pub(crate) fn session_mut(&mut self) -> &mut AggSession {
        &mut self.session
    }
}

impl Engine for PipelinedEngine {
    fn with_chunk(mut self, chunk: usize) -> PipelinedEngine {
        self.session = self.session.with_chunk(chunk);
        self
    }

    fn with_batch_rounds(mut self, rounds: usize) -> PipelinedEngine {
        self.session = self.session.with_batch_rounds(rounds);
        self
    }

    fn plan(&self) -> &EvalPlan {
        self.session.plan()
    }

    fn provisioned_rounds(&self) -> usize {
        self.session.provisioned_rounds()
    }

    fn provision(&mut self, rounds: usize) {
        self.session.provision(rounds);
    }

    fn run_round(&mut self, signs: &[Vec<i8>]) -> EngineOutcome {
        let out = self.session.run_round(signs);
        self.rounds_run = self.session.rounds_run();
        out
    }

    fn run_round_present(
        &mut self,
        signs: &[Vec<i8>],
        present: &ParticipantSet,
    ) -> Result<EngineOutcome, ChurnError> {
        let out = self.session.run_round_present(signs, present)?;
        self.rounds_run = self.session.rounds_run();
        Ok(out)
    }

    fn rounds_run(&self) -> u64 {
        self.rounds_run
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::beaver::Dealer;
    use crate::engine::RoundEngine;
    use crate::mpc::plain_group_vote;
    use crate::poly::TiePolicy;
    use crate::protocol::{group_dealer_seed, plain_hierarchical_vote};
    use crate::util::rng::{Rng, Xoshiro256pp};

    fn rand_signs(n: usize, d: usize, seed: u64) -> Vec<Vec<i8>> {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        (0..n).map(|_| (0..d).map(|_| rng.gen_sign()).collect()).collect()
    }

    #[test]
    fn pipelined_matches_sequential_multi_round() {
        let cfg = HiSafeConfig::hierarchical(12, 4, TiePolicy::TwoBit);
        let mut seq = RoundEngine::new(cfg, 9, 11);
        let mut piped = PipelinedEngine::new(cfg, 9, 11);
        for r in 0..5u64 {
            let signs = rand_signs(12, 9, 40 + r);
            let a = seq.run_round(&signs);
            let b = piped.run_round(&signs);
            assert_eq!(a.global_vote, b.global_vote, "round {r}");
            assert_eq!(a.subgroup_votes, b.subgroup_votes, "round {r}");
            assert_eq!(a.stats, b.stats, "round {r}");
            assert_eq!(b.global_vote, plain_hierarchical_vote(&signs, cfg), "round {r}");
        }
        assert_eq!(piped.rounds_run, 5);
    }

    #[test]
    fn pipelined_handles_zero_mult_plans() {
        // n₁ = 1 makes the vote polynomial the identity — no triples, no
        // provisioning, and the scheduler must not block waiting on any.
        let cfg = HiSafeConfig::flat(1, TiePolicy::OneBit);
        let mut engine = PipelinedEngine::new(cfg, 7, 3);
        let signs = rand_signs(1, 7, 9);
        let got = engine.run_round(&signs);
        assert_eq!(got.global_vote, plain_group_vote(&signs, TiePolicy::OneBit));
    }

    #[test]
    fn explicit_provision_moves_dealing_off_the_round_path() {
        let cfg = HiSafeConfig::hierarchical(8, 2, TiePolicy::OneBit);
        let mut engine = PipelinedEngine::new(cfg, 4, 13);
        engine.provision(3);
        assert!(engine.provisioned_rounds() >= 3);
        let signs = rand_signs(8, 4, 21);
        let got = engine.run_round(&signs);
        assert_eq!(got.global_vote, plain_hierarchical_vote(&signs, cfg));
        assert!(engine.provisioned_rounds() >= 2);
    }

    #[test]
    fn chunk_and_batch_are_observationally_invisible() {
        let cfg = HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit);
        let signs = rand_signs(6, 23, 9);
        let baseline = plain_hierarchical_vote(&signs, cfg);
        for (chunk, batch) in [(1usize, 1usize), (3, 2), (64, 3)] {
            let got = PipelinedEngine::new(cfg, 23, 4)
                .with_chunk(chunk)
                .with_batch_rounds(batch)
                .run_round(&signs)
                .global_vote;
            assert_eq!(got, baseline, "chunk={chunk} batch={batch}");
        }
    }

    #[test]
    fn drop_with_inflight_batches_joins_cleanly() {
        let cfg = HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit);
        let mut engine = PipelinedEngine::new(cfg, 5, 1).with_batch_rounds(3);
        let signs = rand_signs(6, 5, 2);
        let _ = engine.run_round(&signs);
        // The prefetch batch may still be dealing; Drop must join, not hang.
        drop(engine);
    }

    #[test]
    fn wrapper_triple_streams_match_group_dealer_seed_derivation() {
        // Vote equality alone cannot pin the offline phase: Beaver masks
        // cancel exactly, so votes come out right under ANY triple
        // stream. This pins the wrapper's pooled triples to a dealer
        // seeded with `group_dealer_seed(seed, g)` (the run_sync
        // derivation); the multi-tenant variant of the same audit lives
        // in engine/scheduler.rs.
        let cfg = HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit);
        let d = 5;
        let seed = 77u64;
        let mut engine = PipelinedEngine::new(cfg, d, seed);
        let mults = engine.plan().triples_needed();
        assert!(mults > 0, "n₁=3 needs secure multiplications");
        let fp = engine.plan().fp;
        engine.provision(2);
        for g in 0..cfg.ell {
            let mut reference = Dealer::new(fp, group_dealer_seed(seed, g));
            for round in 0..2 {
                let expect = reference.gen_round(d, cfg.n1(), mults);
                for (party, expect_party) in expect.iter().enumerate() {
                    let got = engine
                        .session_mut()
                        .pools_mut()
                        .store_mut(g, party)
                        .take_many(mults);
                    assert_eq!(got.len(), mults);
                    for (t, e) in got.iter().zip(expect_party) {
                        assert_eq!(t.a, e.a, "g={g} party={party} round={round}");
                        assert_eq!(t.b, e.b, "g={g} party={party} round={round}");
                        assert_eq!(t.c, e.c, "g={g} party={party} round={round}");
                    }
                }
            }
        }
    }

    #[test]
    fn span_parallel_large_d_matches_reference() {
        let d = crate::engine::PAR_MIN_D + 61;
        let cfg = HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit);
        let signs = rand_signs(6, d, 41);
        let got = PipelinedEngine::new(cfg, d, 19).run_round(&signs);
        assert_eq!(got.global_vote, plain_hierarchical_vote(&signs, cfg));
    }

    #[test]
    fn engines_sharing_one_scheduler_match_dedicated_engines() {
        let sched = AggScheduler::with_threads(1);
        let cfg_a = HiSafeConfig::hierarchical(12, 4, TiePolicy::OneBit);
        let cfg_b = HiSafeConfig::flat(4, TiePolicy::TwoBit);
        let mut shared_a = PipelinedEngine::on_scheduler(&sched, cfg_a, 9, 5);
        let mut shared_b = PipelinedEngine::on_scheduler(&sched, cfg_b, 13, 6);
        let mut dedicated_a = PipelinedEngine::new(cfg_a, 9, 5);
        let mut dedicated_b = PipelinedEngine::new(cfg_b, 13, 6);
        for r in 0..3u64 {
            let signs_a = rand_signs(12, 9, 50 + r);
            let signs_b = rand_signs(4, 13, 60 + r);
            let sa = shared_a.run_round(&signs_a);
            let sb = shared_b.run_round(&signs_b);
            assert_eq!(sa.global_vote, dedicated_a.run_round(&signs_a).global_vote);
            assert_eq!(sb.global_vote, dedicated_b.run_round(&signs_b).global_vote);
        }
        assert_eq!(sched.worker_threads(), 1, "shared engines spawn no extra pools");
    }
}
