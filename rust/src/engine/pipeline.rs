//! The pipelined round scheduler: triple dealing overlapped with online
//! evaluation.
//!
//! The paper's offline/online split (Table V) exists so triple generation
//! never sits on the online critical path, yet the sequential
//! [`crate::engine::RoundEngine`] deals synchronously inside `run_round`
//! whenever the pool runs dry. [`PipelinedEngine`] moves dealing onto a
//! **background provisioning stage**:
//!
//! ```text
//!            round r                round r+1              round r+2
//! online   │ evaluate(r)          │ evaluate(r+1)        │ evaluate(r+2)
//! offline  │ deal triples(r+1)    │ deal triples(r+2)    │ deal …
//! ```
//!
//! Mechanics: [`GroupPools`] is the front buffer the scheduler consumes;
//! the [`Provisioner`] thread owns every group's [`Dealer`] and deals the
//! back buffer, handing completed [`RoundBatch`]es over an mpsc channel.
//! At the top of each round the scheduler absorbs finished batches,
//! blocks only if the front buffer cannot cover the round (the cold
//! start), and then — before evaluating — requests the next batch so
//! dealing proceeds *while* the span workers evaluate. Evaluation runs on
//! the persistent [`WorkerPool`], all groups' spans in flight at once.
//!
//! **Determinism.** Votes are bit-identical to `run_sync` and the
//! sequential engine: each group's dealer is seeded with
//! [`group_dealer_seed`] (the same derivation as
//! `protocol::run_sync`), the provisioner advances each per-group stream
//! strictly in round order, and pools are refilled a whole round at a
//! time — so party `i` of group `g` consumes exactly the triple sequence
//! it would have consumed synchronously, no matter how dealing and
//! evaluation interleave in wall-clock time. (The votes themselves are
//! triple-independent — Beaver recombination cancels the masks exactly —
//! so even transcript-level divergence could not change an outcome; the
//! aligned streams keep the stronger share-for-share property.)

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::beaver::{Dealer, TripleShare};
use crate::mpc::EvalPlan;
use crate::poly::MvPolynomial;
use crate::protocol::{group_dealer_seed, inter_group_vote, partition, HiSafeConfig};

use super::pool::{GroupPools, RoundBatch};
use super::workers::{span_split, worker_pool_threads, SpanJob, WorkerPool};
use super::{analytic_stats, EngineOutcome, DEFAULT_CHUNK};

/// Handle to the background dealing stage: a thread owning all per-group
/// dealers, a request channel ("deal `k` more rounds") and the handoff
/// channel delivering one [`RoundBatch`] per dealt round.
struct Provisioner {
    req_tx: Option<Sender<usize>>,
    dealt_rx: Receiver<RoundBatch>,
    handle: Option<JoinHandle<()>>,
}

impl Provisioner {
    fn spawn(mut dealers: Vec<Dealer>, d: usize, n1: usize, mults: usize) -> Provisioner {
        let (req_tx, req_rx) = channel::<usize>();
        let (dealt_tx, dealt_rx) = channel::<RoundBatch>();
        let handle = std::thread::spawn(move || {
            while let Ok(rounds) = req_rx.recv() {
                for _ in 0..rounds {
                    // Group order is fixed and each dealer only ever
                    // advances here, so per-group streams are identical
                    // to the synchronous engine's.
                    let batch: RoundBatch = dealers
                        .iter_mut()
                        .map(|dealer| dealer.gen_round(d, n1, mults))
                        .collect();
                    if dealt_tx.send(batch).is_err() {
                        return; // engine dropped mid-batch
                    }
                }
            }
        });
        Provisioner { req_tx: Some(req_tx), dealt_rx, handle: Some(handle) }
    }

    fn request(&self, rounds: usize) {
        self.req_tx
            .as_ref()
            .expect("provisioner queue open")
            .send(rounds)
            .expect("provisioner alive");
    }

    fn recv_round(&self) -> RoundBatch {
        self.dealt_rx.recv().expect("provisioner alive")
    }

    fn try_recv_round(&self) -> Option<RoundBatch> {
        self.dealt_rx.try_recv().ok()
    }
}

impl Drop for Provisioner {
    fn drop(&mut self) {
        // Closing the request channel ends the thread's recv loop; an
        // in-progress batch still sends fine (dealt_rx lives in self).
        drop(self.req_tx.take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Pipelined Hi-SAFE aggregation engine: the [`super::RoundEngine`]
/// arithmetic (bit-identical votes) scheduled so the offline phase of
/// round `r+1` overlaps the online phase of round `r`, with evaluation on
/// a persistent worker pool instead of per-round thread spawns. The FL
/// trainer's multi-round path runs through this engine; the sequential
/// `RoundEngine` remains the reference.
pub struct PipelinedEngine {
    cfg: HiSafeConfig,
    d: usize,
    plan: Arc<EvalPlan>,
    /// Front buffer: rounds ready to consume.
    pools: GroupPools,
    /// Back buffer: the background dealing stage.
    provisioner: Provisioner,
    workers: WorkerPool,
    /// Rounds per provisioning request (default 1 — the double buffer).
    batch_rounds: usize,
    /// Rounds requested from the provisioner but not yet absorbed.
    inflight_rounds: usize,
    chunk: usize,
    /// Rounds executed so far.
    pub rounds_run: u64,
}

impl PipelinedEngine {
    /// Build a pipelined engine for `cfg` over `d`-coordinate votes.
    /// `seed` drives all offline randomness, one independent stream per
    /// subgroup (same derivation as [`crate::protocol::run_sync`]).
    ///
    /// Dealing for the first round starts immediately on the background
    /// stage, so caller-side work before the first `run_round` (gradient
    /// computation, say) already overlaps the offline phase.
    pub fn new(cfg: HiSafeConfig, d: usize, seed: u64) -> PipelinedEngine {
        let n1 = cfg.n1();
        let mv = MvPolynomial::build_fermat(n1, cfg.intra);
        let plan = Arc::new(EvalPlan::new(&mv, d, cfg.sparse));
        let dealers: Vec<Dealer> = (0..cfg.ell)
            .map(|g| Dealer::new(plan.fp, group_dealer_seed(seed, g)))
            .collect();
        let mults = plan.triples_needed();
        let provisioner = Provisioner::spawn(dealers, d, n1, mults);
        let workers = WorkerPool::new(worker_pool_threads());
        let mut engine = PipelinedEngine {
            cfg,
            d,
            plan,
            pools: GroupPools::new(cfg.ell, n1),
            provisioner,
            workers,
            batch_rounds: 1,
            inflight_rounds: 0,
            chunk: DEFAULT_CHUNK,
            rounds_run: 0,
        };
        if mults > 0 {
            engine.request_batch();
        }
        engine
    }

    /// Override the SoA lane-chunk size (tests sweep this to prove chunk
    /// invariance; benches tune it).
    pub fn with_chunk(mut self, chunk: usize) -> PipelinedEngine {
        assert!(chunk >= 1, "chunk must be ≥ 1");
        self.chunk = chunk;
        self
    }

    /// Provision `rounds` rounds per background request (default 1).
    /// Larger batches amortize handoffs at the cost of pooled memory.
    pub fn with_batch_rounds(mut self, rounds: usize) -> PipelinedEngine {
        assert!(rounds >= 1, "batch must be ≥ 1");
        self.batch_rounds = rounds;
        self
    }

    /// The evaluation plan the engine executes (schedule, coefficients).
    pub fn plan(&self) -> &EvalPlan {
        &self.plan
    }

    /// Rounds' worth of triples currently in the front buffer (min across
    /// groups *and* parties; excludes in-flight background batches).
    pub fn provisioned_rounds(&self) -> usize {
        self.pools.provisioned_rounds(self.plan.triples_needed())
    }

    /// Synchronously fill the front buffer to at least `rounds` rounds —
    /// benches use this to move the offline phase out of the measured
    /// loop entirely (the paper's offline/online split, Table V).
    pub fn provision(&mut self, rounds: usize) {
        let mults = self.plan.triples_needed();
        if mults == 0 {
            return;
        }
        self.absorb_ready_batches();
        while self.pools.provisioned_rounds(mults) < rounds {
            if self.inflight_rounds == 0 {
                let missing = rounds - self.pools.provisioned_rounds(mults);
                self.provisioner.request(missing);
                self.inflight_rounds += missing;
            }
            self.recv_one_round();
        }
    }

    fn request_batch(&mut self) {
        self.provisioner.request(self.batch_rounds);
        self.inflight_rounds += self.batch_rounds;
    }

    fn recv_one_round(&mut self) {
        let batch = self.provisioner.recv_round();
        self.pools.refill_round(batch);
        self.inflight_rounds -= 1;
    }

    fn absorb_ready_batches(&mut self) {
        while let Some(batch) = self.provisioner.try_recv_round() {
            self.pools.refill_round(batch);
            self.inflight_rounds -= 1;
        }
    }

    /// Execute one Hi-SAFE aggregation round. `signs[i]` is user `i`'s ±1
    /// sign-gradient vector; users are partitioned into subgroups exactly
    /// like [`crate::protocol::run_sync`]. Votes are bit-identical to the
    /// sequential engine's and to `run_sync`'s.
    pub fn run_round(&mut self, signs: &[Vec<i8>]) -> EngineOutcome {
        assert_eq!(signs.len(), self.cfg.n, "need exactly n sign vectors");
        for (i, s) in signs.iter().enumerate() {
            assert_eq!(s.len(), self.d, "user {i} dimension mismatch");
        }
        let mults = self.plan.triples_needed();
        if mults > 0 {
            // Absorb whatever the background stage finished since the
            // last round, without blocking.
            self.absorb_ready_batches();
            // Cold start / catch-up: block until this round is covered.
            while self.pools.provisioned_rounds(mults) == 0 {
                if self.inflight_rounds == 0 {
                    self.request_batch();
                }
                self.recv_one_round();
            }
            // The overlap: keep a batch in flight so round r+1's triples
            // are dealt while this round's online phase evaluates below.
            if self.inflight_rounds == 0
                && self.pools.provisioned_rounds(mults) < 1 + self.batch_rounds
            {
                self.request_batch();
            }
        }

        let fp = self.plan.fp;
        let d = self.d;
        let n1 = self.cfg.n1();
        let groups = partition(self.cfg.n, self.cfg.ell);
        // Same split policy as the sequential engine; below PAR_MIN_D
        // one span per group still parallelizes across groups.
        let spans = span_split(d, self.workers.threads());
        let span_len = d.div_ceil(spans);

        let (out_tx, out_rx) = channel::<(usize, Vec<i8>)>();
        // slot -> (group, base, len); results reassemble by slot, so
        // worker completion order cannot affect the votes.
        let mut slots: Vec<(usize, usize, usize)> = Vec::new();
        for (g, members) in groups.iter().enumerate() {
            // Cloning the members' sign vectors makes the job 'static for
            // the persistent workers. The copy is n₁·d bytes per group
            // (~600 KB per round at n=24, d=25,450 — well under 1% of the
            // round's field work), the price of keeping `run_round`'s
            // borrow-based signature identical to the sequential engine's.
            let group_signs: Arc<Vec<Vec<i8>>> =
                Arc::new(members.iter().map(|&u| signs[u].clone()).collect());
            let triples: Arc<Vec<Vec<TripleShare>>> = Arc::new(if mults > 0 {
                self.pools.take_round_owned(g, mults)
            } else {
                vec![Vec::new(); n1]
            });
            let mut base = 0usize;
            while base < d {
                let len = span_len.min(d - base);
                let slot = slots.len();
                slots.push((g, base, len));
                self.workers.submit(SpanJob {
                    fp,
                    plan: Arc::clone(&self.plan),
                    signs: Arc::clone(&group_signs),
                    triples: Arc::clone(&triples),
                    base,
                    len,
                    chunk: self.chunk,
                    slot,
                    out: out_tx.clone(),
                });
                base += len;
            }
        }
        drop(out_tx);

        let mut subgroup_votes: Vec<Vec<i8>> = vec![vec![0i8; d]; groups.len()];
        for _ in 0..slots.len() {
            let (slot, span_votes) = out_rx.recv().expect("span worker alive");
            let (g, b, len) = slots[slot];
            subgroup_votes[g][b..b + len].copy_from_slice(&span_votes);
        }

        let global_vote = inter_group_vote(&subgroup_votes, self.cfg.inter);
        let stats = analytic_stats(&self.cfg, &self.plan, d);
        self.rounds_run += 1;
        EngineOutcome { global_vote, subgroup_votes, stats }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::RoundEngine;
    use crate::mpc::plain_group_vote;
    use crate::poly::TiePolicy;
    use crate::protocol::plain_hierarchical_vote;
    use crate::util::rng::{Rng, Xoshiro256pp};

    fn rand_signs(n: usize, d: usize, seed: u64) -> Vec<Vec<i8>> {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        (0..n).map(|_| (0..d).map(|_| rng.gen_sign()).collect()).collect()
    }

    #[test]
    fn pipelined_matches_sequential_multi_round() {
        let cfg = HiSafeConfig::hierarchical(12, 4, TiePolicy::TwoBit);
        let mut seq = RoundEngine::new(cfg, 9, 11);
        let mut piped = PipelinedEngine::new(cfg, 9, 11);
        for r in 0..5u64 {
            let signs = rand_signs(12, 9, 40 + r);
            let a = seq.run_round(&signs);
            let b = piped.run_round(&signs);
            assert_eq!(a.global_vote, b.global_vote, "round {r}");
            assert_eq!(a.subgroup_votes, b.subgroup_votes, "round {r}");
            assert_eq!(a.stats, b.stats, "round {r}");
            assert_eq!(b.global_vote, plain_hierarchical_vote(&signs, cfg), "round {r}");
        }
        assert_eq!(piped.rounds_run, 5);
    }

    #[test]
    fn pipelined_handles_zero_mult_plans() {
        // n₁ = 1 makes the vote polynomial the identity — no triples, no
        // provisioning, and the scheduler must not block waiting on any.
        let cfg = HiSafeConfig::flat(1, TiePolicy::OneBit);
        let mut engine = PipelinedEngine::new(cfg, 7, 3);
        let signs = rand_signs(1, 7, 9);
        let got = engine.run_round(&signs);
        assert_eq!(got.global_vote, plain_group_vote(&signs, TiePolicy::OneBit));
    }

    #[test]
    fn explicit_provision_moves_dealing_off_the_round_path() {
        let cfg = HiSafeConfig::hierarchical(8, 2, TiePolicy::OneBit);
        let mut engine = PipelinedEngine::new(cfg, 4, 13);
        engine.provision(3);
        assert!(engine.provisioned_rounds() >= 3);
        let signs = rand_signs(8, 4, 21);
        let got = engine.run_round(&signs);
        assert_eq!(got.global_vote, plain_hierarchical_vote(&signs, cfg));
        assert!(engine.provisioned_rounds() >= 2);
    }

    #[test]
    fn chunk_and_batch_are_observationally_invisible() {
        let cfg = HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit);
        let signs = rand_signs(6, 23, 9);
        let baseline = plain_hierarchical_vote(&signs, cfg);
        for (chunk, batch) in [(1usize, 1usize), (3, 2), (64, 3)] {
            let got = PipelinedEngine::new(cfg, 23, 4)
                .with_chunk(chunk)
                .with_batch_rounds(batch)
                .run_round(&signs)
                .global_vote;
            assert_eq!(got, baseline, "chunk={chunk} batch={batch}");
        }
    }

    #[test]
    fn drop_with_inflight_batches_joins_cleanly() {
        let cfg = HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit);
        let mut engine = PipelinedEngine::new(cfg, 5, 1).with_batch_rounds(3);
        let signs = rand_signs(6, 5, 2);
        let _ = engine.run_round(&signs);
        // The prefetch batch may still be dealing; Drop must join, not hang.
        drop(engine);
    }

    #[test]
    fn pipelined_triple_streams_match_group_dealer_seed_derivation() {
        // Vote equality alone cannot pin the offline phase: Beaver masks
        // cancel exactly, so votes come out right under ANY triple
        // stream. This pins the streams themselves — the provisioner's
        // pooled triples must equal, share for share and round for
        // round, a dealer seeded with `group_dealer_seed(seed, g)` (the
        // run_sync derivation). A regression that collapsed the
        // per-group stride (reusing masks across subgroups, breaking
        // the Lemma-2 freshness argument) fails here and nowhere else.
        let cfg = HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit);
        let d = 5;
        let seed = 77u64;
        let mut engine = PipelinedEngine::new(cfg, d, seed);
        let mults = engine.plan().triples_needed();
        assert!(mults > 0, "n₁=3 needs secure multiplications");
        let fp = engine.plan().fp;
        engine.provision(2);
        for g in 0..cfg.ell {
            let mut reference = Dealer::new(fp, group_dealer_seed(seed, g));
            for round in 0..2 {
                let expect = reference.gen_round(d, cfg.n1(), mults);
                for (party, expect_party) in expect.iter().enumerate() {
                    let got = engine.pools.store_mut(g, party).take_many(mults);
                    assert_eq!(got.len(), mults);
                    for (t, e) in got.iter().zip(expect_party) {
                        assert_eq!(t.a, e.a, "g={g} party={party} round={round}");
                        assert_eq!(t.b, e.b, "g={g} party={party} round={round}");
                        assert_eq!(t.c, e.c, "g={g} party={party} round={round}");
                    }
                }
            }
        }
    }

    #[test]
    fn span_parallel_large_d_matches_reference() {
        let d = crate::engine::PAR_MIN_D + 61;
        let cfg = HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit);
        let signs = rand_signs(6, d, 41);
        let got = PipelinedEngine::new(cfg, d, 19).run_round(&signs);
        assert_eq!(got.global_vote, plain_hierarchical_vote(&signs, cfg));
    }
}
