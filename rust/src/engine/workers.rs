//! Span evaluation and the shared persistent worker pool.
//!
//! [`eval_span`] is the SoA polynomial-evaluation kernel both engines
//! share (one coordinate span, lane-chunked, lazy modular reduction).
//! Because the protocol is coordinate-local, any partition of `[0, d)`
//! into disjoint spans evaluates bit-identically to a single sequential
//! pass — which is what lets the engines parallelize freely.
//!
//! Two parallel drivers sit on top of it:
//!
//! * [`eval_group`] — the sequential [`crate::engine::RoundEngine`]'s
//!   per-round `std::thread::scope` split (the reference path; spawn cost
//!   is paid every round, which bounds small-`d` wins).
//! * [`WorkerPool`] — a persistent pool spawned once per
//!   [`crate::engine::AggScheduler`] and *shared by every session* the
//!   scheduler multiplexes. Span jobs carry ref-counted owned inputs
//!   (`Arc`ed signs and triples) so they are `'static`, and every job is
//!   **tagged with its session id**: results return over the owning
//!   session's result channel keyed by `(session, slot)`, so rounds of
//!   different tenants can be in flight on the same workers at once and
//!   reassembly stays per-tenant deterministic.
//!
//! The job queue is a shared `Mutex<Receiver<SpanJob>>`: workers take the
//! lock only to *pick up* a job (the guard drops before evaluation), so
//! pickup is serialized but evaluation is fully parallel.
//!
//! Evaluation working memory lives in a per-thread [`SpanScratch`]
//! (power-share matrix + δ/ε/final lane buffers), grown to the high-water
//! workload and recycled: a warm persistent worker allocates only the
//! per-round vote vector it sends back, nothing per span kernel.
//!
//! Every job also carries its session's **in-flight gauge** (an
//! `Arc<AtomicUsize>` incremented at submission, decremented by the
//! worker just before the result send) — the per-session accounting the
//! scheduler's admission layer and the `hisafe sweep` report read via
//! [`AggSession::inflight_jobs`](crate::engine::AggSession::inflight_jobs).

use std::cell::RefCell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::beaver::TripleShare;
use crate::field::Fp;
use crate::mpc::EvalPlan;

/// Process-wide gauge of engine-subsystem threads: incremented at every
/// spawn site (worker pools, provisioning planes), decremented after the
/// corresponding join. Spawn/join both happen on the owner's thread, so
/// the count is deterministic — no racing against thread start-up. This
/// is what lets tests *measure* (not assume) that `k` tenants run on one
/// pool's worth of threads; see [`live_engine_threads`].
static LIVE_ENGINE_THREADS: AtomicUsize = AtomicUsize::new(0);

/// Engine threads currently spawned and not yet joined, process-wide
/// (span workers + provisioning planes). Exposed so the thread-budget
/// test can assert the oversubscription fix on real counts.
pub fn live_engine_threads() -> usize {
    LIVE_ENGINE_THREADS.load(Ordering::SeqCst)
}

pub(crate) fn note_threads_spawned(n: usize) {
    LIVE_ENGINE_THREADS.fetch_add(n, Ordering::SeqCst);
}

pub(crate) fn note_threads_joined(n: usize) {
    LIVE_ENGINE_THREADS.fetch_sub(n, Ordering::SeqCst);
}

/// Worker count for a persistent pool: every core up to the engine's
/// bandwidth-bound cap (small-`d` rounds simply leave workers idle; the
/// pool costs nothing when unused). A `HISAFE_THREADS` env override pins
/// the count explicitly — resolved here, once, by whoever builds the
/// pool (the scheduler), never re-read on the round path.
pub(crate) fn worker_pool_threads() -> usize {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    resolve_threads(std::env::var("HISAFE_THREADS").ok().as_deref(), cores)
}

/// Pure thread-count policy (unit-testable without touching the process
/// environment): an explicit positive `HISAFE_THREADS` override wins;
/// otherwise every available core up to [`super::MAX_THREADS`].
/// A malformed or zero override is ignored rather than trusted —
/// a typo'd env var must not wedge the pool at 0 workers.
pub(crate) fn resolve_threads(env_override: Option<&str>, cores: usize) -> usize {
    if let Some(raw) = env_override {
        if let Ok(n) = raw.trim().parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    cores.clamp(1, super::MAX_THREADS)
}

/// How many spans to split a `d`-coordinate range into, given `threads`
/// available workers — the single parallelism policy shared by the
/// sequential engine's scoped split and the pipelined scheduler's job
/// fan-out, so both paths parallelize under identical conditions (the
/// bench's sequential-vs-pipelined comparison depends on that).
pub(crate) fn span_split(d: usize, threads: usize) -> usize {
    if d >= super::PAR_MIN_D && threads > 1 {
        threads
    } else {
        1
    }
}

/// One span-evaluation result: the originating session, the caller-side
/// slot key, and the span's votes. Sessions assert the session id on
/// receipt — a mis-routed result is a scheduler bug, not a vote glitch.
pub(crate) type SpanResult = (u64, usize, Vec<i8>);

/// One span-evaluation job: evaluate coordinates `[base, base + len)` of
/// one subgroup and deliver `(session, slot, votes)` on `out`. All inputs
/// are owned or ref-counted so the job is `'static` and can cross into a
/// persistent worker shared between sessions.
pub(crate) struct SpanJob {
    /// Owning session (tenant) — results reassemble per-tenant.
    pub session: u64,
    /// The owning session's in-flight job gauge: incremented by the
    /// session at submission, decremented by the worker *before* the
    /// result send — so once a round has received every result, the gauge
    /// is provably back at the pre-submission count. This is the
    /// per-session accounting the admission layer and the `sweep` report
    /// read; it never affects evaluation.
    pub inflight: Arc<AtomicUsize>,
    pub fp: Fp,
    pub plan: Arc<EvalPlan>,
    /// This subgroup's members' sign vectors (full `d`-length).
    pub signs: Arc<Vec<Vec<i8>>>,
    /// `triples[party][mult]` — this subgroup's triples for this round.
    pub triples: Arc<Vec<Vec<TripleShare>>>,
    /// First coordinate of the span.
    pub base: usize,
    /// Span length.
    pub len: usize,
    pub chunk: usize,
    /// Caller-side reassembly key (unique within the session's round).
    pub slot: usize,
    /// Result channel: the owning session's.
    pub out: Sender<SpanResult>,
}

/// Persistent span workers, spawned once per scheduler and fed over a
/// shared queue — replacing the per-round `std::thread::scope` spawns
/// whose cost bounded small-`d` parallel wins (ROADMAP). Every session of
/// a scheduler submits to the same queue through a cloned [`sender`], so
/// `k` tenants still run on exactly one pool's worth of threads. Dropping
/// the pool (with all session senders gone) closes the queue; workers
/// drain and exit, and `drop` joins them.
///
/// [`sender`]: WorkerPool::sender
pub(crate) struct WorkerPool {
    job_tx: Option<Sender<SpanJob>>,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    pub fn new(threads: usize) -> WorkerPool {
        assert!(threads >= 1, "worker pool needs at least one thread");
        let (job_tx, job_rx) = channel::<SpanJob>();
        let job_rx = Arc::new(Mutex::new(job_rx));
        let handles: Vec<JoinHandle<()>> = (0..threads)
            .map(|_| {
                let rx = Arc::clone(&job_rx);
                std::thread::spawn(move || {
                    while let Some(job) = next_job(&rx) {
                        run_span_job(job);
                    }
                })
            })
            .collect();
        note_threads_spawned(handles.len());
        WorkerPool { job_tx: Some(job_tx), handles }
    }

    pub fn threads(&self) -> usize {
        self.handles.len()
    }

    /// A cloned handle onto the job queue, for sessions to submit through
    /// without borrowing the pool (the pool stays owned by the scheduler;
    /// a queue clone outliving the pool would only make sends fail, never
    /// dangle).
    pub fn sender(&self) -> Sender<SpanJob> {
        self.job_tx.as_ref().expect("worker pool queue open").clone()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // Closing the queue unblocks every worker's recv with Err.
        drop(self.job_tx.take());
        let joined = self.handles.len();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        note_threads_joined(joined);
    }
}

/// Take the next job off the shared queue. A helper function so the
/// mutex guard provably drops before the job body runs — inlining this
/// into a `while let` scrutinee would hold the lock across evaluation
/// (2021-edition temporary-lifetime rules) and serialize the pool.
fn next_job(rx: &Mutex<Receiver<SpanJob>>) -> Option<SpanJob> {
    rx.lock().expect("worker queue poisoned").recv().ok()
}

fn run_span_job(job: SpanJob) {
    let signs: Vec<&[i8]> = job.signs.iter().map(|v| v.as_slice()).collect();
    let triples: Vec<&[TripleShare]> = job.triples.iter().map(|v| v.as_slice()).collect();
    let mut votes = vec![0i8; job.len];
    eval_span(job.fp, &job.plan, &signs, &triples, &mut votes, job.base, job.chunk);
    // Decrement BEFORE the send: receiving the last result then implies
    // the gauge already dropped, so a session that has collected a full
    // round reads 0 in-flight deterministically (no post-send race).
    job.inflight.fetch_sub(1, Ordering::SeqCst);
    // The session may be tearing down mid-round; an orphaned result is fine.
    let _ = job.out.send((job.session, job.slot, votes));
}

/// One subgroup's secure vote over its full coordinate range — the
/// sequential engine's driver, splitting the range across scoped span
/// workers when profitable.
pub(crate) fn eval_group(
    fp: Fp,
    plan: &Arc<EvalPlan>,
    group_signs: &[&[i8]],
    triples: &[&[TripleShare]],
    d: usize,
    chunk: usize,
    threads: usize,
) -> Vec<i8> {
    let mut votes = vec![0i8; d];
    if threads > 1 {
        let span = d.div_ceil(threads);
        std::thread::scope(|sc| {
            let plan: &EvalPlan = plan;
            for (si, vspan) in votes.chunks_mut(span).enumerate() {
                sc.spawn(move || {
                    eval_span(fp, plan, group_signs, triples, vspan, si * span, chunk)
                });
            }
        });
    } else {
        eval_span(fp, plan, group_signs, triples, &mut votes, 0, chunk);
    }
    votes
}

/// Reusable per-thread working buffers for [`eval_span`]: the power-share
/// matrix plus the δ/ε/final/output lane buffers. Every buffer is fully
/// overwritten before it is read within a chunk (`pow[1]` by the sign
/// encode, higher powers by their producing step — schedule targets are
/// ≥ 2 and operands ≥ 1, so `pow[0]` is never touched; δ/ε/fin/out are
/// `fill`-initialized per chunk), so recycling a previous round's scratch
/// is observationally invisible. Held in a thread-local: the persistent
/// [`WorkerPool`] threads therefore allocate NOTHING per round once warm
/// — `ensure` only ever grows, and the high-water footprint is bounded by
/// `(max_pow + 1) · n₁ · chunk` lanes (a few hundred KiB at the defaults).
struct SpanScratch {
    /// `pow[k][party]` — one lane chunk of the share of `x^k`.
    pow: Vec<Vec<Vec<u64>>>,
    delta: Vec<u64>,
    eps: Vec<u64>,
    fin: Vec<u64>,
    out: Vec<u64>,
}

impl SpanScratch {
    const fn new() -> SpanScratch {
        SpanScratch {
            pow: Vec::new(),
            delta: Vec::new(),
            eps: Vec::new(),
            fin: Vec::new(),
            out: Vec::new(),
        }
    }

    /// Grow (never shrink) to cover a `(max_pow, n1, chunk)` workload;
    /// a worker multiplexed across sessions keeps one high-water set.
    fn ensure(&mut self, max_pow: usize, n1: usize, chunk: usize) {
        if self.pow.len() < max_pow + 1 {
            self.pow.resize_with(max_pow + 1, Vec::new);
        }
        for row in &mut self.pow {
            if row.len() < n1 {
                row.resize_with(n1, Vec::new);
            }
            for lanes in row.iter_mut() {
                if lanes.len() < chunk {
                    lanes.resize(chunk, 0);
                }
            }
        }
        if self.delta.len() < chunk {
            self.delta.resize(chunk, 0);
            self.eps.resize(chunk, 0);
            self.fin.resize(chunk, 0);
            self.out.resize(chunk, 0);
        }
    }
}

thread_local! {
    static SPAN_SCRATCH: RefCell<SpanScratch> = const { RefCell::new(SpanScratch::new()) };
}

/// Evaluate the majority-vote polynomial over the coordinate span
/// `[base, base + votes.len())` in SoA lane chunks. Pure function of its
/// inputs — spans never overlap, so span workers are deterministic.
/// Working buffers come from the calling thread's [`SpanScratch`].
pub(crate) fn eval_span(
    fp: Fp,
    plan: &EvalPlan,
    group_signs: &[&[i8]],
    triples: &[&[TripleShare]],
    votes: &mut [i8],
    base: usize,
    chunk: usize,
) {
    SPAN_SCRATCH.with(|s| {
        // eval_span never re-enters itself, so the borrow cannot collide.
        let mut scratch = s.borrow_mut();
        eval_span_scratch(fp, plan, group_signs, triples, votes, base, chunk, &mut scratch);
    });
}

#[allow(clippy::too_many_arguments)]
fn eval_span_scratch(
    fp: Fp,
    plan: &EvalPlan,
    group_signs: &[&[i8]],
    triples: &[&[TripleShare]],
    votes: &mut [i8],
    base: usize,
    chunk: usize,
    scratch: &mut SpanScratch,
) {
    let n1 = group_signs.len();
    let steps = &plan.schedule.steps;
    let coeffs = &plan.coeffs;
    let max_pow = plan.schedule.max_power.max(1);
    // §Perf: same raw-accumulation headroom rule as Party::final_share.
    let fused_final = fp.fused_headroom(coeffs.len() as u64 + 1);

    scratch.ensure(max_pow, n1, chunk);
    let SpanScratch { pow, delta, eps, fin, out } = scratch;

    let span = votes.len();
    let mut j0 = 0usize;
    while j0 < span {
        let c = chunk.min(span - j0);
        let lo = base + j0;
        let hi = lo + c;

        // 1. field-encode the ±1 inputs: each user's sign vector IS its
        //    additive share of the aggregate (no input-sharing round).
        for (pi, s) in group_signs.iter().enumerate() {
            for (lane, &sv) in pow[1][pi][..c].iter_mut().zip(&s[lo..hi]) {
                *lane = fp.from_i64(sv as i64);
            }
        }

        // 2. power schedule. Steps are dependency-ordered (operands always
        //    have strictly lower depth), so one sequential pass is exact.
        for (mi, step) in steps.iter().enumerate() {
            // openings: δ = Σᵢ (⟦x^l⟧ᵢ − ⟦a⟧ᵢ), ε likewise — accumulated
            // raw straight off the share matrix, reduced once per lane.
            delta[..c].fill(0);
            eps[..c].fill(0);
            for pi in 0..n1 {
                let t = &triples[pi][mi];
                fp.vec_sub_add_raw(&mut delta[..c], &pow[step.left][pi][..c], &t.a[lo..hi]);
                fp.vec_sub_add_raw(&mut eps[..c], &pow[step.right][pi][..c], &t.b[lo..hi]);
            }
            fp.vec_reduce_in_place(&mut delta[..c]);
            fp.vec_reduce_in_place(&mut eps[..c]);
            // recombination: party 0 adds the public δ·ε term.
            for pi in 0..n1 {
                let t = &triples[pi][mi];
                fp.beaver_combine_into(
                    &mut pow[step.target][pi][..c],
                    &t.c[lo..hi],
                    &t.a[lo..hi],
                    &t.b[lo..hi],
                    &delta[..c],
                    &eps[..c],
                    pi == 0,
                );
            }
        }

        // 3. final shares Σ_k coeff_k·⟦x^k⟧ᵢ (+ c₀ for party 0), summed
        //    into F(x) = sign(x) per lane (Eq. 5).
        out[..c].fill(0);
        for pi in 0..n1 {
            fin[..c].fill(0);
            if pi == 0 && coeffs.first().copied().unwrap_or(0) != 0 {
                fin[..c].fill(coeffs[0]);
            }
            for (k, &coeff) in coeffs.iter().enumerate().skip(1) {
                if coeff == 0 {
                    continue;
                }
                if fused_final {
                    fp.vec_scale_add_raw(&mut fin[..c], coeff, &pow[k][pi][..c]);
                } else {
                    fp.vec_scale_add_assign(&mut fin[..c], coeff, &pow[k][pi][..c]);
                }
            }
            fp.vec_reduce_in_place(&mut fin[..c]);
            fp.vec_add_raw(&mut out[..c], &fin[..c]);
        }
        fp.vec_reduce_in_place(&mut out[..c]);
        for (v, &x) in votes[j0..j0 + c].iter_mut().zip(&out[..c]) {
            *v = fp.level_of(x);
        }
        j0 += c;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::poly::{MvPolynomial, TiePolicy};

    #[test]
    fn pool_evaluates_spans_and_reassembles_by_session_and_slot() {
        // n₁ = 1 makes F the identity (no triples needed): the pool's
        // reassembled output must be the input signs, split across spans.
        // Two "sessions" share the pool; each only trusts results tagged
        // with its own id.
        let mv = MvPolynomial::build_fermat(1, TiePolicy::OneBit);
        let plan = Arc::new(EvalPlan::new(&mv, 10, false));
        let pool = WorkerPool::new(3);
        assert_eq!(pool.threads(), 3);
        let jobs = pool.sender();
        let signs = Arc::new(vec![vec![1i8, -1, 1, -1, 1, -1, 1, -1, 1, -1]]);
        let triples: Arc<Vec<Vec<TripleShare>>> = Arc::new(vec![Vec::new()]);
        let mut per_session = Vec::new();
        for session in [7u64, 9] {
            let (tx, rx) = channel();
            let inflight = Arc::new(AtomicUsize::new(0));
            for (slot, base) in [(0usize, 0usize), (1, 5)] {
                inflight.fetch_add(1, Ordering::SeqCst);
                jobs.send(SpanJob {
                    session,
                    inflight: Arc::clone(&inflight),
                    fp: plan.fp,
                    plan: Arc::clone(&plan),
                    signs: Arc::clone(&signs),
                    triples: Arc::clone(&triples),
                    base,
                    len: 5,
                    chunk: 4,
                    slot,
                    out: tx.clone(),
                })
                .expect("pool alive");
            }
            drop(tx);
            per_session.push((session, inflight, rx));
        }
        for (session, inflight, rx) in per_session {
            let mut votes = vec![0i8; 10];
            for _ in 0..2 {
                let (sid, slot, span) = rx.recv().expect("span result");
                assert_eq!(sid, session, "result routed to the wrong session");
                votes[slot * 5..slot * 5 + 5].copy_from_slice(&span);
            }
            assert_eq!(votes, signs[0]);
            // Workers decrement before sending, so a fully collected
            // round reads an exact 0 — the accounting the admission
            // layer relies on.
            assert_eq!(inflight.load(Ordering::SeqCst), 0, "in-flight gauge must drain");
        }
    }

    #[test]
    fn thread_resolution_honors_override_and_caps_cores() {
        // No override: cores win, capped at MAX_THREADS, floored at 1.
        assert_eq!(resolve_threads(None, 4), 4);
        assert_eq!(resolve_threads(None, 64), crate::engine::MAX_THREADS);
        assert_eq!(resolve_threads(None, 0), 1);
        // Explicit override wins, even above the cap (operator's call).
        assert_eq!(resolve_threads(Some("1"), 16), 1);
        assert_eq!(resolve_threads(Some(" 12 "), 2), 12);
        // Malformed or zero overrides fall back to the core policy.
        assert_eq!(resolve_threads(Some("0"), 4), 4);
        assert_eq!(resolve_threads(Some("lots"), 4), 4);
        assert_eq!(resolve_threads(Some(""), 4), 4);
    }
}
