//! Batched secure-aggregation engines — the round-amortized hot path.
//!
//! [`crate::mpc`] models Algorithm 1 faithfully as message-passing state
//! machines: every multiplication materializes per-party masked-pair
//! vectors, every subround allocates uplink/broadcast messages, and every
//! FL round rebuilds the polynomial, the plan, and a fresh dealer. That is
//! the right shape for protocol tests and the threaded coordinator, but it
//! wastes most of its time on allocation and message plumbing when the
//! same server drives thousands of aggregation rounds over a model-sized
//! `d` (the ROADMAP "heavy traffic" regime).
//!
//! This module executes the *same arithmetic* (share-for-share: it reuses
//! [`crate::field::Fp::beaver_combine_into`] and the schedule from
//! [`EvalPlan`]) with a throughput-oriented layout, split across five
//! files:
//!
//! * `mod.rs` — the [`Engine`] trait (the one builder/accessor surface
//!   every engine shares) and [`RoundEngine`], the **sequential
//!   reference engine**: amortized plan/polynomial setup,
//!   pre-provisioned triple pools refilled synchronously on the round
//!   path, SoA lane-chunked evaluation, per-round scoped span threads.
//! * [`pool`] — [`pool::GroupPools`], the per-group/per-party triple
//!   pools every engine consumes, with party-aware round accounting (the
//!   minimum across parties *and* groups; a divergent pool must surface
//!   as "needs refill", never as a mid-round `take_many` panic). Pools
//!   are owned per engine/session; under the scheduler they are refilled
//!   by the shared provisioning plane.
//! * [`workers`] — the shared span-evaluation kernel plus the
//!   **persistent worker pool** (spawned once per scheduler; span jobs
//!   are `'static`, tagged by session, and results reassemble per-tenant
//!   by slot index).
//! * [`scheduler`] — [`AggScheduler`] / [`AggSession`], the
//!   **multi-tenant scheduler**: one shared worker pool and one
//!   provisioning plane (a single dealer thread weighted-round-robining
//!   Beaver-triple dealing across tenants) multiplexing any number of
//!   concurrent `(cfg, d)` workloads, each behind a session handle with
//!   the engine surface. This is the heavy-traffic shape: `k` tenants
//!   cost one pool's worth of threads, not `k`. Each session carries a
//!   [`QosPolicy`] (dealing weight, bounded queue depth, rounds/sec and
//!   triples/sec token buckets), and backpressure surfaces as a typed
//!   [`AdmissionError`] on the `try_*` session methods instead of
//!   silent queueing.
//! * [`pipeline`] — [`PipelinedEngine`], the **single-tenant pipelined
//!   engine**, now a thin wrapper around a private one-session
//!   scheduler: a background provisioning stage deals round `r+1`'s
//!   Beaver triples while round `r`'s online phase evaluates. This is
//!   the paper's offline/online split (Table V) realized as wall-clock
//!   overlap, and the path `fl/trainer.rs` uses for multi-round training.
//!
//! **Offline/online overlap & determinism.** Subgroups are independent:
//! group `g`'s dealer is seeded with
//! [`crate::protocol::group_dealer_seed`] — the *same* derivation
//! `run_sync` uses (rust/src/protocol.rs) — and only ever advances in
//! whole-round steps, in round order. Dealing may therefore run on any
//! thread at any wall-clock time: party `i` of group `g` still consumes
//! exactly the triple stream it would have consumed synchronously.
//! (`run_sync` reseeds a fresh dealer per call while the engines advance
//! one long-lived stream, so triple-level alignment with a `run_sync`
//! call holds for an engine's first round; later rounds are that same
//! stream's continuation — `engine/scheduler.rs` pins the pooled
//! triples to the derivation share-for-share.)
//!
//! **Why shared provisioning preserves per-group seed streams.** The
//! scheduler's plane owns *per-session* dealers keyed by the session's
//! own seed; multiplexing changes only *when* (in wall-clock) and *in
//! what tenant order* `gen_round` calls happen, never the sequence of
//! calls any single dealer sees. Since a ChaCha20-seeded dealer is a
//! pure stream — its output depends only on its seed and how many
//! triples it has produced — tenant interleaving is invisible to every
//! per-group stream. Votes are a stronger story still: Beaver masks
//! cancel exactly, so *any* fresh triples yield the same votes, and
//! scheduled, pipelined, sequential, and `run_sync` votes are
//! bit-identical round after round (asserted across random configs and
//! random tenant interleavings by `rust/tests/engine_props.rs` and
//! `rust/tests/sched_props.rs`).
//!
//! `rust/tests/engine_props.rs` also pins the engines' analytic
//! [`CommStats`] to the *measured* counters of the message-passing path,
//! field element for field element; the `mpc_mult_throughput` bench
//! measures the batched-vs-per-call speedup and the pipelined overlap
//! win at the paper's n=24/ℓ=8 operating point, and the
//! `sched_multi_tenant` bench compares `k` dedicated engines against one
//! scheduler at equal total work.

mod pipeline;
mod pool;
mod scheduler;
mod workers;

pub use pipeline::PipelinedEngine;
pub use scheduler::{
    AdmissionError, AggScheduler, AggSession, QosPolicy, SessionId, SessionSnapshot,
};
pub use workers::live_engine_threads;

use std::collections::HashMap;
use std::sync::Arc;

use crate::beaver::{Dealer, TripleShare};
use crate::metrics::CommStats;
use crate::mpc::EvalPlan;
use crate::poly::{MvPolynomial, TiePolicy};
use crate::protocol::{
    check_thresholds, churn_dealer_seed, group_dealer_seed, inter_group_vote_q, partition,
    recover_cohort_key, ChurnError, HiSafeConfig, ParticipantSet,
};

use pool::GroupPools;

/// Lane-chunk size (u64 lanes). With `max_power + 1` power rows per party
/// and `n₁ ≤ 6` in every optimal configuration, one chunk's working set
/// stays well inside L2.
pub(crate) const DEFAULT_CHUNK: usize = 2048;

/// Minimum model dimension before span splitting pays for its overhead
/// (scoped-thread spawns on the sequential path, job handoffs on the
/// pipelined one).
pub(crate) const PAR_MIN_D: usize = 8192;

/// Cap on span workers (beyond this, memory bandwidth dominates).
pub(crate) const MAX_THREADS: usize = 8;

/// The one engine surface: builders, provisioning accessors, and the
/// round path, shared by the sequential [`RoundEngine`], the pipelined
/// [`PipelinedEngine`], and the multi-tenant [`AggSession`]. Before this
/// trait the builder/accessor API was copied verbatim between the
/// engines; now it is defined once, the property suite
/// (`rust/tests/engine_props.rs`) is generic over it, and every
/// implementation is pinned to the same reference votes.
///
/// ```
/// use hisafe::engine::{Engine, RoundEngine};
/// use hisafe::poly::TiePolicy;
/// use hisafe::protocol::HiSafeConfig;
///
/// // 6 users in 2 subgroups voting over 4 coordinates.
/// let cfg = HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit);
/// let mut engine = RoundEngine::new(cfg, 4, 7);
///
/// // Move the offline phase (Beaver-triple dealing) off the round path.
/// engine.provision(2);
/// assert!(engine.provisioned_rounds() >= 2);
///
/// // Unanimous inputs make the majority vote obvious.
/// let signs = vec![vec![1i8, -1, 1, -1]; 6];
/// let out = engine.run_round(&signs);
/// assert_eq!(out.global_vote, vec![1, -1, 1, -1]);
/// assert_eq!(out.subgroup_votes.len(), 2);
/// assert_eq!(engine.rounds_run(), 1);
/// ```
pub trait Engine {
    /// Override the SoA lane-chunk size (tests sweep this to prove chunk
    /// invariance; benches tune it).
    fn with_chunk(self, chunk: usize) -> Self
    where
        Self: Sized;

    /// Provision `rounds` rounds of triples per refill/background
    /// request (default 1). Larger batches amortize dealing at the cost
    /// of pooled memory.
    fn with_batch_rounds(self, rounds: usize) -> Self
    where
        Self: Sized;

    /// The evaluation plan the engine executes (schedule, coefficients).
    fn plan(&self) -> &EvalPlan;

    /// Rounds' worth of triples currently pooled — the minimum across
    /// groups *and parties*, so a divergent pool reports its worst
    /// balance instead of party 0's. Excludes in-flight background
    /// batches on the pipelined/scheduled paths.
    fn provisioned_rounds(&self) -> usize;

    /// Explicitly pre-provision at least `rounds` rounds of triples now —
    /// benches use this to move the offline phase out of the measured
    /// loop (the paper's offline/online split, Table V).
    fn provision(&mut self, rounds: usize);

    /// Execute one Hi-SAFE aggregation round. `signs[i]` is user `i`'s
    /// ±1 sign-gradient vector; users are partitioned into subgroups
    /// exactly like [`crate::protocol::run_sync`], and votes are
    /// bit-identical across every implementation.
    fn run_round(&mut self, signs: &[Vec<i8>]) -> EngineOutcome;

    /// Execute one round over an explicit participant set. `signs` keeps
    /// its full `n`-row shape (absent rows are ignored). An all-present
    /// mask takes the exact [`Engine::run_round`] path — bit-identical,
    /// pooled base-stream triples and all. A churned mask evaluates each
    /// affected group over its survivors with a cohort-keyed dealer
    /// stream (the reusable-secret fast path caches per-cohort setup, so
    /// a stable cohort re-keys once) while the group's base stream
    /// advances in lockstep; votes are bit-identical to
    /// [`crate::protocol::run_sync_with_dropouts`] over the same set. A
    /// group below its reconstruction threshold aborts with a typed
    /// [`ChurnError`] before any engine state advances.
    fn run_round_present(
        &mut self,
        signs: &[Vec<i8>],
        present: &ParticipantSet,
    ) -> Result<EngineOutcome, ChurnError>;

    /// Rounds executed so far.
    fn rounds_run(&self) -> u64;
}

/// Outcome of one engine round — the trainer-facing subset of
/// [`crate::protocol::RoundOutcome`] (no transcripts: the engines never
/// materialize server views; use the mpc path for security tests).
#[derive(Debug)]
pub struct EngineOutcome {
    /// Global vote per coordinate (`{−1,+1}`, or 0 under inter TwoBit).
    pub global_vote: Vec<i8>,
    /// Subgroup votes `s_j` (the Theorem-2 leakage).
    pub subgroup_votes: Vec<Vec<i8>>,
    /// Analytic communication counters — equal, field element for field
    /// element, to the measured counters of the message-passing path.
    pub stats: CommStats,
}

/// Analytic per-round communication counters, shared by both engines:
/// 2 openings (δ-share, ε-share) × d lanes per multiplication per user
/// uplink; the server broadcasts the same volume once per group. Equal to
/// the measured per-message counters of [`crate::protocol::run_sync`]
/// (asserted field-for-field by `engine_props.rs`).
pub(crate) fn analytic_stats(cfg: &HiSafeConfig, plan: &EvalPlan, d: usize) -> CommStats {
    let mults = plan.triples_needed() as u64;
    let ell = cfg.ell as u64;
    let n1 = cfg.n1() as u64;
    let per_mult_elems = 2 * d as u64;
    CommStats {
        uplink_elems_total: ell * n1 * mults * per_mult_elems,
        uplink_elems_per_user: mults * per_mult_elems,
        downlink_elems: ell * mults * per_mult_elems,
        elem_bits: plan.fp.bits(),
        subrounds: plan.schedule.depth() as u64,
        mults: ell * mults,
        vote_bits: crate::quant::downlink_bits(cfg.precision, cfg.inter),
    }
}

/// Analytic counters for ONE group evaluated by `k` parties under `plan`
/// — the churn path's per-group unit. Merging one of these per group
/// (heterogeneous `k` and cohort plans included) and then overwriting
/// `vote_bits` with the inter policy reproduces, field for field, the
/// measured stats [`crate::protocol::run_sync_with_dropouts`] merges from
/// its per-group [`crate::mpc::secure_group_vote`] calls; with every
/// group full it collapses back to [`analytic_stats`].
pub(crate) fn analytic_group_stats(
    plan: &EvalPlan,
    d: usize,
    k: usize,
    intra: TiePolicy,
) -> CommStats {
    let mults = plan.triples_needed() as u64;
    let per_mult_elems = 2 * d as u64;
    CommStats {
        uplink_elems_total: k as u64 * mults * per_mult_elems,
        uplink_elems_per_user: mults * per_mult_elems,
        downlink_elems: mults * per_mult_elems,
        elem_bits: plan.fp.bits(),
        subrounds: plan.schedule.depth() as u64,
        mults,
        vote_bits: crate::quant::downlink_bits(plan.q, intra),
    }
}

/// Cached per-cohort setup for the churn path — the reusable-secret fast
/// path. Keyed by `(group, cohort_key)` in the owning engine: the first
/// round a cohort appears pays t-of-n recovery, the `k`-party plan
/// build, and dealer keying; every later round with the same survivors
/// streams triples from the cached dealer. The dealer is a persistent
/// stream (like the base-cohort dealers), which is sound because votes
/// are triple-independent — Beaver masks cancel — so any fresh triples
/// reproduce the reference votes bit for bit.
pub(crate) struct CohortState {
    pub plan: Arc<EvalPlan>,
    pub dealer: Dealer,
}

impl CohortState {
    /// Build the plan + dealer for group `g`'s `k`-survivor cohort.
    pub fn build(cfg: &HiSafeConfig, d: usize, seed: u64, g: usize, k: usize, key: u64) -> CohortState {
        let mv = MvPolynomial::build_fermat_q(k, cfg.precision, cfg.intra);
        let plan = Arc::new(EvalPlan::new(&mv, d, cfg.sparse));
        let dealer = Dealer::new(plan.fp, churn_dealer_seed(seed, g, key));
        CohortState { plan, dealer }
    }

    /// One round of triples for this cohort's `k` parties, owned (`mults
    /// == 0` plans get empty per-party vectors).
    pub fn round_triples(&mut self, d: usize, k: usize) -> Vec<Vec<TripleShare>> {
        let mults = self.plan.triples_needed();
        if mults == 0 {
            vec![Vec::new(); k]
        } else {
            self.dealer.gen_round(d, k, mults)
        }
    }
}

/// Reusable, round-amortized Hi-SAFE aggregation engine for one fixed
/// `(HiSafeConfig, d)` workload — the **sequential reference**: dealing
/// happens synchronously on the round path whenever the pool runs dry,
/// and span threads are scoped per round. [`PipelinedEngine`] is the
/// scheduler that overlaps those phases; its votes are pinned
/// bit-identical to this engine's.
pub struct RoundEngine {
    cfg: HiSafeConfig,
    d: usize,
    /// The root offline seed — kept for the churn path's per-cohort
    /// recovery + dealer derivations ([`crate::protocol::recover_cohort_key`]).
    seed: u64,
    plan: Arc<EvalPlan>,
    /// One streaming dealer per subgroup (seeds mirror `run_sync`'s
    /// per-group seed derivation so subgroups stay independent).
    dealers: Vec<Dealer>,
    /// Pre-provisioned Beaver triples, one pool per party per subgroup.
    pools: GroupPools,
    /// Cached churn-cohort plans/dealers, keyed `(group, cohort_key)` —
    /// the reusable-secret fast path.
    cohorts: HashMap<(usize, u64), CohortState>,
    /// Distinct cohorts keyed so far (== cache misses; a stable cohort
    /// holds this at 1 per churned group however many rounds it runs).
    rekeys: u64,
    /// Rounds of triples generated per refill.
    batch_rounds: usize,
    chunk: usize,
    /// Span-thread budget, resolved once at construction (the
    /// `HISAFE_THREADS` override is never re-read on the round path).
    threads: usize,
    /// Rounds executed so far.
    pub rounds_run: u64,
}

impl RoundEngine {
    /// Build an engine for `cfg` over `d`-coordinate votes. `seed` drives
    /// all offline randomness (triple generation), one independent stream
    /// per subgroup.
    pub fn new(cfg: HiSafeConfig, d: usize, seed: u64) -> RoundEngine {
        let n1 = cfg.n1();
        let mv = MvPolynomial::build_fermat_q(n1, cfg.precision, cfg.intra);
        let plan = Arc::new(EvalPlan::new(&mv, d, cfg.sparse));
        let dealers: Vec<Dealer> = (0..cfg.ell)
            .map(|g| Dealer::new(plan.fp, group_dealer_seed(seed, g)))
            .collect();
        RoundEngine {
            cfg,
            d,
            seed,
            plan,
            dealers,
            pools: GroupPools::new(cfg.ell, n1),
            cohorts: HashMap::new(),
            rekeys: 0,
            batch_rounds: 1,
            chunk: DEFAULT_CHUNK,
            threads: workers::worker_pool_threads(),
            rounds_run: 0,
        }
    }

    /// Distinct churn cohorts keyed so far — the reusable-secret fast
    /// path's miss counter. Stays flat while the survivor set is stable.
    pub fn cohort_rekeys(&self) -> u64 {
        self.rekeys
    }

    /// Base-stream group-rounds consumed-and-discarded on churned rounds
    /// (survivor-aware pool accounting).
    pub fn discarded_rounds(&self) -> usize {
        self.pools.discarded_rounds()
    }

    /// Top up any group whose pool cannot cover one round for *every*
    /// party (inspecting only party 0 — the pre-PR-2 behavior — let an
    /// unbalanced pool panic in `take_many` mid-round).
    fn ensure_provisioned(&mut self) {
        let mults = self.plan.triples_needed();
        if mults == 0 {
            return;
        }
        let d = self.d;
        let batch = self.batch_rounds;
        for (g, dealer) in self.dealers.iter_mut().enumerate() {
            if !self.pools.group_needs_refill(g, mults) {
                continue;
            }
            self.pools.deal_into(g, dealer, d, mults, batch);
        }
    }
}

impl Engine for RoundEngine {
    fn with_chunk(mut self, chunk: usize) -> RoundEngine {
        assert!(chunk >= 1, "chunk must be ≥ 1");
        self.chunk = chunk;
        self
    }

    fn with_batch_rounds(mut self, rounds: usize) -> RoundEngine {
        assert!(rounds >= 1, "batch must be ≥ 1");
        self.batch_rounds = rounds;
        self
    }

    fn plan(&self) -> &EvalPlan {
        &self.plan
    }

    fn provisioned_rounds(&self) -> usize {
        self.pools.provisioned_rounds(self.plan.triples_needed())
    }

    /// Synchronous dealing straight into the pools — the sequential
    /// engine has no background stage.
    fn provision(&mut self, rounds: usize) {
        let mults = self.plan.triples_needed();
        if mults == 0 {
            return;
        }
        let d = self.d;
        for (g, dealer) in self.dealers.iter_mut().enumerate() {
            self.pools.deal_into(g, dealer, d, mults, rounds);
        }
    }

    fn run_round(&mut self, signs: &[Vec<i8>]) -> EngineOutcome {
        assert_eq!(signs.len(), self.cfg.n, "need exactly n sign vectors");
        for (i, s) in signs.iter().enumerate() {
            assert_eq!(s.len(), self.d, "user {i} dimension mismatch");
        }
        self.ensure_provisioned();

        let fp = self.plan.fp;
        let d = self.d;
        let chunk = self.chunk;
        let mults = self.plan.triples_needed();
        let groups = partition(self.cfg.n, self.cfg.ell);
        let threads = workers::span_split(d, self.threads);

        let plan = Arc::clone(&self.plan);
        let mut subgroup_votes = Vec::with_capacity(groups.len());
        for (g, members) in groups.iter().enumerate() {
            let group_signs: Vec<&[i8]> =
                members.iter().map(|&u| signs[u].as_slice()).collect();
            let triples = self.pools.take_round(g, mults);
            subgroup_votes.push(workers::eval_group(
                fp, &plan, &group_signs, &triples, d, chunk, threads,
            ));
        }
        let global_vote =
            inter_group_vote_q(&subgroup_votes, self.cfg.precision, self.cfg.inter);
        let stats = analytic_stats(&self.cfg, &self.plan, d);

        self.rounds_run += 1;
        EngineOutcome { global_vote, subgroup_votes, stats }
    }

    fn run_round_present(
        &mut self,
        signs: &[Vec<i8>],
        present: &ParticipantSet,
    ) -> Result<EngineOutcome, ChurnError> {
        assert_eq!(present.n(), self.cfg.n, "participant mask must cover all n users");
        if present.is_all_present() {
            return Ok(self.run_round(signs));
        }
        assert_eq!(signs.len(), self.cfg.n, "need n sign rows (absent rows are ignored)");
        for (i, s) in signs.iter().enumerate() {
            assert_eq!(s.len(), self.d, "user {i} dimension mismatch");
        }
        check_thresholds(self.cfg, present)?;
        self.ensure_provisioned();

        let d = self.d;
        let chunk = self.chunk;
        let mults = self.plan.triples_needed();
        let groups = partition(self.cfg.n, self.cfg.ell);
        let threads = workers::span_split(d, self.threads);

        let mut subgroup_votes = Vec::with_capacity(groups.len());
        let mut stats = CommStats::default();
        for (g, members) in groups.iter().enumerate() {
            let survivors = present.group_survivors(members);
            if survivors.len() == members.len() {
                // Full cohort: the exact run_round path for this group —
                // same base plan, same pooled base-stream triples.
                let group_signs: Vec<&[i8]> =
                    members.iter().map(|&u| signs[u].as_slice()).collect();
                let plan = Arc::clone(&self.plan);
                let triples = self.pools.take_round(g, mults);
                subgroup_votes.push(workers::eval_group(
                    plan.fp, &plan, &group_signs, &triples, d, chunk, threads,
                ));
                stats.merge(&analytic_group_stats(&plan, d, members.len(), self.cfg.intra));
                continue;
            }
            // Churned cohort: advance the base stream one round (so later
            // all-present rounds draw the triples they always would),
            // then evaluate the survivors under their cached cohort.
            if mults > 0 {
                self.pools.discard_round(g, mults);
            }
            let k = survivors.len();
            let key = recover_cohort_key(self.seed, g, members, present);
            if !self.cohorts.contains_key(&(g, key)) {
                let state = CohortState::build(&self.cfg, d, self.seed, g, k, key);
                self.cohorts.insert((g, key), state);
                self.rekeys += 1;
            }
            let cohort = self.cohorts.get_mut(&(g, key)).expect("just inserted");
            let plan = Arc::clone(&cohort.plan);
            let owned = cohort.round_triples(d, k);
            let triples: Vec<&[TripleShare]> = owned.iter().map(|t| t.as_slice()).collect();
            let group_signs: Vec<&[i8]> =
                survivors.iter().map(|&u| signs[u].as_slice()).collect();
            subgroup_votes.push(workers::eval_group(
                plan.fp, &plan, &group_signs, &triples, d, chunk, threads,
            ));
            stats.merge(&analytic_group_stats(&plan, d, k, self.cfg.intra));
        }
        let global_vote =
            inter_group_vote_q(&subgroup_votes, self.cfg.precision, self.cfg.inter);
        stats.vote_bits = crate::quant::downlink_bits(self.cfg.precision, self.cfg.inter);

        self.rounds_run += 1;
        Ok(EngineOutcome { global_vote, subgroup_votes, stats })
    }

    fn rounds_run(&self) -> u64 {
        self.rounds_run
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpc::{plain_group_vote, secure_group_vote};
    use crate::poly::TiePolicy;
    use crate::protocol::{plain_hierarchical_vote, run_sync};
    use crate::util::rng::{Rng, Xoshiro256pp};

    fn rand_signs(n: usize, d: usize, seed: u64) -> Vec<Vec<i8>> {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        (0..n).map(|_| (0..d).map(|_| rng.gen_sign()).collect()).collect()
    }

    #[test]
    fn flat_engine_equals_plain_and_secure() {
        for n in [1usize, 2, 3, 4, 6, 9] {
            for policy in [TiePolicy::OneBit, TiePolicy::TwoBit] {
                let d = 17;
                let signs = rand_signs(n, d, n as u64 * 31 + 7);
                let cfg = HiSafeConfig::flat(n, policy);
                let mut engine = RoundEngine::new(cfg, d, 5);
                let got = engine.run_round(&signs);
                let plain = plain_group_vote(&signs, policy);
                assert_eq!(got.global_vote, plain, "n={n} {policy:?} vs plain");
                let secure = secure_group_vote(&signs, policy, false, 5);
                assert_eq!(got.global_vote, secure.votes, "n={n} {policy:?} vs mpc");
            }
        }
    }

    #[test]
    fn hierarchical_engine_equals_plain_hierarchy() {
        let cfg = HiSafeConfig::hierarchical(12, 4, TiePolicy::TwoBit);
        let signs = rand_signs(12, 9, 3);
        let mut engine = RoundEngine::new(cfg, 9, 11);
        let got = engine.run_round(&signs);
        assert_eq!(got.global_vote, plain_hierarchical_vote(&signs, cfg));
        assert_eq!(got.subgroup_votes.len(), 4);
    }

    #[test]
    fn chunk_size_is_observationally_invisible() {
        let cfg = HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit);
        let signs = rand_signs(6, 23, 9);
        let baseline = RoundEngine::new(cfg, 23, 4).run_round(&signs).global_vote;
        for chunk in [1usize, 3, 8, 64] {
            let got = RoundEngine::new(cfg, 23, 4)
                .with_chunk(chunk)
                .run_round(&signs)
                .global_vote;
            assert_eq!(got, baseline, "chunk={chunk}");
        }
    }

    #[test]
    fn pool_amortizes_across_rounds() {
        let cfg = HiSafeConfig::flat(3, TiePolicy::OneBit);
        let mut engine = RoundEngine::new(cfg, 8, 2).with_batch_rounds(4);
        assert_eq!(engine.provisioned_rounds(), 0);
        for r in 0..6u64 {
            let signs = rand_signs(3, 8, 100 + r);
            let got = engine.run_round(&signs);
            assert_eq!(
                got.global_vote,
                plain_group_vote(&signs, TiePolicy::OneBit),
                "round {r}"
            );
        }
        assert_eq!(engine.rounds_run, 6);
        // 6 rounds over batches of 4 → 8 rounds dealt, 2 still pooled
        assert_eq!(engine.provisioned_rounds(), 2);
    }

    #[test]
    fn explicit_provision_feeds_rounds() {
        let cfg = HiSafeConfig::hierarchical(8, 2, TiePolicy::OneBit);
        let mut engine = RoundEngine::new(cfg, 4, 13);
        engine.provision(3);
        assert_eq!(engine.provisioned_rounds(), 3);
        let signs = rand_signs(8, 4, 21);
        let got = engine.run_round(&signs);
        assert_eq!(got.global_vote, plain_hierarchical_vote(&signs, cfg));
        assert_eq!(engine.provisioned_rounds(), 2);
    }

    #[test]
    fn unbalanced_pool_reports_min_and_refills_instead_of_panicking() {
        // Regression for the party-0-only pool accounting: overfill ONE
        // party's store so per-party balances diverge. The engine must
        // report the worst party's balance and refill when *any* party
        // runs dry — the old code read party 0, claimed a spare round,
        // skipped the refill, and panicked in take_many mid-round.
        let cfg = HiSafeConfig::flat(3, TiePolicy::OneBit);
        let d = 6;
        let mut engine = RoundEngine::new(cfg, d, 3);
        let mults = engine.plan().triples_needed();
        assert!(mults > 0, "n=3 needs secure multiplications");
        engine.provision(1);
        let fp = engine.plan().fp;
        let extra = Dealer::new(fp, 0xdead_beef).gen_round(d, 3, mults).remove(0);
        engine.pools.store_mut(0, 0).refill(extra);
        // Party 0 now holds 2 rounds, parties 1–2 hold 1: min says 1.
        assert_eq!(engine.provisioned_rounds(), 1);

        // Round 1 consumes the last round every party can cover —
        // streams are still aligned, so the vote is exact.
        let signs = rand_signs(3, d, 5);
        let got = engine.run_round(&signs);
        assert_eq!(got.global_vote, plain_group_vote(&signs, TiePolicy::OneBit));
        // Party 0 has a spare round, the others none: min says 0 (the
        // old accounting said 1 here and round 2 panicked).
        assert_eq!(engine.provisioned_rounds(), 0);

        // Round 2 must refill and complete instead of panicking. (Votes
        // are unspecified: party 0's surplus leaves its stream ahead of
        // the others' — divergence is a should-never-happen state the
        // engine survives, not one it can repair.)
        let out = engine.run_round(&rand_signs(3, d, 6));
        assert_eq!(out.global_vote.len(), d);
        assert_eq!(engine.rounds_run, 2);
    }

    #[test]
    fn stats_match_message_passing_path() {
        let cfg = HiSafeConfig::hierarchical(12, 4, TiePolicy::OneBit);
        let signs = rand_signs(12, 5, 17);
        let mut engine = RoundEngine::new(cfg, 5, 23);
        let got = engine.run_round(&signs);
        let reference = run_sync(&signs, cfg, 23);
        // Full struct equality: every analytic counter must equal the
        // measured one (engine_props.rs repeats this across random cfgs).
        assert_eq!(got.stats, reference.stats);
    }

    #[test]
    fn span_parallel_path_matches_plain_at_large_d() {
        // d above PAR_MIN_D exercises the scoped-thread span split on
        // multi-core hosts (and the sequential path on single-core ones —
        // both must produce the same votes).
        let d = PAR_MIN_D + 137;
        let cfg = HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit);
        let signs = rand_signs(6, d, 41);
        let got = RoundEngine::new(cfg, d, 19).run_round(&signs);
        assert_eq!(got.global_vote, plain_hierarchical_vote(&signs, cfg));
    }

    #[test]
    fn sparse_schedule_supported() {
        let cfg = HiSafeConfig { sparse: true, ..HiSafeConfig::flat(5, TiePolicy::OneBit) };
        let signs = rand_signs(5, 6, 29);
        let got = RoundEngine::new(cfg, 6, 1).run_round(&signs);
        assert_eq!(got.global_vote, plain_group_vote(&signs, TiePolicy::OneBit));
    }

    #[test]
    fn all_present_mask_is_the_run_round_path() {
        let cfg = HiSafeConfig::hierarchical(12, 4, TiePolicy::OneBit);
        let signs = rand_signs(12, 7, 51);
        let mut a = RoundEngine::new(cfg, 7, 23);
        let mut b = RoundEngine::new(cfg, 7, 23);
        let full = a.run_round(&signs);
        let masked = b
            .run_round_present(&signs, &ParticipantSet::all(12))
            .expect("all-present never aborts");
        assert_eq!(full.global_vote, masked.global_vote);
        assert_eq!(full.subgroup_votes, masked.subgroup_votes);
        assert_eq!(full.stats, masked.stats);
        assert_eq!(b.cohort_rekeys(), 0);
        assert_eq!(b.discarded_rounds(), 0);
    }

    #[test]
    fn churned_round_matches_reference_and_survivor_plaintext() {
        use crate::protocol::{
            plain_hierarchical_vote_present, run_sync_with_dropouts,
        };
        let cfg = HiSafeConfig::hierarchical(12, 4, TiePolicy::OneBit);
        let d = 9;
        let signs = rand_signs(12, d, 77);
        // Drop one member of group 1 and one of group 3 (n₁=3 ⇒ t=1 ⇒
        // 2 survivors is exactly at threshold).
        let mut mask = vec![true; 12];
        mask[4] = false;
        mask[10] = false;
        let present = ParticipantSet::from_mask(mask);
        let seed = 23;
        let mut engine = RoundEngine::new(cfg, d, seed);
        let got = engine.run_round_present(&signs, &present).expect("above threshold");
        let reference = run_sync_with_dropouts(&signs, &present, cfg, seed).unwrap();
        assert_eq!(got.global_vote, reference.global_vote);
        assert_eq!(got.subgroup_votes, reference.subgroup_votes);
        assert_eq!(got.stats, reference.stats);
        assert_eq!(
            got.global_vote,
            plain_hierarchical_vote_present(&signs, &present, cfg)
        );
        assert_eq!(engine.discarded_rounds(), 2); // two churned groups
    }

    #[test]
    fn stable_cohort_rekeys_once_unstable_rekeys_per_mask() {
        let cfg = HiSafeConfig::hierarchical(8, 2, TiePolicy::OneBit);
        let d = 5;
        let mut engine = RoundEngine::new(cfg, d, 9);
        let mut mask = vec![true; 8];
        mask[1] = false; // group 0 loses member 1 — a stable cohort
        let stable = ParticipantSet::from_mask(mask);
        for r in 0..4u64 {
            let signs = rand_signs(8, d, 200 + r);
            engine.run_round_present(&signs, &stable).expect("above threshold");
        }
        assert_eq!(engine.cohort_rekeys(), 1, "stable cohort pays setup once");
        // A different survivor pattern keys a second cohort…
        let mut mask2 = vec![true; 8];
        mask2[2] = false;
        engine
            .run_round_present(&rand_signs(8, d, 300), &ParticipantSet::from_mask(mask2))
            .expect("above threshold");
        assert_eq!(engine.cohort_rekeys(), 2);
        // …and returning to the first pattern hits its cache.
        engine
            .run_round_present(&rand_signs(8, d, 301), &stable)
            .expect("above threshold");
        assert_eq!(engine.cohort_rekeys(), 2);
        assert_eq!(engine.rounds_run, 6);
    }

    #[test]
    fn below_threshold_aborts_without_advancing_state() {
        let cfg = HiSafeConfig::hierarchical(10, 2, TiePolicy::OneBit);
        let d = 4;
        let signs = rand_signs(10, d, 13);
        // n₁=5 ⇒ t=2 ⇒ need 3; group 0 keeps only 2.
        let mut mask = vec![true; 10];
        mask[0] = false;
        mask[1] = false;
        mask[3] = false;
        let mut engine = RoundEngine::new(cfg, d, 7);
        let err = engine
            .run_round_present(&signs, &ParticipantSet::from_mask(mask))
            .expect_err("group 0 below threshold");
        assert_eq!(
            err,
            crate::protocol::ChurnError::BelowThreshold { group: 0, survivors: 2, required: 3 }
        );
        assert_eq!(engine.rounds_run, 0);
        assert_eq!(engine.discarded_rounds(), 0);
        assert_eq!(engine.cohort_rekeys(), 0);
    }
}
