//! Double-buffered Beaver-triple pools — the engines' offline-phase state.
//!
//! [`GroupPools`] owns one [`TripleStore`] per party per subgroup and is
//! the single place that accounts for how many rounds of triples are
//! still pooled. Two consumers share it:
//!
//! * the sequential [`crate::engine::RoundEngine`], which refills lazily
//!   on the round path (via [`GroupPools::deal_into`]), and
//! * the scheduler's [`crate::engine::AggSession`]s (and therefore the
//!   [`crate::engine::PipelinedEngine`] wrapper): pools stay **owned
//!   per-session** — no tenant can ever draw from another's stores —
//!   while the shared provisioning plane hands freshly dealt rounds over
//!   the session's private channel as [`RoundBatch`]es
//!   ([`GroupPools::refill_round`]).
//!
//! Accounting is **party-aware**: `provisioned_rounds` takes the minimum
//! remaining across *parties* as well as groups. The dealing paths always
//! refill a group's parties together (one `gen_round` per round), so the
//! per-party stores stay aligned triple-for-triple; but if the pools ever
//! diverge — a bug elsewhere, or test-induced imbalance — the engine must
//! see the *worst* party's balance. Inspecting only party 0 (the pre-PR-2
//! behavior) over-reported the pool and let `take_many` panic mid-round.

use crate::beaver::{Dealer, TripleShare, TripleStore};

/// One freshly dealt round of triples for every group:
/// `batch[group][party][mult]`. The unit of the pipelined engine's
/// provisioner → scheduler handoff channel.
pub(crate) type RoundBatch = Vec<Vec<Vec<TripleShare>>>;

/// Per-group, per-party triple pools with party-aware round accounting.
pub(crate) struct GroupPools {
    /// `pools[group][party]`.
    pools: Vec<Vec<TripleStore>>,
    /// Rounds consumed-and-discarded for churned groups (see
    /// [`GroupPools::discard_round`]) — survivor-aware accounting that
    /// keeps the full-cohort base streams in lockstep across rounds.
    discarded: usize,
}

impl GroupPools {
    /// Empty pools for `ell` groups of `n1` parties each.
    pub fn new(ell: usize, n1: usize) -> GroupPools {
        GroupPools {
            pools: (0..ell)
                .map(|_| (0..n1).map(|_| TripleStore::new(Vec::new())).collect())
                .collect(),
            discarded: 0,
        }
    }

    fn group_min_remaining(group: &[TripleStore]) -> usize {
        group.iter().map(|p| p.remaining()).min().unwrap_or(0)
    }

    /// Rounds' worth of triples every party of every group can still
    /// serve (`usize::MAX` when the plan needs no triples). Min across
    /// parties *and* groups — see the module doc for why party 0 alone
    /// is not enough.
    pub fn provisioned_rounds(&self, mults: usize) -> usize {
        if mults == 0 {
            return usize::MAX;
        }
        self.pools
            .iter()
            .map(|g| Self::group_min_remaining(g) / mults)
            .min()
            .unwrap_or(0)
    }

    /// Can group `g` *not* cover one more round for every party?
    pub fn group_needs_refill(&self, g: usize, mults: usize) -> bool {
        Self::group_min_remaining(&self.pools[g]) < mults
    }

    /// Append one freshly dealt round to group `g` — all parties together,
    /// so per-party triple streams stay aligned by construction.
    pub fn refill_group(&mut self, g: usize, round: Vec<Vec<TripleShare>>) {
        debug_assert_eq!(round.len(), self.pools[g].len(), "asymmetric deal");
        for (party, fresh) in round.into_iter().enumerate() {
            self.pools[g][party].refill(fresh);
        }
    }

    /// Absorb one provisioner handoff (one round for every group).
    pub fn refill_round(&mut self, batch: RoundBatch) {
        debug_assert_eq!(batch.len(), self.pools.len(), "wrong group count");
        for (g, round) in batch.into_iter().enumerate() {
            self.refill_group(g, round);
        }
    }

    /// Deal `rounds` rounds for group `g` from `dealer` straight into the
    /// pools — the sequential engine's (synchronous) provisioning path.
    pub fn deal_into(
        &mut self,
        g: usize,
        dealer: &mut Dealer,
        d: usize,
        mults: usize,
        rounds: usize,
    ) {
        let n1 = self.pools[g].len();
        for _ in 0..rounds {
            let round = dealer.gen_round(d, n1, mults);
            self.refill_group(g, round);
        }
    }

    /// Borrow one round's triples for group `g` (the sequential engine's
    /// consumption path): `out[party]` is a fresh `mults`-triple slice.
    pub fn take_round(&mut self, g: usize, mults: usize) -> Vec<&[TripleShare]> {
        self.pools[g].iter_mut().map(|s| s.take_many(mults)).collect()
    }

    /// Drain one round's triples for group `g` into owned vectors — the
    /// pipelined engine hands these to its `'static` span workers behind
    /// an `Arc`. Same freshness audit as [`take_round`].
    ///
    /// [`take_round`]: GroupPools::take_round
    pub fn take_round_owned(&mut self, g: usize, mults: usize) -> Vec<Vec<TripleShare>> {
        self.pools[g].iter_mut().map(|s| s.take_many_owned(mults)).collect()
    }

    /// Consume-and-discard one round's triples for group `g` — the
    /// churn path's pool advancement. A churned group evaluates with a
    /// dedicated *cohort* dealer (the pre-dealt full-cohort triples are
    /// keyed to the wrong party count), but its base stream must still
    /// advance exactly one round so that every group's pool — and the
    /// provisioning plane feeding it — stays in per-round lockstep, and
    /// a later all-present round draws the same triples it would have
    /// without the churn episode.
    pub fn discard_round(&mut self, g: usize, mults: usize) {
        for s in self.pools[g].iter_mut() {
            s.take_many(mults);
        }
        self.discarded += 1;
    }

    /// Group-rounds discarded so far via [`GroupPools::discard_round`].
    pub fn discarded_rounds(&self) -> usize {
        self.discarded
    }

    /// Direct store access for tests that need to unbalance a pool.
    #[cfg(test)]
    pub fn store_mut(&mut self, g: usize, party: usize) -> &mut TripleStore {
        &mut self.pools[g][party]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::field::Fp;

    #[test]
    fn provisioned_rounds_takes_min_across_parties_and_groups() {
        let fp = Fp::new(5);
        let mut dealer = Dealer::new(fp, 1);
        let mut pools = GroupPools::new(1, 3);
        pools.deal_into(0, &mut dealer, 4, 2, 3); // 3 rounds × 2 mults each
        assert_eq!(pools.provisioned_rounds(2), 3);
        assert!(!pools.group_needs_refill(0, 2));

        // Drain one round's worth from party 2 ONLY: the pool is now
        // unbalanced, and the accounting must report the worst party.
        // (Pre-PR-2 the engine read party 0 and still claimed 3 rounds.)
        pools.store_mut(0, 2).take_many(2);
        assert_eq!(pools.provisioned_rounds(2), 2);

        pools.store_mut(0, 2).take_many(4);
        assert_eq!(pools.provisioned_rounds(2), 0);
        assert!(pools.group_needs_refill(0, 2));

        // Refilling restores a positive (still min-across-parties) count.
        pools.deal_into(0, &mut dealer, 4, 2, 1);
        assert_eq!(pools.provisioned_rounds(2), 1);
    }

    #[test]
    fn discard_round_advances_every_party_in_lockstep() {
        let fp = Fp::new(5);
        let mut dealer = Dealer::new(fp, 3);
        let mut pools = GroupPools::new(1, 3);
        pools.deal_into(0, &mut dealer, 4, 2, 2);
        assert_eq!(pools.provisioned_rounds(2), 2);
        assert_eq!(pools.discarded_rounds(), 0);
        pools.discard_round(0, 2);
        assert_eq!(pools.provisioned_rounds(2), 1);
        assert_eq!(pools.discarded_rounds(), 1);
        // The next take draws the round the dealer generated second —
        // exactly what it would have drawn had the churn round not
        // happened on this group's base stream.
        let taken = pools.take_round(0, 2);
        assert_eq!(taken.len(), 3);
        assert_eq!(taken[0].len(), 2);
    }

    #[test]
    fn zero_mult_plans_never_need_provisioning() {
        let pools = GroupPools::new(2, 1);
        assert_eq!(pools.provisioned_rounds(0), usize::MAX);
    }

    #[test]
    fn round_batch_refill_feeds_every_group() {
        let fp = Fp::new(5);
        let mut d0 = Dealer::new(fp, 7);
        let mut d1 = Dealer::new(fp, 8);
        let mut pools = GroupPools::new(2, 3);
        let batch: RoundBatch = vec![d0.gen_round(4, 3, 2), d1.gen_round(4, 3, 2)];
        pools.refill_round(batch);
        assert_eq!(pools.provisioned_rounds(2), 1);
        let owned = pools.take_round_owned(0, 2);
        assert_eq!(owned.len(), 3); // parties
        assert_eq!(owned[0].len(), 2); // mults
        assert_eq!(pools.provisioned_rounds(2), 0); // group 0 drained
    }
}
