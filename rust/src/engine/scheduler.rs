//! The multi-tenant aggregation scheduler: many `(cfg, d)` workloads on
//! one shared worker pool and one provisioning plane.
//!
//! [`crate::engine::PipelinedEngine`] proved out the offline/online
//! overlap, but it was single-tenant: every engine spawned its own
//! [`WorkerPool`] and its own background dealer thread, so `k` concurrent
//! federations oversubscribed the machine `k`-fold. [`AggScheduler`]
//! inverts the ownership: the *scheduler* owns exactly one worker pool
//! and one provisioning plane, and hands out per-tenant [`AggSession`]
//! handles that expose the engine surface (`provision` / `run_round`) —
//! the heavy-traffic shape (ROADMAP: "multi-engine sharding across
//! configs").
//!
//! ```text
//!                ┌──────────────── AggScheduler ────────────────┐
//!                │  WorkerPool (N span threads, shared)         │
//!                │  provisioning plane (1 dealer thread,        │
//!                │    round-robin across tenants)               │
//!                └──────┬──────────────┬──────────────┬─────────┘
//!   AggSession A (cfg_A, d_A)   session B (cfg_B, d_B)   session C …
//!   own GroupPools, own plan    own GroupPools, own plan
//! ```
//!
//! **Determinism under multiplexing.** A session's votes are bit-identical
//! to a dedicated [`crate::engine::PipelinedEngine`] and to `run_sync`, no matter how
//! tenants' rounds interleave, because the only cross-tenant shared state
//! is *stateless with respect to the protocol*:
//!
//! * The provisioning plane keeps each session's per-group [`Dealer`]s
//!   private to that session's registration. Group `g` of session `s` is
//!   seeded with [`group_dealer_seed`]`(seed_s, g)` — the same derivation
//!   `run_sync` and the dedicated engines use — and the plane only ever
//!   advances a dealer in whole-round steps, in round order, regardless
//!   of which tenants' requests interleave between those steps. Party `i`
//!   of group `g` therefore consumes exactly the triple stream it would
//!   have consumed on dedicated infrastructure (pinned share-for-share by
//!   the in-crate stream test below).
//! * Span workers are pure functions of their job (`workers::eval_span`
//!   never holds state across jobs), jobs are tagged with their session
//!   id, and each session reassembles results from its own channel keyed
//!   by slot — worker interleaving across tenants cannot reorder or
//!   cross-wire any tenant's votes.
//! * [`GroupPools`] stay owned per-session; the plane only *refills* them
//!   through the session's private handoff channel.
//!
//! Fairness and isolation: the plane deals one round per request-holding
//! tenant in round-robin order (a tenant with a huge `provision` request
//! cannot starve the others), and a session dropped mid-stream simply
//! deregisters — in-flight batches for it fail their handoff send and are
//! discarded without stalling any other tenant (regression-tested).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::beaver::{Dealer, TripleShare};
use crate::mpc::EvalPlan;
use crate::poly::MvPolynomial;
use crate::protocol::{group_dealer_seed, inter_group_vote, partition, HiSafeConfig};

use super::pool::{GroupPools, RoundBatch};
use super::workers::{
    note_threads_joined, note_threads_spawned, span_split, worker_pool_threads, SpanJob,
    SpanResult, WorkerPool,
};
use super::{analytic_stats, Engine, EngineOutcome, DEFAULT_CHUNK};

/// Commands to the provisioning plane's dealer thread.
enum PlaneCmd {
    /// A new tenant: its dealers (one per group, pre-seeded), workload
    /// shape, and the handoff channel its dealt rounds go down.
    Register {
        sid: u64,
        dealers: Vec<Dealer>,
        d: usize,
        n1: usize,
        mults: usize,
        dealt_tx: Sender<RoundBatch>,
    },
    /// Deal `rounds` more rounds for tenant `sid` (queued; the plane
    /// interleaves tenants one round at a time).
    Request { sid: u64, rounds: usize },
    /// Tenant is gone; drop its dealers and any queued work.
    Deregister { sid: u64 },
}

/// One tenant's state inside the plane thread.
struct Tenant {
    sid: u64,
    dealers: Vec<Dealer>,
    d: usize,
    n1: usize,
    mults: usize,
    dealt_tx: Sender<RoundBatch>,
    /// Rounds requested but not yet dealt.
    pending: usize,
}

impl Tenant {
    /// Deal exactly one round: every group's dealer advances one
    /// whole-round step, in group order — the per-group streams stay
    /// identical to a dedicated engine's no matter what the plane dealt
    /// for other tenants in between.
    fn deal_one_round(&mut self) -> RoundBatch {
        self.dealers
            .iter_mut()
            .map(|dealer| dealer.gen_round(self.d, self.n1, self.mults))
            .collect()
    }
}

fn apply_cmd(tenants: &mut Vec<Tenant>, cmd: PlaneCmd) {
    match cmd {
        PlaneCmd::Register { sid, dealers, d, n1, mults, dealt_tx } => {
            tenants.push(Tenant { sid, dealers, d, n1, mults, dealt_tx, pending: 0 });
        }
        PlaneCmd::Request { sid, rounds } => {
            // A request for an already-deregistered session is ignored
            // (it can race the Deregister through the same channel).
            if let Some(t) = tenants.iter_mut().find(|t| t.sid == sid) {
                t.pending += rounds;
            }
        }
        PlaneCmd::Deregister { sid } => {
            tenants.retain(|t| t.sid != sid);
        }
    }
}

/// The plane's dealer loop: absorb commands (blocking only when no
/// tenant has pending work), then deal ONE round for the next pending
/// tenant in round-robin order. One round — not one request — is the
/// fairness quantum, so a tenant pre-provisioning 100 rounds cannot
/// starve another tenant's cold start.
fn plane_loop(cmd_rx: Receiver<PlaneCmd>) {
    let mut tenants: Vec<Tenant> = Vec::new();
    let mut cursor = 0usize;
    loop {
        if tenants.iter().any(|t| t.pending > 0) {
            // Drain without blocking; on disconnect keep draining pending
            // work — dead sessions' sends fail below and clean themselves
            // up.
            while let Ok(cmd) = cmd_rx.try_recv() {
                apply_cmd(&mut tenants, cmd);
            }
        } else {
            match cmd_rx.recv() {
                Ok(cmd) => {
                    apply_cmd(&mut tenants, cmd);
                    continue;
                }
                // Scheduler and every session dropped: plane exits.
                Err(_) => return,
            }
        }

        let k = tenants.len();
        for step in 0..k {
            let i = (cursor + step) % k;
            if tenants[i].pending == 0 {
                continue;
            }
            let batch = tenants[i].deal_one_round();
            tenants[i].pending -= 1;
            if tenants[i].dealt_tx.send(batch).is_ok() {
                cursor = (i + 1) % k;
            } else {
                // Session dropped mid-stream: discard it without
                // touching any other tenant's queue. The tenant that
                // shifts into slot `i` is the rightful next in
                // round-robin order, so the cursor points at it.
                tenants.remove(i);
                cursor = if tenants.is_empty() { 0 } else { i % tenants.len() };
            }
            break;
        }
    }
}

/// Shared infrastructure: the one worker pool and the one provisioning
/// plane every session of a scheduler runs on. Sessions keep it alive
/// through an `Arc`, so a scheduler handle may be dropped while its
/// sessions keep running.
struct SchedCore {
    workers: WorkerPool,
    /// Kept open for registering new sessions; closing it (last owner
    /// dropping) is what lets the plane thread exit.
    plane_tx: Option<Sender<PlaneCmd>>,
    plane: Option<JoinHandle<()>>,
    next_sid: AtomicU64,
}

impl Drop for SchedCore {
    fn drop(&mut self) {
        // Close the command channel first (sessions' clones are already
        // gone — they hold the Arc this drop is the last owner of), then
        // join: the plane's blocking recv errors out and it returns.
        drop(self.plane_tx.take());
        if let Some(h) = self.plane.take() {
            let _ = h.join();
            note_threads_joined(1);
        }
        // WorkerPool's own Drop closes the job queue and joins workers.
    }
}

/// Multi-tenant aggregation scheduler: owns exactly one process-visible
/// [`WorkerPool`] and one provisioning plane, multiplexing any number of
/// concurrent `(HiSafeConfig, d, seed)` tenants. Create tenants with
/// [`AggScheduler::session`]; each [`AggSession`] exposes the familiar
/// engine surface and produces votes bit-identical to a dedicated
/// [`PipelinedEngine`](super::PipelinedEngine) and to
/// [`run_sync`](crate::protocol::run_sync), however tenants interleave.
///
/// The handle is cheap to clone (it is an `Arc` underneath); the shared
/// threads live until the last handle *and* last session are gone.
#[derive(Clone)]
pub struct AggScheduler {
    core: Arc<SchedCore>,
}

impl Default for AggScheduler {
    fn default() -> Self {
        Self::new()
    }
}

impl AggScheduler {
    /// A scheduler with the default thread policy: every available core
    /// up to the bandwidth cap, overridable via `HISAFE_THREADS`
    /// (resolved once, here — never re-read on the round path).
    pub fn new() -> AggScheduler {
        Self::with_threads(worker_pool_threads())
    }

    /// A scheduler with an explicitly pinned worker count — tests pin
    /// `threads = 1` for deterministic single-threaded evaluation.
    pub fn with_threads(threads: usize) -> AggScheduler {
        assert!(threads >= 1, "scheduler needs at least one worker thread");
        let (plane_tx, cmd_rx) = channel::<PlaneCmd>();
        let plane = std::thread::spawn(move || plane_loop(cmd_rx));
        note_threads_spawned(1);
        AggScheduler {
            core: Arc::new(SchedCore {
                workers: WorkerPool::new(threads),
                plane_tx: Some(plane_tx),
                plane: Some(plane),
                next_sid: AtomicU64::new(0),
            }),
        }
    }

    /// Live span-worker threads — one pool's worth, shared by every
    /// session, independent of how many tenants are registered.
    pub fn worker_threads(&self) -> usize {
        self.core.workers.threads()
    }

    /// Threads in the provisioning plane (currently a single dealer
    /// thread round-robining across tenants).
    pub fn dealer_threads(&self) -> usize {
        1
    }

    /// Open a tenant session for `cfg` over `d`-coordinate votes. `seed`
    /// drives all of this tenant's offline randomness, one independent
    /// stream per subgroup — the same [`group_dealer_seed`] derivation as
    /// [`run_sync`](crate::protocol::run_sync) and the dedicated engines,
    /// which is what keeps sessions bit-identical to them.
    ///
    /// Dealing for the session's first round starts immediately on the
    /// shared plane, so caller-side work before the first `run_round`
    /// already overlaps the offline phase.
    pub fn session(&self, cfg: HiSafeConfig, d: usize, seed: u64) -> AggSession {
        let n1 = cfg.n1();
        let mv = MvPolynomial::build_fermat(n1, cfg.intra);
        let plan = Arc::new(EvalPlan::new(&mv, d, cfg.sparse));
        let dealers: Vec<Dealer> = (0..cfg.ell)
            .map(|g| Dealer::new(plan.fp, group_dealer_seed(seed, g)))
            .collect();
        let mults = plan.triples_needed();
        let sid = self.core.next_sid.fetch_add(1, Ordering::Relaxed);
        let plane_tx = self.core.plane_tx.as_ref().expect("plane open").clone();
        let (dealt_tx, dealt_rx) = channel::<RoundBatch>();
        plane_tx
            .send(PlaneCmd::Register { sid, dealers, d, n1, mults, dealt_tx })
            .expect("provisioning plane alive");
        let mut session = AggSession {
            sid,
            cfg,
            d,
            plan,
            pools: GroupPools::new(cfg.ell, n1),
            plane_tx,
            dealt_rx,
            jobs: self.core.workers.sender(),
            threads: self.core.workers.threads(),
            batch_rounds: 1,
            inflight_rounds: 0,
            chunk: DEFAULT_CHUNK,
            rounds_run: 0,
            core: Arc::clone(&self.core),
        };
        if mults > 0 {
            session.request_rounds(1);
        }
        session
    }
}

/// One tenant's handle on shared scheduler infrastructure: its own
/// [`EvalPlan`] and [`GroupPools`], refilled by the shared provisioning
/// plane and evaluated on the shared worker pool. Implements [`Engine`]
/// with the exact `PipelinedEngine` semantics (which is now a thin
/// wrapper around a single-tenant session).
pub struct AggSession {
    sid: u64,
    cfg: HiSafeConfig,
    d: usize,
    plan: Arc<EvalPlan>,
    /// Front buffer: rounds ready to consume (owned per-session).
    pools: GroupPools,
    /// Command path to the shared plane (also keeps the plane alive).
    plane_tx: Sender<PlaneCmd>,
    /// This session's private handoff channel from the plane.
    dealt_rx: Receiver<RoundBatch>,
    /// This session's handle on the shared job queue. Span results come
    /// back on a channel created fresh per round (see `run_round`): the
    /// round drops its sender after submission, so a worker dying before
    /// delivering a slot disconnects the channel and fails loudly
    /// instead of blocking the session forever.
    jobs: Sender<SpanJob>,
    /// Worker count, resolved once by the scheduler at construction.
    threads: usize,
    /// Rounds per provisioning request (default 1 — the double buffer).
    batch_rounds: usize,
    /// Rounds requested from the plane but not yet absorbed.
    inflight_rounds: usize,
    chunk: usize,
    rounds_run: u64,
    /// Keeps the shared pool + plane alive while any session runs.
    /// Declared last: the drop-order guarantee means our `plane_tx`
    /// clone is gone before the core (possibly) joins the plane thread.
    core: Arc<SchedCore>,
}

impl Drop for AggSession {
    fn drop(&mut self) {
        // Best-effort: stop the plane dealing rounds nobody will read.
        // The handoff channel closing is the hard backstop — a racing
        // in-flight batch fails its send and evicts the tenant anyway.
        let _ = self.plane_tx.send(PlaneCmd::Deregister { sid: self.sid });
    }
}

impl AggSession {
    /// The session id the scheduler assigned this tenant (diagnostic;
    /// span jobs and results are tagged with it).
    pub fn id(&self) -> u64 {
        self.sid
    }

    fn request_rounds(&mut self, rounds: usize) {
        self.plane_tx
            .send(PlaneCmd::Request { sid: self.sid, rounds })
            .expect("provisioning plane alive");
        self.inflight_rounds += rounds;
    }

    fn recv_one_round(&mut self) {
        let batch = self.dealt_rx.recv().expect("provisioning plane alive");
        self.pools.refill_round(batch);
        self.inflight_rounds -= 1;
    }

    fn absorb_ready_batches(&mut self) {
        while let Ok(batch) = self.dealt_rx.try_recv() {
            self.pools.refill_round(batch);
            self.inflight_rounds -= 1;
        }
    }

    /// Test-only view of the front buffer (the stream-derivation test
    /// audits pooled triples share-for-share).
    #[cfg(test)]
    pub(crate) fn pools_mut(&mut self) -> &mut GroupPools {
        &mut self.pools
    }
}

impl Engine for AggSession {
    fn with_chunk(mut self, chunk: usize) -> AggSession {
        assert!(chunk >= 1, "chunk must be ≥ 1");
        self.chunk = chunk;
        self
    }

    fn with_batch_rounds(mut self, rounds: usize) -> AggSession {
        assert!(rounds >= 1, "batch must be ≥ 1");
        self.batch_rounds = rounds;
        self
    }

    fn plan(&self) -> &EvalPlan {
        &self.plan
    }

    fn provisioned_rounds(&self) -> usize {
        self.pools.provisioned_rounds(self.plan.triples_needed())
    }

    fn provision(&mut self, rounds: usize) {
        let mults = self.plan.triples_needed();
        if mults == 0 {
            return;
        }
        self.absorb_ready_batches();
        while self.pools.provisioned_rounds(mults) < rounds {
            if self.inflight_rounds == 0 {
                let missing = rounds - self.pools.provisioned_rounds(mults);
                self.request_rounds(missing);
            }
            self.recv_one_round();
        }
    }

    fn run_round(&mut self, signs: &[Vec<i8>]) -> EngineOutcome {
        assert_eq!(signs.len(), self.cfg.n, "need exactly n sign vectors");
        for (i, s) in signs.iter().enumerate() {
            assert_eq!(s.len(), self.d, "user {i} dimension mismatch");
        }
        let mults = self.plan.triples_needed();
        if mults > 0 {
            // Absorb whatever the plane finished since the last round,
            // without blocking.
            self.absorb_ready_batches();
            // Cold start / catch-up: block until this round is covered.
            while self.pools.provisioned_rounds(mults) == 0 {
                if self.inflight_rounds == 0 {
                    self.request_rounds(self.batch_rounds);
                }
                self.recv_one_round();
            }
            // The overlap: keep a batch in flight so round r+1's triples
            // are dealt while this round's online phase evaluates below.
            if self.inflight_rounds == 0
                && self.pools.provisioned_rounds(mults) < 1 + self.batch_rounds
            {
                self.request_rounds(self.batch_rounds);
            }
        }

        let fp = self.plan.fp;
        let d = self.d;
        let n1 = self.cfg.n1();
        let groups = partition(self.cfg.n, self.cfg.ell);
        // Same split policy as the sequential engine; below PAR_MIN_D
        // one span per group still parallelizes across groups.
        let spans = span_split(d, self.threads);
        let span_len = d.div_ceil(spans);

        // Per-round result channel: jobs carry clones of out_tx, the
        // round drops its own sender after submission, and reassembly is
        // slot-keyed — so worker completion order cannot affect votes,
        // other tenants' in-flight rounds cannot cross-wire them (the
        // channel is private to this session's round, with the session
        // tag asserted on receipt), and a worker panicking before it
        // sends disconnects the channel instead of hanging the round.
        let (out_tx, out_rx) = channel::<SpanResult>();
        // slot -> (group, base, len)
        let mut slots: Vec<(usize, usize, usize)> = Vec::new();
        for (g, members) in groups.iter().enumerate() {
            // Cloning the members' sign vectors makes the job 'static for
            // the shared workers — n₁·d bytes per group, well under 1% of
            // the round's field work (see PipelinedEngine's history).
            let group_signs: Arc<Vec<Vec<i8>>> =
                Arc::new(members.iter().map(|&u| signs[u].clone()).collect());
            let triples: Arc<Vec<Vec<TripleShare>>> = Arc::new(if mults > 0 {
                self.pools.take_round_owned(g, mults)
            } else {
                vec![Vec::new(); n1]
            });
            let mut base = 0usize;
            while base < d {
                let len = span_len.min(d - base);
                let slot = slots.len();
                slots.push((g, base, len));
                self.jobs
                    .send(SpanJob {
                        session: self.sid,
                        fp,
                        plan: Arc::clone(&self.plan),
                        signs: Arc::clone(&group_signs),
                        triples: Arc::clone(&triples),
                        base,
                        len,
                        chunk: self.chunk,
                        slot,
                        out: out_tx.clone(),
                    })
                    .expect("shared worker pool alive");
                base += len;
            }
        }
        drop(out_tx);

        let mut subgroup_votes: Vec<Vec<i8>> = vec![vec![0i8; d]; groups.len()];
        for _ in 0..slots.len() {
            let (sid, slot, span_votes) = out_rx.recv().expect("span worker alive");
            assert_eq!(sid, self.sid, "span result crossed sessions");
            let (g, b, len) = slots[slot];
            subgroup_votes[g][b..b + len].copy_from_slice(&span_votes);
        }

        let global_vote = inter_group_vote(&subgroup_votes, self.cfg.inter);
        let stats = analytic_stats(&self.cfg, &self.plan, d);
        self.rounds_run += 1;
        EngineOutcome { global_vote, subgroup_votes, stats }
    }

    fn rounds_run(&self) -> u64 {
        self.rounds_run
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpc::plain_group_vote;
    use crate::poly::TiePolicy;
    use crate::protocol::plain_hierarchical_vote;
    use crate::util::rng::{Rng, Xoshiro256pp};

    fn rand_signs(n: usize, d: usize, seed: u64) -> Vec<Vec<i8>> {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        (0..n).map(|_| (0..d).map(|_| rng.gen_sign()).collect()).collect()
    }

    #[test]
    fn two_tenants_interleaved_match_plain_references() {
        let sched = AggScheduler::with_threads(2);
        let cfg_a = HiSafeConfig::hierarchical(12, 4, TiePolicy::TwoBit);
        let cfg_b = HiSafeConfig::flat(5, TiePolicy::OneBit);
        let mut a = sched.session(cfg_a, 9, 11);
        let mut b = sched.session(cfg_b, 17, 3);
        for r in 0..4u64 {
            let signs_a = rand_signs(12, 9, 100 + r);
            let signs_b = rand_signs(5, 17, 200 + r);
            // Alternate which tenant goes first so rounds interleave in
            // both orders.
            if r % 2 == 0 {
                let got = a.run_round(&signs_a);
                assert_eq!(got.global_vote, plain_hierarchical_vote(&signs_a, cfg_a));
                let got = b.run_round(&signs_b);
                assert_eq!(got.global_vote, plain_group_vote(&signs_b, TiePolicy::OneBit));
            } else {
                let got = b.run_round(&signs_b);
                assert_eq!(got.global_vote, plain_group_vote(&signs_b, TiePolicy::OneBit));
                let got = a.run_round(&signs_a);
                assert_eq!(got.global_vote, plain_hierarchical_vote(&signs_a, cfg_a));
            }
        }
        assert_eq!(a.rounds_run(), 4);
        assert_eq!(b.rounds_run(), 4);
    }

    #[test]
    fn k_tenants_share_exactly_one_pool_and_one_plane() {
        // Accessor-contract check: the counts the sweep command and the
        // bench report must stay at one pool's worth however many
        // tenants register. (The accessors return construction-time
        // facts; the *measured* live-thread assertion — a spawn/join
        // gauge proving sessions spawn nothing — lives in
        // rust/tests/thread_budget.rs, a single-test process where the
        // gauge is race-free.)
        let sched = AggScheduler::with_threads(2);
        assert_eq!(sched.worker_threads(), 2);
        assert_eq!(sched.dealer_threads(), 1);
        let mut sessions: Vec<AggSession> = (0..4)
            .map(|i| {
                sched.session(
                    HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit),
                    5 + i,
                    i as u64,
                )
            })
            .collect();
        assert_eq!(sched.worker_threads(), 2, "sessions must not spawn workers");
        assert_eq!(sched.dealer_threads(), 1, "sessions must not spawn dealers");
        for (i, s) in sessions.iter_mut().enumerate() {
            let signs = rand_signs(6, 5 + i, 7 + i as u64);
            let got = s.run_round(&signs);
            assert_eq!(
                got.global_vote,
                plain_hierarchical_vote(&signs, HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit))
            );
        }
        assert_eq!(sched.worker_threads(), 2);
    }

    #[test]
    fn dropping_one_session_mid_stream_leaves_others_running() {
        let sched = AggScheduler::with_threads(1);
        let cfg = HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit);
        let mut keep_a = sched.session(cfg, 7, 1);
        let mut dropped = sched.session(cfg, 7, 2).with_batch_rounds(3);
        let mut keep_b = sched.session(cfg, 7, 3);
        for r in 0..2u64 {
            for s in [&mut keep_a, &mut dropped, &mut keep_b] {
                let signs = rand_signs(6, 7, 10 + r);
                let got = s.run_round(&signs);
                assert_eq!(got.global_vote, plain_hierarchical_vote(&signs, cfg));
            }
        }
        // Drop the middle tenant while it still has batches in flight
        // (batch_rounds = 3 keeps its prefetch queue non-empty).
        drop(dropped);
        // Survivors must neither stall nor corrupt: both blocking
        // provisioning (provision) and the normal round path still work.
        keep_a.provision(2);
        assert!(keep_a.provisioned_rounds() >= 2);
        for r in 0..3u64 {
            for s in [&mut keep_a, &mut keep_b] {
                let signs = rand_signs(6, 7, 20 + r);
                let got = s.run_round(&signs);
                assert_eq!(got.global_vote, plain_hierarchical_vote(&signs, cfg));
            }
        }
        assert_eq!(keep_a.rounds_run(), 5);
        assert_eq!(keep_b.rounds_run(), 5);
    }

    #[test]
    fn sessions_outlive_their_scheduler_handle() {
        let cfg = HiSafeConfig::flat(3, TiePolicy::OneBit);
        let mut session = {
            let sched = AggScheduler::with_threads(1);
            sched.session(cfg, 6, 9)
            // scheduler handle dropped here; the Arc'd core survives
        };
        for r in 0..3u64 {
            let signs = rand_signs(3, 6, 30 + r);
            let got = session.run_round(&signs);
            assert_eq!(got.global_vote, plain_group_vote(&signs, TiePolicy::OneBit));
        }
    }

    #[test]
    fn zero_mult_tenants_never_touch_the_plane() {
        // n₁ = 1 makes the vote polynomial the identity — no triples, no
        // provisioning, and the session must not block on the plane.
        let sched = AggScheduler::with_threads(1);
        let mut s = sched.session(HiSafeConfig::flat(1, TiePolicy::OneBit), 7, 3);
        let signs = rand_signs(1, 7, 9);
        let got = s.run_round(&signs);
        assert_eq!(got.global_vote, plain_group_vote(&signs, TiePolicy::OneBit));
    }

    #[test]
    fn multiplexed_triple_streams_match_group_dealer_seed_derivation() {
        // Vote equality alone cannot pin the offline phase: Beaver masks
        // cancel exactly, so votes come out right under ANY triple
        // stream. This pins the streams themselves — with TWO tenants
        // interleaving their dealing on the shared plane, each session's
        // pooled triples must equal, share for share and round for
        // round, a dealer seeded with `group_dealer_seed(seed, g)` (the
        // run_sync derivation). A regression that let one tenant's
        // dealing advance another's streams (or collapsed the per-group
        // stride) fails here and nowhere else.
        let sched = AggScheduler::with_threads(1);
        let cfg = HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit);
        let d = 5;
        let (seed_a, seed_b) = (77u64, 91u64);
        let mut a = sched.session(cfg, d, seed_a);
        let mut b = sched.session(cfg, d, seed_b);
        let mults = a.plan().triples_needed();
        assert!(mults > 0, "n₁=3 needs secure multiplications");
        let fp = a.plan().fp;
        // Interleave the provisioning so the plane alternates tenants.
        a.provision(1);
        b.provision(2);
        a.provision(2);
        for (session, seed) in [(&mut a, seed_a), (&mut b, seed_b)] {
            for g in 0..cfg.ell {
                let mut reference = Dealer::new(fp, group_dealer_seed(seed, g));
                for round in 0..2 {
                    let expect = reference.gen_round(d, cfg.n1(), mults);
                    for (party, expect_party) in expect.iter().enumerate() {
                        let got = session.pools_mut().store_mut(g, party).take_many(mults);
                        assert_eq!(got.len(), mults);
                        for (t, e) in got.iter().zip(expect_party) {
                            assert_eq!(t.a, e.a, "seed={seed} g={g} party={party} round={round}");
                            assert_eq!(t.b, e.b, "seed={seed} g={g} party={party} round={round}");
                            assert_eq!(t.c, e.c, "seed={seed} g={g} party={party} round={round}");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn per_tenant_chunk_and_batch_are_observationally_invisible() {
        let sched = AggScheduler::with_threads(2);
        let cfg = HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit);
        let signs = rand_signs(6, 23, 9);
        let baseline = plain_hierarchical_vote(&signs, cfg);
        for (chunk, batch) in [(1usize, 1usize), (3, 2), (64, 3)] {
            let got = sched
                .session(cfg, 23, 4)
                .with_chunk(chunk)
                .with_batch_rounds(batch)
                .run_round(&signs)
                .global_vote;
            assert_eq!(got, baseline, "chunk={chunk} batch={batch}");
        }
    }
}
