//! The multi-tenant aggregation scheduler: many `(cfg, d)` workloads on
//! one shared worker pool and one provisioning plane.
//!
//! [`crate::engine::PipelinedEngine`] proved out the offline/online
//! overlap, but it was single-tenant: every engine spawned its own
//! [`WorkerPool`] and its own background dealer thread, so `k` concurrent
//! federations oversubscribed the machine `k`-fold. [`AggScheduler`]
//! inverts the ownership: the *scheduler* owns exactly one worker pool
//! and one provisioning plane, and hands out per-tenant [`AggSession`]
//! handles that expose the engine surface (`provision` / `run_round`) —
//! the heavy-traffic shape (ROADMAP: "multi-engine sharding across
//! configs").
//!
//! ```text
//!                ┌──────────────── AggScheduler ────────────────┐
//!                │  WorkerPool (N span threads, shared)         │
//!                │  provisioning plane (1 dealer thread,        │
//!                │    weighted round-robin across tenants)      │
//!                └──────┬──────────────┬──────────────┬─────────┘
//!   AggSession A (cfg_A, d_A)   session B (cfg_B, d_B)   session C …
//!   own GroupPools, own plan    own GroupPools, own plan
//! ```
//!
//! **Determinism under multiplexing.** A session's votes are bit-identical
//! to a dedicated [`crate::engine::PipelinedEngine`] and to `run_sync`, no matter how
//! tenants' rounds interleave, because the only cross-tenant shared state
//! is *stateless with respect to the protocol*:
//!
//! * The provisioning plane keeps each session's per-group [`Dealer`]s
//!   private to that session's registration. Group `g` of session `s` is
//!   seeded with [`group_dealer_seed`]`(seed_s, g)` — the same derivation
//!   `run_sync` and the dedicated engines use — and the plane only ever
//!   advances a dealer in whole-round steps, in round order, regardless
//!   of which tenants' requests interleave between those steps. Party `i`
//!   of group `g` therefore consumes exactly the triple stream it would
//!   have consumed on dedicated infrastructure (pinned share-for-share by
//!   the in-crate stream test below).
//! * Span workers are pure functions of their job (`workers::eval_span`
//!   never holds state across jobs), jobs are tagged with their session
//!   id, and each session reassembles results from its own channel keyed
//!   by slot — worker interleaving across tenants cannot reorder or
//!   cross-wire any tenant's votes.
//! * [`GroupPools`] stay owned per-session; the plane only *refills* them
//!   through the session's private handoff channel.
//!
//! Fairness and isolation: the plane runs **weighted round-robin** over
//! request-holding tenants — each tenant gets [`QosPolicy::weight`]
//! one-round dealing quanta per cycle, so a tenant with a huge
//! `provision` request cannot starve the others, and priority tenants get
//! proportionally more dealing bandwidth — and a session dropped
//! mid-stream simply deregisters: in-flight batches for it fail their
//! handoff send and are discarded without stalling any other tenant
//! (regression-tested).
//!
//! # Admission control and per-tenant QoS
//!
//! Unbounded tenants are fine for a handful of federations, but under
//! heavy traffic one greedy tenant enqueueing thousands of rounds (or a
//! burst of cold-start `provision` calls) degrades every session on the
//! shared pool. Every session therefore carries a [`QosPolicy`]:
//!
//! * **Bounded dealing queue** ([`QosPolicy::queue_depth`]): at most
//!   `depth` rounds may be queued on the plane plus pooled at once;
//!   excess [`AggSession::try_prefetch`] requests fail with
//!   [`AdmissionError::QueueFull`] instead of queueing silently.
//! * **Token buckets** ([`QosPolicy::rounds_per_sec`],
//!   [`QosPolicy::triples_per_sec`]): sustained-rate budgets for admitted
//!   rounds and for Beaver-triple dealing demand, with a configurable
//!   burst ([`QosPolicy::burst_rounds`]). An exhausted bucket fails the
//!   request with [`AdmissionError::Throttled`] carrying a concrete
//!   `retry_after`.
//! * **Dealing weight** ([`QosPolicy::weight`]): the tenant's share of
//!   the provisioning plane's weighted round-robin.
//! * **Tenant cap** ([`AggScheduler::with_capacity`]): `try_session`
//!   refuses new tenants with [`AdmissionError::Rejected`] once the
//!   scheduler is at capacity.
//!
//! The QoS-checked surface is [`AggSession::try_run_round`] /
//! [`AggSession::try_prefetch`]; the blocking [`Engine`] surface
//! (`run_round` / `provision`) stays infallible and rate-limiter-exempt
//! so existing callers and the determinism properties are untouched.
//! **Throttling never changes votes**: admission only decides *when* a
//! round runs, and triple streams are pure functions of the session seed,
//! so a throttled-and-retried round is bit-identical to an unthrottled
//! one (pinned by `rust/tests/sched_admission_props.rs`).

use std::collections::HashMap;
use std::fmt;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::beaver::{Dealer, TripleShare};
use crate::metrics::{AdmissionStats, CommStats};
use crate::mpc::EvalPlan;
use crate::poly::MvPolynomial;
use crate::protocol::{
    check_thresholds, group_dealer_seed, inter_group_vote_q, partition, recover_cohort_key,
    ChurnError, HiSafeConfig, ParticipantSet,
};

use super::pool::{GroupPools, RoundBatch};
use super::workers::{
    note_threads_joined, note_threads_spawned, span_split, worker_pool_threads, SpanJob,
    SpanResult, WorkerPool,
};
use super::{analytic_group_stats, analytic_stats, CohortState, Engine, EngineOutcome, DEFAULT_CHUNK};

/// A scheduler-assigned session (tenant) identifier.
///
/// One newtype owns the id everywhere a session crosses a boundary — the
/// scheduler's tenant registry, the frontend's placement table, the wire
/// protocol, the balancer's routing map — replacing the raw-`u64`
/// plumbing that let any counter masquerade as a session. The wire form
/// is defined *here*, once: [`Display`](fmt::Display) renders the id as
/// the decimal string the JSON protocol carries (u64s ride as strings
/// because JSON numbers are f64), and [`FromStr`](std::str::FromStr)
/// parses exactly that form back.
///
/// ```
/// use hisafe::engine::SessionId;
///
/// let sid = SessionId::new(42);
/// assert_eq!(sid.to_string(), "42");
/// assert_eq!("42".parse::<SessionId>().unwrap(), sid);
/// assert_eq!(sid.as_u64(), 42);
/// ```
#[derive(Copy, Clone, PartialEq, Eq, PartialOrd, Ord, Hash, Debug)]
pub struct SessionId(u64);

impl SessionId {
    /// Wrap a raw id (the scheduler's counter, or a parsed wire value).
    pub const fn new(raw: u64) -> SessionId {
        SessionId(raw)
    }

    /// The raw integer form (for counters and worker-pool job tags).
    pub const fn as_u64(self) -> u64 {
        self.0
    }
}

impl fmt::Display for SessionId {
    /// The decimal-string wire form (`proto.rs` serializes ids with it).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::str::FromStr for SessionId {
    type Err = std::num::ParseIntError;

    fn from_str(s: &str) -> Result<SessionId, Self::Err> {
        s.parse::<u64>().map(SessionId)
    }
}

/// A serializable point-in-time description of an [`AggSession`]:
/// everything needed to resume the session *bit-identically* on another
/// scheduler, shard, or host.
///
/// `(cfg, d, seed)` pins the per-group triple streams (they are pure
/// functions of [`group_dealer_seed`]`(seed, g)`), and `rounds` counts
/// the whole rounds of triples the session has consumed — dealers only
/// ever advance in whole-round steps, so fast-forwarding fresh dealers
/// by `rounds` rounds reproduces the stream position exactly.
/// [`AggScheduler::try_session_resumed`] performs that replay; the
/// service layer ships this struct over the wire as
/// `SessionSnapshot`/`SessionRestore` messages.
#[derive(Clone, Debug, PartialEq)]
pub struct SessionSnapshot {
    /// The protocol configuration the session aggregates for.
    pub cfg: HiSafeConfig,
    /// The vote dimension.
    pub d: usize,
    /// The seed all offline randomness derives from.
    pub seed: u64,
    /// The admission policy the session runs under.
    pub qos: QosPolicy,
    /// Whole rounds already consumed (dealer fast-forward distance).
    pub rounds: u64,
}

/// Per-tenant quality-of-service policy, fixed at session admission.
///
/// The default ([`QosPolicy::unlimited`]) reproduces the pre-admission
/// scheduler exactly: weight 1, unbounded queue, no rate limits — so
/// QoS is strictly opt-in per tenant.
///
/// ```
/// use hisafe::engine::QosPolicy;
///
/// let qos = QosPolicy::unlimited()
///     .with_weight(3)            // 3x dealing bandwidth share
///     .with_queue_depth(8)       // at most 8 rounds queued + pooled
///     .with_rounds_per_sec(50.0) // sustained online-round budget
///     .with_burst_rounds(2.0);   // allow 2-round bursts
/// assert_eq!(qos.weight, 3);
/// assert_eq!(qos.queue_depth, Some(8));
/// ```
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QosPolicy {
    /// Weighted-round-robin share of the provisioning plane: a tenant
    /// with weight `w` gets `w` one-round dealing quanta per cycle while
    /// it has pending requests. Must be ≥ 1.
    pub weight: u32,
    /// Bound on the tenant's dealing queue: rounds requested-but-undealt
    /// plus rounds pooled may never exceed this. `None` = unbounded.
    pub queue_depth: Option<usize>,
    /// Sustained budget of admitted rounds per second on the
    /// [`AggSession::try_run_round`] path. `None` = unlimited.
    pub rounds_per_sec: Option<f64>,
    /// Sustained budget of Beaver-triple dealing demand per second, in
    /// triples (one round of a session costs `triples_needed() · ℓ`).
    /// Every round of dealing demand is charged exactly once: at
    /// [`AggSession::try_prefetch`] time for prefetched rounds, or at
    /// admission for rounds no prefetch already paid for. `None` =
    /// unlimited.
    pub triples_per_sec: Option<f64>,
    /// Burst capacity of both token buckets, in rounds (≥ 1): how many
    /// rounds may be admitted back-to-back after an idle period.
    pub burst_rounds: f64,
}

impl Default for QosPolicy {
    fn default() -> Self {
        Self::unlimited()
    }
}

impl QosPolicy {
    /// No limits at all — the pre-admission scheduler behavior.
    pub fn unlimited() -> QosPolicy {
        QosPolicy {
            weight: 1,
            queue_depth: None,
            rounds_per_sec: None,
            triples_per_sec: None,
            burst_rounds: 1.0,
        }
    }

    /// Set the weighted-round-robin dealing weight (≥ 1).
    pub fn with_weight(mut self, weight: u32) -> QosPolicy {
        self.weight = weight;
        self
    }

    /// Bound the dealing queue (requested-but-undealt + pooled rounds).
    pub fn with_queue_depth(mut self, depth: usize) -> QosPolicy {
        self.queue_depth = Some(depth);
        self
    }

    /// Set the sustained admitted-rounds-per-second budget.
    pub fn with_rounds_per_sec(mut self, rps: f64) -> QosPolicy {
        self.rounds_per_sec = Some(rps);
        self
    }

    /// Set the sustained triples-per-second dealing budget.
    pub fn with_triples_per_sec(mut self, tps: f64) -> QosPolicy {
        self.triples_per_sec = Some(tps);
        self
    }

    /// Set the burst capacity of both buckets, in rounds (≥ 1).
    pub fn with_burst_rounds(mut self, rounds: f64) -> QosPolicy {
        self.burst_rounds = rounds;
        self
    }

    /// Reject policies no session could ever make progress under —
    /// checked once at admission so the round path never revalidates.
    /// Public so transport front-ends ([`crate::service`]) can refuse a
    /// bad policy before placing the tenant on a shard; the scheduler
    /// still re-checks at [`AggScheduler::try_session`] time, so the
    /// invariant never depends on callers remembering to validate.
    pub fn validate(&self) -> Result<(), AdmissionError> {
        let bad = |reason: String| Err(AdmissionError::Rejected { reason });
        if self.weight == 0 {
            return bad("QosPolicy.weight must be ≥ 1".into());
        }
        if self.queue_depth == Some(0) {
            return bad("QosPolicy.queue_depth must be ≥ 1 (or None)".into());
        }
        for (name, rate) in [
            ("rounds_per_sec", self.rounds_per_sec),
            ("triples_per_sec", self.triples_per_sec),
        ] {
            if let Some(r) = rate {
                if !r.is_finite() || r <= 0.0 {
                    return bad(format!("QosPolicy.{name} must be finite and > 0, got {r}"));
                }
            }
        }
        if !self.burst_rounds.is_finite() || self.burst_rounds < 1.0 {
            return bad(format!(
                "QosPolicy.burst_rounds must be finite and ≥ 1, got {}",
                self.burst_rounds
            ));
        }
        Ok(())
    }
}

/// Typed backpressure from the admission layer — what used to be silent
/// queueing is now an explicit, caller-visible decision.
#[derive(Debug, Clone, PartialEq)]
pub enum AdmissionError {
    /// The request can never be admitted under the current configuration
    /// (scheduler at tenant capacity, invalid policy, or a prefetch
    /// larger than the whole queue). Retrying is pointless.
    Rejected {
        /// Human-readable explanation for logs and error chains.
        reason: String,
    },
    /// A token bucket (rounds/sec or triples/sec) is empty. The request
    /// is well-formed; retry after roughly `retry_after`.
    Throttled {
        /// Time until the bucket holds enough tokens for this request.
        retry_after: Duration,
    },
    /// The tenant's bounded dealing queue is at its configured depth;
    /// consume pooled rounds (run rounds) before requesting more.
    QueueFull {
        /// The configured [`QosPolicy::queue_depth`].
        depth: usize,
    },
    /// This round's participant set left a subgroup below its t-of-n
    /// reconstruction threshold ([`crate::protocol::ChurnError`] carried
    /// across the admission surface). The *round* aborts — retrying with
    /// the same survivor set is pointless, but the session stays healthy
    /// and the next round's participant set is judged on its own.
    ChurnBelowThreshold {
        /// The subgroup that fell below threshold.
        group: usize,
        /// Members of that subgroup present this round.
        survivors: usize,
        /// Minimum survivors required (`group_threshold(n₁) + 1`).
        required: usize,
    },
}

impl From<ChurnError> for AdmissionError {
    fn from(e: ChurnError) -> AdmissionError {
        match e {
            ChurnError::BelowThreshold { group, survivors, required } => {
                AdmissionError::ChurnBelowThreshold { group, survivors, required }
            }
        }
    }
}

impl fmt::Display for AdmissionError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            AdmissionError::Rejected { reason } => write!(f, "admission rejected: {reason}"),
            AdmissionError::Throttled { retry_after } => {
                write!(f, "throttled: retry after {retry_after:?}")
            }
            AdmissionError::QueueFull { depth } => {
                write!(f, "dealing queue full (depth {depth})")
            }
            AdmissionError::ChurnBelowThreshold { group, survivors, required } => write!(
                f,
                "round aborted: subgroup {group} below reconstruction threshold \
                 ({survivors} survivors, need {required})"
            ),
        }
    }
}

impl std::error::Error for AdmissionError {}

/// A token bucket over a continuous token supply. Pure with respect to
/// time — the caller feeds in elapsed seconds — so the policy is
/// unit-testable without sleeping, and sessions pay exactly one
/// `Instant::now()` per admission check.
#[derive(Debug, Clone)]
struct TokenBucket {
    /// Tokens added per second (> 0, validated at admission).
    rate: f64,
    /// Maximum tokens the bucket holds (≥ the largest single request the
    /// policy admits, so every valid request eventually succeeds).
    cap: f64,
    tokens: f64,
}

impl TokenBucket {
    /// A full bucket (bursts are available immediately after admission).
    fn new(rate: f64, cap: f64) -> TokenBucket {
        let cap = cap.max(1.0);
        TokenBucket { rate, cap, tokens: cap }
    }

    fn refill(&mut self, elapsed_secs: f64) {
        if elapsed_secs > 0.0 {
            self.tokens = (self.tokens + elapsed_secs * self.rate).min(self.cap);
        }
    }

    /// Take `n` tokens, or report how long until `n` would be available.
    fn try_take(&mut self, n: f64) -> Result<(), Duration> {
        if self.tokens >= n {
            self.tokens -= n;
            Ok(())
        } else {
            let deficit = n - self.tokens;
            // Rate is validated > 0; the clamp merely keeps a pathological
            // deficit/rate ratio inside Duration's constructible range.
            let secs = (deficit / self.rate).clamp(0.0, 3600.0);
            Err(Duration::from_secs_f64(secs))
        }
    }

    /// Return tokens taken by a request that was later denied elsewhere
    /// (no partial debits across the two buckets).
    fn put_back(&mut self, n: f64) {
        self.tokens = (self.tokens + n).min(self.cap);
    }

    /// Could a request for `n` tokens ever succeed, even against a full
    /// bucket? When false the right answer is [`AdmissionError::Rejected`]
    /// — returning `Throttled` would promise a retry that can never win.
    fn can_ever_admit(&self, n: f64) -> bool {
        n <= self.cap
    }
}

/// One tenant's weighted-round-robin scheduling state inside the plane.
/// Kept as a standalone `Copy` struct so the pick policy ([`wrr_pick`])
/// is a pure function, unit-tested without threads or dealers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) struct WrrState {
    /// Rounds requested but not yet dealt.
    pub pending: usize,
    /// Configured weight (quanta per cycle).
    pub weight: u32,
    /// Quanta left in the current cycle.
    pub credits: u32,
}

impl WrrState {
    pub fn new(weight: u32) -> WrrState {
        WrrState { pending: 0, weight, credits: weight.max(1) }
    }
}

/// Weighted round-robin with per-cycle credits: pick the next tenant to
/// deal ONE round for, starting the search at `cursor`. The picked slot's
/// `pending` and `credits` are decremented; the cursor stays on a tenant
/// until its quantum (credits) or its pending work is exhausted, then
/// advances. When every pending tenant is out of credits the cycle
/// restarts (credits refresh to weights) — so over any window in which a
/// set of tenants is continuously pending, tenant `i` receives exactly
/// `weight_i` of every `Σ weight_j` dealt rounds, and a flooding tenant
/// can never push a weight-`w` tenant below its `w / Σ weight` share.
///
/// Returns `None` when no slot has pending work.
pub(crate) fn wrr_pick(slots: &mut [WrrState], cursor: &mut usize) -> Option<usize> {
    let k = slots.len();
    if k == 0 || !slots.iter().any(|s| s.pending > 0) {
        return None;
    }
    // Pass 0 uses the credits left in the current cycle; if every pending
    // tenant is out, refresh and pass 1 must find one.
    for pass in 0..2 {
        for step in 0..k {
            let i = (*cursor + step) % k;
            let s = &mut slots[i];
            if s.pending > 0 && s.credits > 0 {
                s.pending -= 1;
                s.credits -= 1;
                *cursor = if s.credits == 0 || s.pending == 0 { (i + 1) % k } else { i };
                return Some(i);
            }
        }
        if pass == 0 {
            for s in slots.iter_mut() {
                s.credits = s.weight.max(1);
            }
        }
    }
    unreachable!("a pending tenant always has credits after a refresh")
}

/// Commands to the provisioning plane's dealer thread.
enum PlaneCmd {
    /// A new tenant: its dealers (one per group, pre-seeded), workload
    /// shape, WRR weight, and the handoff channel its dealt rounds go
    /// down. `dealt` is the session-shared counter of rounds the plane
    /// has dealt for this tenant (the fairness tests read it).
    Register {
        sid: SessionId,
        dealers: Vec<Dealer>,
        d: usize,
        n1: usize,
        mults: usize,
        weight: u32,
        dealt: Arc<AtomicU64>,
        dealt_tx: Sender<RoundBatch>,
    },
    /// Deal `rounds` more rounds for tenant `sid` (queued; the plane
    /// interleaves tenants by weighted round-robin, one round at a time).
    Request { sid: SessionId, rounds: usize },
    /// Tenant is gone; drop its dealers and any queued work.
    Deregister { sid: SessionId },
}

/// One tenant's state inside the plane thread.
struct Tenant {
    sid: SessionId,
    dealers: Vec<Dealer>,
    d: usize,
    n1: usize,
    mults: usize,
    dealt_tx: Sender<RoundBatch>,
    /// Rounds successfully dealt and handed off, shared with the session.
    dealt: Arc<AtomicU64>,
    /// WRR bookkeeping (pending rounds, weight, cycle credits).
    wrr: WrrState,
}

impl Tenant {
    /// Deal exactly one round: every group's dealer advances one
    /// whole-round step, in group order — the per-group streams stay
    /// identical to a dedicated engine's no matter what the plane dealt
    /// for other tenants in between.
    fn deal_one_round(&mut self) -> RoundBatch {
        self.dealers
            .iter_mut()
            .map(|dealer| dealer.gen_round(self.d, self.n1, self.mults))
            .collect()
    }
}

fn apply_cmd(tenants: &mut Vec<Tenant>, cmd: PlaneCmd) {
    match cmd {
        PlaneCmd::Register { sid, dealers, d, n1, mults, weight, dealt, dealt_tx } => {
            tenants.push(Tenant {
                sid,
                dealers,
                d,
                n1,
                mults,
                dealt_tx,
                dealt,
                wrr: WrrState::new(weight),
            });
        }
        PlaneCmd::Request { sid, rounds } => {
            // A request for an already-deregistered session is ignored
            // (it can race the Deregister through the same channel).
            if let Some(t) = tenants.iter_mut().find(|t| t.sid == sid) {
                t.wrr.pending += rounds;
            }
        }
        PlaneCmd::Deregister { sid } => {
            tenants.retain(|t| t.sid != sid);
        }
    }
}

/// The plane's dealer loop: absorb commands (blocking only when no
/// tenant has pending work), then deal ONE round for the tenant
/// [`wrr_pick`] selects. One round — not one request — stays the
/// dealing quantum (so command absorption and tenant churn remain
/// responsive mid-flood); *weights* decide how many consecutive quanta a
/// tenant gets per cycle, which is what gives priority tenants a
/// proportionally larger share of dealing bandwidth.
fn plane_loop(cmd_rx: Receiver<PlaneCmd>) {
    let mut tenants: Vec<Tenant> = Vec::new();
    let mut cursor = 0usize;
    loop {
        if tenants.iter().any(|t| t.wrr.pending > 0) {
            // Drain without blocking; on disconnect keep draining pending
            // work — dead sessions' sends fail below and clean themselves
            // up.
            while let Ok(cmd) = cmd_rx.try_recv() {
                apply_cmd(&mut tenants, cmd);
            }
        } else {
            match cmd_rx.recv() {
                Ok(cmd) => {
                    apply_cmd(&mut tenants, cmd);
                    continue;
                }
                // Scheduler and every session dropped: plane exits.
                Err(_) => return,
            }
        }

        // The WRR pick runs over per-tenant Copy state so the policy is a
        // pure, separately-tested function; write the updated state back
        // before acting on the pick.
        let mut slots: Vec<WrrState> = tenants.iter().map(|t| t.wrr).collect();
        let Some(i) = wrr_pick(&mut slots, &mut cursor) else {
            continue;
        };
        for (t, s) in tenants.iter_mut().zip(&slots) {
            t.wrr = *s;
        }
        let batch = tenants[i].deal_one_round();
        if tenants[i].dealt_tx.send(batch).is_ok() {
            tenants[i].dealt.fetch_add(1, Ordering::Relaxed);
        } else {
            // Session dropped mid-stream: discard it without touching
            // any other tenant's queue. Later tenants shift down one
            // slot, so a cursor past `i` moves with them; a cursor at or
            // before `i` already points at the rightful next tenant.
            tenants.remove(i);
            cursor = if tenants.is_empty() {
                0
            } else if cursor > i {
                (cursor - 1) % tenants.len()
            } else {
                cursor % tenants.len()
            };
        }
    }
}

/// Shared infrastructure: the one worker pool and the one provisioning
/// plane every session of a scheduler runs on. Sessions keep it alive
/// through an `Arc`, so a scheduler handle may be dropped while its
/// sessions keep running.
struct SchedCore {
    workers: WorkerPool,
    /// Kept open for registering new sessions; closing it (last owner
    /// dropping) is what lets the plane thread exit.
    plane_tx: Option<Sender<PlaneCmd>>,
    plane: Option<JoinHandle<()>>,
    next_sid: AtomicU64,
    /// Admission cap on concurrent tenants (`None` = unbounded).
    max_tenants: Option<usize>,
    /// Currently admitted tenants (incremented by `try_session`,
    /// decremented by `AggSession::drop`).
    live_tenants: AtomicUsize,
}

impl Drop for SchedCore {
    fn drop(&mut self) {
        // Close the command channel first (sessions' clones are already
        // gone — they hold the Arc this drop is the last owner of), then
        // join: the plane's blocking recv errors out and it returns.
        drop(self.plane_tx.take());
        if let Some(h) = self.plane.take() {
            let _ = h.join();
            note_threads_joined(1);
        }
        // WorkerPool's own Drop closes the job queue and joins workers.
    }
}

/// Multi-tenant aggregation scheduler: owns exactly one process-visible
/// [`WorkerPool`] and one provisioning plane, multiplexing any number of
/// concurrent `(HiSafeConfig, d, seed)` tenants. Create tenants with
/// [`AggScheduler::session`]; each [`AggSession`] exposes the familiar
/// engine surface and produces votes bit-identical to a dedicated
/// [`PipelinedEngine`](super::PipelinedEngine) and to
/// [`run_sync`](crate::protocol::run_sync), however tenants interleave.
///
/// The handle is cheap to clone (it is an `Arc` underneath); the shared
/// threads live until the last handle *and* last session are gone.
#[derive(Clone)]
pub struct AggScheduler {
    core: Arc<SchedCore>,
}

impl Default for AggScheduler {
    fn default() -> Self {
        Self::new()
    }
}

impl AggScheduler {
    /// A scheduler with the default thread policy: every available core
    /// up to the bandwidth cap, overridable via `HISAFE_THREADS`
    /// (resolved once, here — never re-read on the round path).
    pub fn new() -> AggScheduler {
        Self::with_threads(worker_pool_threads())
    }

    /// A scheduler with an explicitly pinned worker count — tests pin
    /// `threads = 1` for deterministic single-threaded evaluation.
    pub fn with_threads(threads: usize) -> AggScheduler {
        Self::build(threads, None)
    }

    /// A scheduler that additionally refuses to admit more than
    /// `max_tenants` concurrent sessions: once at capacity,
    /// [`try_session`](AggScheduler::try_session) returns
    /// [`AdmissionError::Rejected`] until a session drops. This is the
    /// cluster-facing admission knob — it bounds the scheduler's memory
    /// (plans + pools are per-tenant) independent of per-tenant QoS.
    pub fn with_capacity(threads: usize, max_tenants: usize) -> AggScheduler {
        assert!(max_tenants >= 1, "a scheduler that admits no tenants is useless");
        Self::build(threads, Some(max_tenants))
    }

    fn build(threads: usize, max_tenants: Option<usize>) -> AggScheduler {
        assert!(threads >= 1, "scheduler needs at least one worker thread");
        let (plane_tx, cmd_rx) = channel::<PlaneCmd>();
        let plane = std::thread::spawn(move || plane_loop(cmd_rx));
        note_threads_spawned(1);
        AggScheduler {
            core: Arc::new(SchedCore {
                workers: WorkerPool::new(threads),
                plane_tx: Some(plane_tx),
                plane: Some(plane),
                next_sid: AtomicU64::new(0),
                max_tenants,
                live_tenants: AtomicUsize::new(0),
            }),
        }
    }

    /// Live span-worker threads — one pool's worth, shared by every
    /// session, independent of how many tenants are registered.
    pub fn worker_threads(&self) -> usize {
        self.core.workers.threads()
    }

    /// Threads in the provisioning plane (currently a single dealer
    /// thread weighted-round-robining across tenants).
    pub fn dealer_threads(&self) -> usize {
        1
    }

    /// Open a tenant session for `cfg` over `d`-coordinate votes with the
    /// default (unlimited) [`QosPolicy`]. `seed` drives all of this
    /// tenant's offline randomness, one independent stream per subgroup —
    /// the same [`group_dealer_seed`] derivation as
    /// [`run_sync`](crate::protocol::run_sync) and the dedicated engines,
    /// which is what keeps sessions bit-identical to them.
    ///
    /// Dealing for the session's first round starts immediately on the
    /// shared plane, so caller-side work before the first `run_round`
    /// already overlaps the offline phase.
    ///
    /// # Panics
    ///
    /// On a scheduler built with [`with_capacity`] that is at its tenant
    /// cap — use [`try_session`] to handle rejection instead.
    ///
    /// [`with_capacity`]: AggScheduler::with_capacity
    /// [`try_session`]: AggScheduler::try_session
    pub fn session(&self, cfg: HiSafeConfig, d: usize, seed: u64) -> AggSession {
        self.try_session(cfg, d, seed, QosPolicy::unlimited())
            .expect("unlimited-policy session admitted on an uncapped scheduler")
    }

    /// Open a tenant session under an explicit [`QosPolicy`], subject to
    /// admission control: an invalid policy or a scheduler at its
    /// [`with_capacity`](AggScheduler::with_capacity) tenant cap is
    /// refused with [`AdmissionError::Rejected`] — typed backpressure at
    /// the front door, instead of unbounded tenancy.
    ///
    /// ```
    /// use hisafe::engine::{AggScheduler, Engine, QosPolicy};
    /// use hisafe::poly::TiePolicy;
    /// use hisafe::protocol::HiSafeConfig;
    ///
    /// // Two tenants with different priorities on one scheduler.
    /// let sched = AggScheduler::with_threads(1);
    /// let cfg = HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit);
    /// let mut gold = sched
    ///     .try_session(cfg, 4, 7, QosPolicy::unlimited().with_weight(3))
    ///     .unwrap();
    /// let mut best_effort = sched
    ///     .try_session(cfg, 4, 8, QosPolicy::unlimited().with_queue_depth(2))
    ///     .unwrap();
    ///
    /// // Unanimous inputs make the expected majority vote obvious.
    /// let signs = vec![vec![1i8, -1, 1, -1]; 6];
    /// assert_eq!(gold.run_round(&signs).global_vote, vec![1, -1, 1, -1]);
    /// assert_eq!(best_effort.run_round(&signs).global_vote, vec![1, -1, 1, -1]);
    /// ```
    pub fn try_session(
        &self,
        cfg: HiSafeConfig,
        d: usize,
        seed: u64,
        qos: QosPolicy,
    ) -> Result<AggSession, AdmissionError> {
        self.admit(cfg, d, seed, qos, 0)
    }

    /// Resume a snapshotted session on *this* scheduler: admission runs
    /// exactly as [`try_session`](AggScheduler::try_session), then the
    /// fresh per-group dealers are fast-forwarded by `snap.rounds` whole
    /// rounds before registration, so the restored session's next round
    /// consumes precisely the triples round `snap.rounds` of the
    /// original stream — votes stay bit-identical to an uninterrupted
    /// session (pinned by `rust/tests/service_props.rs`). The restored
    /// session reports `rounds_run() == snap.rounds` so round counters
    /// stay continuous across the handoff.
    ///
    /// The replay costs one `gen_round` per skipped round per group;
    /// prefetched-but-unconsumed triples on the dead host are simply
    /// regenerated (they were never consumed, so the stream position is
    /// `rounds`, not `dealt`).
    pub fn try_session_resumed(
        &self,
        snap: &SessionSnapshot,
    ) -> Result<AggSession, AdmissionError> {
        self.admit(snap.cfg, snap.d, snap.seed, snap.qos, snap.rounds)
    }

    /// The shared admission path: validate + reserve a tenant slot,
    /// build the plan and (possibly fast-forwarded) dealers, register on
    /// the plane, and hand out the session.
    fn admit(
        &self,
        cfg: HiSafeConfig,
        d: usize,
        seed: u64,
        qos: QosPolicy,
        resume_rounds: u64,
    ) -> Result<AggSession, AdmissionError> {
        qos.validate()?;
        if let Some(cap) = self.core.max_tenants {
            // CAS loop: concurrent admitters must not overshoot the cap.
            let mut cur = self.core.live_tenants.load(Ordering::SeqCst);
            loop {
                if cur >= cap {
                    return Err(AdmissionError::Rejected {
                        reason: format!("scheduler at tenant capacity ({cap})"),
                    });
                }
                match self.core.live_tenants.compare_exchange(
                    cur,
                    cur + 1,
                    Ordering::SeqCst,
                    Ordering::SeqCst,
                ) {
                    Ok(_) => break,
                    Err(now) => cur = now,
                }
            }
        } else {
            self.core.live_tenants.fetch_add(1, Ordering::SeqCst);
        }

        let n1 = cfg.n1();
        let mv = MvPolynomial::build_fermat_q(n1, cfg.precision, cfg.intra);
        let plan = Arc::new(EvalPlan::new(&mv, d, cfg.sparse));
        let mults = plan.triples_needed();
        let mut dealers: Vec<Dealer> = (0..cfg.ell)
            .map(|g| Dealer::new(plan.fp, group_dealer_seed(seed, g)))
            .collect();
        if resume_rounds > 0 && mults > 0 {
            // Snapshot replay: advance every group's dealer by the whole
            // rounds the original session consumed. Dealers only move in
            // whole-round steps, so this lands each stream exactly where
            // the interrupted session left it.
            for dealer in &mut dealers {
                for _ in 0..resume_rounds {
                    dealer.gen_round(d, n1, mults);
                }
            }
        }
        let sid = SessionId::new(self.core.next_sid.fetch_add(1, Ordering::Relaxed));
        let plane_tx = self.core.plane_tx.as_ref().expect("plane open").clone();
        let (dealt_tx, dealt_rx) = channel::<RoundBatch>();
        let dealt = Arc::new(AtomicU64::new(0));
        plane_tx
            .send(PlaneCmd::Register {
                sid,
                dealers,
                d,
                n1,
                mults,
                weight: qos.weight,
                dealt: Arc::clone(&dealt),
                dealt_tx,
            })
            .expect("provisioning plane alive");
        // Rate buckets are per-session; the triple bucket's capacity is
        // denominated in triples (burst_rounds rounds' worth), so one
        // whole round always fits and every valid request can succeed.
        let per_round_triples = ((mults * cfg.ell) as f64).max(1.0);
        let round_bucket = qos.rounds_per_sec.map(|r| TokenBucket::new(r, qos.burst_rounds));
        let triple_bucket = qos
            .triples_per_sec
            .map(|r| TokenBucket::new(r, qos.burst_rounds * per_round_triples));
        // A resumed session's counters continue where the snapshot left
        // off, so stats reports stay continuous across a failover.
        let mut admission = AdmissionStats::default();
        admission.admitted_rounds = resume_rounds;
        let mut session = AggSession {
            sid,
            cfg,
            d,
            seed,
            plan,
            pools: GroupPools::new(cfg.ell, n1),
            plane_tx,
            dealt_rx,
            jobs: self.core.workers.sender(),
            threads: self.core.workers.threads(),
            batch_rounds: 1,
            inflight_rounds: 0,
            cohorts: HashMap::new(),
            rekeys: 0,
            chunk: DEFAULT_CHUNK,
            rounds_run: resume_rounds,
            qos,
            round_bucket,
            triple_bucket,
            charged_rounds: 0,
            bucket_refill_at: Instant::now(),
            admission,
            dealt,
            inflight_jobs: Arc::new(AtomicUsize::new(0)),
            core: Arc::clone(&self.core),
        };
        if mults > 0 {
            // Bootstrap: one warm-up round on the plane so the first
            // `run_round` overlaps dealing. Uncharged — queue depth is
            // validated ≥ 1 and a session's first round is always
            // admissible.
            session.request_rounds(1);
        }
        Ok(session)
    }

    /// Tenants currently admitted (sessions alive now).
    pub fn live_tenants(&self) -> usize {
        self.core.live_tenants.load(Ordering::SeqCst)
    }

    /// The tenant cap, if this scheduler was built with
    /// [`with_capacity`](AggScheduler::with_capacity).
    pub fn max_tenants(&self) -> Option<usize> {
        self.core.max_tenants
    }
}

/// One tenant's handle on shared scheduler infrastructure: its own
/// [`EvalPlan`] and [`GroupPools`], refilled by the shared provisioning
/// plane and evaluated on the shared worker pool. Implements [`Engine`]
/// with the exact `PipelinedEngine` semantics (which is now a thin
/// wrapper around a single-tenant session).
pub struct AggSession {
    sid: SessionId,
    cfg: HiSafeConfig,
    d: usize,
    /// The seed all offline randomness derives from — retained so the
    /// session can be snapshotted for deterministic resume elsewhere.
    seed: u64,
    plan: Arc<EvalPlan>,
    /// Front buffer: rounds ready to consume (owned per-session).
    pools: GroupPools,
    /// Command path to the shared plane (also keeps the plane alive).
    plane_tx: Sender<PlaneCmd>,
    /// This session's private handoff channel from the plane.
    dealt_rx: Receiver<RoundBatch>,
    /// This session's handle on the shared job queue. Span results come
    /// back on a channel created fresh per round (see `run_round`): the
    /// round drops its sender after submission, so a worker dying before
    /// delivering a slot disconnects the channel and fails loudly
    /// instead of blocking the session forever.
    jobs: Sender<SpanJob>,
    /// Worker count, resolved once by the scheduler at construction.
    threads: usize,
    /// Rounds per provisioning request (default 1 — the double buffer).
    batch_rounds: usize,
    /// Rounds requested from the plane but not yet absorbed.
    inflight_rounds: usize,
    /// Cached churn-cohort plans/dealers, keyed `(group, cohort_key)` —
    /// the reusable-secret fast path (see [`CohortState`]). Cohort
    /// triples are dealt inline by the session, never by the plane: the
    /// plane's per-tenant streams stay whole-round pure, and the base
    /// stream advances one (discarded) round per churned group so
    /// all-present rounds after a churn episode draw the exact triples
    /// they always would have.
    cohorts: HashMap<(usize, u64), CohortState>,
    /// Distinct cohorts keyed so far (cache misses; stable survivor sets
    /// hold this flat).
    rekeys: u64,
    chunk: usize,
    rounds_run: u64,
    /// Admission policy, fixed at `try_session` time.
    qos: QosPolicy,
    /// Rounds/sec budget (None = unlimited).
    round_bucket: Option<TokenBucket>,
    /// Triples/sec dealing budget (None = unlimited).
    triple_bucket: Option<TokenBucket>,
    /// Rounds whose dealing cost `try_prefetch` already debited from the
    /// triple bucket; `try_run_round` consumes these credits instead of
    /// charging again, so each round of dealing demand is billed exactly
    /// once. Only maintained while a triple bucket exists.
    charged_rounds: usize,
    /// Last wall-clock instant the buckets were refilled at.
    bucket_refill_at: Instant,
    /// Admission decision counters (admitted/throttled/queue-full/rejected).
    admission: AdmissionStats,
    /// Rounds the plane has dealt for this tenant (plane-incremented;
    /// the fairness properties and the sweep report read it).
    dealt: Arc<AtomicU64>,
    /// Span jobs submitted to the shared pool and not yet evaluated
    /// (workers decrement before delivering each result).
    inflight_jobs: Arc<AtomicUsize>,
    /// Keeps the shared pool + plane alive while any session runs.
    /// Declared last: the drop-order guarantee means our `plane_tx`
    /// clone is gone before the core (possibly) joins the plane thread.
    core: Arc<SchedCore>,
}

impl Drop for AggSession {
    fn drop(&mut self) {
        // Best-effort: stop the plane dealing rounds nobody will read.
        // The handoff channel closing is the hard backstop — a racing
        // in-flight batch fails its send and evicts the tenant anyway.
        let _ = self.plane_tx.send(PlaneCmd::Deregister { sid: self.sid });
        // Free the admission slot (with_capacity schedulers re-admit).
        self.core.live_tenants.fetch_sub(1, Ordering::SeqCst);
    }
}

impl AggSession {
    /// The session id the scheduler assigned this tenant (diagnostic;
    /// span jobs and results are tagged with it).
    pub fn id(&self) -> SessionId {
        self.sid
    }

    /// The seed this session's offline randomness derives from (what a
    /// [`snapshot`](AggSession::snapshot) carries across hosts).
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// A serializable description of this session sufficient to resume
    /// it bit-identically elsewhere — see [`SessionSnapshot`] and
    /// [`AggScheduler::try_session_resumed`].
    pub fn snapshot(&self) -> SessionSnapshot {
        SessionSnapshot {
            cfg: self.cfg,
            d: self.d,
            seed: self.seed,
            qos: self.qos,
            rounds: self.rounds_run,
        }
    }

    /// The QoS policy this session was admitted under.
    pub fn qos(&self) -> &QosPolicy {
        &self.qos
    }

    /// The protocol configuration this session aggregates for. Transport
    /// front-ends ([`crate::service`]) validate wire-submitted sign
    /// matrices against it before touching the round path, so a
    /// malformed request is a typed rejection instead of a panic.
    pub fn config(&self) -> &HiSafeConfig {
        &self.cfg
    }

    /// The vote dimension `d` this session was opened for (the required
    /// length of every submitted sign vector).
    pub fn dim(&self) -> usize {
        self.d
    }

    /// Snapshot of this session's admission counters (rounds admitted,
    /// throttle/queue-full/reject denials). `train_multi` and
    /// `hisafe sweep` surface these per tenant in their JSON reports.
    pub fn admission_stats(&self) -> AdmissionStats {
        self.admission.clone()
    }

    /// Rounds the shared provisioning plane has dealt for this tenant so
    /// far (including rounds already consumed). Under weighted
    /// round-robin this is each tenant's measured share of dealing
    /// bandwidth — the fairness properties assert on it.
    pub fn dealt_rounds(&self) -> u64 {
        self.dealt.load(Ordering::Relaxed)
    }

    /// Span jobs currently submitted to the shared worker pool and not
    /// yet evaluated. Exactly 0 between rounds (workers decrement the
    /// gauge before delivering each result, and a round collects every
    /// result before returning).
    pub fn inflight_jobs(&self) -> usize {
        self.inflight_jobs.load(Ordering::SeqCst)
    }

    /// Rounds occupying this tenant's dealing queue right now: requested
    /// but undealt, plus dealt and pooled. This is the quantity
    /// [`QosPolicy::queue_depth`] bounds. 0 for plans that need no
    /// triples.
    pub fn queued_rounds(&mut self) -> usize {
        let mults = self.plan.triples_needed();
        if mults == 0 {
            return 0;
        }
        self.absorb_ready_batches();
        self.inflight_rounds + self.pools.provisioned_rounds(mults)
    }

    /// QoS-checked prefetch: ask the plane for `rounds` more rounds of
    /// triples *without blocking*, subject to the session's queue depth
    /// and triples/sec budget. On `Ok(())` the rounds are queued on the
    /// plane (weighted round-robin decides when they deal); the blocking
    /// [`Engine::provision`] remains the wait-until-pooled surface.
    ///
    /// Errors are typed backpressure: [`AdmissionError::Rejected`] for a
    /// request no retry can ever satisfy (larger than the whole queue,
    /// or larger than the triple bucket's burst capacity),
    /// [`AdmissionError::QueueFull`] when the queue is at depth (consume
    /// pooled rounds first), [`AdmissionError::Throttled`] when the
    /// triple budget is exhausted (retry after the returned delay).
    pub fn try_prefetch(&mut self, rounds: usize) -> Result<(), AdmissionError> {
        let mults = self.plan.triples_needed();
        // 0 rounds (e.g. from a computed `depth - queued` that came out
        // empty) and triple-free plans are clean no-ops, not errors.
        if rounds == 0 || mults == 0 {
            return Ok(());
        }
        self.absorb_ready_batches();
        if let Some(depth) = self.qos.queue_depth {
            if rounds > depth {
                self.admission.rejected += 1;
                return Err(AdmissionError::Rejected {
                    reason: format!("prefetch of {rounds} rounds exceeds queue depth {depth}"),
                });
            }
            let queued = self.inflight_rounds + self.pools.provisioned_rounds(mults);
            if queued + rounds > depth {
                self.admission.queue_full += 1;
                return Err(AdmissionError::QueueFull { depth });
            }
        }
        self.refill_buckets();
        if let Some(bucket) = &mut self.triple_bucket {
            let cost = (mults * self.cfg.ell * rounds) as f64;
            // A request larger than the bucket could ever hold must be
            // Rejected, not Throttled — a Throttled retry_after promises
            // a retry that can never succeed (livelock for contract-
            // following callers).
            if !bucket.can_ever_admit(cost) {
                self.admission.rejected += 1;
                return Err(AdmissionError::Rejected {
                    reason: format!(
                        "prefetch of {rounds} rounds exceeds the triple bucket's burst \
                         capacity — raise QosPolicy::burst_rounds or prefetch fewer \
                         rounds per call"
                    ),
                });
            }
            if let Err(retry_after) = bucket.try_take(cost) {
                self.admission.throttled += 1;
                return Err(AdmissionError::Throttled { retry_after });
            }
            // These rounds' dealing is now paid for; admission will not
            // charge them a second time.
            self.charged_rounds += rounds;
        }
        self.request_rounds(rounds);
        Ok(())
    }

    /// QoS-checked round execution: admit one round against the
    /// rounds/sec and triples/sec budgets, then run it. Throttling only
    /// delays a round, it never changes its votes — triple streams are
    /// pure functions of the session seed, so an admitted round is
    /// bit-identical whether it was throttled-and-retried or not
    /// (pinned by `rust/tests/sched_admission_props.rs`).
    ///
    /// The blocking [`Engine::run_round`] stays infallible and
    /// rate-limiter-exempt; use this surface where backpressure must be
    /// visible (the trainer's multi-tenant loop, `hisafe sweep`).
    pub fn try_run_round(&mut self, signs: &[Vec<i8>]) -> Result<EngineOutcome, AdmissionError> {
        self.refill_buckets();
        if let Some(bucket) = &mut self.round_bucket {
            if let Err(retry_after) = bucket.try_take(1.0) {
                self.admission.throttled += 1;
                return Err(AdmissionError::Throttled { retry_after });
            }
        }
        let mults = self.plan.triples_needed();
        if mults > 0 && self.charged_rounds == 0 {
            // No prefetch credit covers this round's dealing, so bill it
            // now. (When a credit exists, run_round_inner consumes it —
            // the same consumption path the blocking surface uses, so
            // credits can never be double-spent across the two surfaces.)
            if let Some(bucket) = &mut self.triple_bucket {
                let cost = (mults * self.cfg.ell) as f64;
                if let Err(retry_after) = bucket.try_take(cost) {
                    // No partial debits: hand the round token back so a
                    // retry is charged exactly once.
                    if let Some(rb) = &mut self.round_bucket {
                        rb.put_back(1.0);
                    }
                    self.admission.throttled += 1;
                    return Err(AdmissionError::Throttled { retry_after });
                }
            }
        }
        Ok(self.run_round_inner(signs))
    }

    /// QoS-checked round execution over an explicit participant set.
    ///
    /// The threshold check runs *before* any billing: a below-threshold
    /// mask costs no tokens and surfaces as
    /// [`AdmissionError::ChurnBelowThreshold`] (counted under
    /// [`AdmissionStats::rejected`]) — the session stays healthy and the
    /// next round's mask is judged on its own. Above threshold, billing
    /// is identical to [`try_run_round`](AggSession::try_run_round): the
    /// round still consumes exactly one round of base-stream dealing
    /// (used by full groups, consumed-and-discarded by churned ones), so
    /// the triple budget charges the same demand either way; the small
    /// inline cohort top-up (≤ n₁ parties per churned group) rides on
    /// the round token.
    pub fn try_run_round_present(
        &mut self,
        signs: &[Vec<i8>],
        present: &ParticipantSet,
    ) -> Result<EngineOutcome, AdmissionError> {
        assert_eq!(present.n(), self.cfg.n, "participant mask must cover all n users");
        if let Err(e) = check_thresholds(self.cfg, present) {
            self.admission.rejected += 1;
            return Err(e.into());
        }
        if present.is_all_present() {
            return self.try_run_round(signs);
        }
        self.refill_buckets();
        if let Some(bucket) = &mut self.round_bucket {
            if let Err(retry_after) = bucket.try_take(1.0) {
                self.admission.throttled += 1;
                return Err(AdmissionError::Throttled { retry_after });
            }
        }
        let mults = self.plan.triples_needed();
        if mults > 0 && self.charged_rounds == 0 {
            if let Some(bucket) = &mut self.triple_bucket {
                let cost = (mults * self.cfg.ell) as f64;
                if let Err(retry_after) = bucket.try_take(cost) {
                    if let Some(rb) = &mut self.round_bucket {
                        rb.put_back(1.0);
                    }
                    self.admission.throttled += 1;
                    return Err(AdmissionError::Throttled { retry_after });
                }
            }
        }
        Ok(self
            .run_round_present_inner(signs, present)
            .expect("thresholds were checked before admission"))
    }

    /// Distinct churn cohorts keyed so far — the reusable-secret fast
    /// path's miss counter (stable survivor sets hold it flat).
    pub fn cohort_rekeys(&self) -> u64 {
        self.rekeys
    }

    /// Base-stream group-rounds consumed-and-discarded on churned rounds.
    pub fn discarded_rounds(&self) -> usize {
        self.pools.discarded_rounds()
    }

    /// Blocking wrapper over [`try_run_round`](AggSession::try_run_round)
    /// for callers that must make progress: waits out `Throttled` denials
    /// (sleeping roughly `retry_after`, clamped to [50 µs, 20 ms] so a
    /// coarse budget stays responsive) until the round is admitted.
    /// Returns the outcome, the number of denials eaten, and the total
    /// time slept — the one retry loop the trainer, `hisafe sweep`, and
    /// the admission bench all share. Callers that need custom backoff
    /// (or want to *drop* rounds instead of waiting) use `try_run_round`
    /// directly.
    pub fn run_round_admitted(&mut self, signs: &[Vec<i8>]) -> (EngineOutcome, u64, Duration) {
        let mut denials = 0u64;
        let mut waited = Duration::ZERO;
        loop {
            match self.try_run_round(signs) {
                Ok(out) => return (out, denials, waited),
                Err(AdmissionError::Throttled { retry_after }) => {
                    denials += 1;
                    let wait =
                        retry_after.clamp(Duration::from_micros(50), Duration::from_millis(20));
                    waited += wait;
                    std::thread::sleep(wait);
                }
                Err(e) => unreachable!("try_run_round only returns Throttled denials: {e}"),
            }
        }
    }

    /// Blocking, churn-aware sibling of
    /// [`run_round_admitted`](AggSession::run_round_admitted): waits out
    /// `Throttled` denials with the same clamped backoff, but surfaces a
    /// below-threshold participant set as
    /// `Err(`[`AdmissionError::ChurnBelowThreshold`]`)` — an aborted
    /// round is a caller decision (skip the round, keep the model),
    /// never something to retry into.
    pub fn run_round_admitted_present(
        &mut self,
        signs: &[Vec<i8>],
        present: &ParticipantSet,
    ) -> Result<(EngineOutcome, u64, Duration), AdmissionError> {
        let mut denials = 0u64;
        let mut waited = Duration::ZERO;
        loop {
            match self.try_run_round_present(signs, present) {
                Ok(out) => return Ok((out, denials, waited)),
                Err(AdmissionError::Throttled { retry_after }) => {
                    denials += 1;
                    let wait =
                        retry_after.clamp(Duration::from_micros(50), Duration::from_millis(20));
                    waited += wait;
                    std::thread::sleep(wait);
                }
                Err(churn @ AdmissionError::ChurnBelowThreshold { .. }) => return Err(churn),
                Err(e) => unreachable!(
                    "try_run_round_present only returns Throttled or ChurnBelowThreshold: {e}"
                ),
            }
        }
    }

    /// Advance both token buckets by the wall-clock elapsed since the
    /// last admission check (one `Instant::now()` per check; the bucket
    /// arithmetic itself is pure and unit-tested with synthetic time).
    fn refill_buckets(&mut self) {
        if self.round_bucket.is_none() && self.triple_bucket.is_none() {
            return;
        }
        let now = Instant::now();
        let elapsed = now.duration_since(self.bucket_refill_at).as_secs_f64();
        self.bucket_refill_at = now;
        if let Some(b) = &mut self.round_bucket {
            b.refill(elapsed);
        }
        if let Some(b) = &mut self.triple_bucket {
            b.refill(elapsed);
        }
    }

    fn request_rounds(&mut self, rounds: usize) {
        self.plane_tx
            .send(PlaneCmd::Request { sid: self.sid, rounds })
            .expect("provisioning plane alive");
        self.inflight_rounds += rounds;
    }

    fn recv_one_round(&mut self) {
        let batch = self.dealt_rx.recv().expect("provisioning plane alive");
        self.pools.refill_round(batch);
        self.inflight_rounds -= 1;
    }

    fn absorb_ready_batches(&mut self) {
        while let Ok(batch) = self.dealt_rx.try_recv() {
            self.pools.refill_round(batch);
            self.inflight_rounds -= 1;
        }
    }

    /// Test-only view of the front buffer (the stream-derivation test
    /// audits pooled triples share-for-share).
    #[cfg(test)]
    pub(crate) fn pools_mut(&mut self) -> &mut GroupPools {
        &mut self.pools
    }

    /// The round path shared by the infallible [`Engine::run_round`] and
    /// the QoS-checked [`try_run_round`](AggSession::try_run_round) —
    /// admission has already been decided by the time this runs.
    fn run_round_inner(&mut self, signs: &[Vec<i8>]) -> EngineOutcome {
        assert_eq!(signs.len(), self.cfg.n, "need exactly n sign vectors");
        for (i, s) in signs.iter().enumerate() {
            assert_eq!(s.len(), self.d, "user {i} dimension mismatch");
        }
        let mults = self.plan.triples_needed();
        if mults > 0 {
            // This round consumes one round of dealing; if a prefetch
            // credit paid for it, retire the credit HERE — on the path
            // both the QoS-checked and the blocking surface share — so a
            // blocking `run_round` can never strand a credit for a later
            // `try_run_round` to spend on unbilled demand.
            self.charged_rounds = self.charged_rounds.saturating_sub(1);
            // Absorb whatever the plane finished since the last round,
            // without blocking.
            self.absorb_ready_batches();
            // Cold start / catch-up: block until this round is covered.
            while self.pools.provisioned_rounds(mults) == 0 {
                if self.inflight_rounds == 0 {
                    // Depth-capped like the overlap below (depth is
                    // validated ≥ 1, so progress is always possible).
                    let depth = self.qos.queue_depth.unwrap_or(usize::MAX);
                    self.request_rounds(self.batch_rounds.min(depth).max(1));
                }
                self.recv_one_round();
            }
            // The overlap: keep a batch in flight so round r+1's triples
            // are dealt while this round's online phase evaluates below.
            // A configured queue depth caps the prefetch — the internal
            // overlap must not outgrow the bound try_prefetch enforces.
            if self.inflight_rounds == 0 {
                let pooled = self.pools.provisioned_rounds(mults);
                if pooled < 1 + self.batch_rounds {
                    let depth = self.qos.queue_depth.unwrap_or(usize::MAX);
                    let want = self.batch_rounds.min(depth.saturating_sub(pooled));
                    if want > 0 {
                        self.request_rounds(want);
                    }
                }
            }
        }

        let fp = self.plan.fp;
        let d = self.d;
        let n1 = self.cfg.n1();
        let groups = partition(self.cfg.n, self.cfg.ell);
        // Same split policy as the sequential engine; below PAR_MIN_D
        // one span per group still parallelizes across groups.
        let spans = span_split(d, self.threads);
        let span_len = d.div_ceil(spans);

        // Per-round result channel: jobs carry clones of out_tx, the
        // round drops its own sender after submission, and reassembly is
        // slot-keyed — so worker completion order cannot affect votes,
        // other tenants' in-flight rounds cannot cross-wire them (the
        // channel is private to this session's round, with the session
        // tag asserted on receipt), and a worker panicking before it
        // sends disconnects the channel instead of hanging the round.
        let (out_tx, out_rx) = channel::<SpanResult>();
        // slot -> (group, base, len)
        let mut slots: Vec<(usize, usize, usize)> = Vec::new();
        for (g, members) in groups.iter().enumerate() {
            // Cloning the members' sign vectors makes the job 'static for
            // the shared workers — n₁·d bytes per group, well under 1% of
            // the round's field work (see PipelinedEngine's history).
            let group_signs: Arc<Vec<Vec<i8>>> =
                Arc::new(members.iter().map(|&u| signs[u].clone()).collect());
            let triples: Arc<Vec<Vec<TripleShare>>> = Arc::new(if mults > 0 {
                self.pools.take_round_owned(g, mults)
            } else {
                vec![Vec::new(); n1]
            });
            let mut base = 0usize;
            while base < d {
                let len = span_len.min(d - base);
                let slot = slots.len();
                slots.push((g, base, len));
                self.inflight_jobs.fetch_add(1, Ordering::SeqCst);
                self.jobs
                    .send(SpanJob {
                        session: self.sid.as_u64(),
                        inflight: Arc::clone(&self.inflight_jobs),
                        fp,
                        plan: Arc::clone(&self.plan),
                        signs: Arc::clone(&group_signs),
                        triples: Arc::clone(&triples),
                        base,
                        len,
                        chunk: self.chunk,
                        slot,
                        out: out_tx.clone(),
                    })
                    .expect("shared worker pool alive");
                base += len;
            }
        }
        drop(out_tx);

        let mut subgroup_votes: Vec<Vec<i8>> = vec![vec![0i8; d]; groups.len()];
        for _ in 0..slots.len() {
            let (sid, slot, span_votes) = out_rx.recv().expect("span worker alive");
            assert_eq!(sid, self.sid.as_u64(), "span result crossed sessions");
            let (g, b, len) = slots[slot];
            subgroup_votes[g][b..b + len].copy_from_slice(&span_votes);
        }
        // Every result is in and workers decrement before sending, so
        // the in-flight gauge is provably drained between rounds.
        debug_assert_eq!(self.inflight_jobs(), 0, "in-flight gauge must drain per round");

        let global_vote =
            inter_group_vote_q(&subgroup_votes, self.cfg.precision, self.cfg.inter);
        let stats = analytic_stats(&self.cfg, &self.plan, d);
        self.rounds_run += 1;
        self.admission.admitted_rounds += 1;
        EngineOutcome { global_vote, subgroup_votes, stats }
    }

    /// The churn-aware round path — [`run_round_inner`]'s sibling for a
    /// partial participant set, shared by the infallible
    /// [`Engine::run_round_present`] and the QoS-checked
    /// [`try_run_round_present`](AggSession::try_run_round_present).
    ///
    /// Full groups run exactly the `run_round_inner` machinery: the same
    /// plane-fed base pools, the same span-job fan-out on the shared
    /// worker pool. Churned groups consume-and-discard their base-stream
    /// round (lockstep pool accounting — see
    /// [`super::pool::GroupPools::discard_round`]) and evaluate their
    /// survivors under a cached `(group, cohort_key)` [`CohortState`]
    /// whose triples are dealt inline. Span jobs already carry their own
    /// `(fp, plan)` per job, so heterogeneous cohort plans fan out on
    /// the one shared pool unchanged.
    ///
    /// [`run_round_inner`]: AggSession::run_round_inner
    fn run_round_present_inner(
        &mut self,
        signs: &[Vec<i8>],
        present: &ParticipantSet,
    ) -> Result<EngineOutcome, ChurnError> {
        assert_eq!(present.n(), self.cfg.n, "participant mask must cover all n users");
        if present.is_all_present() {
            return Ok(self.run_round_inner(signs));
        }
        assert_eq!(signs.len(), self.cfg.n, "need n sign rows (absent rows are ignored)");
        for (i, s) in signs.iter().enumerate() {
            assert_eq!(s.len(), self.d, "user {i} dimension mismatch");
        }
        check_thresholds(self.cfg, present)?;

        let mults = self.plan.triples_needed();
        if mults > 0 {
            // Identical base-stream advancement to run_round_inner: one
            // round of dealing is consumed whether a group uses it or
            // discards it, so the plane, the credits, and the pooled
            // streams cannot tell a churned round from a full one.
            self.charged_rounds = self.charged_rounds.saturating_sub(1);
            self.absorb_ready_batches();
            while self.pools.provisioned_rounds(mults) == 0 {
                if self.inflight_rounds == 0 {
                    let depth = self.qos.queue_depth.unwrap_or(usize::MAX);
                    self.request_rounds(self.batch_rounds.min(depth).max(1));
                }
                self.recv_one_round();
            }
            if self.inflight_rounds == 0 {
                let pooled = self.pools.provisioned_rounds(mults);
                if pooled < 1 + self.batch_rounds {
                    let depth = self.qos.queue_depth.unwrap_or(usize::MAX);
                    let want = self.batch_rounds.min(depth.saturating_sub(pooled));
                    if want > 0 {
                        self.request_rounds(want);
                    }
                }
            }
        }

        let d = self.d;
        let n1 = self.cfg.n1();
        let groups = partition(self.cfg.n, self.cfg.ell);
        let spans = span_split(d, self.threads);
        let span_len = d.div_ceil(spans);

        let (out_tx, out_rx) = channel::<SpanResult>();
        // slot -> (group, base, len)
        let mut slots: Vec<(usize, usize, usize)> = Vec::new();
        let mut stats = CommStats::default();
        for (g, members) in groups.iter().enumerate() {
            let survivors = present.group_survivors(members);
            let full = survivors.len() == members.len();
            let (plan, group_signs, triples) = if full {
                let group_signs: Arc<Vec<Vec<i8>>> =
                    Arc::new(members.iter().map(|&u| signs[u].clone()).collect());
                let triples: Arc<Vec<Vec<TripleShare>>> = Arc::new(if mults > 0 {
                    self.pools.take_round_owned(g, mults)
                } else {
                    vec![Vec::new(); n1]
                });
                (Arc::clone(&self.plan), group_signs, triples)
            } else {
                if mults > 0 {
                    self.pools.discard_round(g, mults);
                }
                let k = survivors.len();
                let key = recover_cohort_key(self.seed, g, members, present);
                if !self.cohorts.contains_key(&(g, key)) {
                    let state = CohortState::build(&self.cfg, d, self.seed, g, k, key);
                    self.cohorts.insert((g, key), state);
                    self.rekeys += 1;
                }
                let cohort = self.cohorts.get_mut(&(g, key)).expect("just inserted");
                let plan = Arc::clone(&cohort.plan);
                let triples: Arc<Vec<Vec<TripleShare>>> =
                    Arc::new(cohort.round_triples(d, k));
                let group_signs: Arc<Vec<Vec<i8>>> =
                    Arc::new(survivors.iter().map(|&u| signs[u].clone()).collect());
                (plan, group_signs, triples)
            };
            stats.merge(&analytic_group_stats(&plan, d, group_signs.len(), self.cfg.intra));
            let mut base = 0usize;
            while base < d {
                let len = span_len.min(d - base);
                let slot = slots.len();
                slots.push((g, base, len));
                self.inflight_jobs.fetch_add(1, Ordering::SeqCst);
                self.jobs
                    .send(SpanJob {
                        session: self.sid.as_u64(),
                        inflight: Arc::clone(&self.inflight_jobs),
                        fp: plan.fp,
                        plan: Arc::clone(&plan),
                        signs: Arc::clone(&group_signs),
                        triples: Arc::clone(&triples),
                        base,
                        len,
                        chunk: self.chunk,
                        slot,
                        out: out_tx.clone(),
                    })
                    .expect("shared worker pool alive");
                base += len;
            }
        }
        drop(out_tx);

        let mut subgroup_votes: Vec<Vec<i8>> = vec![vec![0i8; d]; groups.len()];
        for _ in 0..slots.len() {
            let (sid, slot, span_votes) = out_rx.recv().expect("span worker alive");
            assert_eq!(sid, self.sid.as_u64(), "span result crossed sessions");
            let (g, b, len) = slots[slot];
            subgroup_votes[g][b..b + len].copy_from_slice(&span_votes);
        }
        debug_assert_eq!(self.inflight_jobs(), 0, "in-flight gauge must drain per round");

        let global_vote =
            inter_group_vote_q(&subgroup_votes, self.cfg.precision, self.cfg.inter);
        stats.vote_bits = crate::quant::downlink_bits(self.cfg.precision, self.cfg.inter);
        self.rounds_run += 1;
        self.admission.admitted_rounds += 1;
        Ok(EngineOutcome { global_vote, subgroup_votes, stats })
    }
}

impl Engine for AggSession {
    fn with_chunk(mut self, chunk: usize) -> AggSession {
        assert!(chunk >= 1, "chunk must be ≥ 1");
        self.chunk = chunk;
        self
    }

    fn with_batch_rounds(mut self, rounds: usize) -> AggSession {
        assert!(rounds >= 1, "batch must be ≥ 1");
        self.batch_rounds = rounds;
        self
    }

    fn plan(&self) -> &EvalPlan {
        &self.plan
    }

    fn provisioned_rounds(&self) -> usize {
        self.pools.provisioned_rounds(self.plan.triples_needed())
    }

    /// Blocking pre-provisioning. Exempt from the rate limiters like the
    /// rest of the `Engine` surface, but NOT from the queue bound: the
    /// target is clamped to [`QosPolicy::queue_depth`], so even a legacy
    /// `provision(1000)` cannot queue more than the session's depth on
    /// the shared plane (the invariant `queued_rounds() ≤ depth` holds
    /// on every path).
    fn provision(&mut self, rounds: usize) {
        let mults = self.plan.triples_needed();
        if mults == 0 {
            return;
        }
        let target = rounds.min(self.qos.queue_depth.unwrap_or(usize::MAX));
        self.absorb_ready_batches();
        while self.pools.provisioned_rounds(mults) < target {
            if self.inflight_rounds == 0 {
                let missing = target - self.pools.provisioned_rounds(mults);
                self.request_rounds(missing);
            }
            self.recv_one_round();
        }
    }

    /// Infallible, rate-limiter-exempt round execution (the legacy
    /// engine surface; see [`AggSession::try_run_round`] for the
    /// QoS-checked one). Counts toward
    /// [`AdmissionStats::admitted_rounds`].
    fn run_round(&mut self, signs: &[Vec<i8>]) -> EngineOutcome {
        self.run_round_inner(signs)
    }

    /// Churn-aware round execution, rate-limiter-exempt like the rest of
    /// the `Engine` surface (see
    /// [`AggSession::try_run_round_present`] for the QoS-checked one).
    fn run_round_present(
        &mut self,
        signs: &[Vec<i8>],
        present: &ParticipantSet,
    ) -> Result<EngineOutcome, ChurnError> {
        self.run_round_present_inner(signs, present)
    }

    fn rounds_run(&self) -> u64 {
        self.rounds_run
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpc::plain_group_vote;
    use crate::poly::TiePolicy;
    use crate::protocol::plain_hierarchical_vote;
    use crate::util::rng::{Rng, Xoshiro256pp};

    fn rand_signs(n: usize, d: usize, seed: u64) -> Vec<Vec<i8>> {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        (0..n).map(|_| (0..d).map(|_| rng.gen_sign()).collect()).collect()
    }

    #[test]
    fn two_tenants_interleaved_match_plain_references() {
        let sched = AggScheduler::with_threads(2);
        let cfg_a = HiSafeConfig::hierarchical(12, 4, TiePolicy::TwoBit);
        let cfg_b = HiSafeConfig::flat(5, TiePolicy::OneBit);
        let mut a = sched.session(cfg_a, 9, 11);
        let mut b = sched.session(cfg_b, 17, 3);
        for r in 0..4u64 {
            let signs_a = rand_signs(12, 9, 100 + r);
            let signs_b = rand_signs(5, 17, 200 + r);
            // Alternate which tenant goes first so rounds interleave in
            // both orders.
            if r % 2 == 0 {
                let got = a.run_round(&signs_a);
                assert_eq!(got.global_vote, plain_hierarchical_vote(&signs_a, cfg_a));
                let got = b.run_round(&signs_b);
                assert_eq!(got.global_vote, plain_group_vote(&signs_b, TiePolicy::OneBit));
            } else {
                let got = b.run_round(&signs_b);
                assert_eq!(got.global_vote, plain_group_vote(&signs_b, TiePolicy::OneBit));
                let got = a.run_round(&signs_a);
                assert_eq!(got.global_vote, plain_hierarchical_vote(&signs_a, cfg_a));
            }
        }
        assert_eq!(a.rounds_run(), 4);
        assert_eq!(b.rounds_run(), 4);
    }

    #[test]
    fn snapshot_resume_replays_bit_identically_across_schedulers() {
        let cfg = HiSafeConfig::hierarchical(12, 4, TiePolicy::OneBit);
        let (d, seed, rounds) = (33usize, 77u64, 6u64);
        let signs: Vec<Vec<Vec<i8>>> =
            (0..rounds).map(|r| rand_signs(12, d, 500 + r)).collect();

        // Uninterrupted reference on its own scheduler.
        let sched_ref = AggScheduler::with_threads(2);
        let mut whole = sched_ref.session(cfg, d, seed);
        let reference: Vec<EngineOutcome> =
            signs.iter().map(|s| whole.run_round(s)).collect();

        // Interrupted run: snapshot after 3 rounds, resume on a DIFFERENT
        // scheduler (fresh dealers, fast-forwarded), finish there.
        let sched_a = AggScheduler::with_threads(1);
        let mut first = sched_a.session(cfg, d, seed);
        let mut got: Vec<EngineOutcome> =
            signs[..3].iter().map(|s| first.run_round(s)).collect();
        let snap = first.snapshot();
        assert_eq!(snap.rounds, 3);
        assert_eq!(snap.seed, seed);
        drop(first);
        let sched_b = AggScheduler::with_threads(2);
        let mut second = sched_b.try_session_resumed(&snap).expect("admitted");
        assert_eq!(second.rounds_run(), 3);
        got.extend(signs[3..].iter().map(|s| second.run_round(s)));

        for (r, (a, b)) in reference.iter().zip(&got).enumerate() {
            assert_eq!(a.global_vote, b.global_vote, "round {r} global vote diverged");
            assert_eq!(
                a.subgroup_votes, b.subgroup_votes,
                "round {r} subgroup votes diverged"
            );
        }
        assert_eq!(second.rounds_run(), rounds);
        assert_eq!(second.admission_stats().admitted_rounds, rounds);
    }

    #[test]
    fn session_id_wire_form_round_trips() {
        for raw in [0u64, 1, 42, u64::MAX] {
            let sid = SessionId::new(raw);
            assert_eq!(sid.to_string(), raw.to_string());
            let back: SessionId = sid.to_string().parse().expect("decimal form parses");
            assert_eq!(back, sid);
            assert_eq!(back.as_u64(), raw);
        }
        assert!("not-a-number".parse::<SessionId>().is_err());
    }

    #[test]
    fn k_tenants_share_exactly_one_pool_and_one_plane() {
        // Accessor-contract check: the counts the sweep command and the
        // bench report must stay at one pool's worth however many
        // tenants register. (The accessors return construction-time
        // facts; the *measured* live-thread assertion — a spawn/join
        // gauge proving sessions spawn nothing — lives in
        // rust/tests/thread_budget.rs, a single-test process where the
        // gauge is race-free.)
        let sched = AggScheduler::with_threads(2);
        assert_eq!(sched.worker_threads(), 2);
        assert_eq!(sched.dealer_threads(), 1);
        let mut sessions: Vec<AggSession> = (0..4)
            .map(|i| {
                sched.session(
                    HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit),
                    5 + i,
                    i as u64,
                )
            })
            .collect();
        assert_eq!(sched.worker_threads(), 2, "sessions must not spawn workers");
        assert_eq!(sched.dealer_threads(), 1, "sessions must not spawn dealers");
        for (i, s) in sessions.iter_mut().enumerate() {
            let signs = rand_signs(6, 5 + i, 7 + i as u64);
            let got = s.run_round(&signs);
            assert_eq!(
                got.global_vote,
                plain_hierarchical_vote(&signs, HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit))
            );
        }
        assert_eq!(sched.worker_threads(), 2);
    }

    #[test]
    fn dropping_one_session_mid_stream_leaves_others_running() {
        let sched = AggScheduler::with_threads(1);
        let cfg = HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit);
        let mut keep_a = sched.session(cfg, 7, 1);
        let mut dropped = sched.session(cfg, 7, 2).with_batch_rounds(3);
        let mut keep_b = sched.session(cfg, 7, 3);
        for r in 0..2u64 {
            for s in [&mut keep_a, &mut dropped, &mut keep_b] {
                let signs = rand_signs(6, 7, 10 + r);
                let got = s.run_round(&signs);
                assert_eq!(got.global_vote, plain_hierarchical_vote(&signs, cfg));
            }
        }
        // Drop the middle tenant while it still has batches in flight
        // (batch_rounds = 3 keeps its prefetch queue non-empty).
        drop(dropped);
        // Survivors must neither stall nor corrupt: both blocking
        // provisioning (provision) and the normal round path still work.
        keep_a.provision(2);
        assert!(keep_a.provisioned_rounds() >= 2);
        for r in 0..3u64 {
            for s in [&mut keep_a, &mut keep_b] {
                let signs = rand_signs(6, 7, 20 + r);
                let got = s.run_round(&signs);
                assert_eq!(got.global_vote, plain_hierarchical_vote(&signs, cfg));
            }
        }
        assert_eq!(keep_a.rounds_run(), 5);
        assert_eq!(keep_b.rounds_run(), 5);
    }

    #[test]
    fn sessions_outlive_their_scheduler_handle() {
        let cfg = HiSafeConfig::flat(3, TiePolicy::OneBit);
        let mut session = {
            let sched = AggScheduler::with_threads(1);
            sched.session(cfg, 6, 9)
            // scheduler handle dropped here; the Arc'd core survives
        };
        for r in 0..3u64 {
            let signs = rand_signs(3, 6, 30 + r);
            let got = session.run_round(&signs);
            assert_eq!(got.global_vote, plain_group_vote(&signs, TiePolicy::OneBit));
        }
    }

    #[test]
    fn zero_mult_tenants_never_touch_the_plane() {
        // n₁ = 1 makes the vote polynomial the identity — no triples, no
        // provisioning, and the session must not block on the plane.
        let sched = AggScheduler::with_threads(1);
        let mut s = sched.session(HiSafeConfig::flat(1, TiePolicy::OneBit), 7, 3);
        let signs = rand_signs(1, 7, 9);
        let got = s.run_round(&signs);
        assert_eq!(got.global_vote, plain_group_vote(&signs, TiePolicy::OneBit));
    }

    #[test]
    fn multiplexed_triple_streams_match_group_dealer_seed_derivation() {
        // Vote equality alone cannot pin the offline phase: Beaver masks
        // cancel exactly, so votes come out right under ANY triple
        // stream. This pins the streams themselves — with TWO tenants
        // interleaving their dealing on the shared plane, each session's
        // pooled triples must equal, share for share and round for
        // round, a dealer seeded with `group_dealer_seed(seed, g)` (the
        // run_sync derivation). A regression that let one tenant's
        // dealing advance another's streams (or collapsed the per-group
        // stride) fails here and nowhere else.
        let sched = AggScheduler::with_threads(1);
        let cfg = HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit);
        let d = 5;
        let (seed_a, seed_b) = (77u64, 91u64);
        let mut a = sched.session(cfg, d, seed_a);
        let mut b = sched.session(cfg, d, seed_b);
        let mults = a.plan().triples_needed();
        assert!(mults > 0, "n₁=3 needs secure multiplications");
        let fp = a.plan().fp;
        // Interleave the provisioning so the plane alternates tenants.
        a.provision(1);
        b.provision(2);
        a.provision(2);
        for (session, seed) in [(&mut a, seed_a), (&mut b, seed_b)] {
            for g in 0..cfg.ell {
                let mut reference = Dealer::new(fp, group_dealer_seed(seed, g));
                for round in 0..2 {
                    let expect = reference.gen_round(d, cfg.n1(), mults);
                    for (party, expect_party) in expect.iter().enumerate() {
                        let got = session.pools_mut().store_mut(g, party).take_many(mults);
                        assert_eq!(got.len(), mults);
                        for (t, e) in got.iter().zip(expect_party) {
                            assert_eq!(t.a, e.a, "seed={seed} g={g} party={party} round={round}");
                            assert_eq!(t.b, e.b, "seed={seed} g={g} party={party} round={round}");
                            assert_eq!(t.c, e.c, "seed={seed} g={g} party={party} round={round}");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn token_bucket_policy_is_pure_and_exact() {
        let mut b = TokenBucket::new(10.0, 2.0); // 10 tokens/s, burst 2
        // Starts full: the burst is available immediately.
        assert!(b.try_take(2.0).is_ok());
        // Empty now: a 1-token request must wait 0.1s.
        let wait = b.try_take(1.0).unwrap_err();
        assert!((wait.as_secs_f64() - 0.1).abs() < 1e-9, "got {wait:?}");
        // Synthetic time: refill half a token, still short for 1.0.
        b.refill(0.05);
        assert!(b.try_take(1.0).is_err());
        b.refill(0.05);
        assert!(b.try_take(1.0).is_ok());
        // Refills never exceed the cap.
        b.refill(1000.0);
        assert!(b.try_take(2.0).is_ok());
        assert!(b.try_take(0.5).is_err());
        // put_back restores tokens, also capped.
        b.put_back(0.5);
        assert!(b.try_take(0.5).is_ok());
        b.put_back(100.0);
        assert!(b.try_take(2.0).is_ok());
        assert!(b.try_take(0.1).is_err());
    }

    #[test]
    fn wrr_pick_gives_each_pending_tenant_its_weight_per_cycle() {
        // Tenant 0: weight 3, flooding. Tenant 1: weight 1, modest.
        let mut slots = vec![
            WrrState { pending: 100, ..WrrState::new(3) },
            WrrState { pending: 10, ..WrrState::new(1) },
        ];
        let mut cursor = 0usize;
        let mut picks = Vec::new();
        for _ in 0..16 {
            picks.push(wrr_pick(&mut slots, &mut cursor).unwrap());
        }
        // Per cycle: 3 quanta for tenant 0, then 1 for tenant 1.
        assert_eq!(picks, vec![0, 0, 0, 1, 0, 0, 0, 1, 0, 0, 0, 1, 0, 0, 0, 1]);
        // The weight-1 tenant got exactly its 1/4 proportional share.
        assert_eq!(picks.iter().filter(|&&i| i == 1).count(), 4);
        assert_eq!(slots[1].pending, 6);
    }

    #[test]
    fn wrr_pick_skips_idle_tenants_without_consuming_their_turn() {
        // Tenant 1 has no pending work; 0 and 2 alternate as if adjacent.
        let mut slots = vec![
            WrrState { pending: 5, ..WrrState::new(1) },
            WrrState::new(4), // idle, high weight — must not matter
            WrrState { pending: 5, ..WrrState::new(1) },
        ];
        let mut cursor = 0usize;
        let mut picks = Vec::new();
        for _ in 0..10 {
            picks.push(wrr_pick(&mut slots, &mut cursor).unwrap());
        }
        assert_eq!(picks, vec![0, 2, 0, 2, 0, 2, 0, 2, 0, 2]);
        // Everything dealt; nothing pending anywhere.
        assert_eq!(wrr_pick(&mut slots, &mut cursor), None);
    }

    #[test]
    fn wrr_pick_drains_a_flood_after_the_modest_tenant_finishes() {
        // Once the weight-1 tenant runs out of pending work, the flooder
        // gets the whole plane (work conservation).
        let mut slots = vec![
            WrrState { pending: 8, ..WrrState::new(1) },
            WrrState { pending: 2, ..WrrState::new(1) },
        ];
        let mut cursor = 0usize;
        let mut picks = Vec::new();
        while let Some(i) = wrr_pick(&mut slots, &mut cursor) {
            picks.push(i);
        }
        assert_eq!(picks.len(), 10);
        assert_eq!(picks.iter().filter(|&&i| i == 1).count(), 2);
        // Tail is all tenant 0 (tenant 1 finished in the first cycles).
        assert!(picks[4..].iter().all(|&i| i == 0));
    }

    #[test]
    fn invalid_qos_policies_are_rejected_at_admission() {
        let sched = AggScheduler::with_threads(1);
        let cfg = HiSafeConfig::flat(3, TiePolicy::OneBit);
        for qos in [
            QosPolicy::unlimited().with_weight(0),
            QosPolicy::unlimited().with_queue_depth(0),
            QosPolicy::unlimited().with_rounds_per_sec(0.0),
            QosPolicy::unlimited().with_rounds_per_sec(-1.0),
            QosPolicy::unlimited().with_triples_per_sec(f64::NAN),
            QosPolicy::unlimited().with_burst_rounds(0.5),
            QosPolicy::unlimited().with_burst_rounds(f64::INFINITY),
        ] {
            match sched.try_session(cfg, 4, 1, qos) {
                Err(AdmissionError::Rejected { .. }) => {}
                Err(e) => panic!("{qos:?} must be Rejected, got {e:?}"),
                Ok(_) => panic!("{qos:?} must be rejected, was admitted"),
            }
        }
        // Rejected admissions must not leak tenant slots.
        assert_eq!(sched.live_tenants(), 0);
    }

    #[test]
    fn tenant_capacity_rejects_then_readmits_after_drop() {
        let sched = AggScheduler::with_capacity(1, 2);
        assert_eq!(sched.max_tenants(), Some(2));
        let cfg = HiSafeConfig::flat(3, TiePolicy::OneBit);
        let a = sched.try_session(cfg, 4, 1, QosPolicy::unlimited()).unwrap();
        let _b = sched.try_session(cfg, 4, 2, QosPolicy::unlimited()).unwrap();
        assert_eq!(sched.live_tenants(), 2);
        match sched.try_session(cfg, 4, 3, QosPolicy::unlimited()) {
            Err(AdmissionError::Rejected { reason }) => {
                assert!(reason.contains("capacity"), "unexpected reason: {reason}");
            }
            Err(e) => panic!("third tenant must be Rejected, got {e:?}"),
            Ok(_) => panic!("third tenant must be rejected, was admitted"),
        }
        drop(a);
        assert_eq!(sched.live_tenants(), 1);
        let mut c = sched.try_session(cfg, 4, 4, QosPolicy::unlimited()).unwrap();
        let signs = rand_signs(3, 4, 9);
        let got = c.run_round(&signs);
        assert_eq!(got.global_vote, plain_group_vote(&signs, TiePolicy::OneBit));
    }

    #[test]
    fn queue_depth_bounds_prefetch_deterministically() {
        let sched = AggScheduler::with_threads(1);
        let cfg = HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit);
        let mut s = sched
            .try_session(cfg, 5, 7, QosPolicy::unlimited().with_queue_depth(3))
            .unwrap();
        assert!(s.plan().triples_needed() > 0, "n₁=3 needs triples");
        // Construction bootstraps one warm-up round onto the queue.
        assert_eq!(s.queued_rounds(), 1);
        // A 0-round prefetch (a computed `depth - queued` that came out
        // empty) is a clean no-op, not a panic or a counter bump.
        s.try_prefetch(0).expect("0-round prefetch is a no-op");
        assert_eq!(s.queued_rounds(), 1);
        // Larger than the whole queue: never admissible.
        match s.try_prefetch(4) {
            Err(AdmissionError::Rejected { .. }) => {}
            other => panic!("oversized prefetch must be Rejected, got {other:?}"),
        }
        // Fill to depth, then one more must be QueueFull.
        s.try_prefetch(2).unwrap();
        assert_eq!(s.queued_rounds(), 3);
        match s.try_prefetch(1) {
            Err(AdmissionError::QueueFull { depth: 3 }) => {}
            other => panic!("expected QueueFull at depth, got {other:?}"),
        }
        // Consuming a round frees a slot. (queued = inflight + pooled is
        // conserved under plane timing, so this is deterministic; the
        // overlap request inside run_round is depth-capped and sees a
        // full-enough pool here, so it requests nothing.)
        let signs = rand_signs(6, 5, 11);
        let got = s.run_round(&signs);
        assert_eq!(got.global_vote, plain_hierarchical_vote(&signs, cfg));
        assert_eq!(s.queued_rounds(), 2);
        s.try_prefetch(1).unwrap();
        let stats = s.admission_stats();
        assert_eq!(stats.admitted_rounds, 1);
        assert_eq!(stats.queue_full, 1);
        assert_eq!(stats.rejected, 1);
        assert_eq!(stats.throttled, 0);
    }

    #[test]
    fn exhausted_round_budget_throttles_with_retry_after() {
        let sched = AggScheduler::with_threads(1);
        let cfg = HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit);
        // One round every 2000 s, burst 1: the first round is admitted,
        // the second throttles (no bucket refill within test runtime).
        let mut s = sched
            .try_session(cfg, 5, 3, QosPolicy::unlimited().with_rounds_per_sec(0.0005))
            .unwrap();
        let signs = rand_signs(6, 5, 13);
        let got = s.try_run_round(&signs).expect("burst admits the first round");
        assert_eq!(got.global_vote, plain_hierarchical_vote(&signs, cfg));
        match s.try_run_round(&signs) {
            Err(AdmissionError::Throttled { retry_after }) => {
                assert!(retry_after > Duration::ZERO);
            }
            other => panic!("expected Throttled, got {other:?}"),
        }
        // The blocking Engine surface stays exempt (and bit-identical).
        let got = s.run_round(&signs);
        assert_eq!(got.global_vote, plain_hierarchical_vote(&signs, cfg));
        let stats = s.admission_stats();
        assert_eq!(stats.admitted_rounds, 2);
        assert_eq!(stats.throttled, 1);
    }

    #[test]
    fn oversized_prefetch_against_triple_budget_is_rejected_not_throttled() {
        // A prefetch larger than the triple bucket's burst capacity can
        // never succeed; returning Throttled would livelock callers that
        // follow the retry contract. burst 1 ⇒ the bucket holds exactly
        // one round's cost, so a 2-round prefetch must be Rejected —
        // and a burst of 2 must admit the same request.
        let sched = AggScheduler::with_threads(1);
        let cfg = HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit);
        let mut s = sched
            .try_session(cfg, 5, 3, QosPolicy::unlimited().with_triples_per_sec(1000.0))
            .unwrap();
        match s.try_prefetch(2) {
            Err(AdmissionError::Rejected { reason }) => {
                assert!(reason.contains("burst"), "reason: {reason}");
            }
            other => panic!("expected Rejected, got {other:?}"),
        }
        assert_eq!(s.admission_stats().rejected, 1);
        let mut s2 = sched
            .try_session(
                cfg,
                5,
                4,
                QosPolicy::unlimited().with_triples_per_sec(1000.0).with_burst_rounds(2.0),
            )
            .unwrap();
        s2.try_prefetch(2).expect("a 2-round burst admits a 2-round prefetch");
    }

    #[test]
    fn prefetched_rounds_are_not_double_charged_at_admission() {
        // Each round of dealing demand is billed exactly once: a
        // prefetch-charged round must pass admission without a second
        // triple debit. The budget is microscopic (1e-6 triples/s) so
        // the bucket cannot refill within the test — with burst 1 it
        // holds exactly one round's cost and never again.
        let sched = AggScheduler::with_threads(1);
        let cfg = HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit);
        let mut s = sched
            .try_session(cfg, 5, 3, QosPolicy::unlimited().with_triples_per_sec(1e-6))
            .unwrap();
        s.try_prefetch(1).expect("the full bucket covers one round");
        let signs = rand_signs(6, 5, 13);
        // Pre-double-charge-fix this throttled: the bucket was empty and
        // admission tried to charge the already-paid round again.
        let got = s.try_run_round(&signs).expect("prefetched round is already paid for");
        assert_eq!(got.global_vote, plain_hierarchical_vote(&signs, cfg));
        // The next round has no prefetch credit and an empty bucket.
        match s.try_run_round(&signs) {
            Err(AdmissionError::Throttled { .. }) => {}
            other => panic!("unpaid round must throttle, got {other:?}"),
        }
        let stats = s.admission_stats();
        assert_eq!(stats.admitted_rounds, 1);
        assert_eq!(stats.throttled, 1);
    }

    #[test]
    fn blocking_run_round_retires_prefetch_credits() {
        // The exempt Engine surface consumes prefetched rounds too, so
        // it must also retire their already-billed credits — otherwise
        // mixing run_round with try_run_round would let later rounds
        // spend the stranded credit and put fresh dealing demand on the
        // plane unbilled.
        let sched = AggScheduler::with_threads(1);
        let cfg = HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit);
        let mut s = sched
            .try_session(cfg, 5, 3, QosPolicy::unlimited().with_triples_per_sec(1e-6))
            .unwrap();
        s.try_prefetch(1).expect("the full bucket covers one round");
        let signs = rand_signs(6, 5, 17);
        let got = s.run_round(&signs);
        assert_eq!(got.global_vote, plain_hierarchical_vote(&signs, cfg));
        // No stranded credit and an empty bucket: the next QoS-checked
        // round must be billed, i.e. throttled — not a free ride.
        match s.try_run_round(&signs) {
            Err(AdmissionError::Throttled { .. }) => {}
            other => panic!("leaked prefetch credit gave a free ride: {other:?}"),
        }
    }

    #[test]
    fn blocking_provision_is_clamped_to_queue_depth() {
        // provision() is rate-limiter-exempt but NOT depth-exempt: a
        // legacy provision(100) on a depth-2 session must queue 2 rounds
        // on the plane, keeping queued_rounds() ≤ depth on every path.
        let sched = AggScheduler::with_threads(1);
        let cfg = HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit);
        let mut s = sched
            .try_session(cfg, 5, 3, QosPolicy::unlimited().with_queue_depth(2))
            .unwrap();
        s.provision(100);
        assert_eq!(s.queued_rounds(), 2);
        // The clamped pool still serves rounds correctly.
        let signs = rand_signs(6, 5, 19);
        let got = s.run_round(&signs);
        assert_eq!(got.global_vote, plain_hierarchical_vote(&signs, cfg));
        assert!(s.queued_rounds() <= 2);
    }

    #[test]
    fn throttled_then_admitted_rounds_stay_bit_identical_to_unthrottled() {
        // Admission decides WHEN a round runs, never WHAT it computes:
        // a throttled tenant retried to completion must match a
        // dedicated unthrottled session vote-for-vote and triple-stream
        // for triple-stream (the dealer streams are pure functions of
        // the seed).
        let sched = AggScheduler::with_threads(1);
        let cfg = HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit);
        let seed = 77u64;
        // 200 rounds/s with burst 1: some of the 4 back-to-back rounds
        // throttle (a round at d=5 takes far less than 5 ms).
        let mut limited = sched
            .try_session(cfg, 5, seed, QosPolicy::unlimited().with_rounds_per_sec(200.0))
            .unwrap();
        let mut free = sched.session(cfg, 5, seed);
        for r in 0..4u64 {
            let signs = rand_signs(6, 5, 40 + r);
            let want = free.run_round(&signs);
            let (got, _denials, _waited) = limited.run_round_admitted(&signs);
            assert_eq!(got.global_vote, want.global_vote, "round {r}");
            assert_eq!(got.subgroup_votes, want.subgroup_votes, "round {r}");
            assert_eq!(got.stats, want.stats, "round {r}");
        }
        assert_eq!(limited.admission_stats().admitted_rounds, 4);
    }

    #[test]
    fn plane_counts_dealt_rounds_per_tenant() {
        let sched = AggScheduler::with_threads(1);
        let cfg = HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit);
        let mut s = sched.session(cfg, 5, 3);
        assert!(s.plan().triples_needed() > 0);
        s.provision(3);
        // Bootstrap (1) is part of the 3 provisioned; at least 3 dealt.
        assert!(s.dealt_rounds() >= 3, "dealt {}", s.dealt_rounds());
        assert_eq!(s.inflight_jobs(), 0);
    }

    #[test]
    fn per_tenant_chunk_and_batch_are_observationally_invisible() {
        let sched = AggScheduler::with_threads(2);
        let cfg = HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit);
        let signs = rand_signs(6, 23, 9);
        let baseline = plain_hierarchical_vote(&signs, cfg);
        for (chunk, batch) in [(1usize, 1usize), (3, 2), (64, 3)] {
            let got = sched
                .session(cfg, 23, 4)
                .with_chunk(chunk)
                .with_batch_rounds(batch)
                .run_round(&signs)
                .global_vote;
            assert_eq!(got, baseline, "chunk={chunk} batch={batch}");
        }
    }
}
