//! Security analysis of Hi-SAFE (Section IV-B, Theorem 2, Lemmas 2–4,
//! Remark 4).
//!
//! Three executable artifacts back the paper's proofs:
//!
//! 1. **Lemma 2, empirically** — the publicly opened `(δ, ε)` pairs must be
//!    uniform on `F_p` and *independent of the honest inputs*. We run the
//!    real protocol many times and χ²-test the openings against uniform,
//!    and against the openings produced under *different* honest inputs.
//! 2. **Theorem 2 simulator** — [`simulate_transcript`] produces a server
//!    view given only the leakage `{s_j}, s` (no honest inputs), with the
//!    same marginal structure as the real one; a two-sample test confirms
//!    indistinguishability of the opened values.
//! 3. **Remark 4** — [`residual_leakage_log2`] computes the residual
//!    full-disclosure probability `(2^−(n₁−1))^d` in log₂ space.

use crate::field::Fp;
use crate::mpc::{EvalPlan, Opening, Transcript};
use crate::sharing::share_vec;
use crate::util::rng::{ChaCha20Rng, Rng};

/// χ² statistic of observed counts against the uniform distribution on
/// `cells` categories.
pub fn chi_square_uniform(counts: &[u64]) -> f64 {
    let total: u64 = counts.iter().sum();
    let exp = total as f64 / counts.len() as f64;
    counts
        .iter()
        .map(|&c| {
            let d = c as f64 - exp;
            d * d / exp
        })
        .sum()
}

/// Two-sample χ² statistic (same category space).
pub fn chi_square_two_sample(a: &[u64], b: &[u64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let na: u64 = a.iter().sum();
    let nb: u64 = b.iter().sum();
    let mut stat = 0.0;
    for i in 0..a.len() {
        let tot = (a[i] + b[i]) as f64;
        if tot == 0.0 {
            continue;
        }
        let ea = tot * na as f64 / (na + nb) as f64;
        let eb = tot * nb as f64 / (na + nb) as f64;
        stat += (a[i] as f64 - ea).powi(2) / ea + (b[i] as f64 - eb).powi(2) / eb;
    }
    stat
}

/// Loose upper quantile for χ²(df) at ~99.9%: `df + 4·√(2·df) + 8`.
/// (Normal approximation with generous slack; we only need "not absurdly
/// non-uniform", not a tight test.)
pub fn chi2_threshold(df: usize) -> f64 {
    df as f64 + 4.0 * (2.0 * df as f64).sqrt() + 8.0
}

/// The adversary's view of one subgroup execution: corrupted inputs +
/// everything the server saw.
#[derive(Debug)]
pub struct AdversaryView {
    pub corrupted: Vec<usize>,
    pub corrupted_inputs: Vec<Vec<u64>>,
    pub transcript: Transcript,
}

/// Theorem-2 simulator: fabricate a server transcript given ONLY the
/// output (the subgroup vote, field-encoded) and the public plan —
/// no honest inputs.
///
/// Procedure (Appendix C, Lemmas 3–4): sample every opening uniformly;
/// sample all but one final share uniformly; set the last share so the
/// reconstruction equals the given output.
pub fn simulate_transcript(plan: &EvalPlan, output: &[u64], seed: u64) -> Transcript {
    assert_eq!(output.len(), plan.d);
    let fp = plan.fp;
    let p = fp.modulus();
    let mut rng = ChaCha20Rng::seed_from_u64(seed);
    let openings: Vec<Opening> = plan
        .schedule
        .steps
        .iter()
        .enumerate()
        .map(|(idx, _)| Opening {
            mult_idx: idx,
            delta: (0..plan.d).map(|_| rng.gen_field(p)).collect(),
            eps: (0..plan.d).map(|_| rng.gen_field(p)).collect(),
        })
        .collect();
    // final shares: uniform conditioned on Σ = output
    let final_shares = share_vec(fp, output, plan.n_parties, &mut rng);
    Transcript { openings, final_shares, output: output.to_vec() }
}

/// Histogram the δ-openings of a transcript into `p` cells (coordinate 0
/// of every multiplication; callers accumulate across runs).
pub fn histogram_openings(fp: Fp, transcripts: &[Transcript]) -> Vec<u64> {
    let mut counts = vec![0u64; fp.modulus() as usize];
    for t in transcripts {
        for o in &t.openings {
            counts[o.delta[0] as usize] += 1;
            counts[o.eps[0] as usize] += 1;
        }
    }
    counts
}

/// Remark 4: log₂ of the probability that the final vote fully reveals
/// all inputs — `d·(−(n₁−1))` for subgroup size `n₁` over `d` coordinates
/// (inputs i.i.d. uniform ±1).
pub fn residual_leakage_log2(n1: usize, d: usize) -> f64 {
    -((n1.saturating_sub(1)) as f64) * d as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpc::secure_group_vote;
    use crate::poly::{MvPolynomial, TiePolicy};
    use crate::util::rng::Xoshiro256pp;

    /// Lemma 2: real openings are uniform on F_p.
    #[test]
    fn real_openings_uniform() {
        let n = 5;
        let mut rng = Xoshiro256pp::seed_from_u64(4);
        let mut transcripts = Vec::new();
        for run in 0..1500 {
            let signs: Vec<Vec<i8>> =
                (0..n).map(|_| vec![rng.gen_sign()]).collect();
            let out = secure_group_vote(&signs, TiePolicy::OneBit, false, run);
            transcripts.push(out.transcript);
        }
        let fp = crate::field::field_for_group(n);
        let counts = histogram_openings(fp, &transcripts);
        let chi2 = chi_square_uniform(&counts);
        let thr = chi2_threshold(counts.len() - 1);
        assert!(chi2 < thr, "openings non-uniform: χ² = {chi2:.1} ≥ {thr:.1}");
    }

    /// Lemma 2, input-independence: opening distributions under two fixed,
    /// different honest-input profiles are indistinguishable.
    #[test]
    fn openings_independent_of_inputs() {
        let n = 4;
        let fp = crate::field::field_for_group(n);
        let profile_a: Vec<Vec<i8>> = vec![vec![1], vec![1], vec![1], vec![1]];
        let profile_b: Vec<Vec<i8>> = vec![vec![-1], vec![-1], vec![-1], vec![-1]];
        let collect = |signs: &Vec<Vec<i8>>, base: u64| -> Vec<u64> {
            let ts: Vec<_> = (0..1200)
                .map(|r| secure_group_vote(signs, TiePolicy::OneBit, false, base + r).transcript)
                .collect();
            histogram_openings(fp, &ts)
        };
        let ha = collect(&profile_a, 10_000);
        let hb = collect(&profile_b, 20_000);
        let chi2 = chi_square_two_sample(&ha, &hb);
        let thr = chi2_threshold(ha.len() - 1);
        assert!(
            chi2 < thr,
            "openings depend on inputs: χ² = {chi2:.1} ≥ {thr:.1}"
        );
    }

    /// Theorem 2: the simulator's openings match the real distribution and
    /// its reconstruction equals the leaked output.
    #[test]
    fn simulated_transcript_indistinguishable() {
        let n = 4;
        let mv = MvPolynomial::build_fermat(n, TiePolicy::OneBit);
        let plan = EvalPlan::new(&mv, 1, false);
        let fp = plan.fp;
        let signs: Vec<Vec<i8>> = vec![vec![1], vec![-1], vec![1], vec![1]];
        // real views
        let real: Vec<_> = (0..1200)
            .map(|r| secure_group_vote(&signs, TiePolicy::OneBit, false, 40_000 + r).transcript)
            .collect();
        // simulated views given only the output
        let output = real[0].output.clone();
        let sim: Vec<_> = (0..1200)
            .map(|r| simulate_transcript(&plan, &output, 90_000 + r))
            .collect();
        for t in &sim {
            // reconstruction consistency
            let rec = crate::sharing::reconstruct_vec(fp, &t.final_shares);
            assert_eq!(rec, output);
            assert_eq!(t.openings.len(), real[0].openings.len());
        }
        let hr = histogram_openings(fp, &real);
        let hs = histogram_openings(fp, &sim);
        let chi2 = chi_square_two_sample(&hr, &hs);
        let thr = chi2_threshold(hr.len() - 1);
        assert!(chi2 < thr, "sim distinguishable: χ² = {chi2:.1} ≥ {thr:.1}");
    }

    /// Final shares of honest parties are uniform (any n−1 of them).
    #[test]
    fn final_shares_marginally_uniform() {
        let n = 3;
        let fp = crate::field::field_for_group(n);
        let signs: Vec<Vec<i8>> = vec![vec![1], vec![-1], vec![1]];
        let mut counts = vec![0u64; fp.modulus() as usize];
        for r in 0..4000 {
            let t = secure_group_vote(&signs, TiePolicy::OneBit, false, 70_000 + r).transcript;
            counts[t.final_shares[1][0] as usize] += 1;
        }
        let chi2 = chi_square_uniform(&counts);
        let thr = chi2_threshold(counts.len() - 1);
        assert!(chi2 < thr, "final share non-uniform: χ² = {chi2:.1}");
    }

    #[test]
    fn remark4_leakage_values() {
        // flat n=24 vs subgrouped n₁=3, d=1: 2^−23 vs 2^−2.
        assert_eq!(residual_leakage_log2(24, 1), -23.0);
        assert_eq!(residual_leakage_log2(3, 1), -2.0);
        // model-level (d = 7850): astronomically negligible either way.
        assert!(residual_leakage_log2(3, 7850) < -15_000.0);
        // monotone: larger subgroups leak less
        assert!(residual_leakage_log2(6, 10) < residual_leakage_log2(3, 10));
    }

    #[test]
    fn chi2_helpers_sane() {
        // perfectly uniform counts → statistic 0
        assert_eq!(chi_square_uniform(&[100, 100, 100, 100]), 0.0);
        // identical samples → two-sample statistic 0
        assert_eq!(chi_square_two_sample(&[50, 50], &[50, 50]), 0.0);
        // grossly skewed counts must exceed the threshold
        let skewed = chi_square_uniform(&[1000, 10, 10, 10]);
        assert!(skewed > chi2_threshold(3));
    }
}
