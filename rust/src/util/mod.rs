//! In-tree utility substrates.
//!
//! The build environment is fully offline with only the `xla` crate's
//! dependency closure vendored, so the usual ecosystem crates (rand,
//! serde, clap, criterion, proptest) are unavailable. Per the
//! "build every substrate" rule these are implemented here:
//!
//! * [`rng`] — ChaCha20 (crypto-grade, for Beaver masks and shares) and
//!   xoshiro256++ (fast, for data synthesis), plus distributions.
//! * [`prop`] — a minimal property-based testing harness (seeded random
//!   inputs, shrinking-free but with reported failing seeds).
//! * [`bench`] — a micro-benchmark harness (warmup, adaptive iteration,
//!   median/MAD reporting) used by all `rust/benches/*`.
//! * [`json`] — a small JSON writer + parser for configs and metric logs.
//! * [`cli`] — flag parsing for the launcher and examples.

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
