//! Tiny CLI flag parser for the launcher and examples (clap is not
//! available offline).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, and
//! positional arguments. Unknown flags are an error, so typos fail fast.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    flags: BTreeMap<String, String>,
    positional: Vec<String>,
    /// Flags that were declared boolean when parsing.
    seen: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (no program name).
    /// `bool_flags` lists flags that take no value.
    pub fn parse<I: IntoIterator<Item = String>>(
        raw: I,
        bool_flags: &[&str],
    ) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(body) = arg.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if bool_flags.contains(&body) {
                    out.flags.insert(body.to_string(), "true".to_string());
                } else {
                    let v = it
                        .next()
                        .ok_or_else(|| format!("--{body} requires a value"))?;
                    out.flags.insert(body.to_string(), v);
                }
                out.seen.push(body.split('=').next().unwrap().to_string());
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    /// Parse from `std::env::args()` (skipping the program name).
    pub fn from_env(bool_flags: &[&str]) -> Result<Args, String> {
        Self::parse(std::env::args().skip(1), bool_flags)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s.parse().map_err(|_| format!("--{key} must be u64, got '{s}'")),
        }
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize, String> {
        self.get_u64(key, default as u64).map(|x| x as usize)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s.parse().map_err(|_| format!("--{key} must be f64, got '{s}'")),
        }
    }

    /// Validate that every provided flag is in `allowed` (catches typos).
    pub fn check_known(&self, allowed: &[&str]) -> Result<(), String> {
        for k in self.flags.keys() {
            if !allowed.contains(&k.as_str()) {
                return Err(format!(
                    "unknown flag --{k}; known flags: {}",
                    allowed.join(", ")
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parse_mixed_forms() {
        let a = Args::parse(
            sv(&["train", "--n", "24", "--tie=two_bit", "--verbose", "pos2"]),
            &["verbose"],
        )
        .unwrap();
        assert_eq!(a.positional(), &["train".to_string(), "pos2".to_string()]);
        assert_eq!(a.get_u64("n", 0).unwrap(), 24);
        assert_eq!(a.get("tie"), Some("two_bit"));
        assert!(a.has("verbose"));
        assert_eq!(a.get_f64("lr", 0.005).unwrap(), 0.005);
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(sv(&["--n"]), &[]).is_err());
    }

    #[test]
    fn unknown_flag_detection() {
        let a = Args::parse(sv(&["--nn", "3"]), &[]).unwrap();
        assert!(a.check_known(&["n"]).is_err());
        assert!(a.check_known(&["nn"]).is_ok());
    }

    #[test]
    fn bad_number_is_error() {
        let a = Args::parse(sv(&["--n", "abc"]), &[]).unwrap();
        assert!(a.get_u64("n", 0).is_err());
    }
}
