//! Minimal JSON value model, writer and parser.
//!
//! Used for experiment configs (`configs/*.json`), metric logs written by
//! the launcher (`runs/*.json`), and machine-readable bench output. Not a
//! general-purpose library: no streaming, numbers are f64/i64, strings are
//! UTF-8 with the common escapes — exactly what our configs need.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Object keys are ordered (BTreeMap) so output is
/// deterministic and diff-friendly.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object (panics if not an object — config-builder use).
    pub fn set(&mut self, key: &str, val: impl Into<Json>) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val.into());
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Path lookup: `get_path("a.b.c")`.
    pub fn get_path(&self, path: &str) -> Option<&Json> {
        let mut cur = self;
        for part in path.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 && x <= u64::MAX as f64 {
                Some(x as u64)
            } else {
                None
            }
        })
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Compact serialization.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization (2-space indent).
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u32> for Json {
    fn from(x: u32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

// ------------------------------------------------------------- parsing

/// Parse error with byte offset.
#[derive(Debug)]
pub struct ParseError {
    pub at: usize,
    pub msg: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.at, self.msg)
    }
}

impl std::error::Error for ParseError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: impl Into<String>) -> Result<T, ParseError> {
        Err(ParseError { at: self.i, msg: msg.into() })
    }

    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            self.err(format!(
                "expected '{}', found {:?}",
                c as char,
                self.peek().map(|b| b as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        self.ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => self.err(format!("unexpected {other:?}")),
        }
    }

    fn lit(&mut self, word: &str, val: Json) -> Result<Json, ParseError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(val)
        } else {
            self.err(format!("expected '{word}'"))
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(
            self.peek(),
            Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-')
        ) {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        match s.parse::<f64>() {
            Ok(x) => Ok(Json::Num(x)),
            Err(_) => self.err(format!("bad number '{s}'")),
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return self.err("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{0008}'),
                        Some(b'f') => s.push('\u{000c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return self.err("bad \\u escape");
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.i + 1..self.i + 5],
                            )
                            .map_err(|_| ParseError {
                                at: self.i,
                                msg: "bad \\u escape".into(),
                            })?;
                            let cp = u32::from_str_radix(hex, 16).map_err(
                                |_| ParseError {
                                    at: self.i,
                                    msg: "bad \\u escape".into(),
                                },
                            )?;
                            // BMP only (no surrogate pairs) — enough for configs.
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        other => {
                            return self.err(format!("bad escape {other:?}"))
                        }
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| ParseError {
                            at: self.i,
                            msg: "invalid utf-8".into(),
                        })?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                other => return self.err(format!("expected , or ], got {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                other => return self.err(format!("expected , or }}, got {other:?}")),
            }
        }
    }
}

/// Parse a complete JSON document.
pub fn parse(text: &str) -> Result<Json, ParseError> {
    let mut p = Parser { b: text.as_bytes(), i: 0 };
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return p.err("trailing garbage");
    }
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let mut j = Json::obj();
        j.set("name", "fig2a").set("n", 24u64).set("lr", Json::Num(0.005));
        j.set("tags", vec!["fmnist", "non-iid"]);
        let mut inner = Json::obj();
        inner.set("ell", 8u64).set("tie", "one_bit");
        j.set("subgroup", inner);
        let text = j.to_string_pretty();
        let back = parse(&text).unwrap();
        assert_eq!(back, j);
        let compact = j.to_string_compact();
        assert_eq!(parse(&compact).unwrap(), j);
    }

    #[test]
    fn parse_escapes_and_numbers() {
        let j = parse(r#"{"s":"a\"b\nA","x":-1.5e3,"b":[true,false,null]}"#)
            .unwrap();
        assert_eq!(j.get("s").unwrap().as_str().unwrap(), "a\"b\nA");
        assert_eq!(j.get("x").unwrap().as_f64().unwrap(), -1500.0);
        assert_eq!(j.get("b").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{}x").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn path_lookup() {
        let j = parse(r#"{"a":{"b":{"c":42}}}"#).unwrap();
        assert_eq!(j.get_path("a.b.c").unwrap().as_u64().unwrap(), 42);
        assert!(j.get_path("a.x").is_none());
    }

    #[test]
    fn u64_conversion_guards() {
        assert_eq!(parse("3.5").unwrap().as_u64(), None);
        assert_eq!(parse("-2").unwrap().as_u64(), None);
        assert_eq!(parse("7").unwrap().as_u64(), Some(7));
    }

    /// Random finite value of every shape the writer can emit: huge u64
    /// casts (exercising both the integer and `Display` write paths
    /// around the 1e15 cutoff), negatives, fractions, and strings full
    /// of escapes, control chars, and non-ASCII.
    fn rand_value(g: &mut crate::util::prop::Gen, depth: usize) -> Json {
        let kind = if depth == 0 { g.range(0, 3) } else { g.range(0, 5) };
        match kind {
            0 => Json::Null,
            1 => Json::Bool(g.bool()),
            2 => Json::Num(match g.range(0, 3) {
                // Large u64s: the wire protocol's counters depend on
                // f64-representable integers surviving exactly.
                0 => g.u64() as f64,
                1 => -(g.range(0, 1 << 53) as f64),
                2 => (g.u64() as f64) / (g.range(1, 1 << 20) as f64),
                _ => g.range(0, 1 << 53) as f64,
            }),
            3 => Json::Str(rand_string(g)),
            4 => Json::Arr((0..g.usize_range(0, 4)).map(|_| rand_value(g, depth - 1)).collect()),
            _ => {
                let mut m = BTreeMap::new();
                for _ in 0..g.usize_range(0, 4) {
                    m.insert(rand_string(g), rand_value(g, depth - 1));
                }
                Json::Obj(m)
            }
        }
    }

    fn rand_string(g: &mut crate::util::prop::Gen) -> String {
        const POOL: &[char] = &[
            'a', 'Z', '7', ' ', '"', '\\', '/', '\n', '\r', '\t', '\u{0}', '\u{1}', '\u{1f}',
            '\u{e9}', '\u{4e2d}', '\u{1f600}', '\u{fffd}',
        ];
        (0..g.usize_range(0, 12)).map(|_| POOL[g.usize_range(0, POOL.len() - 1)]).collect()
    }

    #[test]
    fn parse_serialize_round_trip_property() {
        // The wire protocol (src/service/proto.rs) frames every message
        // through this module, so parse ∘ serialize must be the
        // identity on everything the writer emits — compact AND pretty,
        // since configs use pretty and frames use compact.
        crate::util::prop::forall("json parse∘serialize = id", 300, |g| {
            let value = rand_value(g, 3);
            let compact = value.to_string_compact();
            let from_compact =
                parse(&compact).map_err(|e| format!("compact reparse failed: {e} on {compact}"))?;
            crate::prop_assert_eq!(&from_compact, &value, "compact text: {compact}");
            let pretty = value.to_string_pretty();
            let from_pretty =
                parse(&pretty).map_err(|e| format!("pretty reparse failed: {e} on {pretty}"))?;
            crate::prop_assert_eq!(&from_pretty, &value, "pretty text: {pretty}");
            Ok(())
        });
    }

    #[test]
    fn large_u64_num_survives_at_f64_precision() {
        // Json numbers are f64: integers ≤ 2^53 survive bit-exactly
        // (and as_u64 recovers them); larger u64s survive at f64
        // precision — the reason src/service/proto.rs carries ids and
        // seeds as decimal strings instead.
        crate::util::prop::forall("u64 ≤ 2^53 round-trips exactly", 200, |g| {
            let small = g.range(0, 1 << 53);
            let text = Json::Num(small as f64).to_string_compact();
            let back = parse(&text).map_err(|e| e.to_string())?;
            crate::prop_assert_eq!(back.as_u64(), Some(small), "text: {text}");
            let huge = g.u64();
            let text = Json::Num(huge as f64).to_string_compact();
            let back = parse(&text).map_err(|e| e.to_string())?;
            crate::prop_assert_eq!(
                back.as_f64(),
                Some(huge as f64),
                "f64-level precision lost: {text}"
            );
            Ok(())
        });
    }
}
