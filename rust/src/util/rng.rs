//! Random number generation.
//!
//! Two generators with one trait:
//!
//! * [`ChaCha20Rng`] — the IETF ChaCha20 block function used as a CSPRNG.
//!   All *cryptographic* randomness in Hi-SAFE (additive-share masks,
//!   Beaver triples, pairwise masking seeds) comes from here; Lemma 2's
//!   uniformity argument needs masks indistinguishable from uniform, and
//!   the `security` module's χ² tests run against this generator.
//! * [`Xoshiro256pp`] — xoshiro256++, fast statistical PRNG for synthetic
//!   data generation, user selection and test-input generation.
//!
//! Both are fully deterministic from a `u64`/32-byte seed so every
//! experiment in EXPERIMENTS.md is reproducible bit-for-bit.

/// Minimal RNG interface: everything derives from `next_u64`.
pub trait Rng {
    fn next_u64(&mut self) -> u64;

    #[inline]
    fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)` by rejection sampling (no modulo bias).
    #[inline]
    fn gen_below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0);
        // Zone rejection: accept x < zone where zone = bound * floor(2^64/bound).
        let zone = bound.wrapping_mul(u64::MAX / bound);
        loop {
            let x = self.next_u64();
            if zone == 0 || x < zone {
                return x % bound;
            }
        }
    }

    /// Uniform field element in `[0, p)`.
    ///
    /// Fast path for small moduli (every Hi-SAFE field has `p ≤ 131`):
    /// Lemire multiply-shift rejection on a single `u32` draw — half the
    /// keystream of the generic u64 path and no modulo. §Perf: this cut
    /// dealer time ~35%.
    #[inline]
    fn gen_field(&mut self, p: u64) -> u64 {
        if p < (1 << 31) {
            let p32 = p as u32;
            // threshold = (2^32 − p) mod p; draws with low < threshold are
            // biased and rejected (probability < p/2^32 ≈ 10^-8 here).
            let threshold = p32.wrapping_neg() % p32;
            loop {
                let x = self.next_u32();
                let m = x as u64 * p32 as u64;
                if (m as u32) >= threshold {
                    return m >> 32;
                }
            }
        } else {
            self.gen_below(p)
        }
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal via Box–Muller.
    fn gen_gaussian(&mut self) -> f64 {
        loop {
            let u1 = self.gen_f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.gen_f64();
            let r = (-2.0 * u1.ln()).sqrt();
            return r * (2.0 * std::f64::consts::PI * u2).cos();
        }
    }

    /// Fill a slice with uniform field elements in `[0, p)`.
    ///
    /// Default loops [`Rng::gen_field`]; [`ChaCha20Rng`] overrides with a
    /// block-wise fast path (§Perf: the Beaver dealer is keystream-bound).
    fn fill_field(&mut self, p: u64, out: &mut [u64]) {
        for x in out.iter_mut() {
            *x = self.gen_field(p);
        }
    }

    /// Uniform ±1 sign.
    #[inline]
    fn gen_sign(&mut self) -> i8 {
        if self.next_u64() & 1 == 0 {
            1
        } else {
            -1
        }
    }

    /// Fisher–Yates shuffle.
    fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.gen_below(i as u64 + 1) as usize;
            v.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `0..n` (partial Fisher–Yates).
    fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.gen_below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

// ---------------------------------------------------------------- ChaCha20

/// IETF ChaCha20 (RFC 8439 block function) in counter mode as a CSPRNG.
pub struct ChaCha20Rng {
    state: [u32; 16],
    buf: [u32; 16],
    /// Next u32 index into `buf`; 16 means "refill".
    idx: usize,
}

const CHACHA_CONST: [u32; 4] = [0x6170_7865, 0x3320_646e, 0x7962_2d32, 0x6b20_6574];

impl ChaCha20Rng {
    /// Seed from 32 bytes of key material.
    pub fn from_key(key: [u8; 32]) -> Self {
        let mut state = [0u32; 16];
        state[..4].copy_from_slice(&CHACHA_CONST);
        for i in 0..8 {
            state[4 + i] = u32::from_le_bytes([
                key[4 * i],
                key[4 * i + 1],
                key[4 * i + 2],
                key[4 * i + 3],
            ]);
        }
        // counter = 0, nonce = 0
        ChaCha20Rng { state, buf: [0; 16], idx: 16 }
    }

    /// Convenience seeding from a u64 (expanded via SplitMix64 so close
    /// seeds give unrelated keys).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let mut key = [0u8; 32];
        for chunk in key.chunks_exact_mut(8) {
            chunk.copy_from_slice(&sm.next().to_le_bytes());
        }
        Self::from_key(key)
    }

    /// Derive an independent stream (e.g. one per user / per round) by
    /// hashing the parent key with a domain label.
    pub fn fork(&mut self, label: u64) -> ChaCha20Rng {
        let mut key = [0u8; 32];
        let a = self.next_u64() ^ label.rotate_left(17);
        let b = self.next_u64() ^ label.wrapping_mul(0x9e37_79b9_7f4a_7c15);
        let c = self.next_u64();
        let d = self.next_u64();
        key[..8].copy_from_slice(&a.to_le_bytes());
        key[8..16].copy_from_slice(&b.to_le_bytes());
        key[16..24].copy_from_slice(&c.to_le_bytes());
        key[24..].copy_from_slice(&d.to_le_bytes());
        ChaCha20Rng::from_key(key)
    }

    #[inline(always)]
    fn quarter(s: &mut [u32; 16], a: usize, b: usize, c: usize, d: usize) {
        s[a] = s[a].wrapping_add(s[b]);
        s[d] = (s[d] ^ s[a]).rotate_left(16);
        s[c] = s[c].wrapping_add(s[d]);
        s[b] = (s[b] ^ s[c]).rotate_left(12);
        s[a] = s[a].wrapping_add(s[b]);
        s[d] = (s[d] ^ s[a]).rotate_left(8);
        s[c] = s[c].wrapping_add(s[d]);
        s[b] = (s[b] ^ s[c]).rotate_left(7);
    }

    fn refill(&mut self) {
        let mut w = self.state;
        for _ in 0..10 {
            // column rounds
            Self::quarter(&mut w, 0, 4, 8, 12);
            Self::quarter(&mut w, 1, 5, 9, 13);
            Self::quarter(&mut w, 2, 6, 10, 14);
            Self::quarter(&mut w, 3, 7, 11, 15);
            // diagonal rounds
            Self::quarter(&mut w, 0, 5, 10, 15);
            Self::quarter(&mut w, 1, 6, 11, 12);
            Self::quarter(&mut w, 2, 7, 8, 13);
            Self::quarter(&mut w, 3, 4, 9, 14);
        }
        for i in 0..16 {
            self.buf[i] = w[i].wrapping_add(self.state[i]);
        }
        // 64-bit counter across words 12..13
        let (lo, carry) = self.state[12].overflowing_add(1);
        self.state[12] = lo;
        if carry {
            self.state[13] = self.state[13].wrapping_add(1);
        }
        self.idx = 0;
    }
}

impl Rng for ChaCha20Rng {
    /// Block-wise field sampling: drains whole keystream blocks with the
    /// Lemire rejection inlined, skipping per-call index bookkeeping.
    fn fill_field(&mut self, p: u64, out: &mut [u64]) {
        debug_assert!(p >= 2 && p < (1 << 31));
        let p32 = p as u32;
        let threshold = p32.wrapping_neg() % p32;
        let mut i = 0;
        while i < out.len() {
            if self.idx >= 16 {
                self.refill();
            }
            while self.idx < 16 && i < out.len() {
                let x = self.buf[self.idx];
                self.idx += 1;
                let m = x as u64 * p32 as u64;
                if (m as u32) >= threshold {
                    out[i] = m >> 32;
                    i += 1;
                }
            }
        }
    }

    /// u32-granular draw: consumes exactly one keystream word (the default
    /// trait impl would burn a full u64 per u32 — §Perf).
    #[inline]
    fn next_u32(&mut self) -> u32 {
        if self.idx >= 16 {
            self.refill();
        }
        let w = self.buf[self.idx];
        self.idx += 1;
        w
    }

    #[inline]
    fn next_u64(&mut self) -> u64 {
        if self.idx >= 15 {
            // need two fresh u32s from the same block when possible;
            // simplest correct policy: refill if fewer than 2 remain.
            if self.idx >= 16 {
                self.refill();
            } else {
                // one word left — use it and one from the next block
                let lo = self.buf[self.idx] as u64;
                self.refill();
                let hi = self.buf[self.idx] as u64;
                self.idx += 1;
                return (hi << 32) | lo;
            }
        }
        let lo = self.buf[self.idx] as u64;
        let hi = self.buf[self.idx + 1] as u64;
        self.idx += 2;
        (hi << 32) | lo
    }
}

// ------------------------------------------------------------- SplitMix64

/// SplitMix64 — used for seed expansion only.
pub struct SplitMix64(u64);

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64(seed)
    }
    #[inline]
    pub fn next(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9e37_79b9_7f4a_7c15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }
}

// ---------------------------------------------------------- xoshiro256++

/// xoshiro256++ 1.0 — fast statistical PRNG (Blackman & Vigna).
pub struct Xoshiro256pp {
    s: [u64; 4],
}

impl Xoshiro256pp {
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Xoshiro256pp { s: [sm.next(), sm.next(), sm.next(), sm.next()] }
    }
}

impl Rng for Xoshiro256pp {
    #[inline]
    fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chacha_known_answer() {
        // RFC 8439 §2.3.2 test vector: key 00:01:..:1f, counter=1,
        // nonce=000000090000004a00000000. Our RNG uses counter=0/nonce=0,
        // so verify the raw block function via a manual state instead.
        let mut rng = ChaCha20Rng::from_key([0u8; 32]);
        // First u64s must be deterministic and non-degenerate.
        let a = rng.next_u64();
        let b = rng.next_u64();
        assert_ne!(a, b);
        let mut rng2 = ChaCha20Rng::from_key([0u8; 32]);
        assert_eq!(a, rng2.next_u64());
        assert_eq!(b, rng2.next_u64());
    }

    #[test]
    fn chacha_rfc8439_block() {
        // Full RFC 8439 §2.3.2 vector, exercised by constructing the state
        // exactly as the RFC does and running one refill.
        let key: [u8; 32] = core::array::from_fn(|i| i as u8);
        let mut rng = ChaCha20Rng::from_key(key);
        rng.state[12] = 1; // block counter
        // nonce words
        rng.state[13] = 0x0900_0000;
        rng.state[14] = 0x4a00_0000;
        rng.state[15] = 0x0000_0000;
        rng.refill();
        let expected_first4: [u32; 4] =
            [0xe4e7f110, 0x15593bd1, 0x1fdd0f50, 0xc47120a3];
        assert_eq!(&rng.buf[..4], &expected_first4);
    }

    #[test]
    fn distinct_seeds_distinct_streams() {
        let mut a = ChaCha20Rng::seed_from_u64(1);
        let mut b = ChaCha20Rng::seed_from_u64(2);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }

    #[test]
    fn gen_below_no_bias_smoke() {
        let mut rng = Xoshiro256pp::seed_from_u64(42);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[rng.gen_below(5) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as i64 - 10_000).abs() < 600, "counts={counts:?}");
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut rng = Xoshiro256pp::seed_from_u64(7);
        let n = 100_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.gen_gaussian();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        for _ in 0..100 {
            let idx = rng.sample_indices(100, 24);
            assert_eq!(idx.len(), 24);
            let mut sorted = idx.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 24);
            assert!(idx.iter().all(|&i| i < 100));
        }
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = ChaCha20Rng::seed_from_u64(9);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
