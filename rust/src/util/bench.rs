//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Provides warmup, adaptive iteration-count selection targeting a wall
//! budget, and median/MAD statistics. All `rust/benches/*` binaries
//! (declared `harness = false`) use this.

use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    /// Median per-iteration time.
    pub median: Duration,
    /// Median absolute deviation.
    pub mad: Duration,
    pub iters_per_sample: u64,
    pub samples: usize,
}

impl Stats {
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / self.median.as_secs_f64()
    }
}

fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

impl std::fmt::Display for Stats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<48} {:>12} ± {:>10}  ({} samples × {} iters)",
            self.name,
            fmt_dur(self.median),
            fmt_dur(self.mad),
            self.samples,
            self.iters_per_sample
        )
    }
}

/// Benchmark runner with a per-case wall budget.
pub struct Bencher {
    /// Total wall budget per case (warmup excluded).
    pub budget: Duration,
    pub samples: usize,
    results: Vec<Stats>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new()
    }
}

impl Bencher {
    pub fn new() -> Self {
        // HISAFE_BENCH_FAST=1 shrinks budgets for CI smoke runs.
        let fast = std::env::var("HISAFE_BENCH_FAST").ok().is_some();
        Bencher {
            budget: if fast {
                Duration::from_millis(200)
            } else {
                Duration::from_secs(1)
            },
            samples: if fast { 5 } else { 15 },
            results: Vec::new(),
        }
    }

    /// Measure `f`, which performs ONE logical iteration per call.
    /// Returns per-iteration stats; `f`'s return value is black-boxed.
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> Stats {
        // Warmup + calibration: find iters/sample so one sample ≈ budget/samples.
        let target = self.budget / self.samples as u32;
        let mut iters = 1u64;
        loop {
            let t0 = Instant::now();
            for _ in 0..iters {
                black_box(f());
            }
            let el = t0.elapsed();
            if el >= target || iters >= (1 << 30) {
                // scale iters to hit target
                if el < target && iters < (1 << 30) {
                    break;
                }
                let scale = target.as_secs_f64() / el.as_secs_f64().max(1e-12);
                iters = ((iters as f64 * scale).ceil() as u64).max(1);
                break;
            }
            iters *= 2;
        }
        let mut times: Vec<Duration> = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..iters {
                black_box(f());
            }
            times.push(t0.elapsed() / iters as u32);
        }
        times.sort();
        let median = times[times.len() / 2];
        let mut devs: Vec<i128> = times
            .iter()
            .map(|t| (t.as_nanos() as i128 - median.as_nanos() as i128).abs())
            .collect();
        devs.sort();
        let mad = Duration::from_nanos(devs[devs.len() / 2] as u64);
        let s = Stats {
            name: name.to_string(),
            median,
            mad,
            iters_per_sample: iters,
            samples: self.samples,
        };
        println!("{s}");
        self.results.push(s.clone());
        s
    }

    pub fn results(&self) -> &[Stats] {
        &self.results
    }
}

/// Optimization barrier (stable-rust version of `std::hint::black_box`;
/// we use the std one, wrapped so benches read uniformly).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Print a section header in bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        std::env::set_var("HISAFE_BENCH_FAST", "1");
        let mut b = Bencher::new();
        b.budget = Duration::from_millis(50);
        b.samples = 3;
        let s = b.bench("noop-ish", || {
            let mut acc = 0u64;
            for i in 0..100u64 {
                acc = acc.wrapping_add(black_box(i) * i);
            }
            acc
        });
        assert!(s.median >= Duration::from_nanos(0));
        assert_eq!(b.results().len(), 1);
    }
}
