//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Provides warmup, adaptive iteration-count selection targeting a wall
//! budget, median/MAD statistics, and a machine-readable report: every
//! bench binary ends with [`Bencher::write_json`], which persists its
//! measurements as `BENCH_<name>.json` next to the working directory so
//! CI (and humans diffing two runs) never have to scrape stdout. All
//! `rust/benches/*` binaries (declared `harness = false`) use this.

use crate::util::json::Json;
use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    /// Median per-iteration time.
    pub median: Duration,
    /// Median absolute deviation.
    pub mad: Duration,
    pub iters_per_sample: u64,
    pub samples: usize,
    /// Optional per-iteration work declaration `(count, unit)` — e.g.
    /// `(65536.0, "elements")` or `(1536.0, "bytes")` — attached via
    /// [`Bencher::annotate_throughput`]. When present the JSON report
    /// carries the count, the unit, and the derived `per_sec` rate, so
    /// `BENCH_<name>.json` records throughput trajectories (elements/sec,
    /// bytes/round) and not just wall-clock.
    pub items: Option<(f64, String)>,
}

impl Stats {
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / self.median.as_secs_f64()
    }
}

fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

impl std::fmt::Display for Stats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<48} {:>12} ± {:>10}  ({} samples × {} iters)",
            self.name,
            fmt_dur(self.median),
            fmt_dur(self.mad),
            self.samples,
            self.iters_per_sample
        )
    }
}

/// Benchmark runner with a per-case wall budget.
pub struct Bencher {
    /// Total wall budget per case (warmup excluded).
    pub budget: Duration,
    pub samples: usize,
    results: Vec<Stats>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new()
    }
}

impl Bencher {
    pub fn new() -> Self {
        // HISAFE_BENCH_FAST=1 shrinks budgets for CI smoke runs.
        let fast = std::env::var("HISAFE_BENCH_FAST").ok().is_some();
        Bencher {
            budget: if fast {
                Duration::from_millis(200)
            } else {
                Duration::from_secs(1)
            },
            samples: if fast { 5 } else { 15 },
            results: Vec::new(),
        }
    }

    /// Measure `f`, which performs ONE logical iteration per call.
    /// Returns per-iteration stats; `f`'s return value is black-boxed.
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> Stats {
        // Warmup + calibration: find iters/sample so one sample ≈ budget/samples.
        let target = self.budget / self.samples as u32;
        let mut iters = 1u64;
        loop {
            let t0 = Instant::now();
            for _ in 0..iters {
                black_box(f());
            }
            let el = t0.elapsed();
            if el >= target || iters >= (1 << 30) {
                // scale iters to hit target
                if el < target && iters < (1 << 30) {
                    break;
                }
                let scale = target.as_secs_f64() / el.as_secs_f64().max(1e-12);
                iters = ((iters as f64 * scale).ceil() as u64).max(1);
                break;
            }
            iters *= 2;
        }
        let mut times: Vec<Duration> = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..iters {
                black_box(f());
            }
            times.push(t0.elapsed() / iters as u32);
        }
        times.sort();
        let median = times[times.len() / 2];
        let mut devs: Vec<i128> = times
            .iter()
            .map(|t| (t.as_nanos() as i128 - median.as_nanos() as i128).abs())
            .collect();
        devs.sort();
        let mad = Duration::from_nanos(devs[devs.len() / 2] as u64);
        let s = Stats {
            name: name.to_string(),
            median,
            mad,
            iters_per_sample: iters,
            samples: self.samples,
            items: None,
        };
        println!("{s}");
        self.results.push(s.clone());
        s
    }

    pub fn results(&self) -> &[Stats] {
        &self.results
    }

    /// Declare how much work the MOST RECENT measurement does per
    /// iteration — `items` of `unit` (elements, bytes, rounds, …). The
    /// JSON report then emits the count, the unit, and the derived
    /// `per_sec` rate alongside the wall-clock numbers. Panics if no
    /// measurement has been added yet (an annotation with nothing to
    /// annotate is a bench-authoring bug).
    pub fn annotate_throughput(&mut self, items: f64, unit: &str) {
        let last = self
            .results
            .last_mut()
            .expect("annotate_throughput: no measurement to annotate");
        last.items = Some((items, unit.to_string()));
    }

    /// Add a one-shot wall-clock measurement to the report. For sections
    /// that time a scenario once with `Instant` (cold-start pools,
    /// flood/victim races) instead of sampling via [`Bencher::bench`] —
    /// those numbers belong in `BENCH_<name>.json` too.
    pub fn record(&mut self, name: &str, elapsed: Duration) {
        self.results.push(Stats {
            name: name.to_string(),
            median: elapsed,
            mad: Duration::ZERO,
            iters_per_sample: 1,
            samples: 1,
            items: None,
        });
    }

    /// The report as JSON: the harness configuration (wall budget,
    /// samples, `HISAFE_BENCH_FAST`) plus the run mode — `"strict"` when
    /// `HISAFE_BENCH_STRICT=1` (wall-clock assertions armed), else
    /// `"advisory"` — and one object per measurement with nanosecond
    /// medians, so two runs diff numerically.
    pub fn report_json(&self, name: &str) -> Json {
        let strict = std::env::var("HISAFE_BENCH_STRICT").map(|v| v == "1").unwrap_or(false);
        let fast = std::env::var("HISAFE_BENCH_FAST").ok().is_some();
        let mut j = Json::obj();
        j.set("name", name)
            .set("mode", if strict { "strict" } else { "advisory" })
            .set("fast", fast)
            .set("budget_ms", self.budget.as_millis() as u64)
            .set("samples", self.samples as u64)
            .set(
                "results",
                self.results
                    .iter()
                    .map(|s| {
                        let mut r = Json::obj();
                        r.set("name", s.name.clone())
                            .set("median_ns", s.median.as_nanos() as u64)
                            .set("mad_ns", s.mad.as_nanos() as u64)
                            .set("iters_per_sample", s.iters_per_sample)
                            .set("samples", s.samples as u64);
                        // Throughput keys appear only on annotated
                        // measurements (schema snapshot pins both shapes).
                        if let Some((items, unit)) = &s.items {
                            r.set("items_per_iter", *items)
                                .set("unit", unit.clone())
                                .set("per_sec", s.throughput(*items));
                        }
                        r
                    })
                    .collect::<Vec<_>>(),
            );
        j
    }

    /// Write the report to `BENCH_<name>.json` in the current directory.
    /// Advisory runs warn and continue if the write fails (a read-only
    /// checkout shouldn't kill a measurement run); strict runs treat a
    /// missing report as a failure like any other armed assertion.
    pub fn write_json(&self, name: &str) {
        let strict = std::env::var("HISAFE_BENCH_STRICT").map(|v| v == "1").unwrap_or(false);
        let path = format!("BENCH_{name}.json");
        match std::fs::write(&path, self.report_json(name).to_string_pretty()) {
            Ok(()) => println!("\nwrote {path} ({} measurement(s))", self.results.len()),
            Err(e) if strict => panic!("strict bench mode: failed to write {path}: {e}"),
            Err(e) => eprintln!("warning: could not write {path}: {e} (advisory run, continuing)"),
        }
    }
}

/// Optimization barrier (stable-rust version of `std::hint::black_box`;
/// we use the std one, wrapped so benches read uniformly).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Print a section header in bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        std::env::set_var("HISAFE_BENCH_FAST", "1");
        let mut b = Bencher::new();
        b.budget = Duration::from_millis(50);
        b.samples = 3;
        let s = b.bench("noop-ish", || {
            let mut acc = 0u64;
            for i in 0..100u64 {
                acc = acc.wrapping_add(black_box(i) * i);
            }
            acc
        });
        assert!(s.median >= Duration::from_nanos(0));
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn report_json_schema_snapshot() {
        // Pin the exact key sets of BENCH_<name>.json (top level and
        // per-result) so downstream diff tooling can't silently break.
        let mut b = Bencher::new();
        b.budget = Duration::from_millis(10);
        b.samples = 2;
        b.bench("measured", || 1u64 + 1);
        b.record("one_shot", Duration::from_micros(250));
        b.record("with_rate", Duration::from_micros(500));
        b.annotate_throughput(2048.0, "bytes");
        let j = b.report_json("unit");
        let keys = |v: &Json| -> Vec<String> {
            match v {
                Json::Obj(m) => m.keys().cloned().collect(),
                other => panic!("expected object, got {other:?}"),
            }
        };
        assert_eq!(
            keys(&j),
            ["budget_ms", "fast", "mode", "name", "results", "samples"],
            "bench report top-level schema drifted"
        );
        let results = j.get("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 3);
        // Un-annotated measurements keep the wall-clock-only shape…
        for r in &results[..2] {
            assert_eq!(
                keys(r),
                ["iters_per_sample", "mad_ns", "median_ns", "name", "samples"],
                "bench result schema drifted"
            );
        }
        // …and throughput-annotated ones add exactly the three rate keys.
        assert_eq!(
            keys(&results[2]),
            [
                "items_per_iter",
                "iters_per_sample",
                "mad_ns",
                "median_ns",
                "name",
                "per_sec",
                "samples",
                "unit"
            ],
            "annotated bench result schema drifted"
        );
        // The one-shot record keeps its wall time and a unit sample count.
        assert_eq!(results[1].get("name").unwrap().as_str().unwrap(), "one_shot");
        assert_eq!(results[1].get("median_ns").unwrap().as_u64(), Some(250_000));
        assert_eq!(results[1].get("samples").unwrap().as_u64(), Some(1));
        // Rate derivation: 2048 bytes / 500 µs = 4.096 MB/s.
        assert_eq!(results[2].get("unit").unwrap().as_str().unwrap(), "bytes");
        let rate = results[2].get("per_sec").unwrap().as_f64().unwrap();
        assert!((rate - 4_096_000.0).abs() < 1.0, "per_sec derivation drifted: {rate}");
        // Mode is one of the two documented values, and roundtrips.
        let mode = j.get("mode").unwrap().as_str().unwrap().to_string();
        assert!(mode == "advisory" || mode == "strict");
        let text = j.to_string_pretty();
        let back = crate::util::json::parse(&text).unwrap();
        assert_eq!(back.get("name").unwrap().as_str().unwrap(), "unit");
    }
}
