//! Minimal property-based testing harness.
//!
//! `proptest` is not available offline, so this provides the same
//! discipline in ~100 lines: run a property against many seeded random
//! inputs; on failure, report the failing case and the seed that
//! regenerates it. No shrinking — inputs here (field elements, sign
//! vectors, user counts) are already small and interpretable.
//!
//! ```no_run
//! use hisafe::prop_assert_eq;
//! use hisafe::util::prop::{forall, Gen};
//! forall("add commutes", 100, |g: &mut Gen| {
//!     let p = g.prime(100);
//!     let f = hisafe::field::Fp::new(p);
//!     let (a, b) = (g.field(p), g.field(p));
//!     prop_assert_eq!(f.add(a, b), f.add(b, a));
//!     Ok(())
//! });
//! ```

use super::rng::{Rng, Xoshiro256pp};
use crate::field::next_prime;

/// Input generator handed to each property iteration.
pub struct Gen {
    rng: Xoshiro256pp,
    /// Seed that reproduces this iteration (printed on failure).
    pub seed: u64,
}

impl Gen {
    fn new(seed: u64) -> Self {
        Gen { rng: Xoshiro256pp::seed_from_u64(seed), seed }
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    /// Uniform in `[lo, hi]` inclusive.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.rng.gen_below(hi - lo + 1)
    }

    pub fn usize_range(&mut self, lo: usize, hi: usize) -> usize {
        self.range(lo as u64, hi as u64) as usize
    }

    /// Uniform field element below `p`.
    pub fn field(&mut self, p: u64) -> u64 {
        self.rng.gen_field(p)
    }

    /// Random prime in `(2, bound]` (via next_prime of a random base).
    pub fn prime(&mut self, bound: u64) -> u64 {
        let base = self.range(2, bound.saturating_sub(1));
        let p = next_prime(base);
        if p > bound {
            next_prime(2)
        } else {
            p
        }
    }

    /// Random ±1 sign vector of length `d`.
    pub fn sign_vec(&mut self, d: usize) -> Vec<i8> {
        (0..d).map(|_| self.rng.gen_sign()).collect()
    }

    /// Random field-element vector.
    pub fn field_vec(&mut self, p: u64, d: usize) -> Vec<u64> {
        (0..d).map(|_| self.rng.gen_field(p)).collect()
    }

    pub fn f64(&mut self) -> f64 {
        self.rng.gen_f64()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn rng(&mut self) -> &mut Xoshiro256pp {
        &mut self.rng
    }
}

/// Property result: `Err(msg)` fails the case with context.
pub type PropResult = Result<(), String>;

/// Run `prop` against `cases` random inputs. Panics (test failure) on the
/// first failing case, printing the seed that reproduces it.
///
/// Honors `HISAFE_PROP_SEED` to re-run a single failing seed and
/// `HISAFE_PROP_CASES` to scale case counts (CI vs local).
pub fn forall<F>(name: &str, cases: u64, mut prop: F)
where
    F: FnMut(&mut Gen) -> PropResult,
{
    if let Ok(s) = std::env::var("HISAFE_PROP_SEED") {
        let seed: u64 = s.parse().expect("HISAFE_PROP_SEED must be u64");
        let mut g = Gen::new(seed);
        if let Err(msg) = prop(&mut g) {
            panic!("property '{name}' failed (seed {seed}): {msg}");
        }
        return;
    }
    let cases = std::env::var("HISAFE_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(cases);
    // Deterministic but name-dependent base seed: independent properties
    // explore independent input streams.
    let base = name
        .bytes()
        .fold(0xcbf2_9ce4_8422_2325u64, |h, b| {
            (h ^ b as u64).wrapping_mul(0x1000_0000_01b3)
        });
    for i in 0..cases {
        let seed = base.wrapping_add(i).wrapping_mul(0x9e37_79b9_7f4a_7c15);
        let mut g = Gen::new(seed);
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property '{name}' failed on case {i}/{cases} \
                 (re-run with HISAFE_PROP_SEED={seed}): {msg}"
            );
        }
    }
}

/// `assert_eq!` analogue that returns a `PropResult` instead of panicking,
/// so `forall` can attach the reproducing seed.
#[macro_export]
macro_rules! prop_assert_eq {
    ($a:expr, $b:expr) => {{
        let (a, b) = (&$a, &$b);
        if a != b {
            return Err(format!(
                "{} != {} ({:?} vs {:?})",
                stringify!($a),
                stringify!($b),
                a,
                b
            ));
        }
    }};
    ($a:expr, $b:expr, $($ctx:tt)+) => {{
        let (a, b) = (&$a, &$b);
        if a != b {
            return Err(format!(
                "{}: {} != {} ({:?} vs {:?})",
                format!($($ctx)+),
                stringify!($a),
                stringify!($b),
                a,
                b
            ));
        }
    }};
}

/// Boolean property assertion for [`forall`] bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr) => {{
        if !$cond {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    }};
    ($cond:expr, $($ctx:tt)+) => {{
        if !$cond {
            return Err(format!(
                "{}: assertion failed: {}",
                format!($($ctx)+),
                stringify!($cond)
            ));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall("trivial", 100, |g| {
            let x = g.range(0, 10);
            prop_assert!(x <= 10);
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn forall_reports_failures() {
        forall("always-fails", 10, |_| Err("nope".into()));
    }

    #[test]
    fn gen_prime_in_bound() {
        forall("gen-prime", 200, |g| {
            let p = g.prime(101);
            prop_assert!(crate::field::is_prime(p), "p={p}");
            prop_assert!(p <= 101, "p={p}");
            Ok(())
        });
    }
}
