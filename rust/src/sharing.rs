//! Additive secret sharing over `F_p` (Table II: `⟦x⟧ᵢ` notation).
//!
//! A secret vector `z ∈ F_p^d` is split into `n` shares with
//! `Σᵢ ⟦z⟧ᵢ = z (mod p)`; any `n−1` shares are jointly uniform and carry
//! no information about `z` (the basis of Lemma 2 / Theorem 2).
//!
//! A key structural point Hi-SAFE exploits: the users' *inputs*
//! `xᵢ ∈ {−1,+1}^d` **are already additive shares of the aggregate**
//! `x = Σ xᵢ` — no input-sharing round is needed; sharing is only used for
//! the Beaver triples and (in tests/simulator) for resharing outputs.

use crate::field::Fp;
use crate::util::rng::Rng;

/// Split `secret` into `n_parties` additive shares (vectors of the same
/// dimension). Shares `1..n` are uniform; share `0` is the difference.
pub fn share_vec<R: Rng>(
    fp: Fp,
    secret: &[u64],
    n_parties: usize,
    rng: &mut R,
) -> Vec<Vec<u64>> {
    assert!(n_parties >= 1);
    let p = fp.modulus();
    let d = secret.len();
    let mut shares = vec![vec![0u64; d]; n_parties];
    // §Perf: fill whole per-party rows (block-wise keystream), then derive
    // party 0's share as secret − Σ others with raw accumulation and one
    // reduction pass (raw sum < n·p ≪ 2^64).
    let mut acc = vec![0u64; d];
    for s in shares.iter_mut().skip(1) {
        rng.fill_field(p, s);
        fp.vec_add_raw(&mut acc, s);
    }
    fp.vec_reduce_in_place(&mut acc);
    for j in 0..d {
        debug_assert!(secret[j] < p);
        shares[0][j] = fp.sub(secret[j], acc[j]);
    }
    shares
}

/// Reconstruct the secret from all shares.
pub fn reconstruct_vec(fp: Fp, shares: &[Vec<u64>]) -> Vec<u64> {
    assert!(!shares.is_empty());
    let d = shares[0].len();
    let mut out = vec![0u64; d];
    for s in shares {
        assert_eq!(s.len(), d, "inconsistent share dimensions");
        fp.vec_add_assign(&mut out, s);
    }
    out
}

/// Scalar versions (used by the Appendix-A walkthrough example).
pub fn share_scalar<R: Rng>(fp: Fp, secret: u64, n_parties: usize, rng: &mut R) -> Vec<u64> {
    share_vec(fp, &[secret], n_parties, rng)
        .into_iter()
        .map(|v| v[0])
        .collect()
}

pub fn reconstruct_scalar(fp: Fp, shares: &[u64]) -> u64 {
    shares.iter().fold(0u64, |acc, &s| fp.add(acc, s))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::field::next_prime;
    use crate::util::prop::forall;
    use crate::util::rng::ChaCha20Rng;
    use crate::{prop_assert, prop_assert_eq};

    #[test]
    fn roundtrip_property() {
        forall("share/reconstruct roundtrip", 300, |g| {
            let p = g.prime(101);
            let fp = Fp::new(p);
            let d = g.usize_range(1, 64);
            let n = g.usize_range(1, 12);
            let secret = g.field_vec(p, d);
            let mut rng = ChaCha20Rng::seed_from_u64(g.u64());
            let shares = share_vec(fp, &secret, n, &mut rng);
            prop_assert_eq!(shares.len(), n);
            prop_assert_eq!(reconstruct_vec(fp, &shares), secret);
            Ok(())
        });
    }

    #[test]
    fn shares_are_canonical() {
        forall("shares canonical", 100, |g| {
            let p = g.prime(101);
            let fp = Fp::new(p);
            let secret = g.field_vec(p, 16);
            let mut rng = ChaCha20Rng::seed_from_u64(g.u64());
            let shares = share_vec(fp, &secret, 5, &mut rng);
            for s in &shares {
                for &x in s {
                    prop_assert!(x < p, "non-canonical share {x} for p={p}");
                }
            }
            Ok(())
        });
    }

    /// Any n−1 shares are (statistically) uniform: with the secret fixed,
    /// flipping the secret must not change the marginal distribution of a
    /// proper subset. We check a χ²-style bound on a single coordinate.
    #[test]
    fn proper_subsets_uninformative() {
        let fp = Fp::new(next_prime(24));
        let p = fp.modulus();
        let trials = 20_000usize;
        let mut counts0 = vec![0usize; p as usize];
        let mut counts1 = vec![0usize; p as usize];
        let mut rng = ChaCha20Rng::seed_from_u64(77);
        for t in 0..trials {
            let secret0 = vec![3u64];
            let secret1 = vec![17u64];
            let s0 = share_vec(fp, &secret0, 3, &mut rng);
            let s1 = share_vec(fp, &secret1, 3, &mut rng);
            // observe parties {0,1} (missing party 2): sum of visible shares
            let v0 = fp.add(s0[0][0], s0[1][0]);
            let v1 = fp.add(s1[0][0], s1[1][0]);
            counts0[v0 as usize] += 1;
            counts1[v1 as usize] += 1;
            let _ = t;
        }
        // χ² against uniform for both; 29 cells, expected ~690 each.
        let exp = trials as f64 / p as f64;
        for counts in [&counts0, &counts1] {
            let chi2: f64 = counts
                .iter()
                .map(|&c| {
                    let d = c as f64 - exp;
                    d * d / exp
                })
                .sum();
            // df = 28; 99.9th percentile ≈ 56.9. Generous bound: 70.
            assert!(chi2 < 70.0, "χ² = {chi2}");
        }
    }

    #[test]
    fn scalar_helpers() {
        let fp = Fp::new(5);
        let mut rng = ChaCha20Rng::seed_from_u64(1);
        for secret in 0..5u64 {
            let sh = share_scalar(fp, secret, 3, &mut rng);
            assert_eq!(reconstruct_scalar(fp, &sh), secret);
        }
    }
}
