//! Communication / latency accounting.
//!
//! Every protocol message in [`crate::mpc`] and [`crate::protocol`] is
//! tallied here at field-element granularity so the *measured* costs can
//! be cross-checked against the analytic model in [`crate::cost`]
//! (Tables VII–IX) — the integration tests assert they agree exactly.

/// Byte/bit counters for one protocol execution.
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct CommStats {
    /// Field elements each user uploaded (masked openings + final share),
    /// summed over all users.
    pub uplink_elems_total: u64,
    /// Field elements uploaded by the busiest single user (= per-user cost
    /// when symmetric).
    pub uplink_elems_per_user: u64,
    /// Field elements the server broadcast (δ/ε openings), counted once
    /// (broadcast, not per-recipient).
    pub downlink_elems: u64,
    /// Bits per field element (⌈log p⌉).
    pub elem_bits: u32,
    /// Number of sequential subrounds (server round-trips) — the paper's
    /// latency metric.
    pub subrounds: u64,
    /// Secure multiplications performed (Beaver triples consumed, totaled
    /// over all users' groups).
    pub mults: u64,
    /// Final vote bits broadcast per coordinate (1 or 2 by tie policy).
    pub vote_bits: u32,
}

impl CommStats {
    /// Per-user uplink cost in bits — the paper's `C_u` (for one vote
    /// coordinate; multiply by `d` for a model).
    pub fn c_u_bits(&self) -> u64 {
        self.uplink_elems_per_user * self.elem_bits as u64
    }

    /// Total uplink cost in bits summed over *all* users (`n · C_u`).
    pub fn c_t_bits(&self) -> u64 {
        self.uplink_elems_total * self.elem_bits as u64
    }

    /// The paper's `C_T = ℓ·R·⌈log p₁⌉`: this equals the total *broadcast*
    /// (downlink) bits — one `(δ, ε)` pair per multiplication per group —
    /// because the per-group opened elements mirror the per-user masked
    /// uploads. (The paper's "total" is ℓ·C_u, not n·C_u.)
    pub fn c_t_paper_bits(&self) -> u64 {
        self.downlink_elems * self.elem_bits as u64
    }

    /// Machine-readable form for run logs (`runs/*.json`, the `sweep`
    /// command's per-tenant reports): every raw counter plus the derived
    /// `C_u`/`C_T` bit costs, so multi-tenant runs report measured
    /// communication per tenant, not just the analytic model.
    pub fn to_json(&self) -> crate::util::json::Json {
        let mut j = crate::util::json::Json::obj();
        j.set("uplink_elems_total", self.uplink_elems_total)
            .set("uplink_elems_per_user", self.uplink_elems_per_user)
            .set("downlink_elems", self.downlink_elems)
            .set("elem_bits", self.elem_bits as u64)
            .set("subrounds", self.subrounds)
            .set("mults", self.mults)
            .set("vote_bits", self.vote_bits as u64)
            .set("c_u_bits", self.c_u_bits())
            .set("c_t_bits", self.c_t_bits());
        j
    }

    pub fn merge(&mut self, other: &CommStats) {
        self.uplink_elems_total += other.uplink_elems_total;
        self.uplink_elems_per_user =
            self.uplink_elems_per_user.max(other.uplink_elems_per_user);
        self.downlink_elems += other.downlink_elems;
        self.elem_bits = self.elem_bits.max(other.elem_bits);
        self.subrounds = self.subrounds.max(other.subrounds);
        self.mults += other.mults;
        self.vote_bits = self.vote_bits.max(other.vote_bits);
    }
}

/// Wall-clock phase timings for Table V.
#[derive(Debug, Default, Clone)]
pub struct PhaseTimings {
    pub offline_triple_gen: std::time::Duration,
    pub offline_poly_precompute: std::time::Duration,
    pub online_secure_eval: std::time::Duration,
}

impl PhaseTimings {
    pub fn total(&self) -> std::time::Duration {
        self.offline_triple_gen + self.offline_poly_precompute + self.online_secure_eval
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cost_arithmetic() {
        let s = CommStats {
            uplink_elems_total: 12,
            uplink_elems_per_user: 4,
            downlink_elems: 4,
            elem_bits: 3,
            subrounds: 2,
            mults: 2,
            vote_bits: 1,
        };
        assert_eq!(s.c_u_bits(), 12); // paper: n₁=3 → C_u = 12 bits
        assert_eq!(s.c_t_bits(), 36);
    }

    #[test]
    fn json_surface_carries_raw_and_derived_counters() {
        let s = CommStats {
            uplink_elems_total: 12,
            uplink_elems_per_user: 4,
            downlink_elems: 4,
            elem_bits: 3,
            subrounds: 2,
            mults: 2,
            vote_bits: 1,
        };
        let j = s.to_json();
        assert_eq!(j.get("uplink_elems_total").unwrap().as_u64(), Some(12));
        assert_eq!(j.get("c_u_bits").unwrap().as_u64(), Some(12));
        assert_eq!(j.get("c_t_bits").unwrap().as_u64(), Some(36));
        assert_eq!(j.get("subrounds").unwrap().as_u64(), Some(2));
    }

    #[test]
    fn merge_semantics() {
        let mut a = CommStats {
            uplink_elems_total: 10,
            uplink_elems_per_user: 5,
            downlink_elems: 2,
            elem_bits: 3,
            subrounds: 2,
            mults: 3,
            vote_bits: 1,
        };
        let b = CommStats {
            uplink_elems_total: 7,
            uplink_elems_per_user: 7,
            downlink_elems: 1,
            elem_bits: 4,
            subrounds: 3,
            mults: 2,
            vote_bits: 2,
        };
        a.merge(&b);
        assert_eq!(a.uplink_elems_total, 17);
        assert_eq!(a.uplink_elems_per_user, 7);
        assert_eq!(a.subrounds, 3);
        assert_eq!(a.mults, 5);
        assert_eq!(a.elem_bits, 4);
        assert_eq!(a.vote_bits, 2);
    }
}
