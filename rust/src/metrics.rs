//! Communication / latency / admission accounting.
//!
//! Every protocol message in [`crate::mpc`] and [`crate::protocol`] is
//! tallied here at field-element granularity so the *measured* costs can
//! be cross-checked against the analytic model in [`crate::cost`]
//! (Tables VII–IX) — the integration tests assert they agree exactly.
//!
//! [`AdmissionStats`] is the scheduler-side counterpart: per-tenant
//! counters for rounds admitted, throttled, queue-full, and rejected by
//! the admission-control layer in [`crate::engine::AggScheduler`] — the
//! numbers `train_multi` runs and `hisafe sweep` report per tenant.
//!
//! Both structs have a `to_json` surface consumed by `runs/*.json`; its
//! key set is pinned by schema snapshot tests below (and in
//! `fl/trainer.rs`), so the fields documented in README.md and
//! `docs/ARCHITECTURE.md` cannot silently drift.

/// Byte/bit counters for one protocol execution.
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct CommStats {
    /// Field elements each user uploaded (masked openings + final share),
    /// summed over all users.
    pub uplink_elems_total: u64,
    /// Field elements uploaded by the busiest single user (= per-user cost
    /// when symmetric).
    pub uplink_elems_per_user: u64,
    /// Field elements the server broadcast (δ/ε openings), counted once
    /// (broadcast, not per-recipient).
    pub downlink_elems: u64,
    /// Bits per field element (⌈log p⌉).
    pub elem_bits: u32,
    /// Number of sequential subrounds (server round-trips) — the paper's
    /// latency metric.
    pub subrounds: u64,
    /// Secure multiplications performed (Beaver triples consumed, totaled
    /// over all users' groups).
    pub mults: u64,
    /// Final vote bits broadcast per coordinate (1 or 2 by tie policy).
    pub vote_bits: u32,
}

impl CommStats {
    /// Per-user uplink cost in bits — the paper's `C_u` (for one vote
    /// coordinate; multiply by `d` for a model).
    pub fn c_u_bits(&self) -> u64 {
        self.uplink_elems_per_user * self.elem_bits as u64
    }

    /// Total uplink cost in bits summed over *all* users (`n · C_u`).
    pub fn c_t_bits(&self) -> u64 {
        self.uplink_elems_total * self.elem_bits as u64
    }

    /// The paper's `C_T = ℓ·R·⌈log p₁⌉`: this equals the total *broadcast*
    /// (downlink) bits — one `(δ, ε)` pair per multiplication per group —
    /// because the per-group opened elements mirror the per-user masked
    /// uploads. (The paper's "total" is ℓ·C_u, not n·C_u.)
    pub fn c_t_paper_bits(&self) -> u64 {
        self.downlink_elems * self.elem_bits as u64
    }

    /// Machine-readable form for run logs (`runs/*.json`, the `sweep`
    /// command's per-tenant reports): every raw counter plus the derived
    /// `C_u`/`C_T` bit costs, so multi-tenant runs report measured
    /// communication per tenant, not just the analytic model.
    pub fn to_json(&self) -> crate::util::json::Json {
        let mut j = crate::util::json::Json::obj();
        j.set("uplink_elems_total", self.uplink_elems_total)
            .set("uplink_elems_per_user", self.uplink_elems_per_user)
            .set("downlink_elems", self.downlink_elems)
            .set("elem_bits", self.elem_bits as u64)
            .set("subrounds", self.subrounds)
            .set("mults", self.mults)
            .set("vote_bits", self.vote_bits as u64)
            .set("c_u_bits", self.c_u_bits())
            .set("c_t_bits", self.c_t_bits());
        j
    }

    pub fn merge(&mut self, other: &CommStats) {
        self.uplink_elems_total += other.uplink_elems_total;
        self.uplink_elems_per_user =
            self.uplink_elems_per_user.max(other.uplink_elems_per_user);
        self.downlink_elems += other.downlink_elems;
        self.elem_bits = self.elem_bits.max(other.elem_bits);
        self.subrounds = self.subrounds.max(other.subrounds);
        self.mults += other.mults;
        self.vote_bits = self.vote_bits.max(other.vote_bits);
    }
}

/// Per-tenant admission-control counters, kept by every
/// [`crate::engine::AggSession`] and surfaced through
/// [`AggSession::admission_stats`](crate::engine::AggSession::admission_stats).
///
/// The counters record *decisions*, not time: one increment per admitted
/// round, per throttle denial (token bucket empty), per queue-full denial
/// (bounded dealing queue at depth), and per outright rejection (a request
/// the configured [`QosPolicy`](crate::engine::QosPolicy) can never
/// admit). Blocking [`Engine::run_round`](crate::engine::Engine::run_round)
/// calls count as admitted — they bypass the rate limiter by design, not
/// by accident.
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct AdmissionStats {
    /// Rounds admitted and executed (the try- and blocking paths both
    /// count here).
    pub admitted_rounds: u64,
    /// Denials because a token bucket (rounds/sec or triples/sec) was
    /// empty — the caller was told to retry after a delay.
    pub throttled: u64,
    /// Denials because the bounded per-tenant dealing queue was at its
    /// configured depth.
    pub queue_full: u64,
    /// Requests no retry can ever satisfy under the session's policy
    /// (e.g. a prefetch larger than the whole queue depth).
    pub rejected: u64,
}

impl AdmissionStats {
    /// Total denials of any kind (throttle + queue-full + reject).
    pub fn denials(&self) -> u64 {
        self.throttled + self.queue_full + self.rejected
    }

    /// Machine-readable form for run logs (`runs/*.json`): one key per
    /// counter. The key set is pinned by a schema snapshot test below.
    pub fn to_json(&self) -> crate::util::json::Json {
        let mut j = crate::util::json::Json::obj();
        j.set("admitted_rounds", self.admitted_rounds)
            .set("throttled", self.throttled)
            .set("queue_full", self.queue_full)
            .set("rejected", self.rejected);
        j
    }

    pub fn merge(&mut self, other: &AdmissionStats) {
        self.admitted_rounds += other.admitted_rounds;
        self.throttled += other.throttled;
        self.queue_full += other.queue_full;
        self.rejected += other.rejected;
    }

    /// Merge any number of per-session (or per-shard) counters into one
    /// aggregate — what [`crate::service::AggFrontend`] reports for a
    /// frontend-wide `StatsQuery` across all of its scheduler shards.
    pub fn merge_all<'a, I>(parts: I) -> AdmissionStats
    where
        I: IntoIterator<Item = &'a AdmissionStats>,
    {
        let mut total = AdmissionStats::default();
        for p in parts {
            total.merge(p);
        }
        total
    }
}

/// Wall-clock phase timings for Table V.
#[derive(Debug, Default, Clone)]
pub struct PhaseTimings {
    pub offline_triple_gen: std::time::Duration,
    pub offline_poly_precompute: std::time::Duration,
    pub online_secure_eval: std::time::Duration,
}

impl PhaseTimings {
    pub fn total(&self) -> std::time::Duration {
        self.offline_triple_gen + self.offline_poly_precompute + self.online_secure_eval
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cost_arithmetic() {
        let s = CommStats {
            uplink_elems_total: 12,
            uplink_elems_per_user: 4,
            downlink_elems: 4,
            elem_bits: 3,
            subrounds: 2,
            mults: 2,
            vote_bits: 1,
        };
        assert_eq!(s.c_u_bits(), 12); // paper: n₁=3 → C_u = 12 bits
        assert_eq!(s.c_t_bits(), 36);
    }

    #[test]
    fn json_surface_carries_raw_and_derived_counters() {
        let s = CommStats {
            uplink_elems_total: 12,
            uplink_elems_per_user: 4,
            downlink_elems: 4,
            elem_bits: 3,
            subrounds: 2,
            mults: 2,
            vote_bits: 1,
        };
        let j = s.to_json();
        assert_eq!(j.get("uplink_elems_total").unwrap().as_u64(), Some(12));
        assert_eq!(j.get("c_u_bits").unwrap().as_u64(), Some(12));
        assert_eq!(j.get("c_t_bits").unwrap().as_u64(), Some(36));
        assert_eq!(j.get("subrounds").unwrap().as_u64(), Some(2));
    }

    /// Schema snapshot: the exact key set `CommStats::to_json` emits.
    /// README.md and docs/ARCHITECTURE.md document these fields; adding,
    /// renaming, or dropping one must be a conscious decision that
    /// updates this list (and the docs) in the same change.
    #[test]
    fn comm_stats_json_schema_snapshot() {
        let j = CommStats::default().to_json();
        let keys: Vec<&str> = match &j {
            crate::util::json::Json::Obj(m) => m.keys().map(|k| k.as_str()).collect(),
            other => panic!("CommStats::to_json must be an object, got {other:?}"),
        };
        // BTreeMap keys come out sorted; keep this list sorted too.
        assert_eq!(
            keys,
            vec![
                "c_t_bits",
                "c_u_bits",
                "downlink_elems",
                "elem_bits",
                "mults",
                "subrounds",
                "uplink_elems_per_user",
                "uplink_elems_total",
                "vote_bits",
            ],
            "CommStats::to_json schema drifted — update docs + this snapshot together"
        );
    }

    #[test]
    fn admission_stats_arithmetic_merge_and_json_schema() {
        let mut a = AdmissionStats {
            admitted_rounds: 5,
            throttled: 2,
            queue_full: 1,
            rejected: 1,
        };
        assert_eq!(a.denials(), 4);
        let b = AdmissionStats {
            admitted_rounds: 3,
            throttled: 1,
            queue_full: 0,
            rejected: 2,
        };
        a.merge(&b);
        assert_eq!(a.admitted_rounds, 8);
        assert_eq!(a.throttled, 3);
        assert_eq!(a.queue_full, 1);
        assert_eq!(a.rejected, 3);
        let j = a.to_json();
        let keys: Vec<&str> = match &j {
            crate::util::json::Json::Obj(m) => m.keys().map(|k| k.as_str()).collect(),
            other => panic!("AdmissionStats::to_json must be an object, got {other:?}"),
        };
        assert_eq!(
            keys,
            vec!["admitted_rounds", "queue_full", "rejected", "throttled"],
            "AdmissionStats::to_json schema drifted — update docs + this snapshot together"
        );
        assert_eq!(j.get("admitted_rounds").unwrap().as_u64(), Some(8));
        assert_eq!(j.get("throttled").unwrap().as_u64(), Some(3));
    }

    #[test]
    fn merge_all_is_fieldwise_sum_over_shards() {
        let shards = [
            AdmissionStats { admitted_rounds: 4, throttled: 1, queue_full: 0, rejected: 2 },
            AdmissionStats { admitted_rounds: 0, throttled: 0, queue_full: 3, rejected: 0 },
            AdmissionStats { admitted_rounds: 7, throttled: 2, queue_full: 1, rejected: 1 },
        ];
        let total = AdmissionStats::merge_all(shards.iter());
        assert_eq!(total.admitted_rounds, 11);
        assert_eq!(total.throttled, 3);
        assert_eq!(total.queue_full, 4);
        assert_eq!(total.rejected, 3);
        assert_eq!(total.denials(), 10);
        // Empty input is the identity.
        assert_eq!(
            AdmissionStats::merge_all(std::iter::empty::<&AdmissionStats>()),
            AdmissionStats::default()
        );
    }

    #[test]
    fn merge_semantics() {
        let mut a = CommStats {
            uplink_elems_total: 10,
            uplink_elems_per_user: 5,
            downlink_elems: 2,
            elem_bits: 3,
            subrounds: 2,
            mults: 3,
            vote_bits: 1,
        };
        let b = CommStats {
            uplink_elems_total: 7,
            uplink_elems_per_user: 7,
            downlink_elems: 1,
            elem_bits: 4,
            subrounds: 3,
            mults: 2,
            vote_bits: 2,
        };
        a.merge(&b);
        assert_eq!(a.uplink_elems_total, 17);
        assert_eq!(a.uplink_elems_per_user, 7);
        assert_eq!(a.subrounds, 3);
        assert_eq!(a.mults, 5);
        assert_eq!(a.elem_bits, 4);
        assert_eq!(a.vote_bits, 2);
    }
}
