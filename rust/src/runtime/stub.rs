//! Dependency-free stand-in for the PJRT runtime (default build).
//!
//! Mirrors the public surface of [`super::pjrt`] exactly — same type
//! names, same constructor signatures — so every caller (examples,
//! benches, integration tests) compiles without the `xla` crate. Every
//! constructor returns [`RuntimeUnavailable`]; execution methods are
//! unreachable because no value of these types can be built.

use std::fmt;
use std::path::{Path, PathBuf};

use crate::fl::data::Dataset;
use crate::fl::model::Model;

/// Error returned by every stub constructor.
#[derive(Debug, Clone)]
pub struct RuntimeUnavailable(pub String);

impl fmt::Display for RuntimeUnavailable {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for RuntimeUnavailable {}

fn unavailable(what: &str) -> RuntimeUnavailable {
    RuntimeUnavailable(format!(
        "{what} requires the PJRT/XLA runtime: rebuild with \
         `--features xla-runtime` (and the vendored `xla` + `anyhow` \
         crates in rust/Cargo.toml)"
    ))
}

type Result<T> = std::result::Result<T, RuntimeUnavailable>;

/// Stub of the cached-executable PJRT runtime. Path helpers work (they
/// are pure); client construction fails.
pub struct Runtime {
    dir: PathBuf,
}

impl Runtime {
    pub fn cpu(_artifact_dir: impl AsRef<Path>) -> Result<Runtime> {
        Err(unavailable("Runtime::cpu"))
    }

    pub fn platform(&self) -> String {
        "unavailable (stub)".to_string()
    }

    pub fn artifact_path(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{name}.hlo.txt"))
    }

    pub fn has_artifact(&self, name: &str) -> bool {
        self.artifact_path(name).exists()
    }

    pub fn exec_f32(
        &mut self,
        _name: &str,
        _inputs: &[(&[f32], &[i64])],
    ) -> Result<Vec<Vec<f32>>> {
        unreachable!("stub Runtime cannot be constructed")
    }

    pub fn exec_i32(
        &mut self,
        _name: &str,
        _inputs: &[(&[i32], &[i64])],
    ) -> Result<Vec<Vec<i32>>> {
        unreachable!("stub Runtime cannot be constructed")
    }
}

/// Stub of the AOT-JAX-backed [`Model`]; construction always fails.
pub struct JaxModel {
    pub name: String,
    pub param_dim: usize,
    pub in_dim: usize,
    pub n_classes: usize,
    pub batch_size: usize,
}

impl JaxModel {
    pub fn new(
        _artifact_dir: impl AsRef<Path>,
        name: &str,
        _param_dim: usize,
        _in_dim: usize,
        _n_classes: usize,
        _batch_size: usize,
    ) -> Result<JaxModel> {
        Err(unavailable(&format!("JaxModel::new(\"{name}\")")))
    }
}

impl Model for JaxModel {
    fn dim(&self) -> usize {
        unreachable!("stub JaxModel cannot be constructed")
    }

    fn init_params(&self, _seed: u64) -> Vec<f32> {
        unreachable!("stub JaxModel cannot be constructed")
    }

    fn loss_grad(
        &self,
        _params: &[f32],
        _ds: &Dataset,
        _batch: &[usize],
    ) -> (f32, Vec<f32>) {
        unreachable!("stub JaxModel cannot be constructed")
    }

    fn accuracy(&self, _params: &[f32], _ds: &Dataset) -> f32 {
        unreachable!("stub JaxModel cannot be constructed")
    }

    fn name(&self) -> String {
        unreachable!("stub JaxModel cannot be constructed")
    }
}

/// Stub of the L1 Pallas majority-vote kernel loader.
pub struct MvPolyKernel {
    pub d: usize,
    pub max_coeffs: usize,
}

impl MvPolyKernel {
    pub fn new(
        _artifact_dir: impl AsRef<Path>,
        d: usize,
        _max_coeffs: usize,
    ) -> Result<MvPolyKernel> {
        Err(unavailable(&format!("MvPolyKernel::new(d = {d})")))
    }

    pub fn eval(
        &self,
        _fp: crate::field::Fp,
        _coeffs: &[u64],
        _xs: &[u64],
    ) -> Result<Vec<u64>> {
        unreachable!("stub MvPolyKernel cannot be constructed")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_error_with_guidance() {
        let err = Runtime::cpu("artifacts").err().expect("stub must fail");
        assert!(err.to_string().contains("xla-runtime"), "{err}");
        assert!(JaxModel::new("artifacts", "mnist_linear", 7850, 784, 10, 100).is_err());
        assert!(MvPolyKernel::new("artifacts", 1024, 32).is_err());
    }
}
