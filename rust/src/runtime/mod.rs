//! PJRT runtime facade: load AOT-compiled HLO artifacts and execute them
//! on the request path (Python never runs here).
//!
//! Two interchangeable backends sit behind one API:
//!
//! * [`pjrt`] (`--features xla-runtime`) — the real thing: compiles
//!   `artifacts/*.hlo.txt` through the PJRT C API (`xla` crate) and caches
//!   the executables. Requires the vendored `xla` + `anyhow` dependency
//!   closure, which the offline CI image does not ship.
//! * [`stub`] (default) — same types and signatures, but every
//!   construction fails with a descriptive error. The artifact-gated
//!   integration tests (`rust/tests/integration.rs`) check for
//!   `artifacts/manifest.json` before touching the runtime, so the
//!   default build stays green end to end; only a checkout that has both
//!   artifacts *and* a stub build would observe the error.

#[cfg(feature = "xla-runtime")]
mod pjrt;
#[cfg(feature = "xla-runtime")]
pub use pjrt::{JaxModel, MvPolyKernel, Runtime};

#[cfg(not(feature = "xla-runtime"))]
mod stub;
#[cfg(not(feature = "xla-runtime"))]
pub use stub::{JaxModel, MvPolyKernel, Runtime, RuntimeUnavailable};
