//! PJRT runtime: load AOT-compiled HLO artifacts and execute them on the
//! request path (Python never runs here).
//!
//! `make artifacts` runs `python/compile/aot.py` once, lowering the L2 JAX
//! models (which call the L1 Pallas kernels) to **HLO text** under
//! `artifacts/`. This module loads those files with
//! `HloModuleProto::from_text_file`, compiles them on the PJRT CPU client,
//! and caches the executables (one compile per artifact per process —
//! recompilation would dominate the round time otherwise).
//!
//! HLO *text* is the interchange format: jax ≥ 0.5 serializes protos with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::fl::data::Dataset;
use crate::fl::model::Model;

/// Cached-executable PJRT runtime.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// CPU PJRT client rooted at an artifact directory
    /// (default `artifacts/`).
    pub fn cpu(artifact_dir: impl AsRef<Path>) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime {
            client,
            dir: artifact_dir.as_ref().to_path_buf(),
            cache: HashMap::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Path of a named artifact (`<dir>/<name>.hlo.txt`).
    pub fn artifact_path(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{name}.hlo.txt"))
    }

    /// True if the artifact file exists (used to skip runtime-dependent
    /// paths when `make artifacts` has not run).
    pub fn has_artifact(&self, name: &str) -> bool {
        self.artifact_path(name).exists()
    }

    /// Load + compile (cached) an artifact.
    pub fn load(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(name) {
            let path = self.artifact_path(name);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
            self.cache.insert(name.to_string(), exe);
        }
        Ok(&self.cache[name])
    }

    /// Execute an artifact on f32 tensors, returning the flattened f32
    /// outputs of the result tuple (artifacts are lowered with
    /// `return_tuple=True`).
    pub fn exec_f32(
        &mut self,
        name: &str,
        inputs: &[(&[f32], &[i64])],
    ) -> Result<Vec<Vec<f32>>> {
        let lits = inputs
            .iter()
            .map(|(data, dims)| {
                xla::Literal::vec1(data)
                    .reshape(dims)
                    .map_err(|e| anyhow!("reshape {dims:?}: {e:?}"))
            })
            .collect::<Result<Vec<_>>>()?;
        self.exec_literals(name, &lits)?
            .into_iter()
            .map(|l| l.to_vec::<f32>().map_err(|e| anyhow!("to_vec f32: {e:?}")))
            .collect()
    }

    /// Execute on i32 tensors (the majority-vote kernel path).
    pub fn exec_i32(
        &mut self,
        name: &str,
        inputs: &[(&[i32], &[i64])],
    ) -> Result<Vec<Vec<i32>>> {
        let lits = inputs
            .iter()
            .map(|(data, dims)| {
                xla::Literal::vec1(data)
                    .reshape(dims)
                    .map_err(|e| anyhow!("reshape {dims:?}: {e:?}"))
            })
            .collect::<Result<Vec<_>>>()?;
        self.exec_literals(name, &lits)?
            .into_iter()
            .map(|l| l.to_vec::<i32>().map_err(|e| anyhow!("to_vec i32: {e:?}")))
            .collect()
    }

    /// Execute with raw literals; unpack the output tuple.
    pub fn exec_literals(
        &mut self,
        name: &str,
        inputs: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let exe = self.load(name)?;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch output: {e:?}"))?;
        lit.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))
    }
}

// ---------------------------------------------------------------- JaxModel

/// A [`Model`] backed by AOT-compiled JAX artifacts:
///
/// * `<name>_grad` : `(params f32[d], x f32[b,in], y f32[b,classes])
///   → (loss f32[], grad f32[d])`
/// * `<name>_logits` : `(params f32[d], x f32[b,in]) → (logits f32[b,classes])`
///
/// The batch size is baked into the artifact; `loss_grad` requires
/// `batch.len() == batch_size` (the trainer samples with replacement so
/// batches are always full).
pub struct JaxModel {
    rt: std::cell::RefCell<Runtime>,
    pub name: String,
    pub param_dim: usize,
    pub in_dim: usize,
    pub n_classes: usize,
    pub batch_size: usize,
    init_seed_scale: f32,
}

impl JaxModel {
    /// `name` is the artifact family, e.g. `mnist_mlp`.
    pub fn new(
        artifact_dir: impl AsRef<Path>,
        name: &str,
        param_dim: usize,
        in_dim: usize,
        n_classes: usize,
        batch_size: usize,
    ) -> Result<JaxModel> {
        let mut rt = Runtime::cpu(artifact_dir)?;
        for suffix in ["grad", "logits"] {
            let art = format!("{name}_{suffix}");
            if !rt.has_artifact(&art) {
                return Err(anyhow!(
                    "missing artifact {}; run `make artifacts`",
                    rt.artifact_path(&art).display()
                ));
            }
            rt.load(&art).context(art.clone())?;
        }
        Ok(JaxModel {
            rt: std::cell::RefCell::new(rt),
            name: name.to_string(),
            param_dim,
            in_dim,
            n_classes,
            batch_size,
            init_seed_scale: (2.0 / in_dim as f32).sqrt(),
        })
    }

    fn batch_tensors(&self, ds: &Dataset, batch: &[usize]) -> (Vec<f32>, Vec<f32>) {
        let mut xs = Vec::with_capacity(batch.len() * self.in_dim);
        let mut ys = vec![0.0f32; batch.len() * self.n_classes];
        for (row, &i) in batch.iter().enumerate() {
            xs.extend_from_slice(ds.image(i));
            ys[row * self.n_classes + ds.label(i) as usize] = 1.0;
        }
        (xs, ys)
    }
}

impl Model for JaxModel {
    fn dim(&self) -> usize {
        self.param_dim
    }

    fn init_params(&self, seed: u64) -> Vec<f32> {
        // Same family as the rust models: scaled Gaussian, deterministic.
        let mut rng = crate::util::rng::Xoshiro256pp::seed_from_u64(seed);
        use crate::util::rng::Rng;
        (0..self.param_dim)
            .map(|_| self.init_seed_scale * rng.gen_gaussian() as f32)
            .collect()
    }

    fn loss_grad(&self, params: &[f32], ds: &Dataset, batch: &[usize]) -> (f32, Vec<f32>) {
        assert_eq!(
            batch.len(),
            self.batch_size,
            "JaxModel batch size is baked into the artifact"
        );
        let (xs, ys) = self.batch_tensors(ds, batch);
        let out = self
            .rt
            .borrow_mut()
            .exec_f32(
                &format!("{}_grad", self.name),
                &[
                    (params, &[self.param_dim as i64]),
                    (&xs, &[self.batch_size as i64, self.in_dim as i64]),
                    (&ys, &[self.batch_size as i64, self.n_classes as i64]),
                ],
            )
            .expect("grad artifact execution");
        let loss = out[0][0];
        let grad = out[1].clone();
        assert_eq!(grad.len(), self.param_dim);
        (loss, grad)
    }

    fn accuracy(&self, params: &[f32], ds: &Dataset) -> f32 {
        // Run the logits artifact in fixed-size chunks (pad the tail by
        // repeating sample 0, excluded from the count).
        let b = self.batch_size;
        let mut correct = 0usize;
        let mut i = 0usize;
        let mut rt = self.rt.borrow_mut();
        while i < ds.len() {
            let take = (ds.len() - i).min(b);
            let batch: Vec<usize> =
                (0..b).map(|k| if k < take { i + k } else { 0 }).collect();
            let mut xs = Vec::with_capacity(b * self.in_dim);
            for &idx in &batch {
                xs.extend_from_slice(ds.image(idx));
            }
            let out = rt
                .exec_f32(
                    &format!("{}_logits", self.name),
                    &[
                        (params, &[self.param_dim as i64]),
                        (&xs, &[b as i64, self.in_dim as i64]),
                    ],
                )
                .expect("logits artifact execution");
            let logits = &out[0];
            for k in 0..take {
                let row = &logits[k * self.n_classes..(k + 1) * self.n_classes];
                let pred = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0;
                correct += usize::from(pred == ds.label(i + k) as usize);
            }
            i += take;
        }
        correct as f32 / ds.len() as f32
    }

    fn name(&self) -> String {
        format!("jax_{}", self.name)
    }
}

/// Server-side majority-vote evaluation via the L1 Pallas kernel artifact
/// `mv_poly_d<d>`: inputs `(x i32[d], coeffs i32[max_coeffs+1])` (the last
/// coeff slot carries `p`), output `F(x) i32[d]` — must agree with
/// [`crate::poly::Poly::eval_vec`] (cross-layer consistency test in
/// `rust/tests/integration.rs`).
pub struct MvPolyKernel {
    rt: std::cell::RefCell<Runtime>,
    pub d: usize,
    pub max_coeffs: usize,
    artifact: String,
}

impl MvPolyKernel {
    pub fn new(
        artifact_dir: impl AsRef<Path>,
        d: usize,
        max_coeffs: usize,
    ) -> Result<MvPolyKernel> {
        let mut rt = Runtime::cpu(artifact_dir)?;
        let artifact = format!("mv_poly_d{d}");
        if !rt.has_artifact(&artifact) {
            return Err(anyhow!(
                "missing artifact {}; run `make artifacts`",
                rt.artifact_path(&artifact).display()
            ));
        }
        rt.load(&artifact)?;
        Ok(MvPolyKernel { rt: std::cell::RefCell::new(rt), d, max_coeffs, artifact })
    }

    /// Evaluate `F` (canonical coefficients over `F_p`) on canonical
    /// inputs `xs`, via the compiled Pallas kernel.
    pub fn eval(&self, fp: crate::field::Fp, coeffs: &[u64], xs: &[u64]) -> Result<Vec<u64>> {
        assert!(
            coeffs.len() <= self.max_coeffs,
            "polynomial too large for kernel ({} > {})",
            coeffs.len(),
            self.max_coeffs
        );
        assert_eq!(xs.len(), self.d);
        let mut c = vec![0i32; self.max_coeffs + 1];
        for (i, &v) in coeffs.iter().enumerate() {
            c[i] = v as i32;
        }
        // final slot carries p (keeps the artifact signature at 2 inputs)
        c[self.max_coeffs] = fp.modulus() as i32;
        let x: Vec<i32> = xs.iter().map(|&v| v as i32).collect();
        let out = self.rt.borrow_mut().exec_i32(
            &self.artifact,
            &[
                (&x, &[self.d as i64]),
                (&c, &[(self.max_coeffs + 1) as i64]),
            ],
        )?;
        Ok(out[0]
            .iter()
            .map(|&v| v.rem_euclid(fp.modulus() as i32) as u64)
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Runtime tests that need artifacts live in rust/tests/integration.rs
    // (skipped gracefully when `make artifacts` hasn't run). Here: pure
    // path logic only.
    #[test]
    fn artifact_paths() {
        if let Ok(rt) = Runtime::cpu("artifacts") {
            assert!(rt.artifact_path("foo").ends_with("artifacts/foo.hlo.txt"));
            assert!(!rt.has_artifact("definitely_not_there"));
        }
    }
}
