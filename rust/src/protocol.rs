//! The Hi-SAFE protocol engine — the paper's Layer-3 coordination
//! contribution (Algorithms 2 & 3, Section III-C/D/E).
//!
//! Two drivers over the [`crate::mpc`] state machines:
//!
//! * [`run_sync`] — in-process sequential execution. Used by the FL
//!   trainer's hot path, the benches, and all correctness tests.
//! * [`run_threaded`] — a real message-passing deployment: one OS thread
//!   per user plus a server thread, communicating over `std::sync::mpsc`
//!   channels (tokio is unavailable offline; the topology is identical to
//!   an async runtime's). Produces *bit-identical* results to `run_sync`
//!   under the same seed — asserted by tests — so the fast path is provably
//!   faithful to the distributed one.
//!
//! Hierarchy (Algorithm 3): users are partitioned into `ℓ` subgroups of
//! `n₁ = n/ℓ`; each subgroup runs Algorithm 1 over `F_{p₁}`
//! (`p₁ = next_prime(n₁)`) and reveals only its subgroup vote `s_j`; the
//! server then computes the global vote `sign(Σ s_j)` in the clear —
//! exactly the leakage profile Theorem 2 permits (`{s_j}` and `s`).

use std::fmt;
use std::sync::mpsc;
use std::sync::Arc;

use crate::beaver::Dealer;
use crate::field::{next_prime, Fp};
use crate::metrics::CommStats;
use crate::mpc::{
    plain_group_vote, plain_quant_group_vote, secure_group_vote_q, BroadcastMsg, EvalPlan,
    Party, Server, Transcript, UplinkMsg,
};
use crate::poly::{MvPolynomial, TiePolicy};
use crate::shamir::{reconstruct, share};
use crate::util::rng::ChaCha20Rng;

/// Full protocol configuration (Section III-E's A-1/B-1/A-2/B-2 matrix).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HiSafeConfig {
    /// Number of participating users this round (the paper's `n = C·N`).
    pub n: usize,
    /// Number of subgroups `ℓ` (1 = flat, Algorithm 2).
    pub ell: usize,
    /// Intra-subgroup tie policy (Case A = OneBit, Case B = TwoBit).
    pub intra: TiePolicy,
    /// Inter-subgroup (global) tie policy (Case 1 = OneBit, Case 2 = TwoBit).
    pub inter: TiePolicy,
    /// Use the sparse power schedule (ablation; paper = false).
    pub sparse: bool,
    /// Quantization precision `q ∈ {2, 4, 8, 16}`: users vote with the
    /// `q` midrise levels `L_q = {−(q−1), …, q−1}` ([`crate::quant`]).
    /// `2` is the paper's 1-bit sign vote — byte-identical to the
    /// pre-quantization code path.
    pub precision: u8,
}

impl HiSafeConfig {
    /// Flat Hi-SAFE (Algorithm 2): one group of all `n` users.
    pub fn flat(n: usize, policy: TiePolicy) -> HiSafeConfig {
        HiSafeConfig { n, ell: 1, intra: policy, inter: policy, sparse: false, precision: 2 }
    }

    /// Hierarchical Hi-SAFE (Algorithm 3) with the paper's preferred
    /// 1-bit-downlink configurations: `A-1` (intra OneBit) or `B-1`
    /// (intra TwoBit); global policy is OneBit in both.
    pub fn hierarchical(n: usize, ell: usize, intra: TiePolicy) -> HiSafeConfig {
        HiSafeConfig { n, ell, intra, inter: TiePolicy::OneBit, sparse: false, precision: 2 }
    }

    /// The same configuration at quantization precision `q` (panics
    /// unless `q ∈ {2, 4, 8, 16}`).
    pub fn with_precision(mut self, q: u8) -> HiSafeConfig {
        crate::quant::validate_precision(q);
        self.precision = q;
        self
    }

    /// Subgroup size `n₁ = n/ℓ`. Panics unless `ℓ | n` (the paper assumes
    /// equal-size subgroups).
    pub fn n1(&self) -> usize {
        assert!(self.ell >= 1 && self.n % self.ell == 0,
            "ℓ = {} must divide n = {}", self.ell, self.n);
        self.n / self.ell
    }

    /// Section III-E combined-configuration label (A-1, B-1, A-2, B-2).
    pub fn label(&self) -> String {
        let a = match self.intra {
            TiePolicy::OneBit => "A",
            TiePolicy::TwoBit => "B",
        };
        let b = match self.inter {
            TiePolicy::OneBit => "1",
            TiePolicy::TwoBit => "2",
        };
        if self.precision == 2 {
            format!("{a}-{b}")
        } else {
            format!("{a}-{b}-q{}", self.precision)
        }
    }

    /// Is this configuration compatible with SIGNSGD-MV's 1-bit global
    /// update (the paper's Remark in Section III-E)?
    pub fn signsgd_compatible(&self) -> bool {
        self.inter == TiePolicy::OneBit
    }
}

/// Outcome of one Hi-SAFE aggregation round.
#[derive(Debug)]
pub struct RoundOutcome {
    /// Global vote per coordinate (`{−1,+1}`, or 0 under inter TwoBit).
    pub global_vote: Vec<i8>,
    /// Subgroup votes `s_j` (the Theorem-2 leakage).
    pub subgroup_votes: Vec<Vec<i8>>,
    /// Measured communication (openings, subrounds, mults).
    pub stats: CommStats,
    /// Per-subgroup server transcripts (for the security tests).
    pub transcripts: Vec<Transcript>,
}

/// Plain (non-private) majority vote over all users — the SIGNSGD-MV
/// baseline (same function as the flat plaintext reference).
pub use crate::mpc::plain_group_vote as plain_group_vote_all;

/// Per-subgroup dealer seed: the *single* derivation shared by
/// [`run_sync`], [`run_threaded`], and both engines in [`crate::engine`],
/// so every execution path consumes identical per-group triple streams.
/// The golden-ratio stride keeps group streams independent; centralizing
/// it here is what lets the pipelined engine's background dealing stay
/// share-for-share aligned with this module's synchronous paths.
pub fn group_dealer_seed(seed: u64, g: usize) -> u64 {
    seed ^ (g as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15)
}

/// Partition user indices into `ℓ` contiguous subgroups of `n₁`.
pub fn partition(n: usize, ell: usize) -> Vec<Vec<usize>> {
    assert!(ell >= 1 && n % ell == 0, "ℓ = {ell} must divide n = {n}");
    let n1 = n / ell;
    (0..ell).map(|g| (g * n1..(g + 1) * n1).collect()).collect()
}

/// Combine subgroup votes into the global vote (Eq. 8):
/// `sign(Σ_j s_j)` under the inter-subgroup tie policy.
pub fn inter_group_vote(subgroup_votes: &[Vec<i8>], inter: TiePolicy) -> Vec<i8> {
    let d = subgroup_votes[0].len();
    (0..d)
        .map(|j| {
            let sum: i64 = subgroup_votes.iter().map(|s| s[j] as i64).sum();
            inter.sign(sum) as i8
        })
        .collect()
}

/// q-level generalization of [`inter_group_vote`]: the quantized
/// aggregate of the `ℓ` subgroup votes ([`crate::quant::quant_aggregate`]
/// over `n = ℓ` inputs). `q = 2` takes the legacy sign path exactly.
pub fn inter_group_vote_q(subgroup_votes: &[Vec<i8>], q: u8, inter: TiePolicy) -> Vec<i8> {
    if q == 2 {
        return inter_group_vote(subgroup_votes, inter);
    }
    let ell = subgroup_votes.len();
    let d = subgroup_votes[0].len();
    (0..d)
        .map(|j| {
            let sum: i64 = subgroup_votes.iter().map(|s| s[j] as i64).sum();
            crate::quant::quant_aggregate(sum, ell, q, inter) as i8
        })
        .collect()
}

/// Run one Hi-SAFE round in-process (the trainer hot path).
///
/// `signs[i]` is user `i`'s ±1 sign-gradient vector.
pub fn run_sync(signs: &[Vec<i8>], cfg: HiSafeConfig, seed: u64) -> RoundOutcome {
    assert_eq!(signs.len(), cfg.n, "need exactly n sign vectors");
    let groups = partition(cfg.n, cfg.ell);
    // §Perf: subgroups are independent — run them on parallel threads
    // (deterministic: each group's dealer seed depends only on (seed, g)).
    // Only worth it at model-sized d AND with >1 hardware thread (the
    // reference environment is single-core; the code path is exercised by
    // tests either way via run_threaded).
    let d = signs[0].len();
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let parallel = cfg.ell > 1 && d >= 4096 && cores > 1;
    let run_group = |g: usize, members: &[usize]| {
        let group_signs: Vec<Vec<i8>> =
            members.iter().map(|&i| signs[i].clone()).collect();
        secure_group_vote_q(
            &group_signs,
            cfg.precision,
            cfg.intra,
            cfg.sparse,
            group_dealer_seed(seed, g),
        )
    };
    let outcomes: Vec<crate::mpc::GroupVoteOutcome> = if parallel {
        std::thread::scope(|scope| {
            // share the closure by reference: a `move` closure would try to
            // take `run_group` by value once per spawned thread
            let run_group = &run_group;
            let handles: Vec<_> = groups
                .iter()
                .enumerate()
                .map(|(g, members)| scope.spawn(move || run_group(g, members)))
                .collect();
            handles.into_iter().map(|h| h.join().expect("group thread")).collect()
        })
    } else {
        groups.iter().enumerate().map(|(g, m)| run_group(g, m)).collect()
    };
    let mut subgroup_votes = Vec::with_capacity(cfg.ell);
    let mut transcripts = Vec::with_capacity(cfg.ell);
    let mut stats = CommStats::default();
    for out in outcomes {
        stats.merge(&out.stats);
        subgroup_votes.push(out.votes);
        transcripts.push(out.transcript);
    }
    let global_vote = inter_group_vote_q(&subgroup_votes, cfg.precision, cfg.inter);
    stats.vote_bits = crate::quant::downlink_bits(cfg.precision, cfg.inter);
    RoundOutcome { global_vote, subgroup_votes, stats, transcripts }
}

/// Plaintext reference for the full hierarchy (Eq. 8 without crypto):
/// `sign(Σ_j sign(Σ_{i∈G_j} x_{i,j}))`.
pub fn plain_hierarchical_vote(
    signs: &[Vec<i8>],
    cfg: HiSafeConfig,
) -> Vec<i8> {
    let groups = partition(cfg.n, cfg.ell);
    let subgroup_votes: Vec<Vec<i8>> = groups
        .iter()
        .map(|members| {
            let group_signs: Vec<Vec<i8>> =
                members.iter().map(|&i| signs[i].clone()).collect();
            plain_group_vote(&group_signs, cfg.intra)
        })
        .collect();
    inter_group_vote(&subgroup_votes, cfg.inter)
}

/// Plaintext reference for the q-level hierarchy — what every secure
/// path must reproduce bit-for-bit at `cfg.precision`: per subgroup the
/// quantized aggregate of its members' levels, then the quantized
/// aggregate of the subgroup votes. Equals [`plain_hierarchical_vote`]
/// when `cfg.precision == 2` (pinned by the tests below).
pub fn plain_quant_aggregate(signs: &[Vec<i8>], cfg: HiSafeConfig) -> Vec<i8> {
    let groups = partition(cfg.n, cfg.ell);
    let subgroup_votes: Vec<Vec<i8>> = groups
        .iter()
        .map(|members| {
            let group_signs: Vec<Vec<i8>> =
                members.iter().map(|&i| signs[i].clone()).collect();
            plain_quant_group_vote(&group_signs, cfg.precision, cfg.intra)
        })
        .collect();
    inter_group_vote_q(&subgroup_votes, cfg.precision, cfg.inter)
}

/// Survivor-set variant of [`plain_quant_aggregate`]: each subgroup
/// aggregates over its *present* members only — the churn-path q-level
/// reference (mirror of [`plain_hierarchical_vote_present`]).
pub fn plain_quant_aggregate_present(
    signs: &[Vec<i8>],
    present: &ParticipantSet,
    cfg: HiSafeConfig,
) -> Vec<i8> {
    let groups = partition(cfg.n, cfg.ell);
    let subgroup_votes: Vec<Vec<i8>> = groups
        .iter()
        .map(|members| {
            let group_signs: Vec<Vec<i8>> = present
                .group_survivors(members)
                .iter()
                .map(|&i| signs[i].clone())
                .collect();
            assert!(!group_signs.is_empty(), "a group lost every member");
            plain_quant_group_vote(&group_signs, cfg.precision, cfg.intra)
        })
        .collect();
    inter_group_vote_q(&subgroup_votes, cfg.precision, cfg.inter)
}

// ------------------------------------------------------- participant sets

/// The explicit per-round participant set: which of the `n` *registered*
/// users actually answered this round. Every round path (the references
/// here, both engines, the scheduler sessions, and the wire protocol)
/// threads one of these instead of assuming "all n present".
///
/// Sign matrices keep their full `n`-row shape everywhere — absent rows
/// are simply ignored (conventionally zeros) — so shape validation and
/// the wire schema are independent of churn.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParticipantSet {
    mask: Vec<bool>,
}

impl ParticipantSet {
    /// Everyone answered — the pre-churn implicit assumption, explicit.
    pub fn all(n: usize) -> ParticipantSet {
        ParticipantSet { mask: vec![true; n] }
    }

    /// A set from an explicit per-user presence mask (`mask[i]` ⇔ user
    /// `i` answered). This is also the wire form (`'1'`/`'0'` string).
    pub fn from_mask(mask: Vec<bool>) -> ParticipantSet {
        ParticipantSet { mask }
    }

    /// The number of registered users the mask covers (the config's `n`).
    pub fn n(&self) -> usize {
        self.mask.len()
    }

    /// Did user `i` answer this round?
    pub fn is_present(&self, user: usize) -> bool {
        self.mask[user]
    }

    /// Users that answered, over the whole federation.
    pub fn survivors(&self) -> usize {
        self.mask.iter().filter(|&&m| m).count()
    }

    /// `true` iff nobody dropped — the fast path back to the zero-churn
    /// pipeline (bit-identical to [`run_sync`], pooled triples and all).
    pub fn is_all_present(&self) -> bool {
        self.mask.iter().all(|&m| m)
    }

    /// The raw presence mask (wire encoding, trainer bookkeeping).
    pub fn mask(&self) -> &[bool] {
        &self.mask
    }

    /// The members of one subgroup that answered, in member order
    /// (absolute user ids).
    pub fn group_survivors(&self, members: &[usize]) -> Vec<usize> {
        members.iter().copied().filter(|&m| self.mask[m]).collect()
    }

    /// A stable 64-bit key of this group's presence *pattern* (FNV-1a
    /// over the per-member bits). Two rounds with the same surviving
    /// cohort share a key — the engines' reusable-secret fast path caches
    /// per-cohort setup under `(group, cohort_key)`, and
    /// [`churn_dealer_seed`] folds the key in so distinct cohorts draw
    /// from distinct triple streams.
    pub fn cohort_key(&self, members: &[usize]) -> u64 {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for &m in members {
            h ^= if self.mask[m] { 0x9e } else { 0x31 };
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        h
    }
}

/// Typed churn failure: a subgroup lost so many members this round that
/// threshold reconstruction is impossible. Never a panic — every layer
/// (reference, engines, scheduler, wire) surfaces this as a value.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ChurnError {
    /// Group `group` kept only `survivors` members, but reconstruction
    /// needs `required` = t+1 (a within-group honest majority).
    BelowThreshold {
        /// The subgroup index that fell below threshold.
        group: usize,
        /// Members of that subgroup that answered this round.
        survivors: usize,
        /// The minimum survivor count (`group_threshold(n₁) + 1`).
        required: usize,
    },
}

impl fmt::Display for ChurnError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ChurnError::BelowThreshold { group, survivors, required } => write!(
                f,
                "subgroup {group} below reconstruction threshold: \
                 {survivors} survivors, need {required} (t-of-n needs t+1)"
            ),
        }
    }
}

impl std::error::Error for ChurnError {}

/// The per-group Shamir threshold `t = ⌊(n₁ − 1)/2⌋` — the same honest-
/// majority bound `shamir.rs` uses for its DN07 backend. A round survives
/// as long as every subgroup keeps `t + 1` members; Hi-SAFE's subgrouping
/// bounds reconstruction to *group* size, so a fleet-wide dropout storm
/// only aborts if it concentrates ≥ `n₁ − t` losses inside one subgroup.
pub fn group_threshold(n1: usize) -> usize {
    n1.saturating_sub(1) / 2
}

/// Validate one round's participant set against every subgroup's
/// threshold. `Err` identifies the *first* violating group (group order
/// is deterministic, so every path reports the same abort).
pub fn check_thresholds(
    cfg: HiSafeConfig,
    present: &ParticipantSet,
) -> Result<(), ChurnError> {
    assert_eq!(present.n(), cfg.n, "participant mask must cover all n users");
    let n1 = cfg.n1();
    let required = group_threshold(n1) + 1;
    for (g, members) in partition(cfg.n, cfg.ell).iter().enumerate() {
        let survivors = members.iter().filter(|&&m| present.is_present(m)).count();
        if survivors < required {
            return Err(ChurnError::BelowThreshold { group: g, survivors, required });
        }
    }
    Ok(())
}

/// Dealer seed for a *churned* cohort of group `g`: the base
/// [`group_dealer_seed`] derivation XOR-folded with the cohort key (which
/// the reference derives from the Shamir-reconstructed recovery secrets —
/// see [`recover_cohort_key`]). Distinct survivor patterns therefore draw
/// from distinct, deterministic triple streams, while the full-cohort
/// stream stays exactly `group_dealer_seed(seed, g)`.
pub fn churn_dealer_seed(seed: u64, g: usize, cohort_key: u64) -> u64 {
    group_dealer_seed(seed, g) ^ cohort_key.wrapping_mul(0xbf58_476d_1ce4_e5b9)
}

/// Deterministic per-member recovery secret (splitmix64 finalizer over
/// `(seed, group, local index)`): the stand-in for the per-user key
/// material a deployment would have escrowed at setup. Pure function, so
/// every path derives identical secrets without coordination.
fn recovery_secret(seed: u64, g: usize, local: usize) -> u64 {
    let mut z = seed
        ^ (g as u64).rotate_left(32)
        ^ (local as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// The t-of-n recovery step for one churned subgroup, built directly on
/// `shamir.rs`: each dropped member's recovery secret was (notionally, at
/// setup) Shamir-shared degree-`t` among the group's `n₁` members; the
/// `t + 1` lowest-indexed survivors Lagrange-reconstruct it, and the
/// reconstructed secrets fold into the cohort key that seeds the
/// survivor cohort's dealer. Panics if called below threshold — run
/// [`check_thresholds`] first (every round path does).
///
/// The fold is what ties the *transcript* of a churned round to a real
/// reconstruction: votes are triple-independent (Beaver masks cancel),
/// but the dealer stream — and hence the openings the server observes —
/// only reproduces across paths because each path reconstructs the same
/// secrets from its survivor set.
pub fn recover_cohort_key(
    seed: u64,
    g: usize,
    members: &[usize],
    present: &ParticipantSet,
) -> u64 {
    let n1 = members.len();
    let t = group_threshold(n1);
    let fp = Fp::new(next_prime(n1 as u64 + 1));
    let pts: Vec<usize> = members
        .iter()
        .enumerate()
        .filter(|&(_, &m)| present.is_present(m))
        .map(|(local, _)| local + 1)
        .take(t + 1)
        .collect();
    assert_eq!(pts.len(), t + 1, "recovery below threshold — check_thresholds first");
    let mut key = present.cohort_key(members);
    for (local, &m) in members.iter().enumerate() {
        if present.is_present(m) {
            continue;
        }
        let secret = fp.reduce(recovery_secret(seed, g, local));
        let mut rng = ChaCha20Rng::seed_from_u64(recovery_secret(seed ^ 0x5151, g, local));
        let shares = share(fp, secret, n1, t, &mut rng);
        let survivor_shares: Vec<u64> = pts.iter().map(|&x| shares[x - 1]).collect();
        let recovered = reconstruct(fp, &pts, &survivor_shares);
        debug_assert_eq!(recovered, secret, "Lagrange recovery must be exact");
        key = (key ^ recovered).wrapping_mul(0x0000_0100_0000_01b3);
    }
    key
}

/// Run one Hi-SAFE round over an explicit participant set — the
/// reference every churn-tolerant path is pinned against.
///
/// `signs` keeps its full `n`-row shape; rows of absent users are
/// ignored. Groups with every member present run the exact [`run_sync`]
/// pipeline (same [`group_dealer_seed`] stream — a zero-churn call is
/// bit-identical to `run_sync`, transcripts included). Churned groups
/// first run the t-of-n recovery step ([`recover_cohort_key`]) and then
/// evaluate the secure vote over the `k` survivors with a `k`-party plan
/// seeded by [`churn_dealer_seed`]. A group below `t + 1` survivors
/// aborts the whole round with a typed [`ChurnError`] before any group
/// evaluates.
pub fn run_sync_with_dropouts(
    signs: &[Vec<i8>],
    present: &ParticipantSet,
    cfg: HiSafeConfig,
    seed: u64,
) -> Result<RoundOutcome, ChurnError> {
    assert_eq!(signs.len(), cfg.n, "need n sign rows (absent rows are ignored)");
    check_thresholds(cfg, present)?;
    let groups = partition(cfg.n, cfg.ell);
    let mut subgroup_votes = Vec::with_capacity(cfg.ell);
    let mut transcripts = Vec::with_capacity(cfg.ell);
    let mut stats = CommStats::default();
    for (g, members) in groups.iter().enumerate() {
        let survivors = present.group_survivors(members);
        let out = if survivors.len() == members.len() {
            let group_signs: Vec<Vec<i8>> =
                members.iter().map(|&i| signs[i].clone()).collect();
            secure_group_vote_q(
                &group_signs,
                cfg.precision,
                cfg.intra,
                cfg.sparse,
                group_dealer_seed(seed, g),
            )
        } else {
            let key = recover_cohort_key(seed, g, members, present);
            let survivor_signs: Vec<Vec<i8>> =
                survivors.iter().map(|&i| signs[i].clone()).collect();
            secure_group_vote_q(
                &survivor_signs,
                cfg.precision,
                cfg.intra,
                cfg.sparse,
                churn_dealer_seed(seed, g, key),
            )
        };
        stats.merge(&out.stats);
        subgroup_votes.push(out.votes);
        transcripts.push(out.transcript);
    }
    let global_vote = inter_group_vote_q(&subgroup_votes, cfg.precision, cfg.inter);
    stats.vote_bits = crate::quant::downlink_bits(cfg.precision, cfg.inter);
    Ok(RoundOutcome { global_vote, subgroup_votes, stats, transcripts })
}

/// Plaintext reference for the churned hierarchy: Eq. 8 computed over
/// each subgroup's *survivors* only. Panics on a below-threshold set —
/// mirror of [`run_sync_with_dropouts`]'s precondition (audits call this
/// only for rounds that completed).
pub fn plain_hierarchical_vote_present(
    signs: &[Vec<i8>],
    present: &ParticipantSet,
    cfg: HiSafeConfig,
) -> Vec<i8> {
    let groups = partition(cfg.n, cfg.ell);
    let subgroup_votes: Vec<Vec<i8>> = groups
        .iter()
        .map(|members| {
            let group_signs: Vec<Vec<i8>> = present
                .group_survivors(members)
                .iter()
                .map(|&i| signs[i].clone())
                .collect();
            assert!(!group_signs.is_empty(), "a group lost every member");
            plain_group_vote(&group_signs, cfg.intra)
        })
        .collect();
    inter_group_vote(&subgroup_votes, cfg.inter)
}

// ---------------------------------------------------------------- threaded

/// Messages users send the coordinator.
enum ToServer {
    Uplink { group: usize, msg: UplinkMsg },
    FinalShare { group: usize, party: usize, share: Vec<u64> },
}

/// Messages the coordinator sends users.
enum ToUser {
    Broadcast(Arc<BroadcastMsg>),
    GlobalVote(Arc<Vec<i8>>),
}

/// Run one Hi-SAFE round as a real message-passing system: one thread per
/// user, one server thread, mpsc channels. Deterministic given `seed`
/// (identical outcome to [`run_sync`]).
pub fn run_threaded(signs: &[Vec<i8>], cfg: HiSafeConfig, seed: u64) -> RoundOutcome {
    assert_eq!(signs.len(), cfg.n);
    let d = signs[0].len();
    let groups = partition(cfg.n, cfg.ell);
    let n1 = cfg.n1();

    // Per-group plan + offline triples (same derivation as run_sync so the
    // outcomes match bit-for-bit).
    let mv = MvPolynomial::build_fermat_q(n1, cfg.precision, cfg.intra);
    let plan = Arc::new(EvalPlan::new(&mv, d, cfg.sparse));
    let fp = plan.fp;
    let depth = plan.schedule.depth();

    let (to_server_tx, to_server_rx) = mpsc::channel::<ToServer>();
    let mut user_handles = Vec::new();
    let mut servers: Vec<Server> = Vec::new();

    for (g, members) in groups.iter().enumerate() {
        let mut dealer = Dealer::new(fp, group_dealer_seed(seed, g));
        let mut round_triples = dealer.gen_round(d, n1, plan.triples_needed());
        servers.push(Server::new(Arc::clone(&plan)));
        for (local, &uid) in members.iter().enumerate() {
            let (to_user_tx, to_user_rx) = mpsc::channel::<ToUser>();
            let triples = std::mem::take(&mut round_triples[local]);
            let input = fp.encode_signs(&signs[uid]);
            let plan_c = Arc::clone(&plan);
            let tx = to_server_tx.clone();
            let handle = std::thread::spawn(move || {
                let mut party = Party::new(plan_c.clone(), local, input, triples);
                for dep in 0..depth {
                    tx.send(ToServer::Uplink { group: g, msg: party.uplink(dep) })
                        .expect("server alive");
                    match to_user_rx.recv().expect("broadcast") {
                        ToUser::Broadcast(b) => party.absorb(&b),
                        ToUser::GlobalVote(_) => unreachable!("vote before finals"),
                    }
                }
                tx.send(ToServer::FinalShare {
                    group: g,
                    party: local,
                    share: party.final_share(),
                })
                .expect("server alive");
                match to_user_rx.recv().expect("vote") {
                    ToUser::GlobalVote(v) => (*v).clone(),
                    ToUser::Broadcast(_) => unreachable!("broadcast after finals"),
                }
            });
            user_handles.push((g, to_user_tx, handle));
        }
    }
    drop(to_server_tx);

    // Server event loop: per depth, collect one uplink per user per group,
    // aggregate per group, broadcast to that group's members.
    for dep in 0..depth {
        let mut pending: Vec<Vec<UplinkMsg>> = vec![Vec::new(); cfg.ell];
        let mut received = 0usize;
        while received < cfg.n {
            match to_server_rx.recv().expect("users alive") {
                ToServer::Uplink { group, msg } => {
                    assert_eq!(msg.depth, dep, "subround desync");
                    pending[group].push(msg);
                    received += 1;
                }
                ToServer::FinalShare { .. } => panic!("final share mid-round"),
            }
        }
        for (g, msgs) in pending.iter_mut().enumerate() {
            msgs.sort_by_key(|m| m.party);
            let bcast = Arc::new(servers[g].aggregate(msgs));
            for (ug, tx, _) in user_handles.iter().filter(|(ug, _, _)| *ug == g) {
                let _ = ug;
                tx.send(ToUser::Broadcast(Arc::clone(&bcast))).expect("user alive");
            }
        }
    }

    // Collect final shares, reconstruct per-group votes.
    let mut finals: Vec<Vec<Option<Vec<u64>>>> = vec![vec![None; n1]; cfg.ell];
    let mut received = 0usize;
    while received < cfg.n {
        match to_server_rx.recv().expect("users alive") {
            ToServer::FinalShare { group, party, share } => {
                finals[group][party] = Some(share);
                received += 1;
            }
            ToServer::Uplink { .. } => panic!("uplink after subrounds done"),
        }
    }
    let mut subgroup_votes = Vec::with_capacity(cfg.ell);
    let mut transcripts = Vec::with_capacity(cfg.ell);
    let mut stats = CommStats::default();
    for (g, server) in servers.iter_mut().enumerate() {
        let shares: Vec<Vec<u64>> =
            finals[g].iter_mut().map(|s| s.take().expect("all finals")).collect();
        let raw = server.finalize(shares);
        let votes: Vec<i8> = raw.iter().map(|&v| fp.level_of(v)).collect();
        server.stats.vote_bits = crate::quant::downlink_bits(cfg.precision, cfg.intra);
        stats.merge(&server.stats);
        subgroup_votes.push(votes);
        transcripts.push(server.transcript.clone());
    }
    let global_vote = Arc::new(inter_group_vote_q(&subgroup_votes, cfg.precision, cfg.inter));
    stats.vote_bits = crate::quant::downlink_bits(cfg.precision, cfg.inter);
    for (_, tx, _) in &user_handles {
        tx.send(ToUser::GlobalVote(Arc::clone(&global_vote))).expect("user alive");
    }
    for (_, _, h) in user_handles {
        let v = h.join().expect("user thread");
        debug_assert_eq!(v, *global_vote);
    }

    RoundOutcome {
        global_vote: (*global_vote).clone(),
        subgroup_votes,
        stats,
        transcripts,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert_eq;
    use crate::util::prop::forall;

    #[test]
    fn hierarchical_equals_plain_hierarchy() {
        forall("hierarchical secure ≡ Eq. 8", 40, |g| {
            let ell = g.usize_range(1, 4);
            let n1 = g.usize_range(2, 6);
            let n = ell * n1;
            let d = g.usize_range(1, 16);
            let intra = if g.bool() { TiePolicy::OneBit } else { TiePolicy::TwoBit };
            let inter = if g.bool() { TiePolicy::OneBit } else { TiePolicy::TwoBit };
            let cfg = HiSafeConfig { n, ell, intra, inter, sparse: g.bool(), precision: 2 };
            let signs: Vec<Vec<i8>> = (0..n).map(|_| g.sign_vec(d)).collect();
            let out = run_sync(&signs, cfg, g.u64());
            prop_assert_eq!(
                out.global_vote,
                plain_hierarchical_vote(&signs, cfg),
                "cfg={cfg:?}"
            );
            prop_assert_eq!(out.subgroup_votes.len(), ell);
            Ok(())
        });
    }

    #[test]
    fn flat_equals_group_vote() {
        forall("flat ≡ single group", 30, |g| {
            let n = g.usize_range(2, 10);
            let d = g.usize_range(1, 8);
            let policy = if g.bool() { TiePolicy::OneBit } else { TiePolicy::TwoBit };
            let signs: Vec<Vec<i8>> = (0..n).map(|_| g.sign_vec(d)).collect();
            let cfg = HiSafeConfig::flat(n, policy);
            let out = run_sync(&signs, cfg, g.u64());
            prop_assert_eq!(out.global_vote, plain_group_vote(&signs, policy));
            Ok(())
        });
    }

    #[test]
    fn threaded_matches_sync_bit_for_bit() {
        forall("threaded ≡ sync", 12, |g| {
            let ell = g.usize_range(1, 3);
            let n1 = g.usize_range(2, 5);
            let n = ell * n1;
            let d = g.usize_range(1, 8);
            let cfg = HiSafeConfig::hierarchical(
                n,
                ell,
                if g.bool() { TiePolicy::OneBit } else { TiePolicy::TwoBit },
            );
            let signs: Vec<Vec<i8>> = (0..n).map(|_| g.sign_vec(d)).collect();
            let seed = g.u64();
            let a = run_sync(&signs, cfg, seed);
            let b = run_threaded(&signs, cfg, seed);
            prop_assert_eq!(&a.global_vote, &b.global_vote);
            prop_assert_eq!(&a.subgroup_votes, &b.subgroup_votes);
            prop_assert_eq!(a.stats.c_u_bits(), b.stats.c_u_bits());
            prop_assert_eq!(a.stats.subrounds, b.stats.subrounds);
            // transcripts identical (same dealer seeds)
            prop_assert_eq!(a.transcripts.len(), b.transcripts.len());
            for (ta, tb) in a.transcripts.iter().zip(&b.transcripts) {
                prop_assert_eq!(&ta.output, &tb.output);
                prop_assert_eq!(ta.openings.len(), tb.openings.len());
                for (oa, ob) in ta.openings.iter().zip(&tb.openings) {
                    prop_assert_eq!(&oa.delta, &ob.delta);
                    prop_assert_eq!(&oa.eps, &ob.eps);
                }
            }
            Ok(())
        });
    }

    #[test]
    fn partition_is_disjoint_cover() {
        let groups = partition(24, 8);
        assert_eq!(groups.len(), 8);
        let mut all: Vec<usize> = groups.concat();
        all.sort_unstable();
        assert_eq!(all, (0..24).collect::<Vec<_>>());
        for g in &groups {
            assert_eq!(g.len(), 3);
        }
    }

    #[test]
    #[should_panic(expected = "must divide")]
    fn partition_rejects_non_divisor() {
        partition(24, 7);
    }

    #[test]
    fn config_labels() {
        assert_eq!(HiSafeConfig::hierarchical(24, 8, TiePolicy::OneBit).label(), "A-1");
        assert_eq!(HiSafeConfig::hierarchical(24, 8, TiePolicy::TwoBit).label(), "B-1");
        let b2 = HiSafeConfig { n: 24, ell: 8, intra: TiePolicy::TwoBit, inter: TiePolicy::TwoBit, sparse: false, precision: 2 };
        assert_eq!(b2.label(), "B-2");
        assert!(!b2.signsgd_compatible());
        assert!(HiSafeConfig::flat(24, TiePolicy::OneBit).signsgd_compatible());
    }

    #[test]
    fn paper_headline_config_n24_ell8() {
        // Table VII first row: n=24, ℓ*=8, n₁=3, 4 openings ("R"),
        // ⌈log p₁⌉=3 → C_u = 12 bits, C_T = 96 bits (per coordinate).
        let cfg = HiSafeConfig::hierarchical(24, 8, TiePolicy::OneBit);
        let signs: Vec<Vec<i8>> = (0..24).map(|i| vec![if i % 3 == 0 { -1i8 } else { 1 }]).collect();
        let out = run_sync(&signs, cfg, 7);
        assert_eq!(out.stats.c_u_bits(), 12);
        assert_eq!(out.stats.c_t_paper_bits(), 96); // ℓ·R·⌈log p₁⌉ = 8·4·3
        assert_eq!(out.stats.c_t_bits(), 24 * 12); // true all-user uplink = n·C_u
        assert_eq!(out.stats.subrounds, 2); // latency ⌈log p₁−1⌉ = 2
        assert_eq!(out.stats.mults, 8 * 2); // 2 per subgroup
        // flat baseline for the same n (Table VIII n=24 ℓ=1):
        let flat = run_sync(&signs, HiSafeConfig::flat(24, TiePolicy::OneBit), 7);
        assert!(flat.stats.c_u_bits() > out.stats.c_u_bits() * 10,
            "flat {} vs hier {}", flat.stats.c_u_bits(), out.stats.c_u_bits());
        // votes agree between configs on a clear majority
        assert_eq!(out.global_vote, vec![1]);
        assert_eq!(flat.global_vote, vec![1]);
    }

    #[test]
    fn b1_increases_resolution_not_uplink() {
        // Section III-E: B-1 (TwoBit intra) must not change the global
        // 1-bit downlink, and subgroup ties become 0 instead of −1.
        let signs = vec![
            vec![1i8], vec![-1], // group 1: tie
            vec![1], vec![1],    // group 2: +1
        ];
        let a1 = run_sync(&signs, HiSafeConfig::hierarchical(4, 2, TiePolicy::OneBit), 3);
        let b1 = run_sync(&signs, HiSafeConfig::hierarchical(4, 2, TiePolicy::TwoBit), 3);
        assert_eq!(a1.subgroup_votes[0], vec![-1]); // tie → −1 under A
        assert_eq!(b1.subgroup_votes[0], vec![0]);  // tie → 0 under B
        assert_eq!(a1.global_vote, vec![-1]);       // (−1 + 1) = 0 → tie → −1
        assert_eq!(b1.global_vote, vec![1]);        // (0 + 1) = 1 → +1
        assert_eq!(a1.stats.vote_bits, 1);
        assert_eq!(b1.stats.vote_bits, 1);
    }

    /// Random mask whose every group keeps ≥ t+1 survivors.
    fn viable_mask(g: &mut crate::util::prop::Gen, cfg: HiSafeConfig) -> ParticipantSet {
        let n1 = cfg.n1();
        let required = group_threshold(n1) + 1;
        let mut mask = vec![true; cfg.n];
        for members in partition(cfg.n, cfg.ell) {
            let max_drop = n1 - required;
            let drop = g.usize_range(0, max_drop + 1);
            let mut idx: Vec<usize> = members.clone();
            g.rng().shuffle(&mut idx);
            for &m in idx.iter().take(drop) {
                mask[m] = false;
            }
        }
        ParticipantSet::from_mask(mask)
    }

    #[test]
    fn zero_churn_is_bit_identical_to_run_sync() {
        forall("all-present dropout path ≡ run_sync", 25, |g| {
            let ell = g.usize_range(1, 4);
            let n1 = g.usize_range(2, 6);
            let n = ell * n1;
            let d = g.usize_range(1, 12);
            let cfg = HiSafeConfig {
                n,
                ell,
                intra: if g.bool() { TiePolicy::OneBit } else { TiePolicy::TwoBit },
                inter: if g.bool() { TiePolicy::OneBit } else { TiePolicy::TwoBit },
                sparse: g.bool(),
                precision: 2,
            };
            let signs: Vec<Vec<i8>> = (0..n).map(|_| g.sign_vec(d)).collect();
            let seed = g.u64();
            let a = run_sync(&signs, cfg, seed);
            let b = run_sync_with_dropouts(&signs, &ParticipantSet::all(n), cfg, seed)
                .expect("all-present never aborts");
            prop_assert_eq!(&a.global_vote, &b.global_vote);
            prop_assert_eq!(&a.subgroup_votes, &b.subgroup_votes);
            prop_assert_eq!(a.stats, b.stats);
            prop_assert_eq!(a.transcripts.len(), b.transcripts.len());
            for (ta, tb) in a.transcripts.iter().zip(&b.transcripts) {
                prop_assert_eq!(&ta.output, &tb.output);
                prop_assert_eq!(ta.openings.len(), tb.openings.len());
                for (oa, ob) in ta.openings.iter().zip(&tb.openings) {
                    prop_assert_eq!(&oa.delta, &ob.delta);
                    prop_assert_eq!(&oa.eps, &ob.eps);
                }
            }
            Ok(())
        });
    }

    #[test]
    fn dropout_votes_match_survivor_plaintext() {
        forall("survivor-set secure ≡ survivor-set Eq. 8", 30, |g| {
            let ell = g.usize_range(1, 4);
            let n1 = g.usize_range(3, 7);
            let n = ell * n1;
            let d = g.usize_range(1, 10);
            let cfg = HiSafeConfig {
                n,
                ell,
                intra: if g.bool() { TiePolicy::OneBit } else { TiePolicy::TwoBit },
                inter: if g.bool() { TiePolicy::OneBit } else { TiePolicy::TwoBit },
                sparse: g.bool(),
                precision: 2,
            };
            let signs: Vec<Vec<i8>> = (0..n).map(|_| g.sign_vec(d)).collect();
            let present = viable_mask(g, cfg);
            let out = run_sync_with_dropouts(&signs, &present, cfg, g.u64())
                .expect("mask is above threshold");
            prop_assert_eq!(
                out.global_vote,
                plain_hierarchical_vote_present(&signs, &present, cfg),
                "present={:?}",
                present.mask()
            );
            Ok(())
        });
    }

    #[test]
    fn dropout_round_is_deterministic_in_mask_and_seed() {
        forall("same (mask, seed) ⇒ same transcript", 15, |g| {
            let cfg = HiSafeConfig::hierarchical(12, 4, TiePolicy::OneBit);
            let signs: Vec<Vec<i8>> = (0..12).map(|_| g.sign_vec(4)).collect();
            let present = viable_mask(g, cfg);
            let seed = g.u64();
            let a = run_sync_with_dropouts(&signs, &present, cfg, seed).unwrap();
            let b = run_sync_with_dropouts(&signs, &present, cfg, seed).unwrap();
            prop_assert_eq!(&a.global_vote, &b.global_vote);
            for (ta, tb) in a.transcripts.iter().zip(&b.transcripts) {
                prop_assert_eq!(ta.openings.len(), tb.openings.len());
                for (oa, ob) in ta.openings.iter().zip(&tb.openings) {
                    prop_assert_eq!(&oa.delta, &ob.delta);
                }
            }
            Ok(())
        });
    }

    #[test]
    fn below_threshold_is_typed_error_not_panic() {
        // n₁=5 ⇒ t=2 ⇒ need 3 survivors. Drop 3 of group 1's members.
        let cfg = HiSafeConfig::hierarchical(10, 2, TiePolicy::OneBit);
        let signs: Vec<Vec<i8>> = (0..10).map(|i| vec![if i % 2 == 0 { 1i8 } else { -1 }]).collect();
        let mut mask = vec![true; 10];
        mask[5] = false;
        mask[6] = false;
        mask[8] = false;
        let err = run_sync_with_dropouts(&signs, &ParticipantSet::from_mask(mask), cfg, 1)
            .expect_err("group 1 kept 2 < 3 survivors");
        assert_eq!(err, ChurnError::BelowThreshold { group: 1, survivors: 2, required: 3 });
        assert!(err.to_string().contains("subgroup 1"));
        // Exactly at threshold still completes.
        let mut ok_mask = vec![true; 10];
        ok_mask[5] = false;
        ok_mask[6] = false;
        let out = run_sync_with_dropouts(&signs, &ParticipantSet::from_mask(ok_mask), cfg, 1);
        assert!(out.is_ok());
    }

    #[test]
    fn cohort_key_distinguishes_masks_and_recovery_is_stable() {
        let cfg = HiSafeConfig::hierarchical(8, 2, TiePolicy::OneBit);
        let groups = partition(cfg.n, cfg.ell);
        let full = ParticipantSet::all(8);
        let mut m1 = vec![true; 8];
        m1[1] = false;
        let p1 = ParticipantSet::from_mask(m1);
        let mut m2 = vec![true; 8];
        m2[2] = false;
        let p2 = ParticipantSet::from_mask(m2);
        let k_full = full.cohort_key(&groups[0]);
        let k1 = p1.cohort_key(&groups[0]);
        let k2 = p2.cohort_key(&groups[0]);
        assert_ne!(k_full, k1);
        assert_ne!(k1, k2);
        // Recovery is a pure function of (seed, group, mask) and differs
        // across masks, so cohort dealer streams never collide.
        let r1a = recover_cohort_key(9, 0, &groups[0], &p1);
        let r1b = recover_cohort_key(9, 0, &groups[0], &p1);
        let r2 = recover_cohort_key(9, 0, &groups[0], &p2);
        assert_eq!(r1a, r1b);
        assert_ne!(r1a, r2);
        assert_ne!(churn_dealer_seed(9, 0, r1a), group_dealer_seed(9, 0));
    }

    #[test]
    fn group_threshold_matches_shamir_backend() {
        // Same honest-majority bound shamir_group_vote uses: t = (n₁−1)/2.
        assert_eq!(group_threshold(1), 0);
        assert_eq!(group_threshold(2), 0);
        assert_eq!(group_threshold(3), 1);
        assert_eq!(group_threshold(4), 1);
        assert_eq!(group_threshold(5), 2);
        // check_thresholds flags the first violating group.
        let cfg = HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit);
        let mut mask = vec![true; 6];
        mask[0] = false;
        mask[1] = false; // group 0: 1 survivor < 2 required
        let err = check_thresholds(cfg, &ParticipantSet::from_mask(mask)).unwrap_err();
        assert_eq!(err, ChurnError::BelowThreshold { group: 0, survivors: 1, required: 2 });
    }
}
