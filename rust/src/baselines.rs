//! Baseline aggregation methods from Table I, implemented for the
//! quantitative comparison in `examples/baseline_compare.rs`.
//!
//! * [`masking`] — Bonawitz-style pairwise additive masking [18]: a real
//!   secure-sum (PRG-expanded pairwise masks over `Z_2^32`) whose defining
//!   weakness for sign-based FL is that the server *learns the exact sum*
//!   of the sign vectors before taking the majority — the leakage Hi-SAFE
//!   eliminates.
//! * [`dp_signsgd`] — DP-SIGNSGD [21]: Gaussian noise added to the local
//!   gradient before the sign; the server sees every (noisy) sign.
//! * [`he_cost`] — RLWE/CKKS communication cost model [22] (ciphertext
//!   expansion only; Table I compares magnitudes, and HE cannot evaluate
//!   the nonlinear vote anyway — the paper's point).

use crate::util::rng::{ChaCha20Rng, Rng};

// ---------------------------------------------------------------- masking

pub mod masking {
    //! Pairwise additive masking secure-sum over `Z_{2^32}`.
    //!
    //! Users `i < j` share a pairwise seed; user `i` adds the PRG stream,
    //! user `j` subtracts it. The masks cancel in the server's sum, which
    //! therefore equals `Σᵢ xᵢ` exactly — individual vectors are hidden,
    //! but the **summation value is revealed** (Table I row 1).

    use super::*;

    /// Outcome of one masked secure-sum round.
    #[derive(Debug)]
    pub struct MaskedSumOutcome {
        /// The exact sum the server reconstructs (the leaked quantity).
        pub sum: Vec<i64>,
        /// Majority vote derived from the sum (tie → −1, as Hi-SAFE A).
        pub votes: Vec<i8>,
        /// Per-user uplink bits (one 32-bit masked word per coordinate).
        pub uplink_bits_per_user: u64,
    }

    /// Run a masked secure sum of ±1 vectors. Internally verifies that the
    /// masked aggregate equals the plain sum (mask cancellation).
    pub fn secure_sum(signs: &[Vec<i8>], seed: u64) -> MaskedSumOutcome {
        let n = signs.len();
        let d = signs[0].len();
        // pairwise seeds from a root key (stands in for the DH key
        // agreement of [18])
        let mut masked: Vec<Vec<u32>> = signs
            .iter()
            .map(|s| s.iter().map(|&v| v as i32 as u32).collect())
            .collect();
        for i in 0..n {
            for j in (i + 1)..n {
                let mut prg = ChaCha20Rng::seed_from_u64(
                    seed ^ ((i as u64) << 32 | j as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15),
                );
                for t in 0..d {
                    let m = prg.next_u32();
                    masked[i][t] = masked[i][t].wrapping_add(m);
                    masked[j][t] = masked[j][t].wrapping_sub(m);
                }
            }
        }
        // server sums masked words; masks cancel mod 2^32
        let mut sum = vec![0i64; d];
        for t in 0..d {
            let mut acc = 0u32;
            for row in &masked {
                acc = acc.wrapping_add(row[t]);
            }
            // lift from Z_2^32: |true sum| ≤ n < 2^31
            sum[t] = (acc as i32) as i64;
        }
        let votes = sum
            .iter()
            .map(|&s| if s > 0 { 1i8 } else { -1 })
            .collect();
        MaskedSumOutcome { sum, votes, uplink_bits_per_user: 32 * d as u64 }
    }
}

// ------------------------------------------------------------- dp-signsgd

pub mod dp_signsgd {
    //! DP-SIGNSGD [21]: clip, add Gaussian noise calibrated to (ε, δ)-DP,
    //! then sign. The *noisy signs* remain visible to the server.

    use super::*;

    /// Gaussian-mechanism noise multiplier for (ε, δ)-DP (standard
    /// analytic form σ = √(2 ln(1.25/δ)) / ε, sensitivity 1 after clip).
    pub fn noise_multiplier(epsilon: f64, delta: f64) -> f64 {
        (2.0 * (1.25 / delta).ln()).sqrt() / epsilon
    }

    /// Clip a gradient to L2 norm ≤ `clip` and add `σ·clip` Gaussian noise.
    pub fn privatize(grad: &[f32], clip: f64, sigma: f64, rng: &mut ChaCha20Rng) -> Vec<f32> {
        let norm: f64 = grad.iter().map(|&g| (g as f64) * (g as f64)).sum::<f64>().sqrt();
        let scale = if norm > clip { clip / norm } else { 1.0 };
        grad.iter()
            .map(|&g| (g as f64 * scale + sigma * clip * rng.gen_gaussian()) as f32)
            .collect()
    }

    /// Per-user uplink: still 1 bit per coordinate (the method's virtue).
    pub fn uplink_bits_per_user(d: usize) -> u64 {
        d as u64
    }
}

// ---------------------------------------------------------------- he cost

pub mod he_cost {
    //! Communication cost model for CKKS-style RLWE HE [22].
    //!
    //! A ciphertext is two ring elements of degree `N` with `log q`-bit
    //! coefficients; up to `N/2` values pack per ciphertext. Defaults match
    //! a light CKKS parameter set (N = 4096, log q = 109) — already the
    //! *smallest* secure choice, i.e. the comparison is generous to HE.

    /// CKKS parameter set.
    #[derive(Debug, Clone, Copy)]
    pub struct HeParams {
        pub poly_degree: usize,
        pub log_q: u32,
    }

    impl Default for HeParams {
        fn default() -> Self {
            HeParams { poly_degree: 4096, log_q: 109 }
        }
    }

    impl HeParams {
        pub fn ciphertext_bits(&self) -> u64 {
            2 * self.poly_degree as u64 * self.log_q as u64
        }

        pub fn slots(&self) -> usize {
            self.poly_degree / 2
        }

        /// Per-user uplink bits to ship a `d`-dimensional update encrypted.
        pub fn uplink_bits_per_user(&self, d: usize) -> u64 {
            let cts = d.div_ceil(self.slots()) as u64;
            cts * self.ciphertext_bits()
        }

        /// Expansion factor vs the 1-bit sign update.
        pub fn expansion_vs_sign(&self, d: usize) -> f64 {
            self.uplink_bits_per_user(d) as f64 / d as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpc::plain_group_vote;
    use crate::poly::TiePolicy;
    use crate::prop_assert_eq;
    use crate::util::prop::forall;

    #[test]
    fn masked_sum_equals_plain_sum() {
        forall("masking: Σ masked = Σ plain", 50, |g| {
            let n = g.usize_range(2, 20);
            let d = g.usize_range(1, 32);
            let signs: Vec<Vec<i8>> = (0..n).map(|_| g.sign_vec(d)).collect();
            let out = masking::secure_sum(&signs, g.u64());
            for t in 0..d {
                let want: i64 = signs.iter().map(|s| s[t] as i64).sum();
                prop_assert_eq!(out.sum[t], want, "coord {t}");
            }
            // vote matches plain MV with tie→−1
            prop_assert_eq!(
                out.votes,
                plain_group_vote(&signs, TiePolicy::OneBit)
            );
            Ok(())
        });
    }

    #[test]
    fn masking_leaks_sum_but_hisafe_does_not() {
        // The structural difference Table I highlights: masking's outcome
        // includes the exact per-coordinate sum; Hi-SAFE's transcript
        // contains only sign values and uniform openings.
        let signs: Vec<Vec<i8>> = vec![vec![1], vec![1], vec![1], vec![-1], vec![1]];
        let masked = masking::secure_sum(&signs, 3);
        assert_eq!(masked.sum, vec![3]); // reveals the 4-vs-1 split exactly
        let hisafe = crate::mpc::secure_group_vote(&signs, TiePolicy::OneBit, false, 3);
        assert_eq!(hisafe.raw, vec![1]); // reveals only sign(+3) = +1
    }

    #[test]
    fn masking_single_coordinate_cost() {
        let signs: Vec<Vec<i8>> = vec![vec![1; 100], vec![-1; 100]];
        let out = masking::secure_sum(&signs, 1);
        assert_eq!(out.uplink_bits_per_user, 3200);
    }

    #[test]
    fn dp_noise_multiplier_sane() {
        let sigma = dp_signsgd::noise_multiplier(1.0, 1e-5);
        assert!(sigma > 4.0 && sigma < 5.0, "σ = {sigma}");
        // stronger privacy → more noise
        assert!(dp_signsgd::noise_multiplier(0.5, 1e-5) > sigma);
    }

    #[test]
    fn dp_privatize_clips_and_perturbs() {
        let mut rng = ChaCha20Rng::seed_from_u64(5);
        let grad = vec![10.0f32; 100]; // L2 = 100
        let noisy = dp_signsgd::privatize(&grad, 1.0, 0.0, &mut rng);
        let norm: f64 = noisy.iter().map(|&g| (g as f64).powi(2)).sum::<f64>().sqrt();
        assert!((norm - 1.0).abs() < 1e-4, "clipped norm {norm}");
        let noisy2 = dp_signsgd::privatize(&grad, 1.0, 4.0, &mut rng);
        assert_ne!(noisy, noisy2);
    }

    #[test]
    fn he_expansion_is_catastrophic_for_1bit_updates() {
        // Table I "Very Low" comm efficiency: ≥ ~400× expansion over the
        // 1-bit sign update even with packing.
        let he = he_cost::HeParams::default();
        let d = 7850; // linear model on 784 inputs
        assert_eq!(he.ciphertext_bits(), 2 * 4096 * 109);
        let exp = he.expansion_vs_sign(d);
        assert!(exp > 400.0, "expansion {exp}");
        // Hi-SAFE per-coordinate uplink at n₁=3 is 12 bits — 70x+ less
        // than HE's per-coordinate cost.
        assert!(he.uplink_bits_per_user(d) as f64 / d as f64 > 12.0 * 30.0);
    }
}
