//! The federated SIGNSGD-MV training loop (Algorithms 2 & 3 end-to-end).
//!
//! Per global round `t`:
//! 1. select `n = C·N` users;
//! 2. each selected user computes a minibatch gradient on its own shard
//!    and 1-bit quantizes it (Eq. 4);
//! 3. the configured [`Aggregator`] produces the global direction `ĝ(t)`
//!    (secure Hi-SAFE, plain MV, DP-SIGNSGD, masked-sum, or FedAvg);
//! 4. every user applies `θ ← θ − η·ĝ(t)` (Eq. 6 / Alg. 2 line 12).
//!
//! The trainer is generic over [`Model`] so the same loop drives the
//! pure-rust models and the AOT-compiled JAX models. Three entry points
//! share one round-step implementation:
//!
//! * [`train`] — one federation, on a private scheduler (the classic
//!   single-tenant path).
//! * [`train_multi`] — several federations ([`FedSpec`]s) driven
//!   round-robin through **one shared [`AggScheduler`]**: every secure
//!   tenant gets its own [`AggSession`] (own seed stream, own pools) but
//!   all of them evaluate on one worker pool and provision from one
//!   dealing plane. Per-federation trajectories are bit-identical to
//!   running [`train`] separately — sessions are pinned bit-identical to
//!   dedicated engines — so multiplexing is purely an infrastructure
//!   decision.
//! * [`train_remote`] — the same federations driven through a
//!   [`ServiceClient`] against a `hisafe serve` process: sessions open,
//!   rounds submit, and throttle denials retry **over the wire**
//!   (`rust/src/service/`). The session seed derivation and the round
//!   step are shared with the local paths, so remote trajectories are
//!   bit-identical to [`train`] / [`train_multi`] — serving location,
//!   like multiplexing, is purely an infrastructure decision (pinned by
//!   `rust/tests/service_props.rs`).
//!
//! Each [`FedSpec`] carries a [`QosPolicy`] for its secure session
//! (dealing weight, bounded queue depth, rate budgets). Rounds denied by
//! the rate budget are retried until admitted — training needs every
//! round — with the waits surfaced in [`RoundLog::throttled`] and the
//! session's [`AdmissionStats`] in [`TrainResult::admission`], so QoS
//! shapes scheduling, never trajectories.

use crate::baselines::{dp_signsgd, masking};
use crate::engine::{AdmissionError, AggScheduler, AggSession, QosPolicy, SessionId};
use crate::fl::data::Dataset;
use crate::fl::model::{sign_vec, Model};
use crate::metrics::{AdmissionStats, CommStats};
use crate::protocol::{plain_group_vote_all, HiSafeConfig, ParticipantSet};
use crate::service::ServiceClient;
use crate::util::json::Json;
use crate::util::rng::{ChaCha20Rng, Rng, Xoshiro256pp};

/// Aggregation rule for the global update direction.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Aggregator {
    /// The paper's secure protocol (flat if `ell == 1`).
    HiSafe(HiSafeConfig),
    /// Non-private SIGNSGD-MV [25] (functionally equal to flat Hi-SAFE
    /// under 1-bit ties, minus privacy — Section V-B).
    PlainMv(crate::poly::TiePolicy),
    /// DP-SIGNSGD [21]: clip + Gaussian noise, then sign, then plain MV.
    DpSign { clip: f64, sigma: f64 },
    /// Pairwise-masking secure sum [18] then server-side sign.
    MaskedSum,
    /// Federated SGD with float gradient averaging (accuracy reference).
    FedAvg,
}

impl Aggregator {
    pub fn name(&self) -> String {
        match self {
            Aggregator::HiSafe(c) => {
                format!("hisafe_l{}_{}", c.ell, c.label())
            }
            Aggregator::PlainMv(p) => format!("plain_mv_{}", p.name()),
            Aggregator::DpSign { sigma, .. } => format!("dp_signsgd_s{sigma}"),
            Aggregator::MaskedSum => "masked_sum".into(),
            Aggregator::FedAvg => "fedavg".into(),
        }
    }
}

/// Training-run configuration (Table VI hyperparameters).
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Total user population `N` (paper: 100).
    pub n_users: usize,
    /// Participants per round `n = C·N` (paper: C ∈ [0.12, 0.36]).
    pub participants: usize,
    pub rounds: usize,
    pub lr: f32,
    pub batch_size: usize,
    /// Evaluate test accuracy every `eval_every` rounds (and at the end).
    pub eval_every: usize,
    pub seed: u64,
    /// Per-round probability that each selected participant drops out
    /// before submitting (device churn). Sampled from a dedicated RNG
    /// stream, so `0.0` reproduces pre-churn trajectories bit-for-bit.
    /// Dropped users do no gradient work; secure rounds run the t-of-n
    /// threshold path over the survivors, and a round whose survivor
    /// set falls below a group threshold is *aborted* (model untouched,
    /// [`RoundLog::aborted`] set) rather than retried.
    pub churn: f64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            n_users: 100,
            participants: 24,
            rounds: 100,
            lr: 0.005,
            batch_size: 100,
            eval_every: 10,
            seed: 0,
            churn: 0.0,
        }
    }
}

/// One round's log line.
#[derive(Debug, Clone)]
pub struct RoundLog {
    pub round: usize,
    pub train_loss: f32,
    /// Test accuracy (only populated on eval rounds; carries last value).
    pub test_acc: f32,
    /// Per-user uplink bits this round (whole model).
    pub uplink_bits_per_user: u64,
    /// Times this round was throttled (denied-then-retried) by the
    /// session's [`QosPolicy`] rate budget before being admitted. Always
    /// 0 for non-secure aggregators and unlimited policies.
    pub throttled: u64,
    /// Full per-round communication counters from the secure engine
    /// (equal, field element for field element, to the measured counters
    /// of the message-passing path — pinned by `engine_props.rs`). `None`
    /// for aggregators that don't run the secure protocol.
    pub comm: Option<CommStats>,
    /// Selected participants that actually submitted this round (equal
    /// to `participants` when [`TrainConfig::churn`] is 0).
    pub survivors: usize,
    /// `true` iff this round was aborted — the survivor set fell below a
    /// group's reconstruction threshold (secure) or no user at all
    /// survived (baselines). Aborted rounds leave the model untouched
    /// and ship zero uplink bits.
    pub aborted: bool,
}

/// Full training result.
#[derive(Debug)]
pub struct TrainResult {
    pub logs: Vec<RoundLog>,
    pub final_acc: f32,
    pub final_params: Vec<f32>,
    /// Cumulative per-user uplink over the run.
    pub total_uplink_bits_per_user: u64,
    pub aggregator: String,
    /// Admission counters from the secure session (rounds admitted,
    /// throttle/queue-full/reject denials). `None` for aggregators that
    /// don't run through the scheduler.
    pub admission: Option<AdmissionStats>,
}

impl TrainResult {
    /// Serialize the curve for EXPERIMENTS.md / plotting.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("aggregator", self.aggregator.clone());
        j.set("final_acc", self.final_acc as f64);
        j.set(
            "total_uplink_bits_per_user",
            self.total_uplink_bits_per_user,
        );
        if let Some(adm) = &self.admission {
            j.set("admission", adm.to_json());
        }
        j.set(
            "rounds",
            self.logs
                .iter()
                .map(|l| {
                    let mut r = Json::obj();
                    r.set("round", l.round)
                        .set("loss", l.train_loss as f64)
                        .set("acc", l.test_acc as f64)
                        .set("uplink_bits_per_user", l.uplink_bits_per_user)
                        .set("throttled", l.throttled)
                        .set("survivors", l.survivors)
                        .set("aborted", l.aborted);
                    if let Some(comm) = &l.comm {
                        r.set("comm", comm.to_json());
                    }
                    r
                })
                .collect::<Vec<_>>(),
        );
        j
    }
}

/// One federation's full specification — everything [`train`] takes,
/// bundled so [`train_multi`] can drive several federations through one
/// shared scheduler.
pub struct FedSpec<'a, M: Model> {
    pub model: &'a M,
    pub train_ds: &'a Dataset,
    pub test_ds: &'a Dataset,
    /// `shards[u]` lists the training-set indices owned by user `u`
    /// (from [`crate::fl::data::partition_users`]).
    pub shards: &'a [Vec<usize>],
    pub agg: Aggregator,
    pub cfg: TrainConfig,
    /// Per-tenant QoS for the secure session this federation runs on:
    /// dealing weight, bounded queue depth, and rate budgets. The
    /// default ([`QosPolicy::unlimited`]) reproduces pre-QoS behavior.
    /// Rounds denied by the rate budget are retried until admitted (the
    /// training loop needs every round), with the waits counted in
    /// [`RoundLog::throttled`] and [`TrainResult::admission`] — QoS
    /// shapes *when* rounds run, never the trajectory, which stays
    /// bit-identical to an unthrottled run.
    pub qos: QosPolicy,
}

/// The trainer's secure-aggregation backend: an in-process scheduler
/// session, or a session id on a remote `hisafe serve` frontend driven
/// through a [`ServiceClient`]. Both run the identical QoS-checked
/// round path (`run_round_admitted`, local or wire), which is what
/// keeps [`train_remote`] trajectories bit-identical to [`train`].
enum SessionHandle {
    Local(AggSession),
    Remote { id: SessionId },
}

/// The one derivation of a federation's secure-session seed from its
/// run seed. `train`, `train_multi`, and `train_remote` all route
/// through it — if local and remote ever disagreed here, their dealer
/// streams (and the stream-level audits) would diverge.
fn session_seed(cfg: &TrainConfig) -> u64 {
    cfg.seed ^ 0xa6_67e6
}

/// Per-gradient quantization scale for q > 2 tenants: one level step
/// represents the mean coordinate magnitude (so typical coordinates
/// land on the inner levels and outliers saturate), floored at 1.0 for
/// an all-zero gradient.
fn quant_scale(g: &[f32]) -> f32 {
    let mean = g.iter().map(|x| x.abs()).sum::<f32>() / g.len().max(1) as f32;
    if mean > 0.0 { mean } else { 1.0 }
}

/// One federation's in-flight training state: the per-round step of the
/// classic [`train`] loop, factored out so single-, multi-, and
/// remote-federation paths execute the identical code (and therefore
/// identical RNG streams and parameter trajectories).
struct FedRun<'a, M: Model> {
    model: &'a M,
    train_ds: &'a Dataset,
    test_ds: &'a Dataset,
    shards: &'a [Vec<usize>],
    agg: Aggregator,
    cfg: TrainConfig,
    params: Vec<f32>,
    select_rng: Xoshiro256pp,
    batch_rng: Xoshiro256pp,
    dp_rng: ChaCha20Rng,
    /// Dedicated stream for per-round dropout sampling. Kept separate
    /// from the selection/batch streams so `churn == 0.0` (which never
    /// draws from it) leaves every other stream — and therefore the
    /// whole trajectory — bit-identical to pre-churn runs.
    churn_rng: Xoshiro256pp,
    /// Secure aggregation runs through a scheduler session — in-process
    /// or remote: plan and polynomial are built once (scheduler-side),
    /// and the shared provisioning plane deals round r+1's Beaver
    /// triples while round r's online phase (and this loop's gradient
    /// work) executes — the paper's offline/online split as wall-clock
    /// overlap. Votes are bit-identical to run_sync and the sequential
    /// RoundEngine (the dealer streams share run_sync's per-group seed
    /// derivation), wherever the session lives.
    session: Option<SessionHandle>,
    logs: Vec<RoundLog>,
    last_acc: f32,
    total_uplink: u64,
}

impl<'a, M: Model> FedRun<'a, M> {
    fn validate(spec: &FedSpec<'a, M>) {
        assert_eq!(spec.shards.len(), spec.cfg.n_users, "one shard per user");
        assert!(spec.cfg.participants <= spec.cfg.n_users);
        assert!(
            (0.0..1.0).contains(&spec.cfg.churn),
            "churn must be a probability in [0, 1), got {}",
            spec.cfg.churn
        );
        if let Aggregator::HiSafe(hc) = &spec.agg {
            assert_eq!(hc.n, spec.cfg.participants, "HiSafeConfig.n must equal participants");
        }
    }

    fn new(spec: &FedSpec<'a, M>, sched: Option<&AggScheduler>) -> FedRun<'a, M> {
        Self::validate(spec);
        let session = match &spec.agg {
            Aggregator::HiSafe(hc) => Some(SessionHandle::Local(
                sched
                    .expect("a scheduler is required for secure aggregation")
                    .try_session(*hc, spec.model.dim(), session_seed(&spec.cfg), spec.qos)
                    .unwrap_or_else(|e| panic!("federation session not admitted: {e}")),
            )),
            _ => None,
        };
        Self::with_session(spec, session)
    }

    /// Like [`FedRun::new`], but the session lives on a remote frontend:
    /// the same config, dimension, seed derivation, and QoS cross the
    /// wire, so the remote scheduler builds the identical session a
    /// local one would.
    fn new_remote(spec: &FedSpec<'a, M>, client: &mut ServiceClient) -> FedRun<'a, M> {
        Self::validate(spec);
        let session = match &spec.agg {
            Aggregator::HiSafe(hc) => Some(SessionHandle::Remote {
                id: client
                    .open_session(*hc, spec.model.dim(), session_seed(&spec.cfg), spec.qos)
                    .unwrap_or_else(|e| panic!("remote federation session not admitted: {e}")),
            }),
            _ => None,
        };
        Self::with_session(spec, session)
    }

    fn with_session(spec: &FedSpec<'a, M>, session: Option<SessionHandle>) -> FedRun<'a, M> {
        let cfg = spec.cfg.clone();
        FedRun {
            model: spec.model,
            train_ds: spec.train_ds,
            test_ds: spec.test_ds,
            shards: spec.shards,
            agg: spec.agg,
            params: spec.model.init_params(cfg.seed),
            select_rng: Xoshiro256pp::seed_from_u64(cfg.seed ^ 0x5e1ec7),
            batch_rng: Xoshiro256pp::seed_from_u64(cfg.seed ^ 0xba7c4),
            dp_rng: ChaCha20Rng::seed_from_u64(cfg.seed ^ 0xd9),
            churn_rng: Xoshiro256pp::seed_from_u64(cfg.seed ^ 0xc4021),
            session,
            logs: Vec::with_capacity(cfg.rounds),
            last_acc: 0.0,
            total_uplink: 0,
            cfg,
        }
    }

    /// Execute global round `round` (Alg. 2/3 lines 4–12). `client` is
    /// required iff the session is remote (the caller owns the one
    /// connection all its federations share).
    fn step(&mut self, round: usize, client: Option<&mut ServiceClient>) {
        let d = self.model.dim();

        // 1. user selection
        let selected = self.select_rng.sample_indices(self.cfg.n_users, self.cfg.participants);

        // 1b. per-round churn: each selected user independently drops
        // out with probability `churn`. `churn == 0.0` skips the draw
        // entirely — not as an optimization but as a determinism
        // guarantee (no stream is touched, so legacy trajectories are
        // reproduced bit-for-bit).
        let present: Vec<bool> = if self.cfg.churn > 0.0 {
            (0..selected.len())
                .map(|_| {
                    // 53-bit mantissa draw, uniform in [0, 1).
                    let u = (self.churn_rng.next_u64() >> 11) as f64 / (1u64 << 53) as f64;
                    u >= self.cfg.churn
                })
                .collect()
        } else {
            vec![true; selected.len()]
        };
        let survivors = present.iter().filter(|&&p| p).count();

        // 2. local gradients + signs — dropped users do no work (their
        // device is gone for the round), so their slot is `None` and the
        // batch stream only advances for survivors.
        let mut losses = 0.0f32;
        let mut grads: Vec<Option<Vec<f32>>> = Vec::with_capacity(selected.len());
        for (slot, &u) in selected.iter().enumerate() {
            if !present[slot] {
                grads.push(None);
                continue;
            }
            let shard = &self.shards[u];
            assert!(!shard.is_empty(), "user {u} has no data");
            // Sample WITH replacement so batches are always full —
            // required by the JAX backends (batch size is baked into the
            // AOT artifact) and harmless for small shards.
            let batch: Vec<usize> = (0..self.cfg.batch_size)
                .map(|_| shard[self.batch_rng.gen_below(shard.len() as u64) as usize])
                .collect();
            let (loss, grad) = self.model.loss_grad(&self.params, self.train_ds, &batch);
            losses += loss;
            grads.push(Some(grad));
        }
        let train_loss = if survivors > 0 { losses / survivors as f32 } else { 0.0 };

        // 3. aggregate into an update direction. An aborted round (the
        // survivor set fell below a group's reconstruction threshold, or
        // no baseline user survived at all) contributes a zero direction
        // — the model update below is a no-op — and ships zero bits.
        let mut comm: Option<CommStats> = None;
        let mut throttled = 0u64;
        let mut aborted = false;
        let (direction, uplink_bits_per_user): (Vec<f32>, u64) = match &self.agg {
            Aggregator::HiSafe(hc) => {
                // Full n-row sign matrix: absent users contribute a zero
                // row the engine never reads (the wire shape is mask-
                // independent; presence travels separately). At
                // precision 2 this is the exact legacy sign path; a
                // higher-precision tenant quantizes each gradient onto
                // its q odd midrise levels instead, with a per-gradient
                // scale (mean |gᵢ|) — a deterministic function of the
                // gradient, so no RNG stream is touched and q = 2
                // trajectories stay bit-identical to pre-quant builds.
                let q = hc.precision;
                let signs: Vec<Vec<i8>> = grads
                    .iter()
                    .map(|g| {
                        g.as_ref()
                            .map(|g| {
                                if q == 2 {
                                    sign_vec(g)
                                } else {
                                    crate::quant::Quantizer::new(q, quant_scale(g))
                                        .quantize_vec(g)
                                }
                            })
                            .unwrap_or_else(|| vec![0i8; d])
                    })
                    .collect();
                // QoS-checked admission with blocking retry: training
                // needs every round, so a throttle denial is a wait, not
                // a skip. Votes are unaffected — admission decides when
                // a round runs, never what it computes. The remote path
                // runs the same retry loop with the denial crossing the
                // wire each time. A full-present round takes the legacy
                // path (byte-identical v1 frames remotely); a churned
                // round runs the threshold path over the survivors, and
                // a below-threshold mask aborts instead of retrying.
                let outcome = match self.session.as_mut().expect("session built for HiSafe") {
                    SessionHandle::Local(session) => {
                        if survivors == selected.len() {
                            let (out, denials, _waited) = session.run_round_admitted(&signs);
                            Some((out.global_vote, out.stats, denials))
                        } else {
                            let pset = ParticipantSet::from_mask(present.clone());
                            match session.run_round_admitted_present(&signs, &pset) {
                                Ok((out, denials, _waited)) => {
                                    Some((out.global_vote, out.stats, denials))
                                }
                                Err(AdmissionError::ChurnBelowThreshold { .. }) => None,
                                Err(e) => panic!("aggregation round failed: {e}"),
                            }
                        }
                    }
                    SessionHandle::Remote { id } => {
                        let client = client.expect("remote sessions require a ServiceClient");
                        if survivors == selected.len() {
                            let (reply, denials, _waited) = client
                                .run_round_admitted(*id, &signs)
                                .unwrap_or_else(|e| {
                                    panic!("remote aggregation round failed: {e}")
                                });
                            Some((reply.global_vote, reply.stats, denials))
                        } else {
                            match client.run_round_admitted_present(
                                *id,
                                &signs,
                                Some(present.as_slice()),
                            ) {
                                Ok((reply, denials, _waited)) => {
                                    Some((reply.global_vote, reply.stats, denials))
                                }
                                Err(crate::service::Error::Admission(
                                    AdmissionError::ChurnBelowThreshold { .. },
                                )) => None,
                                Err(e) => panic!("remote aggregation round failed: {e}"),
                            }
                        }
                    }
                };
                match outcome {
                    Some((global_vote, stats, denials)) => {
                        throttled = denials;
                        let bits = stats.c_u_bits();
                        let direction = global_vote.iter().map(|&v| v as f32).collect();
                        comm = Some(stats);
                        (direction, bits)
                    }
                    None => {
                        aborted = true;
                        (vec![0.0f32; d], 0)
                    }
                }
            }
            Aggregator::PlainMv(policy) => {
                let signs: Vec<Vec<i8>> = grads.iter().flatten().map(|g| sign_vec(g)).collect();
                if signs.is_empty() {
                    aborted = true;
                    (vec![0.0f32; d], 0)
                } else {
                    let vote = plain_group_vote_all(&signs, *policy);
                    (vote.iter().map(|&v| v as f32).collect(), d as u64)
                }
            }
            Aggregator::DpSign { clip, sigma } => {
                let signs: Vec<Vec<i8>> = grads
                    .iter()
                    .flatten()
                    .map(|g| {
                        sign_vec(&dp_signsgd::privatize(g, *clip, *sigma, &mut self.dp_rng))
                    })
                    .collect();
                if signs.is_empty() {
                    aborted = true;
                    (vec![0.0f32; d], 0)
                } else {
                    let vote = plain_group_vote_all(&signs, crate::poly::TiePolicy::OneBit);
                    (vote.iter().map(|&v| v as f32).collect(), d as u64)
                }
            }
            Aggregator::MaskedSum => {
                let signs: Vec<Vec<i8>> = grads.iter().flatten().map(|g| sign_vec(g)).collect();
                if signs.is_empty() {
                    aborted = true;
                    (vec![0.0f32; d], 0)
                } else {
                    let out = masking::secure_sum(&signs, self.cfg.seed ^ round as u64);
                    (
                        out.votes.iter().map(|&v| v as f32).collect(),
                        out.uplink_bits_per_user,
                    )
                }
            }
            Aggregator::FedAvg => {
                let live: Vec<&Vec<f32>> = grads.iter().flatten().collect();
                if live.is_empty() {
                    aborted = true;
                    (vec![0.0f32; d], 0)
                } else {
                    let mut mean = vec![0.0f32; d];
                    let inv = 1.0 / live.len() as f32;
                    for g in &live {
                        for (m, &gi) in mean.iter_mut().zip(g.iter()) {
                            *m += gi * inv;
                        }
                    }
                    (mean, 32 * d as u64)
                }
            }
        };
        self.total_uplink += uplink_bits_per_user;

        // 4. model update (Eq. 6): θ ← θ − η·ĝ
        for (p, &g) in self.params.iter_mut().zip(&direction) {
            *p -= self.cfg.lr * g;
        }

        // 5. periodic evaluation
        if round % self.cfg.eval_every == 0 || round + 1 == self.cfg.rounds {
            self.last_acc = self.model.accuracy(&self.params, self.test_ds);
        }
        self.logs.push(RoundLog {
            round,
            train_loss,
            test_acc: self.last_acc,
            uplink_bits_per_user,
            throttled,
            comm,
            survivors,
            aborted,
        });
    }

    /// `client` is required iff the session is remote; remote sessions
    /// are closed here (freeing their shard slot) after their admission
    /// counters are fetched.
    fn finish(mut self, client: Option<&mut ServiceClient>) -> TrainResult {
        let final_acc = self.model.accuracy(&self.params, self.test_ds);
        let admission = match self.session.take() {
            None => None,
            Some(SessionHandle::Local(session)) => Some(session.admission_stats()),
            Some(SessionHandle::Remote { id }) => {
                let client = client.expect("remote sessions require a ServiceClient");
                let stats = client
                    .stats(Some(id))
                    .unwrap_or_else(|e| panic!("remote stats query failed: {e}"));
                client
                    .close_session(id)
                    .unwrap_or_else(|e| panic!("remote session close failed: {e}"));
                Some(stats.admission)
            }
        };
        TrainResult {
            logs: self.logs,
            final_acc,
            final_params: self.params,
            total_uplink_bits_per_user: self.total_uplink,
            aggregator: self.agg.name(),
            admission,
        }
    }
}

/// Run federated training for one federation on a private scheduler.
///
/// `shards[u]` lists the training-set indices owned by user `u`
/// (from [`crate::fl::data::partition_users`]).
pub fn train<M: Model>(
    model: &M,
    train_ds: &Dataset,
    test_ds: &Dataset,
    shards: &[Vec<usize>],
    agg: Aggregator,
    cfg: &TrainConfig,
) -> TrainResult {
    // Scheduler infrastructure (worker pool + dealing plane) is only
    // worth spawning when the run actually evaluates the secure
    // protocol; baselines aggregate in-line with zero engine threads.
    let sched = match &agg {
        Aggregator::HiSafe(_) => Some(AggScheduler::new()),
        _ => None,
    };
    let spec = FedSpec {
        model,
        train_ds,
        test_ds,
        shards,
        agg,
        cfg: cfg.clone(),
        qos: QosPolicy::unlimited(),
    };
    train_multi_impl(sched.as_ref(), std::slice::from_ref(&spec))
        .pop()
        .expect("one federation in, one result out")
}

/// Run several federations concurrently through **one shared
/// scheduler**: rounds are interleaved round-robin (federation 0 round
/// `t`, federation 1 round `t`, …, then round `t+1`), so every secure
/// tenant's offline dealing overlaps the others' gradient and online
/// work on the same worker pool — `k` federations cost one pool's worth
/// of threads. Federations may differ in dataset, shards, aggregator,
/// round count, seed, and `(cfg, d)` shape; they must share one model
/// *type* `M` (the slice is monomorphized — to mix model types, make
/// separate `train_multi` calls against the same scheduler).
///
/// Per-federation results are bit-identical to calling [`train`] once
/// per federation: sessions are pinned bit-identical to dedicated
/// engines, and each federation's RNG streams depend only on its own
/// `TrainConfig::seed`.
pub fn train_multi<M: Model>(sched: &AggScheduler, feds: &[FedSpec<M>]) -> Vec<TrainResult> {
    train_multi_impl(Some(sched), feds)
}

/// Run several federations against a **remote** aggregation service
/// (`hisafe serve`) through one blocking [`ServiceClient`]: every
/// secure federation opens a wire session (same config, dimension, seed
/// derivation, and QoS as the local paths), rounds interleave
/// round-robin exactly like [`train_multi`], and throttle denials are
/// retried by the client across the wire.
///
/// Per-federation results are bit-identical to [`train`] /
/// [`train_multi`]: the remote frontend places each tenant on some
/// scheduler shard, and neither placement nor transport touches the
/// seed-derived triple streams (pinned by
/// `rust/tests/service_props.rs`, including under throttling). Remote
/// sessions are closed before this returns.
pub fn train_remote<M: Model>(
    client: &mut ServiceClient,
    feds: &[FedSpec<M>],
) -> Vec<TrainResult> {
    let mut runs: Vec<FedRun<M>> =
        feds.iter().map(|f| FedRun::new_remote(f, client)).collect();
    let max_rounds = feds.iter().map(|f| f.cfg.rounds).max().unwrap_or(0);
    for round in 0..max_rounds {
        for run in runs.iter_mut() {
            if round < run.cfg.rounds {
                run.step(round, Some(&mut *client));
            }
        }
    }
    runs.into_iter().map(|r| r.finish(Some(&mut *client))).collect()
}

fn train_multi_impl<M: Model>(
    sched: Option<&AggScheduler>,
    feds: &[FedSpec<M>],
) -> Vec<TrainResult> {
    let mut runs: Vec<FedRun<M>> = feds.iter().map(|f| FedRun::new(f, sched)).collect();
    let max_rounds = feds.iter().map(|f| f.cfg.rounds).max().unwrap_or(0);
    for round in 0..max_rounds {
        for run in runs.iter_mut() {
            if round < run.cfg.rounds {
                run.step(round, None);
            }
        }
    }
    runs.into_iter().map(|r| r.finish(None)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::data::{partition_users, synthetic, DataKind, Partition};
    use crate::fl::model::LinearSoftmax;
    use crate::poly::TiePolicy;

    fn quick_setup() -> (Dataset, Dataset, Vec<Vec<usize>>) {
        let (tr, te) = synthetic(DataKind::MnistLike, 1200, 300, 7);
        let shards = partition_users(&tr, 20, Partition::TwoClass, 7);
        (tr, te, shards)
    }

    fn quick_cfg(rounds: usize) -> TrainConfig {
        TrainConfig {
            n_users: 20,
            participants: 6,
            rounds,
            lr: 0.002,
            batch_size: 32,
            eval_every: 10,
            seed: 11,
            churn: 0.0,
        }
    }

    #[test]
    fn hisafe_training_learns_non_iid() {
        let (tr, te, shards) = quick_setup();
        let m = LinearSoftmax::new(784, 10);
        let cfg = quick_cfg(60);
        let agg = Aggregator::HiSafe(HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit));
        let res = train(&m, &tr, &te, &shards, agg, &cfg);
        assert!(
            res.final_acc > 0.5,
            "Hi-SAFE training reached only {}",
            res.final_acc
        );
        assert_eq!(res.logs.len(), 60);
    }

    #[test]
    fn hisafe_flat_equals_plain_mv_exactly() {
        // Section V-B: under 1-bit ties, flat Hi-SAFE is functionally
        // identical to SIGNSGD-MV. Same seeds ⇒ identical parameter
        // trajectories.
        let (tr, te, shards) = quick_setup();
        let m = LinearSoftmax::new(784, 10);
        let cfg = quick_cfg(12);
        let secure = train(
            &m, &tr, &te, &shards,
            Aggregator::HiSafe(HiSafeConfig::flat(6, TiePolicy::OneBit)),
            &cfg,
        );
        let plain = train(
            &m, &tr, &te, &shards,
            Aggregator::PlainMv(TiePolicy::OneBit),
            &cfg,
        );
        assert_eq!(secure.final_params, plain.final_params);
        assert_eq!(secure.final_acc, plain.final_acc);
    }

    #[test]
    fn quantized_training_runs_and_learns() {
        // A precision-4 federation drives the q-level secure path end to
        // end: gradients quantize onto {−3, −1, 1, 3}, every round logs
        // measured comm from the wider-field polynomial, and the model
        // still learns the non-IID task.
        let (tr, te, shards) = quick_setup();
        let m = LinearSoftmax::new(784, 10);
        let cfg = quick_cfg(60);
        let agg = Aggregator::HiSafe(
            HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit).with_precision(4),
        );
        let res = train(&m, &tr, &te, &shards, agg, &cfg);
        assert_eq!(res.logs.len(), 60);
        assert!(
            res.final_acc > 0.5,
            "q=4 Hi-SAFE training reached only {}",
            res.final_acc
        );
        // The q = 4 subgroup field (p = 11 for n₁ = 3) is wider than the
        // legacy p = 5, so per-round uplink must exceed the q = 2 run's.
        let q2 = train(
            &m, &tr, &te, &shards,
            Aggregator::HiSafe(HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit)),
            &quick_cfg(1),
        );
        let q4_bits = res.logs[0].uplink_bits_per_user;
        let q2_bits = q2.logs[0].uplink_bits_per_user;
        assert!(q4_bits > q2_bits, "q4 {q4_bits} bits !> q2 {q2_bits} bits");
    }

    #[test]
    fn subgrouped_comm_is_cheaper_per_round() {
        let (tr, te, shards) = quick_setup();
        let m = LinearSoftmax::new(784, 10);
        let cfg = quick_cfg(4);
        let flat = train(
            &m, &tr, &te, &shards,
            Aggregator::HiSafe(HiSafeConfig::flat(6, TiePolicy::OneBit)),
            &cfg,
        );
        let sub = train(
            &m, &tr, &te, &shards,
            Aggregator::HiSafe(HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit)),
            &cfg,
        );
        assert!(
            sub.total_uplink_bits_per_user < flat.total_uplink_bits_per_user,
            "subgrouped {} !< flat {}",
            sub.total_uplink_bits_per_user,
            flat.total_uplink_bits_per_user
        );
    }

    #[test]
    fn dp_noise_degrades_accuracy() {
        let (tr, te, shards) = quick_setup();
        let m = LinearSoftmax::new(784, 10);
        let cfg = quick_cfg(40);
        let clean = train(
            &m, &tr, &te, &shards,
            Aggregator::PlainMv(TiePolicy::OneBit),
            &cfg,
        );
        let noisy = train(
            &m, &tr, &te, &shards,
            Aggregator::DpSign { clip: 1.0, sigma: 8.0 },
            &cfg,
        );
        assert!(
            noisy.final_acc < clean.final_acc,
            "σ=8 DP ({}) should underperform clean MV ({})",
            noisy.final_acc,
            clean.final_acc
        );
    }

    #[test]
    fn masked_sum_matches_plain_mv_trajectory() {
        // Masking computes the exact sum then signs with tie→−1, which is
        // the same vote as plain MV OneBit — trajectories must coincide.
        let (tr, te, shards) = quick_setup();
        let m = LinearSoftmax::new(784, 10);
        let cfg = quick_cfg(8);
        let a = train(&m, &tr, &te, &shards, Aggregator::MaskedSum, &cfg);
        let b = train(
            &m, &tr, &te, &shards,
            Aggregator::PlainMv(TiePolicy::OneBit),
            &cfg,
        );
        assert_eq!(a.final_params, b.final_params);
        // ... but masking ships 32 bits/coordinate uplink
        assert!(a.total_uplink_bits_per_user > b.total_uplink_bits_per_user);
    }

    #[test]
    fn result_json_roundtrips() {
        let (tr, te, shards) = quick_setup();
        let m = LinearSoftmax::new(784, 10);
        let cfg = quick_cfg(3);
        let res = train(&m, &tr, &te, &shards, Aggregator::FedAvg, &cfg);
        let j = res.to_json();
        let text = j.to_string_pretty();
        let back = crate::util::json::parse(&text).unwrap();
        assert_eq!(back.get("aggregator").unwrap().as_str().unwrap(), "fedavg");
        assert_eq!(back.get("rounds").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn multi_federation_on_one_scheduler_matches_sequential_training() {
        // Two secure federations with different (cfg, d is shared via the
        // model here) shapes and seeds, interleaved round-robin on ONE
        // scheduler, must reproduce bit-for-bit the trajectories of
        // training each federation alone.
        let (tr, te, shards) = quick_setup();
        let m = LinearSoftmax::new(784, 10);
        let mut cfg_a = quick_cfg(6);
        cfg_a.seed = 21;
        let mut cfg_b = quick_cfg(4);
        cfg_b.seed = 22;
        let agg_a = Aggregator::HiSafe(HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit));
        let agg_b = Aggregator::HiSafe(HiSafeConfig::flat(6, TiePolicy::TwoBit));

        let solo_a = train(&m, &tr, &te, &shards, agg_a, &cfg_a);
        let solo_b = train(&m, &tr, &te, &shards, agg_b, &cfg_b);

        let sched = AggScheduler::with_threads(2);
        assert_eq!(sched.worker_threads(), 2);
        let specs = vec![
            FedSpec {
                model: &m,
                train_ds: &tr,
                test_ds: &te,
                shards: &shards,
                agg: agg_a,
                cfg: cfg_a,
                qos: QosPolicy::unlimited(),
            },
            FedSpec {
                model: &m,
                train_ds: &tr,
                test_ds: &te,
                shards: &shards,
                agg: agg_b,
                cfg: cfg_b,
                qos: QosPolicy::unlimited(),
            },
        ];
        let multi = train_multi(&sched, &specs);
        assert_eq!(multi.len(), 2);
        assert_eq!(multi[0].final_params, solo_a.final_params);
        assert_eq!(multi[0].final_acc, solo_a.final_acc);
        assert_eq!(multi[1].final_params, solo_b.final_params);
        assert_eq!(multi[1].final_acc, solo_b.final_acc);
        assert_eq!(multi[0].logs.len(), 6);
        assert_eq!(multi[1].logs.len(), 4);
        // k tenants, still one pool's worth of workers.
        assert_eq!(sched.worker_threads(), 2);
    }

    #[test]
    fn qos_throttled_federation_matches_unthrottled_trajectory() {
        // A federation trained under a tight QoS (small queue, modest
        // rate budget) must produce the bit-identical trajectory of an
        // unthrottled run — admission shapes time, not votes — while the
        // run's admission counters surface the throttling that happened.
        let (tr, te, shards) = quick_setup();
        let m = LinearSoftmax::new(784, 10);
        let cfg = quick_cfg(4);
        let agg = Aggregator::HiSafe(HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit));
        let free = train(&m, &tr, &te, &shards, agg, &cfg);

        let sched = AggScheduler::with_threads(1);
        let specs = vec![FedSpec {
            model: &m,
            train_ds: &tr,
            test_ds: &te,
            shards: &shards,
            agg,
            cfg: cfg.clone(),
            // Rounds at 784-dim take well over 1/5000 s of gradient work
            // per round either way; the budget exists to exercise the
            // retry path without slowing the test, not to bite hard.
            qos: QosPolicy::unlimited()
                .with_queue_depth(2)
                .with_rounds_per_sec(5000.0)
                .with_weight(2),
        }];
        let limited = train_multi(&sched, &specs).pop().unwrap();
        assert_eq!(limited.final_params, free.final_params);
        assert_eq!(limited.final_acc, free.final_acc);
        let adm = limited.admission.as_ref().expect("secure run reports admission");
        assert_eq!(adm.admitted_rounds, 4);
        // Throttle waits (if any) must be consistent between the
        // per-round logs and the session counters.
        let waits: u64 = limited.logs.iter().map(|l| l.throttled).sum();
        assert_eq!(adm.throttled, waits);
    }

    #[test]
    fn train_remote_over_loopback_matches_local_training() {
        // One federation trained through a real TCP client/server pair
        // (sharded frontend, loopback) must reproduce the bit-identical
        // trajectory of local training — serving location is an
        // infrastructure decision, like multiplexing. A tight rate
        // budget exercises the wire throttle-retry path; the full
        // random-tenant property lives in rust/tests/service_props.rs.
        use crate::service::{AggFrontend, ServiceClient, ServiceServer};

        let (tr, te, shards) = quick_setup();
        let m = LinearSoftmax::new(784, 10);
        let cfg = quick_cfg(3);
        let agg = Aggregator::HiSafe(HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit));
        let local = train(&m, &tr, &te, &shards, agg, &cfg);

        let server =
            ServiceServer::bind("127.0.0.1:0", AggFrontend::new(2, 1)).expect("bind loopback");
        let addr = server.local_addr().expect("bound addr").to_string();
        let serve = std::thread::spawn(move || server.serve());
        let mut client = ServiceClient::connect(&addr).expect("connect");

        let specs = vec![FedSpec {
            model: &m,
            train_ds: &tr,
            test_ds: &te,
            shards: &shards,
            agg,
            cfg: cfg.clone(),
            // Same rationale as the local QoS test: a generous budget
            // that still exercises the retry loop without stalling.
            qos: QosPolicy::unlimited().with_rounds_per_sec(5000.0).with_queue_depth(2),
        }];
        let remote = train_remote(&mut client, &specs).pop().unwrap();
        assert_eq!(remote.final_params, local.final_params);
        assert_eq!(remote.final_acc, local.final_acc);
        assert_eq!(remote.logs.len(), local.logs.len());
        let adm = remote.admission.as_ref().expect("secure run reports admission");
        assert_eq!(adm.admitted_rounds, 3);
        // Client-side retry counts must agree with the server-side
        // admission counters, round for round.
        let waits: u64 = remote.logs.iter().map(|l| l.throttled).sum();
        assert_eq!(adm.throttled, waits);
        // The remote session was closed by train_remote.
        let fe_stats = client.stats(None).expect("frontend stats");
        assert_eq!(fe_stats.shard_tenants.expect("shards").iter().sum::<usize>(), 0);

        client.shutdown().expect("shutdown acked");
        serve.join().expect("serve thread").expect("clean shutdown");
    }

    #[test]
    fn train_result_json_schema_snapshot() {
        // Pin the exact key sets of the run-log JSON (top level, round,
        // and comm objects) so the fields README/ARCHITECTURE document
        // can't silently drift. Keys are listed sorted (BTreeMap order).
        let (tr, te, shards) = quick_setup();
        let m = LinearSoftmax::new(784, 10);
        let cfg = quick_cfg(2);
        let agg = Aggregator::HiSafe(HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit));
        let res = train(&m, &tr, &te, &shards, agg, &cfg);
        let j = res.to_json();
        let keys = |v: &Json| -> Vec<String> {
            match v {
                Json::Obj(m) => m.keys().cloned().collect(),
                other => panic!("expected object, got {other:?}"),
            }
        };
        assert_eq!(
            keys(&j),
            ["admission", "aggregator", "final_acc", "rounds", "total_uplink_bits_per_user"],
            "TrainResult::to_json top-level schema drifted"
        );
        assert_eq!(
            keys(j.get("admission").unwrap()),
            ["admitted_rounds", "queue_full", "rejected", "throttled"],
            "admission schema drifted"
        );
        let round0 = &j.get("rounds").unwrap().as_arr().unwrap()[0];
        assert_eq!(
            keys(round0),
            [
                "aborted",
                "acc",
                "comm",
                "loss",
                "round",
                "survivors",
                "throttled",
                "uplink_bits_per_user",
            ],
            "round-log schema drifted"
        );
        assert_eq!(
            keys(round0.get("comm").unwrap()),
            [
                "c_t_bits",
                "c_u_bits",
                "downlink_elems",
                "elem_bits",
                "mults",
                "subrounds",
                "uplink_elems_per_user",
                "uplink_elems_total",
                "vote_bits",
            ],
            "per-round comm schema drifted"
        );
        // Baseline aggregators: no admission object, no comm object,
        // but the throttled counter is present (and zero).
        let plain = train(&m, &tr, &te, &shards, Aggregator::PlainMv(TiePolicy::OneBit), &cfg);
        let pj = plain.to_json();
        assert_eq!(
            keys(&pj),
            ["aggregator", "final_acc", "rounds", "total_uplink_bits_per_user"]
        );
        let pr0 = &pj.get("rounds").unwrap().as_arr().unwrap()[0];
        assert_eq!(
            keys(pr0),
            ["aborted", "acc", "loss", "round", "survivors", "throttled", "uplink_bits_per_user"]
        );
        assert_eq!(pr0.get("throttled").unwrap().as_u64(), Some(0));
        // Zero-churn rounds log the full participant count and never abort.
        assert_eq!(pr0.get("survivors").unwrap().as_u64(), Some(6));
        assert_eq!(pr0.get("aborted").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn secure_rounds_carry_measured_comm_stats_into_json() {
        let (tr, te, shards) = quick_setup();
        let m = LinearSoftmax::new(784, 10);
        let cfg = quick_cfg(2);
        let agg = Aggregator::HiSafe(HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit));
        let res = train(&m, &tr, &te, &shards, agg, &cfg);
        for l in &res.logs {
            let comm = l.comm.as_ref().expect("secure rounds log CommStats");
            assert!(comm.mults > 0);
            assert_eq!(comm.c_u_bits(), l.uplink_bits_per_user);
        }
        let j = res.to_json();
        let comm = j
            .get("rounds")
            .and_then(|r| r.as_arr())
            .and_then(|a| a.first())
            .and_then(|r0| r0.get("comm"))
            .expect("per-round comm object in JSON");
        assert!(comm.get("uplink_elems_total").unwrap().as_u64().unwrap() > 0);
        // Non-secure aggregators log no comm object.
        let plain = train(&m, &tr, &te, &shards, Aggregator::PlainMv(TiePolicy::OneBit), &cfg);
        assert!(plain.logs.iter().all(|l| l.comm.is_none()));
    }

    #[test]
    fn churned_training_drops_users_and_aborts_below_threshold() {
        let (tr, te, shards) = quick_setup();
        let m = LinearSoftmax::new(784, 10);
        let agg = Aggregator::HiSafe(HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit));

        // Moderate churn: rounds with dropouts complete over the
        // survivor set (n1 = 3 ⇒ threshold t = 1, so any 2-of-3 group
        // still reconstructs). 20 rounds × 6 draws at p = 0.15 makes
        // both "some round saw a dropout" and "some churned round still
        // completed" sure bets (failure odds < 1e-6 each).
        let mut cfg = quick_cfg(20);
        cfg.churn = 0.15;
        let res = train(&m, &tr, &te, &shards, agg, &cfg);
        assert_eq!(res.logs.len(), 20);
        assert!(
            res.logs.iter().any(|l| l.survivors < 6),
            "0.15 churn over 20×6 draws left every round full-present"
        );
        assert!(
            res.logs.iter().any(|l| !l.aborted && l.survivors < 6),
            "no churned round completed over its survivor set"
        );
        for l in &res.logs {
            assert!(l.survivors <= 6);
            if l.aborted {
                // Aborted rounds never ran the protocol: no comm, no
                // uplink, and the direction was zero (model untouched).
                assert!(l.comm.is_none());
                assert_eq!(l.uplink_bits_per_user, 0);
            } else {
                let comm = l.comm.as_ref().expect("completed secure rounds log comm");
                assert_eq!(comm.c_u_bits(), l.uplink_bits_per_user);
            }
        }
        // Session counters partition the rounds: completions are
        // admitted, below-threshold aborts are typed rejections.
        let adm = res.admission.as_ref().expect("secure run reports admission");
        let completed = res.logs.iter().filter(|l| !l.aborted).count() as u64;
        let aborts = res.logs.iter().filter(|l| l.aborted).count() as u64;
        assert_eq!(adm.admitted_rounds, completed);
        assert_eq!(adm.rejected, aborts);

        // Heavy churn: at p = 0.9 a round survives both group
        // thresholds with probability < 1e-3, so 10 rounds abort at
        // least once with near certainty — typed skips, never panics,
        // and the run still finishes with a full log.
        let mut heavy = quick_cfg(10);
        heavy.churn = 0.9;
        let res = train(&m, &tr, &te, &shards, agg, &heavy);
        assert_eq!(res.logs.len(), 10);
        assert!(
            res.logs.iter().any(|l| l.aborted),
            "0.9 churn should abort at least one of 10 rounds"
        );
        assert!(res.logs.iter().filter(|l| l.aborted).all(|l| l.uplink_bits_per_user == 0));
    }

    #[test]
    fn churned_remote_training_matches_local_churned_training() {
        // The presence mask crosses the wire: a churned remote run must
        // reproduce the local churned trajectory bit-for-bit, including
        // which rounds aborted (the typed below-threshold denial parses
        // back identically to the local error).
        use crate::service::{AggFrontend, ServiceClient, ServiceServer};

        let (tr, te, shards) = quick_setup();
        let m = LinearSoftmax::new(784, 10);
        let mut cfg = quick_cfg(8);
        cfg.churn = 0.2;
        let agg = Aggregator::HiSafe(HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit));
        let local = train(&m, &tr, &te, &shards, agg, &cfg);

        let server =
            ServiceServer::bind("127.0.0.1:0", AggFrontend::new(2, 1)).expect("bind loopback");
        let addr = server.local_addr().expect("bound addr").to_string();
        let serve = std::thread::spawn(move || server.serve());
        let mut client = ServiceClient::connect(&addr).expect("connect");

        let specs = vec![FedSpec {
            model: &m,
            train_ds: &tr,
            test_ds: &te,
            shards: &shards,
            agg,
            cfg: cfg.clone(),
            qos: QosPolicy::unlimited(),
        }];
        let remote = train_remote(&mut client, &specs).pop().unwrap();
        assert_eq!(remote.final_params, local.final_params);
        assert_eq!(remote.final_acc, local.final_acc);
        let fates = |r: &TrainResult| -> Vec<(usize, bool)> {
            r.logs.iter().map(|l| (l.survivors, l.aborted)).collect()
        };
        assert_eq!(fates(&remote), fates(&local));

        client.shutdown().expect("shutdown acked");
        serve.join().expect("serve thread").expect("clean shutdown");
    }
}
