//! Federated-learning harness: datasets, models, the SIGNSGD-MV training
//! loop, and the Theorem-1 convergence bound.
//!
//! The experiments in the paper (Figs. 2–5) train small image classifiers
//! under non-IID federated splits with `N = 100` users and participation
//! fraction `C ∈ [0.12, 0.36]`. MNIST/FMNIST/CIFAR-10 are not downloadable
//! in this environment, so [`data`] provides deterministic synthetic
//! class-conditional analogues (see DESIGN.md §Substitutions) — the
//! properties the figures probe (sign disagreement across non-IID users,
//! tie frequency, subgrouping fidelity) are distributional, not
//! pixel-specific.
//!
//! Two model backends implement [`model::Model`]:
//! * pure-rust [`model::LinearSoftmax`] / [`model::Mlp`] (always available);
//! * the AOT-compiled JAX models via [`crate::runtime::JaxModel`]
//!   (the L2/L1 path — used by `examples/fl_e2e.rs`).

pub mod convergence;
pub mod data;
pub mod model;
pub mod trainer;
