//! Model backends for the FL trainer.
//!
//! [`Model`] abstracts "compute loss+gradient on a batch" so the trainer
//! can run against either the pure-rust implementations here or the
//! AOT-compiled JAX models ([`crate::runtime::JaxModel`]) — and so the
//! integration tests can cross-check the two backends against each other.
//!
//! Both rust models are exact (closed-form softmax cross-entropy
//! gradients), verified against finite differences in the tests.

use crate::fl::data::Dataset;
use crate::util::rng::{Rng, Xoshiro256pp};

/// A differentiable classifier with flat `f32` parameters.
pub trait Model {
    /// Number of parameters `d` (the vote dimension).
    fn dim(&self) -> usize;

    /// Deterministic parameter initialization.
    fn init_params(&self, seed: u64) -> Vec<f32>;

    /// Mean loss and gradient over the given sample indices of `ds`.
    fn loss_grad(&self, params: &[f32], ds: &Dataset, batch: &[usize]) -> (f32, Vec<f32>);

    /// Top-1 accuracy over the whole dataset.
    fn accuracy(&self, params: &[f32], ds: &Dataset) -> f32;

    /// Human-readable name for logs.
    fn name(&self) -> String;
}

// ------------------------------------------------------- linear softmax

/// Multinomial logistic regression: `logits = W x + b`.
/// `d = in_dim·classes + classes`.
#[derive(Debug, Clone, Copy)]
pub struct LinearSoftmax {
    pub in_dim: usize,
    pub n_classes: usize,
}

impl LinearSoftmax {
    pub fn new(in_dim: usize, n_classes: usize) -> Self {
        LinearSoftmax { in_dim, n_classes }
    }

    fn logits(&self, params: &[f32], x: &[f32], out: &mut [f32]) {
        let (w, b) = params.split_at(self.in_dim * self.n_classes);
        for c in 0..self.n_classes {
            // W row-major [class][pixel]
            let row = &w[c * self.in_dim..(c + 1) * self.in_dim];
            let mut z = b[c];
            for (wi, xi) in row.iter().zip(x) {
                z += wi * xi;
            }
            out[c] = z;
        }
    }
}

/// Numerically stable in-place softmax; returns log-sum-exp.
fn softmax_inplace(z: &mut [f32]) -> f32 {
    let m = z.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for v in z.iter_mut() {
        *v = (*v - m).exp();
        sum += *v;
    }
    for v in z.iter_mut() {
        *v /= sum;
    }
    m + sum.ln()
}

impl Model for LinearSoftmax {
    fn dim(&self) -> usize {
        self.in_dim * self.n_classes + self.n_classes
    }

    fn init_params(&self, seed: u64) -> Vec<f32> {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        let scale = (1.0 / self.in_dim as f64).sqrt() as f32;
        (0..self.dim())
            .map(|_| scale * rng.gen_gaussian() as f32)
            .collect()
    }

    fn loss_grad(&self, params: &[f32], ds: &Dataset, batch: &[usize]) -> (f32, Vec<f32>) {
        assert!(!batch.is_empty());
        let k = self.n_classes;
        let mut grad = vec![0.0f32; self.dim()];
        let mut loss = 0.0f32;
        let mut probs = vec![0.0f32; k];
        let inv = 1.0 / batch.len() as f32;
        let (gw, gb) = grad.split_at_mut(self.in_dim * k);
        for &i in batch {
            let x = ds.image(i);
            let y = ds.label(i) as usize;
            self.logits(params, x, &mut probs);
            softmax_inplace(&mut probs);
            loss -= (probs[y].max(1e-12)).ln();
            for c in 0..k {
                let err = (probs[c] - if c == y { 1.0 } else { 0.0 }) * inv;
                gb[c] += err;
                let row = &mut gw[c * self.in_dim..(c + 1) * self.in_dim];
                for (g, &xi) in row.iter_mut().zip(x) {
                    *g += err * xi;
                }
            }
        }
        (loss * inv, grad)
    }

    fn accuracy(&self, params: &[f32], ds: &Dataset) -> f32 {
        let mut z = vec![0.0f32; self.n_classes];
        let mut correct = 0usize;
        for i in 0..ds.len() {
            self.logits(params, ds.image(i), &mut z);
            let pred = z
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            correct += usize::from(pred == ds.label(i) as usize);
        }
        correct as f32 / ds.len() as f32
    }

    fn name(&self) -> String {
        format!("linear_softmax_{}x{}", self.in_dim, self.n_classes)
    }
}

// ------------------------------------------------------------------- MLP

/// One-hidden-layer ReLU MLP: `in → hidden → classes`, softmax CE.
/// Parameter layout: `[W1 (h×in), b1 (h), W2 (k×h), b2 (k)]`.
#[derive(Debug, Clone, Copy)]
pub struct Mlp {
    pub in_dim: usize,
    pub hidden: usize,
    pub n_classes: usize,
}

impl Mlp {
    pub fn new(in_dim: usize, hidden: usize, n_classes: usize) -> Self {
        Mlp { in_dim, hidden, n_classes }
    }

    fn split<'a>(&self, p: &'a [f32]) -> (&'a [f32], &'a [f32], &'a [f32], &'a [f32]) {
        let (w1, rest) = p.split_at(self.hidden * self.in_dim);
        let (b1, rest) = rest.split_at(self.hidden);
        let (w2, b2) = rest.split_at(self.n_classes * self.hidden);
        (w1, b1, w2, b2)
    }

    fn forward(&self, p: &[f32], x: &[f32], hid: &mut [f32], logits: &mut [f32]) {
        let (w1, b1, w2, b2) = self.split(p);
        for h in 0..self.hidden {
            let row = &w1[h * self.in_dim..(h + 1) * self.in_dim];
            let mut z = b1[h];
            for (wi, xi) in row.iter().zip(x) {
                z += wi * xi;
            }
            hid[h] = z.max(0.0); // ReLU
        }
        for c in 0..self.n_classes {
            let row = &w2[c * self.hidden..(c + 1) * self.hidden];
            let mut z = b2[c];
            for (wi, hi) in row.iter().zip(hid.iter()) {
                z += wi * hi;
            }
            logits[c] = z;
        }
    }
}

impl Model for Mlp {
    fn dim(&self) -> usize {
        self.hidden * self.in_dim + self.hidden + self.n_classes * self.hidden + self.n_classes
    }

    fn init_params(&self, seed: u64) -> Vec<f32> {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        let mut p = Vec::with_capacity(self.dim());
        let s1 = (2.0 / self.in_dim as f64).sqrt() as f32; // He init
        for _ in 0..self.hidden * self.in_dim {
            p.push(s1 * rng.gen_gaussian() as f32);
        }
        p.extend(std::iter::repeat(0.0f32).take(self.hidden));
        let s2 = (2.0 / self.hidden as f64).sqrt() as f32;
        for _ in 0..self.n_classes * self.hidden {
            p.push(s2 * rng.gen_gaussian() as f32);
        }
        p.extend(std::iter::repeat(0.0f32).take(self.n_classes));
        p
    }

    fn loss_grad(&self, params: &[f32], ds: &Dataset, batch: &[usize]) -> (f32, Vec<f32>) {
        assert!(!batch.is_empty());
        let (h, k) = (self.hidden, self.n_classes);
        let mut grad = vec![0.0f32; self.dim()];
        let mut hid = vec![0.0f32; h];
        let mut probs = vec![0.0f32; k];
        let mut dhid = vec![0.0f32; h];
        let mut loss = 0.0f32;
        let inv = 1.0 / batch.len() as f32;
        let (w1, _b1, w2, _b2) = self.split(params);
        for &i in batch {
            let x = ds.image(i);
            let y = ds.label(i) as usize;
            self.forward(params, x, &mut hid, &mut probs);
            softmax_inplace(&mut probs);
            loss -= probs[y].max(1e-12).ln();
            // output layer
            let (gw1, grest) = grad.split_at_mut(h * self.in_dim);
            let (gb1, grest) = grest.split_at_mut(h);
            let (gw2, gb2) = grest.split_at_mut(k * h);
            dhid.iter_mut().for_each(|v| *v = 0.0);
            for c in 0..k {
                let err = (probs[c] - if c == y { 1.0 } else { 0.0 }) * inv;
                gb2[c] += err;
                let row = &mut gw2[c * h..(c + 1) * h];
                let wrow = &w2[c * h..(c + 1) * h];
                for j in 0..h {
                    row[j] += err * hid[j];
                    dhid[j] += err * wrow[j];
                }
            }
            // hidden layer (ReLU mask = hid > 0)
            for j in 0..h {
                if hid[j] <= 0.0 {
                    continue;
                }
                gb1[j] += dhid[j];
                let row = &mut gw1[j * self.in_dim..(j + 1) * self.in_dim];
                for (g, &xi) in row.iter_mut().zip(x) {
                    *g += dhid[j] * xi;
                }
            }
            let _ = w1;
        }
        (loss * inv, grad)
    }

    fn accuracy(&self, params: &[f32], ds: &Dataset) -> f32 {
        let mut hid = vec![0.0f32; self.hidden];
        let mut z = vec![0.0f32; self.n_classes];
        let mut correct = 0usize;
        for i in 0..ds.len() {
            self.forward(params, ds.image(i), &mut hid, &mut z);
            let pred = z
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            correct += usize::from(pred == ds.label(i) as usize);
        }
        correct as f32 / ds.len() as f32
    }

    fn name(&self) -> String {
        format!("mlp_{}x{}x{}", self.in_dim, self.hidden, self.n_classes)
    }
}

/// Element-wise sign with 0 mapped to +1 (gradient exactly 0 is a
/// measure-zero event; SIGNSGD implementations conventionally send +1).
pub fn sign_vec(grad: &[f32]) -> Vec<i8> {
    grad.iter().map(|&g| if g < 0.0 { -1i8 } else { 1 }).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::data::{synthetic, DataKind};

    fn tiny_ds() -> Dataset {
        let (tr, _) = synthetic(DataKind::MnistLike, 40, 10, 3);
        tr
    }

    /// Central finite differences on a random subset of coordinates.
    fn check_grad<M: Model>(m: &M, ds: &Dataset) {
        let params = m.init_params(1);
        let batch: Vec<usize> = (0..8).collect();
        let (_, grad) = m.loss_grad(&params, ds, &batch);
        let mut rng = Xoshiro256pp::seed_from_u64(5);
        let eps = 1e-3f32;
        for _ in 0..24 {
            let j = (rng.next_u64() % m.dim() as u64) as usize;
            let mut pp = params.clone();
            pp[j] += eps;
            let (lp, _) = m.loss_grad(&pp, ds, &batch);
            pp[j] -= 2.0 * eps;
            let (lm, _) = m.loss_grad(&pp, ds, &batch);
            let fd = (lp - lm) / (2.0 * eps);
            assert!(
                (fd - grad[j]).abs() < 2e-2 * (1.0 + fd.abs().max(grad[j].abs())),
                "coord {j}: fd {fd} vs analytic {}",
                grad[j]
            );
        }
    }

    #[test]
    fn linear_grad_matches_finite_difference() {
        check_grad(&LinearSoftmax::new(784, 10), &tiny_ds());
    }

    #[test]
    fn mlp_grad_matches_finite_difference() {
        check_grad(&Mlp::new(784, 16, 10), &tiny_ds());
    }

    #[test]
    fn dims() {
        assert_eq!(LinearSoftmax::new(784, 10).dim(), 7850);
        assert_eq!(Mlp::new(784, 32, 10).dim(), 784 * 32 + 32 + 320 + 10);
    }

    #[test]
    fn sgd_reduces_loss() {
        let ds = tiny_ds();
        let m = LinearSoftmax::new(784, 10);
        let mut params = m.init_params(2);
        let batch: Vec<usize> = (0..40).collect();
        let (l0, _) = m.loss_grad(&params, &ds, &batch);
        for _ in 0..50 {
            let (_, g) = m.loss_grad(&params, &ds, &batch);
            for (p, gi) in params.iter_mut().zip(&g) {
                *p -= 0.5 * gi;
            }
        }
        let (l1, _) = m.loss_grad(&params, &ds, &batch);
        assert!(l1 < l0 * 0.5, "loss {l0} → {l1}");
    }

    #[test]
    fn signsgd_reduces_loss_and_learns() {
        // signSGD needs fresh stochastic minibatches (a fixed batch makes
        // the ±lr oscillation overfit it); 600 random-batch steps reach
        // ≈0.9 on the MNIST analogue.
        let (tr, te) = synthetic(DataKind::MnistLike, 4000, 500, 9);
        let m = LinearSoftmax::new(784, 10);
        let mut params = m.init_params(4);
        let a0 = m.accuracy(&params, &te);
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        for _ in 0..600 {
            let batch: Vec<usize> =
                (0..100).map(|_| rng.gen_below(tr.len() as u64) as usize).collect();
            let (_, g) = m.loss_grad(&params, &tr, &batch);
            let s = sign_vec(&g);
            for (p, &si) in params.iter_mut().zip(&s) {
                *p -= 0.002 * si as f32;
            }
        }
        let a1 = m.accuracy(&params, &te);
        assert!(a1 > a0 + 0.5, "accuracy {a0} → {a1}");
    }

    #[test]
    fn sign_vec_semantics() {
        assert_eq!(sign_vec(&[1.5, -0.2, 0.0, -0.0]), vec![1, -1, 1, 1]);
    }

    #[test]
    fn init_is_deterministic() {
        let m = Mlp::new(10, 4, 3);
        assert_eq!(m.init_params(7), m.init_params(7));
        assert_ne!(m.init_params(7), m.init_params(8));
    }
}
