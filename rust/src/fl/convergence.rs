//! Theorem-1 convergence bound for hierarchical SIGNSGD-MV.
//!
//! ```text
//! E[ (1/K) Σ ||g_k||₁ ]² ≤ (1/√N_t) · ( √||L||₁ (f₀ − f* + ½)
//!                                       + (2/√n₁)·||σ||₁
//!                                       + C_hier·e^(−c₂ℓ) )²
//! ```
//! with `c₂ = (2q−1)²/2` and `q > ½` the per-subgroup vote success
//! probability. The module evaluates the bound and exposes the
//! convergence–communication trade-off of Remark 1; tests check the
//! monotonicities the remark claims, and an empirical test estimates `q`
//! from simulation to confirm the Hoeffding direction.

/// Problem constants for the bound.
#[derive(Debug, Clone, Copy)]
pub struct BoundParams {
    /// `||L||₁` — sum of coordinate smoothness constants.
    pub l1_norm_smoothness: f64,
    /// `f₀ − f*`.
    pub init_gap: f64,
    /// `||σ||₁` — sum of per-coordinate stochastic-gradient std bounds.
    pub sigma_l1: f64,
    /// `C_hier = Σ_j E|g_{k,j}|`.
    pub c_hier: f64,
    /// Per-subgroup success probability `q > ½`.
    pub q: f64,
}

/// Evaluate the Theorem-1 right-hand side for `K` iterations with the
/// prescribed step size (`N_t = K²`), users split as `ℓ` groups of `n₁`.
pub fn theorem1_bound(p: &BoundParams, k_iters: usize, n1: usize, ell: usize) -> f64 {
    assert!(p.q > 0.5, "Theorem 1 requires q > 1/2");
    assert!(n1 >= 1 && ell >= 1);
    let n_t = (k_iters as f64) * (k_iters as f64);
    let c2 = (2.0 * p.q - 1.0).powi(2) / 2.0;
    let inner = p.l1_norm_smoothness.sqrt() * (p.init_gap + 0.5)
        + 2.0 / (n1 as f64).sqrt() * p.sigma_l1
        + p.c_hier * (-c2 * ell as f64).exp();
    inner * inner / n_t.sqrt()
}

/// Per-coordinate subgroup vote failure bound `e^(−c₁·n₁)` (Hoeffding,
/// Appendix B) given a per-user success margin `2q_user − 1`.
pub fn subgroup_error_bound(q_user: f64, n1: usize) -> f64 {
    assert!(q_user > 0.5);
    let c1 = (2.0 * q_user - 1.0).powi(2) / 2.0;
    (-c1 * n1 as f64).exp()
}

/// Global majority failure bound `e^(−c₂·ℓ)` (Appendix B).
pub fn global_error_bound(q_subgroup: f64, ell: usize) -> f64 {
    assert!(q_subgroup > 0.5);
    let c2 = (2.0 * q_subgroup - 1.0).powi(2) / 2.0;
    (-c2 * ell as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::{Rng, Xoshiro256pp};

    fn base() -> BoundParams {
        BoundParams {
            l1_norm_smoothness: 10.0,
            init_gap: 5.0,
            sigma_l1: 20.0,
            c_hier: 8.0,
            q: 0.7,
        }
    }

    #[test]
    fn bound_decreases_with_iterations() {
        let p = base();
        let b100 = theorem1_bound(&p, 100, 4, 6);
        let b400 = theorem1_bound(&p, 400, 4, 6);
        assert!(b400 < b100);
        // rate ~ 1/K: quadrupling K should shrink by ~4×
        assert!((b100 / b400 - 4.0).abs() < 0.1);
    }

    #[test]
    fn remark1_tradeoff_monotonicities() {
        let p = base();
        // larger subgroups (n₁↑ at fixed ℓ) → lower variance term → tighter
        assert!(theorem1_bound(&p, 100, 8, 6) < theorem1_bound(&p, 100, 2, 6));
        // more subgroups (ℓ↑ at fixed n₁) → global error suppressed
        assert!(theorem1_bound(&p, 100, 4, 12) < theorem1_bound(&p, 100, 4, 2));
        // higher q → tighter
        let mut p2 = p;
        p2.q = 0.9;
        assert!(theorem1_bound(&p2, 100, 4, 6) < theorem1_bound(&p, 100, 4, 6));
    }

    #[test]
    fn hierarchical_penalty_vanishes_for_moderate_ell() {
        // Remark 1: "exponentially suppressed global error" — with ℓ = 20
        // the hierarchical term must be negligible vs the variance term.
        let p = base();
        let variance_term = 2.0 / 2.0f64.sqrt() * p.sigma_l1;
        let c2 = (2.0 * p.q - 1.0).powi(2) / 2.0;
        let hier_term = p.c_hier * (-c2 * 20.0f64).exp();
        assert!(hier_term < variance_term * 1e-1);
    }

    #[test]
    fn error_bounds_decay() {
        assert!(subgroup_error_bound(0.6, 10) < subgroup_error_bound(0.6, 3));
        assert!(global_error_bound(0.7, 8) < global_error_bound(0.7, 2));
        assert!(global_error_bound(0.7, 8) < 1.0);
    }

    /// Empirical check of the Hoeffding direction: simulate per-user votes
    /// with success prob q_user; measure subgroup majority success; it must
    /// exceed q_user and grow with n₁ (for odd n₁, avoiding tie effects).
    #[test]
    fn empirical_majority_amplification() {
        let mut rng = Xoshiro256pp::seed_from_u64(31);
        let q_user = 0.62;
        let trials = 30_000;
        let success_rate = |n1: usize, rng: &mut Xoshiro256pp| -> f64 {
            let mut ok = 0usize;
            for _ in 0..trials {
                let correct = (0..n1)
                    .filter(|_| rng.gen_f64() < q_user)
                    .count();
                if 2 * correct > n1 {
                    ok += 1;
                }
            }
            ok as f64 / trials as f64
        };
        let s3 = success_rate(3, &mut rng);
        let s9 = success_rate(9, &mut rng);
        assert!(s3 > q_user, "majority of 3 ({s3}) ≤ single user ({q_user})");
        assert!(s9 > s3, "amplification not monotone: {s9} ≤ {s3}");
        // and the failure rate is within the Hoeffding bound
        assert!(1.0 - s9 <= subgroup_error_bound(q_user, 9) + 0.02);
    }
}
