//! Synthetic datasets + federated partitioners.
//!
//! Class-conditional Gaussian image analogues of MNIST / FMNIST / CIFAR-10
//! (DESIGN.md §Substitutions): each class has a fixed prototype drawn from
//! a seeded ChaCha20 stream; samples are `prototype + σ·noise` (zero-mean, clamped to
//! [−1, 1]). "Harder" datasets use higher σ and (for the CIFAR analogue)
//! two blended prototypes per class, which raises sign disagreement across
//! users — the stressor the paper's non-IID experiments exercise.

use crate::util::rng::{ChaCha20Rng, Rng, Xoshiro256pp};

/// A dense classification dataset (row-major `len × dim`).
#[derive(Debug, Clone)]
pub struct Dataset {
    pub dim: usize,
    pub n_classes: usize,
    pub images: Vec<f32>,
    pub labels: Vec<u8>,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// The `i`-th image as a slice.
    pub fn image(&self, i: usize) -> &[f32] {
        &self.images[i * self.dim..(i + 1) * self.dim]
    }

    pub fn label(&self, i: usize) -> u8 {
        self.labels[i]
    }
}

/// Which synthetic analogue to generate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DataKind {
    /// 28×28×1, σ=0.30, scale 0.065 — linear ceiling ≈ 0.92, like MNIST.
    MnistLike,
    /// 28×28×1, σ=0.40, scale 0.075 — ceiling ≈ 0.85, like FMNIST.
    FmnistLike,
    /// 32×32×3, σ=0.50, two prototypes/class — ceiling ≈ 0.48, like CIFAR-10.
    CifarLike,
}

impl DataKind {
    pub fn dim(self) -> usize {
        match self {
            DataKind::MnistLike | DataKind::FmnistLike => 28 * 28,
            DataKind::CifarLike => 32 * 32 * 3,
        }
    }

    pub fn sigma(self) -> f32 {
        match self {
            DataKind::MnistLike => 0.30,
            DataKind::FmnistLike => 0.40,
            DataKind::CifarLike => 0.50,
        }
    }

    /// Prototype amplitude (uniform in `[−scale, scale]` per pixel).
    /// Tuned so a converged linear model lands near the paper's accuracy
    /// bands (MNIST ≈ 0.9+, FMNIST ≈ 0.8, CIFAR ≈ 0.5) — the separation-
    /// to-noise ratio, not the pixel statistics, is what the experiments
    /// exercise.
    pub fn proto_scale(self) -> f32 {
        match self {
            DataKind::MnistLike => 0.065,
            DataKind::FmnistLike => 0.075,
            DataKind::CifarLike => 0.050,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            DataKind::MnistLike => "mnist_like",
            DataKind::FmnistLike => "fmnist_like",
            DataKind::CifarLike => "cifar_like",
        }
    }

    pub fn from_name(s: &str) -> Option<DataKind> {
        match s {
            "mnist_like" | "mnist" => Some(DataKind::MnistLike),
            "fmnist_like" | "fmnist" => Some(DataKind::FmnistLike),
            "cifar_like" | "cifar" | "cifar10" => Some(DataKind::CifarLike),
            _ => None,
        }
    }
}

const N_CLASSES: usize = 10;

/// Generate `(train, test)` splits. Prototypes depend only on
/// `(kind, seed)`; train/test samples use independent noise streams, so
/// generalization is a real signal.
pub fn synthetic(kind: DataKind, n_train: usize, n_test: usize, seed: u64) -> (Dataset, Dataset) {
    let dim = kind.dim();
    let sigma = kind.sigma();
    let mut proto_rng = ChaCha20Rng::seed_from_u64(seed ^ 0x70726f746f); // "proto"
    // Prototypes are ZERO-MEAN (like normalized image data): signed
    // features are essential for sign-based aggregation under non-IID
    // splits — with all-positive pixels, every non-owner of a class votes
    // the same direction on every coordinate and majority voting
    // degenerates (the standard normalize-to-zero-mean preprocessing
    // avoids this on real MNIST too).
    // CIFAR-like blends two prototypes for intra-class multi-modality.
    let n_protos = if kind == DataKind::CifarLike { 2 } else { 1 };
    let s = kind.proto_scale() as f64;
    let protos: Vec<Vec<f32>> = (0..N_CLASSES * n_protos)
        .map(|_| {
            (0..dim)
                .map(|_| (2.0 * s * proto_rng.gen_f64() - s) as f32)
                .collect()
        })
        .collect();
    let gen = |n: usize, stream: u64| -> Dataset {
        let mut rng = Xoshiro256pp::seed_from_u64(seed ^ stream);
        let mut images = Vec::with_capacity(n * dim);
        let mut labels = Vec::with_capacity(n);
        for i in 0..n {
            let class = (i % N_CLASSES) as u8; // balanced classes
            let proto_idx = class as usize * n_protos
                + if n_protos > 1 { (rng.next_u64() % n_protos as u64) as usize } else { 0 };
            let proto = &protos[proto_idx];
            for &p in proto.iter() {
                let v = p + sigma * rng.gen_gaussian() as f32;
                images.push(v.clamp(-1.0, 1.0));
            }
            labels.push(class);
        }
        Dataset { dim, n_classes: N_CLASSES, images, labels }
    };
    (gen(n_train, 0x7472_6169_6e), gen(n_test, 0x7465_7374))
}

/// Federated partitioning schemes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Partition {
    /// Uniformly random equal shards.
    Iid,
    /// The paper's non-IID split ([1]): each user holds samples from
    /// exactly two randomly assigned classes.
    TwoClass,
    /// Dirichlet(α) label-skew (extension; smaller α = more skew).
    Dirichlet(f64),
}

impl Partition {
    pub fn name(self) -> String {
        match self {
            Partition::Iid => "iid".into(),
            Partition::TwoClass => "two_class".into(),
            Partition::Dirichlet(a) => format!("dirichlet_{a}"),
        }
    }

    pub fn from_name(s: &str) -> Option<Partition> {
        match s {
            "iid" => Some(Partition::Iid),
            "two_class" | "non_iid" => Some(Partition::TwoClass),
            _ => s
                .strip_prefix("dirichlet_")
                .and_then(|a| a.parse().ok())
                .map(Partition::Dirichlet),
        }
    }
}

/// Split sample indices of `ds` among `n_users`. Every sample is assigned
/// to exactly one user; users get (near-)equal shard sizes under Iid and
/// TwoClass.
pub fn partition_users(
    ds: &Dataset,
    n_users: usize,
    scheme: Partition,
    seed: u64,
) -> Vec<Vec<usize>> {
    let mut rng = Xoshiro256pp::seed_from_u64(seed ^ 0x7061_7274);
    match scheme {
        Partition::Iid => {
            let mut idx: Vec<usize> = (0..ds.len()).collect();
            rng.shuffle(&mut idx);
            chunk_even(&idx, n_users)
        }
        Partition::TwoClass => {
            // Sort indices by class; split each class pool into equal
            // slices; each user receives one slice from each of two
            // distinct classes (shard-based construction from [1]).
            let mut by_class: Vec<Vec<usize>> = vec![Vec::new(); ds.n_classes];
            for i in 0..ds.len() {
                by_class[ds.label(i) as usize].push(i);
            }
            // user → 2 class slots; exactly 2·n_users slots, spread evenly
            // over classes so each class pool is divided into equal slices.
            let total_slots = 2 * n_users;
            let mut slots: Vec<usize> = (0..total_slots)
                .map(|s| s % ds.n_classes)
                .collect();
            rng.shuffle(&mut slots);
            // fix-up: a user must get two distinct classes
            for u in 0..n_users {
                if slots[2 * u] == slots[2 * u + 1] {
                    // swap with a later slot of a different class
                    for v in (2 * u + 2)..total_slots {
                        if slots[v] != slots[2 * u] {
                            slots.swap(2 * u + 1, v);
                            break;
                        }
                    }
                }
            }
            // count slices per class, then deal out class pools
            let mut slices_needed = vec![0usize; ds.n_classes];
            for &c in &slots {
                slices_needed[c] += 1;
            }
            let mut pools: Vec<std::vec::IntoIter<Vec<usize>>> = by_class
                .into_iter()
                .enumerate()
                .map(|(c, mut pool)| {
                    rng.shuffle(&mut pool);
                    let k = slices_needed[c].max(1);
                    chunk_even(&pool, k).into_iter()
                })
                .collect();
            let mut shards: Vec<Vec<usize>> = vec![Vec::new(); n_users];
            for u in 0..n_users {
                for slot in 0..2 {
                    let c = slots[2 * u + slot];
                    if let Some(slice) = pools[c].next() {
                        shards[u].extend(slice);
                    }
                }
            }
            shards
        }
        Partition::Dirichlet(alpha) => {
            let mut by_class: Vec<Vec<usize>> = vec![Vec::new(); ds.n_classes];
            for i in 0..ds.len() {
                by_class[ds.label(i) as usize].push(i);
            }
            let mut shards: Vec<Vec<usize>> = vec![Vec::new(); n_users];
            for pool in by_class.iter_mut() {
                rng.shuffle(pool);
                // sample user weights ~ Dirichlet(α) via normalized Gammas
                let w: Vec<f64> = (0..n_users).map(|_| gamma_sample(alpha, &mut rng)).collect();
                let total: f64 = w.iter().sum();
                let mut start = 0usize;
                for (u, &wu) in w.iter().enumerate() {
                    let take = if u + 1 == n_users {
                        pool.len() - start
                    } else {
                        ((wu / total) * pool.len() as f64).floor() as usize
                    };
                    let end = (start + take).min(pool.len());
                    shards[u].extend(&pool[start..end]);
                    start = end;
                }
            }
            shards
        }
    }
}

/// Marsaglia–Tsang gamma sampler (shape α > 0, scale 1).
fn gamma_sample<R: Rng>(alpha: f64, rng: &mut R) -> f64 {
    if alpha < 1.0 {
        // boost: Gamma(α) = Gamma(α+1) · U^(1/α)
        let u: f64 = rng.gen_f64().max(f64::MIN_POSITIVE);
        return gamma_sample(alpha + 1.0, rng) * u.powf(1.0 / alpha);
    }
    let d = alpha - 1.0 / 3.0;
    let c = 1.0 / (9.0 * d).sqrt();
    loop {
        let x = rng.gen_gaussian();
        let v = (1.0 + c * x).powi(3);
        if v <= 0.0 {
            continue;
        }
        let u: f64 = rng.gen_f64().max(f64::MIN_POSITIVE);
        if u.ln() < 0.5 * x * x + d - d * v + d * v.ln() {
            return d * v;
        }
    }
}

fn chunk_even(idx: &[usize], k: usize) -> Vec<Vec<usize>> {
    let n = idx.len();
    let base = n / k;
    let extra = n % k;
    let mut out = Vec::with_capacity(k);
    let mut at = 0;
    for i in 0..k {
        let take = base + usize::from(i < extra);
        out.push(idx[at..at + take].to_vec());
        at += take;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_shapes_and_determinism() {
        let (tr, te) = synthetic(DataKind::MnistLike, 500, 100, 42);
        assert_eq!(tr.len(), 500);
        assert_eq!(te.len(), 100);
        assert_eq!(tr.dim, 784);
        let (tr2, _) = synthetic(DataKind::MnistLike, 500, 100, 42);
        assert_eq!(tr.images, tr2.images);
        assert_eq!(tr.labels, tr2.labels);
        // pixels in range
        assert!(tr.images.iter().all(|&v| (-1.0..=1.0).contains(&v)));
        // balanced classes
        let mut counts = [0usize; 10];
        for i in 0..tr.len() {
            counts[tr.label(i) as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c == 50), "{counts:?}");
    }

    #[test]
    fn kinds_have_increasing_difficulty_proxy() {
        assert!(DataKind::MnistLike.sigma() < DataKind::FmnistLike.sigma());
        assert!(DataKind::FmnistLike.sigma() < DataKind::CifarLike.sigma());
        assert_eq!(DataKind::CifarLike.dim(), 3072);
    }

    #[test]
    fn iid_partition_covers_all() {
        let (tr, _) = synthetic(DataKind::MnistLike, 1000, 10, 1);
        let shards = partition_users(&tr, 100, Partition::Iid, 7);
        assert_eq!(shards.len(), 100);
        let mut all: Vec<usize> = shards.concat();
        all.sort_unstable();
        assert_eq!(all, (0..1000).collect::<Vec<_>>());
        assert!(shards.iter().all(|s| s.len() == 10));
    }

    #[test]
    fn two_class_partition_has_at_most_two_labels_per_user() {
        let (tr, _) = synthetic(DataKind::MnistLike, 2000, 10, 1);
        let shards = partition_users(&tr, 100, Partition::TwoClass, 3);
        assert_eq!(shards.len(), 100);
        let mut covered = 0usize;
        for (u, s) in shards.iter().enumerate() {
            assert!(!s.is_empty(), "user {u} got nothing");
            let mut classes: Vec<u8> = s.iter().map(|&i| tr.label(i)).collect();
            classes.sort_unstable();
            classes.dedup();
            assert!(classes.len() <= 2, "user {u} has classes {classes:?}");
            covered += s.len();
        }
        // every sample assigned exactly once
        let mut all: Vec<usize> = shards.concat();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), covered);
    }

    #[test]
    fn dirichlet_partition_skews_with_small_alpha() {
        let (tr, _) = synthetic(DataKind::MnistLike, 2000, 10, 2);
        let skewed = partition_users(&tr, 20, Partition::Dirichlet(0.1), 5);
        let uniformish = partition_users(&tr, 20, Partition::Dirichlet(100.0), 5);
        // measure label entropy per user (lower = more skew)
        let entropy = |shards: &[Vec<usize>]| -> f64 {
            let mut total = 0.0;
            let mut counted = 0usize;
            for s in shards {
                if s.is_empty() {
                    continue;
                }
                let mut c = [0f64; 10];
                for &i in s {
                    c[tr.label(i) as usize] += 1.0;
                }
                let n: f64 = c.iter().sum();
                let h: f64 = c
                    .iter()
                    .filter(|&&x| x > 0.0)
                    .map(|&x| {
                        let p = x / n;
                        -p * p.ln()
                    })
                    .sum();
                total += h;
                counted += 1;
            }
            total / counted as f64
        };
        assert!(
            entropy(&skewed) < entropy(&uniformish),
            "α=0.1 entropy {} !< α=100 entropy {}",
            entropy(&skewed),
            entropy(&uniformish)
        );
    }

    #[test]
    fn gamma_sampler_mean() {
        let mut rng = Xoshiro256pp::seed_from_u64(11);
        for alpha in [0.5f64, 1.0, 3.0] {
            let n = 20_000;
            let mean: f64 =
                (0..n).map(|_| gamma_sample(alpha, &mut rng)).sum::<f64>() / n as f64;
            assert!(
                (mean - alpha).abs() < 0.1 * alpha.max(1.0),
                "α={alpha}: mean {mean}"
            );
        }
    }
}
