//! Analytic communication/latency cost model — regenerates Fig. 6 and
//! Tables IV, VII, VIII, IX.
//!
//! Definitions (Section V-C):
//! * `p₁ = next_prime(n₁)`, `⌈log p₁⌉` bits per field element;
//! * `R` = masked field elements each user uploads = 2 openings per Beaver
//!   multiplication, one multiplication per power `x²..x^deg(F)`
//!   (Algorithm 1's full schedule);
//! * latency = serial subround depth of the power schedule;
//! * `C_u = R·⌈log p₁⌉` bits (per-user uplink per vote coordinate);
//! * `C_T = ℓ·R·⌈log p₁⌉` bits — the paper's "total" is ℓ·C_u (equals the
//!   server's total broadcast volume; true all-user uplink is n·C_u).
//!
//! The model is **derived from the real polynomial and schedule**, not
//! hardcoded — and the integration tests assert the *measured* protocol
//! byte counts ([`crate::metrics::CommStats`]) match this model exactly.
//! Where the paper's own table rows are internally inconsistent with its
//! formulas, [`paper_tables`] embeds the published numbers so the benches
//! can print side-by-side deltas (see EXPERIMENTS.md).

use crate::poly::{MvPolynomial, PowerSchedule, TiePolicy};

/// Cost profile of one subgroup of size `n₁`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GroupCost {
    pub n1: usize,
    pub p1: u64,
    /// `⌈log₂ p₁⌉` — bits per field element.
    pub elem_bits: u32,
    /// Degree of the majority-vote polynomial actually constructed.
    pub deg: usize,
    /// Secure multiplications (Beaver triples per round).
    pub mults: usize,
    /// Masked elements uploaded per user (`R` in the paper's tables).
    pub openings: usize,
    /// Serial subrounds (true schedule depth).
    pub depth: usize,
    /// The paper's latency formula `⌈log p₁⌉ − 1` for comparison.
    pub depth_paper_formula: u32,
    /// Per-user uplink bits per vote coordinate: `C_u = R·⌈log p₁⌉`.
    pub c_u_bits: u64,
}

/// Cost profile of a full configuration `(n, ℓ)`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ConfigCost {
    pub n: usize,
    pub ell: usize,
    pub group: GroupCost,
    /// Paper's total: `C_T = ℓ·C_u` (server broadcast volume).
    pub c_t_bits: u64,
    /// True all-user uplink: `n·C_u`.
    pub c_t_all_users_bits: u64,
}

/// Cost of one subgroup of `n₁` users under `policy`.
/// `sparse = false` reproduces the paper's Algorithm-1 accounting.
pub fn group_cost(n1: usize, policy: TiePolicy, sparse: bool) -> GroupCost {
    group_cost_q(n1, 2, policy, sparse)
}

/// Per-precision subgroup cost: the same accounting over the q-level
/// aggregation polynomial (field `p = next_prime(max(n₁,2)·(q−1))`,
/// degree `p − 1` worth of Fermat indicators). `group_cost_q(n1, 2, …)`
/// is [`group_cost`] exactly — same polynomial, same schedule, same
/// bits — pinned by `q2_precision_cost_is_the_legacy_cost` below.
pub fn group_cost_q(n1: usize, q: u8, policy: TiePolicy, sparse: bool) -> GroupCost {
    let mv = MvPolynomial::build_fermat_q(n1, q, policy);
    let deg = mv.degree();
    let schedule = if sparse {
        PowerSchedule::sparse(&mv.poly.needed_powers())
    } else {
        PowerSchedule::full(deg)
    };
    let p1 = mv.fp.modulus();
    let elem_bits = mv.fp.bits();
    let openings = schedule.openings();
    GroupCost {
        n1,
        p1,
        elem_bits,
        deg,
        mults: schedule.mults(),
        openings,
        depth: schedule.depth(),
        depth_paper_formula: elem_bits.saturating_sub(1),
        c_u_bits: openings as u64 * elem_bits as u64,
    }
}

/// Cost of configuration `(n, ℓ)`.
pub fn config_cost(n: usize, ell: usize, policy: TiePolicy, sparse: bool) -> ConfigCost {
    assert!(ell >= 1 && n % ell == 0, "ℓ = {ell} must divide n = {n}");
    let group = group_cost(n / ell, policy, sparse);
    let c_u = group.c_u_bits;
    ConfigCost {
        n,
        ell,
        group,
        c_t_bits: ell as u64 * c_u,
        c_t_all_users_bits: n as u64 * c_u,
    }
}

/// All divisors of `n` (candidate subgroup counts), ascending.
pub fn divisors(n: usize) -> Vec<usize> {
    let mut d: Vec<usize> = (1..=n).filter(|k| n % k == 0).collect();
    d.sort_unstable();
    d
}

/// Minimum subgroup size. `n₁ = 2` would make the residual-leakage
/// probability `2^−(n₁−1)` (Remark 4) a full 50% per coordinate and the
/// tie-merged vote nearly input-revealing, so — matching the paper's
/// tables, whose smallest subgroup is 3 — the optimizer floors `n₁` at 3.
pub const MIN_SUBGROUP: usize = 3;

/// Find the `ℓ*` minimizing the paper's `C_T` (ties broken toward larger
/// `ℓ`, matching Table VII: lower per-user cost preferred). Subgroups
/// smaller than [`MIN_SUBGROUP`] are excluded (privacy floor).
pub fn optimal_ell(n: usize, policy: TiePolicy, sparse: bool) -> ConfigCost {
    divisors(n)
        .into_iter()
        .filter(|&ell| n / ell >= MIN_SUBGROUP)
        .map(|ell| config_cost(n, ell, policy, sparse))
        .min_by(|a, b| {
            a.c_t_bits
                .cmp(&b.c_t_bits)
                .then(b.ell.cmp(&a.ell)) // prefer larger ℓ on ties (lower C_u)
        })
        .expect("n ≥ 2 has at least ℓ = 1")
}

/// One row of the per-precision communication table (`hisafe tables`):
/// the uplink/downlink bit costs a precision-`q` tenant pays per vote
/// coordinate on a subgroup of `n₁`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PrecisionCost {
    pub q: u8,
    pub group: GroupCost,
    /// Packed wire bits per input coordinate (`⌈log₂(q+1)⌉`).
    pub uplink_wire_bits: u32,
    /// Broadcast bits per vote coordinate (`⌈log₂(2q−1)⌉`; policy-driven
    /// 1/2 bits at `q = 2`).
    pub downlink_bits: u32,
}

/// The per-precision cost column for a subgroup of `n₁` under `policy`:
/// one [`PrecisionCost`] row per supported `q`, ascending.
pub fn precision_costs(n1: usize, policy: TiePolicy, sparse: bool) -> Vec<PrecisionCost> {
    crate::quant::PRECISIONS
        .iter()
        .map(|&q| PrecisionCost {
            q,
            group: group_cost_q(n1, q, policy, sparse),
            uplink_wire_bits: crate::quant::uplink_bits(q),
            downlink_bits: crate::quant::downlink_bits(q, policy),
        })
        .collect()
}

/// Percentage reduction of `x` relative to baseline `b` (paper's
/// parenthesized columns).
pub fn reduction_pct(baseline: u64, x: u64) -> f64 {
    if baseline == 0 {
        return 0.0;
    }
    100.0 * (baseline as f64 - x as f64) / baseline as f64
}

// ------------------------------------------------------------ paper data

/// One published row of Tables VIII/IX: `(n, ℓ, p₁, ⌈log p₁⌉, depth, R,
/// C_T, C_u)` exactly as printed (including internally inconsistent rows —
/// see EXPERIMENTS.md).
#[derive(Debug, Clone, Copy)]
pub struct PaperRow {
    pub n: usize,
    pub ell: usize,
    pub p1: u64,
    pub log_p1: u32,
    pub depth: u32,
    pub r: usize,
    pub c_t: u64,
    pub c_u: u64,
}

/// Tables VIII + IX as published.
pub fn paper_tables() -> Vec<PaperRow> {
    const ROWS: &[(usize, usize, u64, u32, u32, usize, u64, u64)] = &[
        (12, 1, 13, 4, 3, 18, 72, 72),
        (12, 2, 7, 3, 2, 10, 60, 30),
        (12, 3, 5, 3, 2, 6, 54, 18),
        (12, 4, 5, 3, 2, 4, 48, 12),
        (15, 1, 17, 5, 4, 18, 90, 90),
        (15, 3, 7, 3, 2, 8, 48, 24),
        (15, 5, 5, 3, 2, 4, 60, 12),
        (16, 1, 17, 5, 4, 20, 100, 100),
        (16, 2, 11, 4, 3, 14, 112, 56),
        (16, 4, 5, 3, 2, 6, 72, 18),
        (20, 1, 23, 5, 4, 32, 160, 160),
        (20, 2, 11, 4, 3, 16, 128, 64),
        (20, 4, 7, 3, 2, 8, 96, 24),
        (20, 5, 5, 3, 2, 6, 90, 18),
        (24, 1, 29, 5, 4, 40, 200, 200),
        (24, 2, 13, 4, 3, 18, 144, 72),
        (24, 3, 11, 4, 3, 14, 168, 56),
        (24, 4, 7, 3, 2, 10, 120, 30),
        (24, 6, 7, 3, 2, 6, 108, 18),
        (24, 8, 5, 3, 2, 4, 96, 12),
        (28, 1, 29, 5, 4, 40, 200, 200),
        (28, 2, 17, 5, 4, 22, 220, 110),
        (28, 4, 11, 4, 3, 14, 224, 56),
        (28, 7, 5, 3, 2, 6, 126, 18),
        (30, 1, 31, 5, 4, 38, 190, 190),
        (30, 2, 17, 4, 3, 20, 200, 100),
        (30, 3, 11, 4, 3, 16, 192, 64),
        (30, 5, 7, 3, 2, 10, 150, 30),
        (30, 6, 7, 3, 2, 8, 144, 24),
        (30, 10, 5, 3, 2, 4, 120, 12),
        (36, 1, 37, 6, 5, 46, 276, 276),
        (36, 2, 19, 5, 4, 26, 260, 130),
        (36, 3, 13, 4, 3, 18, 216, 72),
        (36, 4, 11, 4, 3, 14, 224, 56),
        (36, 6, 7, 3, 2, 10, 180, 30),
        (36, 9, 5, 3, 2, 6, 162, 18),
        (36, 12, 5, 3, 2, 4, 144, 12),
        (40, 1, 41, 6, 5, 48, 288, 288),
        (40, 2, 23, 5, 4, 32, 320, 160),
        (40, 4, 11, 4, 3, 16, 256, 64),
        (40, 5, 11, 4, 3, 14, 280, 56),
        (40, 8, 7, 3, 2, 8, 192, 24),
        (40, 10, 5, 3, 2, 6, 180, 18),
        (50, 1, 51, 6, 5, 60, 360, 360),
        (50, 2, 29, 5, 4, 34, 340, 170),
        (50, 5, 11, 4, 3, 16, 320, 64),
        (50, 10, 7, 3, 2, 8, 240, 24),
        (60, 1, 61, 6, 5, 72, 432, 432),
        (60, 2, 31, 5, 4, 38, 380, 190),
        (60, 3, 23, 5, 3, 32, 480, 160),
        (60, 5, 13, 4, 3, 18, 360, 72),
        (60, 6, 11, 4, 2, 16, 384, 64),
        (60, 10, 7, 3, 2, 10, 300, 30),
        (60, 12, 7, 3, 2, 8, 288, 24),
        (60, 20, 5, 3, 2, 4, 240, 12),
        (70, 1, 71, 7, 6, 84, 588, 588),
        (70, 2, 37, 6, 5, 44, 528, 264),
        (70, 5, 17, 5, 4, 22, 550, 110),
        (70, 7, 11, 4, 3, 16, 448, 64),
        (70, 10, 11, 4, 3, 14, 560, 56),
        (70, 14, 7, 3, 3, 8, 336, 24),
        (80, 1, 81, 7, 6, 92, 644, 644),
        (80, 2, 41, 6, 5, 48, 576, 288),
        (80, 4, 23, 5, 4, 32, 640, 160),
        (80, 5, 17, 5, 4, 20, 500, 100),
        (80, 8, 11, 4, 3, 16, 512, 64),
        (80, 10, 11, 4, 3, 14, 560, 56),
        (80, 16, 7, 3, 2, 8, 384, 24),
        (80, 20, 5, 3, 2, 6, 360, 18),
        (90, 1, 91, 7, 6, 104, 728, 728),
        (90, 2, 47, 6, 5, 54, 648, 324),
        (90, 3, 31, 5, 4, 38, 570, 190),
        (90, 5, 19, 5, 4, 26, 650, 130),
        (90, 6, 17, 5, 4, 18, 540, 90),
        (90, 9, 11, 4, 3, 16, 576, 64),
        (90, 10, 11, 4, 3, 14, 560, 56),
        (90, 15, 7, 3, 2, 10, 450, 30),
        (90, 18, 7, 3, 2, 8, 432, 24),
        (90, 30, 5, 3, 2, 4, 360, 12),
        (100, 1, 101, 7, 6, 114, 798, 798),
        (100, 2, 51, 6, 5, 60, 720, 360),
        (100, 4, 29, 5, 4, 34, 680, 170),
        (100, 5, 23, 5, 4, 32, 800, 160),
        (100, 10, 11, 4, 3, 16, 640, 64),
        (100, 20, 7, 3, 2, 8, 480, 24),
        (100, 25, 5, 3, 2, 6, 450, 18),
    ];
    ROWS.iter()
        .map(|&(n, ell, p1, log_p1, depth, r, c_t, c_u)| PaperRow {
            n, ell, p1, log_p1, depth, r, c_t, c_u,
        })
        .collect()
}

/// Table VII as published: `(n, ℓ*, n₁, depth, mults("#multiplications"),
/// C_T, C_T_red%, C_u, C_u_red%)`.
pub fn paper_table7() -> Vec<(usize, usize, usize, u32, usize, u64, f64, u64, f64)> {
    vec![
        (24, 8, 3, 2, 4, 96, 52.0, 12, 94.0),
        (36, 12, 3, 2, 4, 144, 47.8, 12, 95.7),
        (60, 20, 3, 2, 4, 240, 44.4, 12, 97.2),
        (90, 30, 3, 2, 4, 360, 50.5, 12, 98.4),
        (100, 25, 4, 2, 6, 450, 43.6, 18, 97.7),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpc::secure_group_vote;
    use crate::prop_assert_eq;
    use crate::protocol::{run_sync, HiSafeConfig};
    use crate::util::prop::forall;

    #[test]
    fn n1_3_matches_paper_exactly() {
        // The headline subgroup (n₁ = 3, p₁ = 5) where our model and every
        // paper table agree: R = 4, depth = 2, C_u = 12.
        let g = group_cost(3, TiePolicy::OneBit, false);
        assert_eq!(g.p1, 5);
        assert_eq!(g.elem_bits, 3);
        assert_eq!(g.deg, 3);
        assert_eq!(g.mults, 2);
        assert_eq!(g.openings, 4);
        assert_eq!(g.depth, 2);
        assert_eq!(g.c_u_bits, 12);
    }

    #[test]
    fn n1_4_matches_paper() {
        // Table VII n=100 row: n₁ = 4, "#multiplications" = 6, C_u = 18.
        let g = group_cost(4, TiePolicy::OneBit, false);
        assert_eq!(g.p1, 5);
        assert_eq!(g.deg, 4);
        assert_eq!(g.openings, 6);
        assert_eq!(g.c_u_bits, 18);
        assert_eq!(g.depth, 2);
    }

    #[test]
    fn table7_star_configs_reproduced() {
        // For each Table VII row, our optimizer must pick a config whose
        // C_u matches the published value, and ℓ* must match where the
        // paper's own table is self-consistent.
        for (n, ell_star, n1, _depth, r, c_t, _ctr, c_u, _cur) in paper_table7() {
            let best = optimal_ell(n, TiePolicy::OneBit, false);
            assert_eq!(best.group.c_u_bits, c_u, "n={n} C_u");
            assert_eq!(best.c_t_bits, c_t, "n={n} C_T");
            assert_eq!(best.ell, ell_star, "n={n} ℓ*");
            assert_eq!(best.group.n1, n1, "n={n} n₁");
            assert_eq!(best.group.openings, r, "n={n} R");
        }
    }

    #[test]
    fn q2_precision_cost_is_the_legacy_cost() {
        // The q = 2 row of the precision table must be the legacy cost
        // model, field-for-field — including the headline n₁ = 3 numbers
        // (p₁ = 5, deg = 3, R = 4, depth = 2, C_u = 12).
        for n1 in 2..=8usize {
            for policy in [TiePolicy::OneBit, TiePolicy::TwoBit] {
                assert_eq!(
                    group_cost_q(n1, 2, policy, false),
                    group_cost(n1, policy, false),
                    "n1={n1} {policy:?}"
                );
            }
        }
        let rows = precision_costs(3, TiePolicy::OneBit, false);
        assert_eq!(rows[0].q, 2);
        assert_eq!(rows[0].group.p1, 5);
        assert_eq!(rows[0].group.deg, 3);
        assert_eq!(rows[0].group.openings, 4);
        assert_eq!(rows[0].group.depth, 2);
        assert_eq!(rows[0].group.c_u_bits, 12);
        assert_eq!(rows[0].uplink_wire_bits, 2);
        assert_eq!(rows[0].downlink_bits, 1);
    }

    #[test]
    fn precision_costs_grow_monotonically() {
        // Higher q → bigger field → strictly more uplink bits; wire
        // widths follow ⌈log₂⌉ exactly.
        for policy in [TiePolicy::OneBit, TiePolicy::TwoBit] {
            let rows = precision_costs(3, policy, false);
            assert_eq!(
                rows.iter().map(|r| r.q).collect::<Vec<_>>(),
                vec![2, 4, 8, 16]
            );
            assert!(rows.windows(2).all(|w| w[0].group.c_u_bits < w[1].group.c_u_bits));
            assert_eq!(
                rows.iter().map(|r| r.uplink_wire_bits).collect::<Vec<_>>(),
                vec![2, 3, 4, 5]
            );
            for r in &rows {
                assert_eq!(r.group.p1, crate::field::next_prime(3 * (r.q as u64 - 1)));
            }
        }
    }

    #[test]
    fn measured_comm_matches_model() {
        // The protocol's byte counters must equal the analytic model —
        // this ties Tables VII–IX to the actual implementation.
        forall("measured ≡ analytic cost", 25, |g| {
            let ell = g.usize_range(1, 4);
            let n1 = g.usize_range(2, 6);
            let n = ell * n1;
            let policy = if g.bool() { TiePolicy::OneBit } else { TiePolicy::TwoBit };
            let cfg = HiSafeConfig { n, ell, intra: policy, inter: TiePolicy::OneBit, sparse: false, precision: 2 };
            let d = g.usize_range(1, 4);
            let signs: Vec<Vec<i8>> = (0..n).map(|_| g.sign_vec(d)).collect();
            let out = run_sync(&signs, cfg, g.u64());
            let model = config_cost(n, ell, policy, false);
            // stats count d coordinates; model is per-coordinate
            prop_assert_eq!(
                out.stats.c_u_bits(),
                model.group.c_u_bits * d as u64,
                "C_u n={n} ell={ell} d={d} {policy:?}"
            );
            prop_assert_eq!(
                out.stats.c_t_paper_bits(),
                model.c_t_bits * d as u64,
                "C_T n={n} ell={ell} d={d}"
            );
            prop_assert_eq!(out.stats.subrounds as usize, model.group.depth);
            prop_assert_eq!(
                out.stats.mults as usize,
                model.group.mults * ell,
                "mults"
            );
            Ok(())
        });
    }

    #[test]
    fn single_group_cost_equals_group_vote_stats() {
        let g = group_cost(6, TiePolicy::OneBit, false);
        let signs: Vec<Vec<i8>> = (0..6).map(|i| vec![if i < 3 { 1i8 } else { -1 }]).collect();
        let out = secure_group_vote(&signs, TiePolicy::OneBit, false, 9);
        assert_eq!(out.stats.c_u_bits(), g.c_u_bits);
        assert_eq!(out.stats.subrounds as usize, g.depth);
    }

    #[test]
    fn headline_reductions_hold() {
        // Abstract claims: per-user reduction > 94% for n ≥ 24; total
        // reduction ≈ 52% at n = 24 — relative to the flat baseline.
        for n in [24usize, 36, 60, 90] {
            let flat = config_cost(n, 1, TiePolicy::OneBit, false);
            let best = optimal_ell(n, TiePolicy::OneBit, false);
            let cu_red = reduction_pct(flat.group.c_u_bits, best.group.c_u_bits);
            assert!(cu_red > 94.0, "n={n}: C_u reduction {cu_red:.1}% ≤ 94%");
        }
        // Paper claims 52.0% total reduction at n=24 against its flat
        // baseline (R=40 ⇒ deg≈21). Our exact construction gives the flat
        // polynomial its true degree (28 for p=29), so the flat baseline is
        // costlier and the measured reduction is *larger* (64.4%) — the
        // paper's figure is a lower bound under our accounting.
        let flat24 = config_cost(24, 1, TiePolicy::OneBit, false);
        let best24 = optimal_ell(24, TiePolicy::OneBit, false);
        let ct_red = reduction_pct(flat24.c_t_bits, best24.c_t_bits);
        assert!(ct_red >= 52.0, "n=24 C_T reduction {ct_red:.1}% < paper's 52%");
    }

    #[test]
    fn per_user_cost_bounded_under_subgrouping() {
        // Fig. 6a claim: with optimal subgrouping the per-user secure
        // multiplication count stays ≤ 6 elements... in our accounting:
        // openings ≤ 6 ⇔ mults ≤ 3 for n₁ ∈ {3, 4}.
        for n in [12usize, 24, 36, 40, 60, 80, 90, 100] {
            let best = optimal_ell(n, TiePolicy::OneBit, false);
            assert!(
                best.group.openings <= 6,
                "n={n}: optimal config has {} openings",
                best.group.openings
            );
            assert!(best.group.depth <= 2, "n={n}: depth {}", best.group.depth);
        }
    }

    #[test]
    fn flat_cost_grows_with_n_subgrouped_constant() {
        // Fig. 6 shape: flat per-user cost grows ~linearly in n; optimal
        // subgrouped cost is constant.
        let flat: Vec<u64> = [12usize, 24, 48, 96]
            .iter()
            .map(|&n| config_cost(n, 1, TiePolicy::OneBit, false).group.c_u_bits)
            .collect();
        assert!(flat.windows(2).all(|w| w[1] > w[0]), "flat not increasing: {flat:?}");
        let sub: Vec<u64> = [12usize, 24, 48, 96]
            .iter()
            .map(|&n| optimal_ell(n, TiePolicy::OneBit, false).group.c_u_bits)
            .collect();
        assert!(sub.iter().all(|&c| c == sub[0]), "subgrouped not constant: {sub:?}");
    }

    #[test]
    fn divisors_basic() {
        assert_eq!(divisors(24), vec![1, 2, 3, 4, 6, 8, 12, 24]);
        assert_eq!(divisors(7), vec![1, 7]);
    }

    #[test]
    fn paper_row_internal_consistency_audit() {
        // Count how many published rows satisfy the paper's own formula
        // C_T = ℓ·R·⌈log p₁⌉ and C_u = R·⌈log p₁⌉. (Several don't — we
        // document rather than reproduce the typos.)
        let rows = paper_tables();
        let mut consistent = 0;
        for r in &rows {
            if r.c_u == (r.r as u64) * r.log_p1 as u64
                && r.c_t == r.ell as u64 * r.c_u
            {
                consistent += 1;
            }
        }
        // The majority of rows must be self-consistent (sanity that we
        // transcribed them correctly).
        assert!(
            consistent * 10 >= rows.len() * 8,
            "only {consistent}/{} rows self-consistent",
            rows.len()
        );
    }

    #[test]
    fn sparse_ablation_never_worse() {
        for n1 in 2..=16usize {
            for policy in [TiePolicy::OneBit, TiePolicy::TwoBit] {
                let full = group_cost(n1, policy, false);
                let sparse = group_cost(n1, policy, true);
                assert!(
                    sparse.c_u_bits <= full.c_u_bits,
                    "n1={n1} {policy:?}: sparse {} > full {}",
                    sparse.c_u_bits,
                    full.c_u_bits
                );
            }
        }
    }
}
