//! Prime-field arithmetic `F_p` — the algebraic substrate of Hi-SAFE.
//!
//! Hi-SAFE evaluates majority-vote polynomials over `F_p` with `p` the
//! smallest prime greater than the (sub)group size, so `p` is tiny
//! (5..101 in the paper's sweeps) but the *vectors* are model-sized
//! (`d ≈ 10^5`). Elements are canonical `u64` in `[0, p)`; products fit in
//! `u64` for any `p < 2^32`, and the hot path uses a precomputed
//! Barrett-style reduction ([`Fp::mul`]) instead of hardware division.
//!
//! Everything here is `no_std`-shaped plain math with no dependencies; it is
//! exercised by exhaustive unit tests (small `p`) and by the in-tree
//! property harness ([`crate::util::prop`]) for field axioms.

use std::fmt;

/// Lane-block width (in `u64` lanes) of the chunked `vec_*` kernels. Eight
/// lanes fill one AVX-512 register (or two AVX2 / four NEON registers); the
/// kernels run `chunks_exact(VEC_LANES)` blocks with a branch-free body and
/// handle the `len % VEC_LANES` tail element-wise with the same arithmetic,
/// so block width is observationally invisible.
pub const VEC_LANES: usize = 8;

/// A prime-field context: the modulus plus precomputed reduction constants.
///
/// `Fp` is cheap to copy (16 bytes) and is passed by value everywhere.
#[derive(Clone, Copy, PartialEq, Eq)]
pub struct Fp {
    /// The prime modulus.
    p: u64,
    /// Barrett constant: `floor((2^64 - 1) / p)` (for p > 1). Equal to
    /// `floor(2^64 / p)` for every odd prime; one less at `p = 2`, which
    /// [`Fp::reduce`]'s error analysis covers.
    barrett: u64,
}

impl fmt::Debug for Fp {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "F_{}", self.p)
    }
}

impl Fp {
    /// Create a field context. Panics if `p` is not prime (this is a
    /// programming error everywhere in Hi-SAFE: moduli come from
    /// [`next_prime`]).
    pub fn new(p: u64) -> Self {
        assert!(is_prime(p), "Fp::new: {p} is not prime");
        assert!(p < (1 << 32), "Fp::new: p must fit in 32 bits, got {p}");
        Fp { p, barrett: if p > 1 { u64::MAX / p } else { 0 } }
    }

    /// The modulus.
    #[inline(always)]
    pub fn modulus(self) -> u64 {
        self.p
    }

    /// Bit length `⌈log2 p⌉` used for field-element wire representation
    /// (the paper's `⌈log p₁⌉`).
    #[inline]
    pub fn bits(self) -> u32 {
        64 - (self.p - 1).leading_zeros().min(63)
    }

    /// Reduce an arbitrary `u64` into `[0, p)`.
    ///
    /// Barrett-style: one multiply-high + one multiply + exactly one
    /// masked correction subtraction, with no data-dependent branch.
    /// Exact for all inputs because
    /// `q = floor(x * floor((2^64-1)/p) / 2^64) ∈ {floor(x/p) - 1, floor(x/p)}`
    /// (the error term is `x·(t+1)/(p·2^64) ≤ x/2^64 < 1` where
    /// `t = (2^64-1) mod p`), so the remainder estimate lands in
    /// `[0, 2p)` and [`Self::csub`] canonicalizes it.
    #[inline(always)]
    pub fn reduce(self, x: u64) -> u64 {
        let q = ((x as u128 * self.barrett as u128) >> 64) as u64;
        let r = x.wrapping_sub(q.wrapping_mul(self.p));
        self.csub(r)
    }

    /// Canonicalize a value known to lie in `[0, 2p)`: subtract `p` iff
    /// `x ≥ p`, as a mask-select instead of a branch. This is the lane
    /// body every chunked kernel compiles down to a compare + masked
    /// subtract, which autovectorizes cleanly.
    #[inline(always)]
    fn csub(self, x: u64) -> u64 {
        debug_assert!(x < 2 * self.p);
        x - (self.p & ((x >= self.p) as u64).wrapping_neg())
    }

    /// Branch-free canonical subtraction: `a - b mod p` for canonical
    /// inputs, adding `p` back iff the raw subtraction borrowed.
    #[inline(always)]
    fn bsub(self, a: u64, b: u64) -> u64 {
        let (d, borrow) = a.overflowing_sub(b);
        d.wrapping_add(self.p & (borrow as u64).wrapping_neg())
    }

    /// Map a signed integer into the canonical representative in `[0, p)`.
    #[inline(always)]
    pub fn from_i64(self, x: i64) -> u64 {
        let m = x.rem_euclid(self.p as i64);
        m as u64
    }

    /// Centered lift: map `[0, p)` to the representative in
    /// `(-p/2, p/2]`. Used to read out vote results (`p-1 ↦ -1`).
    #[inline(always)]
    pub fn lift(self, x: u64) -> i64 {
        debug_assert!(x < self.p);
        if x > self.p / 2 {
            x as i64 - self.p as i64
        } else {
            x as i64
        }
    }

    /// Addition in `F_p`. Inputs must be canonical.
    #[inline(always)]
    pub fn add(self, a: u64, b: u64) -> u64 {
        debug_assert!(a < self.p && b < self.p);
        let s = a + b;
        if s >= self.p {
            s - self.p
        } else {
            s
        }
    }

    /// Subtraction in `F_p`. Inputs must be canonical.
    #[inline(always)]
    pub fn sub(self, a: u64, b: u64) -> u64 {
        debug_assert!(a < self.p && b < self.p);
        if a >= b {
            a - b
        } else {
            a + self.p - b
        }
    }

    /// Negation in `F_p`.
    #[inline(always)]
    pub fn neg(self, a: u64) -> u64 {
        debug_assert!(a < self.p);
        if a == 0 {
            0
        } else {
            self.p - a
        }
    }

    /// Multiplication in `F_p` (Barrett reduction; no division).
    #[inline(always)]
    pub fn mul(self, a: u64, b: u64) -> u64 {
        debug_assert!(a < self.p && b < self.p);
        self.reduce(a * b)
    }

    /// Exponentiation by square-and-multiply.
    pub fn pow(self, mut base: u64, mut exp: u64) -> u64 {
        debug_assert!(base < self.p);
        let mut acc = 1u64 % self.p;
        while exp > 0 {
            if exp & 1 == 1 {
                acc = self.mul(acc, base);
            }
            base = self.mul(base, base);
            exp >>= 1;
        }
        acc
    }

    /// Multiplicative inverse via Fermat's Little Theorem (`a^(p-2)`).
    /// Panics on zero.
    pub fn inv(self, a: u64) -> u64 {
        assert!(a % self.p != 0, "Fp::inv of zero");
        self.pow(a % self.p, self.p - 2)
    }

    /// `sign` of a centered element: `+1`, `0`, or `-1`.
    #[inline]
    pub fn sign_of(self, x: u64) -> i8 {
        let l = self.lift(x);
        if l > 0 {
            1
        } else if l < 0 {
            -1
        } else {
            0
        }
    }

    /// Centered lift as a small vote value — the q-level readout. On the
    /// sign-vote outputs `{0, 1, p−1}` this equals [`Self::sign_of`]; on
    /// a q-level aggregation polynomial's outputs it recovers the level
    /// in `[−(q−1), q−1]` directly. Debug-asserts the lift fits `i8`
    /// (every aggregation polynomial's range does).
    #[inline]
    pub fn level_of(self, x: u64) -> i8 {
        let l = self.lift(x);
        debug_assert!(
            (-(i8::MAX as i64)..=i8::MAX as i64).contains(&l),
            "vote readout {l} outside the i8 level range"
        );
        l as i8
    }

    // ---- vector (model-dimension) operations: the L3 hot path ----
    //
    // Kernel layout (§Perf). Every `vec_*` kernel below follows one
    // SIMD-shaped discipline so the autovectorizer can lower it to lane
    // ops: (1) slice lengths are asserted once up front, (2) the body
    // iterates `chunks_exact(VEC_LANES)` blocks whose fixed width lets
    // the compiler elide every bounds check, (3) the lane body is
    // branch-free — canonicalization is a masked conditional add/sub
    // ([`Self::csub`]/[`Self::bsub`]), never an `if` per element — and
    // (4) each lane pays at most ONE Barrett reduction per kernel:
    // products accumulate raw against the `p < 2^32` headroom
    // (`canonical + (p-1)² < 2^64`) and reduce once, instead of reducing
    // after every term. The `len % VEC_LANES` tail reuses the identical
    // arithmetic element-wise, so block width never changes results.
    // The scalar `add`/`sub`/`mul` ops above stay the readable
    // reference; unit tests pin every kernel to them lane-for-lane.

    /// `dst[i] = (dst[i] + src[i]) mod p` — share aggregation.
    #[inline]
    pub fn vec_add_assign(self, dst: &mut [u64], src: &[u64]) {
        assert_eq!(dst.len(), src.len(), "vec_add_assign: length mismatch");
        let mut d = dst.chunks_exact_mut(VEC_LANES);
        let mut s = src.chunks_exact(VEC_LANES);
        for (dc, sc) in d.by_ref().zip(s.by_ref()) {
            for i in 0..VEC_LANES {
                dc[i] = self.csub(dc[i] + sc[i]);
            }
        }
        for (d, &s) in d.into_remainder().iter_mut().zip(s.remainder()) {
            *d = self.csub(*d + s);
        }
    }

    /// `dst[i] = (dst[i] - src[i]) mod p`.
    #[inline]
    pub fn vec_sub_assign(self, dst: &mut [u64], src: &[u64]) {
        assert_eq!(dst.len(), src.len(), "vec_sub_assign: length mismatch");
        let mut d = dst.chunks_exact_mut(VEC_LANES);
        let mut s = src.chunks_exact(VEC_LANES);
        for (dc, sc) in d.by_ref().zip(s.by_ref()) {
            for i in 0..VEC_LANES {
                dc[i] = self.bsub(dc[i], sc[i]);
            }
        }
        for (d, &s) in d.into_remainder().iter_mut().zip(s.remainder()) {
            *d = self.bsub(*d, s);
        }
    }

    /// Element-wise `dst[i] += a[i]*b[i] mod p` — the Beaver recombination
    /// kernel (`δ·[b] + ε·[a]` terms). One reduction per lane: the raw
    /// sum `dst + a·b < p + (p-1)² < 2^64` for every `p < 2^32`, so the
    /// product accumulates unreduced and Barrett-reduces once.
    #[inline]
    pub fn vec_mul_add_assign(self, dst: &mut [u64], a: &[u64], b: &[u64]) {
        assert_eq!(dst.len(), a.len(), "vec_mul_add_assign: a length mismatch");
        assert_eq!(dst.len(), b.len(), "vec_mul_add_assign: b length mismatch");
        let mut d = dst.chunks_exact_mut(VEC_LANES);
        let mut ac = a.chunks_exact(VEC_LANES);
        let mut bc = b.chunks_exact(VEC_LANES);
        for ((dc, av), bv) in d.by_ref().zip(ac.by_ref()).zip(bc.by_ref()) {
            for i in 0..VEC_LANES {
                dc[i] = self.reduce(dc[i] + av[i] * bv[i]);
            }
        }
        for ((d, &x), &y) in
            d.into_remainder().iter_mut().zip(ac.remainder()).zip(bc.remainder())
        {
            *d = self.reduce(*d + x * y);
        }
    }

    /// Element-wise product `out[i] = a[i]*b[i] mod p` into a
    /// caller-owned buffer — the allocation-free kernel the dealer's
    /// triple loop runs on its reused scratch ([`crate::beaver::Dealer`]).
    #[inline]
    pub fn vec_mul_into(self, out: &mut [u64], a: &[u64], b: &[u64]) {
        assert_eq!(out.len(), a.len(), "vec_mul_into: a length mismatch");
        assert_eq!(out.len(), b.len(), "vec_mul_into: b length mismatch");
        let mut o = out.chunks_exact_mut(VEC_LANES);
        let mut ac = a.chunks_exact(VEC_LANES);
        let mut bc = b.chunks_exact(VEC_LANES);
        for ((oc, av), bv) in o.by_ref().zip(ac.by_ref()).zip(bc.by_ref()) {
            for i in 0..VEC_LANES {
                oc[i] = self.reduce(av[i] * bv[i]);
            }
        }
        for ((o, &x), &y) in
            o.into_remainder().iter_mut().zip(ac.remainder()).zip(bc.remainder())
        {
            *o = self.reduce(x * y);
        }
    }

    /// Element-wise product `out[i] = a[i]*b[i] mod p` (allocating
    /// convenience wrapper over [`Self::vec_mul_into`]).
    #[inline]
    pub fn vec_mul(self, a: &[u64], b: &[u64]) -> Vec<u64> {
        let mut out = vec![0u64; a.len()];
        self.vec_mul_into(&mut out, a, b);
        out
    }

    /// Scalar-vector `dst[i] += k*src[i] mod p`. One reduction per lane
    /// (`dst + k·src < p + (p-1)² < 2^64` for canonical `k`, `src`).
    #[inline]
    pub fn vec_scale_add_assign(self, dst: &mut [u64], k: u64, src: &[u64]) {
        assert_eq!(dst.len(), src.len(), "vec_scale_add_assign: length mismatch");
        if k == 0 {
            return;
        }
        let mut d = dst.chunks_exact_mut(VEC_LANES);
        let mut s = src.chunks_exact(VEC_LANES);
        for (dc, sc) in d.by_ref().zip(s.by_ref()) {
            for i in 0..VEC_LANES {
                dc[i] = self.reduce(dc[i] + k * sc[i]);
            }
        }
        for (d, &s) in d.into_remainder().iter_mut().zip(s.remainder()) {
            *d = self.reduce(*d + k * s);
        }
    }

    /// Reduce every lane of a raw vector into canonical form.
    #[inline]
    pub fn vec_reduce_in_place(self, v: &mut [u64]) {
        let mut c = v.chunks_exact_mut(VEC_LANES);
        for vc in c.by_ref() {
            for i in 0..VEC_LANES {
                vc[i] = self.reduce(vc[i]);
            }
        }
        for x in c.into_remainder().iter_mut() {
            *x = self.reduce(*x);
        }
    }

    /// True when raw (unreduced) accumulation of `terms` products of
    /// canonical elements cannot overflow u64 — the fused fast path used
    /// by the MPC hot loops (§Perf). Every Hi-SAFE field (`p ≤ 131`)
    /// qualifies by ~9 orders of magnitude.
    #[inline]
    pub fn fused_headroom(self, terms: u64) -> bool {
        let p2 = (self.p as u128 - 1) * (self.p as u128 - 1);
        terms as u128 * p2 < u64::MAX as u128
    }

    /// `acc[i] += k·src[i]` WITHOUT reduction (caller guarantees headroom
    /// via [`Self::fused_headroom`] and reduces once at the end).
    #[inline]
    pub fn vec_scale_add_raw(self, acc: &mut [u64], k: u64, src: &[u64]) {
        assert_eq!(acc.len(), src.len(), "vec_scale_add_raw: length mismatch");
        if k == 0 {
            return;
        }
        let mut a = acc.chunks_exact_mut(VEC_LANES);
        let mut s = src.chunks_exact(VEC_LANES);
        for (ac, sc) in a.by_ref().zip(s.by_ref()) {
            for i in 0..VEC_LANES {
                ac[i] += k * sc[i];
            }
        }
        for (a, &s) in a.into_remainder().iter_mut().zip(s.remainder()) {
            *a += k * s;
        }
    }

    /// `acc[i] += src[i]` without reduction (raw accumulation).
    #[inline]
    pub fn vec_add_raw(self, acc: &mut [u64], src: &[u64]) {
        assert_eq!(acc.len(), src.len(), "vec_add_raw: length mismatch");
        let mut a = acc.chunks_exact_mut(VEC_LANES);
        let mut s = src.chunks_exact(VEC_LANES);
        for (ac, sc) in a.by_ref().zip(s.by_ref()) {
            for i in 0..VEC_LANES {
                ac[i] += sc[i];
            }
        }
        for (a, &s) in a.into_remainder().iter_mut().zip(s.remainder()) {
            *a += s;
        }
    }

    /// `acc[i] += (x[i] − a[i] mod p)` with the canonical difference added
    /// RAW (no reduction of the accumulator). This is the batched-engine
    /// kernel for forming `δ = Σᵢ (⟦x⟧ᵢ − ⟦a⟧ᵢ)` in one pass instead of
    /// materializing every party's masked-difference vector: the summand is
    /// `< p`, so `n` accumulations stay far below `u64::MAX` for every
    /// Hi-SAFE field; the caller reduces once per lane at the end. The
    /// canonical difference is the branch-free [`Self::bsub`], so the
    /// per-party accumulation pass has no data-dependent branches at all.
    #[inline]
    pub fn vec_sub_add_raw(self, acc: &mut [u64], x: &[u64], a: &[u64]) {
        assert_eq!(acc.len(), x.len(), "vec_sub_add_raw: x length mismatch");
        assert_eq!(acc.len(), a.len(), "vec_sub_add_raw: a length mismatch");
        let mut av = acc.chunks_exact_mut(VEC_LANES);
        let mut xv = x.chunks_exact(VEC_LANES);
        let mut sv = a.chunks_exact(VEC_LANES);
        for ((ac, xc), sc) in av.by_ref().zip(xv.by_ref()).zip(sv.by_ref()) {
            for i in 0..VEC_LANES {
                ac[i] += self.bsub(xc[i], sc[i]);
            }
        }
        for ((acc, &x), &a) in
            av.into_remainder().iter_mut().zip(xv.remainder()).zip(sv.remainder())
        {
            *acc += self.bsub(x, a);
        }
    }

    /// Beaver recombination kernel (Eq. 2 readout):
    /// `out[i] = c[i] + δ[i]·b[i] + ε[i]·a[i] (+ δ[i]·ε[i])`, canonical.
    ///
    /// §Perf lazy-reduction fast path: with `p ≤ 131` the four raw terms
    /// fit `u64` (`4p² ≪ 2^64`), so each lane accumulates unreduced and
    /// Barrett-reduces ONCE — 3–4× fewer reductions than term-by-term.
    /// Falls back to the always-correct canonical path when
    /// [`Self::fused_headroom`] says a (hypothetical) large field lacks
    /// headroom. Shared by [`crate::mpc::Party::absorb`] and the batched
    /// [`crate::engine::RoundEngine`], which therefore stay bit-identical.
    #[inline]
    pub fn beaver_combine_into(
        self,
        out: &mut [u64],
        c: &[u64],
        a: &[u64],
        b: &[u64],
        delta: &[u64],
        eps: &[u64],
        add_open_product: bool,
    ) {
        let d = out.len();
        assert_eq!(c.len(), d, "beaver_combine_into: c length mismatch");
        assert_eq!(a.len(), d, "beaver_combine_into: a length mismatch");
        assert_eq!(b.len(), d, "beaver_combine_into: b length mismatch");
        assert_eq!(delta.len(), d, "beaver_combine_into: delta length mismatch");
        assert_eq!(eps.len(), d, "beaver_combine_into: eps length mismatch");
        if self.fused_headroom(4) {
            // The δ·ε opening term is a per-CALL choice (party 0 only),
            // monomorphized out of the lane loop — never a per-lane branch.
            if add_open_product {
                self.beaver_fused::<true>(out, c, a, b, delta, eps);
            } else {
                self.beaver_fused::<false>(out, c, a, b, delta, eps);
            }
        } else {
            for j in 0..d {
                let mut v = c[j];
                v = self.add(v, self.mul(delta[j], b[j]));
                v = self.add(v, self.mul(eps[j], a[j]));
                if add_open_product {
                    v = self.add(v, self.mul(delta[j], eps[j]));
                }
                out[j] = v;
            }
        }
    }

    /// The fused Beaver lane loop: `VEC_LANES`-wide blocks, raw 3/4-term
    /// accumulation, one Barrett reduction per lane. Callers checked
    /// `fused_headroom(4)` and equal slice lengths.
    #[inline(always)]
    fn beaver_fused<const OPEN: bool>(
        self,
        out: &mut [u64],
        c: &[u64],
        a: &[u64],
        b: &[u64],
        delta: &[u64],
        eps: &[u64],
    ) {
        let d = out.len();
        let blocks = d - d % VEC_LANES;
        let mut j = 0;
        while j < blocks {
            let o = &mut out[j..j + VEC_LANES];
            let cv = &c[j..j + VEC_LANES];
            let av = &a[j..j + VEC_LANES];
            let bv = &b[j..j + VEC_LANES];
            let dv = &delta[j..j + VEC_LANES];
            let ev = &eps[j..j + VEC_LANES];
            for i in 0..VEC_LANES {
                let mut raw = cv[i] + dv[i] * bv[i] + ev[i] * av[i];
                if OPEN {
                    raw += dv[i] * ev[i];
                }
                o[i] = self.reduce(raw);
            }
            j += VEC_LANES;
        }
        while j < d {
            let mut raw = c[j] + delta[j] * b[j] + eps[j] * a[j];
            if OPEN {
                raw += delta[j] * eps[j];
            }
            out[j] = self.reduce(raw);
            j += 1;
        }
    }

    /// Map a ±1 sign vector (`i8`) into canonical field elements.
    pub fn encode_signs(self, signs: &[i8]) -> Vec<u64> {
        signs.iter().map(|&s| self.from_i64(s as i64)).collect()
    }

    /// Centered lift of a whole vector.
    pub fn lift_vec(self, v: &[u64]) -> Vec<i64> {
        v.iter().map(|&x| self.lift(x)).collect()
    }
}

/// Deterministic Miller–Rabin primality test, exact for all `u64`.
///
/// Witness set {2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37} is sufficient
/// for n < 3.3·10^24 (Sorenson & Webster), hence for all u64.
pub fn is_prime(n: u64) -> bool {
    if n < 2 {
        return false;
    }
    for &q in &[2u64, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37] {
        if n == q {
            return true;
        }
        if n % q == 0 {
            return false;
        }
    }
    let mut d = n - 1;
    let mut r = 0u32;
    while d % 2 == 0 {
        d /= 2;
        r += 1;
    }
    'witness: for &a in &[2u64, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37] {
        let mut x = mod_pow_u64(a % n, d, n);
        if x == 1 || x == n - 1 {
            continue;
        }
        for _ in 0..r - 1 {
            x = mod_mul_u64(x, x, n);
            if x == n - 1 {
                continue 'witness;
            }
        }
        return false;
    }
    true
}

#[inline]
fn mod_mul_u64(a: u64, b: u64, m: u64) -> u64 {
    ((a as u128 * b as u128) % m as u128) as u64
}

fn mod_pow_u64(mut b: u64, mut e: u64, m: u64) -> u64 {
    let mut acc = 1u64 % m;
    b %= m;
    while e > 0 {
        if e & 1 == 1 {
            acc = mod_mul_u64(acc, b, m);
        }
        b = mod_mul_u64(b, b, m);
        e >>= 1;
    }
    acc
}

/// Smallest prime strictly greater than `n` — the paper's modulus rule
/// (`p > n`, Section III-B).
pub fn next_prime(n: u64) -> u64 {
    let mut c = n + 1;
    if c <= 2 {
        return 2;
    }
    if c % 2 == 0 {
        c += 1;
    }
    while !is_prime(c) {
        c += 2;
    }
    c
}

/// The field used for a (sub)group of `n` users: `F_p` with
/// `p = next_prime(n)`, clamped to an odd prime (`n = 1 ⇒ p = 3`; the
/// vote support is only pairwise-distinct mod an odd prime).
pub fn field_for_group(n: usize) -> Fp {
    Fp::new(next_prime(n.max(2) as u64))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn primality_small() {
        let primes: Vec<u64> =
            (0..60).filter(|&n| is_prime(n)).collect();
        assert_eq!(
            primes,
            vec![2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37, 41, 43, 47, 53, 59]
        );
    }

    #[test]
    fn primality_carmichael_and_large() {
        // Carmichael numbers must be rejected.
        for n in [561u64, 1105, 1729, 2465, 2821, 6601, 8911, 41041] {
            assert!(!is_prime(n), "{n} is Carmichael, not prime");
        }
        assert!(is_prime(2_147_483_647)); // 2^31 - 1
        assert!(!is_prime(2_147_483_649));
        assert!(is_prime(4_294_967_291)); // largest prime < 2^32
    }

    #[test]
    fn next_prime_matches_paper_moduli() {
        // Every (n, p) pair appearing in Tables VII–IX.
        for (n, p) in [
            (2u64, 3u64), (3, 5), (4, 5), (5, 7), (6, 7), (7, 11), (8, 11),
            (9, 11), (10, 11), (12, 13), (14, 17), (15, 17), (16, 17),
            (18, 19), (20, 23), (24, 29), (25, 29), (28, 29), (30, 31),
            (35, 37), (36, 37), (40, 41), (45, 47), (50, 53), (60, 61),
            (70, 71), (80, 83), (90, 97), (100, 101),
        ] {
            assert_eq!(next_prime(n), p, "next_prime({n})");
        }
    }

    #[test]
    fn paper_table_nonprime_p1_entries() {
        // Tables VIII/IX list p₁ = 51 for n₁ = 50 and p₁ = 81/91 for
        // n₁ = 80/90 — those are NOT prime; the correct moduli are
        // 53, 83, 97. We document the discrepancy here and use real primes.
        assert!(!is_prime(51));
        assert!(!is_prime(81));
        assert!(!is_prime(91));
        assert_eq!(next_prime(50), 53);
        assert_eq!(next_prime(80), 83);
        assert_eq!(next_prime(90), 97);
        assert_eq!(next_prime(100), 101);
    }

    #[test]
    fn bits_matches_paper_log_column() {
        for (p, bits) in [
            (3u64, 2u32), (5, 3), (7, 3), (11, 4), (13, 4), (17, 5),
            (19, 5), (23, 5), (29, 5), (31, 5), (37, 6), (41, 6), (61, 6),
            (71, 7), (97, 7), (101, 7),
        ] {
            assert_eq!(Fp::new(p).bits(), bits, "bits({p})");
        }
    }

    #[test]
    fn field_axioms_exhaustive_small_p() {
        for p in [2u64, 3, 5, 7, 11, 13] {
            let f = Fp::new(p);
            for a in 0..p {
                for b in 0..p {
                    assert_eq!(f.add(a, b), (a + b) % p);
                    assert_eq!(f.sub(a, b), (a + p - b) % p);
                    assert_eq!(f.mul(a, b), (a * b) % p);
                    // distributivity
                    for c in 0..p {
                        assert_eq!(
                            f.mul(a, f.add(b, c)),
                            f.add(f.mul(a, b), f.mul(a, c))
                        );
                    }
                }
                if a != 0 {
                    assert_eq!(f.mul(a, f.inv(a)), 1 % p, "inv axiom p={p} a={a}");
                }
                assert_eq!(f.add(a, f.neg(a)), 0);
            }
        }
    }

    #[test]
    fn fermat_little_theorem_holds() {
        for p in [3u64, 5, 7, 11, 29, 101] {
            let f = Fp::new(p);
            for a in 1..p {
                assert_eq!(f.pow(a, p - 1), 1, "a^(p-1) != 1 for p={p}, a={a}");
            }
        }
    }

    #[test]
    fn reduce_is_exact_at_extremes() {
        for p in [2u64, 3, 5, 29, 101, 65537, (1 << 31) - 1] {
            let f = Fp::new(p);
            for x in [
                0u64, 1, p - 1, p, p + 1, u64::MAX, u64::MAX - 1,
                (p - 1) * (p - 1),
            ] {
                assert_eq!(f.reduce(x), x % p, "reduce({x}) mod {p}");
            }
        }
    }

    #[test]
    fn lift_roundtrip() {
        let f = Fp::new(29);
        for x in -14i64..=14 {
            assert_eq!(f.lift(f.from_i64(x)), x);
        }
        assert_eq!(f.sign_of(f.from_i64(-3)), -1);
        assert_eq!(f.sign_of(f.from_i64(0)), 0);
        assert_eq!(f.sign_of(f.from_i64(5)), 1);
        // level_of: the q-level readout equals sign_of on sign outputs
        // and recovers multi-bit levels exactly.
        for v in [-1i64, 0, 1] {
            assert_eq!(f.level_of(f.from_i64(v)), f.sign_of(f.from_i64(v)));
        }
        let f31 = Fp::new(31);
        for v in -15i64..=15 {
            assert_eq!(f31.level_of(f31.from_i64(v)), v as i8);
        }
    }

    #[test]
    fn vector_ops_match_scalar() {
        let f = Fp::new(13);
        let a: Vec<u64> = (0..13).collect();
        let b: Vec<u64> = (0..13).rev().collect();
        let mut d = a.clone();
        f.vec_add_assign(&mut d, &b);
        for i in 0..13 {
            assert_eq!(d[i], f.add(a[i], b[i]));
        }
        let mut d = a.clone();
        f.vec_mul_add_assign(&mut d, &a, &b);
        for i in 0..13 {
            assert_eq!(d[i], f.add(a[i], f.mul(a[i], b[i])));
        }
        let mut d = a.clone();
        f.vec_scale_add_assign(&mut d, 7, &b);
        for i in 0..13 {
            assert_eq!(d[i], f.add(a[i], f.mul(7, b[i])));
        }
    }

    #[test]
    fn chunked_kernels_match_scalar_across_tail_lengths() {
        // Lengths straddling the VEC_LANES block boundary exercise both
        // the chunks_exact body and the element-wise tail of every
        // kernel; the scalar ops are the reference.
        for p in [3u64, 29, 101] {
            let f = Fp::new(p);
            for len in
                [0usize, 1, VEC_LANES - 1, VEC_LANES, VEC_LANES + 3, 4 * VEC_LANES + 5]
            {
                let a: Vec<u64> = (0..len as u64).map(|i| (i * 7 + 3) % p).collect();
                let b: Vec<u64> = (0..len as u64).map(|i| (i * 11 + 5) % p).collect();
                let base: Vec<u64> = (0..len as u64).map(|i| (i * 13 + 1) % p).collect();

                let mut got = base.clone();
                f.vec_add_assign(&mut got, &a);
                for i in 0..len {
                    assert_eq!(got[i], f.add(base[i], a[i]), "add p={p} len={len} i={i}");
                }

                let mut got = base.clone();
                f.vec_sub_assign(&mut got, &a);
                for i in 0..len {
                    assert_eq!(got[i], f.sub(base[i], a[i]), "sub p={p} len={len} i={i}");
                }

                let mut got = base.clone();
                f.vec_mul_add_assign(&mut got, &a, &b);
                for i in 0..len {
                    assert_eq!(
                        got[i],
                        f.add(base[i], f.mul(a[i], b[i])),
                        "mul_add p={p} len={len} i={i}"
                    );
                }

                let mut got = vec![0u64; len];
                f.vec_mul_into(&mut got, &a, &b);
                for i in 0..len {
                    assert_eq!(got[i], f.mul(a[i], b[i]), "mul_into p={p} len={len} i={i}");
                }
                assert_eq!(got, f.vec_mul(&a, &b));

                for k in [0u64, 1, p - 1] {
                    let mut got = base.clone();
                    f.vec_scale_add_assign(&mut got, k, &a);
                    for i in 0..len {
                        assert_eq!(
                            got[i],
                            f.add(base[i], f.mul(k, a[i])),
                            "scale_add p={p} k={k} len={len} i={i}"
                        );
                    }
                }

                let mut raw: Vec<u64> =
                    (0..len as u64).map(|i| i.wrapping_mul(0x9e37_79b9_7f4a_7c15)).collect();
                let want: Vec<u64> = raw.iter().map(|&x| x % p).collect();
                f.vec_reduce_in_place(&mut raw);
                assert_eq!(raw, want, "reduce_in_place p={p} len={len}");

                let mut acc = vec![5u64; len];
                f.vec_sub_add_raw(&mut acc, &a, &b);
                for i in 0..len {
                    assert_eq!(
                        acc[i],
                        5 + f.sub(a[i], b[i]),
                        "sub_add_raw p={p} len={len} i={i}"
                    );
                }
            }
        }
    }

    #[test]
    fn vec_sub_add_raw_matches_canonical() {
        let f = Fp::new(29);
        let x: Vec<u64> = (0..29).collect();
        let a: Vec<u64> = (0..29).rev().collect();
        let mut acc = vec![7u64; 29];
        f.vec_sub_add_raw(&mut acc, &x, &a);
        for i in 0..29 {
            assert_eq!(acc[i], 7 + f.sub(x[i], a[i]));
        }
    }

    #[test]
    fn beaver_combine_matches_termwise() {
        for p in [3u64, 5, 29, 101] {
            let f = Fp::new(p);
            let c: Vec<u64> = (0..p).collect();
            let a: Vec<u64> = (0..p).rev().collect();
            let b: Vec<u64> = (0..p).map(|x| (x * 3) % p).collect();
            let delta: Vec<u64> = (0..p).map(|x| (x * 5 + 1) % p).collect();
            let eps: Vec<u64> = (0..p).map(|x| (x * 7 + 2) % p).collect();
            for add_de in [false, true] {
                let mut out = vec![0u64; p as usize];
                f.beaver_combine_into(&mut out, &c, &a, &b, &delta, &eps, add_de);
                for j in 0..p as usize {
                    let mut want = f.add(c[j], f.mul(delta[j], b[j]));
                    want = f.add(want, f.mul(eps[j], a[j]));
                    if add_de {
                        want = f.add(want, f.mul(delta[j], eps[j]));
                    }
                    assert_eq!(out[j], want, "p={p} j={j} add_de={add_de}");
                }
            }
        }
    }

    #[test]
    fn encode_signs_roundtrip() {
        let f = Fp::new(5);
        let signs = vec![1i8, -1, 1, -1, -1];
        let enc = f.encode_signs(&signs);
        assert_eq!(enc, vec![1, 4, 1, 4, 4]);
        let lifted = f.lift_vec(&enc);
        assert_eq!(lifted, vec![1, -1, 1, -1, -1]);
    }
}
