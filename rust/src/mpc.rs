//! Secure evaluation of the majority-vote polynomial (Algorithm 1).
//!
//! This is the *online phase* engine: given per-user ±1 inputs (which are
//! already additive shares of the aggregate `x = Σ xᵢ`), run the Beaver
//! subrounds of the power schedule and produce each user's encrypted share
//! `Enc(xᵢ) = ⟦F(x)⟧ᵢ` (Eq. 3), which the server sums to obtain
//! `F(x) = sign(x)` (Eq. 5) — and *nothing else*.
//!
//! The engine is written as two pure state machines, [`Party`] and
//! [`Server`], exchanging explicit [`UplinkMsg`]/[`BroadcastMsg`] values.
//! [`crate::protocol`] drives them over real channels (threaded
//! coordinator); [`secure_group_vote`] drives them in-process (tests,
//! benches, cost cross-checks). Every message is tallied into
//! [`CommStats`] and the server's view is captured in [`Transcript`] for
//! the Theorem-2 security tests.

use std::sync::Arc;

use crate::beaver::{Dealer, TripleShare};
use crate::field::Fp;
use crate::metrics::CommStats;
use crate::poly::{MvPolynomial, PowerSchedule, TiePolicy};

/// Immutable description of one secure evaluation: field, polynomial
/// coefficients, multiplication schedule, dimensions.
#[derive(Debug)]
pub struct EvalPlan {
    pub fp: Fp,
    pub n_parties: usize,
    /// Vote-vector dimension (model size `d`).
    pub d: usize,
    /// `F` coefficients, index = power.
    pub coeffs: Vec<u64>,
    pub schedule: PowerSchedule,
    /// Tie policy the polynomial encodes (vote downlink width).
    pub policy: TiePolicy,
    /// Quantization precision the polynomial encodes (2 = sign vote).
    pub q: u8,
}

impl EvalPlan {
    /// Plan for a group of `n` users voting on `d` coordinates.
    /// `sparse` selects the sparse power schedule (ablation; the paper's
    /// Algorithm 1 computes every power — `sparse = false`).
    pub fn new(mv: &MvPolynomial, d: usize, sparse: bool) -> EvalPlan {
        let deg = mv.degree();
        let schedule = if sparse {
            PowerSchedule::sparse(&mv.poly.needed_powers())
        } else {
            PowerSchedule::full(deg)
        };
        EvalPlan {
            fp: mv.fp,
            n_parties: mv.n,
            d,
            coeffs: mv.poly.coeffs.clone(),
            schedule,
            policy: mv.policy,
            q: mv.q,
        }
    }

    /// Beaver triples each party needs.
    pub fn triples_needed(&self) -> usize {
        self.schedule.mults()
    }
}

/// Masked openings one party contributes for one multiplication:
/// `d_share = ⟦x^left⟧ᵢ − ⟦a⟧ᵢ`, `e_share = ⟦x^right⟧ᵢ − ⟦b⟧ᵢ`.
#[derive(Debug, Clone)]
pub struct MaskedPair {
    pub mult_idx: usize,
    pub d_share: Vec<u64>,
    pub e_share: Vec<u64>,
}

/// One party's uplink for one subround.
#[derive(Debug, Clone)]
pub struct UplinkMsg {
    pub party: usize,
    pub depth: usize,
    pub pairs: Vec<MaskedPair>,
}

impl UplinkMsg {
    /// Field elements in this message.
    pub fn elems(&self) -> u64 {
        self.pairs.iter().map(|p| (p.d_share.len() + p.e_share.len()) as u64).sum()
    }
}

/// Publicly opened `(δ, ε)` for one multiplication (server → all users).
#[derive(Debug, Clone)]
pub struct Opening {
    pub mult_idx: usize,
    pub delta: Vec<u64>,
    pub eps: Vec<u64>,
}

/// Server broadcast for one subround.
#[derive(Debug, Clone)]
pub struct BroadcastMsg {
    pub depth: usize,
    pub openings: Vec<Opening>,
}

impl BroadcastMsg {
    pub fn elems(&self) -> u64 {
        self.openings.iter().map(|o| (o.delta.len() + o.eps.len()) as u64).sum()
    }
}

/// The server's complete view of one secure evaluation — exactly the
/// leakage Theorem 2 permits the simulator to be given, plus the openings
/// Lemma 2 proves are uniform.
#[derive(Debug, Default, Clone)]
pub struct Transcript {
    /// All `(δ, ε)` openings, in subround order.
    pub openings: Vec<Opening>,
    /// Per-party final shares `⟦F(x)⟧ᵢ` as received.
    pub final_shares: Vec<Vec<u64>>,
    /// The reconstructed output `F(x)` (canonical field elements).
    pub output: Vec<u64>,
}

// ------------------------------------------------------------------ Party

/// User-side state machine for Algorithm 1.
pub struct Party {
    pub id: usize,
    plan: Arc<EvalPlan>,
    /// Triples indexed by multiplication index (schedule order).
    triples: Vec<TripleShare>,
    /// `powers[k] = Some(⟦x^k⟧ᵢ)` once available; `powers[1]` is the input.
    powers: Vec<Option<Vec<u64>>>,
}

impl Party {
    /// `input`: this user's sign vector, field-encoded (`±1 ↦ 1, p−1`).
    pub fn new(
        plan: Arc<EvalPlan>,
        id: usize,
        input: Vec<u64>,
        triples: Vec<TripleShare>,
    ) -> Party {
        assert_eq!(input.len(), plan.d, "input dimension mismatch");
        assert_eq!(
            triples.len(),
            plan.triples_needed(),
            "party {id}: wrong triple count"
        );
        let max_pow = plan.schedule.max_power.max(1);
        let mut powers: Vec<Option<Vec<u64>>> = vec![None; max_pow + 1];
        powers[1] = Some(input);
        Party { id, plan, triples, powers }
    }

    /// Build the uplink message for subround `depth`: for every
    /// multiplication scheduled there, the masked differences of Eq. (2).
    pub fn uplink(&self, depth: usize) -> UplinkMsg {
        let fp = self.plan.fp;
        let mut pairs = Vec::new();
        for (idx, step) in self.plan.schedule.steps.iter().enumerate() {
            if step.depth != depth {
                continue;
            }
            let left = self.powers[step.left]
                .as_ref()
                .unwrap_or_else(|| panic!("party {}: x^{} unavailable", self.id, step.left));
            let right = self.powers[step.right]
                .as_ref()
                .unwrap_or_else(|| panic!("party {}: x^{} unavailable", self.id, step.right));
            let t = &self.triples[idx];
            // single-pass masked differences (no clone-then-sub — §Perf)
            let d_share: Vec<u64> =
                left.iter().zip(&t.a).map(|(&x, &a)| fp.sub(x, a)).collect();
            let e_share: Vec<u64> =
                right.iter().zip(&t.b).map(|(&y, &b)| fp.sub(y, b)).collect();
            pairs.push(MaskedPair { mult_idx: idx, d_share, e_share });
        }
        UplinkMsg { party: self.id, depth, pairs }
    }

    /// Absorb the server broadcast for a subround, deriving the new power
    /// shares: `⟦x^k⟧ᵢ = ⟦c⟧ᵢ + δ·⟦b⟧ᵢ + ε·⟦a⟧ᵢ (+ δ·ε for party 0)`.
    ///
    /// The recombination arithmetic lives in [`Fp::beaver_combine_into`]
    /// (lazy-reduction fast path), shared with the batched
    /// [`crate::engine::RoundEngine`] so both paths stay bit-identical.
    pub fn absorb(&mut self, bcast: &BroadcastMsg) {
        let fp = self.plan.fp;
        for opening in &bcast.openings {
            let step = self.plan.schedule.steps[opening.mult_idx];
            let t = &self.triples[opening.mult_idx];
            let mut share = vec![0u64; self.plan.d];
            // exactly one party (id 0) adds the public δ·ε term
            fp.beaver_combine_into(
                &mut share,
                &t.c,
                &t.a,
                &t.b,
                &opening.delta,
                &opening.eps,
                self.id == 0,
            );
            self.powers[step.target] = Some(share);
        }
    }

    /// Introspection: this party's share of `x^k`, if computed
    /// (used by the walkthrough example and tests).
    pub fn power_share(&self, k: usize) -> Option<&Vec<u64>> {
        self.powers.get(k).and_then(|p| p.as_ref())
    }

    /// After all subrounds: this party's encrypted share
    /// `Enc(xᵢ) = ⟦F(x)⟧ᵢ` (Eq. 3; constant term added by party 0).
    pub fn final_share(&self) -> Vec<u64> {
        let fp = self.plan.fp;
        let mut acc = vec![0u64; self.plan.d];
        // §Perf: Σ_k coeff_k·⟦x^k⟧ has ≤ deg+1 ≤ p terms, each < p², so
        // raw accumulation fits u64 for all Hi-SAFE fields — one reduce
        // per lane at the end.
        let fused = fp.fused_headroom(self.plan.coeffs.len() as u64 + 1);
        for (k, &coeff) in self.plan.coeffs.iter().enumerate() {
            if coeff == 0 {
                continue;
            }
            if k == 0 {
                if self.id == 0 {
                    for a in acc.iter_mut() {
                        *a += coeff; // canonical, raw-safe either way
                    }
                }
                continue;
            }
            let pw = self.powers[k]
                .as_ref()
                .unwrap_or_else(|| panic!("party {}: x^{k} never computed", self.id));
            if fused {
                fp.vec_scale_add_raw(&mut acc, coeff, pw);
            } else {
                fp.vec_scale_add_assign(&mut acc, coeff, pw);
            }
        }
        fp.vec_reduce_in_place(&mut acc);
        acc
    }
}

// ----------------------------------------------------------------- Server

/// Server-side state machine: aggregates masked shares, opens `(δ, ε)`,
/// reconstructs the final vote. Learns nothing but the openings (uniform,
/// Lemma 2) and the output (the permitted leakage).
pub struct Server {
    plan: Arc<EvalPlan>,
    pub transcript: Transcript,
    pub stats: CommStats,
}

impl Server {
    pub fn new(plan: Arc<EvalPlan>) -> Server {
        let elem_bits = plan.fp.bits();
        Server {
            plan,
            transcript: Transcript::default(),
            stats: CommStats { elem_bits, ..Default::default() },
        }
    }

    /// Aggregate one subround's uplinks from all parties into the public
    /// openings, recording transcript + comm stats.
    pub fn aggregate(&mut self, msgs: &[UplinkMsg]) -> BroadcastMsg {
        assert_eq!(msgs.len(), self.plan.n_parties, "missing uplinks");
        let fp = self.plan.fp;
        let depth = msgs[0].depth;
        // openings accumulate per mult index
        let mut acc: std::collections::BTreeMap<usize, (Vec<u64>, Vec<u64>)> =
            std::collections::BTreeMap::new();
        let mut per_user_elems = 0u64;
        // §Perf: raw-accumulate the n canonical shares (sum < n·p ≪ 2^64)
        // and reduce once per lane when forming the openings.
        for m in msgs {
            assert_eq!(m.depth, depth, "subround mismatch");
            per_user_elems = per_user_elems.max(m.elems());
            self.stats.uplink_elems_total += m.elems();
            for pair in &m.pairs {
                let entry = acc.entry(pair.mult_idx).or_insert_with(|| {
                    (vec![0u64; self.plan.d], vec![0u64; self.plan.d])
                });
                fp.vec_add_raw(&mut entry.0, &pair.d_share);
                fp.vec_add_raw(&mut entry.1, &pair.e_share);
            }
        }
        self.stats.uplink_elems_per_user += per_user_elems;
        let openings: Vec<Opening> = acc
            .into_iter()
            .map(|(mult_idx, (mut delta, mut eps))| {
                fp.vec_reduce_in_place(&mut delta);
                fp.vec_reduce_in_place(&mut eps);
                Opening { mult_idx, delta, eps }
            })
            .collect();
        self.transcript.openings.extend(openings.iter().cloned());
        self.stats.mults += openings.len() as u64;
        let bcast = BroadcastMsg { depth, openings };
        self.stats.downlink_elems += bcast.elems();
        self.stats.subrounds += 1;
        bcast
    }

    /// Sum the final shares into `F(x)` (Eq. 5) and record the output.
    pub fn finalize(&mut self, final_shares: Vec<Vec<u64>>) -> Vec<u64> {
        assert_eq!(final_shares.len(), self.plan.n_parties);
        let fp = self.plan.fp;
        let mut out = vec![0u64; self.plan.d];
        for s in &final_shares {
            fp.vec_add_raw(&mut out, s);
        }
        fp.vec_reduce_in_place(&mut out);
        self.transcript.final_shares = final_shares;
        self.transcript.output = out.clone();
        out
    }
}

// -------------------------------------------------------------- one-shot

/// Result of one secure group vote.
#[derive(Debug)]
pub struct GroupVoteOutcome {
    /// Per-coordinate vote in `{−1, 0, +1}` (0 only under
    /// [`TiePolicy::TwoBit`]).
    pub votes: Vec<i8>,
    /// Raw canonical output `F(x)`.
    pub raw: Vec<u64>,
    pub stats: CommStats,
    pub transcript: Transcript,
}

/// Execute a full secure vote for one group, in-process:
/// dealer offline phase → Algorithm-1 subrounds → aggregation (Eq. 5).
///
/// `signs[i]` is user `i`'s ±1 vector; all must share one dimension.
pub fn secure_group_vote(
    signs: &[Vec<i8>],
    policy: TiePolicy,
    sparse: bool,
    seed: u64,
) -> GroupVoteOutcome {
    secure_group_vote_q(signs, 2, policy, sparse, seed)
}

/// q-level generalization of [`secure_group_vote`]: inputs are levels in
/// `L_q` (`signs` keeps its name — at `q = 2` levels ARE signs), the
/// polynomial interpolates the quantized aggregate
/// ([`MvPolynomial::build_fermat_q`]), and the readout lifts the opened
/// output back to a level. `q = 2` is byte-identical to the legacy path
/// (same polynomial, same dealer stream, same transcript).
pub fn secure_group_vote_q(
    signs: &[Vec<i8>],
    q: u8,
    policy: TiePolicy,
    sparse: bool,
    seed: u64,
) -> GroupVoteOutcome {
    let n = signs.len();
    assert!(n >= 1);
    let d = signs[0].len();
    let mv = MvPolynomial::build_fermat_q(n, q, policy);
    let plan = Arc::new(EvalPlan::new(&mv, d, sparse));

    // Offline: dealer distributes triples.
    let mut dealer = Dealer::new(plan.fp, seed);
    let round_triples = dealer.gen_round(d, n, plan.triples_needed());
    secure_group_vote_prepared(signs, plan, round_triples)
}

/// Online-only variant: run Algorithm 1 with **pre-dealt** triples — the
/// paper's offline/online split (Table V). The trainer uses the inline-
/// dealer wrapper above for honest end-to-end accounting; the benches use
/// this to measure the online phase separately.
pub fn secure_group_vote_prepared(
    signs: &[Vec<i8>],
    plan: Arc<EvalPlan>,
    mut round_triples: Vec<Vec<crate::beaver::TripleShare>>,
) -> GroupVoteOutcome {
    let n = signs.len();
    let d = plan.d;
    let fp = plan.fp;
    let policy = plan.policy;
    assert_eq!(round_triples.len(), n, "one triple stash per party");

    // Parties with field-encoded inputs.
    let mut parties: Vec<Party> = signs
        .iter()
        .enumerate()
        .map(|(i, s)| {
            assert_eq!(s.len(), d, "user {i} dimension mismatch");
            Party::new(
                Arc::clone(&plan),
                i,
                fp.encode_signs(s),
                std::mem::take(&mut round_triples[i]),
            )
        })
        .collect();

    let mut server = Server::new(Arc::clone(&plan));

    // Online subrounds.
    for depth in 0..plan.schedule.depth() {
        let ups: Vec<UplinkMsg> = parties.iter().map(|p| p.uplink(depth)).collect();
        let bcast = server.aggregate(&ups);
        for p in parties.iter_mut() {
            p.absorb(&bcast);
        }
    }

    // Final shares → vote.
    let finals: Vec<Vec<u64>> = parties.iter().map(|p| p.final_share()).collect();
    let raw = server.finalize(finals);
    server.stats.vote_bits = crate::quant::downlink_bits(plan.q, policy);
    let votes: Vec<i8> = raw.iter().map(|&v| fp.level_of(v)).collect();

    // move the server's state out (transcripts are MBs at model dim — §Perf)
    let Server { stats, transcript, .. } = server;
    GroupVoteOutcome { votes, raw, stats, transcript }
}

/// Plaintext reference: what SIGNSGD-MV computes without privacy.
pub fn plain_group_vote(signs: &[Vec<i8>], policy: TiePolicy) -> Vec<i8> {
    let d = signs[0].len();
    (0..d)
        .map(|j| {
            let sum: i64 = signs.iter().map(|s| s[j] as i64).sum();
            policy.sign(sum) as i8
        })
        .collect()
}

/// q-level plaintext reference for one group: the quantized aggregate of
/// the column sums ([`crate::quant::quant_aggregate`]). Equals
/// [`plain_group_vote`] at `q = 2`.
pub fn plain_quant_group_vote(signs: &[Vec<i8>], q: u8, policy: TiePolicy) -> Vec<i8> {
    if q == 2 {
        return plain_group_vote(signs, policy);
    }
    let n = signs.len();
    let d = signs[0].len();
    (0..d)
        .map(|j| {
            let sum: i64 = signs.iter().map(|s| s[j] as i64).sum();
            crate::quant::quant_aggregate(sum, n, q, policy) as i8
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert_eq;
    use crate::util::prop::forall;

    #[test]
    fn secure_vote_equals_plain_vote_property() {
        forall("secure vote ≡ plaintext MV", 60, |g| {
            let n = g.usize_range(1, 12);
            let d = g.usize_range(1, 24);
            let policy = if g.bool() { TiePolicy::OneBit } else { TiePolicy::TwoBit };
            let sparse = g.bool();
            let signs: Vec<Vec<i8>> = (0..n).map(|_| g.sign_vec(d)).collect();
            let out = secure_group_vote(&signs, policy, sparse, g.u64());
            let want = plain_group_vote(&signs, policy);
            prop_assert_eq!(out.votes, want, "n={n} d={d} {policy:?} sparse={sparse}");
            Ok(())
        });
    }

    #[test]
    fn appendix_a_example_n3() {
        // x₁=1, x₂=−1, x₃=1 → vote +1 on every coordinate.
        let signs = vec![vec![1i8], vec![-1], vec![1]];
        let out = secure_group_vote(&signs, TiePolicy::OneBit, false, 99);
        assert_eq!(out.votes, vec![1]);
        assert_eq!(out.raw, vec![1]); // F(x) = 1 in F_5
        // two subrounds (x², x³), 2 mults, 4 openings → per-user uplink
        // = 4 elements/coordinate, matching the paper's R = 4.
        assert_eq!(out.stats.subrounds, 2);
        assert_eq!(out.stats.mults, 2);
        assert_eq!(out.stats.uplink_elems_per_user, 4);
        assert_eq!(out.stats.elem_bits, 3);
        assert_eq!(out.stats.c_u_bits(), 12); // Table VIII n₁=3: C_u = 12
    }

    #[test]
    fn all_sign_patterns_n_le_4_exhaustive() {
        // Exhaustive over every sign assignment for n ≤ 4, d = 1.
        for n in 1..=4usize {
            for policy in [TiePolicy::OneBit, TiePolicy::TwoBit] {
                for pattern in 0..(1u32 << n) {
                    let signs: Vec<Vec<i8>> = (0..n)
                        .map(|i| vec![if pattern >> i & 1 == 1 { 1i8 } else { -1 }])
                        .collect();
                    let out = secure_group_vote(&signs, policy, false, pattern as u64);
                    let want = plain_group_vote(&signs, policy);
                    assert_eq!(out.votes, want, "n={n} {policy:?} pattern={pattern:b}");
                }
            }
        }
    }

    #[test]
    fn transcript_records_all_openings() {
        let signs: Vec<Vec<i8>> = (0..6).map(|i| vec![if i % 2 == 0 { 1i8 } else { -1 }; 4]).collect();
        let out = secure_group_vote(&signs, TiePolicy::OneBit, false, 5);
        // n=6 → p=7, deg 6 → 5 mults
        assert_eq!(out.transcript.openings.len(), 5);
        assert_eq!(out.transcript.final_shares.len(), 6);
        assert_eq!(out.transcript.output, out.raw);
        for o in &out.transcript.openings {
            assert_eq!(o.delta.len(), 4);
            assert_eq!(o.eps.len(), 4);
        }
    }

    #[test]
    fn sparse_schedule_fewer_openings_for_odd_n() {
        let signs: Vec<Vec<i8>> = (0..5).map(|_| vec![1i8; 2]).collect();
        let full = secure_group_vote(&signs, TiePolicy::OneBit, false, 1);
        let sparse = secure_group_vote(&signs, TiePolicy::OneBit, true, 1);
        assert_eq!(full.votes, sparse.votes);
        // n=5: F needs {3,5} → sparse chain {2,3,5} wait: 5 = 1+4 needs 4;
        // chain {2,3,4,5}\{unneeded}: actual counted below.
        assert!(sparse.stats.mults <= full.stats.mults);
        assert!(sparse.stats.uplink_elems_per_user <= full.stats.uplink_elems_per_user);
    }

    #[test]
    fn stats_scale_with_dimension() {
        let signs: Vec<Vec<i8>> = (0..3).map(|_| vec![1i8; 10]).collect();
        let out = secure_group_vote(&signs, TiePolicy::OneBit, false, 3);
        // per-user: 2 mults × 2 openings × 10 coords = 40 elements
        assert_eq!(out.stats.uplink_elems_per_user, 40);
        assert_eq!(out.stats.uplink_elems_total, 120);
    }

    #[test]
    fn degenerate_single_user() {
        // n=1 clamps to p=3 (odd prime needed): the "vote" is the user's
        // own sign — identity function, zero multiplications.
        let out = secure_group_vote(&[vec![1i8, -1]], TiePolicy::OneBit, false, 0);
        assert_eq!(out.votes, vec![1, -1]);
        assert_eq!(out.stats.mults, 0);
    }

    #[test]
    fn linear_polynomial_no_subrounds() {
        // n=2 TwoBit: F = 2x (mod 3) — degree 1, zero multiplications.
        let signs = vec![vec![1i8, 1, -1], vec![-1i8, 1, -1]];
        let out = secure_group_vote(&signs, TiePolicy::TwoBit, false, 8);
        assert_eq!(out.stats.subrounds, 0);
        assert_eq!(out.stats.mults, 0);
        assert_eq!(out.stats.uplink_elems_per_user, 0);
        assert_eq!(out.votes, vec![0, 1, -1]);
    }
}
