//! Batched secure-aggregation engine — the round-amortized hot path.
//!
//! [`crate::mpc`] models Algorithm 1 faithfully as message-passing state
//! machines: every multiplication materializes per-party masked-pair
//! vectors, every subround allocates uplink/broadcast messages, and every
//! FL round rebuilds the polynomial, the plan, and a fresh dealer. That is
//! the right shape for protocol tests and the threaded coordinator, but it
//! wastes most of its time on allocation and message plumbing when the
//! same server drives thousands of aggregation rounds over a model-sized
//! `d` (the ROADMAP "heavy traffic" regime).
//!
//! [`RoundEngine`] executes the *same arithmetic* (share-for-share: it
//! reuses [`Fp::beaver_combine_into`] and the schedule from
//! [`EvalPlan`]) with a throughput-oriented layout:
//!
//! * **Amortized setup** — polynomial, power schedule and [`EvalPlan`] are
//!   built once per engine, not once per round.
//! * **Pre-provisioned triple pool** — one streaming [`Dealer`] per
//!   subgroup fills per-party [`TripleStore`]s; rounds consume with
//!   [`TripleStore::take_many`] (one bounds check per round) and the pool
//!   refills in configurable round batches, so offline cost amortizes and
//!   memory stays bounded.
//! * **Structure-of-arrays chunking** — all `d` coordinates stream through
//!   cache-sized lane chunks; openings `δ, ε` are accumulated directly
//!   from the share matrix ([`Fp::vec_sub_add_raw`], raw with one final
//!   reduction) instead of materializing each party's masked-difference
//!   vectors, and no per-message allocation happens on the round path.
//! * **Parallel party-share computation** — at model-sized `d` the
//!   coordinate range splits across `std::thread::scope` workers (each
//!   owning a disjoint span of every party's shares), bit-identical to the
//!   sequential path because the protocol is coordinate-local.
//!
//! `rust/tests/engine_props.rs` asserts the engine's votes are identical
//! to [`crate::mpc::plain_group_vote`] / [`crate::mpc::secure_group_vote`]
//! across random `n`, `d`, tie policies and chunk sizes; the
//! `mpc_mult_throughput` bench measures the batched-vs-per-call speedup.

use std::sync::Arc;

use crate::beaver::{Dealer, TripleShare, TripleStore};
use crate::field::Fp;
use crate::metrics::CommStats;
use crate::mpc::EvalPlan;
use crate::poly::MvPolynomial;
use crate::protocol::{inter_group_vote, partition, HiSafeConfig};

/// Lane-chunk size (u64 lanes). With `max_power + 1` power rows per party
/// and `n₁ ≤ 6` in every optimal configuration, one chunk's working set
/// stays well inside L2.
const DEFAULT_CHUNK: usize = 2048;

/// Minimum model dimension before span threading pays for spawn cost.
const PAR_MIN_D: usize = 8192;

/// Cap on span workers (beyond this, memory bandwidth dominates).
const MAX_THREADS: usize = 8;

/// Outcome of one engine round — the trainer-facing subset of
/// [`crate::protocol::RoundOutcome`] (no transcripts: the engine never
/// materializes server views; use the mpc path for security tests).
#[derive(Debug)]
pub struct EngineOutcome {
    /// Global vote per coordinate (`{−1,+1}`, or 0 under inter TwoBit).
    pub global_vote: Vec<i8>,
    /// Subgroup votes `s_j` (the Theorem-2 leakage).
    pub subgroup_votes: Vec<Vec<i8>>,
    /// Analytic communication counters — equal, field element for field
    /// element, to the measured counters of the message-passing path.
    pub stats: CommStats,
}

/// Reusable, round-amortized Hi-SAFE aggregation engine for one fixed
/// `(HiSafeConfig, d)` workload.
pub struct RoundEngine {
    cfg: HiSafeConfig,
    d: usize,
    plan: Arc<EvalPlan>,
    /// One streaming dealer per subgroup (seeds mirror `run_sync`'s
    /// per-group seed derivation so subgroups stay independent).
    dealers: Vec<Dealer>,
    /// `pools[group][party]` — pre-provisioned Beaver triples.
    pools: Vec<Vec<TripleStore>>,
    /// Rounds of triples generated per refill.
    batch_rounds: usize,
    chunk: usize,
    /// Rounds executed so far.
    pub rounds_run: u64,
}

impl RoundEngine {
    /// Build an engine for `cfg` over `d`-coordinate votes. `seed` drives
    /// all offline randomness (triple generation), one independent stream
    /// per subgroup.
    pub fn new(cfg: HiSafeConfig, d: usize, seed: u64) -> RoundEngine {
        let n1 = cfg.n1();
        let mv = MvPolynomial::build_fermat(n1, cfg.intra);
        let plan = Arc::new(EvalPlan::new(&mv, d, cfg.sparse));
        let dealers: Vec<Dealer> = (0..cfg.ell)
            .map(|g| {
                Dealer::new(plan.fp, seed ^ (g as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15))
            })
            .collect();
        let pools: Vec<Vec<TripleStore>> = (0..cfg.ell)
            .map(|_| (0..n1).map(|_| TripleStore::new(Vec::new())).collect())
            .collect();
        RoundEngine {
            cfg,
            d,
            plan,
            dealers,
            pools,
            batch_rounds: 1,
            chunk: DEFAULT_CHUNK,
            rounds_run: 0,
        }
    }

    /// Override the SoA lane-chunk size (tests sweep this to prove chunk
    /// invariance; benches tune it).
    pub fn with_chunk(mut self, chunk: usize) -> RoundEngine {
        assert!(chunk >= 1, "chunk must be ≥ 1");
        self.chunk = chunk;
        self
    }

    /// Refill the triple pool `rounds` rounds at a time (default 1).
    pub fn with_batch_rounds(mut self, rounds: usize) -> RoundEngine {
        assert!(rounds >= 1, "batch must be ≥ 1");
        self.batch_rounds = rounds;
        self
    }

    /// The evaluation plan the engine executes (schedule, coefficients).
    pub fn plan(&self) -> &EvalPlan {
        &self.plan
    }

    /// Rounds' worth of triples currently pooled (min across groups).
    pub fn provisioned_rounds(&self) -> usize {
        let mults = self.plan.triples_needed();
        if mults == 0 {
            return usize::MAX;
        }
        self.pools
            .iter()
            .map(|g| g[0].remaining() / mults)
            .min()
            .unwrap_or(0)
    }

    /// Explicitly pre-provision `rounds` rounds of triples now — benches
    /// use this to move the offline phase out of the measured loop (the
    /// paper's offline/online split, Table V).
    pub fn provision(&mut self, rounds: usize) {
        let mults = self.plan.triples_needed();
        if mults == 0 {
            return;
        }
        let n1 = self.cfg.n1();
        let d = self.d;
        for (dealer, pool) in self.dealers.iter_mut().zip(self.pools.iter_mut()) {
            deal_group_rounds(dealer, pool, d, n1, mults, rounds);
        }
    }

    /// Top up any group whose pool cannot cover one round.
    fn ensure_provisioned(&mut self) {
        let mults = self.plan.triples_needed();
        if mults == 0 {
            return;
        }
        let n1 = self.cfg.n1();
        let d = self.d;
        let batch = self.batch_rounds;
        for (dealer, pool) in self.dealers.iter_mut().zip(self.pools.iter_mut()) {
            if pool[0].remaining() >= mults {
                continue;
            }
            deal_group_rounds(dealer, pool, d, n1, mults, batch);
        }
    }

    /// Execute one Hi-SAFE aggregation round. `signs[i]` is user `i`'s ±1
    /// sign-gradient vector; users are partitioned into subgroups exactly
    /// like [`crate::protocol::run_sync`].
    pub fn run_round(&mut self, signs: &[Vec<i8>]) -> EngineOutcome {
        assert_eq!(signs.len(), self.cfg.n, "need exactly n sign vectors");
        for (i, s) in signs.iter().enumerate() {
            assert_eq!(s.len(), self.d, "user {i} dimension mismatch");
        }
        self.ensure_provisioned();

        let fp = self.plan.fp;
        let d = self.d;
        let chunk = self.chunk;
        let groups = partition(self.cfg.n, self.cfg.ell);
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let threads = if d >= PAR_MIN_D && cores > 1 { cores.min(MAX_THREADS) } else { 1 };

        let plan = Arc::clone(&self.plan);
        let mut subgroup_votes = Vec::with_capacity(groups.len());
        for (g, members) in groups.iter().enumerate() {
            let stores = &mut self.pools[g];
            subgroup_votes.push(eval_group(
                fp, &plan, members, signs, stores, d, chunk, threads,
            ));
        }
        let global_vote = inter_group_vote(&subgroup_votes, self.cfg.inter);

        // Comm accounting, identical to the measured per-message counters:
        // 2 openings (δ-share, ε-share) × d lanes per multiplication per
        // user uplink; the server broadcasts the same volume once per group.
        let mults = plan.triples_needed() as u64;
        let ell = self.cfg.ell as u64;
        let n1 = self.cfg.n1() as u64;
        let per_mult_elems = 2 * d as u64;
        let stats = CommStats {
            uplink_elems_total: ell * n1 * mults * per_mult_elems,
            uplink_elems_per_user: mults * per_mult_elems,
            downlink_elems: ell * mults * per_mult_elems,
            elem_bits: fp.bits(),
            subrounds: plan.schedule.depth() as u64,
            mults: ell * mults,
            vote_bits: self.cfg.inter.downlink_bits(),
        };

        self.rounds_run += 1;
        EngineOutcome { global_vote, subgroup_votes, stats }
    }
}

/// Deal `rounds` rounds of triples for one subgroup into its per-party
/// pools — the single dealing path shared by explicit provisioning and
/// the lazy run_round refill.
fn deal_group_rounds(
    dealer: &mut Dealer,
    pool: &mut [TripleStore],
    d: usize,
    n1: usize,
    mults: usize,
    rounds: usize,
) {
    for _ in 0..rounds {
        let round = dealer.gen_round(d, n1, mults);
        for (party, fresh) in round.into_iter().enumerate() {
            pool[party].refill(fresh);
        }
    }
}

/// One subgroup's secure vote over its full coordinate range, consuming
/// this round's triples from `stores` and splitting the range across span
/// workers when profitable.
fn eval_group(
    fp: Fp,
    plan: &Arc<EvalPlan>,
    members: &[usize],
    signs: &[Vec<i8>],
    stores: &mut [TripleStore],
    d: usize,
    chunk: usize,
    threads: usize,
) -> Vec<i8> {
    let mults = plan.triples_needed();
    let group_signs: Vec<&[i8]> = members.iter().map(|&u| signs[u].as_slice()).collect();
    let triples: Vec<&[TripleShare]> =
        stores.iter_mut().map(|s| s.take_many(mults)).collect();
    let mut votes = vec![0i8; d];
    if threads > 1 {
        let span = d.div_ceil(threads);
        std::thread::scope(|sc| {
            let group_signs = &group_signs;
            let triples = &triples;
            let plan: &EvalPlan = plan;
            for (si, vspan) in votes.chunks_mut(span).enumerate() {
                sc.spawn(move || {
                    eval_span(fp, plan, group_signs, triples, vspan, si * span, chunk)
                });
            }
        });
    } else {
        eval_span(fp, plan, &group_signs, &triples, &mut votes, 0, chunk);
    }
    votes
}

/// Evaluate the majority-vote polynomial over the coordinate span
/// `[base, base + votes.len())` in SoA lane chunks. Pure function of its
/// inputs — spans never overlap, so span workers are deterministic.
fn eval_span(
    fp: Fp,
    plan: &EvalPlan,
    group_signs: &[&[i8]],
    triples: &[&[TripleShare]],
    votes: &mut [i8],
    base: usize,
    chunk: usize,
) {
    let n1 = group_signs.len();
    let steps = &plan.schedule.steps;
    let coeffs = &plan.coeffs;
    let max_pow = plan.schedule.max_power.max(1);
    // §Perf: same raw-accumulation headroom rule as Party::final_share.
    let fused_final = fp.fused_headroom(coeffs.len() as u64 + 1);

    // pow[k][party] — this span's share of x^k, one lane chunk at a time.
    let mut pow: Vec<Vec<Vec<u64>>> = vec![vec![vec![0u64; chunk]; n1]; max_pow + 1];
    let mut delta = vec![0u64; chunk];
    let mut eps = vec![0u64; chunk];
    let mut fin = vec![0u64; chunk];
    let mut out = vec![0u64; chunk];

    let span = votes.len();
    let mut j0 = 0usize;
    while j0 < span {
        let c = chunk.min(span - j0);
        let lo = base + j0;
        let hi = lo + c;

        // 1. field-encode the ±1 inputs: each user's sign vector IS its
        //    additive share of the aggregate (no input-sharing round).
        for (pi, s) in group_signs.iter().enumerate() {
            for (lane, &sv) in pow[1][pi][..c].iter_mut().zip(&s[lo..hi]) {
                *lane = fp.from_i64(sv as i64);
            }
        }

        // 2. power schedule. Steps are dependency-ordered (operands always
        //    have strictly lower depth), so one sequential pass is exact.
        for (mi, step) in steps.iter().enumerate() {
            // openings: δ = Σᵢ (⟦x^l⟧ᵢ − ⟦a⟧ᵢ), ε likewise — accumulated
            // raw straight off the share matrix, reduced once per lane.
            delta[..c].fill(0);
            eps[..c].fill(0);
            for pi in 0..n1 {
                let t = &triples[pi][mi];
                fp.vec_sub_add_raw(&mut delta[..c], &pow[step.left][pi][..c], &t.a[lo..hi]);
                fp.vec_sub_add_raw(&mut eps[..c], &pow[step.right][pi][..c], &t.b[lo..hi]);
            }
            fp.vec_reduce_in_place(&mut delta[..c]);
            fp.vec_reduce_in_place(&mut eps[..c]);
            // recombination: party 0 adds the public δ·ε term.
            for pi in 0..n1 {
                let t = &triples[pi][mi];
                fp.beaver_combine_into(
                    &mut pow[step.target][pi][..c],
                    &t.c[lo..hi],
                    &t.a[lo..hi],
                    &t.b[lo..hi],
                    &delta[..c],
                    &eps[..c],
                    pi == 0,
                );
            }
        }

        // 3. final shares Σ_k coeff_k·⟦x^k⟧ᵢ (+ c₀ for party 0), summed
        //    into F(x) = sign(x) per lane (Eq. 5).
        out[..c].fill(0);
        for pi in 0..n1 {
            fin[..c].fill(0);
            if pi == 0 && coeffs.first().copied().unwrap_or(0) != 0 {
                fin[..c].fill(coeffs[0]);
            }
            for (k, &coeff) in coeffs.iter().enumerate().skip(1) {
                if coeff == 0 {
                    continue;
                }
                if fused_final {
                    fp.vec_scale_add_raw(&mut fin[..c], coeff, &pow[k][pi][..c]);
                } else {
                    fp.vec_scale_add_assign(&mut fin[..c], coeff, &pow[k][pi][..c]);
                }
            }
            fp.vec_reduce_in_place(&mut fin[..c]);
            fp.vec_add_raw(&mut out[..c], &fin[..c]);
        }
        fp.vec_reduce_in_place(&mut out[..c]);
        for (v, &x) in votes[j0..j0 + c].iter_mut().zip(&out[..c]) {
            *v = fp.sign_of(x);
        }
        j0 += c;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpc::{plain_group_vote, secure_group_vote};
    use crate::poly::TiePolicy;
    use crate::protocol::{plain_hierarchical_vote, run_sync};
    use crate::util::rng::{Rng, Xoshiro256pp};

    fn rand_signs(n: usize, d: usize, seed: u64) -> Vec<Vec<i8>> {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        (0..n).map(|_| (0..d).map(|_| rng.gen_sign()).collect()).collect()
    }

    #[test]
    fn flat_engine_equals_plain_and_secure() {
        for n in [1usize, 2, 3, 4, 6, 9] {
            for policy in [TiePolicy::OneBit, TiePolicy::TwoBit] {
                let d = 17;
                let signs = rand_signs(n, d, n as u64 * 31 + 7);
                let cfg = HiSafeConfig::flat(n, policy);
                let mut engine = RoundEngine::new(cfg, d, 5);
                let got = engine.run_round(&signs);
                let plain = plain_group_vote(&signs, policy);
                assert_eq!(got.global_vote, plain, "n={n} {policy:?} vs plain");
                let secure = secure_group_vote(&signs, policy, false, 5);
                assert_eq!(got.global_vote, secure.votes, "n={n} {policy:?} vs mpc");
            }
        }
    }

    #[test]
    fn hierarchical_engine_equals_plain_hierarchy() {
        let cfg = HiSafeConfig::hierarchical(12, 4, TiePolicy::TwoBit);
        let signs = rand_signs(12, 9, 3);
        let mut engine = RoundEngine::new(cfg, 9, 11);
        let got = engine.run_round(&signs);
        assert_eq!(got.global_vote, plain_hierarchical_vote(&signs, cfg));
        assert_eq!(got.subgroup_votes.len(), 4);
    }

    #[test]
    fn chunk_size_is_observationally_invisible() {
        let cfg = HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit);
        let signs = rand_signs(6, 23, 9);
        let baseline = RoundEngine::new(cfg, 23, 4).run_round(&signs).global_vote;
        for chunk in [1usize, 3, 8, 64] {
            let got = RoundEngine::new(cfg, 23, 4)
                .with_chunk(chunk)
                .run_round(&signs)
                .global_vote;
            assert_eq!(got, baseline, "chunk={chunk}");
        }
    }

    #[test]
    fn pool_amortizes_across_rounds() {
        let cfg = HiSafeConfig::flat(3, TiePolicy::OneBit);
        let mut engine = RoundEngine::new(cfg, 8, 2).with_batch_rounds(4);
        assert_eq!(engine.provisioned_rounds(), 0);
        for r in 0..6u64 {
            let signs = rand_signs(3, 8, 100 + r);
            let got = engine.run_round(&signs);
            assert_eq!(
                got.global_vote,
                plain_group_vote(&signs, TiePolicy::OneBit),
                "round {r}"
            );
        }
        assert_eq!(engine.rounds_run, 6);
        // 6 rounds over batches of 4 → 8 rounds dealt, 2 still pooled
        assert_eq!(engine.provisioned_rounds(), 2);
    }

    #[test]
    fn explicit_provision_feeds_rounds() {
        let cfg = HiSafeConfig::hierarchical(8, 2, TiePolicy::OneBit);
        let mut engine = RoundEngine::new(cfg, 4, 13);
        engine.provision(3);
        assert_eq!(engine.provisioned_rounds(), 3);
        let signs = rand_signs(8, 4, 21);
        let got = engine.run_round(&signs);
        assert_eq!(got.global_vote, plain_hierarchical_vote(&signs, cfg));
        assert_eq!(engine.provisioned_rounds(), 2);
    }

    #[test]
    fn stats_match_message_passing_path() {
        let cfg = HiSafeConfig::hierarchical(12, 4, TiePolicy::OneBit);
        let signs = rand_signs(12, 5, 17);
        let mut engine = RoundEngine::new(cfg, 5, 23);
        let got = engine.run_round(&signs);
        let reference = run_sync(&signs, cfg, 23);
        assert_eq!(got.stats.c_u_bits(), reference.stats.c_u_bits());
        assert_eq!(got.stats.c_t_bits(), reference.stats.c_t_bits());
        assert_eq!(got.stats.c_t_paper_bits(), reference.stats.c_t_paper_bits());
        assert_eq!(got.stats.subrounds, reference.stats.subrounds);
        assert_eq!(got.stats.mults, reference.stats.mults);
        assert_eq!(got.stats.vote_bits, reference.stats.vote_bits);
    }

    #[test]
    fn span_parallel_path_matches_plain_at_large_d() {
        // d above PAR_MIN_D exercises the scoped-thread span split on
        // multi-core hosts (and the sequential path on single-core ones —
        // both must produce the same votes).
        let d = PAR_MIN_D + 137;
        let cfg = HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit);
        let signs = rand_signs(6, d, 41);
        let got = RoundEngine::new(cfg, d, 19).run_round(&signs);
        assert_eq!(got.global_vote, plain_hierarchical_vote(&signs, cfg));
    }

    #[test]
    fn sparse_schedule_supported() {
        let cfg = HiSafeConfig { sparse: true, ..HiSafeConfig::flat(5, TiePolicy::OneBit) };
        let signs = rand_signs(5, 6, 29);
        let got = RoundEngine::new(cfg, 6, 1).run_round(&signs);
        assert_eq!(got.global_vote, plain_group_vote(&signs, TiePolicy::OneBit));
    }
}
