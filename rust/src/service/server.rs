//! A std-only TCP transport for the wire protocol: [`ServiceServer`]
//! (newline-delimited JSON frames over loopback TCP, one thread per
//! connection, all connections multiplexed onto one [`AggFrontend`])
//! and the matching blocking [`ServiceClient`].
//!
//! This is deliberately the simplest transport that makes the service
//! layer *real*: two OS processes can run a genuine client/server
//! aggregation round today (`hisafe serve` + `hisafe sweep --remote`),
//! and the protocol work — versioning, lossless encodings, typed
//! backpressure — lives in [`super::proto`] where any future transport
//! (HTTP, UDS, shared memory) reuses it unchanged.
//!
//! **Framing.** One compact JSON document per line, in both directions.
//! Compact encodings are newline-free by construction (strings escape
//! `\n`), so `read_line` is a complete framer. A line that fails to
//! decode is answered with a typed `Rejected` reply carrying the parse
//! error — a garbage client cannot crash the server.
//!
//! **Concurrency.** The frontend sits behind one mutex: requests from
//! concurrent connections serialize. That is the right first shape —
//! the engine work *behind* the frontend is already parallel (shards'
//! worker pools and dealing planes), and a round's mutex hold time is
//! the online-phase latency the `sched_remote` bench measures. The
//! mutex is the documented scaling boundary a future PR can split
//! per-shard.
//!
//! **Shutdown.** A [`Request::Shutdown`] acks, then stops the accept
//! loop (waking it with a loopback self-connection), and
//! [`ServiceServer::serve`] returns cleanly — the CI smoke test drives
//! exactly this path and asserts the process exits 0.

use std::io::{self, BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::engine::{AdmissionError, QosPolicy};
use crate::protocol::HiSafeConfig;
use crate::util::json::{parse, Json};

use super::frontend::AggFrontend;
use super::proto::{AdmissionReply, ProtoError, Request, Response, StatsReply, VoteReply};

/// Everything a service call can fail with, client-side.
#[derive(Debug)]
pub enum ServiceError {
    /// The transport failed (connect, read, write, peer hung up).
    Io(io::Error),
    /// The peer sent bytes the protocol layer rejects.
    Proto(ProtoError),
    /// The service answered with typed backpressure. `Throttled` is
    /// retryable (see [`ServiceClient::run_round_admitted`]); the rest
    /// are not.
    Denied(AdmissionError),
    /// The reply decoded fine but wasn't the kind this call expects.
    Unexpected(String),
}

impl std::fmt::Display for ServiceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServiceError::Io(e) => write!(f, "service transport error: {e}"),
            ServiceError::Proto(e) => write!(f, "{e}"),
            ServiceError::Denied(e) => write!(f, "service denied request: {e}"),
            ServiceError::Unexpected(msg) => write!(f, "unexpected reply: {msg}"),
        }
    }
}

impl std::error::Error for ServiceError {}

impl From<io::Error> for ServiceError {
    fn from(e: io::Error) -> ServiceError {
        ServiceError::Io(e)
    }
}

impl From<ProtoError> for ServiceError {
    fn from(e: ProtoError) -> ServiceError {
        ServiceError::Proto(e)
    }
}

/// The TCP service: a bound listener plus the shared [`AggFrontend`]
/// every connection talks to.
pub struct ServiceServer {
    listener: TcpListener,
    frontend: Arc<Mutex<AggFrontend>>,
    stop: Arc<AtomicBool>,
}

impl ServiceServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) over a
    /// fresh frontend. The listener is live when this returns — clients
    /// may connect before [`serve`](ServiceServer::serve) is called and
    /// their connections queue in the accept backlog.
    pub fn bind(addr: &str, frontend: AggFrontend) -> io::Result<ServiceServer> {
        Ok(ServiceServer {
            listener: TcpListener::bind(addr)?,
            frontend: Arc::new(Mutex::new(frontend)),
            stop: Arc::new(AtomicBool::new(false)),
        })
    }

    /// The bound address (resolves the actual port after `":0"` binds).
    pub fn local_addr(&self) -> io::Result<SocketAddr> {
        self.listener.local_addr()
    }

    /// Accept-and-dispatch until a client sends `Shutdown`. Each
    /// connection gets its own thread; per-connection threads outlive
    /// `serve` only as long as their sockets do (they exit on EOF /
    /// error), and the shared frontend stays alive through its `Arc`
    /// until the last one finishes.
    pub fn serve(self) -> io::Result<()> {
        let addr = self.listener.local_addr()?;
        loop {
            let stream = match self.listener.accept() {
                Ok((stream, _)) => stream,
                // Transient, per-connection accept failures (peer reset
                // before we accepted, interrupted syscall) must not
                // bring down every live session on the other
                // connections; only listener-fatal errors end the loop.
                Err(e)
                    if matches!(
                        e.kind(),
                        io::ErrorKind::ConnectionAborted
                            | io::ErrorKind::ConnectionReset
                            | io::ErrorKind::Interrupted
                    ) =>
                {
                    continue;
                }
                Err(e) => return Err(e),
            };
            if self.stop.load(Ordering::SeqCst) {
                // Woken by the shutdown self-connection (or raced by a
                // late client): stop accepting.
                return Ok(());
            }
            let frontend = Arc::clone(&self.frontend);
            let stop = Arc::clone(&self.stop);
            std::thread::spawn(move || serve_connection(stream, addr, frontend, stop));
        }
    }
}

/// One connection's request loop. Runs on its own thread; returns (and
/// drops the socket) on EOF, I/O error, or after acking a `Shutdown`.
fn serve_connection(
    stream: TcpStream,
    server_addr: SocketAddr,
    frontend: Arc<Mutex<AggFrontend>>,
    stop: Arc<AtomicBool>,
) {
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) => return, // EOF: client done.
            Ok(_) => {}
            Err(_) => return,
        }
        if line.trim().is_empty() {
            continue;
        }
        let (reply, shutdown) = match decode_request(&line) {
            Ok(Request::Shutdown) => (Response::Admission(AdmissionReply::ok(None)), true),
            Ok(req) => {
                let mut fe = frontend.lock().expect("frontend mutex poisoned");
                (fe.handle(&req), false)
            }
            // Malformed bytes get a typed reply, not a dropped
            // connection — and certainly not a server panic.
            Err(e) => (
                Response::Admission(AdmissionReply::denied(
                    None,
                    AdmissionError::Rejected { reason: e.msg },
                )),
                false,
            ),
        };
        let mut out = reply.to_json().to_string_compact();
        out.push('\n');
        if writer.write_all(out.as_bytes()).is_err() || writer.flush().is_err() {
            return;
        }
        if shutdown {
            stop.store(true, Ordering::SeqCst);
            // Wake the accept loop so `serve` observes the flag and
            // returns. The dummy connection is closed immediately.
            let _ = TcpStream::connect(server_addr);
            return;
        }
    }
}

/// A request as one newline-terminated compact-JSON frame.
fn encode_frame(req: &Request) -> String {
    let mut line = req.to_json().to_string_compact();
    line.push('\n');
    line
}

fn decode_request(line: &str) -> Result<Request, ProtoError> {
    let j: Json =
        parse(line.trim_end()).map_err(|e| ProtoError { msg: format!("bad frame: {e}") })?;
    Request::from_json(&j)
}

/// Blocking wire-protocol client: one TCP connection, synchronous
/// request/reply. Mirrors the in-process session surface —
/// [`open_session`](ServiceClient::open_session) ≈ `try_session`,
/// [`submit_round`](ServiceClient::submit_round) ≈ `try_run_round`,
/// [`run_round_admitted`](ServiceClient::run_round_admitted) ≈ the
/// scheduler's throttle-retry loop — so swapping a local engine for a
/// remote one is a transport decision, not a rewrite (that is what
/// `fl::trainer::train_remote` does).
pub struct ServiceClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl ServiceClient {
    /// Connect to a [`ServiceServer`] at `addr` (e.g. `"127.0.0.1:7433"`).
    pub fn connect(addr: &str) -> io::Result<ServiceClient> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        let writer = stream.try_clone()?;
        Ok(ServiceClient { reader: BufReader::new(stream), writer })
    }

    /// One raw request/reply exchange. The typed helpers below are
    /// usually what callers want.
    pub fn call(&mut self, req: &Request) -> Result<Response, ServiceError> {
        self.exchange(&encode_frame(req))
    }

    /// Send one pre-encoded frame and decode its reply — split from
    /// [`call`](ServiceClient::call) so retry loops can encode a large
    /// request once and resend the same bytes.
    fn exchange(&mut self, frame: &str) -> Result<Response, ServiceError> {
        self.writer.write_all(frame.as_bytes())?;
        self.writer.flush()?;
        let mut reply = String::new();
        if self.reader.read_line(&mut reply)? == 0 {
            return Err(ServiceError::Io(io::Error::new(
                io::ErrorKind::UnexpectedEof,
                "server closed the connection",
            )));
        }
        let j = parse(reply.trim_end())
            .map_err(|e| ServiceError::Proto(ProtoError { msg: format!("bad frame: {e}") }))?;
        Ok(Response::from_json(&j)?)
    }

    /// Open a tenant session; returns the granted session id.
    /// Admission rejections surface as [`ServiceError::Denied`].
    pub fn open_session(
        &mut self,
        cfg: HiSafeConfig,
        d: usize,
        seed: u64,
        qos: QosPolicy,
    ) -> Result<u64, ServiceError> {
        match self.call(&Request::SessionOpen { cfg, d, seed, qos })? {
            Response::Admission(AdmissionReply { session: Some(sid), error: None }) => Ok(sid),
            Response::Admission(AdmissionReply { error: Some(e), .. }) => {
                Err(ServiceError::Denied(e))
            }
            other => Err(ServiceError::Unexpected(format!("{other:?}"))),
        }
    }

    /// Submit one round. A throttle (or any other denial) comes back as
    /// [`ServiceError::Denied`] — use
    /// [`run_round_admitted`](ServiceClient::run_round_admitted) to
    /// retry throttles automatically.
    pub fn submit_round(
        &mut self,
        session: u64,
        signs: &[Vec<i8>],
    ) -> Result<VoteReply, ServiceError> {
        let req = Request::RoundSubmit { session, signs: signs.to_vec() };
        Self::vote_reply(self.call(&req)?)
    }

    fn vote_reply(resp: Response) -> Result<VoteReply, ServiceError> {
        match resp {
            Response::Vote(v) => Ok(v),
            Response::Admission(AdmissionReply { error: Some(e), .. }) => {
                Err(ServiceError::Denied(e))
            }
            other => Err(ServiceError::Unexpected(format!("{other:?}"))),
        }
    }

    /// Interpret a reply that should be a bare admission ack.
    fn ack_reply(resp: Response) -> Result<(), ServiceError> {
        match resp {
            Response::Admission(AdmissionReply { error: None, .. }) => Ok(()),
            Response::Admission(AdmissionReply { error: Some(e), .. }) => {
                Err(ServiceError::Denied(e))
            }
            other => Err(ServiceError::Unexpected(format!("{other:?}"))),
        }
    }

    /// Blocking submit-with-retry: waits out `Throttled` denials
    /// (sleeping roughly `retry_after`, clamped to [50 µs, 20 ms] — the
    /// same loop `AggSession::run_round_admitted` runs in-process, now
    /// with the denial crossing the wire each time). Returns the vote,
    /// the number of denials eaten, and the total time slept.
    pub fn run_round_admitted(
        &mut self,
        session: u64,
        signs: &[Vec<i8>],
    ) -> Result<(VoteReply, u64, Duration), ServiceError> {
        // Encode once: the sign matrix dominates the frame at model
        // sizes and never changes across throttle retries, so retries
        // resend the same bytes instead of re-cloning + re-encoding.
        let frame = encode_frame(&Request::RoundSubmit { session, signs: signs.to_vec() });
        let mut denials = 0u64;
        let mut waited = Duration::ZERO;
        loop {
            match Self::vote_reply(self.exchange(&frame)?) {
                Ok(v) => return Ok((v, denials, waited)),
                Err(ServiceError::Denied(AdmissionError::Throttled { retry_after })) => {
                    denials += 1;
                    let wait =
                        retry_after.clamp(Duration::from_micros(50), Duration::from_millis(20));
                    waited += wait;
                    std::thread::sleep(wait);
                }
                Err(e) => return Err(e),
            }
        }
    }

    /// Queue `rounds` rounds of triple dealing on the session's shard
    /// (the wire form of `try_prefetch`).
    pub fn prefetch(&mut self, session: u64, rounds: usize) -> Result<(), ServiceError> {
        Self::ack_reply(self.call(&Request::Prefetch { session, rounds })?)
    }

    /// Close a session, freeing its shard slot.
    pub fn close_session(&mut self, session: u64) -> Result<(), ServiceError> {
        Self::ack_reply(self.call(&Request::SessionClose { session })?)
    }

    /// Read counters for one session (`Some(id)`) or the whole frontend
    /// (`None`).
    pub fn stats(&mut self, session: Option<u64>) -> Result<StatsReply, ServiceError> {
        match self.call(&Request::StatsQuery { session })? {
            Response::Stats(s) => Ok(s),
            Response::Admission(AdmissionReply { error: Some(e), .. }) => {
                Err(ServiceError::Denied(e))
            }
            other => Err(ServiceError::Unexpected(format!("{other:?}"))),
        }
    }

    /// Ask the server to stop accepting and exit its serve loop.
    pub fn shutdown(&mut self) -> Result<(), ServiceError> {
        Self::ack_reply(self.call(&Request::Shutdown)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::poly::TiePolicy;
    use crate::protocol::plain_hierarchical_vote;
    use crate::util::rng::{Rng, Xoshiro256pp};

    fn rand_signs(n: usize, d: usize, seed: u64) -> Vec<Vec<i8>> {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        (0..n).map(|_| (0..d).map(|_| rng.gen_sign()).collect()).collect()
    }

    /// Spawn a server on an ephemeral port; returns its address and the
    /// serve-loop handle (joined to assert clean shutdown).
    fn spawn_server(frontend: AggFrontend) -> (String, std::thread::JoinHandle<io::Result<()>>) {
        let server = ServiceServer::bind("127.0.0.1:0", frontend).expect("bind loopback");
        let addr = server.local_addr().expect("bound addr").to_string();
        let handle = std::thread::spawn(move || server.serve());
        (addr, handle)
    }

    #[test]
    fn full_session_lifecycle_over_loopback_tcp() {
        let (addr, server) = spawn_server(AggFrontend::new(2, 1));
        let cfg = HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit);
        let mut client = ServiceClient::connect(&addr).expect("connect");

        let sid = client.open_session(cfg, 5, 7, QosPolicy::unlimited()).expect("admitted");
        client.prefetch(sid, 2).expect("prefetch admitted");
        for r in 0..3u64 {
            let signs = rand_signs(6, 5, 40 + r);
            let vote = client.submit_round(sid, &signs).expect("round admitted");
            assert_eq!(vote.global_vote, plain_hierarchical_vote(&signs, cfg));
            assert_eq!(vote.session, sid);
            assert!(vote.stats.mults > 0);
        }
        let stats = client.stats(Some(sid)).expect("session stats");
        assert_eq!(stats.session, Some(sid));
        assert_eq!(stats.rounds_run, 3);
        assert_eq!(stats.admission.admitted_rounds, 3);
        client.close_session(sid).expect("close acked");
        // Closed sessions are unknown afterwards.
        match client.stats(Some(sid)) {
            Err(ServiceError::Denied(AdmissionError::Rejected { reason })) => {
                assert!(reason.contains("unknown session"), "reason: {reason}")
            }
            other => panic!("expected unknown-session, got {other:?}"),
        }
        // Frontend-wide stats survive the close.
        let fe_stats = client.stats(None).expect("frontend stats");
        assert_eq!(fe_stats.rounds_run, 3);
        assert_eq!(fe_stats.shard_tenants, Some(vec![0, 0]));

        client.shutdown().expect("shutdown acked");
        server.join().expect("serve thread").expect("clean shutdown");
    }

    #[test]
    fn malformed_frames_get_typed_replies_not_disconnects() {
        let (addr, server) = spawn_server(AggFrontend::new(1, 1));
        let stream = TcpStream::connect(&addr).expect("connect");
        let mut writer = stream.try_clone().expect("clone");
        let mut reader = BufReader::new(stream);
        let mut reply = String::new();

        // Garbage bytes → typed Rejected reply, connection stays up.
        writer.write_all(b"this is not json\n").expect("write");
        reader.read_line(&mut reply).expect("read");
        let j = parse(reply.trim_end()).expect("reply parses");
        match Response::from_json(&j).expect("reply decodes") {
            Response::Admission(AdmissionReply {
                error: Some(AdmissionError::Rejected { reason }),
                ..
            }) => assert!(reason.contains("bad frame"), "reason: {reason}"),
            other => panic!("expected a frame rejection, got {other:?}"),
        }

        // Valid JSON with a bad version → typed rejection too.
        reply.clear();
        writer.write_all(b"{\"v\":99,\"type\":\"shutdown\"}\n").expect("write");
        reader.read_line(&mut reply).expect("read");
        let j = parse(reply.trim_end()).expect("reply parses");
        match Response::from_json(&j).expect("reply decodes") {
            Response::Admission(AdmissionReply {
                error: Some(AdmissionError::Rejected { reason }),
                ..
            }) => assert!(reason.contains("version"), "reason: {reason}"),
            other => panic!("expected a version rejection, got {other:?}"),
        }

        // The same connection still works for a real request.
        let mut client = ServiceClient { reader, writer };
        client.shutdown().expect("shutdown after garbage");
        server.join().expect("serve thread").expect("clean shutdown");
    }

    #[test]
    fn two_clients_share_one_frontend() {
        let (addr, server) = spawn_server(AggFrontend::new(2, 1));
        let cfg = HiSafeConfig::flat(3, TiePolicy::OneBit);
        let mut c1 = ServiceClient::connect(&addr).expect("connect c1");
        let mut c2 = ServiceClient::connect(&addr).expect("connect c2");
        let s1 = c1.open_session(cfg, 4, 1, QosPolicy::unlimited()).expect("admitted");
        let s2 = c2.open_session(cfg, 4, 2, QosPolicy::unlimited()).expect("admitted");
        assert_ne!(s1, s2, "sessions are distinct frontend-wide");
        // Each client sees both sessions in the frontend aggregate.
        let stats = c1.stats(None).expect("frontend stats");
        assert_eq!(stats.shard_tenants.expect("shards").iter().sum::<usize>(), 2);
        c1.shutdown().expect("shutdown");
        server.join().expect("serve thread").expect("clean shutdown");
    }
}
