//! A std-only TCP transport for the wire protocol: [`ServiceServer`]
//! (newline-delimited JSON frames over TCP, a **bounded pool of
//! connection workers** multiplexing every connection onto one shared
//! [`AggFrontend`]) and the matching blocking [`ServiceClient`].
//!
//! This is deliberately the simplest transport that makes the service
//! layer *real*: separate OS processes run genuine client/server
//! aggregation rounds today (`hisafe serve` + `hisafe sweep --remote`,
//! or several `serve` hosts behind `hisafe balance`), and the protocol
//! work — versioning, lossless encodings, typed backpressure — lives in
//! [`super::proto`] where any future transport (HTTP, UDS, shared
//! memory) reuses it unchanged.
//!
//! **Framing.** Two codecs, selected per frame by the first byte:
//!
//! * **JSON** (the start state): one compact JSON document per line.
//!   Compact encodings are newline-free by construction (strings escape
//!   `\n`), so splitting on `\n` is a complete framer.
//! * **Binary** ([`super::binary`]): length-prefixed frames starting
//!   with the magic byte `0xB2` — never the first byte of a JSON frame,
//!   so a mixed connection is unambiguous. Clients opt in per
//!   connection by asking on `SessionOpen`/`SessionRestore`; the server
//!   acks on the granting reply (iff its codec policy allows — see
//!   [`ServiceServer::with_codec`]) and the client switches from the
//!   next frame on. Replies always ride the codec of the frame they
//!   answer.
//!
//! A frame that fails to decode — garbage JSON or a malformed binary
//! payload — is answered with a typed `Rejected` reply carrying the
//! parse error; a garbage client cannot crash the server. (A broken
//! binary *header* additionally drops the connection: without a valid
//! length there is no next frame boundary to resync on.)
//!
//! **Concurrency: bounded connection workers.** The accept loop puts
//! every connection in **non-blocking** mode and parks it in a shared
//! registry; a fixed pool of worker threads sweeps the registry,
//! `try_lock`ing one connection at a time and pumping whatever bytes
//! are ready (reads accumulate into a per-connection line buffer,
//! writes drain a per-connection out-buffer, `WouldBlock` just means
//! "come back next sweep"). Two things follow:
//!
//! * **Idle is free.** A thousand connected-but-quiet clients cost a
//!   thousand registry entries, not a thousand OS threads — the old
//!   thread-per-connection model is gone.
//! * **The wire path is as parallel as the frontend.** Each worker
//!   calls [`AggFrontend::handle`] on a *shared reference*; the
//!   frontend's per-shard locks (see [`super::frontend`]) let `K`
//!   shards serve `K` concurrent wire rounds, so worker count — not a
//!   global service mutex — is the transport's only concurrency knob.
//!
//! **Fault containment.** Every `handle` call runs under
//! `catch_unwind`: a panicking request costs its caller a typed error
//! reply and (at worst) one poisoned shard — absorbed and restored by
//! the frontend on next touch — never a dead worker or a dead server.
//!
//! **Shutdown.** A [`Request::Shutdown`] is acked synchronously, then
//! stops the accept loop (waking it with a loopback self-connection)
//! and the workers; [`ServiceServer::serve`] joins the pool and returns
//! cleanly — the CI smoke test drives exactly this path and asserts the
//! process exits 0.

use std::io::{self, BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::engine::{AdmissionError, QosPolicy, SessionId, SessionSnapshot};
use crate::protocol::HiSafeConfig;
use crate::util::json::{parse, Json};

use super::binary;
use super::error::Error;
use super::frontend::AggFrontend;
use super::proto::{AdmissionReply, Codec, ProtoError, Request, Response, StatsReply, VoteReply};

/// Default connection-worker pool size when the caller doesn't choose
/// (`hisafe serve --workers N` does). Shared with the balancer, whose
/// client-facing listener runs the same pump.
pub(crate) const DEFAULT_WORKERS: usize = 4;

/// How long a worker sleeps after a sweep that moved no bytes. Low
/// enough to keep per-request latency in the tens of microseconds,
/// high enough that an idle server burns ~no CPU.
const IDLE_SLEEP: Duration = Duration::from_micros(100);

/// One registered connection: its I/O state behind a `try_lock`ed
/// mutex (a connection is pumped by at most one worker at a time) and
/// a closed flag the sweep uses to prune without locking.
struct Conn {
    io: Mutex<ConnIo>,
    closed: AtomicBool,
}

/// The per-connection I/O state a worker pumps: the non-blocking
/// socket plus the partial-line in-buffer and the pending-reply
/// out-buffer that let a connection make progress one readiness slice
/// at a time.
struct ConnIo {
    stream: TcpStream,
    inbuf: Vec<u8>,
    outbuf: Vec<u8>,
}

/// One request surface behind the bounded connection-worker pump:
/// [`serve_frames`] splits and decodes frames (JSON or binary) off
/// every registered connection and answers with whatever the handler
/// returns. Two implementors — the [`AggFrontend`] transport here and
/// the balancer's routing core (`service::balancer`) — so the accept
/// loop, registry, non-blocking pump, codec handling, and shutdown
/// dance exist exactly once.
pub(crate) trait FrameHandler: Send + Sync {
    /// Answer one decoded frame — or a decode failure, which handlers
    /// answer with a typed rejection, never a dropped connection.
    /// Returns the reply plus whether the frame asked the process to
    /// stop serving.
    fn handle_frame(&self, req: &Result<Request, ProtoError>) -> (Response, bool);
}

/// What one pump pass did with a connection.
enum Pump {
    /// No bytes ready in either direction.
    Idle,
    /// Read, handled, or wrote something.
    Progress,
    /// EOF, fatal I/O error, or post-shutdown: unregister it.
    Closed,
}

/// The TCP service: a bound listener, the shared [`AggFrontend`] every
/// connection talks to, and the connection-worker pool configuration.
pub struct ServiceServer {
    listener: TcpListener,
    frontend: Arc<AggFrontend>,
    stop: Arc<AtomicBool>,
    workers: usize,
    codec: Codec,
}

impl ServiceServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) over a
    /// fresh frontend with the default worker pool. The listener is
    /// live when this returns — clients may connect before
    /// [`serve`](ServiceServer::serve) is called and their connections
    /// queue in the accept backlog.
    pub fn bind(addr: &str, frontend: AggFrontend) -> io::Result<ServiceServer> {
        Self::bind_with_workers(addr, frontend, DEFAULT_WORKERS)
    }

    /// Like [`bind`](ServiceServer::bind) with an explicit connection
    /// worker count. Workers bound *concurrent request handling*, not
    /// connections: any number of clients may stay connected, `workers`
    /// of them are served at any instant.
    pub fn bind_with_workers(
        addr: &str,
        frontend: AggFrontend,
        workers: usize,
    ) -> io::Result<ServiceServer> {
        assert!(workers >= 1, "the service needs at least one connection worker");
        Ok(ServiceServer {
            listener: TcpListener::bind(addr)?,
            frontend: Arc::new(frontend),
            stop: Arc::new(AtomicBool::new(false)),
            workers,
            codec: Codec::Binary,
        })
    }

    /// The richest codec this server *acks* (default: [`Codec::Binary`],
    /// i.e. binary-capable). `with_codec(Codec::Json)` makes the server
    /// stay quiet when a client asks for binary — the client then keeps
    /// speaking JSON, which is what `hisafe serve --codec json` uses for
    /// debugging and for mixed-version clusters. Decoding is unaffected:
    /// the pump always understands both codecs.
    pub fn with_codec(mut self, codec: Codec) -> ServiceServer {
        self.codec = codec;
        self
    }

    /// The bound address (resolves the actual port after `":0"` binds).
    pub fn local_addr(&self) -> io::Result<SocketAddr> {
        self.listener.local_addr()
    }

    /// A handle on the frontend behind this server. The chaos harness
    /// ([`service::faults`](crate::service::faults)) uses it to poison
    /// shards and probe `live_sessions()` from outside the wire.
    pub fn frontend(&self) -> Arc<AggFrontend> {
        Arc::clone(&self.frontend)
    }

    /// Accept-and-dispatch until a client sends `Shutdown`: the accept
    /// loop registers connections, the worker pool serves them, and a
    /// shutdown request stops both (the pool is joined before this
    /// returns, so "serve returned" means "no request is in flight").
    pub fn serve(self) -> io::Result<()> {
        let handler = FrontendHandler { frontend: Arc::clone(&self.frontend) };
        serve_frames(self.listener, Arc::new(handler), self.stop, self.workers, self.codec)
    }
}

/// The frontend behind the shared pump: every frame is decoded,
/// answered under `catch_unwind`, and shutdown frames flip the serve
/// loop's stop flag (see [`respond`]).
struct FrontendHandler {
    frontend: Arc<AggFrontend>,
}

impl FrameHandler for FrontendHandler {
    fn handle_frame(&self, req: &Result<Request, ProtoError>) -> (Response, bool) {
        respond(req, &self.frontend)
    }
}

/// The shared transport skeleton: accept connections into the
/// registry, sweep them with `workers` bounded connection workers, and
/// stop cleanly when a frame reports shutdown (the pool is joined
/// before this returns, so "returned" means "no request in flight").
/// [`ServiceServer::serve`] and the balancer both run exactly this.
pub(crate) fn serve_frames<H: FrameHandler + 'static>(
    listener: TcpListener,
    handler: Arc<H>,
    stop: Arc<AtomicBool>,
    workers: usize,
    codec: Codec,
) -> io::Result<()> {
    let addr = listener.local_addr()?;
    let registry: Arc<Mutex<Vec<Arc<Conn>>>> = Arc::new(Mutex::new(Vec::new()));
    let pool: Vec<_> = (0..workers)
        .map(|_| {
            let registry = Arc::clone(&registry);
            let handler = Arc::clone(&handler);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || worker_loop(registry, handler, stop, addr, codec))
        })
        .collect();
    let accept_result = loop {
        let stream = match listener.accept() {
            Ok((stream, _)) => stream,
            // Transient, per-connection accept failures (peer reset
            // before we accepted, interrupted syscall) must not
            // bring down every live session on the other
            // connections; only listener-fatal errors end the loop.
            Err(e)
                if matches!(
                    e.kind(),
                    io::ErrorKind::ConnectionAborted
                        | io::ErrorKind::ConnectionReset
                        | io::ErrorKind::Interrupted
                ) =>
            {
                continue;
            }
            Err(e) => break Err(e),
        };
        if stop.load(Ordering::SeqCst) {
            // Woken by the shutdown self-connection (or raced by a
            // late client): stop accepting.
            break Ok(());
        }
        if stream.set_nonblocking(true).is_err() {
            continue;
        }
        let _ = stream.set_nodelay(true);
        lock_registry(&registry).push(Arc::new(Conn {
            io: Mutex::new(ConnIo { stream, inbuf: Vec::new(), outbuf: Vec::new() }),
            closed: AtomicBool::new(false),
        }));
    };
    // Whether we stopped cleanly or the listener died, the workers
    // must not outlive the server.
    stop.store(true, Ordering::SeqCst);
    for w in pool {
        let _ = w.join();
    }
    accept_result
}

/// Lock the connection registry, absorbing poison: the registry holds
/// only `Arc`s (no invariants beyond "is a list"), and a worker panic
/// is already contained per-request, so recovery is always safe.
fn lock_registry(registry: &Mutex<Vec<Arc<Conn>>>) -> std::sync::MutexGuard<'_, Vec<Arc<Conn>>> {
    registry.lock().unwrap_or_else(|p| p.into_inner())
}

/// One connection worker: sweep the registry, pump every connection
/// whose lock is free, prune the closed, sleep briefly when a full
/// sweep moved nothing.
fn worker_loop<H: FrameHandler>(
    registry: Arc<Mutex<Vec<Arc<Conn>>>>,
    handler: Arc<H>,
    stop: Arc<AtomicBool>,
    server_addr: SocketAddr,
    codec: Codec,
) {
    loop {
        if stop.load(Ordering::SeqCst) {
            return;
        }
        let conns: Vec<Arc<Conn>> = lock_registry(&registry).clone();
        let mut moved = false;
        let mut saw_closed = false;
        for conn in &conns {
            if conn.closed.load(Ordering::SeqCst) {
                saw_closed = true;
                continue;
            }
            // Another worker holds this connection: skip, don't wait.
            let Ok(mut io) = conn.io.try_lock() else { continue };
            match pump(&mut io, handler.as_ref(), &stop, server_addr, codec) {
                Pump::Idle => {}
                Pump::Progress => moved = true,
                Pump::Closed => {
                    conn.closed.store(true, Ordering::SeqCst);
                    saw_closed = true;
                    moved = true;
                }
            }
        }
        if saw_closed {
            lock_registry(&registry).retain(|c| !c.closed.load(Ordering::SeqCst));
        }
        if !moved {
            std::thread::sleep(IDLE_SLEEP);
        }
    }
}

/// Pump one connection: read whatever is ready, answer every complete
/// frame, flush whatever the socket will take. Never blocks (the
/// stream is non-blocking; `WouldBlock` ends each half of the pass).
fn pump<H: FrameHandler + ?Sized>(
    io: &mut ConnIo,
    handler: &H,
    stop: &AtomicBool,
    server_addr: SocketAddr,
    codec: Codec,
) -> Pump {
    let mut moved = false;
    // Read half: drain the socket into the frame buffer.
    let mut chunk = [0u8; 4096];
    loop {
        match io.stream.read(&mut chunk) {
            Ok(0) => return Pump::Closed, // EOF: client done.
            Ok(n) => {
                io.inbuf.extend_from_slice(&chunk[..n]);
                moved = true;
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(_) => return Pump::Closed,
        }
    }
    // Handle half: answer every complete frame at the buffer head, in
    // arrival order. The first byte picks the codec per frame — JSON
    // frames start with `{` (or whitespace), binary frames with the
    // magic byte — so one connection may interleave both (it does,
    // around the negotiation switch).
    loop {
        let Some(&first) = io.inbuf.first() else { break };
        if first == binary::MAGIC {
            if io.inbuf.len() < binary::HEADER_LEN {
                break; // Partial header: wait for more bytes.
            }
            let payload_len = match binary::parse_header(&io.inbuf[..binary::HEADER_LEN]) {
                Ok(len) => len,
                Err(e) => {
                    // The *header* is broken (bad version or oversize
                    // length): answer typed in the codec the peer is
                    // speaking, then drop the connection — without a
                    // valid length there is no next frame boundary.
                    let reply = Response::Admission(AdmissionReply::denied(
                        None,
                        AdmissionError::Rejected { reason: e.msg },
                    ));
                    io.outbuf.extend_from_slice(&binary::encode_response(&reply));
                    let _ = io.stream.set_nonblocking(false);
                    let _ = io.stream.write_all(&io.outbuf);
                    let _ = io.stream.flush();
                    io.outbuf.clear();
                    return Pump::Closed;
                }
            };
            let total = binary::HEADER_LEN + payload_len;
            if io.inbuf.len() < total {
                break; // Partial payload: wait for more bytes.
            }
            let frame: Vec<u8> = io.inbuf.drain(..total).collect();
            moved = true;
            let req = binary::decode_request(&frame[binary::HEADER_LEN..]);
            let (mut reply, shutdown) = handler.handle_frame(&req);
            negotiate_ack(&req, &mut reply, codec);
            io.outbuf.extend_from_slice(&binary::encode_response(&reply));
            if shutdown {
                return finish_shutdown(io, stop, server_addr);
            }
        } else {
            let Some(pos) = io.inbuf.iter().position(|&b| b == b'\n') else { break };
            let line: Vec<u8> = io.inbuf.drain(..=pos).collect();
            let line = String::from_utf8_lossy(&line);
            if line.trim().is_empty() {
                continue;
            }
            moved = true;
            let req = decode_request(&line);
            let (mut reply, shutdown) = handler.handle_frame(&req);
            negotiate_ack(&req, &mut reply, codec);
            let mut out = reply.to_json().to_string_compact();
            out.push('\n');
            io.outbuf.extend_from_slice(out.as_bytes());
            if shutdown {
                return finish_shutdown(io, stop, server_addr);
            }
        }
    }
    // Write half: give the socket whatever it will take, keep the rest.
    while !io.outbuf.is_empty() {
        match io.stream.write(&io.outbuf) {
            Ok(0) => return Pump::Closed,
            Ok(n) => {
                io.outbuf.drain(..n);
                moved = true;
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(_) => return Pump::Closed,
        }
    }
    if moved {
        Pump::Progress
    } else {
        Pump::Idle
    }
}

/// Deliver the shutdown ack synchronously (the socket goes back to
/// blocking just for this), then stop the server: flag the pool and
/// wake the accept loop with a self-connection.
fn finish_shutdown(io: &mut ConnIo, stop: &AtomicBool, server_addr: SocketAddr) -> Pump {
    let _ = io.stream.set_nonblocking(false);
    let _ = io.stream.write_all(&io.outbuf);
    let _ = io.stream.flush();
    io.outbuf.clear();
    stop.store(true, Ordering::SeqCst);
    let _ = TcpStream::connect(server_addr);
    Pump::Closed
}

/// The server's half of codec negotiation: a *granting* reply to an
/// open/restore that asked for binary gets the ack stamped on it — iff
/// this server's policy speaks binary. Denials never ack (the retry
/// renegotiates from scratch), and a JSON-policy server simply stays
/// quiet, which a well-behaved client reads as "keep speaking JSON".
fn negotiate_ack(req: &Result<Request, ProtoError>, reply: &mut Response, policy: Codec) {
    if policy != Codec::Binary {
        return;
    }
    match req {
        Ok(Request::SessionOpen { codec: Some(Codec::Binary), .. })
        | Ok(Request::SessionRestore { codec: Some(Codec::Binary), .. }) => {}
        _ => return,
    }
    if let Response::Admission(r) = reply {
        if r.session.is_some() && r.error.is_none() {
            r.codec = Some(Codec::Binary);
        }
    }
}

/// Answer one decoded frame. Malformed bytes get a typed reply, not a
/// dropped connection; a panicking handler gets a typed reply too
/// (`catch_unwind` — the frontend's shard-poison absorption makes the
/// panicked shard recoverable, this makes the worker survive to see
/// it). Returns the reply plus whether it was a shutdown.
fn respond(req: &Result<Request, ProtoError>, frontend: &AggFrontend) -> (Response, bool) {
    match req {
        Ok(Request::Shutdown) => (Response::Admission(AdmissionReply::ok(None)), true),
        Ok(req) => {
            let reply = catch_unwind(AssertUnwindSafe(|| frontend.handle(req)))
                .unwrap_or_else(|_| {
                    Response::Admission(AdmissionReply::denied(
                        request_session(req),
                        AdmissionError::Rejected {
                            reason: "request handler panicked; the affected shard was \
                                     isolated and its sessions will restore elsewhere"
                                .into(),
                        },
                    ))
                });
            (reply, false)
        }
        Err(e) => (
            Response::Admission(AdmissionReply::denied(
                None,
                AdmissionError::Rejected { reason: e.msg.clone() },
            )),
            false,
        ),
    }
}

/// The session a request targets, for echoing in error replies.
fn request_session(req: &Request) -> Option<SessionId> {
    match req {
        Request::RoundSubmit { session, .. }
        | Request::Prefetch { session, .. }
        | Request::SessionClose { session }
        | Request::SessionDiscard { session }
        | Request::SessionSnapshot { session } => Some(*session),
        Request::StatsQuery { session } => *session,
        Request::SessionOpen { .. }
        | Request::SessionRestore { .. }
        | Request::SessionList
        | Request::Shutdown => None,
    }
}

/// A request as one newline-terminated compact-JSON frame.
fn encode_frame(req: &Request) -> String {
    let mut line = req.to_json().to_string_compact();
    line.push('\n');
    line
}

pub(crate) fn decode_request(line: &str) -> Result<Request, ProtoError> {
    let j: Json =
        parse(line.trim_end()).map_err(|e| ProtoError { msg: format!("bad frame: {e}") })?;
    Request::from_json(&j)
}

/// Blocking wire-protocol client: one TCP connection, synchronous
/// request/reply. Mirrors the in-process session surface —
/// [`open_session`](ServiceClient::open_session) ≈ `try_session`,
/// [`submit_round`](ServiceClient::submit_round) ≈ `try_run_round`,
/// [`run_round_admitted`](ServiceClient::run_round_admitted) ≈ the
/// scheduler's throttle-retry loop — so swapping a local engine for a
/// remote one is a transport decision, not a rewrite (that is what
/// `fl::trainer::train_remote` does). Fails with the unified
/// [`service::Error`](Error): admission denials, transport faults, and
/// protocol faults are distinct variants of one enum.
pub struct ServiceClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    /// The encoding currently in effect on this connection. Starts as
    /// [`Codec::Json`] (the protocol's start state) and flips to binary
    /// only after the server acks a negotiation ask.
    codec: Codec,
    /// The codec this client *wants*: [`call`](ServiceClient::call)
    /// stamps the ask onto every `SessionOpen`/`SessionRestore` until
    /// the server acks (or forever stays quiet, keeping us on JSON).
    want: Codec,
    /// Wire bytes written/read over the connection's lifetime — the
    /// bandwidth counters `hisafe sweep --remote` and the scheduler
    /// bench report per round.
    bytes_sent: u64,
    bytes_recv: u64,
}

impl ServiceClient {
    /// Connect to a [`ServiceServer`] at `addr` (e.g. `"127.0.0.1:7433"`),
    /// speaking plain JSON frames (no negotiation ask) — byte-identical
    /// on the wire to a v1 client.
    pub fn connect(addr: &str) -> io::Result<ServiceClient> {
        Self::connect_with_codec(addr, Codec::Json)
    }

    /// Connect asking for `want`: with [`Codec::Binary`] the next
    /// `SessionOpen`/`SessionRestore` carries the ask and the connection
    /// switches to length-prefixed binary frames once (iff) the server
    /// acks the grant. Against a JSON-policy (or older) server the ask
    /// is simply never acked and the connection stays on JSON — same
    /// sessions, same votes, bigger frames.
    pub fn connect_with_codec(addr: &str, want: Codec) -> io::Result<ServiceClient> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        let writer = stream.try_clone()?;
        Ok(ServiceClient {
            reader: BufReader::new(stream),
            writer,
            codec: Codec::Json,
            want,
            bytes_sent: 0,
            bytes_recv: 0,
        })
    }

    /// The encoding currently in effect (switches from JSON to binary
    /// when the server acks a negotiation ask).
    pub fn codec(&self) -> Codec {
        self.codec
    }

    /// Total wire bytes this client has written (headers included).
    pub fn bytes_sent(&self) -> u64 {
        self.bytes_sent
    }

    /// Total wire bytes this client has read (headers included).
    pub fn bytes_received(&self) -> u64 {
        self.bytes_recv
    }

    /// One raw request/reply exchange. The typed helpers below are
    /// usually what callers want.
    pub fn call(&mut self, req: &Request) -> Result<Response, Error> {
        // While negotiating (want=binary, still on JSON), opens and
        // restores carry the codec ask — injected here so every caller
        // (trainer, balancer, CLI, tests) negotiates without plumbing.
        // A caller-provided `Some(_)` is respected, never overridden.
        let frame = match (self.codec_ask(), req) {
            (Some(ask), Request::SessionOpen { cfg, d, seed, qos, codec: None }) => {
                self.encode(&Request::SessionOpen {
                    cfg: *cfg,
                    d: *d,
                    seed: *seed,
                    qos: *qos,
                    codec: Some(ask),
                })
            }
            (Some(ask), Request::SessionRestore { snapshot, codec: None }) => self.encode(
                &Request::SessionRestore { snapshot: snapshot.clone(), codec: Some(ask) },
            ),
            _ => self.encode(req),
        };
        self.exchange(&frame)
    }

    /// The codec to ask for on the next open/restore, if any: only
    /// while the connection wants binary but still speaks JSON.
    fn codec_ask(&self) -> Option<Codec> {
        (self.want == Codec::Binary && self.codec == Codec::Json).then_some(Codec::Binary)
    }

    /// Encode one request in the connection's current codec.
    fn encode(&self, req: &Request) -> Vec<u8> {
        match self.codec {
            Codec::Json => encode_frame(req).into_bytes(),
            Codec::Binary => binary::encode_request(req),
        }
    }

    /// Send one pre-encoded frame and decode its reply — split from
    /// [`call`](ServiceClient::call) so retry loops can encode a large
    /// request once and resend the same bytes. Watches every admission
    /// reply for the server's codec ack and switches the connection's
    /// encoding when it arrives.
    fn exchange(&mut self, frame: &[u8]) -> Result<Response, Error> {
        self.writer.write_all(frame)?;
        self.writer.flush()?;
        self.bytes_sent += frame.len() as u64;
        let resp = self.read_response()?;
        if let Response::Admission(AdmissionReply { codec: Some(c), error: None, .. }) = &resp {
            self.codec = *c;
        }
        Ok(resp)
    }

    /// Read one reply in whichever codec the server answered with (the
    /// first byte disambiguates, exactly as on the server side).
    fn read_response(&mut self) -> Result<Response, Error> {
        let head = self.reader.fill_buf()?;
        if head.is_empty() {
            return Err(Error::Io(io::Error::new(
                io::ErrorKind::UnexpectedEof,
                "server closed the connection",
            )));
        }
        if head[0] == binary::MAGIC {
            let mut hdr = [0u8; binary::HEADER_LEN];
            self.reader.read_exact(&mut hdr)?;
            let payload_len = binary::parse_header(&hdr).map_err(Error::Proto)?;
            let mut payload = vec![0u8; payload_len];
            self.reader.read_exact(&mut payload)?;
            self.bytes_recv += (binary::HEADER_LEN + payload_len) as u64;
            Ok(binary::decode_response(&payload)?)
        } else {
            let mut reply = String::new();
            if self.reader.read_line(&mut reply)? == 0 {
                return Err(Error::Io(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    "server closed the connection",
                )));
            }
            self.bytes_recv += reply.len() as u64;
            let j = parse(reply.trim_end())
                .map_err(|e| Error::Proto(ProtoError { msg: format!("bad frame: {e}") }))?;
            Ok(Response::from_json(&j)?)
        }
    }

    /// Open a tenant session; returns the granted session id.
    /// Admission rejections surface as [`Error::Admission`].
    pub fn open_session(
        &mut self,
        cfg: HiSafeConfig,
        d: usize,
        seed: u64,
        qos: QosPolicy,
    ) -> Result<SessionId, Error> {
        match self.call(&Request::SessionOpen { cfg, d, seed, qos, codec: None })? {
            Response::Admission(AdmissionReply { session: Some(sid), error: None, .. }) => Ok(sid),
            Response::Admission(AdmissionReply { error: Some(e), .. }) => {
                Err(Error::Admission(e))
            }
            other => Err(Error::Unexpected(format!("{other:?}"))),
        }
    }

    /// Submit one round. A throttle (or any other denial) comes back as
    /// [`Error::Admission`] — use
    /// [`run_round_admitted`](ServiceClient::run_round_admitted) to
    /// retry throttles automatically.
    pub fn submit_round(
        &mut self,
        session: SessionId,
        signs: &[Vec<i8>],
    ) -> Result<VoteReply, Error> {
        let req = Request::RoundSubmit { session, signs: signs.to_vec(), present: None };
        Self::vote_reply(self.call(&req)?)
    }

    /// Submit one round over an explicit participant set: `present[i]`
    /// says whether user `i` answered this round (the sign matrix keeps
    /// its full `n`-row shape; absent rows are ignored server-side).
    /// A subgroup below its reconstruction threshold comes back as
    /// [`AdmissionError::ChurnBelowThreshold`] — a typed per-round
    /// abort, not a session failure.
    pub fn submit_round_present(
        &mut self,
        session: SessionId,
        signs: &[Vec<i8>],
        present: &[bool],
    ) -> Result<VoteReply, Error> {
        let req = Request::RoundSubmit {
            session,
            signs: signs.to_vec(),
            present: Some(present.to_vec()),
        };
        Self::vote_reply(self.call(&req)?)
    }

    fn vote_reply(resp: Response) -> Result<VoteReply, Error> {
        match resp {
            Response::Vote(v) => Ok(v),
            Response::Admission(AdmissionReply { error: Some(e), .. }) => {
                Err(Error::Admission(e))
            }
            other => Err(Error::Unexpected(format!("{other:?}"))),
        }
    }

    /// Interpret a reply that should be a bare admission ack.
    fn ack_reply(resp: Response) -> Result<(), Error> {
        match resp {
            Response::Admission(AdmissionReply { error: None, .. }) => Ok(()),
            Response::Admission(AdmissionReply { error: Some(e), .. }) => {
                Err(Error::Admission(e))
            }
            other => Err(Error::Unexpected(format!("{other:?}"))),
        }
    }

    /// Blocking submit-with-retry: waits out `Throttled` denials
    /// (sleeping roughly `retry_after`, clamped to [50 µs, 20 ms] — the
    /// same loop `AggSession::run_round_admitted` runs in-process, now
    /// with the denial crossing the wire each time). Returns the vote,
    /// the number of denials eaten, and the total time slept.
    pub fn run_round_admitted(
        &mut self,
        session: SessionId,
        signs: &[Vec<i8>],
    ) -> Result<(VoteReply, u64, Duration), Error> {
        self.run_round_admitted_present(session, signs, None)
    }

    /// [`run_round_admitted`](ServiceClient::run_round_admitted) over an
    /// explicit participant set (`None` ⇒ all-present, same bytes as the
    /// v1 frame). Only `Throttled` denials are retried: a
    /// `ChurnBelowThreshold` abort is a property of this round's mask,
    /// not of server load, so it surfaces immediately.
    pub fn run_round_admitted_present(
        &mut self,
        session: SessionId,
        signs: &[Vec<i8>],
        present: Option<&[bool]>,
    ) -> Result<(VoteReply, u64, Duration), Error> {
        // Encode once (in the connection's current codec): the sign
        // matrix dominates the frame at model sizes and never changes
        // across throttle retries, so retries resend the same bytes
        // instead of re-cloning + re-encoding. Round submits never
        // renegotiate, so the codec cannot change mid-loop.
        let frame = self.encode(&Request::RoundSubmit {
            session,
            signs: signs.to_vec(),
            present: present.map(|m| m.to_vec()),
        });
        let mut denials = 0u64;
        let mut waited = Duration::ZERO;
        loop {
            match Self::vote_reply(self.exchange(&frame)?) {
                Ok(v) => return Ok((v, denials, waited)),
                Err(Error::Admission(AdmissionError::Throttled { retry_after })) => {
                    denials += 1;
                    let wait =
                        retry_after.clamp(Duration::from_micros(50), Duration::from_millis(20));
                    waited += wait;
                    std::thread::sleep(wait);
                }
                Err(e) => return Err(e),
            }
        }
    }

    /// Queue `rounds` rounds of triple dealing on the session's shard
    /// (the wire form of `try_prefetch`).
    pub fn prefetch(&mut self, session: SessionId, rounds: usize) -> Result<(), Error> {
        Self::ack_reply(self.call(&Request::Prefetch { session, rounds })?)
    }

    /// Close a session, freeing its shard slot.
    pub fn close_session(&mut self, session: SessionId) -> Result<(), Error> {
        Self::ack_reply(self.call(&Request::SessionClose { session })?)
    }

    /// Read counters for one session (`Some(id)`) or the whole frontend
    /// (`None`).
    pub fn stats(&mut self, session: Option<SessionId>) -> Result<StatsReply, Error> {
        match self.call(&Request::StatsQuery { session })? {
            Response::Stats(s) => Ok(s),
            Response::Admission(AdmissionReply { error: Some(e), .. }) => {
                Err(Error::Admission(e))
            }
            other => Err(Error::Unexpected(format!("{other:?}"))),
        }
    }

    /// Fetch the serializable restore point for a session: everything
    /// needed to resume it bit-identically on another frontend (the
    /// balancer's fail-over primitive).
    pub fn snapshot_session(&mut self, session: SessionId) -> Result<SessionSnapshot, Error> {
        match self.call(&Request::SessionSnapshot { session })? {
            Response::Snapshot(s) => Ok(s.snapshot),
            Response::Admission(AdmissionReply { error: Some(e), .. }) => {
                Err(Error::Admission(e))
            }
            other => Err(Error::Unexpected(format!("{other:?}"))),
        }
    }

    /// Resume a snapshotted session on this server; returns the NEW
    /// session id granted there (ids are per-frontend, not global).
    pub fn restore_session(&mut self, snapshot: &SessionSnapshot) -> Result<SessionId, Error> {
        match self.call(&Request::SessionRestore { snapshot: snapshot.clone(), codec: None })? {
            Response::Admission(AdmissionReply { session: Some(sid), error: None, .. }) => Ok(sid),
            Response::Admission(AdmissionReply { error: Some(e), .. }) => {
                Err(Error::Admission(e))
            }
            other => Err(Error::Unexpected(format!("{other:?}"))),
        }
    }

    /// Ask the server to stop accepting and exit its serve loop.
    pub fn shutdown(&mut self) -> Result<(), Error> {
        Self::ack_reply(self.call(&Request::Shutdown)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::poly::TiePolicy;
    use crate::protocol::{
        plain_hierarchical_vote, plain_hierarchical_vote_present, ParticipantSet,
    };
    use crate::util::rng::{Rng, Xoshiro256pp};

    fn rand_signs(n: usize, d: usize, seed: u64) -> Vec<Vec<i8>> {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        (0..n).map(|_| (0..d).map(|_| rng.gen_sign()).collect()).collect()
    }

    /// Spawn a server on an ephemeral port; returns its address and the
    /// serve-loop handle (joined to assert clean shutdown).
    fn spawn_server(frontend: AggFrontend) -> (String, std::thread::JoinHandle<io::Result<()>>) {
        spawn_server_with_workers(frontend, DEFAULT_WORKERS)
    }

    fn spawn_server_with_workers(
        frontend: AggFrontend,
        workers: usize,
    ) -> (String, std::thread::JoinHandle<io::Result<()>>) {
        let server =
            ServiceServer::bind_with_workers("127.0.0.1:0", frontend, workers).expect("bind");
        let addr = server.local_addr().expect("bound addr").to_string();
        let handle = std::thread::spawn(move || server.serve());
        (addr, handle)
    }

    #[test]
    fn full_session_lifecycle_over_loopback_tcp() {
        let (addr, server) = spawn_server(AggFrontend::new(2, 1));
        let cfg = HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit);
        let mut client = ServiceClient::connect(&addr).expect("connect");

        let sid = client.open_session(cfg, 5, 7, QosPolicy::unlimited()).expect("admitted");
        client.prefetch(sid, 2).expect("prefetch admitted");
        for r in 0..3u64 {
            let signs = rand_signs(6, 5, 40 + r);
            let vote = client.submit_round(sid, &signs).expect("round admitted");
            assert_eq!(vote.global_vote, plain_hierarchical_vote(&signs, cfg));
            assert_eq!(vote.session, sid);
            assert!(vote.stats.mults > 0);
        }
        let stats = client.stats(Some(sid)).expect("session stats");
        assert_eq!(stats.session, Some(sid));
        assert_eq!(stats.rounds_run, 3);
        assert_eq!(stats.admission.admitted_rounds, 3);
        // The snapshot round-trips the wire and reflects consumed rounds.
        let snap = client.snapshot_session(sid).expect("snapshot");
        assert_eq!(snap.rounds, 3);
        assert_eq!(snap.seed, 7);
        client.close_session(sid).expect("close acked");
        // Closed sessions are unknown afterwards.
        match client.stats(Some(sid)) {
            Err(Error::Admission(AdmissionError::Rejected { reason })) => {
                assert!(reason.contains("unknown session"), "reason: {reason}")
            }
            other => panic!("expected unknown-session, got {other:?}"),
        }
        // Frontend-wide stats survive the close.
        let fe_stats = client.stats(None).expect("frontend stats");
        assert_eq!(fe_stats.rounds_run, 3);
        assert_eq!(fe_stats.shard_tenants, Some(vec![0, 0]));

        client.shutdown().expect("shutdown acked");
        server.join().expect("serve thread").expect("clean shutdown");
    }

    #[test]
    fn churned_rounds_cross_the_wire_with_typed_below_threshold_aborts() {
        let (addr, server) = spawn_server(AggFrontend::new(2, 1));
        let cfg = HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit);
        let mut client = ServiceClient::connect(&addr).expect("connect");
        let sid = client.open_session(cfg, 5, 9, QosPolicy::unlimited()).expect("admitted");
        let signs = rand_signs(6, 5, 90);
        // One dropout in group 1: the survivor-set vote crosses the wire.
        let mask = vec![true, true, true, true, false, true];
        let vote = client.submit_round_present(sid, &signs, &mask).expect("churn admitted");
        let set = ParticipantSet::from_mask(mask);
        assert_eq!(vote.global_vote, plain_hierarchical_vote_present(&signs, &set, cfg));
        // Two dropouts in one 3-member group: below threshold, typed.
        let starved = vec![true, true, true, false, false, true];
        match client.submit_round_present(sid, &signs, &starved) {
            Err(Error::Admission(AdmissionError::ChurnBelowThreshold {
                group: 1,
                survivors: 1,
                required: 2,
            })) => {}
            other => panic!("expected a typed churn abort, got {other:?}"),
        }
        // The session is unharmed: an all-present round still works and
        // the churn abort was not billed as an admitted round.
        let vote = client.submit_round(sid, &signs).expect("round admitted");
        assert_eq!(vote.global_vote, plain_hierarchical_vote(&signs, cfg));
        let stats = client.stats(Some(sid)).expect("session stats");
        assert_eq!(stats.admission.admitted_rounds, 2);
        assert_eq!(stats.admission.rejected, 1, "churn aborts count as rejections");
        client.shutdown().expect("shutdown acked");
        server.join().expect("serve thread").expect("clean shutdown");
    }

    #[test]
    fn malformed_frames_get_typed_replies_not_disconnects() {
        let (addr, server) = spawn_server(AggFrontend::new(1, 1));
        let stream = TcpStream::connect(&addr).expect("connect");
        let mut writer = stream.try_clone().expect("clone");
        let mut reader = BufReader::new(stream);
        let mut reply = String::new();

        // Garbage bytes → typed Rejected reply, connection stays up.
        writer.write_all(b"this is not json\n").expect("write");
        reader.read_line(&mut reply).expect("read");
        let j = parse(reply.trim_end()).expect("reply parses");
        match Response::from_json(&j).expect("reply decodes") {
            Response::Admission(AdmissionReply {
                error: Some(AdmissionError::Rejected { reason }),
                ..
            }) => assert!(reason.contains("bad frame"), "reason: {reason}"),
            other => panic!("expected a frame rejection, got {other:?}"),
        }

        // Valid JSON with a bad version → typed rejection too.
        reply.clear();
        writer.write_all(b"{\"v\":99,\"type\":\"shutdown\"}\n").expect("write");
        reader.read_line(&mut reply).expect("read");
        let j = parse(reply.trim_end()).expect("reply parses");
        match Response::from_json(&j).expect("reply decodes") {
            Response::Admission(AdmissionReply {
                error: Some(AdmissionError::Rejected { reason }),
                ..
            }) => assert!(reason.contains("version"), "reason: {reason}"),
            other => panic!("expected a version rejection, got {other:?}"),
        }

        // The same connection still works for a real request.
        let mut client = ServiceClient {
            reader,
            writer,
            codec: Codec::Json,
            want: Codec::Json,
            bytes_sent: 0,
            bytes_recv: 0,
        };
        client.shutdown().expect("shutdown after garbage");
        server.join().expect("serve thread").expect("clean shutdown");
    }

    #[test]
    fn two_clients_share_one_frontend() {
        let (addr, server) = spawn_server(AggFrontend::new(2, 1));
        let cfg = HiSafeConfig::flat(3, TiePolicy::OneBit);
        let mut c1 = ServiceClient::connect(&addr).expect("connect c1");
        let mut c2 = ServiceClient::connect(&addr).expect("connect c2");
        let s1 = c1.open_session(cfg, 4, 1, QosPolicy::unlimited()).expect("admitted");
        let s2 = c2.open_session(cfg, 4, 2, QosPolicy::unlimited()).expect("admitted");
        assert_ne!(s1, s2, "sessions are distinct frontend-wide");
        // Each client sees both sessions in the frontend aggregate.
        let stats = c1.stats(None).expect("frontend stats");
        assert_eq!(stats.shard_tenants.expect("shards").iter().sum::<usize>(), 2);
        c1.shutdown().expect("shutdown");
        server.join().expect("serve thread").expect("clean shutdown");
    }

    #[test]
    fn many_idle_connections_share_two_workers() {
        // 32 connections on a 2-worker pool: connections must not cost
        // a serving thread each. The early clients go idle (but stay
        // connected) while later clients run full lifecycles; then the
        // idle ones prove they're still live. Under thread-per-connection
        // this test is vacuous; under the worker pool it pins that idle
        // connections neither starve active ones nor get dropped.
        let (addr, server) = spawn_server_with_workers(AggFrontend::new(2, 1), 2);
        let cfg = HiSafeConfig::flat(3, TiePolicy::OneBit);
        let mut clients: Vec<ServiceClient> =
            (0..32).map(|_| ServiceClient::connect(&addr).expect("connect")).collect();
        // The last few clients do real work while 28+ sit idle.
        for (i, client) in clients.iter_mut().enumerate().skip(28) {
            let sid = client
                .open_session(cfg, 4, i as u64, QosPolicy::unlimited())
                .expect("admitted");
            let signs = rand_signs(3, 4, i as u64);
            let vote = client.submit_round(sid, &signs).expect("round admitted");
            assert_eq!(vote.global_vote, plain_hierarchical_vote(&signs, cfg));
            client.close_session(sid).expect("close acked");
        }
        // The idle connections are still serviceable afterwards.
        for (i, client) in clients.iter_mut().enumerate().take(3) {
            let sid = client
                .open_session(cfg, 4, 100 + i as u64, QosPolicy::unlimited())
                .expect("idle connection still admitted");
            client.close_session(sid).expect("close acked");
        }
        clients[0].shutdown().expect("shutdown acked");
        server.join().expect("serve thread").expect("clean shutdown");
    }

    #[test]
    fn corrupt_binary_frames_are_contained_to_their_connection() {
        // Companion to the chaos harness (`service::faults`): a corrupt
        // or truncated binary frame arriving mid-session costs a typed
        // reject (bad payload) or the one guilty connection (bad
        // header, truncation) — never a worker, and never the other
        // connections multiplexed on the same 2-worker pool.
        let (addr, server) = spawn_server_with_workers(AggFrontend::new(2, 1), 2);
        let cfg = HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit);

        // A few idle connections that must stay serviceable throughout.
        let mut idle: Vec<ServiceClient> =
            (0..4).map(|_| ServiceClient::connect(&addr).expect("connect idle")).collect();

        // The victim connection: a real session, mid-lifecycle.
        let client = {
            let mut client = ServiceClient::connect(&addr).expect("connect");
            let sid = client.open_session(cfg, 5, 17, QosPolicy::unlimited()).expect("admitted");
            let signs = rand_signs(6, 5, 900);
            let vote = client.submit_round(sid, &signs).expect("round admitted");
            assert_eq!(vote.global_vote, plain_hierarchical_vote(&signs, cfg));

            // Mid-session, the same connection emits a binary frame
            // whose header is valid but whose payload is garbage: the
            // reply is a typed *binary* rejection (replies ride the
            // codec of the frame they answer) and the connection — and
            // session — stay up.
            let ServiceClient { mut reader, mut writer, .. } = client;
            writer.write_all(&binary::frame(&[0xEE, 0xEE, 0xEE])).expect("write bad payload");
            let mut hdr = [0u8; binary::HEADER_LEN];
            reader.read_exact(&mut hdr).expect("binary reply header");
            let len = binary::parse_header(&hdr).expect("reply header parses");
            let mut payload = vec![0u8; len];
            reader.read_exact(&mut payload).expect("binary reply payload");
            match binary::decode_response(&payload).expect("reply decodes") {
                Response::Admission(AdmissionReply { error: Some(_), .. }) => {}
                other => panic!("expected a typed binary rejection, got {other:?}"),
            }
            // Rebuild the client on the same streams; `sid` is live.
            let mut client = ServiceClient {
                reader,
                writer,
                codec: Codec::Json,
                want: Codec::Json,
                bytes_sent: 0,
                bytes_recv: 0,
            };

            // Prove the session survived before the other faults land.
            let signs = rand_signs(6, 5, 901);
            let vote = client.submit_round(sid, &signs).expect("round after bad payload");
            assert_eq!(vote.global_vote, plain_hierarchical_vote(&signs, cfg));
            (client, sid)
        };
        let (mut client, sid) = client;

        // A second connection truncates a frame and vanishes: the
        // header promises 64 bytes, 8 arrive, the peer hangs up.
        {
            let mut t = TcpStream::connect(&addr).expect("connect truncator");
            let mut frame = binary::frame(&[0u8; 64]);
            frame.truncate(binary::HEADER_LEN + 8);
            t.write_all(&frame).expect("write truncated frame");
        }

        // A third connection sends a corrupt header (bad version):
        // typed reject, then the server drops the connection — with no
        // trustworthy length there is no frame boundary to resync on.
        {
            let mut c = TcpStream::connect(&addr).expect("connect corruptor");
            c.set_read_timeout(Some(Duration::from_secs(10))).expect("timeout");
            c.write_all(&[binary::MAGIC, binary::VERSION + 7, 16, 0, 0, 0])
                .expect("write bad header");
            let mut rest = Vec::new();
            c.read_to_end(&mut rest).expect("reject then EOF");
            assert!(!rest.is_empty(), "a typed reject precedes the disconnect");
        }

        // Neither worker wedged: the victim keeps voting bit-identically
        // and every idle connection still serves.
        let signs = rand_signs(6, 5, 902);
        let vote = client.submit_round(sid, &signs).expect("round after the faults");
        assert_eq!(vote.global_vote, plain_hierarchical_vote(&signs, cfg));
        let stats = client.stats(Some(sid)).expect("session stats");
        assert_eq!(stats.rounds_run, 3, "the garbage frames billed nothing");
        client.close_session(sid).expect("close acked");
        for (i, c) in idle.iter_mut().enumerate() {
            let s = c
                .open_session(cfg, 5, 200 + i as u64, QosPolicy::unlimited())
                .expect("idle connection still admitted");
            c.close_session(s).expect("close acked");
        }
        client.shutdown().expect("shutdown acked");
        server.join().expect("serve thread").expect("clean shutdown");
    }

    #[test]
    fn binary_negotiation_switches_the_connection_and_votes_match() {
        let (addr, server) = spawn_server(AggFrontend::new(2, 1));
        let cfg = HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit);
        let mut client =
            ServiceClient::connect_with_codec(&addr, Codec::Binary).expect("connect");
        assert_eq!(client.codec(), Codec::Json, "negotiation starts on JSON");

        let sid = client.open_session(cfg, 5, 21, QosPolicy::unlimited()).expect("admitted");
        assert_eq!(client.codec(), Codec::Binary, "the granting reply carries the ack");
        client.prefetch(sid, 2).expect("prefetch over binary");
        for r in 0..3u64 {
            let signs = rand_signs(6, 5, 300 + r);
            let vote = client.submit_round(sid, &signs).expect("round admitted");
            assert_eq!(vote.global_vote, plain_hierarchical_vote(&signs, cfg));
        }
        // Churn rounds (mask present) and typed aborts cross the binary
        // wire too.
        let signs = rand_signs(6, 5, 310);
        let mask = vec![true, true, true, true, false, true];
        let vote = client.submit_round_present(sid, &signs, &mask).expect("churn admitted");
        let set = ParticipantSet::from_mask(mask);
        assert_eq!(vote.global_vote, plain_hierarchical_vote_present(&signs, &set, cfg));
        let starved = vec![true, true, true, false, false, true];
        match client.submit_round_present(sid, &signs, &starved) {
            Err(Error::Admission(AdmissionError::ChurnBelowThreshold { .. })) => {}
            other => panic!("expected a typed churn abort, got {other:?}"),
        }
        // Snapshot + stats round-trip the binary codec.
        let snap = client.snapshot_session(sid).expect("snapshot");
        assert_eq!(snap.rounds, 4);
        let stats = client.stats(Some(sid)).expect("stats");
        assert_eq!(stats.rounds_run, 4);
        assert!(client.bytes_sent() > 0 && client.bytes_received() > 0);

        // A plain-JSON client shares the server concurrently: the codec
        // is per-connection, not per-process.
        let mut old = ServiceClient::connect(&addr).expect("connect v1");
        let sid2 = old.open_session(cfg, 5, 22, QosPolicy::unlimited()).expect("admitted");
        assert_eq!(old.codec(), Codec::Json, "no ask, no switch");
        let signs = rand_signs(6, 5, 320);
        let v_old = old.submit_round(sid2, &signs).expect("round admitted");
        assert_eq!(v_old.global_vote, plain_hierarchical_vote(&signs, cfg));

        client.close_session(sid).expect("close over binary");
        client.shutdown().expect("shutdown over binary");
        server.join().expect("serve thread").expect("clean shutdown");
    }

    #[test]
    fn json_policy_server_keeps_binary_askers_on_json() {
        let server = ServiceServer::bind_with_workers(
            "127.0.0.1:0",
            AggFrontend::new(1, 1),
            DEFAULT_WORKERS,
        )
        .expect("bind")
        .with_codec(Codec::Json);
        let addr = server.local_addr().expect("bound addr").to_string();
        let handle = std::thread::spawn(move || server.serve());

        let cfg = HiSafeConfig::flat(3, TiePolicy::OneBit);
        let mut client =
            ServiceClient::connect_with_codec(&addr, Codec::Binary).expect("connect");
        let sid = client.open_session(cfg, 4, 5, QosPolicy::unlimited()).expect("admitted");
        assert_eq!(client.codec(), Codec::Json, "no ack from a JSON-policy server");
        let signs = rand_signs(3, 4, 50);
        let vote = client.submit_round(sid, &signs).expect("round admitted");
        assert_eq!(vote.global_vote, plain_hierarchical_vote(&signs, cfg));
        client.shutdown().expect("shutdown acked");
        handle.join().expect("serve thread").expect("clean shutdown");
    }

    #[test]
    fn concurrent_clients_make_progress_in_parallel() {
        // Two clients driving sessions on (very likely distinct) shards
        // from two threads: the wire path has no global frontend mutex,
        // so both streams of rounds complete with reference votes.
        let (addr, server) = spawn_server_with_workers(AggFrontend::new(2, 1), 4);
        let cfg = HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit);
        let drivers: Vec<_> = (0..2u64)
            .map(|k| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    let mut client = ServiceClient::connect(&addr).expect("connect");
                    let sid = client
                        .open_session(cfg, 5, 10 + k, QosPolicy::unlimited())
                        .expect("admitted");
                    for r in 0..4u64 {
                        let signs = rand_signs(6, 5, k * 100 + r);
                        let vote = client.submit_round(sid, &signs).expect("round admitted");
                        assert_eq!(vote.global_vote, plain_hierarchical_vote(&signs, cfg));
                    }
                    client.close_session(sid).expect("close acked");
                })
            })
            .collect();
        for d in drivers {
            d.join().expect("driver thread");
        }
        let mut client = ServiceClient::connect(&addr).expect("connect");
        client.shutdown().expect("shutdown acked");
        server.join().expect("serve thread").expect("clean shutdown");
    }
}
