//! The v2 **binary** wire codec: the same [`Request`]/[`Response`]
//! values as [`crate::service::proto`], length-prefix framed and
//! bit-packed instead of newline-delimited JSON.
//!
//! Frame layout:
//!
//! ```text
//! [MAGIC 0xB2] [VERSION 2] [payload len: u32 LE] [payload]
//! ```
//!
//! The magic byte is what lets one socket speak both codecs: every JSON
//! frame starts with `'{'` (0x7B — compact JSON is never
//! leading-whitespace), so the first byte of a frame decides the
//! decoder. Connections *start* in JSON; a peer opts into binary via
//! [`Codec`] negotiation on `SessionOpen`/`SessionRestore` (see
//! [`crate::service::proto`]), and the codec carries its own version
//! byte, so the JSON `"v":1` envelope never changes.
//!
//! Payload = `[message tag: u8][fields]`, with fixed primitive
//! encodings (all integers little-endian):
//!
//! * `u64` — 8 bytes (seeds, session ids via `as_u64`, counters, and
//!   every `usize`, so the encoding is identical on 32/64-bit hosts).
//! * `u32` — 4 bytes (counts, string/payload lengths, `weight`,
//!   `elem_bits`, subsecond nanos).
//! * `f64` — 8 bytes, IEEE-754 bit pattern (lossless, unlike JSON's
//!   shortest-round-trip printing it never even re-parses).
//! * `String` — u32 byte length + UTF-8 bytes.
//! * `Option<T>` — 1 flag byte (0 absent, 1 present) then `T`.
//! * **Sign vectors** — u32 coordinate count + a width byte `b ∈ {2, 3,
//!   4, 5}` + `b` bits per coordinate, packed LSB-first. `b = 2` is the
//!   sign alphabet (`00`=0, `01`=+1, `10`=−1, `11` rejected) — every
//!   q = 2 payload. `b > 2` carries quantized levels offset-encoded as
//!   `symbol = v + (2^(b−1) − 1)` (the all-ones symbol is out of range
//!   and rejected). Encoders MUST pick the minimal width for the row's
//!   largest |v| (b=3 covers |v| ≤ 3, b=4 ≤ 7, b=5 ≤ 15) and decoders
//!   reject wider-than-needed rows, so the encoding stays canonical.
//!   This is the hot-path payload (`RoundSubmit` is ~n*d/4 bytes at
//!   q = 2, ~n*d*b/8 at higher precisions — bytes scale with log2(q)).
//! * **Participant masks** — u32 entry count + 1 bit per entry.
//!
//! Packed tails must be zero-padded: the encoding is canonical (one
//! byte string per value), so decoders reject stray padding bits
//! instead of ignoring them.
//!
//! The decode surface returns the same [`ProtoError`] as the JSON
//! codec — the transport layer answers malformed binary frames with a
//! typed reply exactly like malformed JSON lines.

use crate::engine::{AdmissionError, QosPolicy, SessionId, SessionSnapshot};
use crate::metrics::CommStats;
use crate::poly::TiePolicy;
use crate::service::proto::{
    AdmissionReply, Codec, ProtoError, Request, Response, SessionListReply, SnapshotReply,
    StatsReply, VoteReply,
};

/// First byte of every binary frame. Never the first byte of a JSON
/// frame (those start with `'{'`), which is what makes per-frame codec
/// detection unambiguous on a mixed connection.
pub const MAGIC: u8 = 0xB2;

/// Binary framing version, carried in every frame header. Independent
/// of the JSON envelope's `"v":1` — bumping one does not bump the other.
/// v3 added the quantization fields: a `precision` byte in every config
/// and a width tag on every packed sign vector.
pub const VERSION: u8 = 3;

/// Bytes before the payload: magic + version + u32 length.
pub const HEADER_LEN: usize = 6;

/// Hard cap on a frame's payload, enforced on both encode (panic — the
/// caller built an impossible message) and decode (typed error — the
/// peer is broken or malicious; a bogus length must not trigger a
/// multi-gigabyte read). 64 MiB comfortably fits n=24 at d in the
/// hundreds of millions.
pub const MAX_FRAME: usize = 64 << 20;

fn perr(msg: impl Into<String>) -> ProtoError {
    ProtoError { msg: msg.into() }
}

/// Wrap a payload in the `[MAGIC][VERSION][len]` header.
///
/// # Panics
///
/// If the payload exceeds [`MAX_FRAME`] — encoding an over-cap message
/// is a caller bug, not a peer's.
pub fn frame(payload: &[u8]) -> Vec<u8> {
    assert!(
        payload.len() <= MAX_FRAME,
        "binary frame payload of {} bytes exceeds the {MAX_FRAME}-byte cap",
        payload.len()
    );
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
    out.push(MAGIC);
    out.push(VERSION);
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(payload);
    out
}

/// Validate a frame header (first [`HEADER_LEN`] bytes) and return the
/// payload length. Rejects a wrong magic, an unknown version, and a
/// length over [`MAX_FRAME`].
pub fn parse_header(hdr: &[u8]) -> Result<usize, ProtoError> {
    if hdr.len() < HEADER_LEN {
        return Err(perr(format!(
            "binary frame header needs {HEADER_LEN} bytes, got {}",
            hdr.len()
        )));
    }
    if hdr[0] != MAGIC {
        return Err(perr(format!(
            "bad binary frame magic {:#04x} (expected {MAGIC:#04x})",
            hdr[0]
        )));
    }
    if hdr[1] != VERSION {
        return Err(perr(format!(
            "unsupported binary framing version {} (this build speaks {VERSION})",
            hdr[1]
        )));
    }
    let len = u32::from_le_bytes([hdr[2], hdr[3], hdr[4], hdr[5]]) as usize;
    if len > MAX_FRAME {
        return Err(perr(format!(
            "binary frame payload of {len} bytes exceeds the {MAX_FRAME}-byte cap"
        )));
    }
    Ok(len)
}

/// The canonical (minimal) packing width for a vote row: 2 for sign
/// rows (|v| ≤ 1), else the smallest of {3, 4, 5} whose offset range
/// covers the row's largest |v|.
///
/// # Panics
///
/// On values outside `[−15, 15]` — the engines never produce them
/// (precision 16 caps levels at ±15), same contract as the JSON codec's
/// `signs_str`.
fn sign_width(signs: &[i8]) -> u8 {
    let max = signs.iter().map(|&v| v.unsigned_abs()).max().unwrap_or(0);
    match max {
        0..=1 => 2,
        2..=3 => 3,
        4..=7 => 4,
        8..=15 => 5,
        other => panic!("vote values must be in [-15, 15], got magnitude {other}"),
    }
}

// ---------------------------------------------------------------- encode

/// Payload writer: a `Vec<u8>` plus the primitive encodings the module
/// doc fixes. Everything is append-only, so encoding never fails (vote
/// values outside `[−15, 15]` panic, same contract as the JSON codec's
/// `signs_str`).
struct W {
    buf: Vec<u8>,
}

impl W {
    fn new(tag: u8) -> W {
        W { buf: vec![tag] }
    }

    fn u8(&mut self, x: u8) {
        self.buf.push(x);
    }

    fn u32(&mut self, x: u32) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }

    fn u64(&mut self, x: u64) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }

    fn usize(&mut self, x: usize) {
        self.u64(x as u64);
    }

    fn f64(&mut self, x: f64) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }

    fn str(&mut self, s: &str) {
        self.u32(u32::try_from(s.len()).expect("string too long for the wire"));
        self.buf.extend_from_slice(s.as_bytes());
    }

    fn flag(&mut self, present: bool) {
        self.u8(present as u8);
    }

    /// Sign vector: u32 count + width byte + `width` bits/coordinate,
    /// packed LSB-first with a zero-padded tail. The width is the
    /// minimal one for the row (see [`sign_width`]), so q = 2 rows
    /// always ride at the legacy 2 bits/coordinate.
    fn signs(&mut self, signs: &[i8]) {
        self.u32(u32::try_from(signs.len()).expect("sign vector too long for the wire"));
        let width = sign_width(signs);
        self.u8(width);
        let offset = (1i32 << (width - 1)) - 1;
        let mut acc = 0u32;
        let mut nbits = 0u32;
        for &s in signs {
            let sym = if width == 2 {
                match s {
                    0 => 0b00u32,
                    1 => 0b01,
                    -1 => 0b10,
                    _ => unreachable!("sign_width chose 2 for a non-sign value"),
                }
            } else {
                (s as i32 + offset) as u32
            };
            acc |= sym << nbits;
            nbits += width as u32;
            while nbits >= 8 {
                self.buf.push((acc & 0xff) as u8);
                acc >>= 8;
                nbits -= 8;
            }
        }
        if nbits > 0 {
            self.buf.push(acc as u8);
        }
    }

    /// Participant mask: u32 count + 1 bit/entry, low bits first,
    /// zero-padded tail.
    fn mask(&mut self, mask: &[bool]) {
        self.u32(u32::try_from(mask.len()).expect("mask too long for the wire"));
        let mut byte = 0u8;
        for (i, &p) in mask.iter().enumerate() {
            byte |= (p as u8) << (i & 7);
            if i & 7 == 7 {
                self.buf.push(byte);
                byte = 0;
            }
        }
        if mask.len() % 8 != 0 {
            self.buf.push(byte);
        }
    }

    fn sid(&mut self, sid: SessionId) {
        self.u64(sid.as_u64());
    }

    fn opt_sid(&mut self, sid: Option<SessionId>) {
        match sid {
            None => self.flag(false),
            Some(s) => {
                self.flag(true);
                self.sid(s);
            }
        }
    }

    fn tie(&mut self, t: TiePolicy) {
        self.u8(match t {
            TiePolicy::OneBit => 0,
            TiePolicy::TwoBit => 1,
        });
    }

    fn cfg(&mut self, cfg: &crate::protocol::HiSafeConfig) {
        self.usize(cfg.n);
        self.usize(cfg.ell);
        self.tie(cfg.intra);
        self.tie(cfg.inter);
        self.u8(cfg.sparse as u8);
        self.u8(cfg.precision);
    }

    fn qos(&mut self, qos: &QosPolicy) {
        self.u32(qos.weight);
        match qos.queue_depth {
            None => self.flag(false),
            Some(d) => {
                self.flag(true);
                self.usize(d);
            }
        }
        for rate in [qos.rounds_per_sec, qos.triples_per_sec] {
            match rate {
                None => self.flag(false),
                Some(r) => {
                    self.flag(true);
                    self.f64(r);
                }
            }
        }
        self.f64(qos.burst_rounds);
    }

    fn snapshot(&mut self, snap: &SessionSnapshot) {
        self.cfg(&snap.cfg);
        self.usize(snap.d);
        self.u64(snap.seed);
        self.qos(&snap.qos);
        self.u64(snap.rounds);
    }

    fn codec(&mut self, c: Option<Codec>) {
        match c {
            None => self.flag(false),
            Some(c) => {
                self.flag(true);
                self.u8(match c {
                    Codec::Json => 0,
                    Codec::Binary => 1,
                });
            }
        }
    }

    fn admission_error(&mut self, e: &AdmissionError) {
        match e {
            AdmissionError::Rejected { reason } => {
                self.u8(0);
                self.str(reason);
            }
            AdmissionError::Throttled { retry_after } => {
                self.u8(1);
                self.u64(retry_after.as_secs());
                self.u32(retry_after.subsec_nanos());
            }
            AdmissionError::QueueFull { depth } => {
                self.u8(2);
                self.usize(*depth);
            }
            AdmissionError::ChurnBelowThreshold { group, survivors, required } => {
                self.u8(3);
                self.usize(*group);
                self.usize(*survivors);
                self.usize(*required);
            }
        }
    }

    fn comm_stats(&mut self, s: &CommStats) {
        self.u64(s.uplink_elems_total);
        self.u64(s.uplink_elems_per_user);
        self.u64(s.downlink_elems);
        self.u32(s.elem_bits);
        self.u64(s.subrounds);
        self.u64(s.mults);
        self.u32(s.vote_bits);
    }

    fn finish(self) -> Vec<u8> {
        frame(&self.buf)
    }
}

/// Encode a request as a complete binary frame (header included).
pub fn encode_request(req: &Request) -> Vec<u8> {
    let mut w;
    match req {
        Request::SessionOpen { cfg, d, seed, qos, codec } => {
            w = W::new(1);
            w.cfg(cfg);
            w.usize(*d);
            w.u64(*seed);
            w.qos(qos);
            w.codec(*codec);
        }
        Request::RoundSubmit { session, signs, present } => {
            w = W::new(2);
            w.sid(*session);
            w.u32(u32::try_from(signs.len()).expect("too many sign rows for the wire"));
            for row in signs {
                w.signs(row);
            }
            match present {
                None => w.flag(false),
                Some(m) => {
                    w.flag(true);
                    w.mask(m);
                }
            }
        }
        Request::Prefetch { session, rounds } => {
            w = W::new(3);
            w.sid(*session);
            w.usize(*rounds);
        }
        Request::SessionClose { session } => {
            w = W::new(4);
            w.sid(*session);
        }
        Request::StatsQuery { session } => {
            w = W::new(5);
            w.opt_sid(*session);
        }
        Request::SessionSnapshot { session } => {
            w = W::new(6);
            w.sid(*session);
        }
        Request::SessionRestore { snapshot, codec } => {
            w = W::new(7);
            w.snapshot(snapshot);
            w.codec(*codec);
        }
        Request::SessionList => {
            w = W::new(9);
        }
        Request::SessionDiscard { session } => {
            w = W::new(10);
            w.sid(*session);
        }
        Request::Shutdown => {
            w = W::new(8);
        }
    }
    w.finish()
}

/// Encode a response as a complete binary frame (header included).
pub fn encode_response(resp: &Response) -> Vec<u8> {
    let mut w;
    match resp {
        Response::Vote(r) => {
            w = W::new(1);
            w.sid(r.session);
            w.signs(&r.global_vote);
            w.u32(u32::try_from(r.subgroup_votes.len()).expect("too many subgroups"));
            for row in &r.subgroup_votes {
                w.signs(row);
            }
            w.comm_stats(&r.stats);
        }
        Response::Admission(r) => {
            w = W::new(2);
            w.opt_sid(r.session);
            match &r.error {
                None => w.flag(false),
                Some(e) => {
                    w.flag(true);
                    w.admission_error(e);
                }
            }
            w.codec(r.codec);
        }
        Response::Stats(r) => {
            w = W::new(3);
            w.opt_sid(r.session);
            match r.shard {
                None => w.flag(false),
                Some(s) => {
                    w.flag(true);
                    w.usize(s);
                }
            }
            w.u64(r.rounds_run);
            w.u64(r.dealt_rounds);
            w.u64(r.admission.admitted_rounds);
            w.u64(r.admission.throttled);
            w.u64(r.admission.queue_full);
            w.u64(r.admission.rejected);
            match &r.shard_tenants {
                None => w.flag(false),
                Some(t) => {
                    w.flag(true);
                    w.u32(u32::try_from(t.len()).expect("too many shards"));
                    for &n in t {
                        w.usize(n);
                    }
                }
            }
        }
        Response::Snapshot(r) => {
            w = W::new(4);
            w.sid(r.session);
            w.snapshot(&r.snapshot);
        }
        Response::Sessions(r) => {
            w = W::new(5);
            w.u32(u32::try_from(r.sessions.len()).expect("too many listed sessions"));
            for e in &r.sessions {
                w.sid(e.session);
                w.snapshot(&e.snapshot);
            }
        }
    }
    w.finish()
}

// ---------------------------------------------------------------- decode

/// Payload reader: a cursor with typed takes. Every overrun is a
/// [`ProtoError`], and [`R::done`] rejects trailing bytes — a frame
/// either parses exactly or not at all.
struct R<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> R<'a> {
    fn new(buf: &'a [u8]) -> R<'a> {
        R { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], ProtoError> {
        if self.buf.len() - self.pos < n {
            return Err(perr(format!(
                "binary payload truncated: wanted {n} bytes at offset {}, frame has {}",
                self.pos,
                self.buf.len()
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, ProtoError> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, ProtoError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64, ProtoError> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    fn usize(&mut self) -> Result<usize, ProtoError> {
        usize::try_from(self.u64()?).map_err(|_| perr("integer does not fit this host's usize"))
    }

    fn f64(&mut self) -> Result<f64, ProtoError> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().expect("take(8) is 8 bytes")))
    }

    fn str(&mut self) -> Result<String, ProtoError> {
        let len = self.u32()? as usize;
        let bytes = self.take(len)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| perr("string field is not UTF-8"))
    }

    fn flag(&mut self) -> Result<bool, ProtoError> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            other => Err(perr(format!("option flag must be 0 or 1, got {other}"))),
        }
    }

    fn signs(&mut self) -> Result<Vec<i8>, ProtoError> {
        let n = self.u32()? as usize;
        let width = self.u8()?;
        if !(2..=5).contains(&width) {
            return Err(perr(format!("sign vector width must be in [2, 5], got {width}")));
        }
        let nbytes = (n * width as usize).div_ceil(8);
        let bytes = self.take(nbytes)?;
        let offset = (1i32 << (width - 1)) - 1;
        let mask = (1u32 << width) - 1;
        let mut v = Vec::with_capacity(n);
        let mut acc = 0u32;
        let mut nbits = 0u32;
        let mut bi = 0usize;
        for _ in 0..n {
            while nbits < width as u32 {
                acc |= (bytes[bi] as u32) << nbits;
                bi += 1;
                nbits += 8;
            }
            let sym = acc & mask;
            acc >>= width;
            nbits -= width as u32;
            v.push(if width == 2 {
                match sym {
                    0b00 => 0i8,
                    0b01 => 1,
                    0b10 => -1,
                    _ => return Err(perr("sign coordinate 0b11 is not in {-1, 0, +1}")),
                }
            } else {
                if sym == mask {
                    return Err(perr(format!(
                        "vote symbol {sym} is out of range for width {width}"
                    )));
                }
                (sym as i32 - offset) as i8
            });
        }
        // Exactly nbytes were consumed (the while-pull is need-driven),
        // and whatever is left in the accumulator is tail padding.
        if acc != 0 {
            return Err(perr("sign vector tail padding must be zero"));
        }
        // Canonicality: a width the row does not need is a stray
        // encoding of the same value — reject it like stray padding.
        if width > 2 {
            let needs = 1u8 << (width - 2); // 3→2, 4→4, 5→8
            if !v.iter().any(|&x| x.unsigned_abs() >= needs) {
                return Err(perr(format!(
                    "non-canonical sign vector: width {width} but no |vote| ≥ {needs}"
                )));
            }
        }
        Ok(v)
    }

    fn mask(&mut self) -> Result<Vec<bool>, ProtoError> {
        let n = self.u32()? as usize;
        let nbytes = n.div_ceil(8);
        let bytes = self.take(nbytes)?;
        let v = (0..n).map(|i| (bytes[i / 8] >> (i & 7)) & 1 == 1).collect();
        if n % 8 != 0 && bytes[nbytes - 1] >> (n % 8) != 0 {
            return Err(perr("participant mask tail padding must be zero"));
        }
        Ok(v)
    }

    fn sid(&mut self) -> Result<SessionId, ProtoError> {
        Ok(SessionId::new(self.u64()?))
    }

    fn opt_sid(&mut self) -> Result<Option<SessionId>, ProtoError> {
        Ok(if self.flag()? { Some(self.sid()?) } else { None })
    }

    fn tie(&mut self) -> Result<TiePolicy, ProtoError> {
        match self.u8()? {
            0 => Ok(TiePolicy::OneBit),
            1 => Ok(TiePolicy::TwoBit),
            other => Err(perr(format!("tie policy tag must be 0 or 1, got {other}"))),
        }
    }

    fn bool(&mut self) -> Result<bool, ProtoError> {
        self.flag()
    }

    fn cfg(&mut self) -> Result<crate::protocol::HiSafeConfig, ProtoError> {
        let n = self.usize()?;
        let ell = self.usize()?;
        let intra = self.tie()?;
        let inter = self.tie()?;
        let sparse = self.bool()?;
        let precision = self.u8()?;
        crate::quant::check_precision(precision).map_err(perr)?;
        Ok(crate::protocol::HiSafeConfig { n, ell, intra, inter, sparse, precision })
    }

    fn qos(&mut self) -> Result<QosPolicy, ProtoError> {
        Ok(QosPolicy {
            weight: self.u32()?,
            queue_depth: if self.flag()? { Some(self.usize()?) } else { None },
            rounds_per_sec: if self.flag()? { Some(self.f64()?) } else { None },
            triples_per_sec: if self.flag()? { Some(self.f64()?) } else { None },
            burst_rounds: self.f64()?,
        })
    }

    fn snapshot(&mut self) -> Result<SessionSnapshot, ProtoError> {
        Ok(SessionSnapshot {
            cfg: self.cfg()?,
            d: self.usize()?,
            seed: self.u64()?,
            qos: self.qos()?,
            rounds: self.u64()?,
        })
    }

    fn codec(&mut self) -> Result<Option<Codec>, ProtoError> {
        if !self.flag()? {
            return Ok(None);
        }
        match self.u8()? {
            0 => Ok(Some(Codec::Json)),
            1 => Ok(Some(Codec::Binary)),
            other => Err(perr(format!("codec tag must be 0 or 1, got {other}"))),
        }
    }

    fn admission_error(&mut self) -> Result<AdmissionError, ProtoError> {
        match self.u8()? {
            0 => Ok(AdmissionError::Rejected { reason: self.str()? }),
            1 => {
                let secs = self.u64()?;
                let nanos = self.u32()?;
                if nanos >= 1_000_000_000 {
                    return Err(perr("throttle subsecond nanos must be < 1e9"));
                }
                Ok(AdmissionError::Throttled {
                    retry_after: std::time::Duration::new(secs, nanos),
                })
            }
            2 => Ok(AdmissionError::QueueFull { depth: self.usize()? }),
            3 => Ok(AdmissionError::ChurnBelowThreshold {
                group: self.usize()?,
                survivors: self.usize()?,
                required: self.usize()?,
            }),
            other => Err(perr(format!("unknown admission error tag {other}"))),
        }
    }

    fn comm_stats(&mut self) -> Result<CommStats, ProtoError> {
        Ok(CommStats {
            uplink_elems_total: self.u64()?,
            uplink_elems_per_user: self.u64()?,
            downlink_elems: self.u64()?,
            elem_bits: self.u32()?,
            subrounds: self.u64()?,
            mults: self.u64()?,
            vote_bits: self.u32()?,
        })
    }

    fn done(self) -> Result<(), ProtoError> {
        if self.pos != self.buf.len() {
            return Err(perr(format!(
                "binary payload has {} trailing byte(s) after the message",
                self.buf.len() - self.pos
            )));
        }
        Ok(())
    }
}

/// Decode a request from a frame's *payload* (header already split off
/// and validated by [`parse_header`]).
pub fn decode_request(payload: &[u8]) -> Result<Request, ProtoError> {
    let mut r = R::new(payload);
    let req = match r.u8()? {
        1 => Request::SessionOpen {
            cfg: r.cfg()?,
            d: r.usize()?,
            seed: r.u64()?,
            qos: r.qos()?,
            codec: r.codec()?,
        },
        2 => {
            let session = r.sid()?;
            let rows = r.u32()? as usize;
            let mut signs = Vec::with_capacity(rows);
            for _ in 0..rows {
                signs.push(r.signs()?);
            }
            let present = if r.flag()? { Some(r.mask()?) } else { None };
            Request::RoundSubmit { session, signs, present }
        }
        3 => Request::Prefetch { session: r.sid()?, rounds: r.usize()? },
        4 => Request::SessionClose { session: r.sid()? },
        5 => Request::StatsQuery { session: r.opt_sid()? },
        6 => Request::SessionSnapshot { session: r.sid()? },
        7 => Request::SessionRestore { snapshot: r.snapshot()?, codec: r.codec()? },
        8 => Request::Shutdown,
        9 => Request::SessionList,
        10 => Request::SessionDiscard { session: r.sid()? },
        other => return Err(perr(format!("unknown binary request tag {other}"))),
    };
    r.done()?;
    Ok(req)
}

/// Decode a response from a frame's *payload* (header already split off
/// and validated by [`parse_header`]).
pub fn decode_response(payload: &[u8]) -> Result<Response, ProtoError> {
    let mut r = R::new(payload);
    let resp = match r.u8()? {
        1 => {
            let session = r.sid()?;
            let global_vote = r.signs()?;
            let groups = r.u32()? as usize;
            let mut subgroup_votes = Vec::with_capacity(groups);
            for _ in 0..groups {
                subgroup_votes.push(r.signs()?);
            }
            let stats = r.comm_stats()?;
            Response::Vote(VoteReply { session, global_vote, subgroup_votes, stats })
        }
        2 => {
            let session = r.opt_sid()?;
            let error = if r.flag()? { Some(r.admission_error()?) } else { None };
            let codec = r.codec()?;
            Response::Admission(AdmissionReply { session, error, codec })
        }
        3 => {
            let session = r.opt_sid()?;
            let shard = if r.flag()? { Some(r.usize()?) } else { None };
            let rounds_run = r.u64()?;
            let dealt_rounds = r.u64()?;
            let admission = crate::metrics::AdmissionStats {
                admitted_rounds: r.u64()?,
                throttled: r.u64()?,
                queue_full: r.u64()?,
                rejected: r.u64()?,
            };
            let shard_tenants = if r.flag()? {
                let k = r.u32()? as usize;
                let mut t = Vec::with_capacity(k);
                for _ in 0..k {
                    t.push(r.usize()?);
                }
                Some(t)
            } else {
                None
            };
            Response::Stats(StatsReply {
                session,
                shard,
                rounds_run,
                dealt_rounds,
                admission,
                shard_tenants,
            })
        }
        4 => Response::Snapshot(SnapshotReply { session: r.sid()?, snapshot: r.snapshot()? }),
        5 => {
            let n = r.u32()? as usize;
            let mut sessions = Vec::with_capacity(n);
            for _ in 0..n {
                sessions.push(SnapshotReply { session: r.sid()?, snapshot: r.snapshot()? });
            }
            Response::Sessions(SessionListReply { sessions })
        }
        other => return Err(perr(format!("unknown binary response tag {other}"))),
    };
    r.done()?;
    Ok(resp)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::poly::TiePolicy;
    use crate::protocol::HiSafeConfig;
    use crate::service::proto::testgen::{rand_request, rand_response, rand_sign_matrix};
    use crate::util::prop::forall;
    use crate::{prop_assert, prop_assert_eq};

    fn split(frame: &[u8]) -> &[u8] {
        let len = parse_header(frame).expect("valid header");
        assert_eq!(frame.len(), HEADER_LEN + len, "frame length matches its header");
        &frame[HEADER_LEN..]
    }

    #[test]
    fn every_request_round_trips_losslessly_in_binary() {
        // Same message distribution as the JSON round-trip property
        // (shared generators) — the two codecs must agree on what is
        // encodable, not just each round-trip alone.
        forall("binary requests round-trip", 80, |g| {
            let req = rand_request(g);
            let frame = encode_request(&req);
            let back = decode_request(split(&frame)).map_err(|e| e.to_string())?;
            prop_assert_eq!(&back, &req, "frame: {} bytes", frame.len());
            Ok(())
        });
    }

    #[test]
    fn every_response_round_trips_losslessly_in_binary() {
        forall("binary responses round-trip", 80, |g| {
            let resp = rand_response(g);
            let frame = encode_response(&resp);
            let back = decode_response(split(&frame)).map_err(|e| e.to_string())?;
            prop_assert_eq!(&back, &resp, "frame: {} bytes", frame.len());
            Ok(())
        });
    }

    #[test]
    fn cross_codec_agreement_on_random_messages() {
        // A message encoded in binary and decoded must re-encode in JSON
        // to exactly what the original encodes to (and vice versa): the
        // codecs are two encodings of ONE value space, not two protocols.
        forall("binary ∘ decode ≡ id under JSON re-encode", 40, |g| {
            let req = rand_request(g);
            let via_binary = decode_request(split(&encode_request(&req))).unwrap();
            prop_assert_eq!(
                via_binary.to_json().to_string_compact(),
                req.to_json().to_string_compact(),
                "JSON re-encode diverged"
            );
            let resp = rand_response(g);
            let via_binary = decode_response(split(&encode_response(&resp))).unwrap();
            prop_assert_eq!(
                via_binary.to_json().to_string_compact(),
                resp.to_json().to_string_compact(),
                "JSON re-encode diverged"
            );
            Ok(())
        });
    }

    #[test]
    fn header_gates_reject_foreign_and_oversize_frames() {
        let frame = encode_request(&Request::Shutdown);
        assert_eq!(frame[0], MAGIC);
        assert_eq!(frame[1], VERSION);
        assert_eq!(parse_header(&frame).unwrap(), 1, "shutdown payload is its tag byte");

        // Wrong magic — a JSON frame's first byte, for instance.
        let mut bad = frame.clone();
        bad[0] = b'{';
        assert!(parse_header(&bad).unwrap_err().msg.contains("magic"));
        // Unknown framing version (v2 frames lack the quant fields, so
        // the old version is as foreign as a future one).
        let mut bad = frame.clone();
        bad[1] = VERSION - 1;
        assert!(parse_header(&bad).unwrap_err().msg.contains("version"));
        let mut bad = frame.clone();
        bad[1] = VERSION + 1;
        assert!(parse_header(&bad).unwrap_err().msg.contains("version"));
        // A length past the cap must be refused before any read.
        let mut bad = frame.clone();
        bad[2..6].copy_from_slice(&(MAX_FRAME as u32 + 1).to_le_bytes());
        assert!(parse_header(&bad).unwrap_err().msg.contains("cap"));
        // Short header.
        assert!(parse_header(&frame[..4]).is_err());
    }

    #[test]
    fn malformed_payloads_are_typed_errors_not_panics() {
        // Unknown message tags.
        assert!(decode_request(&[99]).is_err());
        assert!(decode_response(&[99]).is_err());
        // Empty payload (no tag).
        assert!(decode_request(&[]).is_err());
        // Truncated mid-message.
        let frame = encode_request(&Request::Prefetch {
            session: crate::engine::SessionId::new(7),
            rounds: 3,
        });
        let payload = split(&frame);
        assert!(decode_request(&payload[..payload.len() - 1]).is_err());
        // Trailing bytes are rejected (canonical frames only).
        let mut padded = payload.to_vec();
        padded.push(0);
        assert!(decode_request(&padded).unwrap_err().msg.contains("trailing"));
        // The reserved sign bit-pair 0b11 is a decode error.
        let frame = encode_request(&Request::RoundSubmit {
            session: crate::engine::SessionId::new(1),
            signs: vec![vec![1, -1, 0, 1]],
            present: None,
        });
        let mut payload = split(&frame).to_vec();
        // Payload: tag(1) + sid(8) + rows(4) + count(4) + width(1) = 18
        // bytes before the packed sign byte.
        payload[18] = 0b1111_1111;
        assert!(decode_request(&payload).unwrap_err().msg.contains("0b11"));
        // A width outside [2, 5] is a decode error.
        let mut payload = split(&frame).to_vec();
        payload[17] = 6;
        assert!(decode_request(&payload).unwrap_err().msg.contains("width"));
        // Nonzero padding in a sign tail is non-canonical.
        let frame = encode_request(&Request::RoundSubmit {
            session: crate::engine::SessionId::new(1),
            signs: vec![vec![1]],
            present: None,
        });
        let mut payload = split(&frame).to_vec();
        *payload.last_mut().unwrap() |= 0b0100; // a bit past the 1 coordinate
        assert!(decode_request(&payload).unwrap_err().msg.contains("padding"));
    }

    #[test]
    fn binary_round_frames_are_at_least_three_times_smaller_than_json() {
        // The size claim the codec exists for: 2 bits/coordinate vs the
        // JSON sign-chars' 8 (plus quoting/commas), on a model-shaped
        // round at the paper's n=24. The asymptotic ratio is 4x; assert
        // a robust 3x so fixed per-frame overheads can't flake the test.
        forall("binary frames ≤ json/3 at model shape", 1, |g| {
            let cfg = HiSafeConfig::hierarchical(24, 8, TiePolicy::OneBit);
            let d = 2048;
            let req = Request::RoundSubmit {
                session: crate::engine::SessionId::new(3),
                signs: rand_sign_matrix(g, cfg.n, d),
                present: None,
            };
            let bin = encode_request(&req).len();
            let json = req.to_json().to_string_compact().len() + 1; // + newline delimiter
            prop_assert!(bin * 3 <= json, "RoundSubmit: {bin} vs {json} bytes");
            // And the reply shrinks the same way.
            let resp = Response::Vote(VoteReply {
                session: crate::engine::SessionId::new(3),
                global_vote: rand_sign_matrix(g, 1, d).remove(0),
                subgroup_votes: rand_sign_matrix(g, cfg.ell, d),
                stats: CommStats::default(),
            });
            let bin = encode_response(&resp).len();
            let json = resp.to_json().to_string_compact().len() + 1;
            prop_assert!(bin * 3 <= json, "VoteReply: {bin} vs {json} bytes");
            Ok(())
        });
    }

    #[test]
    fn quantized_vote_rows_round_trip_at_minimal_width() {
        // Each row rides at the minimal width for its largest |vote|:
        // sign rows keep the legacy 2 bits/coordinate, q = 16 rows pay 5.
        for (row, width) in [
            (vec![1i8, -1, 0, 1], 2u8),
            (vec![3, -2, 0, 1], 3),
            (vec![7, -4, 2, -1], 4),
            (vec![15, -15, 8, 0], 5),
        ] {
            let req = Request::RoundSubmit {
                session: crate::engine::SessionId::new(1),
                signs: vec![row.clone()],
                present: None,
            };
            let frame = encode_request(&req);
            let payload = split(&frame);
            assert_eq!(payload[17], width, "width tag for row {row:?}");
            assert_eq!(decode_request(payload).unwrap(), req);
        }
        // Mixed-width rows in one submit each carry their own tag.
        let req = Request::RoundSubmit {
            session: crate::engine::SessionId::new(1),
            signs: vec![vec![1, -1], vec![9, -9]],
            present: None,
        };
        assert_eq!(decode_request(split(&encode_request(&req))).unwrap(), req);

        // Canonicality: a wider-than-needed row is rejected like stray
        // padding. Hand-build a width-3 encoding of the pure-sign row
        // [+1, -1] (offset symbols 4 and 2 → bits 010_100 → 0x14).
        let mut w = W::new(2);
        w.sid(crate::engine::SessionId::new(1));
        w.u32(1); // one row
        w.u32(2); // two coordinates
        w.u8(3); // non-minimal width
        w.u8(0b010_100);
        w.flag(false); // no present mask
        let frame = w.finish();
        let err = decode_request(split(&frame)).unwrap_err();
        assert!(err.msg.contains("non-canonical"), "got: {err}");

        // The all-ones symbol (v = 2^(width−1), past the level range)
        // is out of range at every width > 2.
        let mut w = W::new(2);
        w.sid(crate::engine::SessionId::new(1));
        w.u32(1);
        w.u32(2);
        w.u8(3);
        w.u8(0b111_100); // symbols 4 (= +1) then 7 (all-ones)
        w.flag(false);
        let frame = w.finish();
        let err = decode_request(split(&frame)).unwrap_err();
        assert!(err.msg.contains("out of range"), "got: {err}");
    }

    #[test]
    fn u64_extremes_and_f64_bit_patterns_survive() {
        // The values JSON needs decimal-string workarounds for ride
        // natively here — pin the exact encodings.
        let req = Request::SessionOpen {
            cfg: HiSafeConfig::flat(3, TiePolicy::OneBit),
            d: 2,
            seed: u64::MAX,
            qos: QosPolicy::unlimited().with_rounds_per_sec(0.1 + 0.2), // not representable
            codec: Some(Codec::Binary),
        };
        let back = decode_request(split(&encode_request(&req))).unwrap();
        assert_eq!(back, req);
        match back {
            Request::SessionOpen { seed, qos, codec, .. } => {
                assert_eq!(seed, u64::MAX);
                assert_eq!(qos.rounds_per_sec.map(f64::to_bits), Some((0.1f64 + 0.2).to_bits()));
                assert_eq!(codec, Some(Codec::Binary));
            }
            other => panic!("wrong decode: {other:?}"),
        }
    }
}
