//! The versioned Hi-SAFE wire protocol: every request/response the
//! service layer speaks, as plain data with a lossless JSON encoding.
//!
//! Design rules, in order:
//!
//! 1. **Transport-agnostic.** Messages are values ([`Request`],
//!    [`Response`]) with `to_json` / `from_json` surfaces built on the
//!    in-house zero-dependency [`crate::util::json`]; nothing in this
//!    file knows about sockets. [`crate::service::server`] frames them
//!    as newline-delimited compact JSON over TCP, but any byte pipe
//!    (pipes, shared memory, an HTTP body) can carry them unchanged.
//! 2. **Lossless.** [`QosPolicy`], [`AdmissionError`], [`CommStats`],
//!    and [`AdmissionStats`] round-trip field-for-field, which is what
//!    lets `train_remote` be bit-identical to in-process `train`:
//!    * `u64` identifiers (seeds, session ids) and `Duration`s ride as
//!      **decimal strings** — [`crate::util::json::Json`] numbers are
//!      `f64`, which cannot represent every `u64` exactly.
//!    * Counters (round/element counts) ride as JSON numbers; they are
//!      exact below 2⁵³, far beyond any real run.
//!    * Sign and vote vectors ride as compact strings over `+`/`-`/`0`
//!      (one char per coordinate) — ~20x smaller than number arrays at
//!      model-sized `d`, and trivially lossless over `{-1, 0, +1}`.
//! 3. **Versioned.** Every message carries `"v":` [`PROTOCOL_VERSION`];
//!    decoding rejects other versions up front, so schema evolution is
//!    an explicit version bump instead of silent field drift (the key
//!    sets themselves are pinned by snapshot tests below). Adding NEW
//!    message types is deliberately *not* a version bump: an old peer
//!    rejects an unknown type with a typed error, every pre-existing
//!    message is byte-identical, and the snapshot tests pin the new
//!    types' key sets alongside the old (the
//!    `SessionSnapshot`/`SessionRestore` pair landed this way).
//!
//! The request vocabulary is deliberately the admission-control surface
//! of [`crate::engine::AggScheduler`] — `SessionOpen` ≈ `try_session`,
//! `RoundSubmit` ≈ `try_run_round`, `Prefetch` ≈ `try_prefetch` — so
//! typed backpressure ([`AdmissionError`]) crosses the wire unchanged
//! and a remote client retries throttles exactly like a local caller.
//!
//! JSON is the compatibility/debug codec, not the only one: the same
//! message values also have a length-prefixed binary encoding
//! ([`crate::service::binary`]). A connection starts in JSON and opts
//! into binary per-connection via the optional `codec` field on
//! `SessionOpen`/`SessionRestore` (absent ⇒ JSON, so every pre-codec
//! frame stays byte-identical); the server acks the switch on the
//! granting [`AdmissionReply`] and both sides speak binary from the
//! next frame on. See [`Codec`].

use std::fmt;
use std::time::Duration;

use crate::engine::{AdmissionError, QosPolicy, SessionId, SessionSnapshot};
use crate::metrics::{AdmissionStats, CommStats};
use crate::poly::TiePolicy;
use crate::protocol::HiSafeConfig;
use crate::util::json::Json;

/// Wire-protocol schema version. Bump on any incompatible change; the
/// decoder rejects every other version with a typed [`ProtoError`].
pub const PROTOCOL_VERSION: u64 = 1;

/// A message failed to decode (bad version, missing field, wrong type).
/// Distinct from [`AdmissionError`]: a `ProtoError` means the *bytes*
/// are wrong, not that the service declined a well-formed request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ProtoError {
    /// What was malformed, for logs and error replies.
    pub msg: String,
}

impl ProtoError {
    fn new(msg: impl Into<String>) -> ProtoError {
        ProtoError { msg: msg.into() }
    }
}

impl fmt::Display for ProtoError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "wire protocol error: {}", self.msg)
    }
}

impl std::error::Error for ProtoError {}

/// The two on-wire encodings a connection can speak. Every connection
/// starts in [`Codec::Json`] (newline-delimited compact JSON — the
/// compatibility/debug codec); a client that wants the length-prefixed
/// binary framing of [`crate::service::binary`] asks at
/// `SessionOpen`/`SessionRestore` via the optional `codec` field and
/// switches only when the granting [`AdmissionReply`] echoes it back,
/// so a JSON-only server silently keeps the connection on JSON.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Codec {
    /// Newline-delimited compact JSON (v1-compatible, human-readable).
    Json,
    /// Length-prefixed binary frames ([`crate::service::binary`]).
    Binary,
}

impl Codec {
    /// Stable wire/CLI name: `"json"` or `"binary"`.
    pub fn name(self) -> &'static str {
        match self {
            Codec::Json => "json",
            Codec::Binary => "binary",
        }
    }

    /// Inverse of [`Codec::name`].
    pub fn from_name(s: &str) -> Option<Codec> {
        match s {
            "json" => Some(Codec::Json),
            "binary" => Some(Codec::Binary),
            _ => None,
        }
    }
}

/// Client → service messages.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// Open a tenant session (the wire form of
    /// [`AggScheduler::try_session`](crate::engine::AggScheduler::try_session)).
    /// Placement across scheduler shards is the frontend's decision; the
    /// reply is an [`AdmissionReply`] carrying the granted session id or
    /// the typed rejection.
    SessionOpen {
        /// Protocol shape (users, subgroups, tie policies).
        cfg: HiSafeConfig,
        /// Vote dimension.
        d: usize,
        /// Session seed — drives all offline randomness, same derivation
        /// as every in-process engine, which is what keeps remote votes
        /// bit-identical.
        seed: u64,
        /// Per-tenant QoS, validated at admission like the local path.
        qos: QosPolicy,
        /// Requested wire codec for the rest of the connection. Absent ⇒
        /// stay on JSON, keeping every pre-codec frame byte-identical
        /// (an additive schema extension like `RoundSubmit::present`,
        /// not a version bump). The switch takes effect only when the
        /// granting [`AdmissionReply`] echoes it back.
        codec: Option<Codec>,
    },
    /// Run one aggregation round (the wire form of
    /// [`AggSession::try_run_round`](crate::engine::AggSession::try_run_round)):
    /// answered with a [`VoteReply`] on admission or an
    /// [`AdmissionReply`] carrying `Throttled` for the client to retry.
    RoundSubmit {
        /// Session id granted by `SessionOpen`.
        session: SessionId,
        /// `signs[i]` is user `i`'s sign vector over `{-1, 0, +1}`,
        /// length `d`. The matrix always keeps its full `n`-row shape;
        /// rows of absent users (see `present`) are ignored.
        signs: Vec<Vec<i8>>,
        /// Per-round participant mask, one entry per registered user
        /// (`present[i]` ⇔ user `i` answered), riding as a compact
        /// `'1'`/`'0'` string. **Absent ⇒ all-present** — the v1
        /// compatibility rule: pre-churn peers never emit the key, and
        /// their frames decode (and execute) exactly as before, so this
        /// field is an additive schema extension, not a version bump.
        /// The key is also omitted when the value is `None`, keeping
        /// all-present frames byte-identical to v1.
        present: Option<Vec<bool>>,
    },
    /// Queue `rounds` rounds of Beaver-triple dealing without blocking
    /// (the wire form of
    /// [`AggSession::try_prefetch`](crate::engine::AggSession::try_prefetch)).
    Prefetch {
        /// Session id granted by `SessionOpen`.
        session: SessionId,
        /// Rounds of dealing to queue.
        rounds: usize,
    },
    /// Close a session: frees its shard slot immediately and folds its
    /// admission counters into the frontend-wide aggregate.
    SessionClose {
        /// Session id granted by `SessionOpen`.
        session: SessionId,
    },
    /// Read admission/scheduling counters: for one session
    /// (`Some(id)`), or frontend-wide (`None` — merged across every
    /// shard, plus per-shard tenant counts).
    StatsQuery {
        /// Session scope, or `None` for the whole frontend.
        session: Option<SessionId>,
    },
    /// Read a session's serializable [`SessionSnapshot`] — everything a
    /// balancer needs to re-place the session on another host
    /// bit-identically (answered with [`Response::Snapshot`]).
    SessionSnapshot {
        /// Session id granted by `SessionOpen`.
        session: SessionId,
    },
    /// Resume a snapshotted session on *this* host: admission runs like
    /// `SessionOpen`, then the dealers fast-forward by `snapshot.rounds`
    /// whole rounds (the wire form of
    /// [`try_session_resumed`](crate::engine::AggScheduler::try_session_resumed)).
    /// Answered with an [`AdmissionReply`] carrying the NEW session id.
    SessionRestore {
        /// The snapshot to replay (from [`Request::SessionSnapshot`], or
        /// tracked balancer-side).
        snapshot: SessionSnapshot,
        /// Requested wire codec, same negotiation rule as
        /// `SessionOpen`'s — restores are how a balancer opens backend
        /// sessions, so the backend leg negotiates here.
        codec: Option<Codec>,
    },
    /// List every live session as `(id, snapshot)` pairs (answered with
    /// [`Response::Sessions`]). This is the cluster-recovery sweep: a
    /// restarted balancer rebuilds its session table from each host's
    /// list, and a revived host is reconciled against it (stale backend
    /// sessions discarded, stranded tenants re-placed). A new message
    /// type, not a version bump — an old peer answers with a typed
    /// `unknown request type` rejection, which recovery treats as "no
    /// list available".
    SessionList,
    /// Drop a session *without* folding its counters into the
    /// frontend-wide aggregate — the reconciliation twin of
    /// [`Request::SessionClose`]. Used for a stale copy whose tenant was
    /// restored elsewhere: the restored session's counters are
    /// continuous (they include every pre-failover round), so folding
    /// the stale copy too would double-count its rounds in
    /// cluster-level stats.
    SessionDiscard {
        /// Session id granted by `SessionOpen`.
        session: SessionId,
    },
    /// Ask the server process to stop accepting connections and exit
    /// its serve loop (acknowledged with an empty [`AdmissionReply`]).
    /// Open sessions are dropped; this is the clean-shutdown path the
    /// CI smoke test exercises.
    Shutdown,
}

/// Service → client messages.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    /// A round was admitted and executed.
    Vote(VoteReply),
    /// Admission-layer outcome for everything that isn't a vote:
    /// session grants, prefetch/close acks, and every typed denial.
    Admission(AdmissionReply),
    /// Counters for a `StatsQuery`.
    Stats(StatsReply),
    /// A session's serializable state, for `Request::SessionSnapshot`.
    Snapshot(SnapshotReply),
    /// Every live session's `(id, snapshot)` pair, for
    /// `Request::SessionList` (each entry is exactly a
    /// [`SnapshotReply`]'s payload).
    Sessions(SessionListReply),
}

/// One admitted round's outcome — the wire form of
/// [`EngineOutcome`](crate::engine::EngineOutcome) (no transcripts, like
/// the in-process engines).
#[derive(Debug, Clone, PartialEq)]
pub struct VoteReply {
    /// Session the round ran on.
    pub session: SessionId,
    /// Global vote per coordinate (`{-1, +1}`, or 0 under inter TwoBit).
    pub global_vote: Vec<i8>,
    /// Subgroup votes `s_j` (the Theorem-2 leakage, same as local).
    pub subgroup_votes: Vec<Vec<i8>>,
    /// Per-round communication counters, identical to the in-process
    /// engine's (the wire adds transport bytes, not protocol cost).
    pub stats: CommStats,
}

/// Admission-layer outcome: a grant (`session` set, `error` empty), a
/// plain ack (both empty), or a typed denial (`error` set —
/// [`AdmissionError`] crossing the wire unchanged, so remote callers
/// retry `Throttled` exactly like local ones).
#[derive(Debug, Clone, PartialEq)]
pub struct AdmissionReply {
    /// Granted/echoed session id, when the request targeted one.
    pub session: Option<SessionId>,
    /// The typed denial, absent on success.
    pub error: Option<AdmissionError>,
    /// Codec acknowledgement: set by the server only on a *granting*
    /// reply to a request that asked for a codec the server speaks.
    /// After writing (server) / reading (client) a reply carrying
    /// `Some(c)`, that side's next frame is encoded in `c`. Denials
    /// never ack — a retried open renegotiates.
    pub codec: Option<Codec>,
}

impl AdmissionReply {
    /// A plain success ack (optionally echoing the session id).
    pub fn ok(session: Option<SessionId>) -> AdmissionReply {
        AdmissionReply { session, error: None, codec: None }
    }

    /// A typed denial.
    pub fn denied(session: Option<SessionId>, error: AdmissionError) -> AdmissionReply {
        AdmissionReply { session, error: Some(error), codec: None }
    }
}

/// Counters for a `StatsQuery`. Session scope fills `session` + `shard`;
/// frontend scope fills `shard_tenants` (one entry per shard) and merges
/// `admission` across every live session *and* every closed one (the
/// frontend keeps a fold of closed sessions' counters), so the aggregate
/// survives tenant churn.
#[derive(Debug, Clone, PartialEq)]
pub struct StatsReply {
    /// The queried session, absent for frontend scope.
    pub session: Option<SessionId>,
    /// Shard the session lives on, absent for frontend scope.
    pub shard: Option<usize>,
    /// Rounds executed (session scope) or summed over live sessions.
    pub rounds_run: u64,
    /// Rounds the provisioning plane dealt (same scoping).
    pub dealt_rounds: u64,
    /// Admission counters ([`AdmissionStats::merge_all`] across shards
    /// for frontend scope).
    pub admission: AdmissionStats,
    /// Live tenants per shard, frontend scope only.
    pub shard_tenants: Option<Vec<usize>>,
}

/// A session's serializable state — the answer to
/// [`Request::SessionSnapshot`], and the payload a balancer replays via
/// [`Request::SessionRestore`] to re-place the session on another host
/// with bit-identical votes.
#[derive(Debug, Clone, PartialEq)]
pub struct SnapshotReply {
    /// The snapshotted session's id.
    pub session: SessionId,
    /// Everything needed to resume it elsewhere.
    pub snapshot: SessionSnapshot,
}

/// Every live session's restorable state — the answer to
/// [`Request::SessionList`]. A balancer rebuilding after a restart
/// sweeps this off every host; the revive path reconciles a returning
/// host's list against the balancer's own table.
#[derive(Debug, Clone, PartialEq)]
pub struct SessionListReply {
    /// One `(id, snapshot)` entry per live session, in session-id order.
    pub sessions: Vec<SnapshotReply>,
}

// ---------------------------------------------------------------- encode

fn base(msg_type: &str) -> Json {
    let mut j = Json::obj();
    j.set("v", PROTOCOL_VERSION).set("type", msg_type);
    j
}

/// `u64` as a decimal string — `Json::Num` is `f64` and loses integers
/// above 2⁵³, and seeds/session ids must survive the wire bit-exactly.
fn u64_str(x: u64) -> Json {
    Json::Str(x.to_string())
}

/// A [`SessionId`] in its wire form — the decimal string its `Display`
/// defines (see the `u64_str` rationale above).
fn sid_json(sid: SessionId) -> Json {
    Json::Str(sid.to_string())
}

/// A sign/vote vector as one char per coordinate: `+` / `-` / `0` for
/// the legacy sign alphabet, and for q-level quantized payloads the
/// extension `'A' + (v − 2)` for `v ∈ [2, 15]` / `'a' + (−v − 2)` for
/// `v ∈ [−15, −2]`. The encoding is self-describing (each char carries
/// its own value), so q = 2 vectors are byte-identical to the pre-quant
/// wire form and decoders need no precision context.
///
/// # Panics
///
/// On values outside `[−15, 15]` — the engines never produce them
/// (precision 16 caps levels at ±15), and a client submitting them has
/// a bug this surfaces loudly.
fn signs_str(signs: &[i8]) -> Json {
    let s: String = signs
        .iter()
        .map(|&v| match v {
            1 => '+',
            -1 => '-',
            0 => '0',
            2..=15 => (b'A' + (v as u8 - 2)) as char,
            -15..=-2 => (b'a' + ((-v) as u8 - 2)) as char,
            other => panic!("vote values must be in [-15, 15], got {other}"),
        })
        .collect();
    Json::Str(s)
}

/// A participant mask as one char per user: `'1'` present, `'0'` absent.
/// Same compact-string idiom as [`signs_str`] — the mask is per-round
/// hot-path payload, so it rides as `n` bytes, not an `n`-element array.
fn mask_str(mask: &[bool]) -> Json {
    Json::Str(mask.iter().map(|&p| if p { '1' } else { '0' }).collect())
}

fn qos_json(qos: &QosPolicy) -> Json {
    let opt_f64 = |v: Option<f64>| v.map(Json::Num).unwrap_or(Json::Null);
    let mut j = Json::obj();
    j.set("weight", qos.weight)
        .set(
            "queue_depth",
            qos.queue_depth.map(|d| Json::Num(d as f64)).unwrap_or(Json::Null),
        )
        .set("rounds_per_sec", opt_f64(qos.rounds_per_sec))
        .set("triples_per_sec", opt_f64(qos.triples_per_sec))
        .set("burst_rounds", Json::Num(qos.burst_rounds));
    j
}

fn cfg_json(cfg: &HiSafeConfig) -> Json {
    let mut j = Json::obj();
    j.set("n", cfg.n)
        .set("ell", cfg.ell)
        .set("intra", cfg.intra.name())
        .set("inter", cfg.inter.name())
        .set("sparse", cfg.sparse);
    // Omitted at the sign-vote default so q = 2 configs stay
    // byte-identical to the pre-quant wire form (v1 compat).
    if cfg.precision != 2 {
        j.set("precision", cfg.precision as usize);
    }
    j
}

/// A [`SessionSnapshot`]'s fields, flattened into `j` alongside the
/// message envelope (the same `cfg`/`d`/`seed`/`qos` encodings
/// `SessionOpen` uses; `rounds` rides as a decimal string because the
/// fast-forward distance must survive the wire bit-exactly).
fn set_snapshot_fields(j: &mut Json, snap: &SessionSnapshot) {
    j.set("cfg", cfg_json(&snap.cfg))
        .set("d", snap.d)
        .set("seed", u64_str(snap.seed))
        .set("qos", qos_json(&snap.qos))
        .set("rounds", u64_str(snap.rounds));
}

/// [`AdmissionError`] on the wire: a `kind` tag plus the variant's
/// payload. `Throttled`'s `Duration` splits into whole seconds (decimal
/// string, lossless for any `u64`) and subsecond nanos (a number — `u32`
/// is exact in `f64`).
fn admission_error_json(e: &AdmissionError) -> Json {
    let mut j = Json::obj();
    match e {
        AdmissionError::Rejected { reason } => {
            j.set("kind", "rejected").set("reason", reason.clone());
        }
        AdmissionError::Throttled { retry_after } => {
            j.set("kind", "throttled")
                .set("retry_after_secs", u64_str(retry_after.as_secs()))
                .set("retry_after_subsec_ns", retry_after.subsec_nanos() as u64);
        }
        AdmissionError::QueueFull { depth } => {
            j.set("kind", "queue_full").set("depth", *depth);
        }
        AdmissionError::ChurnBelowThreshold { group, survivors, required } => {
            j.set("kind", "churn_below_threshold")
                .set("group", *group)
                .set("survivors", *survivors)
                .set("required", *required);
        }
    }
    j
}

impl Request {
    /// Encode for the wire. Infallible: every `Request` value has a wire
    /// form (sign vectors outside `{-1, 0, +1}` panic — see
    /// [`signs_str`]'s contract).
    pub fn to_json(&self) -> Json {
        match self {
            Request::SessionOpen { cfg, d, seed, qos, codec } => {
                let mut j = base("session_open");
                j.set("cfg", cfg_json(cfg))
                    .set("d", *d)
                    .set("seed", u64_str(*seed))
                    .set("qos", qos_json(qos));
                if let Some(c) = codec {
                    j.set("codec", c.name());
                }
                j
            }
            Request::RoundSubmit { session, signs, present } => {
                let mut j = base("round_submit");
                j.set("session", sid_json(*session)).set(
                    "signs",
                    Json::Arr(signs.iter().map(|s| signs_str(s)).collect()),
                );
                if let Some(mask) = present {
                    j.set("present", mask_str(mask));
                }
                j
            }
            Request::Prefetch { session, rounds } => {
                let mut j = base("prefetch");
                j.set("session", sid_json(*session)).set("rounds", *rounds);
                j
            }
            Request::SessionClose { session } => {
                let mut j = base("session_close");
                j.set("session", sid_json(*session));
                j
            }
            Request::StatsQuery { session } => {
                let mut j = base("stats_query");
                if let Some(sid) = session {
                    j.set("session", sid_json(*sid));
                }
                j
            }
            Request::SessionSnapshot { session } => {
                let mut j = base("session_snapshot");
                j.set("session", sid_json(*session));
                j
            }
            Request::SessionRestore { snapshot, codec } => {
                let mut j = base("session_restore");
                set_snapshot_fields(&mut j, snapshot);
                if let Some(c) = codec {
                    j.set("codec", c.name());
                }
                j
            }
            Request::SessionList => base("session_list"),
            Request::SessionDiscard { session } => {
                let mut j = base("session_discard");
                j.set("session", sid_json(*session));
                j
            }
            Request::Shutdown => base("shutdown"),
        }
    }

    /// Decode from the wire, rejecting unknown versions and message
    /// types with a [`ProtoError`].
    pub fn from_json(j: &Json) -> Result<Request, ProtoError> {
        check_version(j)?;
        match msg_type(j)? {
            "session_open" => Ok(Request::SessionOpen {
                cfg: parse_cfg(field(j, "cfg")?)?,
                d: parse_usize(j, "d")?,
                seed: parse_u64_str(j, "seed")?,
                qos: parse_qos(field(j, "qos")?)?,
                codec: parse_codec(j)?,
            }),
            "round_submit" => {
                let arr = field(j, "signs")?
                    .as_arr()
                    .ok_or_else(|| ProtoError::new("'signs' must be an array"))?;
                let signs = arr
                    .iter()
                    .map(parse_signs)
                    .collect::<Result<Vec<Vec<i8>>, ProtoError>>()?;
                let present = match j.get("present") {
                    None => None,
                    Some(v) => Some(parse_mask(v)?),
                };
                Ok(Request::RoundSubmit { session: parse_sid(j, "session")?, signs, present })
            }
            "prefetch" => Ok(Request::Prefetch {
                session: parse_sid(j, "session")?,
                rounds: parse_usize(j, "rounds")?,
            }),
            "session_close" => {
                Ok(Request::SessionClose { session: parse_sid(j, "session")? })
            }
            "stats_query" => Ok(Request::StatsQuery {
                session: match j.get("session") {
                    None => None,
                    Some(_) => Some(parse_sid(j, "session")?),
                },
            }),
            "session_snapshot" => {
                Ok(Request::SessionSnapshot { session: parse_sid(j, "session")? })
            }
            "session_restore" => Ok(Request::SessionRestore {
                snapshot: parse_snapshot(j)?,
                codec: parse_codec(j)?,
            }),
            "session_list" => Ok(Request::SessionList),
            "session_discard" => {
                Ok(Request::SessionDiscard { session: parse_sid(j, "session")? })
            }
            "shutdown" => Ok(Request::Shutdown),
            other => Err(ProtoError::new(format!("unknown request type '{other}'"))),
        }
    }
}

impl Response {
    /// Encode for the wire.
    pub fn to_json(&self) -> Json {
        match self {
            Response::Vote(r) => {
                let mut j = base("vote_reply");
                j.set("session", sid_json(r.session))
                    .set("global_vote", signs_str(&r.global_vote))
                    .set(
                        "subgroup_votes",
                        Json::Arr(r.subgroup_votes.iter().map(|s| signs_str(s)).collect()),
                    )
                    .set("stats", r.stats.to_json());
                j
            }
            Response::Admission(r) => {
                let mut j = base("admission_reply");
                if let Some(sid) = r.session {
                    j.set("session", sid_json(sid));
                }
                if let Some(e) = &r.error {
                    j.set("error", admission_error_json(e));
                }
                if let Some(c) = r.codec {
                    j.set("codec", c.name());
                }
                j
            }
            Response::Stats(r) => {
                let mut j = base("stats_reply");
                if let Some(sid) = r.session {
                    j.set("session", sid_json(sid));
                }
                if let Some(shard) = r.shard {
                    j.set("shard", shard);
                }
                j.set("rounds_run", r.rounds_run)
                    .set("dealt_rounds", r.dealt_rounds)
                    .set("admission", r.admission.to_json());
                if let Some(tenants) = &r.shard_tenants {
                    j.set("shard_tenants", tenants.clone());
                }
                j
            }
            Response::Snapshot(r) => {
                let mut j = base("snapshot_reply");
                j.set("session", sid_json(r.session));
                set_snapshot_fields(&mut j, &r.snapshot);
                j
            }
            Response::Sessions(r) => {
                let mut j = base("session_list_reply");
                let entries = r
                    .sessions
                    .iter()
                    .map(|e| {
                        // Each entry is a SnapshotReply's payload without
                        // the message envelope: the session id plus the
                        // flattened snapshot fields.
                        let mut entry = Json::obj();
                        entry.set("session", sid_json(e.session));
                        set_snapshot_fields(&mut entry, &e.snapshot);
                        entry
                    })
                    .collect();
                j.set("sessions", Json::Arr(entries));
                j
            }
        }
    }

    /// Decode from the wire, rejecting unknown versions and message
    /// types with a [`ProtoError`].
    pub fn from_json(j: &Json) -> Result<Response, ProtoError> {
        check_version(j)?;
        match msg_type(j)? {
            "vote_reply" => {
                let votes_arr = field(j, "subgroup_votes")?
                    .as_arr()
                    .ok_or_else(|| ProtoError::new("'subgroup_votes' must be an array"))?;
                Ok(Response::Vote(VoteReply {
                    session: parse_sid(j, "session")?,
                    global_vote: parse_signs(field(j, "global_vote")?)?,
                    subgroup_votes: votes_arr
                        .iter()
                        .map(parse_signs)
                        .collect::<Result<Vec<Vec<i8>>, ProtoError>>()?,
                    stats: parse_comm_stats(field(j, "stats")?)?,
                }))
            }
            "admission_reply" => Ok(Response::Admission(AdmissionReply {
                session: match j.get("session") {
                    None => None,
                    Some(_) => Some(parse_sid(j, "session")?),
                },
                error: match j.get("error") {
                    None => None,
                    Some(e) => Some(parse_admission_error(e)?),
                },
                codec: parse_codec(j)?,
            })),
            "stats_reply" => Ok(Response::Stats(StatsReply {
                session: match j.get("session") {
                    None => None,
                    Some(_) => Some(parse_sid(j, "session")?),
                },
                shard: match j.get("shard") {
                    None => None,
                    Some(_) => Some(parse_usize(j, "shard")?),
                },
                rounds_run: parse_u64_num(j, "rounds_run")?,
                dealt_rounds: parse_u64_num(j, "dealt_rounds")?,
                admission: parse_admission_stats(field(j, "admission")?)?,
                shard_tenants: match j.get("shard_tenants") {
                    None => None,
                    Some(t) => {
                        let arr = t
                            .as_arr()
                            .ok_or_else(|| ProtoError::new("'shard_tenants' must be an array"))?;
                        Some(
                            arr.iter()
                                .map(|v| {
                                    v.as_usize().ok_or_else(|| {
                                        ProtoError::new("'shard_tenants' entries must be integers")
                                    })
                                })
                                .collect::<Result<Vec<usize>, ProtoError>>()?,
                        )
                    }
                },
            })),
            "snapshot_reply" => Ok(Response::Snapshot(SnapshotReply {
                session: parse_sid(j, "session")?,
                snapshot: parse_snapshot(j)?,
            })),
            "session_list_reply" => {
                let arr = field(j, "sessions")?
                    .as_arr()
                    .ok_or_else(|| ProtoError::new("'sessions' must be an array"))?;
                let sessions = arr
                    .iter()
                    .map(|e| {
                        Ok(SnapshotReply {
                            session: parse_sid(e, "session")?,
                            snapshot: parse_snapshot(e)?,
                        })
                    })
                    .collect::<Result<Vec<SnapshotReply>, ProtoError>>()?;
                Ok(Response::Sessions(SessionListReply { sessions }))
            }
            other => Err(ProtoError::new(format!("unknown response type '{other}'"))),
        }
    }
}

// ---------------------------------------------------------------- decode

fn check_version(j: &Json) -> Result<(), ProtoError> {
    match j.get("v").and_then(Json::as_u64) {
        Some(PROTOCOL_VERSION) => Ok(()),
        Some(v) => Err(ProtoError::new(format!(
            "unsupported protocol version {v} (this build speaks {PROTOCOL_VERSION})"
        ))),
        None => Err(ProtoError::new("missing protocol version field 'v'")),
    }
}

fn msg_type(j: &Json) -> Result<&str, ProtoError> {
    field(j, "type")?
        .as_str()
        .ok_or_else(|| ProtoError::new("'type' must be a string"))
}

fn field<'a>(j: &'a Json, key: &str) -> Result<&'a Json, ProtoError> {
    j.get(key).ok_or_else(|| ProtoError::new(format!("missing field '{key}'")))
}

fn parse_u64_str(j: &Json, key: &str) -> Result<u64, ProtoError> {
    field(j, key)?
        .as_str()
        .and_then(|s| s.parse::<u64>().ok())
        .ok_or_else(|| ProtoError::new(format!("'{key}' must be a decimal-string u64")))
}

/// A [`SessionId`] from its decimal-string wire form (its `FromStr`).
fn parse_sid(j: &Json, key: &str) -> Result<SessionId, ProtoError> {
    field(j, key)?
        .as_str()
        .and_then(|s| s.parse::<SessionId>().ok())
        .ok_or_else(|| ProtoError::new(format!("'{key}' must be a decimal-string session id")))
}

fn parse_u64_num(j: &Json, key: &str) -> Result<u64, ProtoError> {
    field(j, key)?
        .as_u64()
        .ok_or_else(|| ProtoError::new(format!("'{key}' must be a non-negative integer")))
}

fn parse_usize(j: &Json, key: &str) -> Result<usize, ProtoError> {
    parse_u64_num(j, key).map(|x| x as usize)
}

fn parse_f64(j: &Json, key: &str) -> Result<f64, ProtoError> {
    field(j, key)?
        .as_f64()
        .ok_or_else(|| ProtoError::new(format!("'{key}' must be a number")))
}

fn parse_opt_f64(j: &Json, key: &str) -> Result<Option<f64>, ProtoError> {
    match field(j, key)? {
        Json::Null => Ok(None),
        Json::Num(x) => Ok(Some(*x)),
        _ => Err(ProtoError::new(format!("'{key}' must be a number or null"))),
    }
}

fn parse_signs(v: &Json) -> Result<Vec<i8>, ProtoError> {
    let s = v.as_str().ok_or_else(|| ProtoError::new("sign vector must be a string"))?;
    s.chars()
        .map(|c| match c {
            '+' => Ok(1i8),
            '-' => Ok(-1i8),
            '0' => Ok(0i8),
            'A'..='N' => Ok((c as u8 - b'A') as i8 + 2),
            'a'..='n' => Ok(-((c as u8 - b'a') as i8 + 2)),
            other => Err(ProtoError::new(format!(
                "sign vectors are strings over '+', '-', '0', 'A'-'N', 'a'-'n'; got {other:?}"
            ))),
        })
        .collect()
}

fn parse_mask(v: &Json) -> Result<Vec<bool>, ProtoError> {
    let s = v
        .as_str()
        .ok_or_else(|| ProtoError::new("participant mask must be a string"))?;
    s.chars()
        .map(|c| match c {
            '1' => Ok(true),
            '0' => Ok(false),
            other => Err(ProtoError::new(format!(
                "participant masks are strings over '1', '0'; got {other:?}"
            ))),
        })
        .collect()
}

/// The optional `codec` negotiation field: absent ⇒ `None` (stay on
/// JSON — the v1 compatibility default), present ⇒ a known codec name.
/// Unknown names are a decode error, never a silent JSON fallback: the
/// sender asked for something this build cannot speak, and half-agreeing
/// would desync the framing.
fn parse_codec(j: &Json) -> Result<Option<Codec>, ProtoError> {
    match j.get("codec") {
        None => Ok(None),
        Some(v) => v
            .as_str()
            .and_then(Codec::from_name)
            .map(Some)
            .ok_or_else(|| ProtoError::new("'codec' must be 'json' or 'binary'")),
    }
}

fn parse_tie(j: &Json, key: &str) -> Result<TiePolicy, ProtoError> {
    field(j, key)?
        .as_str()
        .and_then(TiePolicy::from_name)
        .ok_or_else(|| ProtoError::new(format!("'{key}' must be 'one_bit' or 'two_bit'")))
}

fn parse_cfg(j: &Json) -> Result<HiSafeConfig, ProtoError> {
    // Absent ⇒ 2: v1 peers never send the key, and q = 2 encoders omit
    // it (see cfg_json), so legacy configs round-trip unchanged.
    let precision = match j.get("precision") {
        None => 2u8,
        Some(v) => {
            let q = v
                .as_usize()
                .ok_or_else(|| ProtoError::new("'precision' must be an integer"))?;
            u8::try_from(q).map_err(|_| ProtoError::new("'precision' out of range"))?
        }
    };
    crate::quant::check_precision(precision).map_err(ProtoError::new)?;
    Ok(HiSafeConfig {
        n: parse_usize(j, "n")?,
        ell: parse_usize(j, "ell")?,
        intra: parse_tie(j, "intra")?,
        inter: parse_tie(j, "inter")?,
        sparse: field(j, "sparse")?
            .as_bool()
            .ok_or_else(|| ProtoError::new("'sparse' must be a bool"))?,
        precision,
    })
}

fn parse_qos(j: &Json) -> Result<QosPolicy, ProtoError> {
    Ok(QosPolicy {
        // Reject rather than truncate: a silently wrapped weight would
        // admit the tenant under a different dealing share than it
        // asked for (violating the lossless contract above).
        weight: u32::try_from(parse_u64_num(j, "weight")?)
            .map_err(|_| ProtoError::new("'weight' must fit in u32"))?,
        queue_depth: match field(j, "queue_depth")? {
            Json::Null => None,
            v => Some(v.as_usize().ok_or_else(|| {
                ProtoError::new("'queue_depth' must be a non-negative integer or null")
            })?),
        },
        rounds_per_sec: parse_opt_f64(j, "rounds_per_sec")?,
        triples_per_sec: parse_opt_f64(j, "triples_per_sec")?,
        burst_rounds: parse_f64(j, "burst_rounds")?,
    })
}

/// The inverse of [`set_snapshot_fields`].
fn parse_snapshot(j: &Json) -> Result<SessionSnapshot, ProtoError> {
    Ok(SessionSnapshot {
        cfg: parse_cfg(field(j, "cfg")?)?,
        d: parse_usize(j, "d")?,
        seed: parse_u64_str(j, "seed")?,
        qos: parse_qos(field(j, "qos")?)?,
        rounds: parse_u64_str(j, "rounds")?,
    })
}

fn parse_admission_error(j: &Json) -> Result<AdmissionError, ProtoError> {
    match field(j, "kind")?.as_str() {
        Some("rejected") => Ok(AdmissionError::Rejected {
            reason: field(j, "reason")?
                .as_str()
                .ok_or_else(|| ProtoError::new("'reason' must be a string"))?
                .to_string(),
        }),
        Some("throttled") => {
            let secs = parse_u64_str(j, "retry_after_secs")?;
            let nanos = parse_u64_num(j, "retry_after_subsec_ns")?;
            if nanos >= 1_000_000_000 {
                return Err(ProtoError::new("'retry_after_subsec_ns' must be < 1e9"));
            }
            Ok(AdmissionError::Throttled {
                retry_after: Duration::new(secs, nanos as u32),
            })
        }
        Some("queue_full") => Ok(AdmissionError::QueueFull { depth: parse_usize(j, "depth")? }),
        Some("churn_below_threshold") => Ok(AdmissionError::ChurnBelowThreshold {
            group: parse_usize(j, "group")?,
            survivors: parse_usize(j, "survivors")?,
            required: parse_usize(j, "required")?,
        }),
        _ => Err(ProtoError::new(
            "admission error 'kind' must be rejected|throttled|queue_full|churn_below_threshold",
        )),
    }
}

fn parse_comm_stats(j: &Json) -> Result<CommStats, ProtoError> {
    // The derived c_u_bits / c_t_bits keys in CommStats::to_json are
    // recomputed from the raw counters on the receiving side.
    Ok(CommStats {
        uplink_elems_total: parse_u64_num(j, "uplink_elems_total")?,
        uplink_elems_per_user: parse_u64_num(j, "uplink_elems_per_user")?,
        downlink_elems: parse_u64_num(j, "downlink_elems")?,
        elem_bits: parse_u64_num(j, "elem_bits")? as u32,
        subrounds: parse_u64_num(j, "subrounds")?,
        mults: parse_u64_num(j, "mults")?,
        vote_bits: parse_u64_num(j, "vote_bits")? as u32,
    })
}

fn parse_admission_stats(j: &Json) -> Result<AdmissionStats, ProtoError> {
    Ok(AdmissionStats {
        admitted_rounds: parse_u64_num(j, "admitted_rounds")?,
        throttled: parse_u64_num(j, "throttled")?,
        queue_full: parse_u64_num(j, "queue_full")?,
        rejected: parse_u64_num(j, "rejected")?,
    })
}

/// Random wire-value generators shared by the JSON properties below and
/// the binary codec's round-trip suite ([`crate::service::binary`]):
/// both codecs must survive the SAME message distribution, so the
/// distribution lives in one place.
#[cfg(test)]
pub(crate) mod testgen {
    use super::*;
    use crate::util::prop::Gen;

    pub(crate) fn rand_qos(g: &mut Gen) -> QosPolicy {
        QosPolicy {
            weight: g.range(1, 9) as u32,
            queue_depth: if g.bool() { Some(g.usize_range(1, 64)) } else { None },
            rounds_per_sec: if g.bool() { Some(g.f64() * 100.0 + 0.5) } else { None },
            triples_per_sec: if g.bool() { Some(g.f64() * 1e6 + 1.0) } else { None },
            burst_rounds: 1.0 + g.f64() * 7.0,
        }
    }

    pub(crate) fn rand_cfg(g: &mut Gen) -> HiSafeConfig {
        let ell = g.usize_range(1, 4);
        let n1 = g.usize_range(1, 6);
        HiSafeConfig {
            n: ell * n1,
            ell,
            intra: if g.bool() { TiePolicy::OneBit } else { TiePolicy::TwoBit },
            inter: if g.bool() { TiePolicy::OneBit } else { TiePolicy::TwoBit },
            sparse: g.bool(),
            precision: crate::quant::PRECISIONS[g.usize_range(0, 3)],
        }
    }

    pub(crate) fn rand_sid(g: &mut Gen) -> SessionId {
        SessionId::new(g.u64())
    }

    pub(crate) fn rand_snapshot(g: &mut Gen) -> SessionSnapshot {
        SessionSnapshot {
            cfg: rand_cfg(g),
            d: g.usize_range(1, 40),
            seed: g.u64(),
            qos: rand_qos(g),
            rounds: g.u64(),
        }
    }

    pub(crate) fn rand_sign_matrix(g: &mut Gen, rows: usize, d: usize) -> Vec<Vec<i8>> {
        (0..rows)
            .map(|_| {
                (0..d)
                    .map(|_| match g.range(0, 2) {
                        0 => -1i8,
                        1 => 0i8,
                        _ => 1i8,
                    })
                    .collect()
            })
            .collect()
    }

    pub(crate) fn rand_admission_error(g: &mut Gen) -> AdmissionError {
        match g.range(0, 3) {
            0 => AdmissionError::Rejected {
                reason: format!("reason \"{}\"\n\t{}", g.u64(), g.u64()),
            },
            1 => AdmissionError::Throttled {
                // Arbitrary u64 seconds: the decimal-string encoding must
                // carry even absurd durations losslessly.
                retry_after: Duration::new(g.u64(), g.range(0, 999_999_999) as u32),
            },
            2 => AdmissionError::QueueFull { depth: g.usize_range(1, 1 << 20) },
            _ => AdmissionError::ChurnBelowThreshold {
                group: g.usize_range(0, 64),
                survivors: g.usize_range(0, 8),
                required: g.usize_range(1, 9),
            },
        }
    }

    /// Counters ride as JSON numbers — exact below 2⁵³ (documented
    /// bound; a run would need quadrillions of rounds to exceed it).
    pub(crate) fn rand_counter(g: &mut Gen) -> u64 {
        g.range(0, 1 << 53)
    }

    pub(crate) fn rand_opt_codec(g: &mut Gen) -> Option<Codec> {
        match g.range(0, 2) {
            0 => None,
            1 => Some(Codec::Json),
            _ => Some(Codec::Binary),
        }
    }

    /// One random [`Request`], covering every variant (including the
    /// optional `present` mask and `codec` negotiation fields).
    pub(crate) fn rand_request(g: &mut Gen) -> Request {
        let cfg = rand_cfg(g);
        let d = g.usize_range(0, 40);
        match g.range(0, 10) {
            0 => Request::SessionOpen {
                cfg,
                d,
                seed: g.u64(),
                qos: rand_qos(g),
                codec: rand_opt_codec(g),
            },
            1 => Request::RoundSubmit {
                session: rand_sid(g),
                signs: rand_sign_matrix(g, cfg.n, d),
                present: if g.bool() {
                    Some((0..cfg.n).map(|_| g.bool()).collect())
                } else {
                    None
                },
            },
            2 => Request::Prefetch {
                session: rand_sid(g),
                rounds: g.usize_range(0, 1 << 20),
            },
            3 => Request::SessionClose { session: rand_sid(g) },
            4 => Request::StatsQuery {
                session: if g.bool() { Some(rand_sid(g)) } else { None },
            },
            5 => Request::SessionSnapshot { session: rand_sid(g) },
            6 => Request::SessionRestore {
                snapshot: rand_snapshot(g),
                codec: rand_opt_codec(g),
            },
            7 => Request::SessionList,
            8 => Request::SessionDiscard { session: rand_sid(g) },
            _ => Request::Shutdown,
        }
    }

    /// One random [`Response`], covering every variant.
    pub(crate) fn rand_response(g: &mut Gen) -> Response {
        match g.range(0, 4) {
            0 => {
                let ell = g.usize_range(1, 4);
                let d = g.usize_range(0, 40);
                Response::Vote(VoteReply {
                    session: rand_sid(g),
                    global_vote: rand_sign_matrix(g, 1, d).remove(0),
                    subgroup_votes: rand_sign_matrix(g, ell, d),
                    stats: CommStats {
                        uplink_elems_total: rand_counter(g),
                        uplink_elems_per_user: rand_counter(g),
                        downlink_elems: rand_counter(g),
                        elem_bits: g.range(1, 64) as u32,
                        subrounds: rand_counter(g),
                        mults: rand_counter(g),
                        vote_bits: g.range(1, 2) as u32,
                    },
                })
            }
            1 => Response::Admission(AdmissionReply {
                session: if g.bool() { Some(rand_sid(g)) } else { None },
                error: if g.bool() { Some(rand_admission_error(g)) } else { None },
                codec: rand_opt_codec(g),
            }),
            2 => Response::Snapshot(SnapshotReply {
                session: rand_sid(g),
                snapshot: rand_snapshot(g),
            }),
            3 => Response::Sessions(SessionListReply {
                sessions: (0..g.usize_range(0, 4))
                    .map(|_| SnapshotReply { session: rand_sid(g), snapshot: rand_snapshot(g) })
                    .collect(),
            }),
            _ => Response::Stats(StatsReply {
                session: if g.bool() { Some(rand_sid(g)) } else { None },
                shard: if g.bool() { Some(g.usize_range(0, 64)) } else { None },
                rounds_run: rand_counter(g),
                dealt_rounds: rand_counter(g),
                admission: AdmissionStats {
                    admitted_rounds: rand_counter(g),
                    throttled: rand_counter(g),
                    queue_full: rand_counter(g),
                    rejected: rand_counter(g),
                },
                shard_tenants: if g.bool() {
                    Some((0..g.usize_range(0, 8)).map(|_| g.usize_range(0, 99)).collect())
                } else {
                    None
                },
            }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::testgen::*;
    use super::*;
    use crate::prop_assert_eq;
    use crate::util::prop::forall;

    fn keys(v: &Json) -> Vec<String> {
        match v {
            Json::Obj(m) => m.keys().cloned().collect(),
            other => panic!("expected object, got {other:?}"),
        }
    }

    #[test]
    fn every_request_round_trips_losslessly() {
        forall("wire requests round-trip", 60, |g| {
            let req = rand_request(g);
            let text = req.to_json().to_string_compact();
            let back = Request::from_json(&crate::util::json::parse(&text).unwrap())
                .map_err(|e| e.to_string())?;
            prop_assert_eq!(&back, &req, "wire text: {text}");
            Ok(())
        });
    }

    #[test]
    fn every_response_round_trips_losslessly() {
        forall("wire responses round-trip", 60, |g| {
            let resp = rand_response(g);
            let text = resp.to_json().to_string_compact();
            let back = Response::from_json(&crate::util::json::parse(&text).unwrap())
                .map_err(|e| e.to_string())?;
            prop_assert_eq!(&back, &resp, "wire text: {text}");
            Ok(())
        });
    }

    #[test]
    fn qos_policy_round_trips_including_fractional_rates() {
        forall("QosPolicy wire round-trip", 120, |g| {
            let qos = rand_qos(g);
            let text = qos_json(&qos).to_string_compact();
            let back = parse_qos(&crate::util::json::parse(&text).unwrap())
                .map_err(|e| e.to_string())?;
            prop_assert_eq!(&back, &qos, "wire text: {text}");
            Ok(())
        });
    }

    #[test]
    fn version_and_type_gates_reject_foreign_messages() {
        // Wrong version: a v2 sender must be refused, not half-parsed.
        let mut j = Request::Shutdown.to_json();
        j.set("v", 2u64);
        let err = Request::from_json(&j).unwrap_err();
        assert!(err.msg.contains("version"), "got: {err}");
        // Missing version.
        let j = crate::util::json::parse(r#"{"type":"shutdown"}"#).unwrap();
        assert!(Request::from_json(&j).is_err());
        // Unknown type.
        let j = crate::util::json::parse(r#"{"v":1,"type":"frobnicate"}"#).unwrap();
        assert!(Request::from_json(&j).is_err());
        // Responses are gated the same way.
        let j = crate::util::json::parse(r#"{"v":9,"type":"vote_reply"}"#).unwrap();
        assert!(Response::from_json(&j).is_err());
        // Malformed sign characters are a decode error, not a panic.
        let j = crate::util::json::parse(
            r#"{"v":1,"type":"round_submit","session":"0","signs":["+x-"]}"#,
        )
        .unwrap();
        assert!(Request::from_json(&j).is_err());
        // A pre-churn (v1) frame with no `present` key decodes to
        // `present: None` — the all-present compatibility default.
        let j = crate::util::json::parse(
            r#"{"v":1,"type":"round_submit","session":"0","signs":["+-0"]}"#,
        )
        .unwrap();
        match Request::from_json(&j).unwrap() {
            Request::RoundSubmit { present, .. } => assert_eq!(present, None),
            other => panic!("wrong decode: {other:?}"),
        }
        // Malformed mask characters are a decode error too.
        let j = crate::util::json::parse(
            r#"{"v":1,"type":"round_submit","session":"0","signs":["+-0"],"present":"1x1"}"#,
        )
        .unwrap();
        assert!(Request::from_json(&j).is_err());
        // An unknown codec name is a decode error, never a silent JSON
        // fallback — half-agreeing would desync the framing.
        let mut j = Request::SessionOpen {
            cfg: HiSafeConfig::flat(3, TiePolicy::OneBit),
            d: 1,
            seed: 0,
            qos: QosPolicy::unlimited(),
            codec: None,
        }
        .to_json();
        j.set("codec", "protobuf");
        let err = Request::from_json(&j).unwrap_err();
        assert!(err.msg.contains("codec"), "got: {err}");
        // A weight that overflows u32 is rejected, never truncated (a
        // wrapped weight would admit under the wrong dealing share).
        let too_big = (u32::MAX as u64) + 2; // would truncate to 1
        let j = crate::util::json::parse(&format!(
            r#"{{"burst_rounds":1,"queue_depth":null,"rounds_per_sec":null,"triples_per_sec":null,"weight":{too_big}}}"#,
        ))
        .unwrap();
        let err = parse_qos(&j).unwrap_err();
        assert!(err.msg.contains("weight"), "got: {err}");
    }

    /// Schema snapshots: the exact key set of every wire message, so the
    /// protocol cannot drift without a conscious update here (and a
    /// version bump when the change is incompatible). Keys are listed
    /// sorted (BTreeMap order), same pattern as the CommStats /
    /// AdmissionStats snapshots in `metrics.rs`.
    #[test]
    fn wire_schema_snapshots() {
        let cfg = HiSafeConfig::hierarchical(6, 2, TiePolicy::OneBit);
        let qos = QosPolicy::unlimited().with_queue_depth(4).with_rounds_per_sec(10.0);

        let open = Request::SessionOpen { cfg, d: 3, seed: 7, qos, codec: None }.to_json();
        assert_eq!(keys(&open), ["cfg", "d", "qos", "seed", "type", "v"]);
        assert_eq!(keys(open.get("cfg").unwrap()), ["ell", "inter", "intra", "n", "sparse"]);
        assert_eq!(
            keys(open.get("qos").unwrap()),
            ["burst_rounds", "queue_depth", "rounds_per_sec", "triples_per_sec", "weight"]
        );
        // Codec negotiation is additive: `codec: None` keeps the frame
        // byte-identical to the pre-codec schema (asserted above), and a
        // requesting open adds exactly the one key.
        let open_bin =
            Request::SessionOpen { cfg, d: 3, seed: 7, qos, codec: Some(Codec::Binary) }
                .to_json();
        assert_eq!(keys(&open_bin), ["cfg", "codec", "d", "qos", "seed", "type", "v"]);
        assert_eq!(open_bin.get("codec").unwrap().as_str().unwrap(), "binary");
        // Quantized precision is additive the same way: q = 2 omits the
        // key entirely (the sign-vote frames above stay byte-identical to
        // v1), and a q > 2 open adds exactly `cfg.precision`.
        let open_q = Request::SessionOpen {
            cfg: cfg.with_precision(8),
            d: 3,
            seed: 7,
            qos,
            codec: None,
        }
        .to_json();
        assert_eq!(
            keys(open_q.get("cfg").unwrap()),
            ["ell", "inter", "intra", "n", "precision", "sparse"]
        );
        assert_eq!(open_q.get("cfg").unwrap().get("precision").unwrap().as_usize(), Some(8));

        let sid = SessionId::new(1);
        // All-present submits omit `present` entirely — the frame stays
        // byte-identical to the v1 schema, which is the compat rule the
        // field's doc advertises.
        let submit =
            Request::RoundSubmit { session: sid, signs: vec![vec![1, -1, 0]], present: None }
                .to_json();
        assert_eq!(keys(&submit), ["session", "signs", "type", "v"]);
        let submit_churn = Request::RoundSubmit {
            session: sid,
            signs: vec![vec![1, -1, 0]],
            present: Some(vec![true, false, true]),
        }
        .to_json();
        assert_eq!(keys(&submit_churn), ["present", "session", "signs", "type", "v"]);
        assert_eq!(submit_churn.get("present").unwrap().as_str().unwrap(), "101");

        assert_eq!(
            keys(&Request::Prefetch { session: sid, rounds: 2 }.to_json()),
            ["rounds", "session", "type", "v"]
        );
        assert_eq!(
            keys(&Request::SessionClose { session: sid }.to_json()),
            ["session", "type", "v"]
        );
        assert_eq!(
            keys(&Request::StatsQuery { session: Some(sid) }.to_json()),
            ["session", "type", "v"]
        );
        assert_eq!(keys(&Request::StatsQuery { session: None }.to_json()), ["type", "v"]);
        assert_eq!(
            keys(&Request::SessionSnapshot { session: sid }.to_json()),
            ["session", "type", "v"]
        );
        let snap = SessionSnapshot { cfg, d: 3, seed: 7, qos, rounds: 2 };
        let restore = Request::SessionRestore { snapshot: snap.clone(), codec: None }.to_json();
        assert_eq!(keys(&restore), ["cfg", "d", "qos", "rounds", "seed", "type", "v"]);
        let restore_bin =
            Request::SessionRestore { snapshot: snap.clone(), codec: Some(Codec::Binary) }
                .to_json();
        assert_eq!(
            keys(&restore_bin),
            ["cfg", "codec", "d", "qos", "rounds", "seed", "type", "v"]
        );
        assert_eq!(keys(&Request::SessionList.to_json()), ["type", "v"]);
        assert_eq!(
            keys(&Request::SessionDiscard { session: sid }.to_json()),
            ["session", "type", "v"]
        );
        assert_eq!(keys(&Request::Shutdown.to_json()), ["type", "v"]);

        let vote = Response::Vote(VoteReply {
            session: sid,
            global_vote: vec![1],
            subgroup_votes: vec![vec![1], vec![-1]],
            stats: CommStats::default(),
        })
        .to_json();
        assert_eq!(
            keys(&vote),
            ["global_vote", "session", "stats", "subgroup_votes", "type", "v"]
        );
        // The embedded stats object is CommStats::to_json — its key set
        // is pinned by the snapshot in metrics.rs.

        let denial = Response::Admission(AdmissionReply::denied(
            Some(sid),
            AdmissionError::Throttled { retry_after: Duration::from_millis(5) },
        ))
        .to_json();
        assert_eq!(keys(&denial), ["error", "session", "type", "v"]);
        assert_eq!(
            keys(denial.get("error").unwrap()),
            ["kind", "retry_after_secs", "retry_after_subsec_ns"]
        );
        let churn_denial = Response::Admission(AdmissionReply::denied(
            Some(sid),
            AdmissionError::ChurnBelowThreshold { group: 1, survivors: 1, required: 2 },
        ))
        .to_json();
        assert_eq!(
            keys(churn_denial.get("error").unwrap()),
            ["group", "kind", "required", "survivors"]
        );
        assert_eq!(
            keys(&Response::Admission(AdmissionReply::ok(None)).to_json()),
            ["type", "v"]
        );
        // The negotiation ack: a granting reply that confirms the codec
        // switch adds exactly the one key.
        let ack = Response::Admission(AdmissionReply {
            session: Some(sid),
            error: None,
            codec: Some(Codec::Binary),
        })
        .to_json();
        assert_eq!(keys(&ack), ["codec", "session", "type", "v"]);
        assert_eq!(ack.get("codec").unwrap().as_str().unwrap(), "binary");

        let session_stats = Response::Stats(StatsReply {
            session: Some(sid),
            shard: Some(0),
            rounds_run: 2,
            dealt_rounds: 3,
            admission: AdmissionStats::default(),
            shard_tenants: None,
        })
        .to_json();
        assert_eq!(
            keys(&session_stats),
            ["admission", "dealt_rounds", "rounds_run", "session", "shard", "type", "v"]
        );
        let frontend_stats = Response::Stats(StatsReply {
            session: None,
            shard: None,
            rounds_run: 2,
            dealt_rounds: 3,
            admission: AdmissionStats::default(),
            shard_tenants: Some(vec![1, 0]),
        })
        .to_json();
        assert_eq!(
            keys(&frontend_stats),
            ["admission", "dealt_rounds", "rounds_run", "shard_tenants", "type", "v"]
        );

        let snapshot_reply =
            Response::Snapshot(SnapshotReply { session: sid, snapshot: snap.clone() }).to_json();
        assert_eq!(
            keys(&snapshot_reply),
            ["cfg", "d", "qos", "rounds", "seed", "session", "type", "v"]
        );

        // The recovery-sweep list: each entry repeats the snapshot_reply
        // payload (sans envelope), so host-side snapshots and listed
        // snapshots can never drift apart.
        let list = Response::Sessions(SessionListReply {
            sessions: vec![SnapshotReply { session: sid, snapshot: snap }],
        })
        .to_json();
        assert_eq!(keys(&list), ["sessions", "type", "v"]);
        let entries = list.get("sessions").unwrap().as_arr().unwrap();
        assert_eq!(keys(&entries[0]), ["cfg", "d", "qos", "rounds", "seed", "session"]);
    }

    #[test]
    fn signs_are_compact_strings_not_number_arrays() {
        // The encoding decision the module doc advertises: one char per
        // coordinate, so model-sized rounds stay cheap to frame.
        let req = Request::RoundSubmit {
            session: SessionId::new(0),
            signs: vec![vec![1, -1, 0, 1]],
            present: None,
        };
        let j = req.to_json();
        let arr = j.get("signs").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_str().unwrap(), "+-0+");
    }

    #[test]
    fn quantized_signs_use_the_extended_alphabet() {
        // q-level payloads stay one self-describing char per coordinate:
        // 'A' + (v−2) for v ≥ 2, 'a' + (−v−2) for v ≤ −2. The sign
        // subset {−1, 0, +1} keeps its v1 bytes exactly.
        let req = Request::RoundSubmit {
            session: SessionId::new(0),
            signs: vec![vec![2, -2, 15, -15, 1, -1, 0]],
            present: None,
        };
        let j = req.to_json();
        let arr = j.get("signs").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_str().unwrap(), "AaNn+-0");
        // Every representable level round-trips through the alphabet.
        let all: Vec<i8> = (-15i8..=15).collect();
        let back = parse_signs(&signs_str(&all)).unwrap();
        assert_eq!(back, all);
        // Out-of-alphabet characters are a decode error.
        assert!(parse_signs(&Json::Str("O".into())).is_err());
        assert!(parse_signs(&Json::Str("o".into())).is_err());
        assert!(parse_signs(&Json::Str("9".into())).is_err());
    }

    /// Frames are newline-delimited, so compact encodings must never
    /// contain a raw newline (strings escape them as `\n`).
    #[test]
    fn encoded_messages_are_single_line() {
        let mut m = Json::obj();
        m.set("a", "x\ny");
        assert!(!m.to_string_compact().contains('\n'));
        let req = Request::SessionOpen {
            cfg: HiSafeConfig::flat(3, TiePolicy::OneBit),
            d: 2,
            seed: u64::MAX,
            qos: QosPolicy::unlimited(),
            codec: None,
        };
        let line = req.to_json().to_string_compact();
        assert!(!line.contains('\n'), "frames must stay newline-free: {line}");
        // And the u64::MAX seed survives exactly (the decimal-string rule).
        match Request::from_json(&crate::util::json::parse(&line).unwrap()).unwrap() {
            Request::SessionOpen { seed, .. } => assert_eq!(seed, u64::MAX),
            other => panic!("wrong decode: {other:?}"),
        }
    }
}
