//! The transport-agnostic service layer: Hi-SAFE aggregation behind a
//! serializable request/response protocol instead of in-process method
//! calls.
//!
//! Three files, three responsibilities:
//!
//! * [`proto`] — the versioned wire protocol: [`Request`] / [`Response`]
//!   values with lossless JSON encodings ([`QosPolicy`],
//!   [`AdmissionError`], and [`CommStats`] ride the wire unchanged,
//!   exactly as PR 4 designed them to).
//! * [`frontend`] — [`AggFrontend`], the sharded router: `K`
//!   [`AggScheduler`] shards behind rendezvous-hash tenant placement
//!   with least-loaded spill-over, plus shard drain/rebalance. The
//!   frontend speaks *only* the protocol — no caller reaches an engine
//!   directly.
//! * [`server`] — the std-only TCP transport: [`ServiceServer`]
//!   (newline-delimited JSON frames, `hisafe serve`) and the blocking
//!   [`ServiceClient`] (`hisafe sweep --remote`,
//!   [`train_remote`](crate::fl::trainer::train_remote)).
//!
//! The layering means "remote" is a transport decision, not a protocol
//! fork: the same [`AggFrontend`] serves in-process embedding (call
//! [`AggFrontend::handle`] directly) and cross-process TCP, and remote
//! votes are bit-identical to in-process ones because placement and
//! transport never touch the seed-derived triple streams
//! (`rust/tests/service_props.rs` pins `train_remote` ≡ `train` ≡
//! `run_sync`).
//!
//! [`QosPolicy`]: crate::engine::QosPolicy
//! [`AdmissionError`]: crate::engine::AdmissionError
//! [`CommStats`]: crate::metrics::CommStats
//! [`AggScheduler`]: crate::engine::AggScheduler

pub mod frontend;
pub mod proto;
pub mod server;

pub use frontend::AggFrontend;
pub use proto::{
    AdmissionReply, ProtoError, Request, Response, StatsReply, VoteReply, PROTOCOL_VERSION,
};
pub use server::{ServiceClient, ServiceError, ServiceServer};
