//! The transport-agnostic service layer: Hi-SAFE aggregation behind a
//! serializable request/response protocol instead of in-process method
//! calls — now a multi-process *cluster*, not just a single server.
//!
//! Seven files, seven responsibilities:
//!
//! * [`proto`] — the versioned wire protocol: [`Request`] / [`Response`]
//!   values with lossless JSON encodings ([`QosPolicy`],
//!   [`AdmissionError`], and [`CommStats`] ride the wire unchanged),
//!   including the `SessionSnapshot` / `SessionRestore` pair that makes
//!   a session a serializable, host-portable value, and the [`Codec`]
//!   negotiation fields.
//! * [`binary`] — the v2 length-prefixed **binary** codec for the same
//!   message values: 2 bits per sign coordinate instead of one JSON
//!   char, negotiated per-connection at `SessionOpen`/`SessionRestore`
//!   (JSON stays the always-available compatibility/debug codec).
//! * [`error`] — [`Error`], the one typed error surface every service
//!   layer produces (frontend routing, TCP transport, the balancer);
//!   non-admission variants fold to typed `Rejected` replies on the
//!   wire.
//! * [`frontend`] — [`AggFrontend`], the sharded router: `K`
//!   [`AggScheduler`] shards behind **per-shard locks** (K shards serve
//!   K wire rounds in parallel), rendezvous-hash tenant placement with
//!   least-loaded spill-over, shard drain/rebalance, and shard-death
//!   absorption with transparent bit-identical session restore.
//! * [`server`] — the std-only TCP transport: [`ServiceServer`]
//!   (newline-delimited JSON frames or negotiated binary frames, a
//!   bounded connection-worker pool, `hisafe serve`) and the blocking
//!   [`ServiceClient`]
//!   (`hisafe sweep --remote`,
//!   [`train_remote`](crate::fl::trainer::train_remote)).
//! * [`balancer`] — [`Balancer`] (`hisafe balance`): a fail-over load
//!   balancer fronting several `serve` hosts, with health checks,
//!   dead-host detection, snapshot-based session fail-over that keeps
//!   votes bit-identical across a mid-sweep host kill, host re-join
//!   reconciliation, and session-table rebuild after a balancer
//!   restart.
//! * [`faults`] — the deterministic chaos harness: a seeded
//!   [`FaultPlan`](faults::FaultPlan) scripting host kills/revives,
//!   frame corruption/truncation, shard poison, and balancer restarts
//!   against a real in-process cluster, asserting the bit-identical
//!   vote invariant and zero leaked sessions after every schedule
//!   (`rust/tests/chaos_props.rs`, `hisafe sweep --chaos-seed`).
//!
//! The layering means "remote" is a transport decision, not a protocol
//! fork: the same [`AggFrontend`] serves in-process embedding (call
//! [`AggFrontend::handle`] directly) and cross-process TCP, the
//! balancer speaks the identical protocol on both of its sides, and
//! votes are bit-identical everywhere because placement, transport, and
//! fail-over never touch the seed-derived triple streams
//! (`rust/tests/service_props.rs` pins `train_remote` ≡ `train` ≡
//! `run_sync`, including across shard kills and host fail-over).
//!
//! [`QosPolicy`]: crate::engine::QosPolicy
//! [`AdmissionError`]: crate::engine::AdmissionError
//! [`CommStats`]: crate::metrics::CommStats
//! [`AggScheduler`]: crate::engine::AggScheduler

pub mod balancer;
pub mod binary;
pub mod error;
pub mod faults;
pub mod frontend;
pub mod proto;
pub mod server;

pub use balancer::{Balancer, BalancerHandle};
pub use error::Error;
pub use frontend::AggFrontend;
pub use proto::{
    AdmissionReply, Codec, ProtoError, Request, Response, SessionListReply, SnapshotReply,
    StatsReply, VoteReply, PROTOCOL_VERSION,
};
pub use server::{ServiceClient, ServiceServer};
