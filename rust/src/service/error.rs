//! The single service-layer error surface: everything that can go wrong
//! between a caller and an aggregation round, as one typed enum.
//!
//! Before this module the service layer had two error vocabularies — a
//! transport-side `ServiceError` in `server.rs` and ad-hoc rejection
//! strings minted inside `frontend.rs` — so a caller matching "was that
//! a throttle or a dead connection?" had to know which layer it was
//! talking to. Now every layer (frontend routing, TCP transport, the
//! balancer) produces [`Error`], and clients match one enum:
//!
//! * [`Error::Admission`] — the service *declined* a well-formed
//!   request ([`AdmissionError`] crossing layers unchanged, so a remote
//!   caller retries `Throttled` exactly like a local one).
//! * [`Error::UnknownSession`] — a session id that names no live
//!   session (closed, never granted, or lost with a dead host before a
//!   snapshot could be taken).
//! * [`Error::Io`] / [`Error::Proto`] — the transport failed or the
//!   bytes were malformed; only remote paths produce these.
//! * [`Error::NoLiveHosts`] — the balancer has no healthy backend left
//!   to place or fail a session over to.
//! * [`Error::Unexpected`] — a reply of the wrong shape, or an internal
//!   invariant surfaced as an error instead of a panic.
//!
//! On the wire, errors that are not already [`AdmissionError`]s travel
//! as [`AdmissionError::Rejected`] with a descriptive reason (see
//! [`Error::into_admission`]): the wire schema is unchanged, only the
//! in-process type is unified.

use std::fmt;
use std::io;

use crate::engine::{AdmissionError, SessionId};

use super::proto::ProtoError;

/// The unified service-layer error. See the module docs for the
/// variant-by-variant contract.
#[derive(Debug)]
pub enum Error {
    /// The transport failed (connect, read, write). Remote paths only.
    Io(io::Error),
    /// The bytes were malformed (bad version, missing field) — distinct
    /// from a typed denial of a well-formed request.
    Proto(ProtoError),
    /// The service declined the request: throttled, queue-full, or
    /// rejected, with the same payloads the in-process scheduler uses.
    Admission(AdmissionError),
    /// The session id names no live session on this frontend/balancer.
    UnknownSession(SessionId),
    /// Every backend host the balancer knows is marked dead.
    NoLiveHosts,
    /// A structurally valid but semantically wrong reply (e.g. a vote
    /// where an ack was expected), or an internal invariant break
    /// reported instead of panicking.
    Unexpected(String),
}

impl Error {
    /// The wire form of this error: [`AdmissionError`] is the only
    /// error type the protocol carries, so everything else folds into
    /// [`AdmissionError::Rejected`] with a descriptive reason. Lossy by
    /// design for the non-admission variants (the wire schema predates
    /// them and stays unversioned); [`Error::Admission`] is lossless.
    pub fn into_admission(self) -> AdmissionError {
        match self {
            Error::Admission(e) => e,
            Error::UnknownSession(sid) => {
                AdmissionError::Rejected { reason: format!("unknown session {sid}") }
            }
            other => AdmissionError::Rejected { reason: other.to_string() },
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Io(e) => write!(f, "service transport error: {e}"),
            Error::Proto(e) => write!(f, "{e}"),
            Error::Admission(e) => write!(f, "service denied request: {e}"),
            Error::UnknownSession(sid) => write!(f, "unknown session {sid}"),
            Error::NoLiveHosts => write!(f, "no live backend hosts"),
            Error::Unexpected(msg) => write!(f, "unexpected service state: {msg}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            Error::Proto(e) => Some(e),
            Error::Admission(e) => Some(e),
            _ => None,
        }
    }
}

impl From<io::Error> for Error {
    fn from(e: io::Error) -> Error {
        Error::Io(e)
    }
}

impl From<ProtoError> for Error {
    fn from(e: ProtoError) -> Error {
        Error::Proto(e)
    }
}

impl From<AdmissionError> for Error {
    fn from(e: AdmissionError) -> Error {
        Error::Admission(e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_folding_keeps_admission_lossless_and_names_unknown_sessions() {
        let throttle = AdmissionError::Throttled {
            retry_after: std::time::Duration::from_millis(3),
        };
        // Admission errors cross into wire form unchanged.
        assert_eq!(Error::Admission(throttle.clone()).into_admission(), throttle);
        // Unknown sessions keep the "unknown session <id>" phrasing the
        // pre-unification frontend minted (clients grep for it).
        match Error::UnknownSession(SessionId::new(7)).into_admission() {
            AdmissionError::Rejected { reason } => {
                assert!(reason.contains("unknown session 7"), "reason: {reason}")
            }
            other => panic!("expected Rejected, got {other:?}"),
        }
        // Everything else folds to Rejected with its Display text.
        match Error::NoLiveHosts.into_admission() {
            AdmissionError::Rejected { reason } => {
                assert!(reason.contains("no live backend hosts"), "reason: {reason}")
            }
            other => panic!("expected Rejected, got {other:?}"),
        }
    }

    #[test]
    fn display_distinguishes_layers() {
        let io = Error::Io(io::Error::new(io::ErrorKind::ConnectionReset, "peer gone"));
        assert!(io.to_string().contains("transport"));
        let denied = Error::Admission(AdmissionError::QueueFull { depth: 4 });
        assert!(denied.to_string().contains("denied"));
    }
}
